//! Mini α-sweep (Fig. 2): how the PWR weight trades power savings
//! against GPU fragmentation on a scaled-down cluster.
//!
//! Run: `cargo run --release --example alpha_sweep -- [scale] [reps]`

use repro::cluster::ClusterSpec;
use repro::metrics::{average_on_grid, capacity_grid, Column};
use repro::sched::PolicyKind;
use repro::sim::{run_repetitions, RepeatConfig};
use repro::trace::TraceSpec;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale: f64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(0.1);
    let reps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3);

    let cluster = ClusterSpec::paper_scaled(scale);
    let trace = TraceSpec::default_trace();
    let cfg = RepeatConfig { reps, base_seed: 7, target_ratio: 1.0, ..Default::default() };
    let grid = capacity_grid(1.0, 0.05);

    println!(
        "alpha sweep on {} nodes / {} GPUs ({} reps)",
        cluster.total_nodes(),
        cluster.total_gpus(),
        reps
    );

    let fgd_runs = run_repetitions(&cluster, &trace, PolicyKind::Fgd, &cfg);
    let fgd_series: Vec<_> = fgd_runs.into_iter().map(|r| r.series).collect();
    let fgd_eopc = average_on_grid(&fgd_series, Column::Eopc, &grid);

    println!("\n  alpha   savings@50%   savings@80%   final GRAR");
    for alpha in [0.0, 0.02, 0.05, 0.1, 0.2, 0.5, 0.8, 1.0] {
        let policy = match alpha {
            a if a <= 0.0 => PolicyKind::Fgd,
            a if a >= 1.0 => PolicyKind::Pwr,
            a => PolicyKind::PwrFgd { alpha: a },
        };
        let runs = run_repetitions(&cluster, &trace, policy, &cfg);
        let grar = runs.iter().map(|r| r.final_grar()).sum::<f64>() / runs.len() as f64;
        let series: Vec<_> = runs.into_iter().map(|r| r.series).collect();
        let eopc = average_on_grid(&series, Column::Eopc, &grid);
        let sav = |x: f64| {
            let i = grid.iter().position(|&g| (g - x).abs() < 1e-9).unwrap();
            100.0 * (fgd_eopc[i] - eopc[i]) / fgd_eopc[i]
        };
        println!(
            "  {:>5.2}   {:>9.2} %   {:>9.2} %   {:>9.4}",
            alpha,
            sav(0.5),
            sav(0.8),
            grar
        );
    }
    println!("\nexpected shape (paper Fig. 2): savings grow with alpha and");
    println!("plateau past ~0.2, while GRAR degrades slightly; α ∈ {{0.05, 0.1, 0.2}}");
    println!("strike the best compromise.");
}
