//! Quickstart: build a small GPU cluster, schedule a handful of tasks
//! with the paper's combined PWR+FGD policy, and inspect power and
//! fragmentation.
//!
//! Run: `cargo run --release --example quickstart`

use repro::cluster::ClusterSpec;
use repro::frag;
use repro::power;
use repro::sched::{PolicyKind, Scheduler};
use repro::tasks::{GpuDemand, Task, TaskConstraints};
use repro::trace::TraceSpec;

fn main() {
    // A 16-node slice of the paper's datacenter mix.
    let mut dc = ClusterSpec::paper_scaled(0.02).build();
    println!(
        "cluster: {} nodes, {} GPUs, {} vCPUs",
        dc.nodes.len(),
        dc.total_gpus(),
        dc.total_vcpus()
    );

    // Target workload M (Table-I-calibrated trace).
    let workload = TraceSpec::default_trace().synthesize(7).workload();
    println!("workload classes: {}", workload.classes().len());

    // The paper's sweet spot: alpha = 0.1 (PWR100+FGD900).
    let mut sched = Scheduler::from_policy(PolicyKind::PwrFgd { alpha: 0.1 });

    let tasks = vec![
        Task::new(0, 8.0, 16_384.0, GpuDemand::Whole(1)),
        Task::new(1, 4.0, 8_192.0, GpuDemand::Frac(0.5)),
        Task::new(2, 4.0, 8_192.0, GpuDemand::Frac(0.5)), // should share with task 1
        Task::new(3, 16.0, 32_768.0, GpuDemand::Whole(8)),
        Task::new(4, 2.0, 4_096.0, GpuDemand::Zero),
        // A constrained task: only T4-class GPUs are acceptable (the
        // `filter` extension point enforces it — see docs/scheduler.md).
        Task::new(5, 4.0, 8_192.0, GpuDemand::Whole(1)).with_constraints(TaskConstraints {
            gpu_models: vec![repro::cluster::types::GpuModel::T4],
            ..Default::default()
        }),
    ];

    println!("\nidle EOPC: {:.2} kW", power::p_datacenter(&dc) / 1e3);
    for task in &tasks {
        match sched.schedule(&dc, &workload, task) {
            Some(d) => {
                println!(
                    "task {} (cpu {:>4}, gpu {:?}) -> node {:>3} ({:?}) [{}]",
                    task.id,
                    task.cpu,
                    task.gpu,
                    d.node,
                    d.placement,
                    dc.nodes[d.node]
                        .gpu_model
                        .map(|m| m.to_string())
                        .unwrap_or_else(|| "cpu-only".into()),
                );
                dc.allocate(task, d.node, &d.placement);
                sched.notify_node_changed(d.node);
            }
            None => println!("task {} could not be scheduled", task.id),
        }
    }

    let (cpu_w, gpu_w) = power::p_datacenter_split(&dc);
    println!("\nafter scheduling:");
    println!("  EOPC           {:.2} kW (cpu {:.2} / gpu {:.2})", (cpu_w + gpu_w) / 1e3, cpu_w / 1e3, gpu_w / 1e3);
    println!("  active nodes   {}", dc.active_nodes());
    println!("  active GPUs    {}", dc.active_gpus());
    println!("  fragmentation  {:.3} GPU units", frag::f_datacenter(&dc, &workload));
}
