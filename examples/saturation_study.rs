//! End-to-end driver (the headline experiment): inflate the full
//! 1,213-node / 6,212-GPU datacenter with Monte-Carlo workloads from
//! the Default trace under plain FGD and under the paper's selected
//! PWR⊕FGD combination, and report the power-savings curve — the
//! paper's headline claim (>13% savings until ~80% requested capacity,
//! Fig. 3).
//!
//! Run: `cargo run --release --example saturation_study -- [scale] [reps]`
//! (defaults: scale 1.0 — the full cluster — and 3 repetitions).

use repro::cluster::ClusterSpec;
use repro::metrics::{average_on_grid, capacity_grid, savings_pct, Column};
use repro::sched::PolicyKind;
use repro::sim::{run_repetitions, RepeatConfig};
use repro::trace::TraceSpec;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale: f64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let reps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3);

    let cluster = if scale >= 1.0 {
        ClusterSpec::paper_default()
    } else {
        ClusterSpec::paper_scaled(scale)
    };
    let trace = TraceSpec::default_trace();
    println!(
        "saturation study: {} nodes / {} GPUs, {} reps, Default trace",
        cluster.total_nodes(),
        cluster.total_gpus(),
        reps
    );

    let cfg = RepeatConfig { reps, base_seed: 42, target_ratio: 1.02, ..Default::default() };
    let grid = capacity_grid(1.0, 0.05);

    let t0 = std::time::Instant::now();
    println!("running plain FGD…");
    let fgd_runs = run_repetitions(&cluster, &trace, PolicyKind::Fgd, &cfg);
    let fgd_series: Vec<_> = fgd_runs.iter().map(|r| r.series.clone()).collect();
    let fgd_eopc = average_on_grid(&fgd_series, Column::Eopc, &grid);
    let fgd_grar = average_on_grid(&fgd_series, Column::Grar, &grid);

    println!("running PWR100+FGD900 (α=0.1)…");
    let combo = PolicyKind::PwrFgd { alpha: 0.1 };
    let combo_runs = run_repetitions(&cluster, &trace, combo, &cfg);
    let combo_series: Vec<_> = combo_runs.iter().map(|r| r.series.clone()).collect();
    let combo_eopc = average_on_grid(&combo_series, Column::Eopc, &grid);
    let combo_grar = average_on_grid(&combo_series, Column::Grar, &grid);

    let savings = savings_pct(&fgd_eopc, &combo_eopc);
    println!("\n capacity   FGD EOPC    α=0.1 EOPC   savings   GRAR(FGD)  GRAR(α=0.1)");
    for (i, &x) in grid.iter().enumerate() {
        println!(
            "   {:>5.2}  {:>8.1} kW  {:>8.1} kW  {:>6.2} %   {:>7.4}   {:>7.4}",
            x,
            fgd_eopc[i] / 1e3,
            combo_eopc[i] / 1e3,
            savings[i],
            fgd_grar[i],
            combo_grar[i]
        );
    }

    // Headline: savings in the mid-load region (paper: >13% until ~80%).
    let mid: Vec<f64> = grid
        .iter()
        .zip(&savings)
        .filter(|(&x, _)| (0.2..=0.8).contains(&x))
        .map(|(_, &s)| s)
        .collect();
    let mid_avg = repro::util::stats::mean(&mid);
    let decisions: u64 = fgd_runs.iter().chain(&combo_runs).map(|r| r.submitted).sum();
    println!(
        "\nheadline: mean savings over 20–80% capacity = {:.1}% (paper: >13%)",
        mid_avg
    );
    println!(
        "simulated {} scheduling decisions in {:.1}s",
        decisions,
        t0.elapsed().as_secs_f64()
    );
}
