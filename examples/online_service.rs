//! Online-serving demo: start the coordinator service on a local TCP
//! port, drive it with a concurrent stream of task submissions over the
//! JSON-lines protocol, and report scheduling latency/throughput — the
//! deployable form of the paper's Kubernetes score plugin.
//!
//! Run: `cargo run --release --example online_service -- [n_tasks] [n_clients]`

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use repro::cluster::ClusterSpec;
use repro::coordinator::{CoordinatorState, Server};
use repro::sched::PolicyKind;
use repro::trace::TraceSpec;
use repro::util::stats;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_tasks: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let n_clients: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    let spec = TraceSpec::default_trace();
    let workload = spec.synthesize(7).workload();
    let state = CoordinatorState::new(
        ClusterSpec::paper_scaled(0.25).build(),
        PolicyKind::PwrFgd { alpha: 0.1 },
        workload,
    );
    let server = Server::bind("127.0.0.1:0", state).expect("bind");
    let port = server.port();
    let shared = server.state();
    let server_thread = std::thread::spawn(move || server.run().unwrap());
    println!("coordinator on 127.0.0.1:{port} (policy PWR100+FGD900)");

    let t0 = std::time::Instant::now();
    let per_client = n_tasks / n_clients;
    let clients: Vec<_> = (0..n_clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut sampler = TraceSpec::default_trace().sampler(100 + c as u64);
                let conn = TcpStream::connect(("127.0.0.1", port)).expect("connect");
                conn.set_nodelay(true).unwrap();
                let mut writer = conn.try_clone().unwrap();
                let mut reader = BufReader::new(conn);
                let mut latencies_us = Vec::with_capacity(per_client);
                let mut scheduled = 0usize;
                let mut line = String::new();
                for i in 0..per_client {
                    let task = sampler.next_task();
                    let req = format!(
                        "{{\"op\":\"submit\",\"id\":{},\"cpu\":{},\"mem\":{},\"gpu\":{}}}\n",
                        (c * 1_000_000 + i),
                        task.cpu,
                        task.mem,
                        task.gpu.units()
                    );
                    let t = std::time::Instant::now();
                    writer.write_all(req.as_bytes()).unwrap();
                    line.clear();
                    reader.read_line(&mut line).unwrap();
                    latencies_us.push(t.elapsed().as_micros() as f64);
                    if line.contains("\"ok\":true") {
                        scheduled += 1;
                    }
                }
                (latencies_us, scheduled)
            })
        })
        .collect();

    let mut all_lat = Vec::new();
    let mut total_sched = 0usize;
    for c in clients {
        let (lat, sched) = c.join().unwrap();
        all_lat.extend(lat);
        total_sched += sched;
    }
    let dt = t0.elapsed().as_secs_f64();

    println!("\nsubmitted {n_tasks} tasks from {n_clients} clients in {dt:.2}s");
    println!("  throughput  {:.0} decisions/s", n_tasks as f64 / dt);
    println!(
        "  latency     p50 {:.0} µs | p95 {:.0} µs | p99 {:.0} µs",
        stats::percentile(&all_lat, 50.0),
        stats::percentile(&all_lat, 95.0),
        stats::percentile(&all_lat, 99.0)
    );
    println!("  scheduled   {total_sched} / {n_tasks}");
    {
        let st = shared.lock().unwrap();
        let stats_json = st.stats();
        println!("  server view {}", stats_json.dump());
    }

    // Shut the server down cleanly.
    let mut conn = TcpStream::connect(("127.0.0.1", port)).unwrap();
    conn.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
    let mut line = String::new();
    let _ = BufReader::new(conn).read_line(&mut line);
    server_thread.join().unwrap();
}
