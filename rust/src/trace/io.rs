//! Trace import/export in the Alibaba *openb_pod_list* CSV schema used
//! by the FGD artifact (the 2023 Alibaba GPU trace release).
//!
//! Users holding the real trace CSVs can load them directly instead of
//! the Table-I-calibrated synthesizer: columns `cpu_milli` (vCPU
//! millicores), `memory_mib`, `num_gpu` (whole GPUs), `gpu_milli`
//! (fraction of one GPU when `num_gpu == 1` and sharing), and
//! `gpu_spec` (model constraint, empty = unconstrained). Extra columns
//! are ignored; export writes the same schema.

use anyhow::{bail, Context, Result};

use crate::cluster::types::GpuModel;
use crate::tasks::{GpuDemand, Task};
use crate::trace::Trace;
use crate::util::csv::read_csv;

/// Parse a trace from openb_pod_list CSV text.
pub fn parse_csv(name: &str, text: &str) -> Result<Trace> {
    let (header, rows) = read_csv(text);
    let col = |n: &str| header.iter().position(|h| h == n);
    let c_cpu = col("cpu_milli").context("missing column cpu_milli")?;
    let c_mem = col("memory_mib").context("missing column memory_mib")?;
    let c_ngpu = col("num_gpu").context("missing column num_gpu")?;
    let c_gmilli = col("gpu_milli"); // absent in CPU-only exports
    let c_spec = col("gpu_spec");

    let mut tasks = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        let ctx = || format!("row {}", i + 2);
        let get = |c: usize| -> Result<f64> {
            let v = row.get(c).map(|s| s.trim()).unwrap_or("");
            if v.is_empty() {
                Ok(0.0)
            } else {
                v.parse::<f64>().with_context(|| format!("{}: bad number '{v}'", ctx()))
            }
        };
        let cpu = get(c_cpu)? / 1000.0;
        let mem = get(c_mem)?;
        let num_gpu = get(c_ngpu)?;
        let gpu_milli = c_gmilli.map(get).transpose()?.unwrap_or(0.0);
        let gpu = if num_gpu == 0.0 {
            GpuDemand::Zero
        } else if num_gpu == 1.0 && gpu_milli > 0.0 && gpu_milli < 1000.0 {
            GpuDemand::Frac(gpu_milli / 1000.0)
        } else if num_gpu.fract() == 0.0 && num_gpu >= 1.0 {
            GpuDemand::Whole(num_gpu as u32)
        } else {
            bail!("{}: invalid GPU demand num_gpu={num_gpu} gpu_milli={gpu_milli}", ctx());
        };
        let gpu_model = match c_spec.and_then(|c| row.get(c)).map(|s| s.trim()) {
            None | Some("") => None,
            Some(spec) => {
                // The trace uses pipe-separated alternatives; we take
                // the first recognizable model and ignore the rest.
                spec.split('|').find_map(GpuModel::parse)
            }
        };
        tasks.push(Task {
            id: i as u64,
            cpu,
            mem,
            gpu,
            gpu_model,
            constraints: None,
            gang: None,
            priority: 0,
        });
    }
    Ok(Trace { name: name.to_string(), tasks })
}

/// Load a trace from an openb_pod_list CSV file.
pub fn load_csv(path: &std::path::Path) -> Result<Trace> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let name = path.file_stem().map(|s| s.to_string_lossy().to_string()).unwrap_or_default();
    parse_csv(&name, &text)
}

/// Serialize a trace to openb_pod_list CSV text.
pub fn to_csv(trace: &Trace) -> String {
    let mut out = String::from("name,cpu_milli,memory_mib,num_gpu,gpu_milli,gpu_spec\n");
    for t in &trace.tasks {
        let (num_gpu, gpu_milli) = match t.gpu {
            GpuDemand::Zero => (0, 0),
            GpuDemand::Frac(f) => (1, (f * 1000.0).round() as i64),
            GpuDemand::Whole(k) => (k as i64, 1000),
            // The openb schema has no MIG column; export the slice
            // fraction as a sharing request (lossy, documented).
            GpuDemand::Mig(p) => (1, (p.units() * 1000.0).round() as i64),
        };
        let spec = t.gpu_model.map(|m| m.to_string()).unwrap_or_default();
        out.push_str(&format!(
            "task-{},{},{},{},{},{}\n",
            t.id,
            (t.cpu * 1000.0).round() as i64,
            t.mem.round() as i64,
            num_gpu,
            gpu_milli,
            spec
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceSpec;

    const SAMPLE: &str = "\
name,cpu_milli,memory_mib,num_gpu,gpu_milli,gpu_spec
openb-pod-0001,4000,12288,0,0,
openb-pod-0002,2000,8192,1,500,
openb-pod-0003,8000,32768,2,1000,
openb-pod-0004,16000,65536,1,1000,V100M16|V100M32
openb-pod-0005,1000,4096,1,250,T4
";

    #[test]
    fn parses_all_demand_kinds() {
        let trace = parse_csv("sample", SAMPLE).unwrap();
        assert_eq!(trace.tasks.len(), 5);
        assert_eq!(trace.tasks[0].gpu, GpuDemand::Zero);
        assert_eq!(trace.tasks[0].cpu, 4.0);
        assert_eq!(trace.tasks[1].gpu, GpuDemand::Frac(0.5));
        assert_eq!(trace.tasks[2].gpu, GpuDemand::Whole(2));
        // whole-GPU with gpu_milli=1000 is Whole(1), not Frac
        assert_eq!(trace.tasks[3].gpu, GpuDemand::Whole(1));
        assert_eq!(trace.tasks[3].gpu_model, Some(GpuModel::V100M16));
        assert_eq!(trace.tasks[4].gpu_model, Some(GpuModel::T4));
        assert_eq!(trace.tasks[4].mem, 4096.0);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_csv("x", "cpu_milli,num_gpu\n1,1\n").is_err()); // no memory col
        let bad = "name,cpu_milli,memory_mib,num_gpu,gpu_milli,gpu_spec\np,abc,1,0,0,\n";
        assert!(parse_csv("x", bad).is_err());
        let bad = "name,cpu_milli,memory_mib,num_gpu,gpu_milli,gpu_spec\np,1000,1,1.5,0,\n";
        assert!(parse_csv("x", bad).is_err());
    }

    #[test]
    fn roundtrip_synthesized_trace() {
        let trace = TraceSpec::constrained_gpu(0.25).synthesize(3);
        let csv = to_csv(&trace);
        let back = parse_csv(&trace.name, &csv).unwrap();
        assert_eq!(back.tasks.len(), trace.tasks.len());
        for (a, b) in trace.tasks.iter().zip(&back.tasks) {
            assert_eq!(a.gpu.bucket(), b.gpu.bucket());
            assert!((a.cpu - b.cpu).abs() < 1e-9);
            assert!((a.gpu.units() - b.gpu.units()).abs() < 1e-3);
            assert_eq!(a.gpu_model, b.gpu_model);
        }
        // Statistical identity: bucket marginals survive the roundtrip.
        let (pa, pb) = (trace.population_pct(), back.population_pct());
        for (x, y) in pa.iter().zip(&pb) {
            assert!((x - y).abs() < 1e-9);
        }
    }

    #[test]
    fn workload_extraction_from_imported_trace() {
        let trace = parse_csv("sample", SAMPLE).unwrap();
        let w = trace.workload();
        assert_eq!(w.classes().len(), 5);
        assert!((w.total_pop() - 1.0).abs() < 1e-12);
    }
}
