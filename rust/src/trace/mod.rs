//! Trace synthesis and workload generation (§V-A).
//!
//! The 2023 Alibaba GPU trace is not redistributable, so this module
//! synthesizes traces calibrated to the paper's published statistics:
//! Table I pins the per-bucket task population and GPU-request shares of
//! the **Default** trace (8,152 tasks); §V-A describes how the
//! **multi-GPU**, **sharing-GPU** and **constrained-GPU** traces are
//! derived from it. All evaluated policies are functions of the joint
//! (CPU, MEM, GPU, constraint) demand distribution, which is exactly
//! what is being reproduced here.
//!
//! Workloads are produced by the paper's *Monte-Carlo workload
//! inflation*: tasks are sampled from the trace with replacement and
//! submitted until the cluster saturates ([`InflationSampler`]).

pub mod io;

use crate::cluster::mig::MigProfile;
use crate::cluster::types::GpuModel;
use crate::tasks::{GangSpec, GpuDemand, Task, TaskConstraints, Workload, NUM_BUCKETS};
use crate::util::rng::{Rng, WeightedIndex};

/// How sampled tasks of a profile get their declarative
/// [`TaskConstraints`] — the `constrained-<pct>` trace families (the
/// legacy single-model pin keeps its own [`TaskProfile::constrained`]
/// flag for the paper's `constrained-gpu-*` traces).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum ConstraintGen {
    /// No declarative constraints.
    #[default]
    None,
    /// Tenant isolation: the task joins one of [`N_TENANTS`] tenant
    /// classes and is anti-affine to every other tenant's tasks
    /// (Zambianco et al.'s multi-tenant MIG-cloud setting).
    Tenant,
    /// Instance-type restriction: a sampled two-model GPU set (models
    /// drawn ∝ their share of cluster GPUs, so demand is serviceable in
    /// expectation).
    ModelSet,
    /// Blast-radius spread: at most [`SPREAD_MAX_PER_NODE`] tasks of
    /// the task's demand-bucket class per node.
    Spread,
}

/// Tenant classes of [`ConstraintGen::Tenant`].
pub const N_TENANTS: usize = 4;
/// Per-node cap of [`ConstraintGen::Spread`].
pub const SPREAD_MAX_PER_NODE: u32 = 4;

/// Sinusoidal arrival-rate modulation of the `diurnal-<amp>` trace
/// family: `rate(t) = base_rate · (1 + amplitude · sin(2πt/period))`,
/// clamped to ≥ 5% of the base rate. Only the steady-state loop
/// ([`crate::sim::events::SteadySim`]) has an arrival clock, so only
/// it reads this; Monte-Carlo inflation sees the plain catalog (which
/// for `diurnal-*` equals the Default trace's). The valleys are what
/// the DRS subsystem (`docs/power.md`) converts into slept nodes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DiurnalMod {
    /// Relative swing of the instantaneous arrival rate, ∈ [0, 1].
    pub amplitude: f64,
    /// Day length in simulated seconds.
    pub period_s: f64,
}

/// Default day length of [`TraceSpec::diurnal`] (two full cycles fit
/// the default [`crate::sim::events::SteadyConfig`] horizon).
pub const DIURNAL_PERIOD_S: f64 = 10_000.0;

/// One demand profile in a trace's catalog.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskProfile {
    pub cpu: f64,
    pub mem: f64,
    pub gpu: GpuDemand,
    /// If true, sampled tasks are pinned to a concrete GPU model
    /// (chosen ∝ the model's share of cluster GPUs, so that demand is
    /// serviceable in expectation).
    pub constrained: bool,
    /// Declarative-constraint generator for sampled tasks.
    pub constraint: ConstraintGen,
    /// Model-parallel gang shape (the `gang-<pct>` family). The
    /// profile's demand fields hold the gang *totals*, matching
    /// [`crate::sched::gang::gang_task`]; `None` for ordinary tasks.
    pub gang: Option<GangSpec>,
    /// Tenant priority stamped on sampled tasks (the `priority-<pct>`
    /// family; 0 everywhere else). Assigned statically per profile, so
    /// priority-free traces draw no extra randomness.
    pub priority: u8,
}

/// A declarative trace: weighted profile catalog + nominal size.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub name: String,
    pub profiles: Vec<(TaskProfile, f64)>,
    /// Nominal trace size (the paper's Default has 8,152 tasks).
    pub n_tasks: usize,
    /// Arrival-rate modulation (the `diurnal-<amp>` family); `None`
    /// for every other trace — and the `None` path must not perturb
    /// the RNG stream, so legacy runs stay bit-identical.
    pub diurnal: Option<DiurnalMod>,
}

/// Table I, row "Task Population (%)": buckets `0, (0,1), 1, 2, 4, 8`.
pub const TABLE1_POPULATION: [f64; NUM_BUCKETS] = [13.3, 37.8, 48.0, 0.2, 0.2, 0.5];
/// Table I, row "Total GPU Reqs. (%)".
pub const TABLE1_GPU_SHARE: [f64; NUM_BUCKETS] = [0.0, 28.5, 64.2, 0.5, 1.0, 5.8];

/// Fractional-GPU request values and weights. Mean ≈ 0.564, which makes
/// the synthesized bucket GPU-request shares match Table I row 2
/// (28.5% from sharing tasks vs 64.2% from 1-GPU tasks).
const FRAC_VALUES: [f64; 5] = [0.25, 0.5, 0.6, 0.75, 0.8];
const FRAC_WEIGHTS: [f64; 5] = [0.18, 0.35, 0.12, 0.20, 0.15];

/// Per-bucket CPU demand options (vCPUs) and weights. Calibrated so the
/// trace's vCPU:GPU demand ratio (~7.7 vCPU per GPU unit) sits below the
/// cluster's 17.2 installed ratio — the paper's cluster is GPU-bound.
const CPU_ONLY_CPUS: [f64; 6] = [2.0, 4.0, 8.0, 16.0, 32.0, 64.0];
const CPU_ONLY_WEIGHTS: [f64; 6] = [0.15, 0.20, 0.25, 0.20, 0.12, 0.08];
const FRAC_TASK_CPUS: [f64; 4] = [2.0, 4.0, 8.0, 12.0];
const FRAC_TASK_CPU_WEIGHTS: [f64; 4] = [0.25, 0.35, 0.25, 0.15];
const ONE_GPU_CPUS: [f64; 5] = [4.0, 8.0, 10.0, 12.0, 16.0];
const ONE_GPU_CPU_WEIGHTS: [f64; 5] = [0.20, 0.30, 0.20, 0.20, 0.10];

/// Memory demand: GiB per vCPU (MiB factor). Keeps memory comfortably
/// non-binding, matching the paper's CPU/GPU-centric analysis.
const MEM_PER_VCPU_MIB: f64 = 3072.0;

/// Per-member vCPU demand of the `gang-<pct>` family's gang shapes
/// (memory follows [`MEM_PER_VCPU_MIB`], like every other profile).
pub const GANG_MEMBER_VCPUS: f64 = 8.0;

/// The four gang shapes of the `gang-<pct>` family, with their share of
/// the converted whole-GPU mass: (tp, pp, dp, share). Spans 4–16 GPUs,
/// mixing NVLink-only (dp=1, pp=2 fits two nodes) and replicated jobs.
pub const GANG_SHAPES: [(u32, u32, u32, f64); 4] = [
    (2, 2, 1, 0.35), // 4 GPUs: 2 members of 2
    (4, 2, 1, 0.25), // 8 GPUs: 2 members of 4
    (2, 2, 2, 0.25), // 8 GPUs: 4 members of 2
    (4, 2, 2, 0.15), // 16 GPUs: 4 members of 4
];

fn profile(cpu: f64, gpu: GpuDemand) -> TaskProfile {
    TaskProfile {
        cpu,
        mem: cpu * MEM_PER_VCPU_MIB,
        gpu,
        constrained: false,
        constraint: ConstraintGen::None,
        gang: None,
        priority: 0,
    }
}

/// The priority tiers of the `priority-<pct>` family and their share of
/// the elevated mass: a deliberately skewed tenant mix — a thin
/// latency-critical tier over a broad production tier, with the
/// remaining `1 − pct` of GPU demand staying best-effort (priority 0).
pub const PRIORITY_TIERS: [(u8, f64); 2] = [(2, 0.25), (1, 0.75)];

impl TraceSpec {
    /// The **Default** trace calibrated to Table I.
    pub fn default_trace() -> TraceSpec {
        let mut profiles: Vec<(TaskProfile, f64)> = Vec::new();
        // Bucket 0: CPU-only (13.3%).
        for (c, wc) in CPU_ONLY_CPUS.iter().zip(CPU_ONLY_WEIGHTS) {
            profiles.push((profile(*c, GpuDemand::Zero), TABLE1_POPULATION[0] * wc));
        }
        // Bucket 1: sharing-GPU (37.8%) — frac × cpu cross product.
        for (f, wf) in FRAC_VALUES.iter().zip(FRAC_WEIGHTS) {
            for (c, wc) in FRAC_TASK_CPUS.iter().zip(FRAC_TASK_CPU_WEIGHTS) {
                profiles.push((
                    profile(*c, GpuDemand::Frac(*f)),
                    TABLE1_POPULATION[1] * wf * wc,
                ));
            }
        }
        // Bucket 2: exactly one GPU (48.0%).
        for (c, wc) in ONE_GPU_CPUS.iter().zip(ONE_GPU_CPU_WEIGHTS) {
            profiles.push((profile(*c, GpuDemand::Whole(1)), TABLE1_POPULATION[2] * wc));
        }
        // Buckets 3–5: multi-GPU (0.2 / 0.2 / 0.5%).
        for (k, cpus, pop) in [
            (2u32, [12.0, 24.0], TABLE1_POPULATION[3]),
            (4, [24.0, 32.0], TABLE1_POPULATION[4]),
            (8, [48.0, 64.0], TABLE1_POPULATION[5]),
        ] {
            for c in cpus {
                profiles.push((profile(c, GpuDemand::Whole(k)), pop * 0.5));
            }
        }
        TraceSpec { name: "default".into(), profiles, n_tasks: 8152, diurnal: None }
    }

    /// **Diurnal** derived trace (`diurnal-<amp·100>`): Default's
    /// demand catalog with a sinusoidal arrival-rate modulation of
    /// relative amplitude `amplitude` and the default
    /// [`DIURNAL_PERIOD_S`] day length. The load valleys leave nodes
    /// idle — the scenario the DRS subsystem (`ext-drs`) exploits.
    pub fn diurnal(amplitude: f64) -> TraceSpec {
        Self::diurnal_with_period(amplitude, DIURNAL_PERIOD_S)
    }

    /// [`Self::diurnal`] with an explicit day length (experiments pin
    /// the period to their horizon so every run sees whole cycles). A
    /// non-default period is encoded into the name
    /// (`diurnal-<amp>-p<period>`) so the [`Self::by_name`] roundtrip
    /// reconstructs the *same* arrival process, never a silently
    /// different one.
    pub fn diurnal_with_period(amplitude: f64, period_s: f64) -> TraceSpec {
        assert!((0.0..=1.0).contains(&amplitude), "amplitude must be in [0, 1]");
        assert!(period_s > 0.0 && period_s.is_finite(), "period must be positive");
        let mut spec = Self::default_trace();
        spec.diurnal = Some(DiurnalMod { amplitude, period_s });
        spec.name = if period_s == DIURNAL_PERIOD_S {
            format!("diurnal-{:.0}", amplitude * 100.0)
        } else {
            format!("diurnal-{:.0}-p{period_s}", amplitude * 100.0)
        };
        spec
    }

    /// **Multi-GPU** derived trace: GPU resources requested by whole-GPU
    /// tasks (1 or more entire GPUs) increase by `pct` (e.g. `0.2` for
    /// the +20% trace) by inflating the *multi*-GPU (≥2) task counts
    /// with their internal distribution fixed; CPU-only and sharing
    /// counts unchanged (§V-A).
    pub fn multi_gpu(pct: f64) -> TraceSpec {
        let mut spec = Self::default_trace();
        let whole_units = spec.bucket_units(2) + spec.multi_units();
        let multi_units = spec.multi_units();
        assert!(multi_units > 0.0);
        let scale = 1.0 + pct * whole_units / multi_units;
        for (p, w) in &mut spec.profiles {
            if matches!(p.gpu, GpuDemand::Whole(k) if k >= 2) {
                *w *= scale;
            }
        }
        spec.name = format!("multi-gpu-{:.0}", pct * 100.0);
        spec
    }

    /// **Sharing-GPU** derived trace: sharing tasks request `share`
    /// (e.g. `1.0` for the 100% case) of all GPU resources. Sharing and
    /// whole-GPU task counts are rescaled, intra-class distributions and
    /// the CPU-only population share stay fixed (§V-A).
    pub fn sharing_gpu(share: f64) -> TraceSpec {
        assert!((0.0..=1.0).contains(&share));
        let mut spec = Self::default_trace();
        let pop_frac: f64 = spec.bucket_pop(1);
        let pop_whole: f64 = (2..NUM_BUCKETS).map(|b| spec.bucket_pop(b)).sum();
        let units_frac: f64 = spec.bucket_units(1);
        let units_whole: f64 = (2..NUM_BUCKETS).map(|b| spec.bucket_units(b)).sum();
        // Scale sharing profiles by `a` and whole-GPU profiles by `b`,
        // solving (1) GPU-task population unchanged:
        //     a·pop_frac + b·pop_whole = pop_frac + pop_whole
        // and (2) sharing tasks' share of GPU units hits the target:
        //     a·units_frac / (a·units_frac + b·units_whole) = share.
        let (a, b) = if share >= 1.0 - 1e-12 {
            ((pop_frac + pop_whole) / pop_frac, 0.0)
        } else {
            let ratio = share * units_whole / ((1.0 - share) * units_frac); // a = ratio·b
            let b = (pop_frac + pop_whole) / (ratio * pop_frac + pop_whole);
            (ratio * b, b)
        };
        for (p, w) in &mut spec.profiles {
            match p.gpu {
                GpuDemand::Frac(_) => *w *= a,
                GpuDemand::Whole(_) => *w *= b,
                GpuDemand::Zero | GpuDemand::Mig(_) => {}
            }
        }
        spec.name = format!("sharing-gpu-{:.0}", share * 100.0);
        spec
    }

    /// **Constrained-GPU** derived trace: `pct` of GPU tasks request a
    /// specific GPU model; everything else matches Default (§V-A).
    pub fn constrained_gpu(pct: f64) -> TraceSpec {
        assert!((0.0..=1.0).contains(&pct));
        let mut spec = Self::default_trace();
        let mut extra = Vec::new();
        for (p, w) in &mut spec.profiles {
            if p.gpu.is_gpu() {
                let mut constrained = p.clone();
                constrained.constrained = true;
                extra.push((constrained, *w * pct));
                *w *= 1.0 - pct;
            }
        }
        spec.profiles.extend(extra);
        spec.name = format!("constrained-gpu-{:.0}", pct * 100.0);
        spec
    }

    /// **Constraint-aware** derived trace (`constrained-<pct>`): `pct`
    /// of GPU tasks carry a declarative [`TaskConstraints`] — 40%
    /// tenant anti-affinity ([`ConstraintGen::Tenant`]), 40% GPU-model
    /// sets ([`ConstraintGen::ModelSet`]), 20% per-node spread caps
    /// ([`ConstraintGen::Spread`]); demand marginals match Default.
    /// The `ext-filters` experiment sweeps `pct` ∈ {0, 25, 50}%.
    pub fn constrained(pct: f64) -> TraceSpec {
        assert!((0.0..=1.0).contains(&pct));
        let mut spec = Self::default_trace();
        let mut extra = Vec::new();
        for (p, w) in &mut spec.profiles {
            if p.gpu.is_gpu() {
                for (kind, share) in [
                    (ConstraintGen::Tenant, 0.4),
                    (ConstraintGen::ModelSet, 0.4),
                    (ConstraintGen::Spread, 0.2),
                ] {
                    let mut c = p.clone();
                    c.constraint = kind;
                    extra.push((c, *w * pct * share));
                }
                *w *= 1.0 - pct;
            }
        }
        spec.profiles.extend(extra);
        spec.name = format!("constrained-{:.0}", pct * 100.0);
        spec
    }

    /// **MIG** trace: a slice-profile demand mix for MIG-partitioned
    /// clusters (see [`crate::cluster::mig`]). 10% of tasks are
    /// CPU-only; the GPU tasks request one MIG instance each, with
    /// `large_pop` of them drawn from the large profiles (3g/4g/7g)
    /// and the rest from the small ones (1g/2g). Within each group the
    /// mix is fixed (1g:2g = 55:45; 3g:4g:7g = 50:35:15, roughly the
    /// instance-size histogram Zambianco et al. report for multi-tenant
    /// MIG clouds). CPU demand is calibrated to ≈1.6 vCPU per slice so
    /// MIG clusters stay GPU-bound like the paper's.
    pub fn mig_trace(large_pop: f64) -> TraceSpec {
        assert!((0.0..=1.0).contains(&large_pop));
        let gpu_pop = 90.0;
        let groups: [(MigProfile, f64, &[f64]); 5] = [
            (MigProfile::P1g, (1.0 - large_pop) * 0.55, &[1.0, 2.0]),
            (MigProfile::P2g, (1.0 - large_pop) * 0.45, &[2.0, 4.0]),
            (MigProfile::P3g, large_pop * 0.50, &[4.0, 6.0]),
            (MigProfile::P4g, large_pop * 0.35, &[6.0, 8.0]),
            (MigProfile::P7g, large_pop * 0.15, &[8.0, 12.0]),
        ];
        let mut profiles: Vec<(TaskProfile, f64)> = Vec::new();
        for (c, wc) in [2.0, 4.0, 8.0].iter().zip([0.4, 0.4, 0.2]) {
            profiles.push((profile(*c, GpuDemand::Zero), 10.0 * wc));
        }
        for (p, share, cpus) in groups {
            for &c in cpus {
                profiles.push((
                    profile(c, GpuDemand::Mig(p)),
                    gpu_pop * share / cpus.len() as f64,
                ));
            }
        }
        TraceSpec {
            name: format!("mig-{:.0}", large_pop * 100.0),
            profiles,
            n_tasks: 8152,
            diurnal: None,
        }
    }

    /// **Heterogeneous MIG** trace for mixed A100+A30 fleets:
    /// `a30_share` of the MIG demands target the A30 4-slice lattice
    /// (a30-1g/a30-2g/a30-4g profiles), the rest the A100 7-slice one.
    /// Within each lattice the large-vs-small mix follows
    /// [`Self::mig_trace`]'s `large_pop` knob (A30's "large" group is
    /// the full-GPU a30-4g). CPU-only population stays at 10%. A
    /// profile only runs on nodes of its lattice, so the fleet mix and
    /// the demand mix must be co-tuned — exactly the scenario the
    /// `ext-mig-het` experiment sweeps.
    pub fn mig_het_trace(large_pop: f64, a30_share: f64) -> TraceSpec {
        assert!((0.0..=1.0).contains(&large_pop));
        assert!((0.0..=1.0).contains(&a30_share));
        let gpu_pop = 90.0;
        let a100_pop = gpu_pop * (1.0 - a30_share);
        let a30_pop = gpu_pop * a30_share;
        let groups: [(MigProfile, f64, &[f64]); 8] = [
            // A100 lattice (as in mig_trace).
            (MigProfile::P1g, a100_pop * (1.0 - large_pop) * 0.55, &[1.0, 2.0]),
            (MigProfile::P2g, a100_pop * (1.0 - large_pop) * 0.45, &[2.0, 4.0]),
            (MigProfile::P3g, a100_pop * large_pop * 0.50, &[4.0, 6.0]),
            (MigProfile::P4g, a100_pop * large_pop * 0.35, &[6.0, 8.0]),
            (MigProfile::P7g, a100_pop * large_pop * 0.15, &[8.0, 12.0]),
            // A30 lattice: 1g/2g small, the full-GPU 4g large.
            (MigProfile::A30P1g, a30_pop * (1.0 - large_pop) * 0.55, &[1.0, 2.0]),
            (MigProfile::A30P2g, a30_pop * (1.0 - large_pop) * 0.45, &[2.0, 4.0]),
            (MigProfile::A30P4g, a30_pop * large_pop, &[4.0, 6.0]),
        ];
        let mut profiles: Vec<(TaskProfile, f64)> = Vec::new();
        for (c, wc) in [2.0, 4.0, 8.0].iter().zip([0.4, 0.4, 0.2]) {
            profiles.push((profile(*c, GpuDemand::Zero), 10.0 * wc));
        }
        for (p, share, cpus) in groups {
            if share <= 0.0 {
                continue;
            }
            for &c in cpus {
                profiles.push((
                    profile(c, GpuDemand::Mig(p)),
                    share / cpus.len() as f64,
                ));
            }
        }
        TraceSpec {
            name: format!("mig-het-{:.0}", a30_share * 100.0),
            profiles,
            n_tasks: 8152,
            diurnal: None,
        }
    }

    /// **Gang** derived trace (`gang-<pct>`): `pct` of the whole-GPU
    /// *population* mass arrives as model-parallel gangs — the four
    /// [`GANG_SHAPES`] TP×PP×DP splits, [`GANG_MEMBER_VCPUS`] vCPUs
    /// per member — while CPU-only and sharing demand stays exactly
    /// Default's. `gang-0` carries the gang profiles at weight zero,
    /// so it samples no gang tasks; the `ext-gang` experiment sweeps
    /// `pct` ∈ {0, 30, 60}%.
    pub fn gang_trace(pct: f64) -> TraceSpec {
        assert!((0.0..=1.0).contains(&pct));
        let mut spec = Self::default_trace();
        let whole_pop: f64 = (2..NUM_BUCKETS).map(|b| spec.bucket_pop(b)).sum();
        for (p, w) in &mut spec.profiles {
            if matches!(p.gpu, GpuDemand::Whole(_)) {
                *w *= 1.0 - pct;
            }
        }
        for (tp, pp, dp, share) in GANG_SHAPES {
            let Some(g) = GangSpec::new(tp, pp, dp) else { continue };
            let cpu = GANG_MEMBER_VCPUS * g.n_members() as f64;
            spec.profiles.push((
                TaskProfile {
                    cpu,
                    mem: cpu * MEM_PER_VCPU_MIB,
                    gpu: GpuDemand::Whole(g.total_gpus()),
                    constrained: false,
                    constraint: ConstraintGen::None,
                    gang: Some(g),
                    priority: 0,
                },
                whole_pop * pct * share,
            ));
        }
        spec.name = format!("gang-{:.0}", pct * 100.0);
        spec
    }

    /// **Priority** derived trace (`priority-<pct>`): `pct` of the GPU
    /// demand mass carries an elevated tenant priority, split across
    /// the skewed [`PRIORITY_TIERS`] mix; everything else matches
    /// Default (priority 0, best-effort). Like `gang-0`, `priority-0`
    /// carries the elevated profiles at weight zero and samples no
    /// prioritized tasks — and priorities are assigned statically per
    /// profile, so sampling draws no extra randomness. Feeds the
    /// fairness subsystem's `preempt` hook (`docs/fairness.md`); the
    /// `ext-fairness` experiment runs `priority-50` churn.
    pub fn priority_trace(pct: f64) -> TraceSpec {
        assert!((0.0..=1.0).contains(&pct));
        let mut spec = Self::default_trace();
        let mut extra = Vec::new();
        for (p, w) in &mut spec.profiles {
            if p.gpu.is_gpu() {
                for (prio, share) in PRIORITY_TIERS {
                    let mut elevated = p.clone();
                    elevated.priority = prio;
                    extra.push((elevated, *w * pct * share));
                }
                *w *= 1.0 - pct;
            }
        }
        spec.profiles.extend(extra);
        spec.name = format!("priority-{:.0}", pct * 100.0);
        spec
    }

    /// Reconstruct a spec from a trace name (`default`,
    /// `multi-gpu-20`, `sharing-gpu-100`, `constrained-gpu-33`,
    /// `mig-30`/`mig-default`, `mig-het-40`, `diurnal-60`, `gang-50`,
    /// `priority-50`, …).
    pub fn by_name(name: &str) -> Option<TraceSpec> {
        if name == "default" {
            return Some(Self::default_trace());
        }
        if name == "mig-default" {
            return Some(Self::mig_trace(0.3));
        }
        if let Some(pct) = name.strip_prefix("mig-het-") {
            return pct.parse::<f64>().ok().map(|p| Self::mig_het_trace(0.3, p / 100.0));
        }
        if let Some(pct) = name.strip_prefix("mig-") {
            return pct.parse::<f64>().ok().map(|p| Self::mig_trace(p / 100.0));
        }
        if let Some(pct) = name.strip_prefix("multi-gpu-") {
            return pct.parse::<f64>().ok().map(|p| Self::multi_gpu(p / 100.0));
        }
        if let Some(pct) = name.strip_prefix("sharing-gpu-") {
            return pct.parse::<f64>().ok().map(|p| Self::sharing_gpu(p / 100.0));
        }
        if let Some(pct) = name.strip_prefix("constrained-gpu-") {
            return pct.parse::<f64>().ok().map(|p| Self::constrained_gpu(p / 100.0));
        }
        if let Some(pct) = name.strip_prefix("constrained-") {
            return pct.parse::<f64>().ok().map(|p| Self::constrained(p / 100.0));
        }
        if let Some(pct) = name.strip_prefix("priority-") {
            return pct
                .parse::<f64>()
                .ok()
                .filter(|p| (0.0..=100.0).contains(p))
                .map(|p| Self::priority_trace(p / 100.0));
        }
        if let Some(pct) = name.strip_prefix("gang-") {
            return pct
                .parse::<f64>()
                .ok()
                .filter(|p| (0.0..=100.0).contains(p))
                .map(|p| Self::gang_trace(p / 100.0));
        }
        if let Some(rest) = name.strip_prefix("diurnal-") {
            // `diurnal-<amp>` (default period) or `diurnal-<amp>-p<period>`.
            let (amp, period) = match rest.split_once("-p") {
                Some((a, p)) => (a, p.parse::<f64>().ok()?),
                None => (rest, DIURNAL_PERIOD_S),
            };
            if !(period > 0.0 && period.is_finite()) {
                return None;
            }
            return amp
                .parse::<f64>()
                .ok()
                .filter(|a| (0.0..=100.0).contains(a))
                .map(|a| Self::diurnal_with_period(a / 100.0, period));
        }
        None
    }

    fn bucket_pop(&self, bucket: usize) -> f64 {
        self.profiles
            .iter()
            .filter(|(p, _)| p.gpu.bucket() == bucket)
            .map(|(_, w)| w)
            .sum()
    }

    fn bucket_units(&self, bucket: usize) -> f64 {
        self.profiles
            .iter()
            .filter(|(p, _)| p.gpu.bucket() == bucket)
            .map(|(p, w)| w * p.gpu.units())
            .sum()
    }

    fn multi_units(&self) -> f64 {
        (3..NUM_BUCKETS).map(|b| self.bucket_units(b)).sum()
    }

    /// Expected per-bucket task population (%, normalized).
    pub fn population_pct(&self) -> [f64; NUM_BUCKETS] {
        let total: f64 = self.profiles.iter().map(|(_, w)| w).sum();
        let mut out = [0.0; NUM_BUCKETS];
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.bucket_pop(i) / total * 100.0;
        }
        out
    }

    /// Expected per-bucket GPU request share (%, normalized).
    pub fn gpu_share_pct(&self) -> [f64; NUM_BUCKETS] {
        let total: f64 = (0..NUM_BUCKETS).map(|b| self.bucket_units(b)).sum();
        let mut out = [0.0; NUM_BUCKETS];
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.bucket_units(i) / total * 100.0;
        }
        out
    }

    /// Materialize a trace of `n_tasks` sampled tasks.
    pub fn synthesize(&self, seed: u64) -> Trace {
        let mut rng = Rng::new(seed);
        let weights: Vec<f64> = self.profiles.iter().map(|(_, w)| *w).collect();
        let index = WeightedIndex::new(&weights);
        let model_weights: Vec<f64> =
            GpuModel::ALL.iter().map(|m| m.paper_count() as f64).collect();
        let model_index = WeightedIndex::new(&model_weights);
        let tasks = (0..self.n_tasks)
            .map(|id| self.sample_one(id as u64, &index, &model_index, &mut rng))
            .collect();
        Trace { name: self.name.clone(), tasks }
    }

    fn sample_one(
        &self,
        id: u64,
        index: &WeightedIndex,
        model_index: &WeightedIndex,
        rng: &mut Rng,
    ) -> Task {
        let (p, _) = &self.profiles[index.sample(rng)];
        let gpu_model = if p.constrained {
            Some(GpuModel::ALL[model_index.sample(rng)])
        } else {
            None
        };
        // Declarative constraints (constraint-free profiles draw no
        // extra randomness, so legacy traces are bit-identical).
        let constraints = match p.constraint {
            ConstraintGen::None => None,
            ConstraintGen::Tenant => {
                let t = rng.below(N_TENANTS);
                Some(TaskConstraints {
                    class_key: Some(format!("tenant-{t}")),
                    anti_affinity: (0..N_TENANTS)
                        .filter(|&i| i != t)
                        .map(|i| format!("tenant-{i}"))
                        .collect(),
                    ..Default::default()
                })
            }
            ConstraintGen::ModelSet => {
                let a = GpuModel::ALL[model_index.sample(rng)];
                let b = GpuModel::ALL[model_index.sample(rng)];
                Some(TaskConstraints {
                    gpu_models: if a == b { vec![a] } else { vec![a, b] },
                    ..Default::default()
                })
            }
            ConstraintGen::Spread => Some(TaskConstraints {
                class_key: Some(format!("spread-{}", p.gpu.bucket())),
                max_per_node: Some(SPREAD_MAX_PER_NODE),
                ..Default::default()
            }),
        };
        Task {
            id,
            cpu: p.cpu,
            mem: p.mem,
            gpu: p.gpu,
            gpu_model,
            constraints: constraints.map(Box::new),
            gang: p.gang,
            priority: p.priority,
        }
    }

    /// Build a with-replacement sampler for Monte-Carlo inflation.
    pub fn sampler(&self, seed: u64) -> InflationSampler {
        let weights: Vec<f64> = self.profiles.iter().map(|(_, w)| *w).collect();
        InflationSampler {
            spec: self.clone(),
            index: WeightedIndex::new(&weights),
            model_index: WeightedIndex::new(
                &GpuModel::ALL.iter().map(|m| m.paper_count() as f64).collect::<Vec<_>>(),
            ),
            rng: Rng::new(seed),
            next_id: 0,
        }
    }
}

/// A materialized trace.
#[derive(Clone, Debug)]
pub struct Trace {
    pub name: String,
    pub tasks: Vec<Task>,
}

impl Trace {
    /// Extract the target workload `M` (class catalog + popularity) the
    /// FGD metric needs.
    pub fn workload(&self) -> Workload {
        Workload::from_tasks(&self.tasks)
    }

    /// Empirical per-bucket population (%).
    pub fn population_pct(&self) -> [f64; NUM_BUCKETS] {
        let mut counts = [0usize; NUM_BUCKETS];
        for t in &self.tasks {
            counts[t.gpu.bucket()] += 1;
        }
        let total = self.tasks.len().max(1) as f64;
        let mut out = [0.0; NUM_BUCKETS];
        for (o, c) in out.iter_mut().zip(counts) {
            *o = c as f64 / total * 100.0;
        }
        out
    }

    /// Empirical per-bucket GPU-request share (%).
    pub fn gpu_share_pct(&self) -> [f64; NUM_BUCKETS] {
        let mut units = [0.0; NUM_BUCKETS];
        for t in &self.tasks {
            units[t.gpu.bucket()] += t.gpu.units();
        }
        let total: f64 = units.iter().sum();
        let mut out = [0.0; NUM_BUCKETS];
        for (o, u) in out.iter_mut().zip(units) {
            *o = if total > 0.0 { u / total * 100.0 } else { 0.0 };
        }
        out
    }
}

/// Infinite with-replacement task stream (Monte-Carlo inflation, §V-A).
pub struct InflationSampler {
    spec: TraceSpec,
    index: WeightedIndex,
    model_index: WeightedIndex,
    rng: Rng,
    next_id: u64,
}

impl InflationSampler {
    /// Draw the next arriving task.
    pub fn next_task(&mut self) -> Task {
        let id = self.next_id;
        self.next_id += 1;
        self.spec.sample_one(id, &self.index, &self.model_index, &mut self.rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_population_matches_table1() {
        let spec = TraceSpec::default_trace();
        let pop = spec.population_pct();
        for (i, (&got, &want)) in pop.iter().zip(&TABLE1_POPULATION).enumerate() {
            assert!((got - want).abs() < 0.05, "bucket {i}: {got} vs {want}");
        }
    }

    #[test]
    fn default_gpu_share_matches_table1() {
        let spec = TraceSpec::default_trace();
        let share = spec.gpu_share_pct();
        for (i, (&got, &want)) in share.iter().zip(&TABLE1_GPU_SHARE).enumerate() {
            assert!((got - want).abs() < 0.7, "bucket {i}: {got} vs {want}");
        }
    }

    #[test]
    fn synthesized_trace_matches_spec() {
        let trace = TraceSpec::default_trace().synthesize(7);
        assert_eq!(trace.tasks.len(), 8152);
        let pop = trace.population_pct();
        for (i, (&got, &want)) in pop.iter().zip(&TABLE1_POPULATION).enumerate() {
            assert!((got - want).abs() < 1.5, "bucket {i}: {got} vs {want}");
        }
    }

    #[test]
    fn synthesis_is_deterministic() {
        let a = TraceSpec::default_trace().synthesize(42);
        let b = TraceSpec::default_trace().synthesize(42);
        assert_eq!(a.tasks, b.tasks);
        let c = TraceSpec::default_trace().synthesize(43);
        assert_ne!(a.tasks, c.tasks);
    }

    #[test]
    fn multi_gpu_increases_whole_units() {
        let base = TraceSpec::default_trace();
        let plus20 = TraceSpec::multi_gpu(0.2);
        // Whole-GPU units per unit population mass must grow 20%.
        let base_total: f64 = base.profiles.iter().map(|(_, w)| w).sum();
        let whole_base: f64 =
            (2..NUM_BUCKETS).map(|b| base.bucket_units(b)).sum::<f64>() / base_total;
        // CPU-only and sharing *counts* unchanged -> same absolute mass.
        let whole_new: f64 =
            (2..NUM_BUCKETS).map(|b| plus20.bucket_units(b)).sum::<f64>() / base_total;
        assert!((whole_new / whole_base - 1.2).abs() < 1e-9);
        // sharing/CPU-only masses untouched
        assert!((plus20.bucket_pop(0) - base.bucket_pop(0)).abs() < 1e-12);
        assert!((plus20.bucket_pop(1) - base.bucket_pop(1)).abs() < 1e-12);
        assert!((plus20.bucket_units(2) - base.bucket_units(2)).abs() < 1e-12);
    }

    #[test]
    fn sharing_gpu_hits_target_share() {
        for target in [0.4, 0.6, 0.8, 1.0] {
            let spec = TraceSpec::sharing_gpu(target);
            let share = spec.gpu_share_pct();
            assert!(
                (share[1] / 100.0 - target).abs() < 1e-9,
                "target {target}: got {}",
                share[1]
            );
            // CPU-only population share preserved.
            let pop = spec.population_pct();
            assert!((pop[0] - TABLE1_POPULATION[0]).abs() < 0.05);
        }
    }

    #[test]
    fn constrained_gpu_fraction() {
        let trace = TraceSpec::constrained_gpu(0.33).synthesize(3);
        let gpu_tasks: Vec<_> = trace.tasks.iter().filter(|t| t.gpu.is_gpu()).collect();
        let constrained = gpu_tasks.iter().filter(|t| t.gpu_model.is_some()).count();
        let frac = constrained as f64 / gpu_tasks.len() as f64;
        assert!((frac - 0.33).abs() < 0.03, "constrained fraction {frac}");
        // CPU-only tasks never constrained.
        assert!(trace
            .tasks
            .iter()
            .filter(|t| !t.gpu.is_gpu())
            .all(|t| t.gpu_model.is_none()));
    }

    #[test]
    fn mig_trace_mix_and_roundtrip() {
        let spec = TraceSpec::mig_trace(0.3);
        assert_eq!(spec.name, "mig-30");
        // Name → spec roundtrip (Simulation::new relies on this).
        let back = TraceSpec::by_name("mig-30").unwrap();
        assert_eq!(back.profiles.len(), spec.profiles.len());
        assert!(TraceSpec::by_name("mig-default").is_some());
        // Large-profile population share of GPU tasks ≈ 30%.
        let total_gpu: f64 = spec
            .profiles
            .iter()
            .filter(|(p, _)| p.gpu.is_gpu())
            .map(|(_, w)| w)
            .sum();
        let large: f64 = spec
            .profiles
            .iter()
            .filter(|(p, _)| {
                matches!(p.gpu, GpuDemand::Mig(m)
                    if m >= MigProfile::P3g)
            })
            .map(|(_, w)| w)
            .sum();
        assert!((large / total_gpu - 0.3).abs() < 1e-9);
        // Synthesis produces only CPU-only and MIG demands.
        let trace = spec.synthesize(11);
        assert_eq!(trace.tasks.len(), 8152);
        for t in &trace.tasks {
            assert!(matches!(t.gpu, GpuDemand::Zero | GpuDemand::Mig(_)));
        }
        let mig_frac = trace.tasks.iter().filter(|t| t.gpu.is_gpu()).count() as f64
            / trace.tasks.len() as f64;
        assert!((mig_frac - 0.9).abs() < 0.02, "gpu-task share {mig_frac}");
        // Workload extraction covers all five profiles.
        let w = trace.workload();
        let profiles: std::collections::BTreeSet<usize> = w
            .classes()
            .iter()
            .filter_map(|c| match c.gpu {
                GpuDemand::Mig(p) => Some(p.index()),
                _ => None,
            })
            .collect();
        assert_eq!(profiles.len(), 5);
    }

    #[test]
    fn mig_het_trace_splits_lattices() {
        use crate::cluster::mig::MigLattice;
        let spec = TraceSpec::mig_het_trace(0.3, 0.4);
        assert_eq!(spec.name, "mig-het-40");
        let back = TraceSpec::by_name("mig-het-40").unwrap();
        assert_eq!(back.profiles.len(), spec.profiles.len());
        // A30-lattice share of GPU demand population ≈ 40%.
        let pop_of = |lat: MigLattice| -> f64 {
            spec.profiles
                .iter()
                .filter_map(|(p, w)| match p.gpu {
                    GpuDemand::Mig(m) if m.lattice() == lat => Some(*w),
                    _ => None,
                })
                .sum()
        };
        let (a100, a30) = (pop_of(MigLattice::A100), pop_of(MigLattice::A30));
        assert!((a30 / (a100 + a30) - 0.4).abs() < 1e-9);
        // Synthesis covers both lattices and only Zero/Mig demands.
        let trace = spec.synthesize(13);
        let mut seen = std::collections::BTreeSet::new();
        for t in &trace.tasks {
            match t.gpu {
                GpuDemand::Zero => {}
                GpuDemand::Mig(p) => {
                    seen.insert(p.lattice().index());
                }
                other => panic!("unexpected demand {other:?}"),
            }
        }
        assert_eq!(seen.len(), 2, "both lattices must appear");
        // Extremes collapse to one lattice.
        let pure_a100 = TraceSpec::mig_het_trace(0.3, 0.0);
        assert!(pure_a100.profiles.iter().all(|(p, _)| match p.gpu {
            GpuDemand::Mig(m) => m.lattice() == MigLattice::A100,
            _ => true,
        }));
        let pure_a30 = TraceSpec::mig_het_trace(0.3, 1.0);
        assert!(pure_a30.profiles.iter().all(|(p, _)| match p.gpu {
            GpuDemand::Mig(m) => m.lattice() == MigLattice::A30,
            _ => true,
        }));
    }

    #[test]
    fn mig_trace_knob_extremes() {
        // All-small and all-large mixes are valid specs.
        for (pop, small_only) in [(0.0, true), (1.0, false)] {
            let spec = TraceSpec::mig_trace(pop);
            let trace = spec.synthesize(5);
            let has_large = trace.tasks.iter().any(|t| {
                matches!(t.gpu, GpuDemand::Mig(m) if m >= MigProfile::P3g)
            });
            assert_eq!(has_large, !small_only);
        }
    }

    #[test]
    fn constrained_trace_tags_declarative_constraints() {
        let spec = TraceSpec::constrained(0.5);
        assert_eq!(spec.name, "constrained-50");
        // Name → spec roundtrip (and no clash with constrained-gpu-*).
        let back = TraceSpec::by_name("constrained-50").unwrap();
        assert_eq!(back.profiles.len(), spec.profiles.len());
        assert_eq!(TraceSpec::by_name("constrained-gpu-33").unwrap().name, "constrained-gpu-33");
        let trace = spec.synthesize(17);
        let gpu_tasks: Vec<_> = trace.tasks.iter().filter(|t| t.gpu.is_gpu()).collect();
        let constrained = gpu_tasks.iter().filter(|t| t.constraints.is_some()).count();
        let frac = constrained as f64 / gpu_tasks.len() as f64;
        assert!((frac - 0.5).abs() < 0.03, "constrained fraction {frac}");
        // CPU-only tasks never carry constraints.
        assert!(trace
            .tasks
            .iter()
            .filter(|t| !t.gpu.is_gpu())
            .all(|t| t.constraints.is_none()));
        // All three kinds appear, with sane contents.
        let (mut tenants, mut sets, mut spreads) = (0usize, 0usize, 0usize);
        for t in &trace.tasks {
            let Some(c) = t.constraints.as_deref() else { continue };
            if !c.anti_affinity.is_empty() {
                tenants += 1;
                let key = c.class_key.as_deref().unwrap();
                assert!(key.starts_with("tenant-"));
                assert_eq!(c.anti_affinity.len(), N_TENANTS - 1);
                assert!(!c.anti_affinity.iter().any(|k| k == key), "self-anti-affine");
            } else if !c.gpu_models.is_empty() {
                sets += 1;
                assert!(c.gpu_models.len() <= 2);
            } else {
                spreads += 1;
                assert_eq!(c.max_per_node, Some(SPREAD_MAX_PER_NODE));
                assert!(c.class_key.as_deref().unwrap().starts_with("spread-"));
            }
        }
        assert!(tenants > 0 && sets > 0 && spreads > 0, "{tenants}/{sets}/{spreads}");
        // 40/40/20 split, loosely.
        let total = (tenants + sets + spreads) as f64;
        assert!((tenants as f64 / total - 0.4).abs() < 0.05);
        assert!((spreads as f64 / total - 0.2).abs() < 0.05);
        // Demand marginals match Default (constraints ride along).
        let pop = spec.population_pct();
        for (i, (&got, &want)) in pop.iter().zip(&TABLE1_POPULATION).enumerate() {
            assert!((got - want).abs() < 0.05, "bucket {i}: {got} vs {want}");
        }
    }

    #[test]
    fn gang_trace_mixes_gangs_with_singletons() {
        let spec = TraceSpec::gang_trace(0.5);
        assert_eq!(spec.name, "gang-50");
        let back = TraceSpec::by_name("gang-50").unwrap();
        assert_eq!(back.profiles.len(), spec.profiles.len());
        assert!(TraceSpec::by_name("gang-150").is_none());
        // Gang mass = 50% of Default's whole-GPU population mass;
        // CPU-only and sharing demand untouched.
        let base = TraceSpec::default_trace();
        let whole_pop: f64 = (2..NUM_BUCKETS).map(|b| base.bucket_pop(b)).sum();
        let gang_mass: f64 = spec
            .profiles
            .iter()
            .filter(|(p, _)| p.gang.is_some())
            .map(|(_, w)| w)
            .sum();
        assert!((gang_mass - 0.5 * whole_pop).abs() < 1e-9);
        assert!((spec.bucket_pop(0) - base.bucket_pop(0)).abs() < 1e-12);
        assert!((spec.bucket_pop(1) - base.bucket_pop(1)).abs() < 1e-12);
        // Synthesis: gang tasks carry the gang *totals* (the shape
        // `place_gang` decomposes), and all four shapes can appear.
        let trace = spec.synthesize(21);
        let mut shapes = std::collections::BTreeSet::new();
        for t in &trace.tasks {
            if let Some(g) = t.gang {
                assert_eq!(t.gpu, GpuDemand::Whole(g.total_gpus()));
                assert_eq!(t.cpu, GANG_MEMBER_VCPUS * g.n_members() as f64);
                assert_eq!(t.mem, t.cpu * MEM_PER_VCPU_MIB);
                shapes.insert((g.tp, g.pp, g.dp));
            }
        }
        assert_eq!(shapes.len(), GANG_SHAPES.len(), "all shapes sampled");
        // gang-0 keeps its gang profiles at weight zero: no gang tasks.
        let zero = TraceSpec::gang_trace(0.0).synthesize(21);
        assert!(zero.tasks.iter().all(|t| t.gang.is_none()));
    }

    #[test]
    fn constraint_free_sampling_is_bit_identical_to_legacy() {
        // The constraint generator must not perturb the RNG stream of
        // constraint-free traces: synthesize(default) is unchanged.
        let a = TraceSpec::default_trace().synthesize(42);
        assert!(a.tasks.iter().all(|t| t.constraints.is_none()));
        // constrained(0.0) leaves every constrained profile at weight 0.
        let b = TraceSpec::constrained(0.0).synthesize(42);
        assert!(b.tasks.iter().all(|t| t.constraints.is_none()));
    }

    #[test]
    fn diurnal_trace_keeps_default_catalog() {
        let spec = TraceSpec::diurnal(0.6);
        assert_eq!(spec.name, "diurnal-60");
        let m = spec.diurnal.expect("modulation attached");
        assert!((m.amplitude - 0.6).abs() < 1e-12);
        assert_eq!(m.period_s, DIURNAL_PERIOD_S);
        // Name → spec roundtrip, out-of-range amplitudes rejected.
        assert!(TraceSpec::by_name("diurnal-60").is_some());
        assert!(TraceSpec::by_name("diurnal-150").is_none());
        assert!(TraceSpec::by_name("diurnal--5").is_none());
        // Demand marginals are exactly Default's: the modulation only
        // shapes arrival *timing*, never the catalog, so inflation
        // runs on diurnal-* reproduce Default bit for bit.
        let base = TraceSpec::default_trace();
        assert_eq!(spec.profiles, base.profiles);
        assert_eq!(spec.synthesize(42).tasks, base.synthesize(42).tasks);
        // Custom periods are encoded in the name, and the name → spec
        // roundtrip reconstructs the same arrival process (the
        // contract `Simulation::new`'s re-derivation relies on).
        let custom = TraceSpec::diurnal_with_period(0.4, 2_000.0);
        assert_eq!(custom.name, "diurnal-40-p2000");
        assert_eq!(custom.diurnal.unwrap().period_s, 2_000.0);
        let back = TraceSpec::by_name(&custom.name).unwrap();
        assert_eq!(back.diurnal, custom.diurnal);
        assert!(TraceSpec::by_name("diurnal-40-p0").is_none());
        assert!(TraceSpec::by_name("diurnal-40-pnope").is_none());
    }

    #[test]
    fn sampler_streams_fresh_ids() {
        let spec = TraceSpec::default_trace();
        let mut s = spec.sampler(9);
        let a = s.next_task();
        let b = s.next_task();
        assert_eq!(a.id, 0);
        assert_eq!(b.id, 1);
    }

    #[test]
    fn workload_extraction_covers_trace() {
        let trace = TraceSpec::default_trace().synthesize(5);
        let w = trace.workload();
        assert!((w.total_pop() - 1.0).abs() < 1e-9);
        // All six buckets represented in the classes.
        let buckets: std::collections::BTreeSet<usize> =
            w.classes().iter().map(|c| c.gpu.bucket()).collect();
        assert_eq!(buckets.len(), NUM_BUCKETS);
    }
}
