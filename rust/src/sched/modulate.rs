//! The `weightModulator` extension point: retargets score-plugin
//! weights per decision from live cluster state.
//!
//! The paper's §VII future-work item — load-adaptive α — is the first
//! implementation ([`LoadAlphaModulator`]); recent dynamic
//! multi-objective schedulers (Mamirov '25) motivate the general form:
//! any profile may attach one modulator, and the modulator sees *all*
//! plugin weights, not a hard-wired `[PWR, FGD]` pair.
//!
//! Modulators may additionally refine weights **per node**
//! ([`WeightModulator::modulate_node`]): [`LatticeAlphaModulator`]
//! applies a different α on nodes by MIG partition lattice (A100-7g vs
//! A30-4g vs non-MIG), since coarse lattices repack cheaply and can
//! afford power-greedier placement.

use crate::cluster::mig::MigLattice;
use crate::cluster::node::{Node, ResourceView};
use crate::cluster::Datacenter;

/// A weight modulator: rewrites the effective per-decision plugin
/// weights from cluster state.
///
/// `base` holds the profile's static weights; `weights` starts as a
/// copy of `base` and may be rewritten in place (same length, indexed
/// like the profile's score plugins). The returned value, if any, is
/// the α the `weighted` binder should use for placement selection this
/// decision (see [`crate::sched::bind::BindCtx::alpha_override`]).
pub trait WeightModulator: Send {
    fn name(&self) -> &'static str;

    /// Sanity-check the score-plugin stack this modulator is being
    /// attached to (`plugin_names` in score order).
    /// [`crate::sched::Scheduler::set_modulator`] enforces it in debug
    /// builds, so hand-assembled schedulers get the same layout guard
    /// the profile builder applies at parse time.
    fn check_layout(&self, _plugin_names: &[&str]) -> Result<(), String> {
        Ok(())
    }

    /// Receive the shared fairness core
    /// ([`crate::sched::framework::Scheduler::bind_fairness`]).
    /// Modulators that read starvation state (e.g.
    /// [`crate::sched::fairness::StarveModulator`]) override this;
    /// everything else ignores it and stays fairness-agnostic.
    fn bind_fairness(&mut self, _shared: &crate::sched::fairness::FairnessShared) {}

    fn modulate(&self, dc: &Datacenter, base: &[f64], weights: &mut [f64]) -> Option<f64>;

    /// Whether [`Self::modulate_node`] refines weights per node. The
    /// framework only takes the (slightly costlier) per-node combine
    /// path when this is true.
    fn per_node(&self) -> bool {
        false
    }

    /// Per-node weight refinement: `weights` arrives holding the
    /// per-decision output of [`Self::modulate`] and may be rewritten
    /// for this specific node (`base` holds the profile's static
    /// weights). Only called when [`Self::per_node`] is true.
    fn modulate_node(&self, _node: &Node, _base: &[f64], _weights: &mut [f64]) {}
}

/// Shared α-split: the first plugin (the power objective) gets `alpha`,
/// the remaining plugins share `1 − alpha` proportionally to their base
/// weights (equal split when every non-power base weight is zero —
/// matching legacy `pwrfgddyn:1:…`, where FGD regains weight under
/// load).
fn split_alpha(alpha: f64, base: &[f64], weights: &mut [f64]) {
    weights[0] = alpha;
    let rest: f64 = base[1..].iter().sum();
    for (w, b) in weights[1..].iter_mut().zip(&base[1..]) {
        // `(b / rest) * (1 − α)`, in exactly this association: for the
        // legacy two-plugin lowering b == rest, so b/rest is exactly
        // 1.0 and the FGD weight is bit-identical to the pre-profile
        // inline `1.0 − α` (the other association drifts by 1 ulp for
        // some inputs).
        *w = if rest > 0.0 {
            (b / rest) * (1.0 - alpha)
        } else {
            (1.0 - alpha) / (base.len() - 1) as f64
        };
    }
}

/// Load-adaptive α (paper §VII): linearly interpolate a power weight α
/// from `alpha_empty` (idle cluster — maximize power savings) down to
/// `alpha_full` (saturated — protect GRAR) on GPU utilization.
///
/// The *first* score plugin is treated as the power objective
/// (profiles attaching `loadalpha` must list `pwr` first —
/// [`crate::sched::profile::SchedulerProfile::build`] enforces it) and
/// gets weight α; the remaining plugins share `1−α` proportionally to
/// their base weights. With the legacy `[PWR, FGD]` layout this
/// reproduces the original dynamic-α exactly (`[α, 1−α]`); with ≥ 3
/// plugins the non-power objectives keep their relative importance
/// while the whole non-power mass tracks load. When every non-power
/// base weight is zero, `1−α` is split equally instead — deliberately
/// matching the legacy `pwrfgddyn:1:…` behavior, where FGD still
/// receives `1−α` as load grows even though the static weight started
/// at zero.
#[derive(Clone, Copy, Debug)]
pub struct LoadAlphaModulator {
    pub alpha_empty: f64,
    pub alpha_full: f64,
}

impl WeightModulator for LoadAlphaModulator {
    fn name(&self) -> &'static str {
        "loadalpha"
    }

    fn check_layout(&self, plugin_names: &[&str]) -> Result<(), String> {
        if plugin_names.first() == Some(&"PWR") {
            Ok(())
        } else {
            Err(format!(
                "loadalpha drives the first score plugin as the power objective; \
                 expected PWR first, got {plugin_names:?}"
            ))
        }
    }

    fn modulate(&self, dc: &Datacenter, base: &[f64], weights: &mut [f64]) -> Option<f64> {
        let u = dc.gpu_utilization().clamp(0.0, 1.0);
        let alpha = self.alpha_empty + (self.alpha_full - self.alpha_empty) * u;
        split_alpha(alpha, base, weights);
        Some(alpha)
    }
}

/// Per-lattice α (the ROADMAP follow-up to the profile API): MIG nodes
/// get a lattice-specific power weight — `alpha_a100` on A100-lattice
/// (7-slice) nodes, `alpha_a30` on A30-lattice (4-slice) nodes — and
/// non-MIG nodes keep `alpha_base`. The non-power plugins share `1 − α`
/// proportionally, exactly like [`LoadAlphaModulator`]. The weighted
/// binder keeps its static α (binding happens after node selection,
/// inside one node, where the lattice is already fixed).
#[derive(Clone, Copy, Debug)]
pub struct LatticeAlphaModulator {
    pub alpha_base: f64,
    pub alpha_a100: f64,
    pub alpha_a30: f64,
}

impl WeightModulator for LatticeAlphaModulator {
    fn name(&self) -> &'static str {
        "latticealpha"
    }

    fn check_layout(&self, plugin_names: &[&str]) -> Result<(), String> {
        if plugin_names.first() == Some(&"PWR") {
            Ok(())
        } else {
            Err(format!(
                "latticealpha drives the first score plugin as the power objective; \
                 expected PWR first, got {plugin_names:?}"
            ))
        }
    }

    fn modulate(&self, _dc: &Datacenter, _base: &[f64], _weights: &mut [f64]) -> Option<f64> {
        // Cluster-wide pass is identity; the per-node hook below does
        // the work. The binder keeps its own α.
        None
    }

    fn per_node(&self) -> bool {
        true
    }

    fn modulate_node(&self, node: &Node, base: &[f64], weights: &mut [f64]) {
        let alpha = match node.mig_lattice() {
            Some(MigLattice::A100) => self.alpha_a100,
            Some(MigLattice::A30) => self.alpha_a30,
            None => self.alpha_base,
        };
        split_alpha(alpha, base, weights);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::cluster::node::Placement;
    use crate::tasks::{GpuDemand, Task};

    #[test]
    fn loadalpha_reproduces_two_plugin_dynamic_alpha() {
        let dc = ClusterSpec::tiny(2, 4, 0).build();
        let m = LoadAlphaModulator { alpha_empty: 0.9, alpha_full: 0.1 };
        let base = [0.9, 0.1];
        let mut w = base;
        // Empty cluster: α = alpha_empty, weights = [α, 1−α].
        let a = m.modulate(&dc, &base, &mut w).unwrap();
        assert!((a - 0.9).abs() < 1e-12);
        assert!((w[0] - 0.9).abs() < 1e-12 && (w[1] - 0.1).abs() < 1e-12);
        // Bit-identity with the pre-profile inline dynamic-α (which set
        // weights[1] = 1.0 − α literally): checked across awkward α
        // pairs at a partially-utilized cluster, since the proportional
        // split must reduce to *exactly* 1−α for the two-plugin layout.
        let mut dc = ClusterSpec::tiny(1, 4, 0).build();
        for (i, g) in [(10u64, 0usize), (11, 1)] {
            dc.allocate(&Task::new(i, 1.0, 0.0, GpuDemand::Whole(1)), 0, &Placement::Whole {
                gpus: vec![g],
            });
        }
        for (ae, af) in [(0.01, 0.62), (0.9, 0.1), (0.37, 0.0), (1.0, 0.05)] {
            let m = LoadAlphaModulator { alpha_empty: ae, alpha_full: af };
            let base = [ae, 1.0 - ae];
            let mut w = base;
            let a = m.modulate(&dc, &base, &mut w).unwrap();
            assert_eq!(w[0].to_bits(), a.to_bits());
            assert_eq!(
                w[1].to_bits(),
                (1.0 - a).to_bits(),
                "FGD weight drifted from 1−α for α_empty={ae}, α_full={af}"
            );
        }
    }

    #[test]
    fn loadalpha_splits_rest_proportionally_for_three_plugins() {
        let mut dc = ClusterSpec::tiny(1, 4, 0).build();
        // Half the GPUs busy → u = 0.5 → α = 0.5.
        for (i, g) in [(0u64, 0usize), (1, 1)] {
            dc.allocate(&Task::new(i, 1.0, 0.0, GpuDemand::Whole(1)), 0, &Placement::Whole {
                gpus: vec![g],
            });
        }
        let m = LoadAlphaModulator { alpha_empty: 1.0, alpha_full: 0.0 };
        let base = [0.5, 0.3, 0.2];
        let mut w = base;
        let a = m.modulate(&dc, &base, &mut w).unwrap();
        assert!((a - 0.5).abs() < 1e-12);
        assert!((w[0] - 0.5).abs() < 1e-12);
        // 1−α = 0.5 split 3:2 over the base [0.3, 0.2].
        assert!((w[1] - 0.3).abs() < 1e-12 && (w[2] - 0.2).abs() < 1e-12);
    }

    #[test]
    fn latticealpha_applies_per_lattice_weights() {
        let m = LatticeAlphaModulator { alpha_base: 0.5, alpha_a100: 0.1, alpha_a30: 0.9 };
        assert!(m.per_node());
        assert!(m.check_layout(&["PWR", "FGD"]).is_ok());
        assert!(m.check_layout(&["FGD", "PWR"]).is_err());
        // Mixed fleet: 1 A100 node, 1 A30 node, plus a non-MIG node.
        let het = ClusterSpec::mig_het_cluster(1, 1, 2, 0).build();
        let plain = ClusterSpec::tiny(1, 2, 0).build();
        let base = [0.5, 0.5];
        let alpha_of = |node: &crate::cluster::node::Node| {
            let mut w = base;
            m.modulate_node(node, &base, &mut w);
            assert!((w[0] + w[1] - 1.0).abs() < 1e-12);
            w[0]
        };
        use crate::cluster::mig::MigLattice;
        use crate::cluster::node::ResourceView;
        let a100 = het.nodes.iter().find(|n| n.mig_lattice() == Some(MigLattice::A100)).unwrap();
        let a30 = het.nodes.iter().find(|n| n.mig_lattice() == Some(MigLattice::A30)).unwrap();
        assert!((alpha_of(a100) - 0.1).abs() < 1e-12);
        assert!((alpha_of(a30) - 0.9).abs() < 1e-12);
        assert!((alpha_of(&plain.nodes[0]) - 0.5).abs() < 1e-12);
        // The cluster-wide pass is identity and claims no binder α.
        let mut w = base;
        assert_eq!(m.modulate(&plain, &base, &mut w), None);
        assert_eq!(w, base);
    }

    #[test]
    fn latticealpha_schedules_end_to_end_on_het_fleet() {
        use crate::cluster::mig::MigProfile;
        use crate::sched::SchedulerProfile;
        let profile = SchedulerProfile::parse(
            "score(pwr=0.5,fgd=0.5)|bind(weighted:0.5)|mod(latticealpha:0.5:0.1:0.9)",
        )
        .unwrap();
        let mut sched = profile.build().unwrap();
        let mut dc = ClusterSpec::mig_het_cluster(2, 2, 2, 0).build();
        let w = crate::tasks::Workload::default();
        let mut placed = 0;
        for i in 0..8 {
            let p = if i % 2 == 0 { MigProfile::P1g } else { MigProfile::A30P1g };
            let t = Task::new(i, 1.0, 0.0, GpuDemand::Mig(p));
            if sched.place(&mut dc, &w, &t).is_some() {
                placed += 1;
            }
        }
        assert_eq!(placed, 8, "per-lattice α profile must keep scheduling");
    }
}
