//! The `weightModulator` extension point: retargets score-plugin
//! weights per decision from live cluster state.
//!
//! The paper's §VII future-work item — load-adaptive α — is the first
//! implementation ([`LoadAlphaModulator`]); recent dynamic
//! multi-objective schedulers (Mamirov '25) motivate the general form:
//! any profile may attach one modulator, and the modulator sees *all*
//! plugin weights, not a hard-wired `[PWR, FGD]` pair.

use crate::cluster::Datacenter;

/// A weight modulator: rewrites the effective per-decision plugin
/// weights from cluster state.
///
/// `base` holds the profile's static weights; `weights` starts as a
/// copy of `base` and may be rewritten in place (same length, indexed
/// like the profile's score plugins). The returned value, if any, is
/// the α the `weighted` binder should use for placement selection this
/// decision (see [`crate::sched::bind::BindCtx::alpha_override`]).
pub trait WeightModulator: Send {
    fn name(&self) -> &'static str;

    /// Sanity-check the score-plugin stack this modulator is being
    /// attached to (`plugin_names` in score order).
    /// [`crate::sched::Scheduler::set_modulator`] enforces it in debug
    /// builds, so hand-assembled schedulers get the same layout guard
    /// the profile builder applies at parse time.
    fn check_layout(&self, _plugin_names: &[&str]) -> Result<(), String> {
        Ok(())
    }

    fn modulate(&self, dc: &Datacenter, base: &[f64], weights: &mut [f64]) -> Option<f64>;
}

/// Load-adaptive α (paper §VII): linearly interpolate a power weight α
/// from `alpha_empty` (idle cluster — maximize power savings) down to
/// `alpha_full` (saturated — protect GRAR) on GPU utilization.
///
/// The *first* score plugin is treated as the power objective
/// (profiles attaching `loadalpha` must list `pwr` first —
/// [`crate::sched::profile::SchedulerProfile::build`] enforces it) and
/// gets weight α; the remaining plugins share `1−α` proportionally to
/// their base weights. With the legacy `[PWR, FGD]` layout this
/// reproduces the original dynamic-α exactly (`[α, 1−α]`); with ≥ 3
/// plugins the non-power objectives keep their relative importance
/// while the whole non-power mass tracks load. When every non-power
/// base weight is zero, `1−α` is split equally instead — deliberately
/// matching the legacy `pwrfgddyn:1:…` behavior, where FGD still
/// receives `1−α` as load grows even though the static weight started
/// at zero.
#[derive(Clone, Copy, Debug)]
pub struct LoadAlphaModulator {
    pub alpha_empty: f64,
    pub alpha_full: f64,
}

impl WeightModulator for LoadAlphaModulator {
    fn name(&self) -> &'static str {
        "loadalpha"
    }

    fn check_layout(&self, plugin_names: &[&str]) -> Result<(), String> {
        if plugin_names.first() == Some(&"PWR") {
            Ok(())
        } else {
            Err(format!(
                "loadalpha drives the first score plugin as the power objective; \
                 expected PWR first, got {plugin_names:?}"
            ))
        }
    }

    fn modulate(&self, dc: &Datacenter, base: &[f64], weights: &mut [f64]) -> Option<f64> {
        let u = dc.gpu_utilization().clamp(0.0, 1.0);
        let alpha = self.alpha_empty + (self.alpha_full - self.alpha_empty) * u;
        weights[0] = alpha;
        let rest: f64 = base[1..].iter().sum();
        for (w, b) in weights[1..].iter_mut().zip(&base[1..]) {
            // `(b / rest) * (1 − α)`, in exactly this association: for
            // the legacy two-plugin lowering b == rest, so b/rest is
            // exactly 1.0 and the FGD weight is bit-identical to the
            // pre-profile inline `1.0 − α` (the other association,
            // `(1−α)·b/rest`, drifts by 1 ulp for some inputs).
            *w = if rest > 0.0 {
                (b / rest) * (1.0 - alpha)
            } else {
                (1.0 - alpha) / (base.len() - 1) as f64
            };
        }
        Some(alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::cluster::node::Placement;
    use crate::tasks::{GpuDemand, Task};

    #[test]
    fn loadalpha_reproduces_two_plugin_dynamic_alpha() {
        let dc = ClusterSpec::tiny(2, 4, 0).build();
        let m = LoadAlphaModulator { alpha_empty: 0.9, alpha_full: 0.1 };
        let base = [0.9, 0.1];
        let mut w = base;
        // Empty cluster: α = alpha_empty, weights = [α, 1−α].
        let a = m.modulate(&dc, &base, &mut w).unwrap();
        assert!((a - 0.9).abs() < 1e-12);
        assert!((w[0] - 0.9).abs() < 1e-12 && (w[1] - 0.1).abs() < 1e-12);
        // Bit-identity with the pre-profile inline dynamic-α (which set
        // weights[1] = 1.0 − α literally): checked across awkward α
        // pairs at a partially-utilized cluster, since the proportional
        // split must reduce to *exactly* 1−α for the two-plugin layout.
        let mut dc = ClusterSpec::tiny(1, 4, 0).build();
        for (i, g) in [(10u64, 0usize), (11, 1)] {
            dc.allocate(&Task::new(i, 1.0, 0.0, GpuDemand::Whole(1)), 0, &Placement::Whole {
                gpus: vec![g],
            });
        }
        for (ae, af) in [(0.01, 0.62), (0.9, 0.1), (0.37, 0.0), (1.0, 0.05)] {
            let m = LoadAlphaModulator { alpha_empty: ae, alpha_full: af };
            let base = [ae, 1.0 - ae];
            let mut w = base;
            let a = m.modulate(&dc, &base, &mut w).unwrap();
            assert_eq!(w[0].to_bits(), a.to_bits());
            assert_eq!(
                w[1].to_bits(),
                (1.0 - a).to_bits(),
                "FGD weight drifted from 1−α for α_empty={ae}, α_full={af}"
            );
        }
    }

    #[test]
    fn loadalpha_splits_rest_proportionally_for_three_plugins() {
        let mut dc = ClusterSpec::tiny(1, 4, 0).build();
        // Half the GPUs busy → u = 0.5 → α = 0.5.
        for (i, g) in [(0u64, 0usize), (1, 1)] {
            dc.allocate(&Task::new(i, 1.0, 0.0, GpuDemand::Whole(1)), 0, &Placement::Whole {
                gpus: vec![g],
            });
        }
        let m = LoadAlphaModulator { alpha_empty: 1.0, alpha_full: 0.0 };
        let base = [0.5, 0.3, 0.2];
        let mut w = base;
        let a = m.modulate(&dc, &base, &mut w).unwrap();
        assert!((a - 0.5).abs() < 1e-12);
        assert!((w[0] - 0.5).abs() < 1e-12);
        // 1−α = 0.5 split 3:2 over the base [0.3, 0.2].
        assert!((w[1] - 0.3).abs() < 1e-12 && (w[2] - 0.2).abs() < 1e-12);
    }
}
