//! DRS — Dynamic Resource Scaling: the node sleep/wake subsystem.
//!
//! The paper's power model (Eq. 1–3, `rust/src/power/mod.rs`) assumes
//! every node is always powered on, so idle nodes still burn idle
//! watts and no placement policy can change the *denominator* of the
//! power objective. The energy-efficient-cluster literature (Hu et
//! al.'s DRS; see PAPERS.md) shows switching idle nodes off dominates
//! cluster energy savings. This module realizes that lever as three
//! composable profile entries (`docs/power.md` documents the whole
//! layer):
//!
//! * [`DrsHook`] — a [`PostHook`] driving the per-node
//!   [`PowerState`] machine: a node idle for `idle_timeout`
//!   scheduler-event ticks is drained (`Active → Draining`, one tick of
//!   grace) and then slept (`Draining → Asleep`, standby watts). On
//!   demand pressure — a task fails on the awake fleet but would fit a
//!   sleeper — the hook cancels a drain for free (retry succeeds
//!   immediately) or boots a sleeper (`Asleep → Waking → Active` after
//!   `wake_latency` ticks; the triggering task is lost, which is the
//!   GRAR cost of sleeping that `ext-drs` measures against the EOPC
//!   gain). Wake targets are vetted against the scheduler's *real*
//!   filter chain evaluated on the hypothetically-`Active` node
//!   (`postFailChained`), so a wake is never spent on a node the
//!   retry's chain would veto — including static/custom filters a
//!   node-local heuristic cannot see.
//!   DSL: `hook(drs[:idle_timeout[:wake_latency[:sleep_j[:wake_j]]]])`.
//! * [`DrsFilter`] — the `drs` filter plugin: only `Active` nodes
//!   accept placements. Part of the default chain (a no-op while every
//!   node is `Active`, so legacy placements are bit-identical —
//!   `rust/tests/drs_equivalence.rs` pins this and
//!   `rust/tests/filter_equivalence.rs` still passes). Its PreFilter
//!   never vetoes: the aggregate capacity checks read state-independent
//!   totals, deliberately treating `Waking` (and wakeable `Asleep`)
//!   nodes as future capacity so the `postFail` wake path always gets
//!   its chance.
//! * [`ConsolidatePlugin`] — the `consolidate` score plugin: biases
//!   placements onto nodes that already host work, so idle nodes reach
//!   their sleep deadline instead of being re-touched. Composes with
//!   PWR⊕FGD as `score(pwr=0.4,fgd=0.4,consolidate=0.2)`.
//!
//! **Time.** DRS runs on the scheduler-event clock: one tick per
//! [`crate::sched::Scheduler::place`]/[`crate::sched::Scheduler::release`]
//! protocol entry, delivered to hooks through the `onTick` phase
//! *before* each decision. Both simulation loops drive the same
//! protocol, so tick semantics are identical under monotone inflation
//! and steady-state churn — no loop-specific wiring exists to skip.
//!
//! **Legacy pinning.** `idle_timeout = ∞` (the default; `-1` in the
//! DSL) never sleeps anything, every node stays `Active`, and runs are
//! bit-identical to a scheduler without the hook across policies ×
//! traces × seeds in both loops (`rust/tests/drs_equivalence.rs`).
//!
//! **Counters.** [`DrsHook`] reports its lifecycle counters
//! (`drs_sleeps`, `drs_wakes`, `drs_drains`, `drs_wake_cancels`,
//! `drs_transition_j`) through [`PostHook::counters`]; the
//! observability layer folds them into every
//! [`crate::sched::Scheduler::metrics`] snapshot and catalogues them in
//! [`crate::obs::METRICS_CATALOG`], so they surface in `obs_summary.json`
//! and the coordinator's Prometheus exposition without extra plumbing.

use crate::cluster::node::{Node, Placement, PowerState, ResourceView};
use crate::cluster::Datacenter;
use crate::power;
use crate::sched::filter::{
    AffinityFilter, FilterCtx, FilterPlugin, GpuModelFilter, LabelsFilter,
};
use crate::sched::framework::{PostHook, SchedCtx, ScorePlugin};
use crate::tasks::{GpuDemand, Task};

/// Whether waking node `i` could actually help `task`: resource fit
/// (`can_fit`) plus the task's own node-local declarative constraints
/// (model sets, node selectors, affinity/anti-affinity/spread),
/// mirrored from the default constraint filters — a wake must never be
/// spent on a node the retry's filter chain would veto anyway.
///
/// This is the *fallback* heuristic for direct [`PostHook::post_fail`]
/// calls; the framework's protocol hands the hook the real filter
/// chain ([`PostHook::post_fail_chained`]), where
/// [`wake_could_help_chained`] evaluates the chain itself — including
/// profile-level static filters this mirror cannot see.
fn wake_could_help(dc: &Datacenter, i: usize, task: &Task) -> bool {
    let node = &dc.nodes[i];
    if !node.can_fit(task) {
        return false;
    }
    let ctx = FilterCtx { dc };
    GpuModelFilter.feasible(&ctx, node, task)
        && LabelsFilter { selector: Vec::new() }.feasible(&ctx, node, task)
        && AffinityFilter.feasible(&ctx, node, task)
}

/// Whether waking node `i` would let `task` pass the scheduler's
/// *actual* filter chain: flip the node to a hypothetical `Active`,
/// evaluate every filter (including any static/custom ones the
/// node-local mirror above is blind to — the futile-wake bug), and
/// restore the real power state. Pure with respect to the datacenter:
/// the flip is visible only to the chain evaluation.
fn wake_could_help_chained(
    dc: &mut Datacenter,
    i: usize,
    task: &Task,
    filters: &[Box<dyn FilterPlugin>],
) -> bool {
    let prev = dc.nodes[i].power_state;
    dc.nodes[i].power_state = PowerState::Active;
    let ctx = FilterCtx { dc: &*dc };
    let node = &ctx.dc.nodes[i];
    let ok = node.can_fit(task) && filters.iter().all(|f| f.feasible(&ctx, node, task));
    dc.nodes[i].power_state = prev;
    ok
}

/// How many copies of gang member `member` node `i` could host were it
/// `Active`: the min of whole-GPU groups (`⌊fully_free/tp⌋`), CPU and
/// memory headroom — zero when the (hypothetically `Active`) node fails
/// the real filter chain at all. Like [`wake_could_help_chained`], the
/// power-state flip is visible only to this evaluation.
fn gang_capacity_if_active(
    dc: &mut Datacenter,
    i: usize,
    member: &Task,
    filters: &[Box<dyn FilterPlugin>],
) -> u32 {
    let prev = dc.nodes[i].power_state;
    dc.nodes[i].power_state = PowerState::Active;
    let cap = {
        let ctx = FilterCtx { dc: &*dc };
        let node = &ctx.dc.nodes[i];
        if !node.can_fit(member) || !filters.iter().all(|f| f.feasible(&ctx, node, member)) {
            0
        } else {
            let by_gpu = match member.gpu {
                GpuDemand::Whole(tp) if tp > 0 => (node.gpus_fully_free() / tp as usize) as u32,
                // Members are `Whole(tp)` by construction; anything
                // else fits at least the one copy `can_fit` admitted.
                _ => 1,
            };
            let by_cpu = if member.cpu > 0.0 {
                (node.cpu_free() / member.cpu).floor() as u32
            } else {
                u32::MAX
            };
            let by_mem = if member.mem > 0.0 {
                (node.mem_free() / member.mem).floor() as u32
            } else {
                u32::MAX
            };
            by_gpu.min(by_cpu).min(by_mem)
        }
    };
    dc.nodes[i].power_state = prev;
    cap
}

/// Configuration of the [`DrsHook`] sleep/wake lifecycle.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DrsConfig {
    /// Scheduler-event ticks a node must stay idle before it is
    /// drained for sleep; `f64::INFINITY` (default) disables sleeping
    /// entirely — the legacy-equivalence mode.
    pub idle_timeout: f64,
    /// Ticks a woken node spends in `Waking` before it is `Active`
    /// again. `0` makes wakes instantaneous (the failed decision is
    /// retried and succeeds, so no demand is lost).
    pub wake_latency: u64,
    /// One-time energy charged per sleep transition (J), accumulated
    /// into the `drs_transition_j` counter.
    pub sleep_cost_j: f64,
    /// One-time energy charged per wake transition (J).
    pub wake_cost_j: f64,
}

impl Default for DrsConfig {
    fn default() -> Self {
        DrsConfig {
            idle_timeout: f64::INFINITY,
            wake_latency: 0,
            sleep_cost_j: 0.0,
            wake_cost_j: 0.0,
        }
    }
}

impl DrsConfig {
    /// The two knobs the `ext-drs` sweep varies, transition costs zero.
    pub fn with_timeout(idle_timeout: f64, wake_latency: u64) -> DrsConfig {
        DrsConfig { idle_timeout, wake_latency, ..Default::default() }
    }
}

/// The DRS lifecycle manager (see the module docs for the state
/// machine). Owns the per-node idle ledger; the states themselves live
/// on [`Node::power_state`] so the power sums and the `drs` filter read
/// them without reaching into the hook.
pub struct DrsHook {
    cfg: DrsConfig,
    /// Latest scheduler-event clock value (from `onTick`).
    now: u64,
    /// Per node: the tick at which it last became idle (`None` while
    /// it hosts tasks). Clusters are built empty, so every node starts
    /// idle at the hook's first tick.
    idle_since: Vec<Option<u64>>,
    sleeps: u64,
    wakes: u64,
    drains: u64,
    wake_cancels: u64,
    transition_j: f64,
    /// Whether any node might be in a non-`Active` state — the guard
    /// of the inert-mode (`idle_timeout = ∞`) fast path, which skips
    /// the per-tick fleet walk once a scan has observed an all-awake
    /// fleet. Starts `true` so the first tick always scans; set again
    /// whenever this hook makes a node non-`Active`.
    maybe_non_active: bool,
}

impl DrsHook {
    pub fn new(cfg: DrsConfig) -> DrsHook {
        DrsHook {
            cfg,
            now: 0,
            idle_since: Vec::new(),
            sleeps: 0,
            wakes: 0,
            drains: 0,
            wake_cancels: 0,
            transition_j: 0.0,
            maybe_non_active: true,
        }
    }

    /// Total sleep/wake transition energy charged so far (J); equals
    /// `sleeps·sleep_cost_j + wakes·wake_cost_j` exactly.
    pub fn transition_energy_j(&self) -> f64 {
        self.transition_j
    }

    /// Estimated cost of waking `node`: the configured one-time charge
    /// plus the idle power it will burn over the `wake_latency` ticks
    /// it spends booting (watt·ticks — an energy *proxy* used only to
    /// rank wake targets). The ledger still charges exactly
    /// `wake_cost_j` per wake, so the `transition_j` invariant —
    /// `sleeps·sleep_cost_j + wakes·wake_cost_j` — is untouched.
    fn wake_cost_estimate_j(&self, node: &Node) -> f64 {
        self.cfg.wake_cost_j + self.cfg.wake_latency as f64 * power::p_node(node)
    }

    /// (Re)size the idle ledger to the fleet. A freshly observed node
    /// without tasks counts as idle from now.
    fn ensure_tracking(&mut self, dc: &Datacenter) {
        if self.idle_since.len() != dc.nodes.len() {
            let now = self.now;
            self.idle_since = dc
                .nodes
                .iter()
                .map(|n| if n.n_tasks == 0 { Some(now) } else { None })
                .collect();
        }
    }

    /// The demand-pressure wake pass shared by `post_fail` (node-local
    /// [`wake_could_help`] heuristic) and `post_fail_chained` (full
    /// chain via [`wake_could_help_chained`]). `could_help` decides
    /// whether spending a wake on node `i` can actually serve `task`.
    fn wake_pass(
        &mut self,
        dc: &mut Datacenter,
        task: &Task,
        could_help: &mut dyn FnMut(&mut Datacenter, usize) -> bool,
        invalidate: &mut dyn FnMut(usize),
    ) -> bool {
        self.ensure_tracking(dc);
        let n = dc.nodes.len();
        // Demand pressure: the task failed on the awake fleet. First
        // try to cancel a drain — the node never slept, so waking it is
        // free and the framework's immediate retry can use it.
        let drain_hit = (0..n)
            .find(|&i| dc.nodes[i].power_state == PowerState::Draining && could_help(dc, i));
        if let Some(i) = drain_hit {
            dc.nodes[i].power_state = PowerState::Active;
            self.wake_cancels += 1;
            self.idle_since[i] = Some(self.now);
            invalidate(i);
            return true;
        }
        // Otherwise boot the *cheapest* admissible sleeper: minimum
        // estimated wake cost (`wake_cost_j` plus idle power burned
        // over the boot latency), ties broken by lowest node id — so a
        // homogeneous fleet degenerates to the legacy first-by-index
        // pick and existing equivalence pins hold. `could_help` (which
        // may evaluate the whole filter chain) only runs on strictly
        // cheaper candidates. With zero wake latency the node is
        // usable immediately; otherwise it becomes future capacity and
        // only later arrivals benefit (this task is lost).
        let mut sleep_hit: Option<(usize, f64)> = None;
        for i in 0..n {
            if dc.nodes[i].power_state != PowerState::Asleep {
                continue;
            }
            let est = self.wake_cost_estimate_j(&dc.nodes[i]);
            let cheaper = match sleep_hit {
                Some((_, best)) => est < best,
                None => true,
            };
            if cheaper && could_help(dc, i) {
                sleep_hit = Some((i, est));
            }
        }
        if let Some((i, _)) = sleep_hit {
            self.wakes += 1;
            self.transition_j += self.cfg.wake_cost_j;
            self.idle_since[i] = Some(self.now);
            invalidate(i);
            if self.cfg.wake_latency == 0 {
                dc.nodes[i].power_state = PowerState::Active;
                return true;
            }
            dc.nodes[i].power_state =
                PowerState::Waking { ready_at: self.now + self.cfg.wake_latency };
            self.maybe_non_active = true;
            return false;
        }
        false
    }
}

impl PostHook for DrsHook {
    fn name(&self) -> &'static str {
        "drs"
    }

    fn on_tick(&mut self, dc: &mut Datacenter, now: u64, invalidate: &mut dyn FnMut(usize)) {
        self.now = now;
        self.ensure_tracking(dc);
        // Inert-mode fast path: with an infinite timeout this hook
        // never drains, so once a scan has seen an all-Active fleet
        // there is nothing a tick could transition — skip the O(nodes)
        // walk until a `postFail` wake makes a node non-Active again.
        if !self.cfg.idle_timeout.is_finite() && !self.maybe_non_active {
            return;
        }
        let mut any_non_active = false;
        for i in 0..dc.nodes.len() {
            match dc.nodes[i].power_state {
                PowerState::Waking { ready_at } => {
                    if ready_at <= now {
                        dc.nodes[i].power_state = PowerState::Active;
                        // Idle age restarts at boot, or a wasted wake
                        // would re-drain on the very next tick.
                        self.idle_since[i] = Some(now);
                        invalidate(i);
                    }
                }
                PowerState::Draining => {
                    if dc.nodes[i].n_tasks == 0 {
                        dc.nodes[i].power_state = PowerState::Asleep;
                        self.sleeps += 1;
                        self.transition_j += self.cfg.sleep_cost_j;
                    } else {
                        // A custom filter chain without `drs` may have
                        // placed onto the draining node; cancel.
                        dc.nodes[i].power_state = PowerState::Active;
                        self.idle_since[i] = None;
                    }
                    invalidate(i);
                }
                PowerState::Active => {
                    if let Some(since) = self.idle_since[i] {
                        if self.cfg.idle_timeout.is_finite()
                            && (now.saturating_sub(since)) as f64 >= self.cfg.idle_timeout
                        {
                            dc.nodes[i].power_state = PowerState::Draining;
                            self.drains += 1;
                            invalidate(i);
                        }
                    }
                }
                PowerState::Asleep => {}
            }
            if dc.nodes[i].power_state != PowerState::Active {
                any_non_active = true;
            }
        }
        self.maybe_non_active = any_non_active;
    }

    fn post_fail(
        &mut self,
        dc: &mut Datacenter,
        task: &Task,
        invalidate: &mut dyn FnMut(usize),
    ) -> bool {
        self.wake_pass(dc, task, &mut |dc, i| wake_could_help(dc, i, task), invalidate)
    }

    /// The chain-aware wake path the framework's protocol actually
    /// takes: candidate sleepers/drainers are vetted against the
    /// scheduler's *real* filter chain (hypothetically `Active`), so a
    /// wake is never spent on a node a static or custom filter — one
    /// [`wake_could_help`]'s node-local mirror cannot see — would veto
    /// on the retry.
    fn post_fail_chained(
        &mut self,
        dc: &mut Datacenter,
        task: &Task,
        filters: &[Box<dyn FilterPlugin>],
        invalidate: &mut dyn FnMut(usize),
    ) -> bool {
        self.wake_pass(
            dc,
            task,
            &mut |dc, i| wake_could_help_chained(dc, i, task, filters),
            invalidate,
        )
    }

    /// Gang-aware wake sizing, called from the `place_gang` protocol's
    /// `postFail` round: the singleton paths above wake exactly one
    /// node per failure, but a gang member failing with `remaining`
    /// members still to place may need *several* nodes booted at once —
    /// and a wake that cannot reach the full residual demand is futile
    /// (the gang rolls back atomically and every booted node goes back
    /// to sleep unused). So: size a wake *set* against `remaining`
    /// using chain-vetted per-node member capacity
    /// ([`gang_capacity_if_active`]), spend free drain cancellations
    /// first, then the cheapest sleepers by wake-cost estimate (ties by
    /// lowest id, as in the singleton pass), and decline entirely —
    /// waking nothing — when even the whole admissible fleet cannot
    /// host the remainder.
    fn post_fail_gang(
        &mut self,
        dc: &mut Datacenter,
        member: &Task,
        remaining: u32,
        filters: &[Box<dyn FilterPlugin>],
        invalidate: &mut dyn FnMut(usize),
    ) -> bool {
        if remaining <= 1 {
            // The last member is exactly the singleton problem.
            return self.post_fail_chained(dc, member, filters, invalidate);
        }
        self.ensure_tracking(dc);
        let mut active_cap: u32 = 0;
        let mut drains: Vec<(usize, u32)> = Vec::new();
        let mut sleepers: Vec<(usize, u32, f64)> = Vec::new();
        for i in 0..dc.nodes.len() {
            match dc.nodes[i].power_state {
                PowerState::Active => {
                    active_cap = active_cap
                        .saturating_add(gang_capacity_if_active(dc, i, member, filters));
                }
                PowerState::Draining => {
                    let cap = gang_capacity_if_active(dc, i, member, filters);
                    if cap > 0 {
                        drains.push((i, cap));
                    }
                }
                PowerState::Asleep => {
                    let cap = gang_capacity_if_active(dc, i, member, filters);
                    if cap > 0 {
                        let est = self.wake_cost_estimate_j(&dc.nodes[i]);
                        sleepers.push((i, cap, est));
                    }
                }
                // Already booting: future capacity, not wakeable again.
                PowerState::Waking { .. } => {}
            }
        }
        let mut needed = remaining.saturating_sub(active_cap);
        if needed == 0 {
            // Capacity was never the problem (this member's failure has
            // some other cause) — waking cannot help.
            return false;
        }
        let reachable: u32 = drains
            .iter()
            .map(|&(_, c)| c)
            .chain(sleepers.iter().map(|&(_, c, _)| c))
            .fold(0, u32::saturating_add);
        if reachable < needed {
            // Even the full fleet cannot host the residual gang:
            // decline, spending no wake energy on a doomed attempt.
            return false;
        }
        let mut retry = false;
        for &(i, cap) in &drains {
            if needed == 0 {
                break;
            }
            dc.nodes[i].power_state = PowerState::Active;
            self.wake_cancels += 1;
            self.idle_since[i] = Some(self.now);
            invalidate(i);
            needed = needed.saturating_sub(cap);
            retry = true;
        }
        // Cheapest sleepers next; the scan above is id-ordered and the
        // sort is stable, so cost ties break by lowest id.
        sleepers.sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap_or(core::cmp::Ordering::Equal));
        for &(i, cap, _) in &sleepers {
            if needed == 0 {
                break;
            }
            self.wakes += 1;
            self.transition_j += self.cfg.wake_cost_j;
            self.idle_since[i] = Some(self.now);
            invalidate(i);
            if self.cfg.wake_latency == 0 {
                dc.nodes[i].power_state = PowerState::Active;
                retry = true;
            } else {
                dc.nodes[i].power_state =
                    PowerState::Waking { ready_at: self.now + self.cfg.wake_latency };
                self.maybe_non_active = true;
            }
            needed = needed.saturating_sub(cap);
        }
        retry
    }

    fn post_place(
        &mut self,
        dc: &mut Datacenter,
        node_id: usize,
        invalidate: &mut dyn FnMut(usize),
    ) {
        self.ensure_tracking(dc);
        let node = &mut dc.nodes[node_id];
        if node.n_tasks == 0 {
            // A release drained the node: start (or keep) its idle
            // clock — the sleep deadline is idle_since + idle_timeout.
            if self.idle_since[node_id].is_none() {
                self.idle_since[node_id] = Some(self.now);
            }
        } else {
            self.idle_since[node_id] = None;
            // A placement landed mid-transition or on a sleeper (only
            // possible through a custom chain that admits non-Active
            // nodes): force the node awake so its workload is accounted
            // as powered. A slept node pays the wake transition so the
            // ledger (`sleeps = wakes + |Asleep|`) stays balanced.
            match node.power_state {
                PowerState::Active => {}
                PowerState::Asleep => {
                    self.wakes += 1;
                    self.transition_j += self.cfg.wake_cost_j;
                    node.power_state = PowerState::Active;
                    invalidate(node_id);
                }
                PowerState::Draining | PowerState::Waking { .. } => {
                    node.power_state = PowerState::Active;
                    invalidate(node_id);
                }
            }
        }
    }

    fn counters(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("drs_sleeps", self.sleeps),
            ("drs_wakes", self.wakes),
            ("drs_drains", self.drains),
            ("drs_wake_cancels", self.wake_cancels),
            ("drs_transition_j", self.transition_j.round() as u64),
        ]
    }
}

/// The `drs` filter plugin: only [`PowerState::Active`] nodes accept
/// placements — `Draining` nodes must not be re-touched on their way
/// to sleep, `Asleep` nodes host nothing, and `Waking` nodes are still
/// booting. Part of the default chain; a no-op while every node is
/// `Active`.
pub struct DrsFilter;

impl FilterPlugin for DrsFilter {
    fn name(&self) -> &'static str {
        "drs"
    }

    // No `pre_filter` override: the cluster-wide capacity checks
    // (aggregate totals, candidate counts) deliberately ignore power
    // states — `Waking` and wakeable `Asleep` nodes are future
    // capacity, and a veto here would rob the DRS hook's `postFail`
    // wake path of its trigger.

    fn feasible(&self, _ctx: &FilterCtx, node: &Node, _task: &Task) -> bool {
        node.power_state == PowerState::Active
    }
}

/// The `consolidate` score plugin: prefer nodes already hosting work,
/// then idle-but-powered nodes — so sleepers stay asleep and idle
/// nodes age toward their sleep deadline untouched. Useful on its own
/// as a packing nudge, and the intended companion of `hook(drs:…)`.
pub struct ConsolidatePlugin;

impl ScorePlugin for ConsolidatePlugin {
    fn name(&self) -> &'static str {
        "Consolidate"
    }

    fn score(&self, _ctx: &SchedCtx, node: &Node, _task: &Task, _placements: &[Placement]) -> f64 {
        match node.power_state {
            PowerState::Active if node.n_tasks > 0 => 2.0,
            PowerState::Active => 1.0,
            // Only reachable through custom chains that admit
            // non-Active nodes; rank them below everything powered.
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::sched::{PolicyKind, Scheduler};
    use crate::tasks::{GpuDemand, Workload};

    fn fill_node(dc: &mut Datacenter, node: usize, id: u64) -> (Task, Placement) {
        let gpus = dc.nodes[node].gpu_alloc.len() as u32;
        let t = Task::new(id, 1.0, 0.0, GpuDemand::Whole(gpus));
        let p = dc.nodes[node].candidate_placements(&t).pop().unwrap();
        dc.allocate(&t, node, &p);
        (t, p)
    }

    #[test]
    fn idle_nodes_drain_then_sleep_after_timeout() {
        let mut dc = ClusterSpec::tiny(2, 2, 0).build();
        let mut h = DrsHook::new(DrsConfig::with_timeout(3.0, 5));
        let mut inval = |_n: usize| {};
        // Ticks 1..3: idle but under the timeout (idle since tick 1).
        for now in 1..=3 {
            h.on_tick(&mut dc, now, &mut inval);
            assert_eq!(dc.nodes[0].power_state, PowerState::Active, "tick {now}");
        }
        // Tick 4: 3 ticks idle -> drained; tick 5: asleep.
        h.on_tick(&mut dc, 4, &mut inval);
        assert_eq!(dc.nodes[0].power_state, PowerState::Draining);
        h.on_tick(&mut dc, 5, &mut inval);
        assert_eq!(dc.nodes[0].power_state, PowerState::Asleep);
        assert_eq!(dc.nodes[1].power_state, PowerState::Asleep);
        let counters = h.counters();
        assert!(counters.contains(&("drs_sleeps", 2)));
        assert!(counters.contains(&("drs_drains", 2)));
    }

    #[test]
    fn infinite_timeout_never_sleeps() {
        let mut dc = ClusterSpec::tiny(2, 2, 0).build();
        let mut h = DrsHook::new(DrsConfig::default());
        let mut inval = |_n: usize| {};
        for now in 1..=1_000 {
            h.on_tick(&mut dc, now, &mut inval);
        }
        assert!(dc.nodes.iter().all(|n| n.power_state == PowerState::Active));
        assert_eq!(h.counters(), vec![
            ("drs_sleeps", 0),
            ("drs_wakes", 0),
            ("drs_drains", 0),
            ("drs_wake_cancels", 0),
            ("drs_transition_j", 0),
        ]);
    }

    #[test]
    fn busy_nodes_never_drain_and_release_restarts_the_clock() {
        let mut dc = ClusterSpec::tiny(1, 2, 0).build();
        let mut h = DrsHook::new(DrsConfig::with_timeout(2.0, 0));
        let mut inval = |_n: usize| {};
        h.on_tick(&mut dc, 1, &mut inval);
        let (t, p) = fill_node(&mut dc, 0, 7);
        h.post_place(&mut dc, 0, &mut inval);
        for now in 2..=50 {
            h.on_tick(&mut dc, now, &mut inval);
            assert_eq!(dc.nodes[0].power_state, PowerState::Active, "tick {now}");
        }
        // Release at tick 50: idle clock restarts, sleep at ~tick 53.
        dc.deallocate(&t, 0, &p);
        h.post_place(&mut dc, 0, &mut inval);
        h.on_tick(&mut dc, 51, &mut inval);
        assert_eq!(dc.nodes[0].power_state, PowerState::Active);
        h.on_tick(&mut dc, 52, &mut inval);
        assert_eq!(dc.nodes[0].power_state, PowerState::Draining);
        h.on_tick(&mut dc, 53, &mut inval);
        assert_eq!(dc.nodes[0].power_state, PowerState::Asleep);
    }

    #[test]
    fn demand_pressure_cancels_drains_and_wakes_sleepers() {
        let mut dc = ClusterSpec::tiny(2, 2, 0).build();
        let mut h = DrsHook::new(DrsConfig {
            idle_timeout: 1.0,
            wake_latency: 4,
            sleep_cost_j: 10.0,
            wake_cost_j: 30.0,
        });
        let mut inval = |_n: usize| {};
        // Drive both nodes asleep.
        for now in 1..=4 {
            h.on_tick(&mut dc, now, &mut inval);
        }
        assert!(dc.nodes.iter().all(|n| n.power_state == PowerState::Asleep));
        // A failing task wakes node 0 (lowest id that fits); with a
        // 4-tick latency the decision is not retried.
        let t = Task::new(9, 1.0, 0.0, GpuDemand::Whole(1));
        assert!(!h.post_fail(&mut dc, &t, &mut inval));
        assert_eq!(dc.nodes[0].power_state, PowerState::Waking { ready_at: 4 + 4 });
        assert_eq!(dc.nodes[1].power_state, PowerState::Asleep);
        // Wake completes once the clock reaches ready_at.
        h.on_tick(&mut dc, 7, &mut inval);
        assert!(matches!(dc.nodes[0].power_state, PowerState::Waking { .. }));
        h.on_tick(&mut dc, 8, &mut inval);
        assert_eq!(dc.nodes[0].power_state, PowerState::Active);
        // Energy ledger: 2 sleeps + 1 wake, exactly once each.
        assert!((h.transition_energy_j() - (2.0 * 10.0 + 30.0)).abs() < 1e-12);
        // A draining node cancels for free (retry requested).
        h.on_tick(&mut dc, 10, &mut inval); // node 0 idle since 8 -> drains
        assert_eq!(dc.nodes[0].power_state, PowerState::Draining);
        assert!(h.post_fail(&mut dc, &t, &mut inval));
        assert_eq!(dc.nodes[0].power_state, PowerState::Active);
        assert!((h.transition_energy_j() - (2.0 * 10.0 + 30.0)).abs() < 1e-12);
    }

    #[test]
    fn zero_latency_wake_allows_immediate_retry() {
        let mut dc = ClusterSpec::tiny(1, 2, 0).build();
        let mut h = DrsHook::new(DrsConfig::with_timeout(1.0, 0));
        let mut inval = |_n: usize| {};
        for now in 1..=3 {
            h.on_tick(&mut dc, now, &mut inval);
        }
        assert_eq!(dc.nodes[0].power_state, PowerState::Asleep);
        let t = Task::new(1, 1.0, 0.0, GpuDemand::Whole(1));
        assert!(h.post_fail(&mut dc, &t, &mut inval), "zero-latency wake must retry");
        assert_eq!(dc.nodes[0].power_state, PowerState::Active);
    }

    #[test]
    fn wake_pass_picks_cheapest_sleeper() {
        use crate::cluster::inventory::NodePool;
        use crate::cluster::GpuModel;
        // Node 0: 8 GPUs, node 1: 1 GPU — same model, so booting
        // node 1 burns far less idle power over the wake latency.
        let pool = |gpus| NodePool {
            count: 1,
            vcpus: 96.0,
            mem: 393_216.0,
            gpu_model: Some(GpuModel::G2),
            gpus_per_node: gpus,
            mig: false,
            labels: Vec::new(),
        };
        let mut dc = ClusterSpec { zones: 0, pools: vec![pool(8), pool(1)] }.build();
        let mut h = DrsHook::new(DrsConfig {
            idle_timeout: 1.0,
            wake_latency: 50,
            sleep_cost_j: 0.0,
            wake_cost_j: 5.0,
        });
        let mut inval = |_n: usize| {};
        for now in 1..=3 {
            h.on_tick(&mut dc, now, &mut inval);
        }
        assert!(dc.nodes.iter().all(|n| n.power_state == PowerState::Asleep));
        assert!(h.wake_cost_estimate_j(&dc.nodes[1]) < h.wake_cost_estimate_j(&dc.nodes[0]));
        // The task fits either node; the hook must boot the cheap one,
        // not the first by index.
        let t = Task::new(9, 1.0, 0.0, GpuDemand::Whole(1));
        assert!(!h.post_fail(&mut dc, &t, &mut inval), "50-tick boot: no retry");
        assert_eq!(dc.nodes[0].power_state, PowerState::Asleep, "woke the expensive node");
        assert_eq!(dc.nodes[1].power_state, PowerState::Waking { ready_at: 3 + 50 });
        // A demand only the big node can serve still wakes the big node.
        let big = Task::new(10, 1.0, 0.0, GpuDemand::Whole(8));
        assert!(!h.post_fail(&mut dc, &big, &mut inval));
        assert_eq!(dc.nodes[0].power_state, PowerState::Waking { ready_at: 3 + 50 });
    }

    #[test]
    fn wake_pass_breaks_cost_ties_by_lowest_id() {
        // Homogeneous fleet: every sleeper costs the same, so the
        // legacy deterministic pick (lowest id) must be preserved.
        let mut dc = ClusterSpec::tiny(3, 2, 0).build();
        let mut h = DrsHook::new(DrsConfig {
            idle_timeout: 1.0,
            wake_latency: 4,
            sleep_cost_j: 0.0,
            wake_cost_j: 30.0,
        });
        let mut inval = |_n: usize| {};
        for now in 1..=3 {
            h.on_tick(&mut dc, now, &mut inval);
        }
        assert!(dc.nodes.iter().all(|n| n.power_state == PowerState::Asleep));
        let t = Task::new(9, 1.0, 0.0, GpuDemand::Whole(1));
        assert!(!h.post_fail(&mut dc, &t, &mut inval));
        assert_eq!(dc.nodes[0].power_state, PowerState::Waking { ready_at: 3 + 4 });
        assert_eq!(dc.nodes[1].power_state, PowerState::Asleep);
        assert_eq!(dc.nodes[2].power_state, PowerState::Asleep);
    }

    #[test]
    fn wake_targeting_respects_task_constraints() {
        use crate::tasks::TaskConstraints;
        // Two sleepers; the task's node-selector only matches node 1.
        // Waking node 0 would be wasted energy (the retry's labels
        // filter vetoes it), so the hook must skip to node 1.
        let mut dc = ClusterSpec::tiny(2, 2, 0).build();
        dc.nodes[1].labels.push(("zone".to_string(), "z1".to_string()));
        let mut h = DrsHook::new(DrsConfig::with_timeout(1.0, 0));
        let mut inval = |_n: usize| {};
        for now in 1..=3 {
            h.on_tick(&mut dc, now, &mut inval);
        }
        assert!(dc.nodes.iter().all(|n| n.power_state == PowerState::Asleep));
        let t = Task::new(1, 1.0, 0.0, GpuDemand::Whole(1)).with_constraints(TaskConstraints {
            node_selector: vec![("zone".to_string(), "z1".to_string())],
            ..Default::default()
        });
        assert!(h.post_fail(&mut dc, &t, &mut inval), "zero-latency wake must retry");
        assert_eq!(dc.nodes[0].power_state, PowerState::Asleep, "wasted wake on node 0");
        assert_eq!(dc.nodes[1].power_state, PowerState::Active);
        // No admissible sleeper at all: nothing is woken.
        let nowhere = Task::new(2, 1.0, 0.0, GpuDemand::Whole(1)).with_constraints(
            TaskConstraints {
                node_selector: vec![("zone".to_string(), "z9".to_string())],
                ..Default::default()
            },
        );
        assert!(!h.post_fail(&mut dc, &nowhere, &mut inval));
        assert_eq!(dc.nodes[0].power_state, PowerState::Asleep);
    }

    #[test]
    fn chained_wake_sees_static_chain_filters() {
        use crate::sched::filter::default_filter_chain;
        // The chain carries a *static* `labels` selector (profile
        // policy, not a task constraint), which the node-local
        // `wake_could_help` mirror is blind to: the old code woke
        // node 0 only for the retry's chain to veto it — a futile
        // wake. The chained path must skip straight to node 1.
        let mut dc = ClusterSpec::tiny(2, 2, 0).build();
        dc.nodes[1].labels.push(("zone".to_string(), "z1".to_string()));
        let mut h = DrsHook::new(DrsConfig::with_timeout(1.0, 0));
        let mut inval = |_n: usize| {};
        for now in 1..=3 {
            h.on_tick(&mut dc, now, &mut inval);
        }
        assert!(dc.nodes.iter().all(|n| n.power_state == PowerState::Asleep));
        let mut chain = default_filter_chain();
        chain.push(Box::new(LabelsFilter {
            selector: vec![("zone".to_string(), "z1".to_string())],
        }));
        let t = Task::new(1, 1.0, 0.0, GpuDemand::Whole(1));
        assert!(h.post_fail_chained(&mut dc, &t, &chain, &mut inval));
        assert_eq!(dc.nodes[0].power_state, PowerState::Asleep, "futile wake of node 0");
        assert_eq!(dc.nodes[1].power_state, PowerState::Active);
        // The hypothetical-Active flip must not leak: node 0 is still
        // asleep, and a task no chain admits wakes nothing.
        let t2 = Task::new(2, 1.0, 0.0, GpuDemand::Whole(64));
        assert!(!h.post_fail_chained(&mut dc, &t2, &chain, &mut inval));
        assert_eq!(dc.nodes[0].power_state, PowerState::Asleep);
    }

    #[test]
    fn place_protocol_skips_futile_wakes_end_to_end() {
        use crate::sched::filter::default_filter_chain;
        // Through the full protocol: a scheduler whose chain pins
        // placements to zone=z1 plus a DRS hook. Once the fleet
        // sleeps, a failing task must wake (and land on) the z1 node
        // — never the chain-vetoed node 0.
        let mut dc = ClusterSpec::tiny(2, 2, 0).build();
        dc.nodes[1].labels.push(("zone".to_string(), "z1".to_string()));
        dc.note_fleet_changed();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::FirstFit);
        let mut chain = default_filter_chain();
        chain.push(Box::new(LabelsFilter {
            selector: vec![("zone".to_string(), "z1".to_string())],
        }));
        s.set_filters(chain);
        s.add_post_hook(Box::new(DrsHook::new(DrsConfig::with_timeout(1.0, 0))));
        // Tick the fleet to sleep with protocol entries that place
        // nothing (the demand exceeds any node, so no wake either).
        for i in 0..4 {
            let big = Task::new(i, 1.0, 0.0, GpuDemand::Whole(64));
            assert!(s.place(&mut dc, &w, &big).is_none());
        }
        assert!(dc.nodes.iter().all(|n| n.power_state == PowerState::Asleep));
        let t = Task::new(9, 1.0, 0.0, GpuDemand::Whole(1));
        let d = s.place(&mut dc, &w, &t).expect("zero-latency wake retries onto z1");
        assert_eq!(d.node, 1);
        assert_eq!(
            dc.nodes[0].power_state,
            PowerState::Asleep,
            "woke a node the chain's static selector vetoes"
        );
    }

    #[test]
    fn placement_on_sleeper_via_custom_chain_wakes_and_pays() {
        // A chain without the `drs` filter may legally place onto a
        // sleeping node; post_place must wake it (so its workload is
        // billed as powered) and charge the wake so the
        // `sleeps = wakes + |Asleep|` ledger stays balanced.
        let mut dc = ClusterSpec::tiny(1, 2, 0).build();
        let mut h = DrsHook::new(DrsConfig {
            idle_timeout: 1.0,
            wake_latency: 5,
            sleep_cost_j: 10.0,
            wake_cost_j: 30.0,
        });
        let mut inval = |_n: usize| {};
        for now in 1..=3 {
            h.on_tick(&mut dc, now, &mut inval);
        }
        assert_eq!(dc.nodes[0].power_state, PowerState::Asleep);
        let (_t, _p) = fill_node(&mut dc, 0, 1);
        h.post_place(&mut dc, 0, &mut inval);
        assert_eq!(dc.nodes[0].power_state, PowerState::Active);
        // 1 sleep + 1 (forced) wake, energy charged exactly once each.
        assert!((h.transition_energy_j() - (10.0 + 30.0)).abs() < 1e-12);
        let counters = h.counters();
        assert!(counters.contains(&("drs_sleeps", 1)));
        assert!(counters.contains(&("drs_wakes", 1)));
    }

    #[test]
    fn filter_admits_only_active_nodes() {
        let mut dc = ClusterSpec::tiny(4, 2, 0).build();
        dc.nodes[1].power_state = PowerState::Draining;
        dc.nodes[2].power_state = PowerState::Asleep;
        dc.nodes[3].power_state = PowerState::Waking { ready_at: 99 };
        let ctx = FilterCtx { dc: &dc };
        let t = Task::new(0, 1.0, 0.0, GpuDemand::Whole(1));
        assert!(DrsFilter.feasible(&ctx, &dc.nodes[0], &t));
        for i in 1..4 {
            assert!(!DrsFilter.feasible(&ctx, &dc.nodes[i], &t), "node {i}");
        }
        // PreFilter never vetoes (future capacity).
        assert!(DrsFilter.pre_filter(&ctx, &t));
        assert!(!DrsFilter.constrains(&t));
        // Through the whole scheduler: only node 0 is ever selected.
        let mut sched = Scheduler::from_policy(PolicyKind::FirstFit);
        let w = Workload::default();
        let d = sched.schedule(&dc, &w, &t).expect("node 0 is awake");
        assert_eq!(d.node, 0);
    }

    #[test]
    fn consolidate_prefers_busy_then_idle_active_nodes() {
        let mut dc = ClusterSpec::tiny(3, 2, 0).build();
        let t = Task::new(5, 1.0, 0.0, GpuDemand::Frac(0.5));
        // Node 0 busy, node 1 idle-active, node 2 asleep.
        fill_node(&mut dc, 0, 1);
        dc.nodes[2].power_state = PowerState::Asleep;
        let w = Workload::default();
        let pw = crate::frag::PreparedWorkload::new(&w);
        let ctx = SchedCtx {
            dc: &dc,
            workload: &w,
            prepared: &pw,
            generations: &[0, 0, 0],
            caps: crate::sched::framework::ClusterCaps::of(&dc),
            gang: None,
        };
        let score_of = |node: usize| {
            ConsolidatePlugin.score(&ctx, &dc.nodes[node], &t, &[])
        };
        assert!(score_of(0) > score_of(1));
        assert!(score_of(1) > score_of(2));
    }

    #[test]
    fn gang_wake_boots_a_set_sized_to_the_remaining_members() {
        use crate::sched::filter::default_filter_chain;
        // 4 sleeping 2-GPU nodes; a member needs 2 whole GPUs, so each
        // node hosts exactly one. 3 residual members must wake exactly
        // 3 nodes (the old one-wake-per-failure path stranded the gang).
        let mut dc = ClusterSpec::tiny(4, 2, 0).build();
        let mut h = DrsHook::new(DrsConfig::with_timeout(1.0, 0));
        let mut inval = |_n: usize| {};
        for now in 1..=3 {
            h.on_tick(&mut dc, now, &mut inval);
        }
        assert!(dc.nodes.iter().all(|n| n.power_state == PowerState::Asleep));
        let chain = default_filter_chain();
        let member = Task::new(9, 1.0, 0.0, GpuDemand::Whole(2));
        assert!(h.post_fail_gang(&mut dc, &member, 3, &chain, &mut inval));
        let active =
            dc.nodes.iter().filter(|n| n.power_state == PowerState::Active).count();
        assert_eq!(active, 3, "wake set sized to the residual gang");
        assert_eq!(dc.nodes[3].power_state, PowerState::Asleep, "cost ties: lowest ids");
        assert!(h.counters().contains(&("drs_wakes", 3)));
    }

    #[test]
    fn gang_wake_declines_when_the_fleet_cannot_host_the_remainder() {
        use crate::sched::filter::default_filter_chain;
        // Only 4 nodes can host one member each; 5 residual members are
        // unreachable, so the hook must wake *nothing* (a partial wake
        // spree would be rolled back unused by the atomic gang).
        let mut dc = ClusterSpec::tiny(4, 2, 0).build();
        let mut h = DrsHook::new(DrsConfig::with_timeout(1.0, 0));
        let mut inval = |_n: usize| {};
        for now in 1..=3 {
            h.on_tick(&mut dc, now, &mut inval);
        }
        let chain = default_filter_chain();
        let member = Task::new(9, 1.0, 0.0, GpuDemand::Whole(2));
        assert!(!h.post_fail_gang(&mut dc, &member, 5, &chain, &mut inval));
        assert!(dc.nodes.iter().all(|n| n.power_state == PowerState::Asleep));
        assert!(h.counters().contains(&("drs_wakes", 0)));
        assert!((h.transition_energy_j() - 0.0).abs() < 1e-12);
    }

    #[test]
    fn gang_wake_spends_free_drain_cancellations_before_sleepers() {
        use crate::sched::filter::default_filter_chain;
        let mut dc = ClusterSpec::tiny(4, 2, 0).build();
        let mut h = DrsHook::new(DrsConfig {
            idle_timeout: 1.0,
            wake_latency: 0,
            sleep_cost_j: 0.0,
            wake_cost_j: 30.0,
        });
        let mut inval = |_n: usize| {};
        for now in 1..=3 {
            h.on_tick(&mut dc, now, &mut inval);
        }
        // Node 2 is mid-drain (never slept): cancelling it is free.
        dc.nodes[2].power_state = PowerState::Draining;
        let chain = default_filter_chain();
        let member = Task::new(9, 1.0, 0.0, GpuDemand::Whole(2));
        assert!(h.post_fail_gang(&mut dc, &member, 2, &chain, &mut inval));
        assert_eq!(dc.nodes[2].power_state, PowerState::Active, "drain cancelled");
        assert_eq!(dc.nodes[0].power_state, PowerState::Active, "one sleeper booted");
        assert_eq!(dc.nodes[1].power_state, PowerState::Asleep);
        assert_eq!(dc.nodes[3].power_state, PowerState::Asleep);
        assert!(h.counters().contains(&("drs_wake_cancels", 1)));
        // Energy: one paid wake only — the cancellation was free.
        assert!((h.transition_energy_j() - 30.0).abs() < 1e-12);
    }

    #[test]
    fn gang_wake_with_latency_boots_future_capacity_without_retry() {
        use crate::sched::filter::default_filter_chain;
        let mut dc = ClusterSpec::tiny(4, 2, 0).build();
        let mut h = DrsHook::new(DrsConfig::with_timeout(1.0, 4));
        let mut inval = |_n: usize| {};
        for now in 1..=3 {
            h.on_tick(&mut dc, now, &mut inval);
        }
        let chain = default_filter_chain();
        let member = Task::new(9, 1.0, 0.0, GpuDemand::Whole(2));
        // Booting takes 4 ticks: the wakes are committed (future
        // capacity for the gang's next arrival) but no retry now.
        assert!(!h.post_fail_gang(&mut dc, &member, 2, &chain, &mut inval));
        let waking = dc
            .nodes
            .iter()
            .filter(|n| matches!(n.power_state, PowerState::Waking { .. }))
            .count();
        assert_eq!(waking, 2);
    }

    #[test]
    fn gang_wake_for_the_last_member_is_the_singleton_path() {
        use crate::sched::filter::default_filter_chain;
        let mut dc = ClusterSpec::tiny(3, 2, 0).build();
        let mut h = DrsHook::new(DrsConfig::with_timeout(1.0, 0));
        let mut inval = |_n: usize| {};
        for now in 1..=3 {
            h.on_tick(&mut dc, now, &mut inval);
        }
        let chain = default_filter_chain();
        let member = Task::new(9, 1.0, 0.0, GpuDemand::Whole(2));
        assert!(h.post_fail_gang(&mut dc, &member, 1, &chain, &mut inval));
        let active =
            dc.nodes.iter().filter(|n| n.power_state == PowerState::Active).count();
        assert_eq!(active, 1, "one member, one wake");
    }

    #[test]
    fn place_gang_wakes_a_sleeping_fleet_end_to_end() {
        use crate::sched::gang::{gang_task, tp_violations};
        use crate::tasks::GangSpec;
        let mut dc = ClusterSpec::tiny(4, 2, 0).build();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::FirstFit);
        s.add_post_hook(Box::new(DrsHook::new(DrsConfig::with_timeout(1.0, 0))));
        // Tick the fleet to sleep with protocol entries placing nothing.
        for i in 0..4 {
            let big = Task::new(i, 1.0, 0.0, GpuDemand::Whole(64));
            assert!(s.place(&mut dc, &w, &big).is_none());
        }
        assert!(dc.nodes.iter().all(|n| n.power_state == PowerState::Asleep));
        // A 2-member gang (tp=2, pp=2, dp=1) needs two nodes awake at
        // once; the gang-aware wake must boot both.
        let spec = GangSpec::new(2, 2, 1).unwrap();
        let g = gang_task(9, 1.0, 0.0, spec);
        let d = s.place_gang(&mut dc, &w, &g).expect("gang-aware wake places the gang");
        assert_eq!(d.members.len(), 2);
        assert_ne!(d.members[0].node, d.members[1].node, "2-GPU nodes host one member");
        assert_eq!(tp_violations(&d.members, spec), 0);
        assert_eq!(dc.nodes[2].power_state, PowerState::Asleep);
        assert_eq!(dc.nodes[3].power_state, PowerState::Asleep);
    }

    #[test]
    fn place_gang_declines_cpu_bound_gangs_without_spending_wakes() {
        use crate::sched::gang::gang_task;
        use crate::tasks::GangSpec;
        // The `gang` PreFilter's GPU-contiguity bound passes (8 groups
        // of 2 across 4×4 GPUs ≥ 5 members) and so do the aggregate
        // CPU sums (300 ≤ 384), but per-node CPU caps each node at one
        // 60-vCPU member — 4 < 5: the wake pass must recognize the
        // shortfall and leave the whole fleet asleep.
        let mut dc = ClusterSpec::tiny(4, 4, 0).build();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::FirstFit);
        s.add_post_hook(Box::new(DrsHook::new(DrsConfig::with_timeout(1.0, 0))));
        for i in 0..4 {
            let big = Task::new(i, 1.0, 0.0, GpuDemand::Whole(64));
            assert!(s.place(&mut dc, &w, &big).is_none());
        }
        assert!(dc.nodes.iter().all(|n| n.power_state == PowerState::Asleep));
        let spec = GangSpec::new(2, 5, 1).unwrap();
        let g = gang_task(9, 60.0, 0.0, spec);
        assert!(s.place_gang(&mut dc, &w, &g).is_none());
        assert!(dc.nodes.iter().all(|n| n.power_state == PowerState::Asleep));
        assert_eq!(s.hook_counter("drs_wakes"), 0, "no energy spent on a doomed gang");
    }
}
