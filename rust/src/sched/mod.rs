//! The scheduling layer: a Kubernetes-scheduling-framework analog with
//! named extension points (filter → modulate → score → normalize →
//! weighted combine → bind → postPlace/postFail) and the paper's policy
//! zoo, assembled from declarative [`SchedulerProfile`]s.
//!
//! * [`framework`] — the plugin pipeline of Algorithm 1, including the
//!   k8s score normalization used to combine PWR with FGD (§IV-A), plus
//!   the `postPlace`/`postFail` hook protocol.
//! * [`profile`] — `SchedulerProfile` + the `--policy` DSL + the
//!   string-keyed plugin/binder/modulator/hook/filter registries.
//! * [`filter`] — the `filter` extension point: declarative
//!   feasibility (Cond. 1–3 decomposed, model sets, node selectors,
//!   affinity/anti-affinity, spread caps) with a PreFilter early-exit.
//! * [`bind`] — the `bind` extension point (five built-in binders).
//! * [`modulate`] — the `weightModulator` extension point (load-adaptive
//!   α, per-lattice α).
//! * [`fairness`] — the multi-tenant fairness subsystem: pending queue
//!   with starvation metrics, the `starve` dynamic modulator and the
//!   `preempt` postFail hook (`docs/fairness.md`).
//! * [`drs`] — the Dynamic Resource Scaling subsystem: the node
//!   sleep/wake lifecycle hook, the `drs` power-state filter and the
//!   `consolidate` score plugin (`docs/power.md`).
//! * [`policies`] — PWR (the contribution), FGD [19], BestFit [6],
//!   DotProd [4], GpuPacking [18], GpuClustering [21], FirstFit and
//!   Random sanity baselines, and the MIG family + repartitioner.
//!
//! Every pipeline stage is instrumented through the opt-in
//! observability layer ([`crate::obs`]): the [`Scheduler`] owns a
//! `MetricsRegistry` of counters and phase-latency histograms, and can
//! emit a per-decision JSONL trace (filter vetoes, per-plugin scores,
//! bind choice). Both are off by default and cost nothing when
//! disabled — see `docs/observability.md`.

pub mod bind;
pub mod drs;
pub mod fairness;
pub mod filter;
pub mod framework;
pub mod gang;
pub mod modulate;
pub mod policies;
pub mod profile;

pub use bind::{BindCtx, BindPlugin};
pub use drs::{ConsolidatePlugin, DrsConfig, DrsFilter, DrsHook};
pub use fairness::{
    FairnessConfig, FairnessCore, FairnessShared, FairnessState, PreemptHook, StarveModulator,
};
pub use filter::{FilterCtx, FilterPlugin};
pub use framework::{Decision, PostHook, SchedCtx, Scheduler, ScorePlugin};
pub use gang::{GangDecision, GangFilter, GangProgress, TopoPlugin, ZonespreadPlugin};
pub use modulate::{LatticeAlphaModulator, LoadAlphaModulator, WeightModulator};
pub use profile::SchedulerProfile;

/// Every scheduling policy evaluated in the paper (§V), plus two sanity
/// baselines. `PwrFgd { alpha }` is the paper's
/// `α·PWR + (1−α)·FGD` linear combination.
///
/// Since the [`SchedulerProfile`] redesign this enum is *sugar*: each
/// variant lowers to an equivalent profile ([`PolicyKind::profile`])
/// with a byte-identical label, so pre-profile CSV headers and pinned
/// outputs are unchanged. New combinations (≥ 3 objectives, custom
/// binders/modulators/hooks) are expressed directly in the profile DSL
/// instead of widening this enum.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PolicyKind {
    /// Fragmentation Gradient Descent (Weng et al. [19]).
    Fgd,
    /// The paper's power-aware policy (Algorithm 1).
    Pwr,
    /// k8s-normalized linear combination of PWR and FGD scores.
    PwrFgd { alpha: f64 },
    /// Extension (paper §VII future work): load-adaptive α, linearly
    /// interpolated from `alpha_empty` (idle cluster — maximize power
    /// savings) down to `alpha_full` (saturated — protect GRAR).
    PwrFgdDynamic { alpha_empty: f64, alpha_full: f64 },
    /// Best-fit bin packing (Protean [6]).
    BestFit,
    /// Dot-product alignment (Tetris [4]).
    DotProd,
    /// GPU packing tiers (MLaaS [18]).
    GpuPacking,
    /// Gandiva-style affinity packing (GPU clustering [21]).
    GpuClustering,
    /// Lowest-id feasible node.
    FirstFit,
    /// Uniformly random feasible node.
    Random,
    /// MIG-aware best-fit: node-level best-fit scoring over slice
    /// placements, slice best-fit binding (see
    /// [`crate::sched::policies::mig`]).
    MigBestFit,
    /// MIG-aware slice-fit: a genuinely slice-granular packing plugin
    /// (fullest-GPU-first with powered-GPU preference).
    MigSliceFit,
    /// FGD over the slice-level fragmentation metric.
    MigFgd,
    /// PWR over the per-slice power model (Eq. 2-MIG).
    MigPwr,
    /// The paper's combination on MIG clusters: `α·PWR + (1−α)·FGD`
    /// over (node, GPU, profile, start) placements.
    MigPwrFgd { alpha: f64 },
}

impl PolicyKind {
    /// Parse a CLI policy name: `fgd`, `pwr`, `pwrfgd:0.1`, `bestfit`,
    /// `dotprod`, `gpupacking`, `gpuclustering`, `firstfit`, `random`,
    /// plus the MIG family `mig-bestfit`, `mig-slicefit`, `mig-fgd`,
    /// `mig-pwr`, `mig-pwrfgd:0.1`.
    ///
    /// α parameters are validated at parse time: values outside [0, 1]
    /// (which would silently produce negative FGD weights) are rejected.
    pub fn parse(s: &str) -> Option<PolicyKind> {
        let lower = s.to_ascii_lowercase();
        // One α domain for legacy strings and the DSL alike.
        let alpha_in_range = |a: f64| profile::validate_alpha(a, "α").is_ok();
        if let Some(rest) = lower.strip_prefix("pwrfgddyn:") {
            let (hi, lo) = rest.split_once(':')?;
            let (alpha_empty, alpha_full) = (hi.parse().ok()?, lo.parse().ok()?);
            if !alpha_in_range(alpha_empty) || !alpha_in_range(alpha_full) {
                return None;
            }
            return Some(PolicyKind::PwrFgdDynamic { alpha_empty, alpha_full });
        }
        if let Some(alpha) = lower.strip_prefix("pwrfgd:") {
            let alpha: f64 = alpha.parse().ok()?;
            return alpha_in_range(alpha).then_some(PolicyKind::PwrFgd { alpha });
        }
        if let Some(alpha) = lower.strip_prefix("mig-pwrfgd:") {
            let alpha: f64 = alpha.parse().ok()?;
            return alpha_in_range(alpha).then_some(PolicyKind::MigPwrFgd { alpha });
        }
        match lower.as_str() {
            "fgd" => Some(PolicyKind::Fgd),
            "pwr" => Some(PolicyKind::Pwr),
            "bestfit" => Some(PolicyKind::BestFit),
            "dotprod" => Some(PolicyKind::DotProd),
            "gpupacking" => Some(PolicyKind::GpuPacking),
            "gpuclustering" => Some(PolicyKind::GpuClustering),
            "firstfit" => Some(PolicyKind::FirstFit),
            "random" => Some(PolicyKind::Random),
            "mig-bestfit" => Some(PolicyKind::MigBestFit),
            "mig-slicefit" => Some(PolicyKind::MigSliceFit),
            "mig-fgd" => Some(PolicyKind::MigFgd),
            "mig-pwr" => Some(PolicyKind::MigPwr),
            _ => None,
        }
    }

    /// Lower to the equivalent [`SchedulerProfile`] (same plugins,
    /// weights, binder and — byte-identical — label as the pre-profile
    /// hard-wired scheduler; pinned by `tests/profile_equivalence.rs`).
    pub fn profile(&self) -> SchedulerProfile {
        SchedulerProfile::from(*self)
    }

    /// Human-readable label used in CSV headers and reports.
    pub fn label(&self) -> String {
        match self {
            PolicyKind::Fgd => "FGD".into(),
            PolicyKind::Pwr => "PWR".into(),
            PolicyKind::PwrFgd { alpha } => format!("PWR{:.0}+FGD{:.0}", alpha * 1000.0, (1.0 - alpha) * 1000.0),
            PolicyKind::PwrFgdDynamic { alpha_empty, alpha_full } => {
                format!("PWRdyn{:.0}-{:.0}", alpha_empty * 1000.0, alpha_full * 1000.0)
            }
            PolicyKind::BestFit => "BestFit".into(),
            PolicyKind::DotProd => "DotProd".into(),
            PolicyKind::GpuPacking => "GpuPacking".into(),
            PolicyKind::GpuClustering => "GpuClustering".into(),
            PolicyKind::FirstFit => "FirstFit".into(),
            PolicyKind::Random => "Random".into(),
            PolicyKind::MigBestFit => "MIG-BestFit".into(),
            PolicyKind::MigSliceFit => "MIG-SliceFit".into(),
            PolicyKind::MigFgd => "MIG-FGD".into(),
            PolicyKind::MigPwr => "MIG-PWR".into(),
            PolicyKind::MigPwrFgd { alpha } => format!(
                "MIG-PWR{:.0}+FGD{:.0}",
                alpha * 1000.0,
                (1.0 - alpha) * 1000.0
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        assert_eq!(PolicyKind::parse("fgd"), Some(PolicyKind::Fgd));
        assert_eq!(PolicyKind::parse("PWR"), Some(PolicyKind::Pwr));
        assert_eq!(PolicyKind::parse("pwrfgd:0.2"), Some(PolicyKind::PwrFgd { alpha: 0.2 }));
        assert_eq!(
            PolicyKind::parse("pwrfgddyn:0.5:0.02"),
            Some(PolicyKind::PwrFgdDynamic { alpha_empty: 0.5, alpha_full: 0.02 })
        );
        assert_eq!(PolicyKind::parse("pwrfgddyn:0.5"), None);
        assert_eq!(PolicyKind::parse("nope"), None);
        assert_eq!(PolicyKind::parse("mig-fgd"), Some(PolicyKind::MigFgd));
        assert_eq!(
            PolicyKind::parse("MIG-PWRFGD:0.1"),
            Some(PolicyKind::MigPwrFgd { alpha: 0.1 })
        );
        assert_eq!(PolicyKind::parse("mig-bestfit"), Some(PolicyKind::MigBestFit));
        assert_eq!(PolicyKind::parse("mig-nope"), None);
    }

    #[test]
    fn parse_rejects_alpha_outside_unit_interval() {
        // α ∉ [0, 1] used to silently produce negative FGD weights.
        for bad in [
            "pwrfgd:1.7",
            "pwrfgd:-0.3",
            "pwrfgd:nan",
            "pwrfgd:inf",
            "mig-pwrfgd:1.001",
            "mig-pwrfgd:-0.0001",
            "pwrfgddyn:1.5:0.0",
            "pwrfgddyn:0.9:-0.1",
        ] {
            assert_eq!(PolicyKind::parse(bad), None, "accepted '{bad}'");
        }
        // The boundary values are legal.
        assert_eq!(PolicyKind::parse("pwrfgd:0"), Some(PolicyKind::PwrFgd { alpha: 0.0 }));
        assert_eq!(PolicyKind::parse("pwrfgd:1"), Some(PolicyKind::PwrFgd { alpha: 1.0 }));
        assert_eq!(
            PolicyKind::parse("pwrfgddyn:1:0"),
            Some(PolicyKind::PwrFgdDynamic { alpha_empty: 1.0, alpha_full: 0.0 })
        );
    }

    #[test]
    fn dynamic_alpha_schedules_and_adapts() {
        use crate::cluster::ClusterSpec;
        use crate::tasks::{GpuDemand, Task, Workload};
        let mut dc = ClusterSpec::tiny(4, 4, 0).build();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::PwrFgdDynamic {
            alpha_empty: 0.9,
            alpha_full: 0.0,
        });
        // Fill the cluster with whole-GPU tasks; dynamic α must keep
        // producing legal decisions all the way to saturation.
        let mut placed = 0;
        for i in 0..16 {
            let t = Task::new(i, 2.0, 512.0, GpuDemand::Whole(1));
            if let Some(d) = s.schedule(&dc, &w, &t) {
                assert!(dc.nodes[d.node].placement_fits(&t, &d.placement));
                dc.allocate(&t, d.node, &d.placement);
                s.notify_node_changed(d.node);
                placed += 1;
            }
        }
        assert_eq!(placed, 16);
    }

    #[test]
    fn labels_match_paper_notation() {
        // The paper shows α·1000 in plot legends.
        assert_eq!(PolicyKind::PwrFgd { alpha: 0.1 }.label(), "PWR100+FGD900");
        assert_eq!(PolicyKind::Fgd.label(), "FGD");
    }
}
