//! The Kubernetes-scheduling-framework analog (Algorithm 1).
//!
//! Pipeline per arriving task:
//! 1. **Filter** — drop nodes failing Cond. 1–3 or the model constraint
//!    (the k8s filter plugin of Algorithm 1, line 4).
//! 2. **Score** — every score plugin rates each feasible node (the
//!    hypothetical-assignment loop, lines 5–8). Plugins return raw
//!    "higher is better" scores.
//! 3. **NormalizeScore** — per-plugin min-max normalization to [0, 100],
//!    exactly how the k8s scheduling framework makes heterogeneous
//!    plugin scores combinable (§IV-A).
//! 4. **Combine** — weighted sum (`α·PWR + (1−α)·FGD` uses weights α and
//!    1−α).
//! 5. **Bind** — pick the arg-max node (ties → lowest id, deterministic)
//!    and choose the concrete GPU placement inside it.

use std::cell::RefCell;

use crate::cluster::node::{Node, Placement, ResourceView, EPS};
use crate::cluster::Datacenter;
use crate::frag;
use crate::power;
use crate::tasks::{GpuDemand, Task, Workload};
use crate::util::rng::Rng;

/// Context handed to score plugins.
pub struct SchedCtx<'a> {
    pub dc: &'a Datacenter,
    pub workload: &'a Workload,
    /// Hot-loop form of the workload (see [`frag::PreparedWorkload`]).
    pub prepared: &'a frag::PreparedWorkload,
    /// Monotonic per-node generation counters; bumped whenever a node's
    /// allocation changes. Plugins key internal caches on these.
    pub generations: &'a [u64],
    /// Cluster-wide normalization constants (largest node shapes).
    pub caps: ClusterCaps,
}

/// Largest node shapes in the cluster, for dimension normalization.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClusterCaps {
    pub max_vcpus: f64,
    pub max_mem: f64,
    pub max_gpus: f64,
}

impl ClusterCaps {
    pub fn of(dc: &Datacenter) -> ClusterCaps {
        ClusterCaps {
            max_vcpus: dc.nodes.iter().map(|n| n.vcpus).fold(1.0, f64::max),
            max_mem: dc.nodes.iter().map(|n| n.mem).fold(1.0, f64::max),
            max_gpus: dc.nodes.iter().map(|n| n.gpu_alloc.len() as f64).fold(1.0, f64::max),
        }
    }
}

/// A score plugin: rates how desirable `node` is for `task`, given the
/// deduplicated candidate `placements` (non-empty, all legal). Raw
/// scores are plugin-local scale, **higher is better**; the framework
/// normalizes before combining.
pub trait ScorePlugin: Send {
    fn name(&self) -> &'static str;
    fn score(&self, ctx: &SchedCtx, node: &Node, task: &Task, placements: &[Placement]) -> f64;
}

/// How the chosen node's concrete GPU placement is selected at bind
/// time.
pub enum Binder {
    /// Minimize `alpha·Δpower + (1−alpha)·Δfrag` over candidate
    /// placements (each term min-max normalized across the candidates).
    /// `alpha=1` ⇒ pure PWR, `alpha=0` ⇒ pure FGD.
    WeightedPwrFgd { alpha: f64 },
    /// Best-fit on the GPU residual: pick the feasible GPU with the
    /// least leftover fraction (the open-simulator default).
    GpuBestFit,
    /// Prefer already-occupied GPUs, then pack best-fit (MLaaS tiers).
    PackOccupied,
    /// First candidate (lowest GPU index).
    First,
    /// Uniformly random candidate.
    Random(RefCell<Rng>),
}

/// A scheduling decision: the node and the concrete placement.
#[derive(Clone, Debug, PartialEq)]
pub struct Decision {
    pub node: usize,
    pub placement: Placement,
}

/// The scheduler: filter + weighted score plugins + binder.
pub struct Scheduler {
    plugins: Vec<(Box<dyn ScorePlugin>, f64)>,
    binder: Binder,
    /// Per-node allocation generation (cache invalidation for plugins).
    generations: Vec<u64>,
    /// Scratch buffers, reused across decisions (hot path: zero alloc).
    feasible: Vec<usize>,
    placements: Vec<Vec<Placement>>,
    raw: Vec<f64>,
    combined: Vec<f64>,
    /// Cached hot-loop workload (rebuilt when the workload changes).
    prepared_cache: Option<(*const Workload, usize, frag::PreparedWorkload)>,
    /// Cached cluster caps (node shapes are static).
    caps_cache: Option<(usize, ClusterCaps)>,
    /// Seeded RNG for the k8s-style random tie-break (reproducible).
    tie_rng: Rng,
    /// Ablation switch: pick the lowest-id node among ties instead of
    /// k8s's random choice (`repro experiment ablation-tiebreak`).
    deterministic_ties: bool,
    /// Extension (paper §VII future work): dynamically adjust α with
    /// cluster load — `(alpha_empty, alpha_full)`, linearly
    /// interpolated on GPU utilization. Requires the plugin layout
    /// `[(PWR, ·), (FGD, ·)]`.
    dynamic_alpha: Option<(f64, f64)>,
    label: String,
}

// SAFETY: the cached raw pointer is only ever *compared*, never
// dereferenced; all other fields are Send.
unsafe impl Send for Scheduler {}

impl Scheduler {
    /// Build from explicit plugins (weight per plugin) and a binder.
    pub fn new(plugins: Vec<(Box<dyn ScorePlugin>, f64)>, binder: Binder, label: &str) -> Scheduler {
        Scheduler {
            plugins,
            binder,
            generations: Vec::new(),
            feasible: Vec::new(),
            placements: Vec::new(),
            raw: Vec::new(),
            combined: Vec::new(),
            prepared_cache: None,
            caps_cache: None,
            tie_rng: Rng::new(0xC0FFEE),
            deterministic_ties: false,
            dynamic_alpha: None,
            label: label.to_string(),
        }
    }

    /// Reseed the tie-break RNG (each simulation repetition uses its own
    /// stream so repetitions are independent).
    pub fn reseed_ties(&mut self, seed: u64) {
        self.tie_rng = Rng::new(seed ^ 0xC0FFEE);
    }

    /// Ablation: lowest-id instead of random tie-break.
    pub fn set_deterministic_ties(&mut self, on: bool) {
        self.deterministic_ties = on;
    }

    /// Enable load-adaptive α (see [`crate::sched::PolicyKind::PwrFgdDynamic`]).
    pub fn set_dynamic_alpha(&mut self, alpha_empty: f64, alpha_full: f64) {
        self.dynamic_alpha = Some((alpha_empty, alpha_full));
    }

    /// Build the scheduler for a named policy (see [`crate::sched::PolicyKind`]).
    pub fn from_policy(kind: crate::sched::PolicyKind) -> Scheduler {
        crate::sched::policies::build(kind)
    }

    /// Policy label for reports.
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Notify the scheduler that `node_id`'s allocation changed (commit
    /// or departure). Invalidate plugin caches via the generation bump.
    pub fn notify_node_changed(&mut self, node_id: usize) {
        if node_id < self.generations.len() {
            self.generations[node_id] += 1;
        }
    }

    /// Schedule one task (Algorithm 1). Returns `None` when no node can
    /// host it (a scheduling failure — GRAR's denominator still counts
    /// the arrival). Does **not** mutate the datacenter; the caller
    /// commits via [`Datacenter::allocate`] and then calls
    /// [`Self::notify_node_changed`].
    pub fn schedule(&mut self, dc: &Datacenter, workload: &Workload, task: &Task) -> Option<Decision> {
        let n = dc.nodes.len();
        if self.generations.len() != n {
            self.generations = vec![0; n];
        }
        // --- 1. Filter + candidate placements (deduped). ---
        self.feasible.clear();
        self.placements.clear();
        for node in &dc.nodes {
            if !node.can_fit(task) {
                continue;
            }
            let ps = dedup_placements(node, task);
            if ps.is_empty() {
                continue;
            }
            self.feasible.push(node.id);
            self.placements.push(ps);
        }
        if self.feasible.is_empty() {
            return None;
        }
        // Refresh the per-workload / per-cluster caches when needed
        // (identity-keyed; the simulator keeps both alive and stable).
        let wl_key = (workload as *const Workload, workload.classes.len());
        if self
            .prepared_cache
            .as_ref()
            .map(|(p, l, _)| (*p, *l) != wl_key)
            .unwrap_or(true)
        {
            self.prepared_cache =
                Some((wl_key.0, wl_key.1, frag::PreparedWorkload::new(workload)));
        }
        if self.caps_cache.map(|(l, _)| l != n).unwrap_or(true) {
            self.caps_cache = Some((n, ClusterCaps::of(dc)));
        }
        let ctx = SchedCtx {
            dc,
            workload,
            prepared: &self.prepared_cache.as_ref().unwrap().2,
            generations: &self.generations,
            caps: self.caps_cache.unwrap().1,
        };
        // --- 2–4. Score, normalize, combine. ---
        // Load-adaptive α (extension): interpolate between alpha_empty
        // and alpha_full on GPU utilization, retargeting the plugin
        // weights [(PWR, α), (FGD, 1−α)] and the binder.
        let mut bind_alpha_override = None;
        if let Some((hi, lo)) = self.dynamic_alpha {
            let u = dc.gpu_utilization().clamp(0.0, 1.0);
            let alpha = hi + (lo - hi) * u;
            debug_assert_eq!(self.plugins.len(), 2, "dynamic α needs [PWR, FGD]");
            self.plugins[0].1 = alpha;
            self.plugins[1].1 = 1.0 - alpha;
            bind_alpha_override = Some(alpha);
        }
        let k = self.feasible.len();
        self.combined.clear();
        self.combined.resize(k, 0.0);
        for (plugin, weight) in &self.plugins {
            self.raw.clear();
            for (idx, &node_id) in self.feasible.iter().enumerate() {
                let s = plugin.score(&ctx, &dc.nodes[node_id], task, &self.placements[idx]);
                debug_assert!(s.is_finite(), "{} returned {s}", plugin.name());
                self.raw.push(s);
            }
            normalize_scores(&mut self.raw);
            for (c, r) in self.combined.iter_mut().zip(&self.raw) {
                *c += weight * r;
            }
        }
        // --- 5. Arg-max + bind. Kubernetes semantics: plugin scores are
        // int64 in [0,100] after NormalizeScore (normalize_scores already
        // rounds), and `selectHost` picks *uniformly at random* among the
        // max-scoring nodes. The random tie-break matters: for e.g. a
        // whole-GPU task on a large pool of identical idle nodes FGD is
        // indifferent, and k8s spreads the load — which is precisely the
        // power-wasting behaviour PWR corrects (paper §VI-B).
        let mut best = 0;
        let mut n_ties = 1u32;
        for i in 1..k {
            if self.combined[i] > self.combined[best] + 1e-9 {
                best = i;
                n_ties = 1;
            } else if !self.deterministic_ties
                && (self.combined[i] - self.combined[best]).abs() <= 1e-9
            {
                // Reservoir-sample uniformly among ties.
                n_ties += 1;
                if self.tie_rng.below(n_ties as usize) == 0 {
                    best = i;
                }
            }
        }
        let node_id = self.feasible[best];
        let binder_alpha;
        let binder = match (&self.binder, bind_alpha_override) {
            (Binder::WeightedPwrFgd { .. }, Some(alpha)) => {
                binder_alpha = Binder::WeightedPwrFgd { alpha };
                &binder_alpha
            }
            (b, _) => b,
        };
        let placement = bind_placement(
            binder,
            &dc.nodes[node_id],
            task,
            &self.placements[best],
            &self.prepared_cache.as_ref().unwrap().2,
        );
        Some(Decision { node: node_id, placement })
    }
}

/// k8s NormalizeScore: min-max map to [0, 100], **rounded to integers**
/// (framework scores are int64); all-equal maps to 100.
pub fn normalize_scores(scores: &mut [f64]) {
    let lo = scores.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = scores.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    if !(hi - lo).is_finite() || hi - lo < 1e-12 {
        scores.iter_mut().for_each(|s| *s = 100.0);
        return;
    }
    for s in scores {
        *s = (100.0 * (*s - lo) / (hi - lo)).round();
    }
}

/// Candidate placements with equivalence dedup: for fractional tasks,
/// GPUs with the same free fraction are interchangeable for every
/// plugin metric (power, fragmentation, packing) — keep the lowest
/// index per distinct residual. For MIG tasks, GPUs in the identical
/// partition state (same occupancy mask) are likewise interchangeable —
/// keep the lowest-index GPU per distinct mask, with all of its legal
/// starts (starts on one GPU are *not* equivalent: each blocks
/// different future windows). Whole-GPU placements are already
/// canonical.
pub fn dedup_placements(node: &Node, task: &Task) -> Vec<Placement> {
    match task.gpu {
        GpuDemand::Frac(d) => {
            let mut seen: Vec<u64> = Vec::with_capacity(4);
            let mut out = Vec::with_capacity(4);
            for g in 0..node.gpu_alloc.len() {
                let free = node.gpu_free_of(g);
                if free < d - EPS {
                    continue;
                }
                let key = (free * (1u64 << 32) as f64) as u64;
                if !seen.contains(&key) {
                    seen.push(key);
                    out.push(Placement::Shared { gpu: g });
                }
            }
            out
        }
        GpuDemand::Mig(p) => {
            let Some(migs) = &node.mig else { return Vec::new() };
            let mut seen: Vec<u8> = Vec::with_capacity(4);
            let mut out = Vec::new();
            for (g, mg) in migs.iter().enumerate() {
                if seen.contains(&mg.mask) {
                    continue;
                }
                seen.push(mg.mask);
                for s in mg.free_starts(p) {
                    out.push(Placement::MigSlice { gpu: g, start: s });
                }
            }
            out
        }
        _ => node.candidate_placements(task),
    }
}

/// Δ estimated node power of a hypothetical assignment (PWR's metric).
pub fn power_delta(node: &Node, task: &Task, placement: &Placement) -> f64 {
    let before = power::p_node(node);
    let h = node.hypothetical(task, placement);
    power::p_node(&h) - before
}

/// Δ expected node fragmentation of a hypothetical assignment (FGD's
/// metric).
pub fn frag_delta(node: &Node, task: &Task, placement: &Placement, workload: &Workload) -> f64 {
    let before = frag::f_node(node, workload);
    frag_delta_with_before(node, task, placement, workload, before)
}

/// Like [`frag_delta`] with `F_n(M)` of the current state precomputed
/// (plugins cache it per node generation).
pub fn frag_delta_with_before(
    node: &Node,
    task: &Task,
    placement: &Placement,
    workload: &Workload,
    before: f64,
) -> f64 {
    let h = node.hypothetical(task, placement);
    frag::f_node(&h, workload) - before
}

fn bind_placement(
    binder: &Binder,
    node: &Node,
    task: &Task,
    placements: &[Placement],
    prepared: &frag::PreparedWorkload,
) -> Placement {
    assert!(!placements.is_empty());
    if placements.len() == 1 {
        return placements[0].clone();
    }
    match binder {
        Binder::First => placements[0].clone(),
        Binder::Random(rng) => {
            let i = rng.borrow_mut().below(placements.len());
            placements[i].clone()
        }
        Binder::GpuBestFit => best_fit_gpu(node, placements),
        Binder::PackOccupied => {
            // Tier 1: occupied GPUs, best-fit among them.
            let occupied: Vec<Placement> = placements
                .iter()
                .filter(|p| matches!(p, Placement::Shared { gpu } if node.gpu_alloc[*gpu] > 0.0))
                .cloned()
                .collect();
            if !occupied.is_empty() {
                best_fit_gpu(node, &occupied)
            } else {
                best_fit_gpu(node, placements)
            }
        }
        Binder::WeightedPwrFgd { alpha } => {
            let before = frag::f_node_fast(node, prepared);
            let dp: Vec<f64> =
                placements.iter().map(|p| power_delta(node, task, p)).collect();
            let df: Vec<f64> = placements
                .iter()
                .map(|p| frag::frag_delta_fast(node, task, p, prepared, before))
                .collect();
            // Min-max normalize each criterion across the candidates,
            // then minimize the weighted blend (mirrors the node-level
            // k8s combination at placement granularity).
            let norm = |v: &[f64]| -> Vec<f64> {
                let lo = v.iter().copied().fold(f64::INFINITY, f64::min);
                let hi = v.iter().copied().fold(f64::NEG_INFINITY, f64::max);
                if hi - lo < 1e-12 {
                    vec![0.0; v.len()]
                } else {
                    v.iter().map(|x| (x - lo) / (hi - lo)).collect()
                }
            };
            let (dpn, dfn) = (norm(&dp), norm(&df));
            let mut best = 0;
            let mut best_cost = f64::INFINITY;
            for i in 0..placements.len() {
                let cost = alpha * dpn[i] + (1.0 - alpha) * dfn[i];
                if cost < best_cost - 1e-12 {
                    best_cost = cost;
                    best = i;
                }
            }
            placements[best].clone()
        }
    }
}

/// Best-fit on GPU residual: least leftover after placing. For MIG
/// placements the residual is the target GPU's free-slice fraction, so
/// instances pack onto the fullest GPU that still has a legal start
/// (ties → the profile's preferred start order).
fn best_fit_gpu(node: &Node, placements: &[Placement]) -> Placement {
    let mut best = 0;
    let mut best_free = f64::INFINITY;
    for (i, p) in placements.iter().enumerate() {
        let free = match p {
            Placement::Shared { gpu } | Placement::MigSlice { gpu, .. } => {
                node.gpu_free_of(*gpu)
            }
            _ => return p.clone(), // whole/CPU placements are canonical
        };
        if free < best_free - EPS {
            best_free = free;
            best = i;
        }
    }
    placements[best].clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::types::{CpuModel, GpuModel};
    use crate::cluster::ClusterSpec;

    fn dc2() -> Datacenter {
        ClusterSpec::tiny(2, 4, 0).build()
    }

    #[test]
    fn normalize_maps_to_0_100() {
        let mut s = vec![-5.0, 0.0, 5.0];
        normalize_scores(&mut s);
        assert_eq!(s, vec![0.0, 50.0, 100.0]);
        let mut eq = vec![3.0, 3.0];
        normalize_scores(&mut eq);
        assert_eq!(eq, vec![100.0, 100.0]);
    }

    #[test]
    fn dedup_groups_equal_residuals() {
        let mut node =
            Node::new(0, CpuModel::XeonE5_2682V4, Some(GpuModel::G2), 96.0, 393_216.0, 4);
        // Make GPU1 and GPU2 identical (0.5 free), GPU0 and GPU3 free.
        node.allocate(&Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.5)), &Placement::Shared { gpu: 1 });
        node.allocate(&Task::new(2, 1.0, 0.0, GpuDemand::Frac(0.5)), &Placement::Shared { gpu: 2 });
        let ps = dedup_placements(&node, &Task::new(3, 1.0, 0.0, GpuDemand::Frac(0.25)));
        // distinct residuals: 1.0 (gpu0) and 0.5 (gpu1) -> 2 candidates
        assert_eq!(ps.len(), 2);
    }

    #[test]
    fn dedup_groups_identical_mig_masks() {
        use crate::cluster::mig::MigProfile;
        let mut node =
            Node::new(0, CpuModel::XeonE5_2682V4, Some(GpuModel::G3), 128.0, 786_432.0, 4);
        node.enable_mig();
        // All four GPUs empty -> one representative GPU, 7 starts for 1g.
        let t1g = Task::new(0, 1.0, 0.0, GpuDemand::Mig(MigProfile::P1g));
        assert_eq!(dedup_placements(&node, &t1g).len(), 7);
        // Partition GPU 2 -> two distinct masks -> starts from two GPUs.
        node.allocate(&t1g, &Placement::MigSlice { gpu: 2, start: 0 });
        let ps = dedup_placements(&node, &t1g);
        assert_eq!(ps.len(), 7 + 6);
        assert!(ps.iter().all(|p| matches!(p,
            Placement::MigSlice { gpu, .. } if *gpu == 0 || *gpu == 2)));
    }

    #[test]
    fn power_delta_fractional_prefers_occupied_gpu() {
        let mut node =
            Node::new(0, CpuModel::XeonE5_2682V4, Some(GpuModel::G2), 96.0, 393_216.0, 4);
        node.allocate(&Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.5)), &Placement::Shared { gpu: 0 });
        let t = Task::new(2, 1.0, 0.0, GpuDemand::Frac(0.25));
        let on_occupied = power_delta(&node, &t, &Placement::Shared { gpu: 0 });
        let on_idle = power_delta(&node, &t, &Placement::Shared { gpu: 1 });
        assert_eq!(on_occupied, 0.0);
        assert_eq!(on_idle, 120.0); // G2: 150 max − 30 idle
    }

    #[test]
    fn scheduler_schedules_on_tiny_cluster() {
        let dc = dc2();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::FirstFit);
        let t = Task::new(0, 4.0, 1024.0, GpuDemand::Whole(1));
        let d = s.schedule(&dc, &w, &t).unwrap();
        assert_eq!(d.node, 0);
        assert_eq!(d.placement, Placement::Whole { gpus: vec![0] });
    }

    #[test]
    fn scheduler_returns_none_when_infeasible() {
        let dc = dc2();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::FirstFit);
        let t = Task::new(0, 4.0, 0.0, GpuDemand::Whole(64));
        assert!(s.schedule(&dc, &w, &t).is_none());
    }

    #[test]
    fn commit_then_notify_flow() {
        let mut dc = dc2();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::Fgd);
        for i in 0..8 {
            let t = Task::new(i, 2.0, 512.0, GpuDemand::Whole(1));
            let d = s.schedule(&dc, &w, &t).expect("fits");
            dc.allocate(&t, d.node, &d.placement);
            s.notify_node_changed(d.node);
        }
        assert_eq!(dc.gpu_allocated_units(), 8.0);
        // Cluster full for whole-GPU tasks now.
        let t = Task::new(99, 2.0, 512.0, GpuDemand::Whole(1));
        assert!(s.schedule(&dc, &w, &t).is_none());
    }
}
