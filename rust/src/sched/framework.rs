//! The Kubernetes-scheduling-framework analog (Algorithm 1), organized
//! around named extension points like real k8s scheduler profiles (see
//! [`crate::sched::profile`] for the profile/DSL layer):
//!
//! Pipeline per arriving task:
//! 1. **PreFilter + Filter** (extension point) — every
//!    [`FilterPlugin`](crate::sched::filter::FilterPlugin) in the
//!    profile's chain first gets a cheap cluster-wide PreFilter veto
//!    (hopeless tasks skip the node loop entirely), then drops nodes
//!    failing Cond. 1–3 or any declarative constraint (the k8s
//!    filter plugin of Algorithm 1, line 4; the legacy `can_fit` is the
//!    built-in `resources` ∧ `gpumodel` ∧ `miglattice` chain).
//! 2. **WeightModulator** (extension point) — an optional
//!    [`WeightModulator`] retargets the plugin weights from live
//!    cluster state (load-adaptive α is the first implementation;
//!    per-lattice α modulators refine weights per node).
//! 3. **Score** (extension point) — every [`ScorePlugin`] rates each
//!    feasible node (the hypothetical-assignment loop, lines 5–8).
//!    Plugins return raw "higher is better" scores.
//! 4. **NormalizeScore** — per-plugin min-max normalization to [0, 100],
//!    exactly how the k8s scheduling framework makes heterogeneous
//!    plugin scores combinable (§IV-A).
//! 5. **Combine** — weighted sum (`α·PWR + (1−α)·FGD` uses weights α and
//!    1−α).
//! 6. **Bind** (extension point) — pick the arg-max node (ties →
//!    uniform random, k8s `selectHost` semantics) and let the
//!    [`BindPlugin`](crate::sched::bind::BindPlugin) choose the
//!    concrete GPU placement inside it.
//! 7. **PostFail / PostPlace / OnTick** (extension points) —
//!    [`PostHook`]s run after a failed decision (e.g. repack a MIG GPU
//!    and retry — the k8s-preemption analog), after every allocation
//!    change (e.g. proactive defragmentation), and at the start of
//!    every `place`/`release` protocol entry (the scheduler-event
//!    clock: DRS wake completions and sleep deadlines — see
//!    [`crate::sched::drs`]). The [`Scheduler::place`] /
//!    [`Scheduler::release`] protocol drives them, so simulation loops
//!    can never silently skip a hook.
//!
//! **Scale-out fast path** (`docs/scheduler.md`): raw scores from
//! cacheable plugins are cached per (plugin, demand signature, node
//! generation) and reused bit-for-bit across decisions; profiles can
//! cap the feasibility sweep with a k8s
//! `percentageOfNodesToScore`-style `sample(<pct>)` knob backed by the
//! [`Datacenter`] static candidate indexes; and `shards(<n>)` scores
//! cache misses on scoped threads. At `sample(100)` (the default)
//! every fast-path combination is bit-identical to the naive loop
//! (`rust/tests/scale_equivalence.rs`).

use std::collections::HashMap;

use crate::cluster::node::{Node, Placement, ResourceView, EPS};
use crate::cluster::Datacenter;
use crate::frag;
use crate::obs::{self, DecisionTracer, MetricsRegistry, ObsState, ScoreRow, TraceCapture};
use crate::power;
use crate::sched::bind::{BindCtx, BindPlugin};
use crate::sched::filter::{default_filter_chain, FilterCtx, FilterPlugin};
use crate::sched::modulate::WeightModulator;
use crate::tasks::{GpuDemand, Task, Workload};
use crate::util::benchkit::PhaseTimer;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Context handed to score plugins.
pub struct SchedCtx<'a> {
    pub dc: &'a Datacenter,
    pub workload: &'a Workload,
    /// Hot-loop form of the workload (see [`frag::PreparedWorkload`]).
    pub prepared: &'a frag::PreparedWorkload,
    /// Monotonic per-node generation counters; bumped whenever a node's
    /// allocation changes. Plugins key internal caches on these.
    pub generations: &'a [u64],
    /// Cluster-wide normalization constants (largest node shapes).
    pub caps: ClusterCaps,
    /// In-flight gang placement progress (`None` for ordinary
    /// decisions): which member is being placed and where the committed
    /// members sit. Read by topology-aware plugins
    /// ([`crate::sched::gang::TopoPlugin`]).
    pub gang: Option<&'a crate::sched::gang::GangProgress>,
}

/// Largest node shapes in the cluster, for dimension normalization.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClusterCaps {
    pub max_vcpus: f64,
    pub max_mem: f64,
    pub max_gpus: f64,
}

impl ClusterCaps {
    pub fn of(dc: &Datacenter) -> ClusterCaps {
        ClusterCaps {
            max_vcpus: dc.nodes.iter().map(|n| n.vcpus).fold(1.0, f64::max),
            max_mem: dc.nodes.iter().map(|n| n.mem).fold(1.0, f64::max),
            max_gpus: dc.nodes.iter().map(|n| n.gpu_alloc.len() as f64).fold(1.0, f64::max),
        }
    }
}

/// A score plugin: rates how desirable `node` is for `task`, given the
/// deduplicated candidate `placements` (non-empty, all legal). Raw
/// scores are plugin-local scale, **higher is better**; the framework
/// normalizes before combining.
///
/// `Sync` because the sharded scoring path calls `score` from scoped
/// threads; plugins with internal caches guard them with a `Mutex`
/// (see [`crate::sched::policies::FgdPlugin`]).
pub trait ScorePlugin: Send + Sync {
    fn name(&self) -> &'static str;

    /// Whether `score` is a pure function of (node state as stamped by
    /// the per-node generation counter, the task's demand signature,
    /// the revision-keyed context). True for every built-in except
    /// `random`, whose score is a fresh RNG draw. Cacheable plugins
    /// participate in the framework's raw-score cache and may be
    /// scored on shard threads; a non-cacheable plugin is always
    /// scored sequentially in feasible order, so its internal state
    /// (e.g. an RNG stream) advances exactly as in the naive loop.
    fn cacheable(&self) -> bool {
        true
    }

    fn score(&self, ctx: &SchedCtx, node: &Node, task: &Task, placements: &[Placement]) -> f64;
}

/// Bit-exact demand signature: everything a cacheable plugin's raw
/// score can depend on besides node state and the revision-keyed
/// context. Two tasks with equal signatures are interchangeable to
/// every cacheable score plugin (trace tasks repeat a small set of
/// class shapes, so signatures recur heavily).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
struct TaskSig {
    cpu: u64,
    mem: u64,
    gpu_kind: u8,
    gpu_val: u64,
}

impl TaskSig {
    fn of(task: &Task) -> TaskSig {
        let (gpu_kind, gpu_val) = match task.gpu {
            GpuDemand::Zero => (0u8, 0u64),
            GpuDemand::Frac(f) => (1, f.to_bits()),
            GpuDemand::Whole(k) => (2, k as u64),
            GpuDemand::Mig(p) => (3, p.index() as u64),
        };
        TaskSig { cpu: task.cpu.to_bits(), mem: task.mem.to_bits(), gpu_kind, gpu_val }
    }
}

/// Per-scheduler raw-score cache: for each cacheable plugin, demand
/// signature → per-node `(generation, raw score)` entries. A hit
/// (entry generation == current node generation) skips the plugin
/// call; misses are recomputed and written back. The whole cache is
/// epoch-scoped on `(workload revision, fleet revision)`, so a
/// workload swap or structural fleet change can never serve a stale
/// score. Since raw scores are *reused bit-for-bit* (never
/// recombined differently), cache on ≡ cache off exactly
/// (`tests/scale_equivalence.rs`).
#[derive(Default)]
struct ScoreCache {
    /// `(workload revision, fleet revision)`; `(0, 0)` = never primed
    /// (revision stamps start at 1).
    epoch: (u64, u64),
    /// One map per score plugin, in plugin order.
    plugins: Vec<HashMap<TaskSig, Vec<(u64, f64)>>>,
}

impl ScoreCache {
    /// Clear everything when the epoch (or plugin layout) moved.
    fn ensure_epoch(&mut self, epoch: (u64, u64), n_plugins: usize) {
        if self.epoch != epoch || self.plugins.len() != n_plugins {
            self.epoch = epoch;
            self.plugins.clear();
            self.plugins.resize_with(n_plugins, HashMap::new);
        }
    }
}

/// Per-decision scoring-phase tallies, flushed to the metrics registry
/// once per decision (`MetricsRegistry::inc` is not free — never call
/// it per node).
#[derive(Default)]
struct ScoreStats {
    hits: u64,
    misses: u64,
    shard_batches: u64,
}

/// A post-decision extension point (the k8s-preemption analog): hooks
/// may *mutate the datacenter* after a failed decision or after an
/// allocation change. The MIG repartitioner
/// ([`crate::sched::policies::MigRepartitioner`]) is the first
/// implementation.
///
/// Hooks MUST report **every** node they mutate through the
/// `invalidate` callback (it bumps the framework's per-node plugin-cache
/// generation); a cross-node hook that skips one leaves stale cached
/// scores for that node.
pub trait PostHook: Send {
    fn name(&self) -> &'static str;

    /// Receive the shared fairness core ([`Scheduler::bind_fairness`]).
    /// Hooks that participate in the fairness subsystem (e.g.
    /// [`crate::sched::fairness::PreemptHook`]) override this; all
    /// others ignore it and stay fairness-agnostic.
    fn bind_fairness(&mut self, _shared: &crate::sched::fairness::FairnessShared) {}

    /// Advance the hook's clock to `now` — the scheduler-event clock,
    /// bumped once per [`Scheduler::place`] / [`Scheduler::release`]
    /// protocol entry and delivered *before* the decision, so
    /// time-driven state (DRS sleep deadlines, wake completions) is
    /// settled by the time the filter chain reads it. Report each
    /// mutated node via `invalidate`.
    fn on_tick(
        &mut self,
        _dc: &mut Datacenter,
        _now: u64,
        _invalidate: &mut dyn FnMut(usize),
    ) {
    }

    /// After a scheduling failure: try to make room for `task` (e.g.
    /// repack a MIG GPU), reporting each mutated node via `invalidate`.
    /// Return `true` when the framework should retry the decision once.
    fn post_fail(
        &mut self,
        _dc: &mut Datacenter,
        _task: &Task,
        _invalidate: &mut dyn FnMut(usize),
    ) -> bool {
        false
    }

    /// [`PostHook::post_fail`] with the scheduler's filter chain in
    /// hand, so a hook can judge *hypothetical* feasibility before
    /// spending real resources — the DRS manager evaluates whether a
    /// candidate wake target would pass the full chain once `Active`
    /// instead of burning `wake_j` on a node some filter then vetoes
    /// (see [`crate::sched::drs`]). The framework always calls this
    /// variant; the default forwards to `post_fail`, so hooks override
    /// exactly one of the two.
    fn post_fail_chained(
        &mut self,
        dc: &mut Datacenter,
        task: &Task,
        _filters: &[Box<dyn FilterPlugin>],
        invalidate: &mut dyn FnMut(usize),
    ) -> bool {
        self.post_fail(dc, task, invalidate)
    }

    /// A gang *member* failed with `remaining` members (this one
    /// included) still to place. Unlike the single-task post-fail, a
    /// useful remedy may need to free capacity for *several* members at
    /// once (the DRS hook wakes a whole set of sleepers sized to the
    /// residual gang — see [`crate::sched::drs::DrsHook`]). The default
    /// forwards to [`PostHook::post_fail_chained`], so hooks unaware of
    /// gangs keep their single-task behavior.
    fn post_fail_gang(
        &mut self,
        dc: &mut Datacenter,
        member: &Task,
        _remaining: u32,
        filters: &[Box<dyn FilterPlugin>],
        invalidate: &mut dyn FnMut(usize),
    ) -> bool {
        self.post_fail_chained(dc, member, filters, invalidate)
    }

    /// After `node_id`'s allocation changed (commit or release): e.g.
    /// proactive defragmentation. Report each mutated node via
    /// `invalidate` (a hook may touch nodes other than `node_id`).
    fn post_place(
        &mut self,
        _dc: &mut Datacenter,
        _node_id: usize,
        _invalidate: &mut dyn FnMut(usize),
    ) {
    }

    /// Named activity counters for reporting (e.g. repartition counts);
    /// surfaced through [`Scheduler::hook_counter`].
    fn counters(&self) -> Vec<(&'static str, u64)> {
        Vec::new()
    }
}

/// A scheduling decision: the node and the concrete placement.
#[derive(Clone, Debug, PartialEq)]
pub struct Decision {
    pub node: usize,
    pub placement: Placement,
}

/// The one invalidation callback handed to every hook phase: bump a
/// node's plugin-cache generation, ignoring ids beyond the sized fleet
/// (before the first `schedule` the generation vector is empty and no
/// caches exist to invalidate). Taking the generations slice keeps the
/// borrow split from `self.hooks` at every call site.
fn bump_generation(generations: &mut [u64]) -> impl FnMut(usize) + '_ {
    move |n: usize| {
        if n < generations.len() {
            generations[n] += 1;
        }
    }
}

/// The scheduler: filter + weighted score plugins + binder, with
/// optional weight modulator and post-decision hooks. Assembled from a
/// [`crate::sched::profile::SchedulerProfile`] (or directly via
/// [`Scheduler::new`] for custom plugin stacks).
pub struct Scheduler {
    plugins: Vec<Box<dyn ScorePlugin>>,
    /// Static per-plugin weights (the profile's `score(...)` weights).
    weights: Vec<f64>,
    /// Per-decision effective weights (scratch; modulator output).
    eff_weights: Vec<f64>,
    binder: Box<dyn BindPlugin>,
    modulator: Option<Box<dyn WeightModulator>>,
    hooks: Vec<Box<dyn PostHook>>,
    /// The `filter` extension-point chain (conjunction). Defaults to
    /// [`default_filter_chain`]; profiles override via `filter(...)`.
    filters: Vec<Box<dyn FilterPlugin>>,
    /// Whether the most recent `schedule()` rejection involved a
    /// constraint filter (consumed by [`Scheduler::place`]).
    last_reject_constrained: bool,
    /// Observability: the metrics registry plus the opt-in tracing /
    /// profiling switches (all off by default — see [`crate::obs`]).
    obs: ObsState,
    /// Per-node allocation generation (cache invalidation for plugins).
    generations: Vec<u64>,
    /// Scratch buffers, reused across decisions (hot path: zero alloc).
    feasible: Vec<usize>,
    placements: Vec<Vec<Placement>>,
    raw: Vec<f64>,
    combined: Vec<f64>,
    /// Scratch for per-node weight modulation (normalized score rows ×
    /// per-node weight vector; only used when the modulator is
    /// per-node, e.g. `latticealpha`).
    norm_rows: Vec<f64>,
    node_weights: Vec<f64>,
    /// Cached hot-loop workload, keyed on [`Workload::revision`]
    /// (identity stamps are immune to allocator address reuse, unlike
    /// the raw-pointer key this replaces).
    prepared_cache: Option<(u64, frag::PreparedWorkload)>,
    /// Cached cluster caps, keyed on [`Datacenter::revision`] (a
    /// node-count key served stale caps to every plugin whenever a
    /// fleet change preserved the count).
    caps_cache: Option<(u64, ClusterCaps)>,
    /// Raw-score cache (epoch- and generation-keyed; on by default —
    /// cache on ≡ cache off bit-for-bit, see [`ScoreCache`]).
    score_cache: Option<ScoreCache>,
    /// k8s `percentageOfNodesToScore` analog, clamped to 1..=100;
    /// 100 (the default) runs the exact full-sweep loop.
    sample_pct: u32,
    /// Rotating start offset of the sampled sweep (k8s
    /// `nextStartNodeIndex`), advanced by nodes scanned per decision
    /// so successive decisions sample different fleet slices.
    sample_offset: usize,
    /// Scoring shards for cacheable plugins (scoped threads); 1 =
    /// sequential. Pure plugins score identically on any thread, so
    /// any shard count is bit-identical to sequential.
    score_shards: usize,
    /// Scratch: per-decision memo of `FilterPlugin::constrains(task)`
    /// (the attribution rescan otherwise re-evaluates it node × filter
    /// times).
    filter_constrains: Vec<bool>,
    /// Scratch: cache-miss indices (into `feasible`) during scoring.
    miss_scratch: Vec<usize>,
    /// The scheduler-event clock: one tick per `place`/`release`
    /// protocol entry. The DRS subsystem's time unit (`docs/power.md`);
    /// identical semantics in both simulation loops.
    events: u64,
    /// In-flight gang placement progress ([`Scheduler::place_gang`]);
    /// exposed to plugins through [`SchedCtx::gang`]. Always `None`
    /// outside the gang member loop.
    gang_progress: Option<crate::sched::gang::GangProgress>,
    /// Seeded RNG for the k8s-style random tie-break (reproducible).
    tie_rng: Rng,
    /// Ablation switch: pick the lowest-id node among ties instead of
    /// k8s's random choice (`repro experiment ablation-tiebreak`).
    deterministic_ties: bool,
    label: String,
}

impl Scheduler {
    /// Build from explicit plugins (weight per plugin) and a binder.
    pub fn new(
        plugins: Vec<(Box<dyn ScorePlugin>, f64)>,
        binder: Box<dyn BindPlugin>,
        label: &str,
    ) -> Scheduler {
        let (plugins, weights): (Vec<_>, Vec<_>) = plugins.into_iter().unzip();
        Scheduler {
            plugins,
            weights,
            eff_weights: Vec::new(),
            binder,
            modulator: None,
            hooks: Vec::new(),
            filters: default_filter_chain(),
            last_reject_constrained: false,
            obs: ObsState::default(),
            generations: Vec::new(),
            feasible: Vec::new(),
            placements: Vec::new(),
            raw: Vec::new(),
            combined: Vec::new(),
            norm_rows: Vec::new(),
            node_weights: Vec::new(),
            prepared_cache: None,
            caps_cache: None,
            score_cache: Some(ScoreCache::default()),
            sample_pct: 100,
            sample_offset: 0,
            score_shards: 1,
            filter_constrains: Vec::new(),
            miss_scratch: Vec::new(),
            events: 0,
            gang_progress: None,
            tie_rng: Rng::new(0xC0FFEE),
            deterministic_ties: false,
            label: label.to_string(),
        }
    }

    /// Replace the `filter` extension-point chain (the profile builder
    /// resolves `filter(...)` keys through the registry and calls
    /// this). The chain is a conjunction and must be non-empty.
    ///
    /// # Panics
    /// On an empty chain — a scheduler without feasibility checks would
    /// bind illegal placements.
    pub fn set_filters(&mut self, filters: Vec<Box<dyn FilterPlugin>>) {
        assert!(!filters.is_empty(), "filter chain must be non-empty");
        self.filters = filters;
    }

    /// Toggle the raw-score cache (on by default). The cached and
    /// uncached paths are bit-identical ([`ScoreCache`]); off exists
    /// for ablation and as the bench-scale baseline.
    pub fn set_score_cache(&mut self, on: bool) {
        self.score_cache = on.then(ScoreCache::default);
    }

    /// Set the candidate-sampling percentage (the k8s
    /// `percentageOfNodesToScore` analog; profile DSL `sample(<pct>)`).
    /// Clamped to 1..=100; at 100 the scheduler runs the exact naive
    /// full sweep. Below 100 the feasibility sweep walks the smallest
    /// applicable static candidate index (nodes per model / lattice /
    /// label) from a rotating offset and stops early once
    /// `max(100, ⌈pct·|universe|/100⌉)` feasible nodes are found —
    /// an approximation, by design (never bit-identical below 100).
    pub fn set_sample_pct(&mut self, pct: u32) {
        self.sample_pct = pct.clamp(1, 100);
    }

    /// Set the scoring shard count (profile DSL `shards(<n>)`; 1 =
    /// sequential). Shards only apply to cacheable (pure) plugins and
    /// only above a minimum batch size, and produce bit-identical
    /// scores at any count.
    pub fn set_score_shards(&mut self, shards: usize) {
        self.score_shards = shards.max(1);
    }

    /// Tasks that failed scheduling because of a declarative constraint:
    /// the task carries [`crate::tasks::TaskConstraints`] and either a
    /// constraint PreFilter vetoed it cluster-wide, or some node passed
    /// every resource filter but a constraint filter rejected it. Tasks
    /// without declarative constraints (including legacy
    /// `Task::gpu_model` pins) never count. The `ext-filters`
    /// experiment surfaces this counter.
    ///
    /// Thin shim over the metrics registry (the counter's single home
    /// since the observability layer — see [`Scheduler::metrics`]).
    pub fn constraint_unschedulable(&self) -> u64 {
        self.obs.registry.counter("constraint_unschedulable")
    }

    /// Attach the `weightModulator` extension point.
    ///
    /// Debug builds panic when the modulator rejects the plugin layout
    /// (see [`WeightModulator::check_layout`]) — the raw-assembly analog
    /// of the profile builder's parse-time layout validation.
    pub fn set_modulator(&mut self, m: Box<dyn WeightModulator>) {
        #[cfg(debug_assertions)]
        {
            let names: Vec<&str> = self.plugins.iter().map(|p| p.name()).collect();
            if let Err(e) = m.check_layout(&names) {
                // lint:allow(hot-path-hygiene) debug-only layout check at attach time, not in the decision path
                panic!("invalid modulator attachment: {e}");
            }
        }
        self.modulator = Some(m);
    }

    /// Append a `postPlace`/`postFail` hook.
    pub fn add_post_hook(&mut self, h: Box<dyn PostHook>) {
        self.hooks.push(h);
    }

    /// Hand the shared fairness core to every attached plugin that
    /// wants one: the modulator and all post hooks get
    /// `bind_fairness`, which is a documented no-op everywhere except
    /// the fairness plugins ([`crate::sched::fairness::StarveModulator`],
    /// [`crate::sched::fairness::PreemptHook`]). Call after the profile
    /// is built and hooks are attached; schedulers that never bind
    /// leave every plugin inert and behave exactly as before the
    /// fairness subsystem existed.
    pub fn bind_fairness(&mut self, shared: &crate::sched::fairness::FairnessShared) {
        if let Some(m) = &mut self.modulator {
            m.bind_fairness(shared);
        }
        for h in &mut self.hooks {
            h.bind_fairness(shared);
        }
    }

    /// Sum of the named counter over all attached hooks (see
    /// [`PostHook::counters`]).
    pub fn hook_counter(&self, name: &str) -> u64 {
        self.hooks
            .iter()
            .flat_map(|h| h.counters())
            .filter(|(k, _)| *k == name)
            .map(|(_, v)| v)
            .sum()
    }

    /// Summed hook counters, one entry per distinct name (sorted).
    fn hook_counters_snapshot(&self) -> Vec<(&'static str, u64)> {
        let mut sums: std::collections::BTreeMap<&'static str, u64> = Default::default();
        for h in &self.hooks {
            for (k, v) in h.counters() {
                *sums.entry(k).or_insert(0) += v;
            }
        }
        sums.into_iter().collect()
    }

    /// Merged metrics snapshot — the single home for every counter
    /// (`docs/observability.md`): the scheduler-owned registry
    /// (protocol counters, `constraint_unschedulable`, phase
    /// histograms) plus every attached hook's counters (DRS lifecycle,
    /// MIG repartitions, custom hooks) and the process-wide XLA MIG
    /// fallback count. The coordinator renders this via
    /// [`MetricsRegistry::to_prometheus`].
    pub fn metrics(&self) -> MetricsRegistry {
        let mut m = self.obs.registry.clone();
        for (k, v) in self.hook_counters_snapshot() {
            m.set_counter(k, v);
        }
        m.set_counter(
            "mig_scorer_fallbacks",
            crate::runtime::scorer::mig_scorer_fallbacks(),
        );
        m
    }

    /// Borrow the scheduler-owned registry (hook counters are *not*
    /// merged here — use [`Scheduler::metrics`] for the full snapshot).
    pub fn registry(&self) -> &MetricsRegistry {
        &self.obs.registry
    }

    /// Mutably borrow the scheduler-owned registry, so run drivers can
    /// publish end-of-run gauges (the fairness subsystem writes
    /// `pending_depth`/`p99_wait`/`oldest_pending_age` here via
    /// [`crate::sched::fairness::FairnessCore::publish`]).
    pub fn registry_mut(&mut self) -> &mut MetricsRegistry {
        &mut self.obs.registry
    }

    /// Toggle phase-latency profiling: filter / score / bind / hook
    /// [`PhaseTimer`]s accumulate into registry histograms
    /// (`phase_*_ns`, `place_ns`). Off by default; the disabled path
    /// never reads the clock.
    pub fn enable_profiling(&mut self, on: bool) {
        self.obs.profiling = on;
    }

    /// Attach a decision tracer: every subsequent `place`/`release`
    /// emits one JSONL event (see [`crate::obs::trace`]). Tracing
    /// never touches the tie-break RNG or any score computation, so
    /// results are bit-identical with and without it
    /// (`rust/tests/obs_equivalence.rs`).
    pub fn set_tracer(&mut self, tracer: DecisionTracer) {
        self.obs.tracer = Some(tracer);
    }

    /// How many runners-up each trace event records (default 3).
    pub fn set_trace_top_k(&mut self, top_k: usize) {
        self.obs.top_k = top_k;
    }

    /// Flush the attached tracer's sink (end of run); no-op untraced.
    pub fn trace_flush(&self) {
        if let Some(t) = &self.obs.tracer {
            t.sink().flush();
        }
    }

    /// Reseed the tie-break RNG (each simulation repetition uses its own
    /// stream so repetitions are independent).
    pub fn reseed_ties(&mut self, seed: u64) {
        self.obs.tie_seed = seed;
        self.tie_rng = Rng::new(seed ^ 0xC0FFEE);
    }

    /// Ablation: lowest-id instead of random tie-break.
    pub fn set_deterministic_ties(&mut self, on: bool) {
        self.deterministic_ties = on;
    }

    /// Build the scheduler for a named policy (see [`crate::sched::PolicyKind`]).
    ///
    /// # Panics
    /// On a programmatically constructed policy whose α lies outside
    /// [0, 1] (the string parsers reject such values up front; a direct
    /// `PolicyKind::PwrFgd { alpha: 1.5 }` would lower to a negative
    /// FGD weight, which `build` refuses).
    pub fn from_policy(kind: crate::sched::PolicyKind) -> Scheduler {
        kind.profile()
            .build()
            // lint:allow(hot-path-hygiene) constructor-time policy validation, documented under # Panics above
            .unwrap_or_else(|e| panic!("invalid policy {kind:?}: {e}"))
    }

    /// Policy label for reports.
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Notify the scheduler that `node_id`'s allocation changed (commit
    /// or departure). Invalidate plugin caches via the generation bump.
    pub fn notify_node_changed(&mut self, node_id: usize) {
        bump_generation(&mut self.generations)(node_id);
    }

    /// Schedule one task (Algorithm 1). Returns `None` when no node can
    /// host it (a scheduling failure — GRAR's denominator still counts
    /// the arrival). Does **not** mutate the datacenter and does **not**
    /// run hooks; prefer the full [`Scheduler::place`] protocol unless
    /// the caller owns the commit (then: [`Datacenter::allocate`] +
    /// [`Self::notify_node_changed`]).
    pub fn schedule(&mut self, dc: &Datacenter, workload: &Workload, task: &Task) -> Option<Decision> {
        let n = dc.nodes.len();
        if self.generations.len() != n {
            self.generations = vec![0; n];
        }
        // Observability: capture the decision when a tracer is attached
        // (or `repro explain` requested a one-shot), and arm the phase
        // timers when profiling is on. Both default off; the disabled
        // path costs two boolean checks and never perturbs the RNG
        // stream or any float computation.
        let capturing = self.obs.capture_requested || self.obs.tracer.is_some();
        let mut cap = capturing.then(|| TraceCapture {
            filter_names: self.filters.iter().map(|f| f.name()).collect(),
            filter_vetoes: vec![0; self.filters.len()],
            ..TraceCapture::default()
        });
        let prof = self.obs.profiling;
        let t_filter = PhaseTimer::start(prof);
        // --- 1. Filter (extension point) + candidate placements. ---
        self.feasible.clear();
        self.placements.clear();
        self.last_reject_constrained = false;
        // Memoize `constrains(task)` once per decision: the attribution
        // rescan in `filter_node` otherwise re-evaluates it per
        // node × filter, turning the filter phase O(nodes × filters²)
        // for constrained tasks.
        self.filter_constrains.clear();
        for f in &self.filters {
            self.filter_constrains.push(f.constrains(task));
        }
        let fctx = FilterCtx { dc };
        // PreFilter pass: cheap cluster-wide infeasibility checks
        // (aggregate capacity, candidate counts) — a hopeless task
        // skips the O(nodes) loop entirely. Conservative by contract,
        // so the outcome (None) and the RNG stream are unchanged.
        for (fi, f) in self.filters.iter().enumerate() {
            if !f.pre_filter(&fctx, task) {
                // Per-cause attribution: only a plugin enforcing one of
                // *this task's* declarative constraints counts (a
                // legacy model pin or a static `labels:` selector
                // failing is a plain resource-style failure).
                self.last_reject_constrained = self.filter_constrains[fi];
                self.obs.registry.inc("sched_prefilter_rejections", 1);
                if let Some(c) = &mut cap {
                    c.prefilter_veto = Some(f.name());
                    c.constrained = self.last_reject_constrained;
                }
                if let Some(ns) = t_filter.stop_ns() {
                    self.obs.registry.observe_ns("phase_filter_ns", ns);
                }
                self.obs.capture = cap;
                return None;
            }
        }
        if self.sample_pct >= 100 {
            // Full sweep: the exact naive loop (the bit-identity
            // baseline `tests/scale_equivalence.rs` pins).
            for node in &dc.nodes {
                if !filter_node(
                    &self.filters,
                    &self.filter_constrains,
                    &fctx,
                    node,
                    task,
                    &mut self.last_reject_constrained,
                    &mut cap,
                ) {
                    continue;
                }
                let ps = dedup_placements(node, task);
                if ps.is_empty() {
                    continue;
                }
                self.feasible.push(node.id);
                self.placements.push(ps);
            }
        } else {
            // Sampled sweep (k8s `percentageOfNodesToScore`): walk the
            // smallest applicable static candidate index from a
            // rotating offset and stop once enough feasible nodes are
            // found. Approximate by design — the shortlist assumes the
            // chain enforces the constraint the index encodes (true
            // for the default chain).
            let universe = smallest_static_universe(dc, task);
            let u_len = universe.map_or(n, <[u32]>::len);
            if u_len > 0 {
                let want = (self.sample_pct as usize * u_len + 99) / 100;
                let target = want.max(SAMPLE_MIN_FEASIBLE).min(u_len);
                let start = self.sample_offset % u_len;
                let mut scanned = 0;
                while scanned < u_len && self.feasible.len() < target {
                    let mut pos = start + scanned;
                    if pos >= u_len {
                        pos -= u_len;
                    }
                    scanned += 1;
                    let node_id = universe.map_or(pos, |u| u[pos] as usize);
                    let node = &dc.nodes[node_id];
                    if !filter_node(
                        &self.filters,
                        &self.filter_constrains,
                        &fctx,
                        node,
                        task,
                        &mut self.last_reject_constrained,
                        &mut cap,
                    ) {
                        continue;
                    }
                    let ps = dedup_placements(node, task);
                    if ps.is_empty() {
                        continue;
                    }
                    self.feasible.push(node.id);
                    self.placements.push(ps);
                }
                self.sample_offset = (start + scanned) % u_len;
                self.obs.registry.inc("sched_sampled_sweeps", 1);
            }
        }
        if let Some(ns) = t_filter.stop_ns() {
            self.obs.registry.observe_ns("phase_filter_ns", ns);
        }
        if self.feasible.is_empty() {
            if let Some(c) = &mut cap {
                c.constrained = self.last_reject_constrained;
            }
            self.obs.capture = cap;
            return None;
        }
        self.last_reject_constrained = false;
        // Refresh the per-workload / per-cluster caches when needed
        // (revision-keyed; see `prepared_cache`). The caps cache keys
        // on the fleet revision — a node-count key served stale caps
        // whenever a fleet change preserved the count (same-size fleet
        // swap, lattice repartition resizing per-node capacity).
        let rev = workload.revision();
        if self.prepared_cache.as_ref().map(|(r, _)| *r != rev).unwrap_or(true) {
            self.prepared_cache = Some((rev, frag::PreparedWorkload::new(workload)));
        }
        let fleet_rev = dc.revision();
        if self.caps_cache.map(|(r, _)| r != fleet_rev).unwrap_or(true) {
            self.caps_cache = Some((fleet_rev, ClusterCaps::of(dc)));
        }
        if let Some(sc) = &mut self.score_cache {
            sc.ensure_epoch((rev, fleet_rev), self.plugins.len());
        }
        // Both caches were (re)filled just above, so destructure them
        // infallibly; if that invariant ever breaks, fail the decision
        // instead of panicking mid-protocol.
        let (Some((_, prepared)), Some((_, caps))) = (&self.prepared_cache, &self.caps_cache)
        else {
            self.obs.capture = cap;
            return None;
        };
        let ctx = SchedCtx {
            dc,
            workload,
            prepared,
            generations: &self.generations,
            caps: *caps,
            gang: self.gang_progress.as_ref(),
        };
        let t_score = PhaseTimer::start(prof);
        // --- 2. WeightModulator extension point: retarget the plugin
        // weights (and possibly the weighted binder's α) per decision
        // from cluster state.
        self.eff_weights.clear();
        self.eff_weights.extend_from_slice(&self.weights);
        let bind_alpha_override = self
            .modulator
            .as_ref()
            .and_then(|m| m.modulate(dc, &self.weights, &mut self.eff_weights));
        // --- 3–5. Score, normalize, combine. ---
        let k = self.feasible.len();
        self.combined.clear();
        self.combined.resize(k, 0.0);
        let per_node_mod = self.modulator.as_deref().filter(|m| m.per_node());
        // Raw scores come from `score_one_plugin`: cache hits reuse
        // the stored f64 bit-for-bit, misses call the plugin (on shard
        // threads when enabled), so every downstream step (normalize,
        // combine, tie-break) sees exactly the naive loop's values.
        let sig = TaskSig::of(task);
        let shards = self.score_shards;
        let mut stats = ScoreStats::default();
        let score_cache = &mut self.score_cache;
        if let Some(modulator) = per_node_mod {
            // Per-node modulation (e.g. per-lattice α): normalization is
            // still per plugin across nodes, so keep every normalized
            // row and combine with a node-specific weight vector.
            self.norm_rows.clear();
            for (pi, plugin) in self.plugins.iter().enumerate() {
                let cache = score_cache
                    .as_mut()
                    .filter(|_| plugin.cacheable())
                    .map(|sc| &mut sc.plugins[pi]);
                score_one_plugin(
                    plugin.as_ref(),
                    &ctx,
                    task,
                    sig,
                    &self.feasible,
                    &self.placements,
                    cache,
                    if plugin.cacheable() { shards } else { 1 },
                    &mut self.raw,
                    &mut self.miss_scratch,
                    &mut stats,
                );
                normalize_scores(&mut self.raw);
                if let Some(c) = &mut cap {
                    c.norm_rows.push(self.raw.clone());
                }
                self.norm_rows.extend_from_slice(&self.raw);
            }
            let n_plugins = self.plugins.len();
            for i in 0..k {
                self.node_weights.clear();
                self.node_weights.extend_from_slice(&self.eff_weights);
                modulator.modulate_node(
                    &dc.nodes[self.feasible[i]],
                    &self.weights,
                    &mut self.node_weights,
                );
                let mut acc = 0.0;
                for p in 0..n_plugins {
                    acc += self.node_weights[p] * self.norm_rows[p * k + i];
                }
                self.combined[i] = acc;
            }
        } else {
            for (pi, (plugin, &weight)) in self.plugins.iter().zip(&self.eff_weights).enumerate() {
                let cache = score_cache
                    .as_mut()
                    .filter(|_| plugin.cacheable())
                    .map(|sc| &mut sc.plugins[pi]);
                score_one_plugin(
                    plugin.as_ref(),
                    &ctx,
                    task,
                    sig,
                    &self.feasible,
                    &self.placements,
                    cache,
                    if plugin.cacheable() { shards } else { 1 },
                    &mut self.raw,
                    &mut self.miss_scratch,
                    &mut stats,
                );
                normalize_scores(&mut self.raw);
                if let Some(c) = &mut cap {
                    c.norm_rows.push(self.raw.clone());
                }
                for (c, r) in self.combined.iter_mut().zip(&self.raw) {
                    *c += weight * r;
                }
            }
        }
        // Flush the per-decision scoring tallies in one shot each (the
        // registry's string-keyed `inc` is too costly per node).
        if stats.hits > 0 {
            self.obs.registry.inc("score_cache_hits", stats.hits);
        }
        if stats.misses > 0 {
            self.obs.registry.inc("score_cache_misses", stats.misses);
        }
        if stats.shard_batches > 0 {
            self.obs.registry.inc("score_shard_batches", stats.shard_batches);
        }
        if let Some(ns) = t_score.stop_ns() {
            self.obs.registry.observe_ns("phase_score_ns", ns);
        }
        let t_bind = PhaseTimer::start(prof);
        // --- 6. Arg-max + bind. Kubernetes semantics: plugin scores are
        // int64 in [0,100] after NormalizeScore (normalize_scores already
        // rounds), and `selectHost` picks *uniformly at random* among the
        // max-scoring nodes. The random tie-break matters: for e.g. a
        // whole-GPU task on a large pool of identical idle nodes FGD is
        // indifferent, and k8s spreads the load — which is precisely the
        // power-wasting behaviour PWR corrects (paper §VI-B).
        let mut best = 0;
        let mut n_ties = 1u32;
        for i in 1..k {
            if self.combined[i] > self.combined[best] + 1e-9 {
                best = i;
                n_ties = 1;
            } else if !self.deterministic_ties
                && (self.combined[i] - self.combined[best]).abs() <= 1e-9
            {
                // Reservoir-sample uniformly among ties.
                n_ties += 1;
                if self.tie_rng.below(n_ties as usize) == 0 {
                    best = i;
                }
            }
        }
        // Capture the scoring table: winner first, then the top-k
        // runners-up by combined score (ties broken by node index).
        if let Some(c) = &mut cap {
            c.feasible = k;
            c.plugins = self.plugins.iter().map(|p| p.name()).collect();
            c.weights = self.eff_weights.clone();
            c.ties = n_ties;
            let norm_rows = std::mem::take(&mut c.norm_rows);
            let mut order: Vec<usize> = (0..k).collect();
            order.sort_by(|&a, &b| {
                self.combined[b]
                    .partial_cmp(&self.combined[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            let mut picked = vec![best];
            for &i in &order {
                if i != best && picked.len() < self.obs.top_k + 1 {
                    picked.push(i);
                }
            }
            for &i in &picked {
                c.scores.push(ScoreRow {
                    node: self.feasible[i],
                    combined: self.combined[i],
                    per_plugin: norm_rows.iter().map(|row| row[i]).collect(),
                    winner: i == best,
                });
            }
        }
        let node_id = self.feasible[best];
        let candidates = &self.placements[best];
        let n_candidates = candidates.len();
        let placement = if n_candidates == 1 {
            candidates[0].clone()
        } else {
            let bctx = BindCtx {
                prepared: ctx.prepared,
                alpha_override: bind_alpha_override,
            };
            self.binder.bind(&bctx, &dc.nodes[node_id], task, candidates)
        };
        if let Some(ns) = t_bind.stop_ns() {
            self.obs.registry.observe_ns("phase_bind_ns", ns);
        }
        if let Some(c) = &mut cap {
            c.bind_node = Some(node_id);
            c.candidates = n_candidates;
            c.placement = Some(format!("{placement:?}"));
        }
        self.obs.capture = cap;
        Some(Decision { node: node_id, placement })
    }

    /// Current value of the scheduler-event clock (ticks; one per
    /// `place`/`release` protocol entry).
    pub fn now(&self) -> u64 {
        self.events
    }

    /// Bump the scheduler-event clock and run every hook's `onTick`
    /// phase (wake completions, sleep deadlines) before the decision.
    fn advance_clock(&mut self, dc: &mut Datacenter) {
        self.events += 1;
        let now = self.events;
        let mut invalidate = bump_generation(&mut self.generations);
        for h in &mut self.hooks {
            h.on_tick(dc, now, &mut invalidate);
        }
    }

    /// The full per-task protocol: clock tick (`onTick` hooks) →
    /// schedule → (on failure: `postFail` hooks, one retry) → commit →
    /// `postPlace` hooks. This is the one entry point the simulation
    /// loops and the coordinator use, so a profile's hooks (e.g. the
    /// MIG repartitioner, the DRS sleep/wake manager) can never be
    /// silently skipped.
    pub fn place(&mut self, dc: &mut Datacenter, workload: &Workload, task: &Task) -> Option<Decision> {
        let prof = self.obs.profiling;
        let tracing = self.obs.tracer.is_some();
        let hooks_before = if tracing { self.hook_counters_snapshot() } else { Vec::new() };
        let t_place = PhaseTimer::start(prof);
        let mut hooks_ns = 0.0;
        let t = PhaseTimer::start(prof);
        self.advance_clock(dc);
        if let Some(ns) = t.stop_ns() {
            hooks_ns += ns;
        }
        let mut retried = false;
        let decision = match self.schedule(dc, workload, task) {
            Some(d) => Some(d),
            None => {
                let t = PhaseTimer::start(prof);
                let filters = &self.filters;
                let mut invalidate = bump_generation(&mut self.generations);
                let mut retry = false;
                for h in &mut self.hooks {
                    if h.post_fail_chained(dc, task, filters, &mut invalidate) {
                        retry = true;
                        break;
                    }
                }
                if let Some(ns) = t.stop_ns() {
                    hooks_ns += ns;
                }
                if retry {
                    retried = true;
                    self.obs.registry.inc("sched_retries", 1);
                    self.schedule(dc, workload, task)
                } else {
                    None
                }
            }
        };
        let result = match decision {
            None => {
                // The task is definitively unschedulable; attribute it
                // once (retries included) to constraints when a
                // constraint filter was the blocker.
                if self.last_reject_constrained {
                    self.obs.registry.inc("constraint_unschedulable", 1);
                }
                self.obs.registry.inc("sched_failures", 1);
                None
            }
            Some(decision) => {
                dc.allocate(task, decision.node, &decision.placement);
                self.notify_node_changed(decision.node);
                let t = PhaseTimer::start(prof);
                self.run_post_place(dc, decision.node);
                if let Some(ns) = t.stop_ns() {
                    hooks_ns += ns;
                }
                self.obs.registry.inc("sched_places", 1);
                Some(decision)
            }
        };
        if let Some(ns) = t_place.stop_ns() {
            self.obs.registry.observe_ns("place_ns", ns);
            self.obs.registry.observe_ns("phase_hooks_ns", hooks_ns);
        }
        if tracing {
            self.emit_place_event(task, result.as_ref(), retried, &hooks_before);
        }
        result
    }

    /// The departure protocol: clock tick, release the allocation and
    /// run the `postPlace` hooks (departures are where e.g. MIG
    /// lattice holes open up and where nodes fall idle for DRS).
    pub fn release(&mut self, dc: &mut Datacenter, task: &Task, node: usize, placement: &Placement) {
        let prof = self.obs.profiling;
        let tracing = self.obs.tracer.is_some();
        let hooks_before = if tracing { self.hook_counters_snapshot() } else { Vec::new() };
        let mut hooks_ns = 0.0;
        let t = PhaseTimer::start(prof);
        self.advance_clock(dc);
        if let Some(ns) = t.stop_ns() {
            hooks_ns += ns;
        }
        dc.deallocate(task, node, placement);
        self.notify_node_changed(node);
        let t = PhaseTimer::start(prof);
        self.run_post_place(dc, node);
        if let Some(ns) = t.stop_ns() {
            hooks_ns += ns;
            self.obs.registry.observe_ns("phase_hooks_ns", hooks_ns);
        }
        self.obs.registry.inc("sched_releases", 1);
        if tracing {
            let after = self.hook_counters_snapshot();
            let deltas = hook_counter_deltas(&hooks_before, &after);
            let event = obs::trace::release_event(task, node, placement, self.events, &deltas);
            if let Some(t) = self.obs.tracer.as_mut() {
                t.emit(event);
                self.obs.registry.inc("trace_events", 1);
            }
        }
    }

    /// The all-or-nothing gang protocol: one clock tick, the PreFilter
    /// chain on the gang *parent* (aggregate capacity including the
    /// `gang` filter's NVLink-contiguous bound), then each member —
    /// one TP group, [`crate::sched::gang::member_task`] — through the
    /// full decision pipeline in member order, committing as it goes so
    /// later members see earlier ones. A member failure first offers
    /// every hook a gang-aware remedy ([`PostHook::post_fail_gang`],
    /// one retry), and a definitive failure rolls the committed prefix
    /// back in reverse — counters, per-node state and revision stamps
    /// return to their pre-call values, so a failed gang is
    /// indistinguishable from one never attempted (pinned by
    /// `rust/tests/gang_equivalence.rs`). `postPlace` hooks run only
    /// after the whole gang commits. Tasks without a gang fall through
    /// to the ordinary [`Scheduler::place`] protocol as a one-member
    /// gang. A committed gang emits one JSONL `gang` trace event with a
    /// per-member bind record (node + placement) for every TP group
    /// ([`crate::obs::trace::gang_event`]); failed or rolled-back gangs
    /// emit nothing.
    pub fn place_gang(
        &mut self,
        dc: &mut Datacenter,
        workload: &Workload,
        task: &Task,
    ) -> Option<crate::sched::gang::GangDecision> {
        use crate::sched::gang::{member_task, pp_span, tp_violations, GangDecision, GangProgress};
        let Some(spec) = task.gang else {
            return self
                .place(dc, workload, task)
                .map(|d| GangDecision { members: vec![d] });
        };
        let tracing = self.obs.tracer.is_some();
        let hooks_before = if tracing { self.hook_counters_snapshot() } else { Vec::new() };
        self.advance_clock(dc);
        // PreFilter the parent: its demand fields carry the gang
        // totals, so aggregate checks need no special casing, and the
        // `gang` filter adds the contiguous-capacity bound.
        {
            let fctx = FilterCtx { dc };
            for f in &self.filters {
                if !f.pre_filter(&fctx, task) {
                    self.obs.registry.inc("sched_prefilter_rejections", 1);
                    self.obs.registry.inc("gangs_failed", 1);
                    self.obs.registry.inc("sched_failures", 1);
                    return None;
                }
            }
        }
        let n_members = spec.n_members();
        let mut members: Vec<Decision> = Vec::with_capacity(n_members as usize);
        for i in 0..n_members {
            let member = member_task(task, i);
            self.gang_progress = Some(GangProgress {
                spec,
                member: i,
                nodes: members.iter().map(|d| d.node).collect(),
            });
            let decision = match self.schedule(dc, workload, &member) {
                Some(d) => Some(d),
                None => {
                    let filters = &self.filters;
                    let mut invalidate = bump_generation(&mut self.generations);
                    let mut retry = false;
                    for h in &mut self.hooks {
                        if h.post_fail_gang(dc, &member, n_members - i, filters, &mut invalidate) {
                            retry = true;
                            break;
                        }
                    }
                    if retry {
                        self.obs.registry.inc("sched_retries", 1);
                        self.schedule(dc, workload, &member)
                    } else {
                        None
                    }
                }
            };
            let Some(d) = decision else {
                // All-or-nothing: unwind the committed prefix in
                // reverse, restoring every counter exactly.
                self.gang_progress = None;
                for (j, dj) in members.iter().enumerate().rev() {
                    let m = member_task(task, j as u32);
                    dc.deallocate(&m, dj.node, &dj.placement);
                }
                let touched: Vec<usize> = members.iter().map(|d| d.node).collect();
                for n in touched {
                    self.notify_node_changed(n);
                }
                if self.last_reject_constrained {
                    self.obs.registry.inc("constraint_unschedulable", 1);
                }
                self.obs.registry.inc("gangs_failed", 1);
                self.obs.registry.inc("sched_failures", 1);
                return None;
            };
            dc.allocate(&member, d.node, &d.placement);
            self.notify_node_changed(d.node);
            members.push(d);
        }
        self.gang_progress = None;
        // `postPlace` hooks run once per member, only now that the gang
        // is committed (a hook mutating the cluster mid-gang would make
        // rollback inexact).
        let touched: Vec<usize> = members.iter().map(|d| d.node).collect();
        for n in touched {
            self.run_post_place(dc, n);
        }
        self.obs.registry.inc("gang_pp_span_sum", pp_span(&members));
        let violations = tp_violations(&members, spec);
        if violations > 0 {
            self.obs.registry.inc("gang_tp_violations", violations);
        }
        self.obs.registry.inc("gangs_placed", 1);
        self.obs.registry.inc("sched_places", 1);
        if tracing {
            let after = self.hook_counters_snapshot();
            let deltas = hook_counter_deltas(&hooks_before, &after);
            let event = obs::trace::gang_event(task, &members, self.events, &deltas);
            if let Some(t) = self.obs.tracer.as_mut() {
                t.emit(event);
                self.obs.registry.inc("trace_events", 1);
            }
        }
        Some(GangDecision { members })
    }

    /// Departure of a committed gang: one clock tick, every member
    /// released (members are rebuilt deterministically from the parent),
    /// then the `postPlace` hooks per touched node — the mirror of
    /// [`Scheduler::place_gang`], counted as one `sched_releases`.
    pub fn release_gang(
        &mut self,
        dc: &mut Datacenter,
        task: &Task,
        decision: &crate::sched::gang::GangDecision,
    ) {
        self.advance_clock(dc);
        for (i, d) in decision.members.iter().enumerate() {
            let member = crate::sched::gang::member_task(task, i as u32);
            dc.deallocate(&member, d.node, &d.placement);
            self.notify_node_changed(d.node);
        }
        let touched: Vec<usize> = decision.members.iter().map(|d| d.node).collect();
        for n in touched {
            self.run_post_place(dc, n);
        }
        self.obs.registry.inc("sched_releases", 1);
    }

    /// Turn the capture of the just-finished decision into a JSONL
    /// `place` event, with the hook-counter deltas observed across this
    /// protocol entry (DRS wakes, repartitions, …).
    fn emit_place_event(
        &mut self,
        task: &Task,
        decision: Option<&Decision>,
        retried: bool,
        hooks_before: &[(&'static str, u64)],
    ) {
        let cap = self.obs.capture.take().unwrap_or_default();
        let after = self.hook_counters_snapshot();
        let deltas = hook_counter_deltas(hooks_before, &after);
        let event = obs::trace::place_event(
            task,
            &cap,
            decision,
            retried,
            self.events,
            self.obs.tie_seed,
            &deltas,
        );
        if let Some(t) = self.obs.tracer.as_mut() {
            t.emit(event);
            self.obs.registry.inc("trace_events", 1);
        }
    }

    /// Replay one arrival in capture mode **without committing**: run
    /// the decision pipeline (no clock tick, no hooks, no allocation)
    /// and return the would-be trace event — the scoring table `repro
    /// explain` pretty-prints. The tie-break RNG advances exactly as a
    /// real decision would, so an explain interleaved into a live run
    /// shifts subsequent tie-breaks; on a fresh scheduler it is
    /// side-effect-free.
    pub fn explain(
        &mut self,
        dc: &Datacenter,
        workload: &Workload,
        task: &Task,
        top_k: usize,
    ) -> Json {
        let prev_top_k = self.obs.top_k;
        self.obs.top_k = top_k;
        self.obs.capture_requested = true;
        let decision = self.schedule(dc, workload, task);
        self.obs.capture_requested = false;
        self.obs.top_k = prev_top_k;
        let cap = self.obs.capture.take().unwrap_or_default();
        obs::trace::place_event(
            task,
            &cap,
            decision.as_ref(),
            false,
            self.events,
            self.obs.tie_seed,
            &[],
        )
    }

    fn run_post_place(&mut self, dc: &mut Datacenter, node_id: usize) {
        let mut invalidate = bump_generation(&mut self.generations);
        for h in &mut self.hooks {
            h.post_place(dc, node_id, &mut invalidate);
        }
    }
}

/// Non-zero increments between two [`Scheduler::hook_counters_snapshot`]
/// calls (the hook-action deltas a trace event reports).
fn hook_counter_deltas(
    before: &[(&'static str, u64)],
    after: &[(&'static str, u64)],
) -> Vec<(String, u64)> {
    let mut out = Vec::new();
    for &(k, v) in after {
        let prev = before.iter().find(|&&(bk, _)| bk == k).map(|&(_, bv)| bv).unwrap_or(0);
        if v > prev {
            out.push((k.to_string(), v - prev));
        }
    }
    out
}

/// k8s `minFeasibleNodesToFind`: the sampled sweep never settles for
/// fewer feasible candidates than this (so small clusters always get
/// the full sweep regardless of the percentage).
const SAMPLE_MIN_FEASIBLE: usize = 100;

/// Minimum per-batch work before the sharded path spawns threads —
/// below this, thread setup dwarfs the scoring it parallelizes. Scores
/// are identical either way (pure plugins), so the cutover is purely a
/// latency knob.
const SHARD_MIN_WORK: usize = 64;

/// One node through the filter chain (conjunction, first-veto-wins),
/// shared by the full and sampled sweeps. Counts the veto for the
/// trace capture and settles constraint attribution: a rejection is
/// constraint-attributed when the vetoing filter enforces one of this
/// task's declarative constraints *and* every other filter accepts the
/// node (earlier filters already ran; later non-constraint filters are
/// rescanned here). `constrains` is the per-decision memo of
/// `FilterPlugin::constrains(task)`, and the rescan short-circuits for
/// the rest of the decision once attribution is settled.
fn filter_node(
    filters: &[Box<dyn FilterPlugin>],
    constrains: &[bool],
    fctx: &FilterCtx,
    node: &Node,
    task: &Task,
    last_reject_constrained: &mut bool,
    cap: &mut Option<TraceCapture>,
) -> bool {
    for (fi, f) in filters.iter().enumerate() {
        if !f.feasible(fctx, node, task) {
            // First-rejector attribution for the trace: filters run in
            // chain order, the first `false` owns the veto (later
            // filters never see the node).
            if let Some(c) = cap {
                c.filter_vetoes[fi] += 1;
            }
            if !*last_reject_constrained
                && constrains[fi]
                && filters[fi + 1..]
                    .iter()
                    .zip(&constrains[fi + 1..])
                    .filter(|(_, &c)| !c)
                    .all(|(g, _)| g.feasible(fctx, node, task))
            {
                *last_reject_constrained = true;
            }
            return false;
        }
    }
    true
}

/// The smallest static candidate index applicable to `task` (the
/// sampled sweep's universe): a legacy model pin or single-model
/// constraint set shortlists to that model's nodes, a MIG demand to
/// its lattice's nodes, and each node-selector entry to its label's
/// nodes. `None` = no static index applies; sweep the whole fleet.
fn smallest_static_universe<'a>(dc: &'a Datacenter, task: &Task) -> Option<&'a [u32]> {
    let mut best: Option<&'a [u32]> = None;
    let mut consider = |list: &'a [u32]| {
        if best.map_or(true, |b| list.len() < b.len()) {
            best = Some(list);
        }
    };
    if let Some(m) = task.gpu_model {
        consider(dc.nodes_of_model(m));
    }
    if let GpuDemand::Mig(p) = task.gpu {
        consider(dc.nodes_of_lattice(p.lattice()));
    }
    if let Some(c) = task.constraints.as_deref() {
        if let [m] = c.gpu_models[..] {
            consider(dc.nodes_of_model(m));
        }
        for (k, v) in &c.node_selector {
            consider(dc.nodes_of_label(k, v));
        }
    }
    best
}

/// Fill `raw` with one plugin's scores over the feasible set. Cache
/// hits reuse the stored raw score bit-for-bit; misses call the plugin
/// — sequentially, or on scoped shard threads when `shards > 1` and
/// the batch is worth it — and write back `(generation, score)`.
/// `cache` is `None` for non-cacheable plugins and when the cache is
/// disabled (then `shards` must be 1 for non-cacheable plugins so
/// their internal state advances in feasible order, exactly as the
/// naive loop).
#[allow(clippy::too_many_arguments)]
fn score_one_plugin(
    plugin: &dyn ScorePlugin,
    ctx: &SchedCtx,
    task: &Task,
    sig: TaskSig,
    feasible: &[usize],
    placements: &[Vec<Placement>],
    cache: Option<&mut HashMap<TaskSig, Vec<(u64, f64)>>>,
    shards: usize,
    raw: &mut Vec<f64>,
    miss_scratch: &mut Vec<usize>,
    stats: &mut ScoreStats,
) {
    raw.clear();
    let Some(map) = cache else {
        if shards <= 1 || feasible.len() < SHARD_MIN_WORK {
            for (idx, &node_id) in feasible.iter().enumerate() {
                let s = plugin.score(ctx, &ctx.dc.nodes[node_id], task, &placements[idx]);
                debug_assert!(s.is_finite(), "{} returned {s}", plugin.name());
                raw.push(s);
            }
        } else {
            raw.resize(feasible.len(), 0.0);
            miss_scratch.clear();
            miss_scratch.extend(0..feasible.len());
            score_targets_sharded(plugin, ctx, task, feasible, placements, miss_scratch, shards, raw);
            stats.shard_batches += 1;
        }
        return;
    };
    let n_nodes = ctx.dc.nodes.len();
    let entries = map
        .entry(sig)
        .or_insert_with(|| vec![(u64::MAX, 0.0); n_nodes]);
    if entries.len() != n_nodes {
        entries.clear();
        entries.resize(n_nodes, (u64::MAX, 0.0));
    }
    raw.resize(feasible.len(), 0.0);
    // Hit pass: an entry is valid when its stored generation matches
    // the node's current one (u64::MAX marks "never scored" — node
    // generations start at 0 and only increment, so it never matches).
    miss_scratch.clear();
    for (idx, &node_id) in feasible.iter().enumerate() {
        let (gen, s) = entries[node_id];
        if gen == ctx.generations[node_id] {
            raw[idx] = s;
        } else {
            miss_scratch.push(idx);
        }
    }
    stats.hits += (feasible.len() - miss_scratch.len()) as u64;
    stats.misses += miss_scratch.len() as u64;
    if miss_scratch.is_empty() {
        return;
    }
    if shards <= 1 || miss_scratch.len() < SHARD_MIN_WORK {
        for &idx in miss_scratch.iter() {
            let node_id = feasible[idx];
            let s = plugin.score(ctx, &ctx.dc.nodes[node_id], task, &placements[idx]);
            debug_assert!(s.is_finite(), "{} returned {s}", plugin.name());
            raw[idx] = s;
        }
    } else {
        score_targets_sharded(plugin, ctx, task, feasible, placements, miss_scratch, shards, raw);
        stats.shard_batches += 1;
    }
    for &idx in miss_scratch.iter() {
        let node_id = feasible[idx];
        entries[node_id] = (ctx.generations[node_id], raw[idx]);
    }
}

/// Score `targets` (indices into `feasible`) on up to `shards` scoped
/// threads and write the results into `raw[target]`. Each thread owns
/// a contiguous chunk; the join order is the spawn order, so the
/// merge is deterministic — and since only cacheable (pure) plugins
/// reach here, every score is bit-identical to the sequential path.
#[allow(clippy::too_many_arguments)]
fn score_targets_sharded(
    plugin: &dyn ScorePlugin,
    ctx: &SchedCtx,
    task: &Task,
    feasible: &[usize],
    placements: &[Vec<Placement>],
    targets: &[usize],
    shards: usize,
    raw: &mut [f64],
) {
    let chunk = (targets.len() + shards - 1) / shards;
    let mut computed: Vec<Vec<f64>> = Vec::with_capacity(shards);
    std::thread::scope(|scope| {
        let handles: Vec<_> = targets
            .chunks(chunk)
            .map(|ch| {
                scope.spawn(move || {
                    ch.iter()
                        .map(|&idx| {
                            let node_id = feasible[idx];
                            let s =
                                plugin.score(ctx, &ctx.dc.nodes[node_id], task, &placements[idx]);
                            debug_assert!(s.is_finite(), "{} returned {s}", plugin.name());
                            s
                        })
                        .collect::<Vec<f64>>()
                })
            })
            .collect();
        for h in handles {
            // lint:allow(hot-path-hygiene) propagating a shard thread's panic is the correct failure mode
            computed.push(h.join().expect("score shard panicked"));
        }
    });
    for (ch, vals) in targets.chunks(chunk).zip(&computed) {
        for (&idx, &s) in ch.iter().zip(vals) {
            raw[idx] = s;
        }
    }
}

/// k8s NormalizeScore: min-max map to [0, 100], **rounded to integers**
/// (framework scores are int64); all-equal maps to 100.
pub fn normalize_scores(scores: &mut [f64]) {
    let lo = scores.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = scores.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    if !(hi - lo).is_finite() || hi - lo < 1e-12 {
        scores.iter_mut().for_each(|s| *s = 100.0);
        return;
    }
    for s in scores {
        *s = (100.0 * (*s - lo) / (hi - lo)).round();
    }
}

/// Candidate placements with equivalence dedup: for fractional tasks,
/// GPUs with the same free fraction are interchangeable for every
/// plugin metric (power, fragmentation, packing) — keep the lowest
/// index per distinct residual. For MIG tasks, GPUs in the identical
/// partition state (same occupancy mask) are likewise interchangeable —
/// keep the lowest-index GPU per distinct mask, with all of its legal
/// starts (starts on one GPU are *not* equivalent: each blocks
/// different future windows). Whole-GPU placements are already
/// canonical.
pub fn dedup_placements(node: &Node, task: &Task) -> Vec<Placement> {
    match task.gpu {
        GpuDemand::Frac(d) => {
            let mut seen: Vec<u64> = Vec::with_capacity(4);
            let mut out = Vec::with_capacity(4);
            for g in 0..node.gpu_alloc.len() {
                let free = node.gpu_free_of(g);
                if free < d - EPS {
                    continue;
                }
                let key = (free * (1u64 << 32) as f64) as u64;
                if !seen.contains(&key) {
                    seen.push(key);
                    out.push(Placement::Shared { gpu: g });
                }
            }
            out
        }
        GpuDemand::Mig(p) => {
            let Some(migs) = &node.mig else { return Vec::new() };
            let mut seen: Vec<u8> = Vec::with_capacity(4);
            let mut out = Vec::new();
            for (g, mg) in migs.iter().enumerate() {
                if seen.contains(&mg.mask) {
                    continue;
                }
                seen.push(mg.mask);
                for s in mg.free_starts(p) {
                    out.push(Placement::MigSlice { gpu: g, start: s });
                }
            }
            out
        }
        _ => node.candidate_placements(task),
    }
}

/// Δ estimated node power of a hypothetical assignment (PWR's metric).
pub fn power_delta(node: &Node, task: &Task, placement: &Placement) -> f64 {
    let before = power::p_node(node);
    let h = node.hypothetical(task, placement);
    power::p_node(&h) - before
}

/// Δ expected node fragmentation of a hypothetical assignment (FGD's
/// metric).
pub fn frag_delta(node: &Node, task: &Task, placement: &Placement, workload: &Workload) -> f64 {
    let before = frag::f_node(node, workload);
    frag_delta_with_before(node, task, placement, workload, before)
}

/// Like [`frag_delta`] with `F_n(M)` of the current state precomputed
/// (plugins cache it per node generation).
pub fn frag_delta_with_before(
    node: &Node,
    task: &Task,
    placement: &Placement,
    workload: &Workload,
    before: f64,
) -> f64 {
    let h = node.hypothetical(task, placement);
    frag::f_node(&h, workload) - before
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::types::{CpuModel, GpuModel};
    use crate::cluster::ClusterSpec;

    fn dc2() -> Datacenter {
        ClusterSpec::tiny(2, 4, 0).build()
    }

    #[test]
    fn normalize_maps_to_0_100() {
        let mut s = vec![-5.0, 0.0, 5.0];
        normalize_scores(&mut s);
        assert_eq!(s, vec![0.0, 50.0, 100.0]);
        let mut eq = vec![3.0, 3.0];
        normalize_scores(&mut eq);
        assert_eq!(eq, vec![100.0, 100.0]);
    }

    #[test]
    fn dedup_groups_equal_residuals() {
        let mut node =
            Node::new(0, CpuModel::XeonE5_2682V4, Some(GpuModel::G2), 96.0, 393_216.0, 4);
        // Make GPU1 and GPU2 identical (0.5 free), GPU0 and GPU3 free.
        node.allocate(&Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.5)), &Placement::Shared { gpu: 1 });
        node.allocate(&Task::new(2, 1.0, 0.0, GpuDemand::Frac(0.5)), &Placement::Shared { gpu: 2 });
        let ps = dedup_placements(&node, &Task::new(3, 1.0, 0.0, GpuDemand::Frac(0.25)));
        // distinct residuals: 1.0 (gpu0) and 0.5 (gpu1) -> 2 candidates
        assert_eq!(ps.len(), 2);
    }

    #[test]
    fn dedup_groups_identical_mig_masks() {
        use crate::cluster::mig::MigProfile;
        let mut node =
            Node::new(0, CpuModel::XeonE5_2682V4, Some(GpuModel::G3), 128.0, 786_432.0, 4);
        node.enable_mig();
        // All four GPUs empty -> one representative GPU, 7 starts for 1g.
        let t1g = Task::new(0, 1.0, 0.0, GpuDemand::Mig(MigProfile::P1g));
        assert_eq!(dedup_placements(&node, &t1g).len(), 7);
        // Partition GPU 2 -> two distinct masks -> starts from two GPUs.
        node.allocate(&t1g, &Placement::MigSlice { gpu: 2, start: 0 });
        let ps = dedup_placements(&node, &t1g);
        assert_eq!(ps.len(), 7 + 6);
        assert!(ps.iter().all(|p| matches!(p,
            Placement::MigSlice { gpu, .. } if *gpu == 0 || *gpu == 2)));
    }

    #[test]
    fn power_delta_fractional_prefers_occupied_gpu() {
        let mut node =
            Node::new(0, CpuModel::XeonE5_2682V4, Some(GpuModel::G2), 96.0, 393_216.0, 4);
        node.allocate(&Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.5)), &Placement::Shared { gpu: 0 });
        let t = Task::new(2, 1.0, 0.0, GpuDemand::Frac(0.25));
        let on_occupied = power_delta(&node, &t, &Placement::Shared { gpu: 0 });
        let on_idle = power_delta(&node, &t, &Placement::Shared { gpu: 1 });
        assert_eq!(on_occupied, 0.0);
        assert_eq!(on_idle, 120.0); // G2: 150 max − 30 idle
    }

    #[test]
    fn scheduler_schedules_on_tiny_cluster() {
        let dc = dc2();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::FirstFit);
        let t = Task::new(0, 4.0, 1024.0, GpuDemand::Whole(1));
        let d = s.schedule(&dc, &w, &t).unwrap();
        assert_eq!(d.node, 0);
        assert_eq!(d.placement, Placement::Whole { gpus: vec![0] });
    }

    #[test]
    fn scheduler_returns_none_when_infeasible() {
        let dc = dc2();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::FirstFit);
        let t = Task::new(0, 4.0, 0.0, GpuDemand::Whole(64));
        assert!(s.schedule(&dc, &w, &t).is_none());
    }

    #[test]
    fn commit_then_notify_flow() {
        let mut dc = dc2();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::Fgd);
        for i in 0..8 {
            let t = Task::new(i, 2.0, 512.0, GpuDemand::Whole(1));
            let d = s.schedule(&dc, &w, &t).expect("fits");
            dc.allocate(&t, d.node, &d.placement);
            s.notify_node_changed(d.node);
        }
        assert_eq!(dc.gpu_allocated_units(), 8.0);
        // Cluster full for whole-GPU tasks now.
        let t = Task::new(99, 2.0, 512.0, GpuDemand::Whole(1));
        assert!(s.schedule(&dc, &w, &t).is_none());
    }

    #[test]
    fn place_protocol_commits_and_runs_hooks() {
        // A counting hook: post_place fires on every commit; post_fail
        // fires on every failure (and declines to make room).
        struct CountingHook {
            places: u64,
            fails: u64,
        }
        impl PostHook for CountingHook {
            fn name(&self) -> &'static str {
                "counting"
            }
            fn post_fail(
                &mut self,
                _dc: &mut Datacenter,
                _task: &Task,
                _invalidate: &mut dyn FnMut(usize),
            ) -> bool {
                self.fails += 1;
                false
            }
            fn post_place(
                &mut self,
                _dc: &mut Datacenter,
                _node_id: usize,
                _invalidate: &mut dyn FnMut(usize),
            ) {
                self.places += 1;
            }
            fn counters(&self) -> Vec<(&'static str, u64)> {
                vec![("places", self.places), ("fails", self.fails)]
            }
        }
        let mut dc = dc2();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::FirstFit);
        s.add_post_hook(Box::new(CountingHook { places: 0, fails: 0 }));
        for i in 0..8 {
            let t = Task::new(i, 2.0, 512.0, GpuDemand::Whole(1));
            assert!(s.place(&mut dc, &w, &t).is_some());
        }
        assert_eq!(dc.gpu_allocated_units(), 8.0);
        let t = Task::new(99, 2.0, 512.0, GpuDemand::Whole(1));
        assert!(s.place(&mut dc, &w, &t).is_none());
        assert_eq!(s.hook_counter("places"), 8);
        assert_eq!(s.hook_counter("fails"), 1);
        // release() runs postPlace again.
        let t0 = Task::new(0, 2.0, 512.0, GpuDemand::Whole(1));
        s.release(&mut dc, &t0, 0, &Placement::Whole { gpus: vec![0] });
        assert_eq!(s.hook_counter("places"), 9);
    }

    #[test]
    fn constraint_unschedulable_counter_attributes_correctly() {
        use crate::tasks::TaskConstraints;
        let mut dc = dc2(); // 2 G2 nodes
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::FirstFit);
        // Resource-infeasible task: fails, but not due to constraints.
        let huge = Task::new(0, 4.0, 0.0, GpuDemand::Whole(64));
        assert!(s.place(&mut dc, &w, &huge).is_none());
        assert_eq!(s.constraint_unschedulable(), 0);
        // Model-set excluding every installed model: constraint failure
        // (vetoed by the gpumodel PreFilter).
        let wrong_model = Task::new(1, 1.0, 0.0, GpuDemand::Whole(1)).with_constraints(
            TaskConstraints {
                gpu_models: vec![crate::cluster::types::GpuModel::T4],
                ..Default::default()
            },
        );
        assert!(s.place(&mut dc, &w, &wrong_model).is_none());
        assert_eq!(s.constraint_unschedulable(), 1);
        // Tenant isolation: fill both nodes with tenant-a, then a
        // tenant-b anti-affine task has resources everywhere but no
        // admissible node.
        let tenant = |key: &str, others: &[&str]| TaskConstraints {
            class_key: Some(key.to_string()),
            anti_affinity: others.iter().map(|s| s.to_string()).collect(),
            ..Default::default()
        };
        for (i, node) in [(10u64, 0usize), (11, 1)] {
            // Whole(4) fills each node's GPUs, so FirstFit advances.
            let t = Task::new(i, 1.0, 0.0, GpuDemand::Whole(4))
                .with_constraints(tenant("tenant-a", &["tenant-b"]));
            let d = s.place(&mut dc, &w, &t).expect("fits");
            assert_eq!(d.node, node);
        }
        // CPU-only tenant-b task: every node has CPU room (resources
        // pass) but hosts tenant-a — a pure constraint failure.
        let tb = Task::new(12, 1.0, 0.0, GpuDemand::Zero)
            .with_constraints(tenant("tenant-b", &["tenant-a"]));
        assert!(s.place(&mut dc, &w, &tb).is_none());
        assert_eq!(s.constraint_unschedulable(), 2);
        // A scheduled task never bumps the counter.
        let ok = Task::new(13, 1.0, 0.0, GpuDemand::Zero);
        assert!(s.place(&mut dc, &w, &ok).is_some());
        assert_eq!(s.constraint_unschedulable(), 2);
    }

    #[test]
    fn metrics_snapshot_merges_registry_and_catalog() {
        let mut dc = dc2();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::FirstFit);
        let t = Task::new(0, 2.0, 512.0, GpuDemand::Whole(1));
        assert!(s.place(&mut dc, &w, &t).is_some());
        let big = Task::new(1, 2.0, 512.0, GpuDemand::Whole(64));
        assert!(s.place(&mut dc, &w, &big).is_none());
        let m = s.metrics();
        assert_eq!(m.counter("sched_places"), 1);
        assert_eq!(m.counter("sched_failures"), 1);
        assert_eq!(m.counter("sched_releases"), 0);
        // Catalog keys are pre-registered even with no hook attached.
        assert_eq!(m.counter("drs_sleeps"), 0);
        assert_eq!(m.counter("repartitions"), 0);
        s.release(&mut dc, &t, 0, &Placement::Whole { gpus: vec![0] });
        assert_eq!(s.metrics().counter("sched_releases"), 1);
        // The shim accessor and the registry agree.
        assert_eq!(s.constraint_unschedulable(), s.metrics().counter("constraint_unschedulable"));
    }

    #[test]
    fn tracer_emits_one_event_per_protocol_entry() {
        use crate::obs::TraceSink;
        use crate::util::json;
        let mut dc = dc2();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::Fgd);
        let sink = TraceSink::memory();
        let label = s.label().to_string();
        s.set_tracer(DecisionTracer::new(sink.clone(), &label, 7));
        let mut placed = Vec::new();
        for i in 0..3 {
            let t = Task::new(i, 2.0, 512.0, GpuDemand::Whole(1));
            let d = s.place(&mut dc, &w, &t).expect("fits");
            placed.push((t, d));
        }
        let (t0, d0) = &placed[0];
        s.release(&mut dc, t0, d0.node, &d0.placement);
        s.trace_flush();
        let text = sink.contents();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        let first = json::parse(lines[0]).expect("valid JSONL");
        assert_eq!(first.get("event").and_then(Json::as_str), Some("place"));
        assert_eq!(first.get("outcome").and_then(Json::as_str), Some("placed"));
        assert_eq!(first.get("policy").and_then(Json::as_str), Some(label.as_str()));
        assert_eq!(first.get("seed").and_then(Json::as_u64), Some(7));
        assert!(!first.get("scores").and_then(Json::as_arr).unwrap().is_empty());
        let last = json::parse(lines[3]).expect("valid JSONL");
        assert_eq!(last.get("event").and_then(Json::as_str), Some("release"));
        assert_eq!(s.metrics().counter("trace_events"), 4);
    }

    #[test]
    fn explain_reports_scoring_table_without_committing() {
        let dc = dc2();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::PwrFgd { alpha: 0.1 });
        let t = Task::new(0, 2.0, 512.0, GpuDemand::Whole(1));
        let ev = s.explain(&dc, &w, &t, 5);
        assert_eq!(ev.get("outcome").and_then(Json::as_str), Some("placed"));
        let scores = ev.get("scores").and_then(Json::as_arr).unwrap();
        assert!(!scores.is_empty());
        assert_eq!(scores[0].get("winner"), Some(&Json::Bool(true)));
        // Nothing committed, nothing counted.
        assert_eq!(dc.gpu_allocated_units(), 0.0);
        assert_eq!(s.metrics().counter("sched_places"), 0);
    }

    #[test]
    fn profiling_accumulates_phase_histograms() {
        let mut dc = dc2();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::FirstFit);
        s.enable_profiling(true);
        let t = Task::new(0, 2.0, 512.0, GpuDemand::Whole(1));
        assert!(s.place(&mut dc, &w, &t).is_some());
        let m = s.metrics();
        for key in
            ["phase_filter_ns", "phase_score_ns", "phase_bind_ns", "phase_hooks_ns", "place_ns"]
        {
            assert_eq!(m.histogram(key).unwrap().count(), 1, "{key} not observed");
        }
    }

    #[test]
    fn caps_cache_keys_on_fleet_revision_not_node_count() {
        let dc_a = ClusterSpec::tiny(2, 4, 0).build();
        let dc_b = ClusterSpec::tiny(2, 8, 0).build();
        assert_eq!(dc_a.nodes.len(), dc_b.nodes.len());
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::FirstFit);
        let t = Task::new(0, 1.0, 0.0, GpuDemand::Whole(1));
        assert!(s.schedule(&dc_a, &w, &t).is_some());
        assert_eq!(s.caps_cache.unwrap().1.max_gpus, 4.0);
        // Same node count, different shapes: the old `len`-keyed cache
        // served dc_a's caps here (the stale-caps regression).
        assert!(s.schedule(&dc_b, &w, &t).is_some());
        assert_eq!(s.caps_cache.unwrap().1.max_gpus, 8.0);
    }

    #[test]
    fn score_cache_reuses_unchanged_nodes() {
        let mut dc = dc2();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::Fgd);
        // First decision: both nodes are first-sight misses.
        let t0 = Task::new(0, 2.0, 512.0, GpuDemand::Whole(1));
        assert!(s.place(&mut dc, &w, &t0).is_some());
        let m0 = s.metrics();
        assert_eq!(m0.counter("score_cache_hits"), 0);
        assert_eq!(m0.counter("score_cache_misses"), 2);
        // Identical demand: only the node the first task landed on
        // (generation bumped) re-scores; the untouched node hits.
        let t1 = Task::new(1, 2.0, 512.0, GpuDemand::Whole(1));
        assert!(s.place(&mut dc, &w, &t1).is_some());
        let m1 = s.metrics();
        assert_eq!(m1.counter("score_cache_hits"), 1);
        assert_eq!(m1.counter("score_cache_misses"), 3);
    }

    #[test]
    fn score_cache_invalidates_on_fleet_swap() {
        // Two same-size fleets: the epoch (workload rev, fleet rev)
        // must split them even though node ids and count coincide.
        let dc_a = ClusterSpec::tiny(2, 4, 0).build();
        let dc_b = ClusterSpec::tiny(2, 8, 0).build();
        let w = Workload::default();
        let t = Task::new(0, 1.0, 0.0, GpuDemand::Whole(1));
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::Fgd);
        assert!(s.schedule(&dc_a, &w, &t).is_some());
        assert!(s.schedule(&dc_b, &w, &t).is_some());
        // All four decisions' node scores were misses (no cross-fleet
        // reuse despite identical generations).
        assert_eq!(s.metrics().counter("score_cache_hits"), 0);
        assert_eq!(s.metrics().counter("score_cache_misses"), 4);
    }

    #[test]
    fn sampled_sweep_places_and_counts() {
        let mut dc = ClusterSpec::tiny(8, 4, 0).build();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::FirstFit);
        s.set_sample_pct(25);
        let t = Task::new(0, 1.0, 0.0, GpuDemand::Whole(1));
        assert!(s.place(&mut dc, &w, &t).is_some());
        assert_eq!(s.metrics().counter("sched_sampled_sweeps"), 1);
    }

    #[test]
    fn sharded_scoring_matches_sequential() {
        // 100 feasible nodes clears SHARD_MIN_WORK, so shards=4 really
        // spawns scoped threads; pure plugins make it bit-identical.
        let w = Workload::default();
        let t = Task::new(0, 1.0, 0.0, GpuDemand::Whole(1));
        let run = |shards: usize, cache: bool| {
            let dc = ClusterSpec::tiny(100, 2, 0).build();
            let mut s =
                Scheduler::from_policy(crate::sched::PolicyKind::PwrFgd { alpha: 0.5 });
            s.set_score_shards(shards);
            s.set_score_cache(cache);
            s.schedule(&dc, &w, &t).expect("fits").node
        };
        let naive = run(1, false);
        assert_eq!(naive, run(4, false));
        assert_eq!(naive, run(4, true));
        assert_eq!(naive, run(1, true));
    }

    #[test]
    fn set_filters_replaces_the_chain() {
        use crate::sched::filter::{FilterCtx, FilterPlugin};
        // A chain rejecting every node makes everything unschedulable.
        struct RejectAll;
        impl FilterPlugin for RejectAll {
            fn name(&self) -> &'static str {
                "reject-all"
            }
            fn feasible(&self, _: &FilterCtx, _: &Node, _: &Task) -> bool {
                false
            }
        }
        let dc = dc2();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(crate::sched::PolicyKind::FirstFit);
        let t = Task::new(0, 1.0, 0.0, GpuDemand::Whole(1));
        assert!(s.schedule(&dc, &w, &t).is_some());
        s.set_filters(vec![Box::new(RejectAll)]);
        assert!(s.schedule(&dc, &w, &t).is_none());
    }
}
