//! The `filter` extension point: declarative feasibility.
//!
//! Pre-redesign, the paper's Filter phase (Algorithm 1 line 4 — Cond.
//! 1–3 plus the model constraint) was a hard-coded `node.can_fit(task)`
//! call inside the scheduler loop, and a task could express exactly one
//! constraint (`Task::gpu_model`). This module turns feasibility into a
//! first-class plugin surface mirroring the `score`/`bind`/`mod`/`hook`
//! registries of [`crate::sched::profile`]:
//!
//! * [`FilterPlugin`] — per-node feasibility plus an optional
//!   **PreFilter** pass: a cheap cluster-wide infeasibility check
//!   (aggregate free capacity, per-constraint candidate counts from
//!   [`Datacenter`]'s static indexes) that lets hopeless tasks skip the
//!   O(nodes) scoring loop entirely, exactly like the k8s PreFilter
//!   extension point.
//! * Built-ins: the legacy `can_fit` lowers to the conjunction
//!   `resources` ∧ `gpumodel` ∧ `miglattice` (placement-equivalent on
//!   constraint-free tasks — pinned by `rust/tests/filter_equivalence.rs`),
//!   and the declarative [`crate::tasks::TaskConstraints`] vocabulary is
//!   enforced by `labels` (node selectors) and `affinity` (class-keyed
//!   affinity / anti-affinity / per-node spread caps).
//! * Profiles select chains via the `filter(...)` DSL section; the
//!   default chain ([`default_filter_chain`]) runs all six built-ins
//!   (including the [`crate::sched::drs::DrsFilter`] power-state
//!   gate), which is a no-op beyond `can_fit` for unconstrained tasks
//!   on an all-`Active` fleet.
//!
//! A plugin reporting [`FilterPlugin::constrains`] for a task enforces
//! one of that task's declarative constraints rather than a resource
//! condition; the scheduler uses this per-cause signal to count tasks
//! that were *unschedulable due to constraints* — some node had the
//! resources but the task's own constraints forbade it (surfaced by
//! the `ext-filters` experiment).
//!
//! Observability ([`crate::obs`]): when decision tracing is on, the
//! scheduler records a per-filter veto count for every decision under
//! **first-rejector attribution** — plugins run in chain order and the
//! first `false` wins the veto, so a node rejected by both `resources`
//! and `labels` counts only against whichever ran first. PreFilter
//! vetoes are reported separately (the node loop never ran).

use crate::cluster::mig::first_fit_start;
use crate::cluster::node::{Node, ResourceView, EPS};
use crate::cluster::Datacenter;
use crate::tasks::{GpuDemand, Task};

/// Context handed to filter plugins (cluster-wide state + indexes).
pub struct FilterCtx<'a> {
    pub dc: &'a Datacenter,
}

/// A feasibility plugin. The scheduler runs every plugin's
/// [`FilterPlugin::pre_filter`] once per task and, when all pass, its
/// [`FilterPlugin::feasible`] once per node; a node is a scoring
/// candidate iff every plugin in the chain accepts it.
pub trait FilterPlugin: Send {
    fn name(&self) -> &'static str;

    /// True when this plugin enforces a *declarative constraint of this
    /// task* (`TaskConstraints`: model sets, node selectors, affinity,
    /// spread caps) rather than a resource condition (Cond. 1–3), a
    /// legacy `Task::gpu_model` pin, or profile-level policy like a
    /// static `labels:` selector. Per-task so attribution is per-cause:
    /// it drives the scheduler's unschedulable-due-to-constraints
    /// counter, which must not count tasks blocked by anything other
    /// than their own declarative constraints.
    fn constrains(&self, _task: &Task) -> bool {
        false
    }

    /// Cheap cluster-wide pre-check (k8s PreFilter): return `false`
    /// only when **no node can possibly pass** [`Self::feasible`] for
    /// this task — the scheduler then fails the task without touching
    /// the node loop. Must be conservative: a `false` here and a
    /// feasible node somewhere would change placements.
    fn pre_filter(&self, _ctx: &FilterCtx, _task: &Task) -> bool {
        true
    }

    /// Per-node feasibility.
    fn feasible(&self, ctx: &FilterCtx, node: &Node, task: &Task) -> bool;
}

/// Cond. 1 (CPU), Cond. 2 (MEM) and Cond. 3 (GPU quantity/shape) —
/// everything of the legacy `can_fit` except the model constraint,
/// which [`GpuModelFilter`] owns. PreFilter: aggregate free capacity
/// (an upper bound on any single node's free capacity, so the check is
/// conservative by construction).
pub struct ResourcesFilter;

impl FilterPlugin for ResourcesFilter {
    fn name(&self) -> &'static str {
        "resources"
    }

    fn pre_filter(&self, ctx: &FilterCtx, task: &Task) -> bool {
        if task.cpu > ctx.dc.cpu_free_total() + EPS {
            return false;
        }
        if task.mem > ctx.dc.mem_free_total() + EPS {
            return false;
        }
        task.gpu.units() <= ctx.dc.gpu_free_units() + EPS
    }

    fn feasible(&self, _ctx: &FilterCtx, node: &Node, task: &Task) -> bool {
        if task.cpu > node.cpu_free() + EPS {
            return false; // Cond. 1
        }
        if task.mem > node.mem_free() + EPS {
            return false; // Cond. 2
        }
        match task.gpu {
            GpuDemand::Zero => true,
            _ if node.gpu_model.is_none() => false,
            GpuDemand::Frac(d) => !node.is_mig() && node.largest_free() >= d - EPS,
            GpuDemand::Whole(k) => !node.is_mig() && node.gpus_fully_free() >= k as usize,
            GpuDemand::Mig(p) => {
                node.mig_lattice() == Some(p.lattice())
                    && (0..node.n_gpus()).any(|g| {
                        node.mig_mask_of(g).is_some_and(|m| first_fit_start(m, p).is_some())
                    })
            }
        }
    }
}

/// The GPU-model constraint: the legacy single-model pin
/// (`Task::gpu_model`) plus the declarative model *set*
/// (`TaskConstraints::gpu_models`). PreFilter: the cluster's static
/// per-model node counts.
pub struct GpuModelFilter;

impl FilterPlugin for GpuModelFilter {
    fn name(&self) -> &'static str {
        "gpumodel"
    }

    fn constrains(&self, task: &Task) -> bool {
        // Only the declarative model *set* counts as a constraint of
        // the task; the legacy pin is classed with the resource
        // conditions for attribution purposes.
        task.gpu.is_gpu()
            && task.constraints.as_deref().is_some_and(|c| !c.gpu_models.is_empty())
    }

    fn pre_filter(&self, ctx: &FilterCtx, task: &Task) -> bool {
        if !task.gpu.is_gpu() {
            return true;
        }
        if let Some(m) = task.gpu_model {
            if ctx.dc.nodes_with_model(m) == 0 {
                return false;
            }
        }
        if let Some(c) = task.constraints.as_deref() {
            if !c.gpu_models.is_empty()
                && c.gpu_models.iter().all(|&m| ctx.dc.nodes_with_model(m) == 0)
            {
                return false;
            }
        }
        true
    }

    fn feasible(&self, _ctx: &FilterCtx, node: &Node, task: &Task) -> bool {
        if !task.gpu.is_gpu() {
            return true; // legacy semantics: CPU-only tasks ignore C_t^GPU
        }
        let Some(model) = node.gpu_model else { return false };
        if let Some(required) = task.gpu_model {
            if required != model {
                return false;
            }
        }
        if let Some(c) = task.constraints.as_deref() {
            if !c.gpu_models.is_empty() && !c.gpu_models.contains(&model) {
                return false;
            }
        }
        true
    }
}

/// MIG lattice compatibility: a slice demand only fits nodes partitioned
/// with the profile's lattice. (Also enforced by [`ResourcesFilter`]'s
/// quantity check; kept as a named plugin so custom chains can reason
/// about lattice placement separately.) PreFilter: static per-lattice
/// node counts.
pub struct MigLatticeFilter;

impl FilterPlugin for MigLatticeFilter {
    fn name(&self) -> &'static str {
        "miglattice"
    }

    fn pre_filter(&self, ctx: &FilterCtx, task: &Task) -> bool {
        match task.gpu {
            GpuDemand::Mig(p) => ctx.dc.nodes_with_lattice(p.lattice()) > 0,
            _ => true,
        }
    }

    fn feasible(&self, _ctx: &FilterCtx, node: &Node, task: &Task) -> bool {
        match task.gpu {
            GpuDemand::Mig(p) => node.mig_lattice() == Some(p.lattice()),
            _ => true,
        }
    }
}

/// Node-label selection: the task's `node_selector` pairs plus an
/// optional chain-level static `selector` (from `filter(labels:k=v)`)
/// must all be present on the node. PreFilter: static per-label node
/// counts.
pub struct LabelsFilter {
    /// Profile-level selector ANDed with every task's own selector
    /// (scheduler-wide node restriction; empty = none).
    pub selector: Vec<(String, String)>,
}

impl FilterPlugin for LabelsFilter {
    fn name(&self) -> &'static str {
        "labels"
    }

    fn constrains(&self, task: &Task) -> bool {
        // The chain-level static selector is profile policy, not a task
        // constraint — only the task's own node_selector attributes.
        task.constraints.as_deref().is_some_and(|c| !c.node_selector.is_empty())
    }

    fn pre_filter(&self, ctx: &FilterCtx, task: &Task) -> bool {
        let task_selector = task
            .constraints
            .as_deref()
            .map(|c| c.node_selector.iter())
            .into_iter()
            .flatten();
        self.selector
            .iter()
            .chain(task_selector)
            .all(|(k, v)| ctx.dc.nodes_with_label(k, v) > 0)
    }

    fn feasible(&self, _ctx: &FilterCtx, node: &Node, task: &Task) -> bool {
        if !self.selector.iter().all(|(k, v)| node.has_label(k, v)) {
            return false;
        }
        match task.constraints.as_deref() {
            Some(c) => c.node_selector.iter().all(|(k, v)| node.has_label(k, v)),
            None => true,
        }
    }
}

/// Class-keyed inter-task rules: anti-affinity (reject nodes hosting
/// listed classes — tenant isolation), affinity (require a node already
/// hosting one of the listed classes) and the per-node spread cap on
/// the task's own class. PreFilter: a `max_per_node` of 0 and affinity
/// to classes with no resident task anywhere are both unsatisfiable.
pub struct AffinityFilter;

impl FilterPlugin for AffinityFilter {
    fn name(&self) -> &'static str {
        "affinity"
    }

    fn constrains(&self, task: &Task) -> bool {
        task.constraints.as_deref().is_some_and(|c| {
            !c.anti_affinity.is_empty()
                || !c.affinity.is_empty()
                || (c.max_per_node.is_some() && c.class_key.is_some())
        })
    }

    fn pre_filter(&self, ctx: &FilterCtx, task: &Task) -> bool {
        let Some(c) = task.constraints.as_deref() else { return true };
        // The spread cap only binds when the task names a class —
        // `feasible` ignores it otherwise, and PreFilter must never be
        // stricter than the per-node pass.
        if c.max_per_node == Some(0) && c.class_key.is_some() {
            return false;
        }
        c.affinity.is_empty() || c.affinity.iter().any(|k| ctx.dc.class_resident(k) > 0)
    }

    fn feasible(&self, _ctx: &FilterCtx, node: &Node, task: &Task) -> bool {
        let Some(c) = task.constraints.as_deref() else { return true };
        if c.anti_affinity.iter().any(|k| node.class_count(k) > 0) {
            return false;
        }
        if !c.affinity.is_empty() && !c.affinity.iter().any(|k| node.class_count(k) > 0) {
            return false;
        }
        if let (Some(max), Some(key)) = (c.max_per_node, c.class_key.as_ref()) {
            if node.class_count(key) >= max {
                return false;
            }
        }
        true
    }
}

/// The default chain every profile gets unless it names an explicit
/// `filter(...)` section: the `can_fit` decomposition plus the
/// constraint plugins (no-ops for unconstrained tasks, so legacy
/// placements are bit-identical) plus the `drs` power-state gate (a
/// no-op while every node is `Active`, i.e. whenever no DRS hook is
/// attached — same bit-identity argument, pinned by
/// `rust/tests/drs_equivalence.rs`) plus the `gang` aggregate PreFilter
/// (a no-op for every non-gang task, pinned by
/// `rust/tests/gang_equivalence.rs`).
pub fn default_filter_chain() -> Vec<Box<dyn FilterPlugin>> {
    vec![
        Box::new(ResourcesFilter),
        Box::new(GpuModelFilter),
        Box::new(MigLatticeFilter),
        Box::new(LabelsFilter { selector: Vec::new() }),
        Box::new(AffinityFilter),
        Box::new(crate::sched::drs::DrsFilter),
        Box::new(crate::sched::gang::GangFilter),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::mig::MigProfile;
    use crate::cluster::types::GpuModel;
    use crate::cluster::ClusterSpec;
    use crate::tasks::TaskConstraints;

    fn gpu_task(id: u64) -> Task {
        Task::new(id, 2.0, 1024.0, GpuDemand::Whole(1))
    }

    /// The default chain's per-node verdict must equal `can_fit` for
    /// every legacy (constraint-free / model-pinned) task shape.
    #[test]
    fn default_chain_equals_can_fit() {
        let mut dc = ClusterSpec::tiny(2, 4, 1).build();
        // Load node 0 partially so verdicts vary.
        let filler = Task::new(90, 90.0, 0.0, GpuDemand::Frac(0.75));
        dc.allocate(&filler, 0, &crate::cluster::node::Placement::Shared { gpu: 0 });
        let chain = default_filter_chain();
        let tasks = [
            Task::new(0, 4.0, 1024.0, GpuDemand::Zero),
            Task::new(1, 4.0, 1024.0, GpuDemand::Frac(0.5)),
            Task::new(2, 4.0, 1024.0, GpuDemand::Whole(2)),
            Task::new(3, 200.0, 0.0, GpuDemand::Zero),
            Task::new(4, 4.0, 1024.0, GpuDemand::Mig(MigProfile::P2g)),
            gpu_task(5).constrained(GpuModel::G2),
            gpu_task(6).constrained(GpuModel::T4),
        ];
        let ctx = FilterCtx { dc: &dc };
        for t in &tasks {
            for node in &dc.nodes {
                let chain_ok = chain.iter().all(|f| f.feasible(&ctx, node, t));
                assert_eq!(
                    chain_ok,
                    node.can_fit(t),
                    "task {} on node {} diverged from can_fit",
                    t.id,
                    node.id
                );
            }
        }
    }

    #[test]
    fn prefilter_rejects_cluster_wide_infeasible() {
        let dc = ClusterSpec::tiny(2, 4, 0).build();
        let ctx = FilterCtx { dc: &dc };
        // More CPU than the whole cluster has.
        assert!(!ResourcesFilter.pre_filter(&ctx, &Task::new(0, 10_000.0, 0.0, GpuDemand::Zero)));
        // More GPUs than installed.
        assert!(!ResourcesFilter.pre_filter(&ctx, &Task::new(1, 1.0, 0.0, GpuDemand::Whole(9))));
        // Feasible demand passes.
        assert!(ResourcesFilter.pre_filter(&ctx, &gpu_task(2)));
        // Model with zero nodes (single pin and full set).
        assert!(!GpuModelFilter.pre_filter(&ctx, &gpu_task(3).constrained(GpuModel::T4)));
        let set = TaskConstraints {
            gpu_models: vec![GpuModel::T4, GpuModel::P100],
            ..Default::default()
        };
        assert!(!GpuModelFilter.pre_filter(&ctx, &gpu_task(4).with_constraints(set)));
        let ok_set = TaskConstraints {
            gpu_models: vec![GpuModel::T4, GpuModel::G2],
            ..Default::default()
        };
        assert!(GpuModelFilter.pre_filter(&ctx, &gpu_task(5).with_constraints(ok_set)));
        // No MIG nodes at all.
        assert!(!MigLatticeFilter
            .pre_filter(&ctx, &Task::new(6, 1.0, 0.0, GpuDemand::Mig(MigProfile::P1g))));
        // Selector nobody carries.
        let sel = TaskConstraints {
            node_selector: vec![("zone".to_string(), "z9".to_string())],
            ..Default::default()
        };
        let labels = LabelsFilter { selector: Vec::new() };
        assert!(!labels.pre_filter(&ctx, &gpu_task(7).with_constraints(sel)));
        // Spread cap of zero / affinity to an absent class.
        let zero = TaskConstraints {
            class_key: Some("a".to_string()),
            max_per_node: Some(0),
            ..Default::default()
        };
        assert!(!AffinityFilter.pre_filter(&ctx, &gpu_task(8).with_constraints(zero)));
        let aff = TaskConstraints {
            affinity: vec!["nobody".to_string()],
            ..Default::default()
        };
        assert!(!AffinityFilter.pre_filter(&ctx, &gpu_task(9).with_constraints(aff)));
    }

    #[test]
    fn model_set_accepts_any_listed_model() {
        let dc = ClusterSpec::tiny(1, 4, 0).build(); // G2 nodes
        let ctx = FilterCtx { dc: &dc };
        let node = &dc.nodes[0];
        let yes = TaskConstraints {
            gpu_models: vec![GpuModel::T4, GpuModel::G2],
            ..Default::default()
        };
        let no = TaskConstraints {
            gpu_models: vec![GpuModel::T4, GpuModel::P100],
            ..Default::default()
        };
        assert!(GpuModelFilter.feasible(&ctx, node, &gpu_task(0).with_constraints(yes)));
        assert!(!GpuModelFilter.feasible(&ctx, node, &gpu_task(1).with_constraints(no)));
        // CPU-only tasks ignore the model set entirely.
        let cpu = Task::new(2, 1.0, 0.0, GpuDemand::Zero).with_constraints(TaskConstraints {
            gpu_models: vec![GpuModel::T4],
            ..Default::default()
        });
        assert!(GpuModelFilter.feasible(&ctx, node, &cpu));
    }

    #[test]
    fn affinity_rules_read_class_counts() {
        let mut dc = ClusterSpec::tiny(2, 4, 0).build();
        let a = TaskConstraints {
            class_key: Some("tenant-a".to_string()),
            ..Default::default()
        };
        let resident = Task::new(0, 1.0, 0.0, GpuDemand::Frac(0.25)).with_constraints(a);
        dc.allocate(&resident, 0, &crate::cluster::node::Placement::Shared { gpu: 0 });
        let ctx = FilterCtx { dc: &dc };
        // Anti-affinity to tenant-a: node 0 rejected, node 1 fine.
        let anti = TaskConstraints {
            class_key: Some("tenant-b".to_string()),
            anti_affinity: vec!["tenant-a".to_string()],
            ..Default::default()
        };
        let t = gpu_task(1).with_constraints(anti);
        assert!(!AffinityFilter.feasible(&ctx, &dc.nodes[0], &t));
        assert!(AffinityFilter.feasible(&ctx, &dc.nodes[1], &t));
        // Affinity to tenant-a: only node 0 qualifies.
        let aff = TaskConstraints {
            affinity: vec!["tenant-a".to_string()],
            ..Default::default()
        };
        let t = gpu_task(2).with_constraints(aff);
        assert!(AffinityFilter.feasible(&ctx, &dc.nodes[0], &t));
        assert!(!AffinityFilter.feasible(&ctx, &dc.nodes[1], &t));
        assert!(AffinityFilter.pre_filter(&ctx, &t));
        // Spread cap: tenant-a already has 1 resident on node 0.
        let spread = TaskConstraints {
            class_key: Some("tenant-a".to_string()),
            max_per_node: Some(1),
            ..Default::default()
        };
        let t = gpu_task(3).with_constraints(spread);
        assert!(!AffinityFilter.feasible(&ctx, &dc.nodes[0], &t));
        assert!(AffinityFilter.feasible(&ctx, &dc.nodes[1], &t));
    }

    #[test]
    fn labels_filter_static_and_task_selectors() {
        let dc = ClusterSpec::tiny(4, 2, 0).with_zones(2).build();
        let ctx = FilterCtx { dc: &dc };
        let plain = LabelsFilter { selector: Vec::new() };
        let pinned = LabelsFilter {
            selector: vec![("zone".to_string(), "z0".to_string())],
        };
        let free = gpu_task(0);
        assert!(plain.feasible(&ctx, &dc.nodes[1], &free));
        // Static selector restricts every task.
        assert!(pinned.feasible(&ctx, &dc.nodes[0], &free));
        assert!(!pinned.feasible(&ctx, &dc.nodes[1], &free));
        // Task selector composes on top.
        let z1 = TaskConstraints {
            node_selector: vec![("zone".to_string(), "z1".to_string())],
            ..Default::default()
        };
        let t = gpu_task(1).with_constraints(z1);
        assert!(plain.feasible(&ctx, &dc.nodes[1], &t));
        assert!(!plain.feasible(&ctx, &dc.nodes[0], &t));
        assert!(plain.pre_filter(&ctx, &t));
    }
}
