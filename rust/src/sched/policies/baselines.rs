//! Packing baselines: Best-fit (Protean [6]) and Dot-product
//! (Tetris [4]), as implemented in the open-simulator the paper uses.

use crate::cluster::node::{Node, Placement, ResourceView};
use crate::sched::framework::{SchedCtx, ScorePlugin};
use crate::tasks::{GpuDemand, Task};

/// Best-fit: assign to the node with the least remaining resources
/// after the (hypothetical) placement, computed as a weighted sum over
/// the resource dimensions, each normalized by the largest node shape.
pub struct BestFitPlugin;

/// Dimension weights for Best-fit (CPU and GPU dominate the paper's
/// cluster economics; memory is secondary).
const W_CPU: f64 = 1.0;
const W_GPU: f64 = 1.0;
const W_MEM: f64 = 0.25;

impl ScorePlugin for BestFitPlugin {
    fn name(&self) -> &'static str {
        "BestFit"
    }

    fn score(&self, ctx: &SchedCtx, node: &Node, task: &Task, _placements: &[Placement]) -> f64 {
        // Remaining after placement, normalized by the largest shapes.
        let cpu_left = (node.cpu_free() - task.cpu) / ctx.caps.max_vcpus;
        let mem_left = (node.mem_free() - task.mem) / ctx.caps.max_mem;
        let gpu_left = (node.gpu_free_total() - task.gpu.units()) / ctx.caps.max_gpus;
        let remaining = W_CPU * cpu_left + W_MEM * mem_left + W_GPU * gpu_left;
        -remaining // least remaining wins
    }
}

/// Dot-product: assign to the node with the *smallest* dot product
/// between the node's available resource vector and the task's demand
/// vector (per the paper's §V description), dimensions normalized by
/// the largest node shape.
pub struct DotProdPlugin;

impl ScorePlugin for DotProdPlugin {
    fn name(&self) -> &'static str {
        "DotProd"
    }

    fn score(&self, ctx: &SchedCtx, node: &Node, task: &Task, _placements: &[Placement]) -> f64 {
        let avail = [
            node.cpu_free() / ctx.caps.max_vcpus,
            node.mem_free() / ctx.caps.max_mem,
            node.gpu_free_total() / ctx.caps.max_gpus,
        ];
        let demand = [
            task.cpu / ctx.caps.max_vcpus,
            task.mem / ctx.caps.max_mem,
            task.gpu.units() / ctx.caps.max_gpus,
        ];
        let dot: f64 = avail.iter().zip(&demand).map(|(a, d)| a * d).sum();
        -dot
    }
}

/// Helper shared by tests: does the task ask for any GPU at all?
#[allow(dead_code)]
fn is_gpu_task(task: &Task) -> bool {
    !matches!(task.gpu, GpuDemand::Zero)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::sched::{PolicyKind, Scheduler};
    use crate::tasks::Workload;

    /// Best-fit packs: after one allocation the fuller node wins the
    /// next task.
    #[test]
    fn bestfit_prefers_fuller_node() {
        let mut dc = ClusterSpec::tiny(2, 4, 0).build();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::BestFit);
        let t0 = Task::new(0, 8.0, 1024.0, GpuDemand::Whole(1));
        let d0 = s.schedule(&dc, &w, &t0).unwrap();
        dc.allocate(&t0, d0.node, &d0.placement);
        s.notify_node_changed(d0.node);
        let t1 = Task::new(1, 8.0, 1024.0, GpuDemand::Whole(1));
        let d1 = s.schedule(&dc, &w, &t1).unwrap();
        assert_eq!(d1.node, d0.node);
    }

    /// DotProd avoids nodes with large aligned availability: an empty
    /// big node scores worse than a nearly-full one.
    #[test]
    fn dotprod_picks_smallest_alignment() {
        let mut dc = ClusterSpec::tiny(2, 4, 0).build();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::DotProd);
        // Fill most of node 1's GPUs.
        let filler = Task::new(0, 4.0, 1024.0, GpuDemand::Whole(3));
        let p = dc.nodes[1].candidate_placements(&filler).pop().unwrap();
        dc.allocate(&filler, 1, &p);
        s.notify_node_changed(1);
        let t = Task::new(1, 2.0, 512.0, GpuDemand::Whole(1));
        let d = s.schedule(&dc, &w, &t).unwrap();
        assert_eq!(d.node, 1, "smaller availability·demand dot product");
    }

    /// CPU-only tasks are also packed (GPU dimension is zero).
    #[test]
    fn bestfit_cpu_only() {
        let mut dc = ClusterSpec::tiny(1, 2, 2).build();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::BestFit);
        let t0 = Task::new(0, 50.0, 1024.0, GpuDemand::Zero);
        // CPU-only nodes have 94 vCPU vs the GPU node's 96 but zero GPUs:
        // the GPU term makes CPU-only nodes the best fit.
        let d0 = s.schedule(&dc, &w, &t0).unwrap();
        assert!(dc.nodes[d0.node].gpu_model.is_none());
        dc.allocate(&t0, d0.node, &d0.placement);
        s.notify_node_changed(d0.node);
        let t1 = Task::new(1, 20.0, 512.0, GpuDemand::Zero);
        let d1 = s.schedule(&dc, &w, &t1).unwrap();
        assert_eq!(d1.node, d0.node, "packs onto the fuller CPU node");
    }
}
