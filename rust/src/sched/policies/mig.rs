//! The MIG-aware policy family and the greedy online repartitioner.
//!
//! The cluster, fragmentation and power layers are slice-aware (see
//! [`crate::cluster::mig`], [`crate::frag`], [`crate::power`]), so the
//! existing PWR/FGD/BestFit score plugins transparently evaluate
//! `(node, GPU, profile, start)` placements; the
//! [`crate::sched::PolicyKind`] `Mig*` variants wire them with
//! slice-aware binders. This module adds the two genuinely new pieces:
//!
//! * [`MigSliceFitPlugin`] — slice-granular packing: prefer the node
//!   whose best candidate GPU is left with the fewest free slices,
//!   nudged toward GPUs that are already powered (Eq. 2-MIG makes those
//!   strictly cheaper to extend).
//! * [`MigRepartitioner`] — a greedy online defragmenter with two
//!   triggers, attached to the framework as a
//!   [`PostHook`] (`hook(repartition:…)` in the profile DSL — the
//!   k8s-preemption analog; [`crate::sched::Scheduler::place`] drives
//!   it, so no simulation loop can silently skip defrag):
//!   - **reactive** (PR 1, now the `postFail` extension point): when a
//!     MIG task cannot be placed anywhere, find the cheapest single-GPU
//!     repack (first-fit-decreasing over the partition lattice) that
//!     opens a legal start for the profile, apply it, and let the
//!     scheduler retry;
//!   - **proactive** (threshold-driven, Lipe et al.'s dynamic
//!     repartitioning; the `postPlace` extension point): after a node's
//!     allocation changes, repack any of its GPUs whose
//!     slice-fragmentation ratio
//!     ([`crate::cluster::mig::MigGpu::frag_ratio`]) reached
//!     [`RepartitionConfig::frag_threshold`] — defragmenting *ahead of
//!     demand* instead of waiting for a placement failure. The default
//!     threshold is `∞`, which disables the proactive mode and
//!     reproduces the failure-only behavior exactly.
//!
//!   Each repack migrates running instances between slice offsets; the
//!   configurable migration cost caps how many slices one event may
//!   move and how many may move over a whole run (shared between both
//!   triggers), mirroring the repartitioning budget of Lipe et al.

use crate::cluster::mig::MigProfile;
use crate::cluster::node::{Node, Placement, ResourceView, EPS};
use crate::cluster::Datacenter;
use crate::sched::framework::{PostHook, SchedCtx, ScorePlugin};
use crate::tasks::{GpuDemand, Task};

/// Slice-granular packing plugin (see module docs).
pub struct MigSliceFitPlugin;

/// Score bonus for extending an already-powered GPU (in free-slice
/// units; one slice is 1/7 ≈ 0.143, so this breaks equal-residual ties
/// without overriding a one-slice packing difference).
const POWERED_BONUS: f64 = 0.05;

impl ScorePlugin for MigSliceFitPlugin {
    fn name(&self) -> &'static str {
        "MIG-SliceFit"
    }

    fn score(&self, _ctx: &SchedCtx, node: &Node, task: &Task, placements: &[Placement]) -> f64 {
        let mut best = f64::NEG_INFINITY;
        for p in placements {
            let s = match p {
                Placement::MigSlice { gpu, .. } => {
                    let left = node.gpu_free_of(*gpu) - task.gpu.units();
                    let powered = node.gpu_alloc[*gpu] > EPS;
                    -left + if powered { POWERED_BONUS } else { 0.0 }
                }
                // Non-MIG placements (CPU-only tasks routed through
                // this plugin): neutral.
                _ => 0.0,
            };
            if s > best {
                best = s;
            }
        }
        best
    }
}

/// Migration-cost model for online repartitioning.
#[derive(Clone, Copy, Debug)]
pub struct RepartitionConfig {
    /// Most slices one repack may migrate (a 7-slice GPU can move at
    /// most 6 — something must stay for the repack to matter).
    pub max_moved_slices: u32,
    /// Total slice-migration budget for the run; `u64::MAX` ⇒ unbounded.
    pub budget_slices: u64,
    /// Proactive trigger: repack a GPU whose slice-fragmentation ratio
    /// ([`crate::cluster::mig::MigGpu::frag_ratio`]) reaches this value.
    /// `f64::INFINITY` (the default) disables proactive repartitioning —
    /// the repartitioner then fires only on placement failures, exactly
    /// reproducing the PR 1 behavior.
    pub frag_threshold: f64,
}

impl Default for RepartitionConfig {
    fn default() -> Self {
        RepartitionConfig {
            max_moved_slices: 6,
            budget_slices: u64::MAX,
            frag_threshold: f64::INFINITY,
        }
    }
}

impl RepartitionConfig {
    /// Default caps with a proactive fragmentation threshold.
    pub fn with_threshold(frag_threshold: f64) -> RepartitionConfig {
        RepartitionConfig { frag_threshold, ..Default::default() }
    }
}

/// Cumulative repartitioning activity.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RepartitionStats {
    /// Reactive (placement-failure-triggered) repacks applied.
    pub repartitions: u64,
    /// Proactive (frag-threshold-triggered) repacks applied.
    pub proactive_repartitions: u64,
    /// Slices migrated across all repacks (both triggers; the
    /// [`RepartitionConfig::budget_slices`] budget is shared).
    pub migrated_slices: u64,
    /// Placement failures no affordable repack could fix.
    pub exhausted: u64,
}

/// Greedy online repartitioner (see module docs).
#[derive(Clone, Debug, Default)]
pub struct MigRepartitioner {
    pub cfg: RepartitionConfig,
    pub stats: RepartitionStats,
}

impl MigRepartitioner {
    pub fn new(cfg: RepartitionConfig) -> MigRepartitioner {
        MigRepartitioner { cfg, stats: RepartitionStats::default() }
    }

    /// Try to open room for `task` (a MIG demand) somewhere in the
    /// datacenter: among all nodes where the task fits on CPU/MEM and
    /// the model constraint, pick the GPU whose repack migrates the
    /// fewest slices, apply it, and return the node id (the caller must
    /// `notify_node_changed` and re-run the scheduler). `None` when the
    /// demand is not MIG, nothing needs or affords a repack, or the
    /// migration budget is exhausted.
    pub fn try_make_room(&mut self, dc: &mut Datacenter, task: &Task) -> Option<usize> {
        let GpuDemand::Mig(profile) = task.gpu else { return None };
        let best = self.cheapest_repack(dc, task, profile);
        match best {
            Some((node_id, gpu, plan, moved)) => {
                dc.nodes[node_id].mig_apply_repack(gpu, &plan);
                self.stats.repartitions += 1;
                self.stats.migrated_slices += moved as u64;
                Some(node_id)
            }
            None => {
                self.stats.exhausted += 1;
                None
            }
        }
    }

    /// Proactive pass over one node (call after its allocation
    /// changed): for every GPU whose slice-fragmentation ratio reached
    /// [`RepartitionConfig::frag_threshold`], plan the FFD repack that
    /// opens a legal start for the *widest profile still fitting* its
    /// free capacity ([`crate::cluster::mig::MigGpu::repack_plan`]) and
    /// apply it when it strictly lowers the ratio and fits the
    /// migration-cost caps. Returns `true` when any repack was applied
    /// (the caller must `notify_node_changed`). A non-finite threshold
    /// (the default) makes this a no-op — failure-only behavior.
    pub fn defrag_node_if_fragmented(&mut self, dc: &mut Datacenter, node_id: usize) -> bool {
        if !self.cfg.frag_threshold.is_finite() {
            return false;
        }
        let Some(n_gpus) = dc.nodes[node_id].mig.as_ref().map(|m| m.len()) else {
            return false;
        };
        let mut applied = false;
        for g in 0..n_gpus {
            let budget_left = self
                .cfg
                .budget_slices
                .saturating_sub(self.stats.migrated_slices);
            let mg = &dc.nodes[node_id].mig.as_ref().unwrap()[g];
            let ratio = mg.frag_ratio();
            if ratio < self.cfg.frag_threshold {
                continue;
            }
            let Some(target) = mg.lattice.widest_fitting(mg.free_slices()) else {
                continue;
            };
            let Some((plan, moved)) = mg.repack_plan(target) else { continue };
            if moved == 0 || moved > self.cfg.max_moved_slices || (moved as u64) > budget_left {
                continue;
            }
            // Only pay the migration cost when it actually helps.
            let mut after = mg.clone();
            after.apply_repack(&plan);
            if after.frag_ratio() + 1e-12 >= ratio {
                continue;
            }
            dc.nodes[node_id].mig_apply_repack(g, &plan);
            self.stats.proactive_repartitions += 1;
            self.stats.migrated_slices += moved as u64;
            applied = true;
        }
        applied
    }

    /// The cheapest affordable repack candidate, if any.
    fn cheapest_repack(
        &self,
        dc: &Datacenter,
        task: &Task,
        profile: MigProfile,
    ) -> Option<(usize, usize, Vec<(usize, u8)>, u32)> {
        let budget_left = self
            .cfg
            .budget_slices
            .saturating_sub(self.stats.migrated_slices);
        let mut best: Option<(usize, usize, Vec<(usize, u8)>, u32)> = None;
        for node in &dc.nodes {
            let Some(migs) = &node.mig else { continue };
            if task.cpu > node.cpu_free() + EPS || task.mem > node.mem_free() + EPS {
                continue;
            }
            if let Some(required) = task.gpu_model {
                if node.gpu_model != Some(required) {
                    continue;
                }
            }
            for (g, mg) in migs.iter().enumerate() {
                if mg.can_place(profile).is_some() {
                    // The scheduler can already use this GPU; a repack
                    // would be pointless.
                    continue;
                }
                if mg.free_slices() < profile.slices() {
                    continue;
                }
                if let Some((plan, moved)) = mg.repack_plan(profile) {
                    let affordable = moved > 0
                        && moved <= self.cfg.max_moved_slices
                        && (moved as u64) <= budget_left;
                    let better = match &best {
                        None => true,
                        Some(b) => moved < b.3,
                    };
                    if affordable && better {
                        best = Some((node.id, g, plan, moved));
                    }
                }
            }
        }
        best
    }
}

/// The framework wiring: the repartitioner *is* a `postFail`/`postPlace`
/// hook. [`crate::sched::Scheduler::place`] runs `post_fail` on a
/// scheduling failure (repack-and-retry) and `post_place` after every
/// allocation change (threshold-driven proactive defrag), in both the
/// inflation ([`crate::sim::Simulation`]) and churn
/// ([`crate::sim::events::SteadySim`]) loops — structurally, not by
/// each loop remembering to call it.
impl PostHook for MigRepartitioner {
    fn name(&self) -> &'static str {
        "repartition"
    }

    fn post_fail(
        &mut self,
        dc: &mut Datacenter,
        task: &Task,
        invalidate: &mut dyn FnMut(usize),
    ) -> bool {
        match self.try_make_room(dc, task) {
            Some(node_id) => {
                invalidate(node_id);
                true
            }
            None => false,
        }
    }

    fn post_place(&mut self, dc: &mut Datacenter, node_id: usize, invalidate: &mut dyn FnMut(usize)) {
        if self.defrag_node_if_fragmented(dc, node_id) {
            invalidate(node_id);
        }
    }

    fn counters(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("repartitions", self.stats.repartitions),
            ("proactive_repartitions", self.stats.proactive_repartitions),
            ("migrated_slices", self.stats.migrated_slices),
            ("exhausted", self.stats.exhausted),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::sched::{PolicyKind, Scheduler};
    use crate::tasks::Workload;

    fn mig_task(id: u64, p: MigProfile) -> Task {
        Task::new(id, 2.0, 1024.0, GpuDemand::Mig(p))
    }

    #[test]
    fn mig_policies_schedule_slice_tasks() {
        let dc = ClusterSpec::mig_cluster(4, 4, 0).build();
        let w = Workload::default();
        for kind in [
            PolicyKind::MigBestFit,
            PolicyKind::MigSliceFit,
            PolicyKind::MigFgd,
            PolicyKind::MigPwr,
            PolicyKind::MigPwrFgd { alpha: 0.1 },
        ] {
            let mut s = Scheduler::from_policy(kind);
            let d = s.schedule(&dc, &w, &mig_task(0, MigProfile::P3g)).expect("fits");
            assert!(matches!(d.placement, Placement::MigSlice { .. }));
            assert!(dc.nodes[d.node].placement_fits(&mig_task(0, MigProfile::P3g), &d.placement));
        }
    }

    #[test]
    fn slicefit_packs_partial_gpu() {
        let mut dc = ClusterSpec::mig_cluster(2, 2, 0).build();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::MigSliceFit);
        let t0 = mig_task(0, MigProfile::P3g);
        let d0 = s.schedule(&dc, &w, &t0).unwrap();
        dc.allocate(&t0, d0.node, &d0.placement);
        s.notify_node_changed(d0.node);
        // Next 2g should land on the same, already-partial GPU.
        let t1 = mig_task(1, MigProfile::P2g);
        let d1 = s.schedule(&dc, &w, &t1).unwrap();
        assert_eq!(d1.node, d0.node);
        let (Placement::MigSlice { gpu: g0, .. }, Placement::MigSlice { gpu: g1, .. }) =
            (&d0.placement, &d1.placement)
        else {
            panic!("expected slice placements");
        };
        assert_eq!(g0, g1, "slice-fit must extend the partial GPU");
    }

    #[test]
    fn repartitioner_defragments_for_a_blocked_profile() {
        // One node, one GPU: {3g@0, 2g@4} blocks a 2g although 2 slices
        // are free. The repartitioner must repack and unblock it.
        let mut dc = ClusterSpec::mig_cluster(1, 1, 0).build();
        let t3 = mig_task(1, MigProfile::P3g);
        let t2 = mig_task(2, MigProfile::P2g);
        dc.allocate(&t3, 0, &Placement::MigSlice { gpu: 0, start: 0 });
        dc.allocate(&t2, 0, &Placement::MigSlice { gpu: 0, start: 4 });
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::MigFgd);
        let blocked = mig_task(3, MigProfile::P2g);
        assert!(s.schedule(&dc, &w, &blocked).is_none(), "should be blocked pre-repack");
        let mut rp = MigRepartitioner::new(RepartitionConfig::default());
        let nid = rp.try_make_room(&mut dc, &blocked).expect("repack possible");
        assert_eq!(nid, 0);
        s.notify_node_changed(nid);
        let d = s.schedule(&dc, &w, &blocked).expect("fits after repack");
        dc.allocate(&blocked, d.node, &d.placement);
        assert_eq!(rp.stats.repartitions, 1);
        assert!(rp.stats.migrated_slices > 0);
        // GPU is now exactly full: 3 + 2 + 2 slices.
        assert!((dc.nodes[0].gpu_alloc[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn repartitioner_respects_cost_caps() {
        let mut dc = ClusterSpec::mig_cluster(1, 1, 0).build();
        let t3 = mig_task(1, MigProfile::P3g);
        let t2 = mig_task(2, MigProfile::P2g);
        dc.allocate(&t3, 0, &Placement::MigSlice { gpu: 0, start: 0 });
        dc.allocate(&t2, 0, &Placement::MigSlice { gpu: 0, start: 4 });
        let blocked = mig_task(3, MigProfile::P2g);
        // The needed repack moves 5 slices; a cap of 4 forbids it.
        let mut rp = MigRepartitioner::new(RepartitionConfig {
            max_moved_slices: 4,
            ..Default::default()
        });
        assert!(rp.try_make_room(&mut dc, &blocked).is_none());
        assert_eq!(rp.stats.exhausted, 1);
        // A zero budget also forbids it.
        let mut rp = MigRepartitioner::new(RepartitionConfig {
            budget_slices: 0,
            ..Default::default()
        });
        assert!(rp.try_make_room(&mut dc, &blocked).is_none());
        // Non-MIG demands are ignored outright.
        let mut rp = MigRepartitioner::new(RepartitionConfig::default());
        assert!(rp
            .try_make_room(&mut dc, &Task::new(9, 1.0, 0.0, GpuDemand::Frac(0.5)))
            .is_none());
    }

    #[test]
    fn proactive_defrag_fires_on_threshold() {
        // A lone 1g at slice 0 locks a 4g out of 6 free slices:
        // frag_ratio = 1. A 0.9 threshold must trigger an FFD repack
        // that moves the 1g high and reopens the 0-3 window.
        let mut dc = ClusterSpec::mig_cluster(1, 1, 0).build();
        let t1 = mig_task(1, MigProfile::P1g);
        dc.allocate(&t1, 0, &Placement::MigSlice { gpu: 0, start: 0 });
        let mut rp = MigRepartitioner::new(RepartitionConfig::with_threshold(0.9));
        assert!(rp.defrag_node_if_fragmented(&mut dc, 0));
        assert_eq!(rp.stats.proactive_repartitions, 1);
        assert_eq!(rp.stats.repartitions, 0);
        assert_eq!(rp.stats.migrated_slices, 1);
        let mg = &dc.nodes[0].mig.as_ref().unwrap()[0];
        assert_eq!(mg.can_place(MigProfile::P4g), Some(0));
        // The resident instance survived the repack.
        assert_eq!(mg.instances.len(), 1);
        assert_eq!(mg.instances[0].profile, MigProfile::P1g);
        // Below threshold now: a second pass is a no-op.
        assert!(!rp.defrag_node_if_fragmented(&mut dc, 0));
        assert_eq!(rp.stats.proactive_repartitions, 1);
    }

    #[test]
    fn proactive_defrag_honors_caps_and_infinite_threshold() {
        let fragment = || {
            let mut dc = ClusterSpec::mig_cluster(1, 1, 0).build();
            dc.allocate(
                &mig_task(1, MigProfile::P1g),
                0,
                &Placement::MigSlice { gpu: 0, start: 0 },
            );
            dc
        };
        // The default ∞ threshold never fires (PR 1 failure-only mode).
        let mut dc = fragment();
        let mut rp = MigRepartitioner::new(RepartitionConfig::default());
        assert!(!rp.defrag_node_if_fragmented(&mut dc, 0));
        assert_eq!(rp.stats, RepartitionStats::default());
        // A zero per-event cap blocks the (1-slice) move.
        let mut dc = fragment();
        let mut rp = MigRepartitioner::new(RepartitionConfig {
            max_moved_slices: 0,
            frag_threshold: 0.5,
            ..Default::default()
        });
        assert!(!rp.defrag_node_if_fragmented(&mut dc, 0));
        assert_eq!(rp.stats.proactive_repartitions, 0);
        // An exhausted budget blocks it too.
        let mut dc = fragment();
        let mut rp = MigRepartitioner::new(RepartitionConfig {
            budget_slices: 0,
            frag_threshold: 0.5,
            ..Default::default()
        });
        assert!(!rp.defrag_node_if_fragmented(&mut dc, 0));
        assert_eq!(rp.stats.migrated_slices, 0);
    }
}
