//! FGD — Fragmentation Gradient Descent (Weng et al., USENIX ATC'23;
//! baseline [19] and the fragmentation half of the paper's combination).
//!
//! Scores a node `−ΔF_n(M)`: the increase in the node's *expected*
//! fragmentation for the target workload `M` if the task were placed
//! there (best placement inside the node). The k8s arg-max then descends
//! the fragmentation gradient.
//!
//! `F_n(M)` of the *current* state is cached per node and invalidated
//! via the scheduler's per-node generation counters — only the bound
//! node's cache entry is recomputed after each decision, which makes the
//! native scorer's hot loop O(placements · M) instead of
//! O((placements+1) · M).

use std::sync::Mutex;

use crate::cluster::node::{Node, Placement};
use crate::frag;
use crate::sched::framework::{SchedCtx, ScorePlugin};
use crate::tasks::Task;

/// The FGD score plugin with its generation-keyed `F_n(M)` cache. The
/// cache sits behind a `Mutex` (`ScorePlugin: Sync` since the sharded
/// scoring path): shard threads serialize on it briefly per scored
/// node, and the generation key makes the result identical whichever
/// thread computes it.
pub struct FgdPlugin {
    cache: Mutex<Vec<(u64, f64)>>,
}

impl FgdPlugin {
    pub fn new() -> FgdPlugin {
        FgdPlugin { cache: Mutex::new(Vec::new()) }
    }

    /// `F_n(M)` of the node's current state, cached by generation.
    fn f_before(&self, ctx: &SchedCtx, node: &Node) -> f64 {
        let mut cache = self.cache.lock().expect("fgd cache lock poisoned");
        if cache.len() != ctx.dc.nodes.len() {
            cache.clear();
            cache.resize(ctx.dc.nodes.len(), (u64::MAX, 0.0));
        }
        let gen = ctx.generations[node.id];
        let entry = &mut cache[node.id];
        if entry.0 != gen {
            *entry = (gen, frag::f_node_fast(node, ctx.prepared));
        }
        entry.1
    }
}

impl Default for FgdPlugin {
    fn default() -> Self {
        Self::new()
    }
}

impl ScorePlugin for FgdPlugin {
    fn name(&self) -> &'static str {
        "FGD"
    }

    fn score(&self, ctx: &SchedCtx, node: &Node, task: &Task, placements: &[Placement]) -> f64 {
        let before = self.f_before(ctx, node);
        let delta = placements
            .iter()
            .map(|p| frag::frag_delta_fast(node, task, p, ctx.prepared, before))
            .fold(f64::INFINITY, f64::min);
        -delta
    }

    /// The `Mutex` above guards a generation-keyed memo of the pure
    /// `F_n(M)` function — identical inputs yield bit-identical scores
    /// whichever thread computes them, so revision-cached reuse is
    /// sound. `tests/purity_check.rs` pins this claim dynamically.
    fn cacheable(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::sched::{PolicyKind, Scheduler};
    use crate::tasks::{GpuDemand, Task, TaskClass, Workload};

    fn workload_half_and_whole() -> Workload {
        Workload::new(vec![
            TaskClass {
                cpu: 2.0,
                mem: 0.0,
                gpu: GpuDemand::Frac(0.5),
                gpu_model: None,
                pop: 0.5,
            },
            TaskClass {
                cpu: 2.0,
                mem: 0.0,
                gpu: GpuDemand::Whole(1),
                gpu_model: None,
                pop: 0.5,
            },
        ])
    }

    /// FGD's signature behaviour: fill the half-used GPU instead of
    /// splitting a fresh one.
    #[test]
    fn fgd_packs_partial_gpus() {
        let mut dc = ClusterSpec::tiny(2, 4, 0).build();
        let w = workload_half_and_whole();
        let mut s = Scheduler::from_policy(PolicyKind::Fgd);
        let t0 = Task::new(0, 2.0, 0.0, GpuDemand::Frac(0.5));
        let d0 = s.schedule(&dc, &w, &t0).unwrap();
        dc.allocate(&t0, d0.node, &d0.placement);
        s.notify_node_changed(d0.node);
        let t1 = Task::new(1, 2.0, 0.0, GpuDemand::Frac(0.5));
        let d1 = s.schedule(&dc, &w, &t1).unwrap();
        assert_eq!(d1.node, d0.node);
        assert_eq!(d1.placement, d0.placement, "perfect fill beats a fresh split");
    }

    /// Cache correctness: scoring twice with an interleaved allocation
    /// must see the updated state (generation invalidation).
    #[test]
    fn cache_invalidation_on_generation_bump() {
        let mut dc = ClusterSpec::tiny(1, 2, 0).build();
        let w = workload_half_and_whole();
        let mut s = Scheduler::from_policy(PolicyKind::Fgd);
        let t0 = Task::new(0, 2.0, 0.0, GpuDemand::Frac(0.5));
        let d0 = s.schedule(&dc, &w, &t0).unwrap();
        dc.allocate(&t0, d0.node, &d0.placement);
        s.notify_node_changed(d0.node);
        // Second identical task: with a stale cache the deltas would be
        // computed against the empty node and pick a fresh GPU; with a
        // fresh cache FGD fills GPU 0.
        let t1 = Task::new(1, 2.0, 0.0, GpuDemand::Frac(0.5));
        let d1 = s.schedule(&dc, &w, &t1).unwrap();
        assert_eq!(d1.placement, d0.placement);
    }
}
