//! Sanity baselines: FirstFit (lowest-id feasible node) and Random
//! (uniform over feasible nodes). Not in the paper's comparison set;
//! used to sanity-check the harness (any reasonable policy must beat
//! Random on both EOPC and GRAR).

use std::sync::Mutex;

use crate::cluster::node::{Node, Placement};
use crate::sched::framework::{SchedCtx, ScorePlugin};
use crate::tasks::Task;
use crate::util::rng::Rng;

/// Picks the feasible node with the lowest id.
pub struct FirstFitPlugin;

impl ScorePlugin for FirstFitPlugin {
    fn name(&self) -> &'static str {
        "FirstFit"
    }

    fn score(&self, _ctx: &SchedCtx, node: &Node, _task: &Task, _ps: &[Placement]) -> f64 {
        -(node.id as f64)
    }
}

/// Picks a uniformly random feasible node (seeded, reproducible). The
/// RNG sits behind a `Mutex` only because `ScorePlugin: Sync`; the
/// framework never scores `random` off-thread or from the score cache
/// (see [`ScorePlugin::cacheable`]), so the stream always advances in
/// feasible order and the lock is uncontended.
pub struct RandomPlugin {
    rng: Mutex<Rng>,
}

impl RandomPlugin {
    pub fn new(seed: u64) -> RandomPlugin {
        RandomPlugin { rng: Mutex::new(Rng::new(seed)) }
    }
}

impl ScorePlugin for RandomPlugin {
    fn name(&self) -> &'static str {
        "Random"
    }

    /// Impure by design: every call is a fresh draw, so a cached score
    /// would freeze the "random" choice per (node, demand) pair.
    fn cacheable(&self) -> bool {
        false
    }

    fn score(&self, _ctx: &SchedCtx, _node: &Node, _task: &Task, _ps: &[Placement]) -> f64 {
        self.rng.lock().expect("rng lock poisoned").f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::sched::{PolicyKind, Scheduler};
    use crate::tasks::{GpuDemand, Workload};

    #[test]
    fn firstfit_is_deterministic_lowest_id() {
        let dc = ClusterSpec::tiny(3, 2, 0).build();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::FirstFit);
        for i in 0..3 {
            let t = Task::new(i, 1.0, 0.0, GpuDemand::Frac(0.2));
            assert_eq!(s.schedule(&dc, &w, &t).unwrap().node, 0);
        }
    }

    #[test]
    fn random_spreads_over_nodes() {
        let dc = ClusterSpec::tiny(8, 2, 0).build();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::Random);
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..64 {
            let t = Task::new(i, 1.0, 0.0, GpuDemand::Frac(0.2));
            seen.insert(s.schedule(&dc, &w, &t).unwrap().node);
        }
        assert!(seen.len() >= 4, "random policy stuck on {seen:?}");
    }
}
