//! The scheduling policy zoo (§V of the paper).
//!
//! Since the [`crate::sched::profile::SchedulerProfile`] redesign this
//! module only hosts the score-plugin implementations (and the MIG
//! repartitioner hook); policy *assembly* lives in the profile layer:
//! every [`crate::sched::PolicyKind`] lowers to a profile naming these
//! plugins by their registry keys (`pwr`, `fgd`, `bestfit`, `dotprod`,
//! `gpupacking`, `gpuclustering`, `firstfit`, `random`, `slicefit`).

pub mod baselines;
pub mod fgd;
pub mod mig;
pub mod packing;
pub mod pwr;
pub mod trivial;

pub use baselines::{BestFitPlugin, DotProdPlugin};
pub use fgd::FgdPlugin;
pub use mig::{MigRepartitioner, MigSliceFitPlugin, RepartitionConfig, RepartitionStats};
pub use packing::{GpuClusteringPlugin, GpuPackingPlugin};
pub use pwr::PwrPlugin;
pub use trivial::{FirstFitPlugin, RandomPlugin};

use crate::sched::{PolicyKind, Scheduler};

/// Materialize the scheduler for a legacy policy. Equivalent to
/// `kind.profile().build()` — kept as the historical entry point.
pub fn build(kind: PolicyKind) -> Scheduler {
    Scheduler::from_policy(kind)
}
