//! The scheduling policy zoo (§V of the paper).

pub mod baselines;
pub mod fgd;
pub mod mig;
pub mod packing;
pub mod pwr;
pub mod trivial;

use std::cell::RefCell;

use crate::sched::framework::{Binder, Scheduler, ScorePlugin};
use crate::sched::PolicyKind;
use crate::util::rng::Rng;

pub use baselines::{BestFitPlugin, DotProdPlugin};
pub use fgd::FgdPlugin;
pub use mig::{
    proactive_defrag, schedule_with_repartition, MigRepartitioner, MigSliceFitPlugin,
    RepartitionConfig, RepartitionStats,
};
pub use packing::{GpuClusteringPlugin, GpuPackingPlugin};
pub use pwr::PwrPlugin;
pub use trivial::{FirstFitPlugin, RandomPlugin};

/// Materialize the scheduler for a policy, wiring the plugin weights and
/// the GPU binder each policy uses:
/// * FGD / PWR / combinations → the weighted Δpower/Δfrag binder with
///   the matching α (1.0 for plain PWR, 0.0 for plain FGD);
/// * GpuPacking → occupied-GPU-first packing;
/// * everything else → GPU best-fit (the open-simulator default).
pub fn build(kind: PolicyKind) -> Scheduler {
    let label = kind.label();
    // The MIG variants share their non-MIG twin's wiring (the frag and
    // power layers are slice-aware, so the plugins natively evaluate
    // MIG placements); only the label — and MigSliceFit's plugin —
    // differ.
    let (plugins, binder): (Vec<(Box<dyn ScorePlugin>, f64)>, Binder) = match kind {
        PolicyKind::Fgd | PolicyKind::MigFgd => (
            vec![(Box::new(FgdPlugin::new()) as Box<dyn ScorePlugin>, 1.0)],
            Binder::WeightedPwrFgd { alpha: 0.0 },
        ),
        PolicyKind::Pwr | PolicyKind::MigPwr => (
            vec![(Box::new(PwrPlugin) as Box<dyn ScorePlugin>, 1.0)],
            Binder::WeightedPwrFgd { alpha: 1.0 },
        ),
        PolicyKind::PwrFgd { alpha } | PolicyKind::MigPwrFgd { alpha } => (
            vec![
                (Box::new(PwrPlugin) as Box<dyn ScorePlugin>, alpha),
                (Box::new(FgdPlugin::new()) as Box<dyn ScorePlugin>, 1.0 - alpha),
            ],
            Binder::WeightedPwrFgd { alpha },
        ),
        PolicyKind::PwrFgdDynamic { alpha_empty, .. } => (
            vec![
                (Box::new(PwrPlugin) as Box<dyn ScorePlugin>, alpha_empty),
                (Box::new(FgdPlugin::new()) as Box<dyn ScorePlugin>, 1.0 - alpha_empty),
            ],
            Binder::WeightedPwrFgd { alpha: alpha_empty },
        ),
        PolicyKind::BestFit | PolicyKind::MigBestFit => (
            vec![(Box::new(BestFitPlugin) as Box<dyn ScorePlugin>, 1.0)],
            Binder::GpuBestFit,
        ),
        PolicyKind::MigSliceFit => (
            vec![(Box::new(MigSliceFitPlugin) as Box<dyn ScorePlugin>, 1.0)],
            Binder::GpuBestFit,
        ),
        PolicyKind::DotProd => (
            vec![(Box::new(DotProdPlugin) as Box<dyn ScorePlugin>, 1.0)],
            Binder::GpuBestFit,
        ),
        PolicyKind::GpuPacking => (
            vec![(Box::new(GpuPackingPlugin) as Box<dyn ScorePlugin>, 1.0)],
            Binder::PackOccupied,
        ),
        PolicyKind::GpuClustering => (
            vec![(Box::new(GpuClusteringPlugin) as Box<dyn ScorePlugin>, 1.0)],
            Binder::GpuBestFit,
        ),
        PolicyKind::FirstFit => (
            vec![(Box::new(FirstFitPlugin) as Box<dyn ScorePlugin>, 1.0)],
            Binder::First,
        ),
        PolicyKind::Random => (
            vec![(Box::new(RandomPlugin::new(0x5EED)) as Box<dyn ScorePlugin>, 1.0)],
            Binder::Random(RefCell::new(Rng::new(0xB14D))),
        ),
    };
    let mut sched = Scheduler::new(plugins, binder, &label);
    if let PolicyKind::PwrFgdDynamic { alpha_empty, alpha_full } = kind {
        sched.set_dynamic_alpha(alpha_empty, alpha_full);
    }
    sched
}
