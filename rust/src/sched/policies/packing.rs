//! GPU-centric baselines: GpuPacking (MLaaS-in-the-wild [18]) and
//! GpuClustering (Gandiva [21]).

use crate::cluster::node::{Node, Placement, ResourceView};
use crate::sched::framework::{SchedCtx, ScorePlugin};
use crate::tasks::{GpuDemand, Task};

/// GpuPacking: prioritize (1) occupied GPUs, then (2) idle GPUs on
/// active nodes, then (3) idle nodes — preserving fully-free nodes and
/// GPUs for multi-GPU tasks. Within a tier, fuller GPUs/nodes win.
pub struct GpuPackingPlugin;

impl ScorePlugin for GpuPackingPlugin {
    fn name(&self) -> &'static str {
        "GpuPacking"
    }

    fn score(&self, _ctx: &SchedCtx, node: &Node, task: &Task, placements: &[Placement]) -> f64 {
        let tier = match task.gpu {
            GpuDemand::Frac(_) => {
                let has_occupied_candidate = placements.iter().any(
                    |p| matches!(p, Placement::Shared { gpu } if node.gpu_alloc[*gpu] > 0.0),
                );
                if has_occupied_candidate {
                    2.0
                } else if node.is_active() {
                    1.0
                } else {
                    0.0
                }
            }
            // Whole-GPU and CPU-only tasks can't share a GPU; prefer
            // active nodes over waking idle ones.
            _ => {
                if node.is_active() {
                    1.0
                } else {
                    0.0
                }
            }
        };
        // Tie-break inside a tier: fuller node (less free GPU) first.
        let fullness = if node.n_gpus() > 0 {
            1.0 - node.gpu_free_total() / node.n_gpus() as f64
        } else {
            1.0 - node.cpu_free() / node.cpu_capacity()
        };
        tier * 10.0 + fullness
    }
}

/// GpuClustering: pack tasks with *similar GPU requirements* together,
/// avoiding heterogeneous demand mixes on a node (Gandiva's affinity
/// rule). Nodes hosting same-bucket tasks score high; nodes hosting
/// other buckets score low; empty nodes are neutral.
pub struct GpuClusteringPlugin;

impl ScorePlugin for GpuClusteringPlugin {
    fn name(&self) -> &'static str {
        "GpuClustering"
    }

    fn score(&self, _ctx: &SchedCtx, node: &Node, task: &Task, _placements: &[Placement]) -> f64 {
        let bucket = task.gpu.bucket();
        let same = node.bucket_mix[bucket] as f64;
        let other: f64 =
            node.bucket_mix.iter().enumerate().filter(|&(b, _)| b != bucket).map(|(_, &c)| c as f64).sum();
        same - other
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::sched::{PolicyKind, Scheduler};
    use crate::tasks::Workload;

    #[test]
    fn gpupacking_reuses_occupied_gpu() {
        let mut dc = ClusterSpec::tiny(3, 4, 0).build();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::GpuPacking);
        let t0 = Task::new(0, 2.0, 512.0, GpuDemand::Frac(0.3));
        let d0 = s.schedule(&dc, &w, &t0).unwrap();
        dc.allocate(&t0, d0.node, &d0.placement);
        s.notify_node_changed(d0.node);
        // Tier 1: the next sharing task must land on the same GPU.
        let t1 = Task::new(1, 2.0, 512.0, GpuDemand::Frac(0.3));
        let d1 = s.schedule(&dc, &w, &t1).unwrap();
        assert_eq!(d1.node, d0.node);
        assert_eq!(d1.placement, d0.placement);
    }

    #[test]
    fn gpupacking_preserves_idle_nodes_for_multigpu() {
        let mut dc = ClusterSpec::tiny(2, 4, 0).build();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::GpuPacking);
        let t0 = Task::new(0, 2.0, 512.0, GpuDemand::Whole(1));
        let d0 = s.schedule(&dc, &w, &t0).unwrap();
        dc.allocate(&t0, d0.node, &d0.placement);
        s.notify_node_changed(d0.node);
        // A whole-GPU task prefers the already-active node (tier 1 vs 0).
        let t1 = Task::new(1, 2.0, 512.0, GpuDemand::Whole(2));
        let d1 = s.schedule(&dc, &w, &t1).unwrap();
        assert_eq!(d1.node, d0.node);
    }

    #[test]
    fn clustering_groups_same_bucket() {
        let mut dc = ClusterSpec::tiny(2, 4, 0).build();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::GpuClustering);
        // Seed node 0 with a sharing task, node 1 with a whole-GPU task.
        let frac = Task::new(0, 2.0, 512.0, GpuDemand::Frac(0.4));
        let p = dc.nodes[0].candidate_placements(&frac)[0].clone();
        dc.allocate(&frac, 0, &p);
        s.notify_node_changed(0);
        let whole = Task::new(1, 2.0, 512.0, GpuDemand::Whole(1));
        let pw = dc.nodes[1].candidate_placements(&whole).pop().unwrap();
        dc.allocate(&whole, 1, &pw);
        s.notify_node_changed(1);
        // A new sharing task clusters with the sharing node...
        let t = Task::new(2, 2.0, 512.0, GpuDemand::Frac(0.4));
        assert_eq!(s.schedule(&dc, &w, &t).unwrap().node, 0);
        // ...and a new whole-GPU task with the whole-GPU node.
        let t = Task::new(3, 2.0, 512.0, GpuDemand::Whole(1));
        assert_eq!(s.schedule(&dc, &w, &t).unwrap().node, 1);
    }
}
