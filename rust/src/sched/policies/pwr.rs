//! PWR — the paper's power-aware score plugin (§IV, Algorithm 1).
//!
//! For each feasible node the plugin hypothetically assigns the task
//! (`HYPASSIGNTONODE`), computes the increase Δ in the node's estimated
//! power `p(n) = p_CPU(n) + p_GPU(n)` (Eq. 1–2), and scores the node
//! `−Δ` so that the k8s framework's arg-max picks the node with the
//! smallest power increase (Algorithm 1, lines 9–10).

use crate::cluster::node::{Node, Placement};
use crate::sched::framework::{power_delta, SchedCtx, ScorePlugin};
use crate::tasks::Task;

/// The PWR score plugin.
pub struct PwrPlugin;

impl ScorePlugin for PwrPlugin {
    fn name(&self) -> &'static str {
        "PWR"
    }

    fn score(&self, _ctx: &SchedCtx, node: &Node, task: &Task, placements: &[Placement]) -> f64 {
        // Best (smallest) power increase over the candidate placements.
        let delta = placements
            .iter()
            .map(|p| power_delta(node, task, p))
            .fold(f64::INFINITY, f64::min);
        -delta
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::sched::{PolicyKind, Scheduler};
    use crate::tasks::{GpuDemand, Task, Workload};

    /// PWR consolidates: with one node already active, the next task
    /// goes to the same node (zero idle→max promotions elsewhere).
    #[test]
    fn pwr_consolidates_onto_active_node() {
        let mut dc = ClusterSpec::tiny(4, 4, 0).build();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::Pwr);
        let t0 = Task::new(0, 4.0, 1024.0, GpuDemand::Frac(0.5));
        let d0 = s.schedule(&dc, &w, &t0).unwrap();
        dc.allocate(&t0, d0.node, &d0.placement);
        s.notify_node_changed(d0.node);
        // Next fractional task: sharing the already-powered GPU costs 0 W.
        let t1 = Task::new(1, 4.0, 1024.0, GpuDemand::Frac(0.5));
        let d1 = s.schedule(&dc, &w, &t1).unwrap();
        assert_eq!(d1.node, d0.node, "PWR must reuse the active node");
        assert_eq!(d1.placement, d0.placement, "and the active GPU");
    }

    /// PWR picks the power-efficient GPU model when both fit: a 1-GPU
    /// task should go to a T4 node (Δ 70−10 = 60 W) over a G3/A100 node
    /// (Δ 400−50 = 350 W).
    #[test]
    fn pwr_prefers_efficient_gpu_model() {
        use crate::cluster::inventory::{ClusterSpec, NodePool};
        use crate::cluster::types::GpuModel;
        let spec = ClusterSpec {
            zones: 0,
            pools: vec![
                NodePool {
                    count: 1,
                    vcpus: 128.0,
                    mem: 786_432.0,
                    gpu_model: Some(GpuModel::G3),
                    gpus_per_node: 8,
                    mig: false,
                    labels: Vec::new(),
                },
                NodePool {
                    count: 1,
                    vcpus: 64.0,
                    mem: 131_072.0,
                    gpu_model: Some(GpuModel::T4),
                    gpus_per_node: 4,
                    mig: false,
                    labels: Vec::new(),
                },
            ],
        };
        let dc = spec.build();
        let w = Workload::default();
        let mut s = Scheduler::from_policy(PolicyKind::Pwr);
        let t = Task::new(0, 2.0, 1024.0, GpuDemand::Whole(1));
        let d = s.schedule(&dc, &w, &t).unwrap();
        assert_eq!(dc.nodes[d.node].gpu_model, Some(GpuModel::T4));
    }

    /// The plugin's raw score is exactly −Δp for the best placement.
    #[test]
    fn raw_score_is_negative_power_delta() {
        let dc = ClusterSpec::tiny(1, 4, 0).build();
        let node = &dc.nodes[0];
        let w = Workload::default();
        let pw = crate::frag::PreparedWorkload::new(&w);
        let ctx = SchedCtx {
            dc: &dc,
            workload: &w,
            prepared: &pw,
            generations: &[0],
            caps: crate::sched::framework::ClusterCaps::of(&dc),
            gang: None,
        };
        let t = Task::new(0, 2.0, 512.0, GpuDemand::Whole(2));
        let ps = node.candidate_placements(&t);
        let s = PwrPlugin.score(&ctx, node, &t, &ps);
        // 2 G2 GPUs idle→max: 2·(150−30); plus 1 socket idle→max: 105.
        assert_eq!(s, -(2.0 * 120.0 + 105.0));
    }
}
