//! Topology-aware gang scheduling for model-parallel jobs.
//!
//! The paper schedules single-node tasks, but the LLM workloads behind
//! its power/fragmentation problem arrive as *gangs*: a TP×PP×DP
//! parallelism split ([`GangSpec`]) where each tensor-parallel group
//! (`tp` whole GPUs) must share one node's NVLink domain, pipeline
//! stages prefer locality, and data-parallel replicas can go anywhere.
//! This module supplies the gang-specific pieces the framework composes
//! (`rust/src/sched/framework.rs` owns the
//! [`Scheduler::place_gang`](crate::sched::Scheduler::place_gang) /
//! [`Scheduler::release_gang`](crate::sched::Scheduler::release_gang)
//! protocol; `docs/gang.md` has the full model):
//!
//! * [`member_task`] / [`gang_task`] — the deterministic decomposition
//!   of a gang-carrying [`Task`] into `pp·dp` identical member tasks
//!   (`Whole(tp)` GPUs, per-member CPU/memory shares). Rollback and
//!   release rebuild members from the parent, so no per-member state
//!   needs to be stored.
//! * [`GangFilter`] — the PreFilter aggregate check (registry key
//!   `gang`): a gang is hopeless unless the fleet holds enough
//!   NVLink-contiguous capacity, Σ_n ⌊free_whole_gpus(n)/tp⌋ ≥ pp·dp.
//!   A no-op for ordinary tasks, so the default chain stays
//!   placement-identical on gang-free traces.
//! * [`TopoPlugin`] — the `topo` score plugin: prices a candidate
//!   node for the member being placed by estimated communication cost
//!   over the committed members ([`GangProgress`]), pipeline edges at
//!   [`PP_TRAFFIC`] and data-parallel edges at [`DP_TRAFFIC`] units,
//!   each divided by [`crate::cluster::Topology`] bandwidth. TP groups
//!   never cross a node by construction (a member *is* one TP group),
//!   so the hard requirement costs nothing to enforce.
//! * [`ZonespreadPlugin`] — the `zonespread` score plugin: softens the
//!   hard per-node spread cap of the `affinity` filter into a score
//!   penalty (−1 per resident task of the same class), so classes
//!   spread when possible without becoming unschedulable when not.

use crate::cluster::node::{Node, Placement, ResourceView};
use crate::sched::filter::{FilterCtx, FilterPlugin};
use crate::sched::framework::{Decision, SchedCtx, ScorePlugin};
use crate::tasks::{GangSpec, GpuDemand, Task};

/// Relative traffic of one pipeline-parallel edge (activations flow
/// every microbatch — the expensive span).
pub const PP_TRAFFIC: f64 = 1.0;

/// Relative traffic of one data-parallel edge (gradient all-reduce once
/// per step — cheaper than the pipeline hop).
pub const DP_TRAFFIC: f64 = 0.5;

/// An atomically committed gang placement: one [`Decision`] per member,
/// in member order (`i = replica·pp + stage`). Release via
/// [`crate::sched::Scheduler::release_gang`] with the parent task.
#[derive(Clone, Debug, PartialEq)]
pub struct GangDecision {
    pub members: Vec<Decision>,
}

/// Progress of an in-flight gang placement, exposed to score plugins
/// through [`SchedCtx::gang`] so they can see which member is being
/// placed and where the committed members sit.
#[derive(Clone, Debug)]
pub struct GangProgress {
    /// The gang's parallelism split.
    pub spec: GangSpec,
    /// Index of the member currently being scheduled.
    pub member: u32,
    /// Hosting node of each already-committed member (`len == member`).
    pub nodes: Vec<usize>,
}

/// Build a gang-carrying task from *per-member* demand: the returned
/// task's demand fields hold the gang totals (so aggregate accounting —
/// GRAR denominators, PreFilter capacity sums — needs no special case)
/// and its [`Task::gang`] carries the split.
pub fn gang_task(id: u64, member_cpu: f64, member_mem: f64, spec: GangSpec) -> Task {
    let n = spec.n_members() as f64;
    Task::new(id, member_cpu * n, member_mem * n, GpuDemand::Whole(spec.total_gpus()))
        .with_gang(spec)
}

/// Member `member` of a gang-carrying task: one tensor-parallel group —
/// `Whole(tp)` GPUs on a single node — with an even share of the
/// parent's CPU/memory and the parent's constraints. Deterministic, so
/// rollback and release rebuild the exact task that was allocated. For
/// a task without a gang the parent itself is returned unchanged.
pub fn member_task(parent: &Task, member: u32) -> Task {
    let Some(spec) = parent.gang else { return parent.clone() };
    let n = spec.n_members() as f64;
    let mut t = parent.clone();
    // Members share the parent's identity for accounting; the low bits
    // carry the member index purely for debuggability (nothing keys on
    // task ids).
    t.id = parent.id.wrapping_mul(64).wrapping_add(member as u64);
    t.cpu = parent.cpu / n;
    t.mem = parent.mem / n;
    t.gpu = GpuDemand::Whole(spec.tp);
    t.gang = None;
    t
}

/// Distinct hosting nodes of a committed gang — the communication
/// footprint (1 = fully node-local). Reported as `gang_pp_span_sum`
/// so experiments can derive the mean span per placed gang.
pub fn pp_span(members: &[Decision]) -> u64 {
    let mut nodes: Vec<usize> = members.iter().map(|d| d.node).collect();
    nodes.sort_unstable();
    nodes.dedup();
    nodes.len() as u64
}

/// Members whose placement is *not* one whole-GPU group of exactly `tp`
/// GPUs on a single node. Structurally impossible through
/// `place_gang` (a member is one TP group by construction); counted
/// defensively as `gang_tp_violations`, which experiments assert is 0.
pub fn tp_violations(members: &[Decision], spec: GangSpec) -> u64 {
    members
        .iter()
        .filter(|d| {
            !matches!(&d.placement, Placement::Whole { gpus } if gpus.len() == spec.tp as usize)
        })
        .count() as u64
}

/// The `gang` filter: PreFilter-only aggregate feasibility for gang
/// tasks. A gang of `pp·dp` members, each needing `tp` whole GPUs on
/// one node, is cluster-wide infeasible unless
/// Σ_n ⌊free_whole_gpus(n)/tp⌋ ≥ pp·dp. Conservative by contract:
/// power states and CPU/memory are deliberately ignored here (a DRS
/// hook may wake sleepers, and per-member feasibility is the node
/// loop's job), so a `false` really means no placement could exist.
/// Both phases are no-ops for ordinary tasks.
pub struct GangFilter;

impl FilterPlugin for GangFilter {
    fn name(&self) -> &'static str {
        "gang"
    }

    fn pre_filter(&self, ctx: &FilterCtx, task: &Task) -> bool {
        let Some(spec) = task.gang else { return true };
        let mut groups: u32 = 0;
        for node in &ctx.dc.nodes {
            groups += node.gpus_fully_free() as u32 / spec.tp;
            if groups >= spec.n_members() {
                return true;
            }
        }
        false
    }

    fn feasible(&self, _ctx: &FilterCtx, _node: &Node, _task: &Task) -> bool {
        // Per-node feasibility of a *member* is entirely Cond. 1–3
        // (`resources` sees `Whole(tp)`); the gang parent never enters
        // the node loop.
        true
    }
}

/// The `topo` score plugin: estimated communication cost of hosting
/// the member being placed on `node`, given the committed members in
/// [`SchedCtx::gang`]. The cost of each edge is its traffic divided by
/// the bandwidth tier between the endpoints
/// ([`crate::cluster::Datacenter::bandwidth_between`]): the previous
/// pipeline stage of the same replica at [`PP_TRAFFIC`], and the
/// same-stage member of every earlier replica at [`DP_TRAFFIC`].
/// Scores are negated costs (higher is better), so co-located members
/// win and cross-zone spans lose. 0 for ordinary tasks and for member
/// 0 (no peers yet), which normalizes to a constant 100 — composing
/// `topo` into a profile leaves gang-free decisions bit-identical.
pub struct TopoPlugin;

impl ScorePlugin for TopoPlugin {
    fn name(&self) -> &'static str {
        "topo"
    }

    /// Not cacheable: the score depends on the in-flight gang progress
    /// (which member, where its peers sit), which the raw-score cache
    /// key (demand signature × node generation) cannot see — all
    /// members share one signature, yet their topology costs differ.
    fn cacheable(&self) -> bool {
        false
    }

    fn score(&self, ctx: &SchedCtx, node: &Node, _task: &Task, _placements: &[Placement]) -> f64 {
        let Some(g) = ctx.gang else { return 0.0 };
        if g.member == 0 {
            return 0.0;
        }
        let spec = g.spec;
        let stage = spec.stage_of(g.member);
        let replica = spec.replica_of(g.member);
        let mut cost = 0.0;
        // Pipeline edge: the previous stage of this replica (member
        // order is replica-major, so that is the immediately preceding
        // member).
        if stage > 0 {
            if let Some(&peer) = g.nodes.get(g.member as usize - 1) {
                cost += PP_TRAFFIC / ctx.dc.bandwidth_between(node.id, peer);
            }
        }
        // Data-parallel edges: the same stage of every earlier replica
        // (the gradient all-reduce ring).
        for r in 0..replica {
            if let Some(&peer) = g.nodes.get((r * spec.pp + stage) as usize) {
                cost += DP_TRAFFIC / ctx.dc.bandwidth_between(node.id, peer);
            }
        }
        -cost
    }
}

/// The `zonespread` score plugin: a *soft* spread preference. Where the
/// `affinity` filter's `max_per_node` cap makes a class-keyed task
/// unschedulable once every node reaches the cap, this plugin merely
/// penalizes a candidate by the number of same-class tasks it already
/// hosts (−1 each), spreading the class while it can and degrading
/// gracefully when it cannot. 0 for tasks without a class key, so
/// unkeyed traces are bit-identical under any `zonespread` weight.
pub struct ZonespreadPlugin;

impl ScorePlugin for ZonespreadPlugin {
    fn name(&self) -> &'static str {
        "zonespread"
    }

    fn score(&self, _ctx: &SchedCtx, node: &Node, task: &Task, _placements: &[Placement]) -> f64 {
        match task.constraints.as_deref().and_then(|c| c.class_key.as_deref()) {
            Some(key) => -f64::from(node.class_count(key)),
            None => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, Datacenter};
    use crate::sched::framework::ClusterCaps;
    use crate::tasks::{TaskConstraints, Workload};

    fn spec(tp: u32, pp: u32, dp: u32) -> GangSpec {
        GangSpec::new(tp, pp, dp).expect("valid spec")
    }

    #[test]
    fn member_decomposition_conserves_totals() {
        let parent = gang_task(7, 8.0, 24_576.0, spec(2, 2, 2));
        assert_eq!(parent.cpu, 32.0);
        assert_eq!(parent.mem, 98_304.0);
        assert_eq!(parent.gpu, GpuDemand::Whole(8));
        let members: Vec<Task> = (0..4).map(|i| member_task(&parent, i)).collect();
        let cpu: f64 = members.iter().map(|m| m.cpu).sum();
        let gpus: f64 = members.iter().map(|m| m.gpu.units()).sum();
        assert_eq!(cpu, parent.cpu);
        assert_eq!(gpus, parent.gpu.units());
        assert!(members.iter().all(|m| m.gpu == GpuDemand::Whole(2) && m.gang.is_none()));
        // Deterministic: rollback/release rebuild the identical task.
        assert_eq!(member_task(&parent, 3), member_task(&parent, 3));
        // Non-gang tasks pass through unchanged.
        let plain = Task::new(1, 2.0, 512.0, GpuDemand::Whole(1));
        assert_eq!(member_task(&plain, 0), plain);
    }

    #[test]
    fn gang_prefilter_counts_contiguous_whole_gpu_capacity() {
        // Two 4-GPU nodes: ⌊4/4⌋+⌊4/4⌋ = 2 four-GPU groups.
        let mut dc = ClusterSpec::tiny(2, 4, 0).build();
        let ctx = FilterCtx { dc: &dc };
        let fits = |s: GangSpec| {
            GangFilter.pre_filter(&ctx, &gang_task(0, 1.0, 0.0, s))
        };
        assert!(fits(spec(4, 2, 1))); // 2 members of 4
        assert!(!fits(spec(4, 2, 2))); // 4 members of 4: too many
        assert!(fits(spec(2, 2, 2))); // 4 members of 2: ⌊4/2⌋·2 = 4
        assert!(!fits(spec(3, 3, 1))); // ⌊4/3⌋·2 = 2 < 3 members
        // Fragmented capacity: one GPU busy per node kills 4-GPU groups
        // but leaves 2-GPU ones.
        let filler = Task::new(9, 1.0, 0.0, GpuDemand::Whole(1));
        let p = Placement::Whole { gpus: vec![0] };
        dc.allocate(&filler, 0, &p);
        dc.allocate(&filler, 1, &p);
        let ctx = FilterCtx { dc: &dc };
        let fits = |s: GangSpec| {
            GangFilter.pre_filter(&ctx, &gang_task(0, 1.0, 0.0, s))
        };
        assert!(!fits(spec(4, 2, 1)));
        assert!(fits(spec(2, 2, 1)));
        // Ordinary tasks are never vetoed.
        assert!(GangFilter.pre_filter(&ctx, &Task::new(1, 1.0, 0.0, GpuDemand::Whole(64))));
    }

    fn ctx_with<'a>(
        dc: &'a Datacenter,
        w: &'a Workload,
        pw: &'a crate::frag::PreparedWorkload,
        gens: &'a [u64],
        gang: Option<&'a GangProgress>,
    ) -> SchedCtx<'a> {
        SchedCtx { dc, workload: w, prepared: pw, generations: gens, caps: ClusterCaps::of(dc), gang }
    }

    #[test]
    fn topo_plugin_prices_spans_by_bandwidth_tier() {
        // 4 nodes across 2 zones: 0,2 in z0; 1,3 in z1.
        let dc = ClusterSpec::tiny(4, 4, 0).with_zones(2).build();
        let w = Workload::default();
        let pw = crate::frag::PreparedWorkload::new(&w);
        let gens = vec![0u64; 4];
        let t = Task::new(0, 1.0, 0.0, GpuDemand::Whole(2));
        // Member 1 = stage 1 of replica 0; member 0 sits on node 0.
        let g = GangProgress { spec: spec(2, 2, 2), member: 1, nodes: vec![0] };
        let ctx = ctx_with(&dc, &w, &pw, &gens, Some(&g));
        let score = |n: usize| TopoPlugin.score(&ctx, &dc.nodes[n], &t, &[]);
        let (same, zone, cross) = (score(0), score(2), score(1));
        assert_eq!(same, -PP_TRAFFIC / 600.0);
        assert_eq!(zone, -PP_TRAFFIC / 100.0);
        assert_eq!(cross, -PP_TRAFFIC / 25.0);
        assert!(same > zone && zone > cross);
        // Member 2 = stage 0 of replica 1: a DP edge to member 0 only.
        let g = GangProgress { spec: spec(2, 2, 2), member: 2, nodes: vec![0, 2] };
        let ctx = ctx_with(&dc, &w, &pw, &gens, Some(&g));
        assert_eq!(TopoPlugin.score(&ctx, &dc.nodes[0], &t, &[]), -DP_TRAFFIC / 600.0);
        // Member 3 = stage 1 of replica 1: PP edge to member 2 and DP
        // edge to member 1.
        let g = GangProgress { spec: spec(2, 2, 2), member: 3, nodes: vec![0, 2, 1] };
        let ctx = ctx_with(&dc, &w, &pw, &gens, Some(&g));
        assert_eq!(
            TopoPlugin.score(&ctx, &dc.nodes[1], &t, &[]),
            -(PP_TRAFFIC / 25.0 + DP_TRAFFIC / 600.0)
        );
        // Member 0 and non-gang decisions are flat zero.
        let g0 = GangProgress { spec: spec(2, 2, 2), member: 0, nodes: vec![] };
        let ctx = ctx_with(&dc, &w, &pw, &gens, Some(&g0));
        assert_eq!(TopoPlugin.score(&ctx, &dc.nodes[3], &t, &[]), 0.0);
        let ctx = ctx_with(&dc, &w, &pw, &gens, None);
        assert_eq!(TopoPlugin.score(&ctx, &dc.nodes[3], &t, &[]), 0.0);
        assert!(!TopoPlugin.cacheable());
    }

    #[test]
    fn zonespread_penalizes_resident_class_counts() {
        let mut dc = ClusterSpec::tiny(2, 4, 0).build();
        let keyed = |id: u64| {
            Task::new(id, 1.0, 0.0, GpuDemand::Frac(0.25)).with_constraints(TaskConstraints {
                class_key: Some("job-a".to_string()),
                ..Default::default()
            })
        };
        dc.allocate(&keyed(1), 0, &Placement::Shared { gpu: 0 });
        dc.allocate(&keyed(2), 0, &Placement::Shared { gpu: 0 });
        let w = Workload::default();
        let pw = crate::frag::PreparedWorkload::new(&w);
        let gens = vec![0u64; 2];
        let ctx = ctx_with(&dc, &w, &pw, &gens, None);
        let t = keyed(3);
        assert_eq!(ZonespreadPlugin.score(&ctx, &dc.nodes[0], &t, &[]), -2.0);
        assert_eq!(ZonespreadPlugin.score(&ctx, &dc.nodes[1], &t, &[]), 0.0);
        // Unkeyed tasks see a flat surface (bit-identity under weight).
        let plain = Task::new(4, 1.0, 0.0, GpuDemand::Frac(0.25));
        assert_eq!(ZonespreadPlugin.score(&ctx, &dc.nodes[0], &plain, &[]), 0.0);
        assert!(ZonespreadPlugin.cacheable());
    }

    #[test]
    fn span_and_violation_helpers() {
        let whole = |node: usize, gpus: Vec<usize>| Decision {
            node,
            placement: Placement::Whole { gpus },
        };
        let members = vec![whole(0, vec![0, 1]), whole(0, vec![2, 3]), whole(2, vec![0, 1])];
        assert_eq!(pp_span(&members), 2);
        assert_eq!(tp_violations(&members, spec(2, 3, 1)), 0);
        // A member holding the wrong group width is a violation.
        assert_eq!(tp_violations(&members, spec(4, 3, 1)), 3);
        let shared = vec![Decision { node: 0, placement: Placement::Shared { gpu: 0 } }];
        assert_eq!(tp_violations(&shared, spec(1, 1, 1)), 1);
    }
}
