//! Multi-tenant fairness subsystem: pending queue, starvation metrics,
//! dynamic multi-objective modulation, and priority preemption.
//!
//! Without this module a task that fails placement (after the postFail
//! retry) simply vanishes from the allocated count, so the simulator
//! cannot express the queueing/starvation dynamics that dominate real
//! multi-tenant GPU clusters near saturation. The subsystem has three
//! cooperating parts, all sharing one [`FairnessCore`] behind an
//! `Arc<Mutex<_>>` ([`FairnessShared`]):
//!
//! 1. **Pending queue** — failed arrivals enqueue instead of dropping
//!    and are retried on every capacity event (release / tick). The
//!    queue is ordered priority-first, FIFO within a priority tier, and
//!    carries wait-time accounting surfaced as catalogued starvation
//!    metrics (`pending_depth`, `p99_wait`, `oldest_pending_age`,
//!    `starvation_events`).
//! 2. **[`StarveModulator`]** (`mod(starve:<threshold>:<boost>)`) — a
//!    dynamic [`WeightModulator`] that shifts a `boost` fraction of the
//!    power weight onto the packing/FGD objectives while the observed
//!    p99 wait exceeds `threshold` (the 2512.10980 dynamic
//!    multi-objective idea).
//! 3. **[`PreemptHook`]** (`hook(preempt:<max_evictions>)`) — a
//!    postFail hook that evicts strictly-lower-priority residents
//!    (victims re-enter the pending queue, never lost) so the failed
//!    arrival can retry against the freed capacity.
//!
//! Plugins find the shared core via
//! [`crate::sched::framework::Scheduler::bind_fairness`]; unbound
//! plugins are inert, and a simulation that never installs a
//! [`FairnessState`] is bit-identical to the historical drop behavior
//! (pinned by `tests/fairness_equivalence.rs`).
//!
//! See `docs/fairness.md` for the queue model and knob reference.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use crate::cluster::{Datacenter, Node, Placement};
use crate::obs::MetricsRegistry;
use crate::sched::framework::PostHook;
use crate::sched::modulate::WeightModulator;
use crate::tasks::{GpuDemand, Task};

/// Tunables for the fairness subsystem.
#[derive(Debug, Clone, Copy)]
pub struct FairnessConfig {
    /// Wait time beyond which a pending task is counted as *starved*
    /// (one `starvation_events` increment per task, at the moment its
    /// age first crosses the threshold).
    pub starve_threshold: f64,
}

impl Default for FairnessConfig {
    fn default() -> FairnessConfig {
        FairnessConfig { starve_threshold: 1000.0 }
    }
}

/// One queued task awaiting retry.
#[derive(Debug, Clone)]
pub struct PendingEntry {
    /// The task awaiting placement.
    pub task: Task,
    /// Clock value when the task entered the queue (this stint).
    pub enqueued_at: f64,
    /// Monotone admission ticket — FIFO order within a priority tier.
    pub seq: u64,
    /// True when the entry is a preemption victim re-entering the
    /// queue (its first placement was already counted by the caller).
    pub requeued: bool,
    /// Whether this entry already fired its starvation event.
    starved: bool,
}

/// Bookkeeping for a placed task, so the preemption hook can evict it
/// with an exact resource restore.
#[derive(Debug, Clone)]
pub struct ResidentRecord {
    /// The resident task (priority decides preemptability).
    pub task: Task,
    /// Node it occupies.
    pub node: usize,
    /// Exact placement, replayed through `Datacenter::deallocate` on
    /// eviction.
    pub placement: Placement,
}

/// Shared handle to the fairness core: the sim loop, the
/// [`StarveModulator`] and the [`PreemptHook`] all hold clones.
pub type FairnessShared = Arc<Mutex<FairnessCore>>;

/// Build a fresh shared fairness core.
pub fn shared(cfg: FairnessConfig) -> FairnessShared {
    Arc::new(Mutex::new(FairnessCore::new(cfg)))
}

/// The single source of truth for pending/resident/evicted tasks and
/// all wait-time accounting. Lives behind [`FairnessShared`]; callers
/// must never hold the lock across a `Scheduler::place` call (the
/// preemption hook re-locks it from inside the postFail phase).
#[derive(Debug)]
pub struct FairnessCore {
    cfg: FairnessConfig,
    now: f64,
    seq: u64,
    /// Sorted: priority descending, then seq ascending (FIFO within a
    /// tier). `head()` is always `pending[0]`.
    pending: Vec<PendingEntry>,
    residents: HashMap<u64, ResidentRecord>,
    /// Eviction outbox: records the hook moved out of `residents`,
    /// awaiting `requeue_evicted` by the sim loop.
    evicted: Vec<ResidentRecord>,
    /// Completed queue waits, kept sorted ascending.
    completed_waits: Vec<f64>,
    p99_cache: f64,
    enqueues: u64,
    requeues: u64,
    drains: u64,
    preemptions: u64,
    starvation_events: u64,
}

impl FairnessCore {
    /// Fresh core at clock zero.
    pub fn new(cfg: FairnessConfig) -> FairnessCore {
        FairnessCore {
            cfg,
            now: 0.0,
            seq: 0,
            pending: Vec::new(),
            residents: HashMap::new(),
            evicted: Vec::new(),
            completed_waits: Vec::new(),
            p99_cache: 0.0,
            enqueues: 0,
            requeues: 0,
            drains: 0,
            preemptions: 0,
            starvation_events: 0,
        }
    }

    /// Advance the fairness clock (monotone), refresh the starvation
    /// ledger and the cached p99 wait.
    pub fn set_now(&mut self, now: f64) {
        if now > self.now {
            self.now = now;
        }
        for e in &mut self.pending {
            if !e.starved && self.now - e.enqueued_at > self.cfg.starve_threshold {
                e.starved = true;
                self.starvation_events += 1;
            }
        }
        self.p99_cache = self.compute_p99();
    }

    /// Current fairness clock.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Enqueue a task that failed placement. `requeued` marks
    /// preemption victims re-entering the queue (so result counters
    /// are not double-counted on their second placement).
    pub fn enqueue(&mut self, task: Task, requeued: bool) {
        self.seq += 1;
        let entry = PendingEntry {
            enqueued_at: self.now,
            seq: self.seq,
            requeued,
            starved: false,
            task,
        };
        // Keep (priority desc, seq asc): insert before the first entry
        // of strictly lower priority; ties on priority keep arrival
        // order because seq grows monotonically.
        let at = self
            .pending
            .iter()
            .position(|e| e.task.priority < entry.task.priority)
            .unwrap_or(self.pending.len());
        self.pending.insert(at, entry);
        if requeued {
            self.requeues += 1;
        } else {
            self.enqueues += 1;
        }
    }

    /// The next task to retry (highest priority, oldest within the
    /// tier), cloned so the caller can drop the lock before placing.
    pub fn head(&self) -> Option<Task> {
        self.pending.first().map(|e| e.task.clone())
    }

    /// Remove the head after a successful placement, recording its
    /// completed wait. Returns the entry so the caller can tell fresh
    /// arrivals from requeued victims.
    pub fn pop_placed(&mut self) -> Option<PendingEntry> {
        if self.pending.is_empty() {
            return None;
        }
        let entry = self.pending.remove(0);
        let wait = (self.now - entry.enqueued_at).max(0.0);
        let at = self.completed_waits.partition_point(|w| *w <= wait);
        self.completed_waits.insert(at, wait);
        self.drains += 1;
        Some(entry)
    }

    /// Register a placed task so the preemption hook can later evict
    /// it with an exact restore.
    pub fn note_resident(&mut self, task: &Task, node: usize, placement: &Placement) {
        self.residents.insert(
            task.id,
            ResidentRecord { task: task.clone(), node, placement: placement.clone() },
        );
    }

    /// Drop the resident record on departure (no-op if unknown).
    pub fn forget_resident(&mut self, id: u64) -> Option<ResidentRecord> {
        self.residents.remove(&id)
    }

    /// Move everything in the eviction outbox back into the pending
    /// queue (as requeued entries) and return the victim task ids so
    /// the sim loop can drop them from its running ledger.
    pub fn requeue_evicted(&mut self) -> Vec<u64> {
        let victims = std::mem::take(&mut self.evicted);
        let mut ids = Vec::with_capacity(victims.len());
        for v in victims {
            ids.push(v.task.id);
            self.enqueue(v.task, true);
        }
        ids
    }

    /// Evict up to `budget` strictly-lower-priority residents from one
    /// node so `task` has a coarse chance of fitting there, restoring
    /// each victim's resources exactly via `Datacenter::deallocate`.
    /// Victims land in the eviction outbox (see [`Self::requeue_evicted`]);
    /// returns the number evicted (0 = no viable node within budget,
    /// in which case nothing was touched).
    pub fn preempt_for(
        &mut self,
        dc: &mut Datacenter,
        task: &Task,
        budget: u64,
        invalidate: &mut dyn FnMut(usize),
    ) -> u64 {
        if budget == 0 || task.priority == 0 {
            return 0;
        }
        // Group preemptable residents per node (BTreeMap: deterministic
        // ascending node order for tie-breaks).
        let mut by_node: BTreeMap<usize, Vec<&ResidentRecord>> = BTreeMap::new();
        for r in self.residents.values() {
            if r.task.priority < task.priority {
                by_node.entry(r.node).or_default().push(r);
            }
        }
        let mut best: Option<(usize, Vec<u64>)> = None;
        for (&node, victims) in &mut by_node {
            // Cheapest tenants first: lowest priority, then youngest
            // (highest id) — deterministic regardless of map order.
            victims.sort_by(|a, b| {
                a.task
                    .priority
                    .cmp(&b.task.priority)
                    .then(b.task.id.cmp(&a.task.id))
            });
            let mut chosen: Vec<&ResidentRecord> = Vec::new();
            let mut fits = false;
            for &v in victims.iter().take(budget as usize) {
                chosen.push(v);
                if fits_after_eviction(&dc.nodes[node], task, &chosen) {
                    fits = true;
                    break;
                }
            }
            if fits {
                let ids: Vec<u64> = chosen.iter().map(|v| v.task.id).collect();
                let better = match &best {
                    None => true,
                    Some((_, b)) => ids.len() < b.len(),
                };
                if better {
                    best = Some((node, ids));
                }
            }
        }
        let Some((_, ids)) = best else { return 0 };
        let n = ids.len() as u64;
        for id in ids {
            if let Some(r) = self.residents.remove(&id) {
                dc.deallocate(&r.task, r.node, &r.placement);
                invalidate(r.node);
                self.evicted.push(r);
                self.preemptions += 1;
            }
        }
        n
    }

    /// Number of tasks currently waiting.
    pub fn pending_depth(&self) -> u64 {
        self.pending.len() as u64
    }

    /// Age of the oldest pending task (0 when the queue is empty).
    /// Within one queue stint this is monotone in the clock: entries
    /// keep their `enqueued_at` across failed retries.
    pub fn oldest_pending_age(&self) -> f64 {
        self.pending
            .iter()
            .map(|e| self.now - e.enqueued_at)
            .fold(0.0, f64::max)
    }

    /// Cached p99 wait over completed waits plus current pending ages
    /// (refreshed by [`Self::set_now`]).
    pub fn p99_wait(&self) -> f64 {
        self.p99_cache
    }

    fn compute_p99(&self) -> f64 {
        let mut waits: Vec<f64> = self.completed_waits.clone();
        waits.extend(self.pending.iter().map(|e| self.now - e.enqueued_at));
        if waits.is_empty() {
            return 0.0;
        }
        waits.sort_by(|a, b| a.total_cmp(b));
        // Nearest-rank p99.
        let rank = ((0.99 * waits.len() as f64).ceil() as usize).max(1);
        waits[rank.min(waits.len()) - 1]
    }

    /// Tasks that crossed the starvation threshold (one event per
    /// queue stint).
    pub fn starvation_events(&self) -> u64 {
        self.starvation_events
    }

    /// Fresh-arrival enqueues (excludes preemption requeues).
    pub fn enqueues(&self) -> u64 {
        self.enqueues
    }

    /// Preemption-victim requeues.
    pub fn requeues(&self) -> u64 {
        self.requeues
    }

    /// Successful drains (pending tasks later placed).
    pub fn drains(&self) -> u64 {
        self.drains
    }

    /// Residents evicted by the preemption hook so far.
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Entries currently queued, in retry order (tests/diagnostics).
    pub fn pending_entries(&self) -> &[PendingEntry] {
        &self.pending
    }

    /// Resident record for a task id (tests/diagnostics).
    pub fn resident(&self, id: u64) -> Option<&ResidentRecord> {
        self.residents.get(&id)
    }

    /// Write the starvation gauges/counters into a metrics registry
    /// (keys are pre-registered in the obs catalog).
    pub fn publish(&self, reg: &mut MetricsRegistry) {
        reg.set_gauge("pending_depth", self.pending_depth() as f64);
        reg.set_gauge("p99_wait", self.p99_wait());
        reg.set_gauge("oldest_pending_age", self.oldest_pending_age());
        reg.set_counter("starvation_events", self.starvation_events);
        reg.set_counter("pending_enqueues", self.enqueues + self.requeues);
        reg.set_counter("pending_drains", self.drains);
    }
}

/// Coarse feasibility after hypothetically removing `victims` from
/// `node`: scalar cpu/mem headroom plus a demand-shaped GPU check on a
/// simulated allocation vector. Deliberately conservative/coarse — the
/// real placement retry (filters + scoring) remains the authority; this
/// only avoids evicting tenants when no amount of budgeted eviction
/// could possibly help.
fn fits_after_eviction(node: &Node, task: &Task, victims: &[&ResidentRecord]) -> bool {
    const EPS: f64 = 1e-9;
    let freed_cpu: f64 = victims.iter().map(|v| v.task.cpu).sum();
    let freed_mem: f64 = victims.iter().map(|v| v.task.mem).sum();
    if node.vcpus - node.cpu_alloc + freed_cpu + EPS < task.cpu {
        return false;
    }
    if node.mem - node.mem_alloc + freed_mem + EPS < task.mem {
        return false;
    }
    let mut alloc = node.gpu_alloc.clone();
    for v in victims {
        match &v.placement {
            Placement::CpuOnly => {}
            Placement::Shared { gpu } => {
                alloc[*gpu] = (alloc[*gpu] - v.task.gpu.units()).max(0.0);
            }
            Placement::Whole { gpus } => {
                for &g in gpus {
                    alloc[g] = 0.0;
                }
            }
            Placement::MigSlice { gpu, .. } => {
                alloc[*gpu] = (alloc[*gpu] - v.task.gpu.units()).max(0.0);
            }
        }
    }
    match task.gpu {
        GpuDemand::Zero => true,
        GpuDemand::Whole(k) => alloc.iter().filter(|a| **a <= EPS).count() >= k as usize,
        GpuDemand::Frac(f) => alloc.iter().any(|a| 1.0 - *a + EPS >= f),
        GpuDemand::Mig(p) => alloc.iter().any(|a| 1.0 - *a + EPS >= p.units()),
    }
}

/// Sim-loop driver state: owns the shared core plus per-task placement
/// epochs (so a departure event scheduled for an evicted-and-replaced
/// task can be recognized as stale and skipped).
#[derive(Debug)]
pub struct FairnessState {
    shared: FairnessShared,
    epochs: HashMap<u64, u64>,
}

impl FairnessState {
    /// Fresh driver state with its own shared core.
    pub fn new(cfg: FairnessConfig) -> FairnessState {
        FairnessState { shared: shared(cfg), epochs: HashMap::new() }
    }

    /// Handle for [`crate::sched::framework::Scheduler::bind_fairness`]
    /// and direct core access.
    pub fn shared(&self) -> &FairnessShared {
        &self.shared
    }

    /// Run `f` with the locked core (panic-free: a poisoned lock —
    /// impossible in the single-threaded sim loops — yields the
    /// default).
    pub fn with_core<T: Default>(&self, f: impl FnOnce(&mut FairnessCore) -> T) -> T {
        match self.shared.lock() {
            Ok(mut core) => f(&mut core),
            Err(_) => T::default(),
        }
    }

    /// Advance the shared fairness clock.
    pub fn set_now(&self, now: f64) {
        self.with_core(|c| c.set_now(now));
    }

    /// Current placement epoch of a task (0 before first placement).
    pub fn epoch(&self, id: u64) -> u64 {
        self.epochs.get(&id).copied().unwrap_or(0)
    }

    /// Bump and return the placement epoch for a (re)placed task.
    pub fn bump_epoch(&mut self, id: u64) -> u64 {
        let e = self.epochs.entry(id).or_insert(0);
        *e += 1;
        *e
    }
}

/// `mod(starve:<threshold>:<boost>)` — while the observed p99 wait
/// exceeds `threshold`, shift a `boost` fraction of the power weight
/// (slot 0, `PWR`) onto the remaining packing/FGD objectives,
/// proportionally to their base weights (equal split when all zero).
/// Inert until bound to a fairness core.
pub struct StarveModulator {
    threshold: f64,
    boost: f64,
    shared: Option<FairnessShared>,
}

impl StarveModulator {
    /// `threshold` must be positive and finite; `boost` in `[0, 1]`.
    pub fn new(threshold: f64, boost: f64) -> StarveModulator {
        StarveModulator { threshold, boost, shared: None }
    }
}

impl WeightModulator for StarveModulator {
    fn name(&self) -> &'static str {
        "starve"
    }

    fn check_layout(&self, plugin_names: &[&str]) -> Result<(), String> {
        if plugin_names.first() != Some(&"PWR") || plugin_names.len() < 2 {
            return Err(format!(
                "mod(starve) expects score layout [PWR, <packing>, ...], got {plugin_names:?}"
            ));
        }
        Ok(())
    }

    fn bind_fairness(&mut self, shared: &FairnessShared) {
        self.shared = Some(shared.clone());
    }

    fn modulate(&self, _dc: &Datacenter, base: &[f64], weights: &mut [f64]) -> Option<f64> {
        let Some(shared) = &self.shared else { return None };
        let p99 = match shared.lock() {
            Ok(core) => core.p99_wait(),
            Err(_) => return None,
        };
        if !(p99 > self.threshold) || base.len() < 2 {
            return None;
        }
        let freed = base[0] * self.boost;
        weights[0] = base[0] - freed;
        let rest: f64 = base[1..].iter().sum();
        if rest > 0.0 {
            for (w, b) in weights[1..].iter_mut().zip(&base[1..]) {
                *w = *b + freed * (*b / rest);
            }
        } else {
            let share = freed / (base.len() - 1) as f64;
            for w in weights[1..].iter_mut() {
                *w = share;
            }
        }
        None
    }
}

/// `hook(preempt:<max_evictions>)` — postFail hook that frees capacity
/// for a failed non-best-effort arrival by evicting up to
/// `max_evictions` strictly-lower-priority residents from a single
/// node (victims re-enter the pending queue via the fairness core's
/// eviction outbox). Inert until bound to a fairness core.
pub struct PreemptHook {
    max_evictions: u64,
    shared: Option<FairnessShared>,
    evictions: u64,
    triggers: u64,
}

impl PreemptHook {
    /// Budget of evictions per failed placement.
    pub fn new(max_evictions: u64) -> PreemptHook {
        PreemptHook { max_evictions, shared: None, evictions: 0, triggers: 0 }
    }
}

impl PostHook for PreemptHook {
    fn name(&self) -> &'static str {
        "preempt"
    }

    fn bind_fairness(&mut self, shared: &FairnessShared) {
        self.shared = Some(shared.clone());
    }

    fn post_fail(
        &mut self,
        dc: &mut Datacenter,
        task: &Task,
        invalidate: &mut dyn FnMut(usize),
    ) -> bool {
        let Some(shared) = &self.shared else { return false };
        let Ok(mut core) = shared.lock() else { return false };
        let n = core.preempt_for(dc, task, self.max_evictions, invalidate);
        if n == 0 {
            return false;
        }
        self.triggers += 1;
        self.evictions += n;
        true
    }

    fn counters(&self) -> Vec<(&'static str, u64)> {
        vec![("preempt_evictions", self.evictions), ("preempt_triggers", self.triggers)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;

    fn t(id: u64, prio: u8) -> Task {
        Task::new(id, 2.0, 512.0, GpuDemand::Whole(1)).with_priority(prio)
    }

    #[test]
    fn queue_is_fifo_within_priority() {
        let mut core = FairnessCore::new(FairnessConfig::default());
        core.enqueue(t(0, 0), false);
        core.enqueue(t(1, 2), false);
        core.enqueue(t(2, 1), false);
        core.enqueue(t(3, 2), false);
        core.enqueue(t(4, 0), false);
        let order: Vec<u64> = core.pending_entries().iter().map(|e| e.task.id).collect();
        assert_eq!(order, vec![1, 3, 2, 0, 4]);
        assert_eq!(core.head().unwrap().id, 1);
        assert_eq!(core.pop_placed().unwrap().task.id, 1);
        assert_eq!(core.head().unwrap().id, 3);
    }

    #[test]
    fn starvation_ledger_fires_once_per_entry() {
        let mut core = FairnessCore::new(FairnessConfig { starve_threshold: 10.0 });
        core.enqueue(t(0, 0), false);
        core.set_now(5.0);
        assert_eq!(core.starvation_events(), 0);
        core.set_now(11.0);
        assert_eq!(core.starvation_events(), 1);
        core.set_now(500.0);
        assert_eq!(core.starvation_events(), 1, "one event per queue stint");
        core.enqueue(t(1, 0), false);
        core.set_now(511.0);
        assert_eq!(core.starvation_events(), 2);
    }

    #[test]
    fn wait_accounting_p99_and_oldest_age() {
        let mut core = FairnessCore::new(FairnessConfig::default());
        core.enqueue(t(0, 0), false);
        core.set_now(40.0);
        core.enqueue(t(1, 0), false);
        core.set_now(100.0);
        assert!((core.oldest_pending_age() - 100.0).abs() < 1e-9);
        // p99 over pending ages {100, 60} → nearest-rank max.
        assert!((core.p99_wait() - 100.0).abs() < 1e-9);
        core.pop_placed();
        core.pop_placed();
        assert_eq!(core.pending_depth(), 0);
        assert_eq!(core.oldest_pending_age(), 0.0);
        core.set_now(101.0);
        // Completed waits {100, 61} persist in the p99 sample.
        assert!((core.p99_wait() - 100.0).abs() < 1e-9);
        assert_eq!(core.drains(), 2);
    }

    #[test]
    fn oldest_age_monotone_across_failed_retries() {
        let mut core = FairnessCore::new(FairnessConfig::default());
        core.enqueue(t(0, 0), false);
        let mut last = 0.0;
        for step in 1..20 {
            core.set_now(step as f64 * 3.0);
            let age = core.oldest_pending_age();
            assert!(age >= last, "age must not shrink while the entry waits");
            last = age;
        }
    }

    #[test]
    fn preempt_evicts_only_lower_priority_and_restores_resources() {
        let mut dc = ClusterSpec::tiny(1, 4, 0).build();
        let mut core = FairnessCore::new(FairnessConfig::default());
        // Fill the node: 3 best-effort + 1 high-priority whole-GPU tasks.
        for id in 0..4u64 {
            let prio = if id == 3 { 2 } else { 0 };
            let task = t(id, prio);
            let p = dc.nodes[0].candidate_placements(&task).pop().unwrap();
            dc.allocate(&task, 0, &p);
            core.note_resident(&task, 0, &p);
        }
        let free_before = dc.gpu_free_units();
        assert!(free_before < 1.0, "node saturated");
        let mut invalidated = Vec::new();
        let arrival = t(10, 1);
        let n = core.preempt_for(&mut dc, &arrival, 2, &mut |n| invalidated.push(n));
        assert_eq!(n, 1, "one eviction frees one whole GPU");
        assert_eq!(invalidated, vec![0]);
        assert!((dc.gpu_free_units() - (free_before + 1.0)).abs() < 1e-9);
        let ids = core.requeue_evicted();
        assert_eq!(ids.len(), 1);
        let victim = &core.pending_entries()[0];
        assert!(victim.requeued);
        assert_eq!(victim.task.priority, 0, "never evict equal-or-higher priority");
        assert!(ids[0] != 3, "the priority-2 resident survives");
        // A same-priority arrival finds nothing to evict (only the
        // priority-2 task and the arrival's own tier remain eligible).
        core.forget_resident(ids[0]);
        let blocked = core.preempt_for(&mut dc, &t(11, 0), 4, &mut |_| {});
        assert_eq!(blocked, 0, "best-effort arrivals never preempt");
    }

    #[test]
    fn preempt_budget_respected_and_noop_when_infeasible() {
        let mut dc = ClusterSpec::tiny(1, 4, 0).build();
        let mut core = FairnessCore::new(FairnessConfig::default());
        for id in 0..4u64 {
            let task = t(id, 0);
            let p = dc.nodes[0].candidate_placements(&task).pop().unwrap();
            dc.allocate(&task, 0, &p);
            core.note_resident(&task, 0, &p);
        }
        let free = dc.gpu_free_units();
        // Needs 3 GPUs freed but budget is 2 → refuse, touch nothing.
        let big = Task::new(20, 2.0, 512.0, GpuDemand::Whole(3)).with_priority(1);
        let n = core.preempt_for(&mut dc, &big, 2, &mut |_| {});
        assert_eq!(n, 0);
        assert_eq!(dc.gpu_free_units(), free, "no partial evictions");
        assert_eq!(core.preemptions(), 0);
        // Budget 3 suffices; youngest best-effort tenants go first.
        let n = core.preempt_for(&mut dc, &big, 3, &mut |_| {});
        assert_eq!(n, 3);
        let ids = core.requeue_evicted();
        assert_eq!(ids, vec![3, 2, 1], "youngest (highest id) evicted first");
    }

    #[test]
    fn starve_modulator_shifts_weight_only_past_threshold() {
        let fs = shared(FairnessConfig::default());
        let mut m = StarveModulator::new(50.0, 0.5);
        assert!(m.check_layout(&["PWR", "FGD"]).is_ok());
        assert!(m.check_layout(&["FGD", "PWR"]).is_err());
        assert!(m.check_layout(&["PWR"]).is_err());
        let dc = ClusterSpec::tiny(1, 2, 0).build();
        let base = [0.8, 0.2];
        let mut w = base;
        // Unbound → inert.
        assert!(m.modulate(&dc, &base, &mut w).is_none());
        assert_eq!(w, base);
        m.bind_fairness(&fs);
        // Bound but p99 below threshold → still inert.
        m.modulate(&dc, &base, &mut w);
        assert_eq!(w, base);
        // Push p99 past the threshold.
        if let Ok(mut core) = fs.lock() {
            core.enqueue(Task::new(0, 1.0, 1.0, GpuDemand::Zero), false);
            core.set_now(100.0);
            assert!(core.p99_wait() > 50.0);
        }
        m.modulate(&dc, &base, &mut w);
        assert!((w[0] - 0.4).abs() < 1e-9, "half the PWR mass moved");
        assert!((w[1] - 0.6).abs() < 1e-9, "packing weight absorbs it");
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn preempt_hook_inert_until_bound() {
        let mut dc = ClusterSpec::tiny(1, 2, 0).build();
        let mut hook = PreemptHook::new(4);
        let arrival = t(0, 2);
        let mut calls = 0usize;
        assert!(!hook.post_fail(&mut dc, &arrival, &mut |_| calls += 1));
        assert_eq!(calls, 0);
        assert_eq!(hook.counters(), vec![("preempt_evictions", 0), ("preempt_triggers", 0)]);
    }

    #[test]
    fn publish_writes_catalogued_keys() {
        let mut core = FairnessCore::new(FairnessConfig { starve_threshold: 1.0 });
        core.enqueue(t(0, 0), false);
        core.set_now(5.0);
        let mut reg = MetricsRegistry::with_catalog();
        core.publish(&mut reg);
        assert_eq!(reg.gauge("pending_depth"), 1.0);
        assert!(reg.gauge("p99_wait") > 0.0);
        assert!(reg.gauge("oldest_pending_age") > 0.0);
        assert_eq!(reg.counter("starvation_events"), 1);
        assert_eq!(reg.counter("pending_enqueues"), 1);
        assert_eq!(reg.counter("pending_drains"), 0);
    }
}
