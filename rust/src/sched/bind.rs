//! The `bind` extension point: how the chosen node's concrete GPU
//! placement is selected once the score plugins have picked a node.
//!
//! Binding is a [`BindPlugin`] trait (the k8s `Bind` extension-point
//! analog); the five built-in binders live here and are registered
//! under string keys in [`crate::sched::profile`]:
//!
//! | key         | binder                     | semantics                          |
//! |-------------|----------------------------|------------------------------------|
//! | `weighted:α`| [`WeightedBinder`]         | min `α·Δpower + (1−α)·Δfrag`       |
//! | `bestfit`   | [`BestFitBinder`]          | least GPU residual after placing   |
//! | `packed`    | [`PackOccupiedBinder`]     | occupied GPUs first, then best-fit |
//! | `first`     | [`FirstBinder`]            | lowest GPU index                   |
//! | `random`    | [`RandomBinder`]           | uniform over candidates (seeded)   |
//!
//! The framework only consults the binder when a node offers ≥ 2
//! candidate placements (a single candidate is bound directly), so
//! plugins may assume `placements.len() >= 2`.

use std::cell::RefCell;

use crate::cluster::node::{Node, Placement, ResourceView, EPS};
use crate::frag;
use crate::sched::framework::power_delta;
use crate::tasks::Task;
use crate::util::rng::Rng;

/// Context handed to bind plugins.
pub struct BindCtx<'a> {
    /// Hot-loop form of the target workload.
    pub prepared: &'a frag::PreparedWorkload,
    /// Per-decision α retarget from the weight modulator, if any
    /// (honored by [`WeightedBinder`], ignored by the rest).
    pub alpha_override: Option<f64>,
}

/// A bind plugin: selects the concrete placement on the already-chosen
/// node from the (deduped, all-legal, ≥ 2) candidates.
pub trait BindPlugin: Send {
    fn name(&self) -> &'static str;
    fn bind(&self, ctx: &BindCtx, node: &Node, task: &Task, placements: &[Placement]) -> Placement;
}

/// Minimize `α·Δpower + (1−α)·Δfrag` over candidate placements (each
/// term min-max normalized across the candidates). `α=1` ⇒ pure PWR,
/// `α=0` ⇒ pure FGD — mirrors the node-level k8s combination at
/// placement granularity.
pub struct WeightedBinder {
    pub alpha: f64,
}

impl BindPlugin for WeightedBinder {
    fn name(&self) -> &'static str {
        "weighted"
    }

    fn bind(&self, ctx: &BindCtx, node: &Node, task: &Task, placements: &[Placement]) -> Placement {
        let alpha = ctx.alpha_override.unwrap_or(self.alpha);
        let before = frag::f_node_fast(node, ctx.prepared);
        let dp: Vec<f64> = placements.iter().map(|p| power_delta(node, task, p)).collect();
        let df: Vec<f64> = placements
            .iter()
            .map(|p| frag::frag_delta_fast(node, task, p, ctx.prepared, before))
            .collect();
        let norm = |v: &[f64]| -> Vec<f64> {
            let lo = v.iter().copied().fold(f64::INFINITY, f64::min);
            let hi = v.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            if hi - lo < 1e-12 {
                vec![0.0; v.len()]
            } else {
                v.iter().map(|x| (x - lo) / (hi - lo)).collect()
            }
        };
        let (dpn, dfn) = (norm(&dp), norm(&df));
        let mut best = 0;
        let mut best_cost = f64::INFINITY;
        for i in 0..placements.len() {
            let cost = alpha * dpn[i] + (1.0 - alpha) * dfn[i];
            if cost < best_cost - 1e-12 {
                best_cost = cost;
                best = i;
            }
        }
        placements[best].clone()
    }
}

/// Best-fit on the GPU residual: pick the feasible GPU with the least
/// leftover fraction (the open-simulator default).
pub struct BestFitBinder;

impl BindPlugin for BestFitBinder {
    fn name(&self) -> &'static str {
        "bestfit"
    }

    fn bind(&self, _ctx: &BindCtx, node: &Node, _task: &Task, placements: &[Placement]) -> Placement {
        best_fit_gpu(node, placements)
    }
}

/// Prefer already-occupied GPUs, then pack best-fit (MLaaS tiers).
pub struct PackOccupiedBinder;

impl BindPlugin for PackOccupiedBinder {
    fn name(&self) -> &'static str {
        "packed"
    }

    fn bind(&self, _ctx: &BindCtx, node: &Node, _task: &Task, placements: &[Placement]) -> Placement {
        let occupied: Vec<Placement> = placements
            .iter()
            .filter(|p| matches!(p, Placement::Shared { gpu } if node.gpu_alloc[*gpu] > 0.0))
            .cloned()
            .collect();
        if !occupied.is_empty() {
            best_fit_gpu(node, &occupied)
        } else {
            best_fit_gpu(node, placements)
        }
    }
}

/// First candidate (lowest GPU index).
pub struct FirstBinder;

impl BindPlugin for FirstBinder {
    fn name(&self) -> &'static str {
        "first"
    }

    fn bind(&self, _ctx: &BindCtx, _node: &Node, _task: &Task, placements: &[Placement]) -> Placement {
        placements[0].clone()
    }
}

/// Uniformly random candidate (seeded, reproducible).
pub struct RandomBinder {
    rng: RefCell<Rng>,
}

impl RandomBinder {
    pub fn new(seed: u64) -> RandomBinder {
        RandomBinder { rng: RefCell::new(Rng::new(seed)) }
    }
}

impl BindPlugin for RandomBinder {
    fn name(&self) -> &'static str {
        "random"
    }

    fn bind(&self, _ctx: &BindCtx, _node: &Node, _task: &Task, placements: &[Placement]) -> Placement {
        let i = self.rng.borrow_mut().below(placements.len());
        placements[i].clone()
    }
}

/// Best-fit on GPU residual: least leftover after placing. For MIG
/// placements the residual is the target GPU's free-slice fraction, so
/// instances pack onto the fullest GPU that still has a legal start
/// (ties → the profile's preferred start order).
pub fn best_fit_gpu(node: &Node, placements: &[Placement]) -> Placement {
    let mut best = 0;
    let mut best_free = f64::INFINITY;
    for (i, p) in placements.iter().enumerate() {
        let free = match p {
            Placement::Shared { gpu } | Placement::MigSlice { gpu, .. } => node.gpu_free_of(*gpu),
            _ => return p.clone(), // whole/CPU placements are canonical
        };
        if free < best_free - EPS {
            best_free = free;
            best = i;
        }
    }
    placements[best].clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::types::{CpuModel, GpuModel};
    use crate::tasks::{GpuDemand, Workload};

    fn node4() -> Node {
        Node::new(0, CpuModel::XeonE5_2682V4, Some(GpuModel::G2), 96.0, 393_216.0, 4)
    }

    #[test]
    fn weighted_binder_honors_alpha_override() {
        // GPU0 half-full, GPU1 empty: α=1 (pure power) packs onto the
        // occupied GPU; the override must win over the stored α.
        let mut node = node4();
        node.allocate(&Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.5)), &Placement::Shared { gpu: 0 });
        let w = Workload::default();
        let prepared = frag::PreparedWorkload::new(&w);
        let t = Task::new(2, 1.0, 0.0, GpuDemand::Frac(0.25));
        let ps = vec![Placement::Shared { gpu: 0 }, Placement::Shared { gpu: 1 }];
        let b = WeightedBinder { alpha: 0.0 };
        let ctx = BindCtx { prepared: &prepared, alpha_override: Some(1.0) };
        assert_eq!(b.bind(&ctx, &node, &t, &ps), Placement::Shared { gpu: 0 });
    }

    #[test]
    fn pack_occupied_prefers_powered_gpu() {
        let mut node = node4();
        node.allocate(&Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.5)), &Placement::Shared { gpu: 2 });
        let w = Workload::default();
        let prepared = frag::PreparedWorkload::new(&w);
        let ctx = BindCtx { prepared: &prepared, alpha_override: None };
        let t = Task::new(2, 1.0, 0.0, GpuDemand::Frac(0.25));
        let ps = vec![
            Placement::Shared { gpu: 0 },
            Placement::Shared { gpu: 2 },
            Placement::Shared { gpu: 3 },
        ];
        assert_eq!(
            PackOccupiedBinder.bind(&ctx, &node, &t, &ps),
            Placement::Shared { gpu: 2 }
        );
        // First binder stays positional.
        assert_eq!(FirstBinder.bind(&ctx, &node, &t, &ps), Placement::Shared { gpu: 0 });
    }
}
