//! `SchedulerProfile` — the k8s-scheduler-profile analog: a declarative
//! description of one scheduler assembled from the framework's named
//! extension points, plus the textual policy-spec DSL behind `--policy`.
//!
//! ## Extension points and registries
//!
//! A profile names entries in five string-keyed registries (built-ins
//! below; [`register_score_plugin`] & co. add custom entries at
//! runtime):
//!
//! * `score` — N weighted [`ScorePlugin`]s: `pwr`, `fgd`, `bestfit`,
//!   `dotprod`, `gpupacking`, `gpuclustering`, `firstfit`, `random`,
//!   `slicefit`, `consolidate`.
//! * `bind` — one [`BindPlugin`](crate::sched::bind::BindPlugin):
//!   `weighted:α`, `bestfit`, `packed`, `first`, `random`.
//! * `mod` — at most one
//!   [`WeightModulator`](crate::sched::modulate::WeightModulator):
//!   `loadalpha:α_empty:α_full`, `latticealpha:α_base:α_a100:α_a30`.
//! * `hook` — any number of [`PostHook`]s: `repartition` (the MIG
//!   defragmenter; optional `:frag_threshold[:max_moved[:budget]]`)
//!   and `drs` (the node sleep/wake lifecycle,
//!   [`crate::sched::drs`]; optional
//!   `:idle_timeout[:wake_latency[:sleep_j[:wake_j]]]`).
//! * `filter` — the feasibility chain
//!   ([`FilterPlugin`](crate::sched::filter::FilterPlugin)):
//!   `resources`, `gpumodel`, `miglattice`, `labels[:key=value...]`,
//!   `affinity`, `drs`. Omitted = the default chain (legacy `can_fit`
//!   + constraint plugins + the power-state gate;
//!   placement-identical on constraint-free traces with every node
//!   awake).
//!
//! ## DSL grammar
//!
//! ```text
//! profile  := section ('|' section)*
//! section  := 'score(' entry (',' entry)* ')'      -- required, exactly one
//!           | 'bind(' key (':' num)* ')'           -- default bind(bestfit)
//!           | 'mod(' key (':' num)* ')'            -- optional
//!           | 'hook(' key (':' num)* ')'           -- repeatable
//!           | 'filter(' fentry (',' fentry)* ')'   -- optional, at most one
//!           | 'sample(' int ')'                    -- optional, % of nodes to score (default 100)
//!           | 'shards(' int ')'                    -- optional, parallel score shards (default 1)
//! entry    := key ('=' num)?                       -- weight defaults to 1
//! fentry   := key (':' selector)*                  -- selector := lkey '=' lvalue
//! ```
//!
//! `sample` is the `percentageOfNodesToScore` analog (the scale-out
//! fast path, [`crate::sched::framework`] module docs): below 100 the
//! feasibility sweep stops after a target share of the candidate
//! universe, trading placement quality for throughput. `shards` splits
//! the scoring loop across that many OS threads; pure (cacheable)
//! plugins are bit-identical at any shard count, so it is a
//! latency-only knob.
//!
//! Example — three objectives, load-adaptive weights, proactive MIG
//! defrag:
//!
//! ```text
//! score(pwr=0.5,fgd=0.3,dotprod=0.2)|bind(weighted:0.5)|mod(loadalpha:0.9:0.0)|hook(repartition:0.5)
//! ```
//!
//! Every legacy [`PolicyKind`] string (`pwrfgd:0.1`, `mig-fgd`, …)
//! remains valid sugar: it lowers to an equivalent profile whose label
//! is byte-identical to the pre-profile scheduler's, so CSV headers and
//! pinned experiment outputs are unchanged
//! (`rust/tests/profile_equivalence.rs` locks this).

use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

use crate::sched::bind::{
    BestFitBinder, BindPlugin, FirstBinder, PackOccupiedBinder, RandomBinder, WeightedBinder,
};
use crate::sched::drs::{ConsolidatePlugin, DrsConfig, DrsFilter, DrsHook};
use crate::sched::fairness::{PreemptHook, StarveModulator};
use crate::sched::filter::{
    AffinityFilter, FilterPlugin, GpuModelFilter, LabelsFilter, MigLatticeFilter,
    ResourcesFilter,
};
use crate::sched::framework::{PostHook, Scheduler, ScorePlugin};
use crate::sched::modulate::{LatticeAlphaModulator, LoadAlphaModulator, WeightModulator};
use crate::sched::policies::{
    BestFitPlugin, DotProdPlugin, FgdPlugin, FirstFitPlugin, GpuClusteringPlugin,
    GpuPackingPlugin, MigRepartitioner, MigSliceFitPlugin, PwrPlugin, RandomPlugin,
    RepartitionConfig,
};
use crate::sched::PolicyKind;

/// Seeds matching the pre-profile hard-wired policy zoo (reproducible
/// runs; `rust/tests/profile_equivalence.rs` pins the equivalence).
const RANDOM_PLUGIN_SEED: u64 = 0x5EED;
const RANDOM_BINDER_SEED: u64 = 0xB14D;

/// A declarative scheduler assembly: what to build at each extension
/// point. Plain data — `Clone + Send`, so experiment harnesses ship it
/// across repetition threads and build one `Scheduler` per thread.
#[derive(Clone, Debug, PartialEq)]
pub struct SchedulerProfile {
    /// `score` extension point: (registry key, weight) per plugin.
    pub score: Vec<(String, f64)>,
    /// `bind` extension point: registry key + numeric params.
    pub bind: (String, Vec<f64>),
    /// `weightModulator` extension point (at most one).
    pub modulator: Option<(String, Vec<f64>)>,
    /// `postPlace`/`postFail` hooks, in attachment order.
    pub hooks: Vec<(String, Vec<f64>)>,
    /// `filter` extension point: (registry key, string params) per
    /// plugin, evaluated as a conjunction in order. String params carry
    /// selector syntax (`labels:zone=z1`). Empty = the built-in
    /// [`default_filter_keys`] chain.
    pub filters: Vec<(String, Vec<String>)>,
    /// `sample(<pct>)`: percentage of the candidate universe the
    /// feasibility sweep targets before scoring (the
    /// `percentageOfNodesToScore` analog). 100 (the default) is the
    /// exhaustive, bit-identical legacy sweep.
    pub sample_pct: u32,
    /// `shards(<n>)`: scoring-loop parallelism. 1 (the default) is the
    /// sequential legacy loop; pure plugins score bit-identically at
    /// any value.
    pub score_shards: usize,
    /// Report/CSV label. Legacy policies keep their [`PolicyKind::label`]
    /// byte-for-byte; DSL profiles get a canonical compact label.
    pub label: String,
}

/// The registry keys of the default filter chain — derived from
/// [`crate::sched::filter::default_filter_chain`] itself (plugin names
/// double as registry keys), so the key list cannot drift from the
/// chain `Scheduler::new` installs.
pub fn default_filter_keys() -> Vec<(String, Vec<String>)> {
    crate::sched::filter::default_filter_chain()
        .iter()
        .map(|f| (f.name().to_string(), Vec::new()))
        .collect()
}

impl From<PolicyKind> for SchedulerProfile {
    fn from(kind: PolicyKind) -> SchedulerProfile {
        lower(kind)
    }
}

impl SchedulerProfile {
    /// Parse a `--policy` string: every legacy [`PolicyKind`] name is
    /// accepted as sugar (lowered to an equivalent profile, identical
    /// label); anything containing `(` is parsed as the profile DSL and
    /// validated eagerly (unknown keys / bad params fail here, not at
    /// simulation time).
    pub fn parse(s: &str) -> Result<SchedulerProfile, String> {
        if let Some(kind) = PolicyKind::parse(s) {
            return Ok(kind.into());
        }
        if s.contains('(') {
            let p = parse_dsl(s)?;
            p.build()?; // eager validation of keys and params
            return Ok(p);
        }
        Err(format!(
            "unknown policy '{s}': neither a legacy policy name (fgd, pwr, pwrfgd:<α∈[0,1]>, \
             mig-pwrfgd:<α>, …) nor a profile DSL like \
             'score(pwr=0.5,fgd=0.3,dotprod=0.2)|bind(weighted:0.5)' (see docs/scheduler.md)"
        ))
    }

    /// Materialize the scheduler: resolve every key against its
    /// registry and wire the extension points.
    pub fn build(&self) -> Result<Scheduler, String> {
        if self.score.is_empty() {
            return Err("profile has no score plugins".into());
        }
        let mut plugins: Vec<(Box<dyn ScorePlugin>, f64)> = Vec::new();
        for (key, weight) in &self.score {
            if !weight.is_finite() || *weight < 0.0 {
                return Err(format!(
                    "score weight for '{key}' must be finite and >= 0, got {weight}"
                ));
            }
            plugins.push((build_score_plugin(key)?, *weight));
        }
        if !self.score.iter().any(|(_, w)| *w > 0.0) {
            return Err("at least one score weight must be > 0".into());
        }
        // Modulators carry layout contracts (e.g. loadalpha requires
        // the power plugin first); check against the resolved plugin
        // names *before* assembly so a violation is an eager Err, not a
        // debug-only panic downstream.
        let modulator = match &self.modulator {
            Some((key, params)) => {
                let m = build_modulator(key, params)?;
                let names: Vec<&str> = plugins.iter().map(|(p, _)| p.name()).collect();
                m.check_layout(&names).map_err(|e| format!("mod({key}:…): {e}"))?;
                Some(m)
            }
            None => None,
        };
        let binder = build_binder(&self.bind.0, &self.bind.1)?;
        // Resolve the filter chain eagerly (unknown keys / bad selector
        // syntax fail here). Empty = keep the default chain that
        // `Scheduler::new` installs.
        let filters: Option<Vec<Box<dyn FilterPlugin>>> = if self.filters.is_empty() {
            None
        } else {
            let mut fs = Vec::with_capacity(self.filters.len());
            for (key, params) in &self.filters {
                fs.push(build_filter(key, params)?);
            }
            Some(fs)
        };
        if !(1..=100).contains(&self.sample_pct) {
            return Err(format!(
                "sample(<pct>): percentage must be in [1, 100], got {}",
                self.sample_pct
            ));
        }
        if self.score_shards == 0 {
            return Err("shards(<n>): shard count must be >= 1".into());
        }
        let mut sched = Scheduler::new(plugins, binder, &self.label);
        sched.set_sample_pct(self.sample_pct);
        sched.set_score_shards(self.score_shards);
        if let Some(fs) = filters {
            sched.set_filters(fs);
        }
        if let Some(m) = modulator {
            sched.set_modulator(m);
        }
        for (key, params) in &self.hooks {
            sched.add_post_hook(build_hook(key, params)?);
        }
        Ok(sched)
    }
}

/// Lower a legacy [`PolicyKind`] to its equivalent profile (same
/// plugins, weights, binder and label as the pre-profile hard-wired
/// zoo; the MIG variants share their non-MIG twin's wiring because the
/// frag/power layers are slice-aware).
fn lower(kind: PolicyKind) -> SchedulerProfile {
    let label = kind.label();
    let s = |k: &str, w: f64| (k.to_string(), w);
    let (score, bind, modulator) = match kind {
        PolicyKind::Fgd | PolicyKind::MigFgd => {
            (vec![s("fgd", 1.0)], ("weighted".to_string(), vec![0.0]), None)
        }
        PolicyKind::Pwr | PolicyKind::MigPwr => {
            (vec![s("pwr", 1.0)], ("weighted".to_string(), vec![1.0]), None)
        }
        PolicyKind::PwrFgd { alpha } | PolicyKind::MigPwrFgd { alpha } => (
            vec![s("pwr", alpha), s("fgd", 1.0 - alpha)],
            ("weighted".to_string(), vec![alpha]),
            None,
        ),
        PolicyKind::PwrFgdDynamic { alpha_empty, alpha_full } => (
            vec![s("pwr", alpha_empty), s("fgd", 1.0 - alpha_empty)],
            ("weighted".to_string(), vec![alpha_empty]),
            Some(("loadalpha".to_string(), vec![alpha_empty, alpha_full])),
        ),
        PolicyKind::BestFit | PolicyKind::MigBestFit => {
            (vec![s("bestfit", 1.0)], ("bestfit".to_string(), vec![]), None)
        }
        PolicyKind::MigSliceFit => {
            (vec![s("slicefit", 1.0)], ("bestfit".to_string(), vec![]), None)
        }
        PolicyKind::DotProd => (vec![s("dotprod", 1.0)], ("bestfit".to_string(), vec![]), None),
        PolicyKind::GpuPacking => {
            (vec![s("gpupacking", 1.0)], ("packed".to_string(), vec![]), None)
        }
        PolicyKind::GpuClustering => {
            (vec![s("gpuclustering", 1.0)], ("bestfit".to_string(), vec![]), None)
        }
        PolicyKind::FirstFit => (vec![s("firstfit", 1.0)], ("first".to_string(), vec![]), None),
        PolicyKind::Random => (vec![s("random", 1.0)], ("random".to_string(), vec![]), None),
    };
    SchedulerProfile {
        score,
        bind,
        modulator,
        hooks: Vec::new(),
        filters: default_filter_keys(),
        sample_pct: 100,
        score_shards: 1,
        label,
    }
}

// ---------------------------------------------------------------------
// Registries: built-ins resolved by match, runtime extensions in global
// string-keyed maps.
// ---------------------------------------------------------------------

type ScoreFactory = Arc<dyn Fn() -> Box<dyn ScorePlugin> + Send + Sync>;
type BindFactory = Arc<dyn Fn(&[f64]) -> Result<Box<dyn BindPlugin>, String> + Send + Sync>;
type ModulatorFactory =
    Arc<dyn Fn(&[f64]) -> Result<Box<dyn WeightModulator>, String> + Send + Sync>;
type HookFactory = Arc<dyn Fn(&[f64]) -> Result<Box<dyn PostHook>, String> + Send + Sync>;
type FilterFactory =
    Arc<dyn Fn(&[String]) -> Result<Box<dyn FilterPlugin>, String> + Send + Sync>;

fn score_ext() -> &'static RwLock<HashMap<String, ScoreFactory>> {
    static REG: OnceLock<RwLock<HashMap<String, ScoreFactory>>> = OnceLock::new();
    REG.get_or_init(Default::default)
}

fn bind_ext() -> &'static RwLock<HashMap<String, BindFactory>> {
    static REG: OnceLock<RwLock<HashMap<String, BindFactory>>> = OnceLock::new();
    REG.get_or_init(Default::default)
}

fn modulator_ext() -> &'static RwLock<HashMap<String, ModulatorFactory>> {
    static REG: OnceLock<RwLock<HashMap<String, ModulatorFactory>>> = OnceLock::new();
    REG.get_or_init(Default::default)
}

fn hook_ext() -> &'static RwLock<HashMap<String, HookFactory>> {
    static REG: OnceLock<RwLock<HashMap<String, HookFactory>>> = OnceLock::new();
    REG.get_or_init(Default::default)
}

fn filter_ext() -> &'static RwLock<HashMap<String, FilterFactory>> {
    static REG: OnceLock<RwLock<HashMap<String, FilterFactory>>> = OnceLock::new();
    REG.get_or_init(Default::default)
}

/// Register a custom score plugin under `key` (later profiles may name
/// it in `score(...)`). Built-in keys cannot be shadowed.
pub fn register_score_plugin(
    key: &str,
    factory: impl Fn() -> Box<dyn ScorePlugin> + Send + Sync + 'static,
) -> Result<(), String> {
    // The DSL lowercases keys, so registration must too or the entry
    // would be unreachable from --policy strings.
    let key = key.to_ascii_lowercase();
    if BUILTIN_SCORE.iter().any(|(k, _, _)| *k == key) {
        return Err(format!("'{key}' is a built-in score plugin"));
    }
    score_ext().write().unwrap().insert(key, Arc::new(factory));
    Ok(())
}

/// Register a custom filter plugin under `key` (later profiles may name
/// it in `filter(...)`; params arrive as raw strings, so selector-style
/// arguments are possible). Built-in keys cannot be shadowed.
pub fn register_filter_plugin(
    key: &str,
    factory: impl Fn(&[String]) -> Result<Box<dyn FilterPlugin>, String> + Send + Sync + 'static,
) -> Result<(), String> {
    let key = key.to_ascii_lowercase();
    if BUILTIN_FILTER.iter().any(|(k, _, _)| *k == key) {
        return Err(format!("'{key}' is a built-in filter plugin"));
    }
    filter_ext().write().unwrap().insert(key, Arc::new(factory));
    Ok(())
}

/// Register a custom bind plugin under `key`.
pub fn register_bind_plugin(
    key: &str,
    factory: impl Fn(&[f64]) -> Result<Box<dyn BindPlugin>, String> + Send + Sync + 'static,
) -> Result<(), String> {
    let key = key.to_ascii_lowercase();
    if BUILTIN_BIND.iter().any(|(k, _, _)| *k == key) {
        return Err(format!("'{key}' is a built-in binder"));
    }
    bind_ext().write().unwrap().insert(key, Arc::new(factory));
    Ok(())
}

/// Register a custom weight modulator under `key`.
pub fn register_modulator(
    key: &str,
    factory: impl Fn(&[f64]) -> Result<Box<dyn WeightModulator>, String> + Send + Sync + 'static,
) -> Result<(), String> {
    let key = key.to_ascii_lowercase();
    if BUILTIN_MODULATOR.iter().any(|(k, _, _)| *k == key) {
        return Err(format!("'{key}' is a built-in modulator"));
    }
    modulator_ext().write().unwrap().insert(key, Arc::new(factory));
    Ok(())
}

/// Register a custom post hook under `key`.
pub fn register_post_hook(
    key: &str,
    factory: impl Fn(&[f64]) -> Result<Box<dyn PostHook>, String> + Send + Sync + 'static,
) -> Result<(), String> {
    let key = key.to_ascii_lowercase();
    if BUILTIN_HOOK.iter().any(|(k, _, _)| *k == key) {
        return Err(format!("'{key}' is a built-in hook"));
    }
    hook_ext().write().unwrap().insert(key, Arc::new(factory));
    Ok(())
}

// Each built-in registry is ONE table of (key, description, factory):
// the lookup, the shadowing guard in `register_*`, the keys listed in
// error messages and the `repro list-plugins` catalog all derive from
// it, so a new entry cannot drift out of sync.

const BUILTIN_SCORE: &[(&str, &str, fn() -> Box<dyn ScorePlugin>)] = &[
    ("pwr", "−Δ estimated node power of the best placement (Eq. 2/Eq. 2-MIG)", || {
        Box::new(PwrPlugin)
    }),
    ("fgd", "−Δ expected fragmentation F_n(M) (Weng et al., slice-aware)", || {
        Box::new(FgdPlugin::new())
    }),
    ("bestfit", "tightest node fit (Protean-style best-fit)", || Box::new(BestFitPlugin)),
    ("dotprod", "demand/free-vector alignment (Tetris dot-product)", || {
        Box::new(DotProdPlugin)
    }),
    ("gpupacking", "MLaaS GPU-packing tiers", || Box::new(GpuPackingPlugin)),
    ("gpuclustering", "Gandiva-style affinity packing", || Box::new(GpuClusteringPlugin)),
    ("firstfit", "lowest-id feasible node", || Box::new(FirstFitPlugin)),
    ("random", "uniform random feasible node (seeded)", || {
        Box::new(RandomPlugin::new(RANDOM_PLUGIN_SEED))
    }),
    ("slicefit", "MIG slice packing (fullest GPU first, powered preferred)", || {
        Box::new(MigSliceFitPlugin)
    }),
    ("consolidate", "bias placements onto already-active nodes so DRS sleepers stay asleep", || {
        Box::new(ConsolidatePlugin)
    }),
    ("topo", "gang communication cost: PP/DP spans priced by topology bandwidth", || {
        Box::new(crate::sched::gang::TopoPlugin)
    }),
    ("zonespread", "soft class spreading: penalize nodes by resident same-class count", || {
        Box::new(crate::sched::gang::ZonespreadPlugin)
    }),
];

type BindBuilder = fn(&[f64]) -> Result<Box<dyn BindPlugin>, String>;
const BUILTIN_BIND: &[(&str, &str, BindBuilder)] = &[
    ("weighted", "minimize α·Δpower + (1−α)·Δfrag over candidates (weighted:α)", |params| {
        let [alpha] = params else {
            return Err(format!(
                "binder 'weighted' takes exactly one α param, got {}",
                params.len()
            ));
        };
        validate_alpha(*alpha, "bind(weighted:α)")?;
        Ok(Box::new(WeightedBinder { alpha: *alpha }))
    }),
    ("bestfit", "tightest candidate placement", |params| {
        no_params(params, "bestfit")?;
        Ok(Box::new(BestFitBinder))
    }),
    ("packed", "prefer already-occupied GPUs", |params| {
        no_params(params, "packed")?;
        Ok(Box::new(PackOccupiedBinder))
    }),
    ("first", "first (lowest-index) candidate", |params| {
        no_params(params, "first")?;
        Ok(Box::new(FirstBinder))
    }),
    ("random", "uniform random candidate (seeded)", |params| {
        no_params(params, "random")?;
        Ok(Box::new(RandomBinder::new(RANDOM_BINDER_SEED)))
    }),
];

type ModulatorBuilder = fn(&[f64]) -> Result<Box<dyn WeightModulator>, String>;
const BUILTIN_MODULATOR: &[(&str, &str, ModulatorBuilder)] = &[
    ("loadalpha", "load-adaptive α: α_empty→α_full on GPU utilization (loadalpha:αe:αf)", |params| {
        let [alpha_empty, alpha_full] = params else {
            return Err(format!(
                "modulator 'loadalpha' takes exactly two params (α_empty:α_full), got {}",
                params.len()
            ));
        };
        validate_alpha(*alpha_empty, "mod(loadalpha:α_empty:·)")?;
        validate_alpha(*alpha_full, "mod(loadalpha:·:α_full)")?;
        Ok(Box::new(LoadAlphaModulator { alpha_empty: *alpha_empty, alpha_full: *alpha_full }))
    }),
    (
        "latticealpha",
        "per-MIG-lattice α: α_base non-MIG, α_a100 / α_a30 per lattice (latticealpha:αb:α100:α30)",
        |params| {
            let [base, a100, a30] = params else {
                return Err(format!(
                    "modulator 'latticealpha' takes exactly three params \
                     (α_base:α_a100:α_a30), got {}",
                    params.len()
                ));
            };
            validate_alpha(*base, "mod(latticealpha:α_base:·:·)")?;
            validate_alpha(*a100, "mod(latticealpha:·:α_a100:·)")?;
            validate_alpha(*a30, "mod(latticealpha:·:·:α_a30)")?;
            Ok(Box::new(LatticeAlphaModulator {
                alpha_base: *base,
                alpha_a100: *a100,
                alpha_a30: *a30,
            }))
        },
    ),
    (
        "starve",
        "starvation-adaptive weights: shift PWR weight toward packing when \
         pending p99 wait crosses threshold (starve:threshold:boost)",
        |params| {
            let [threshold, boost] = params else {
                return Err(format!(
                    "modulator 'starve' takes exactly two params (threshold:boost), got {}",
                    params.len()
                ));
            };
            if !(*threshold > 0.0) || !threshold.is_finite() {
                return Err(format!(
                    "mod(starve:threshold:·): threshold must be positive and finite, \
                     got {threshold}"
                ));
            }
            validate_alpha(*boost, "mod(starve:·:boost)")?;
            Ok(Box::new(StarveModulator::new(*threshold, *boost)))
        },
    ),
];

type HookBuilder = fn(&[f64]) -> Result<Box<dyn PostHook>, String>;
const BUILTIN_HOOK: &[(&str, &str, HookBuilder)] = &[(
    "repartition",
    "MIG defrag: postFail repack-and-retry + proactive threshold (repartition[:thr[:moved[:budget]]])",
    |params| {
    // hook(repartition[:frag_threshold[:max_moved[:budget]]]);
    // omitted or negative threshold = ∞ (reactive / failure-only mode —
    // the DSL has no literal for ∞, so `-1` is the sentinel that lets
    // custom max_moved/budget caps combine with reactive-only defrag).
    let mut cfg = RepartitionConfig::default();
    if let Some(&t) = params.first() {
        if t.is_nan() {
            return Err("repartition frag_threshold must be a number".into());
        }
        // Sign-based so `-0` also selects reactive-only mode.
        cfg.frag_threshold = if t.is_sign_negative() { f64::INFINITY } else { t };
    }
    if let Some(&m) = params.get(1) {
        if !(m >= 0.0) || !m.is_finite() || m.fract() != 0.0 {
            return Err(format!("repartition max_moved must be a whole number, got {m}"));
        }
        cfg.max_moved_slices = m as u32;
    }
    if let Some(&b) = params.get(2) {
        if !(b >= 0.0) || !b.is_finite() || b.fract() != 0.0 {
            return Err(format!("repartition budget must be a whole number, got {b}"));
        }
        cfg.budget_slices = b as u64;
    }
    if params.len() > 3 {
        return Err(format!(
            "hook 'repartition' takes at most 3 params, got {}",
            params.len()
        ));
    }
        Ok(Box::new(MigRepartitioner::new(cfg)))
    },
),
(
    "drs",
    "node sleep/wake lifecycle: drain+sleep idle nodes, wake on demand \
     (drs[:idle_timeout[:wake_latency[:sleep_j[:wake_j]]]])",
    |params| {
        // hook(drs[:idle_timeout[:wake_latency[:sleep_j[:wake_j]]]]);
        // omitted or negative idle_timeout = ∞ (never sleep — the
        // legacy-equivalence mode; same `-1` sentinel convention as
        // hook(repartition)). Timeout/latency are scheduler-event
        // ticks; costs are joules per transition.
        let mut cfg = DrsConfig::default();
        if let Some(&t) = params.first() {
            if t.is_nan() {
                return Err("drs idle_timeout must be a number".into());
            }
            cfg.idle_timeout = if t.is_sign_negative() { f64::INFINITY } else { t };
        }
        if let Some(&l) = params.get(1) {
            if !(l >= 0.0) || !l.is_finite() || l.fract() != 0.0 {
                return Err(format!(
                    "drs wake_latency must be a whole number of ticks, got {l}"
                ));
            }
            cfg.wake_latency = l as u64;
        }
        let cost = |v: f64, what: &str| -> Result<f64, String> {
            if v >= 0.0 && v.is_finite() {
                Ok(v)
            } else {
                Err(format!("drs {what} must be finite and >= 0, got {v}"))
            }
        };
        if let Some(&v) = params.get(2) {
            cfg.sleep_cost_j = cost(v, "sleep_j")?;
        }
        if let Some(&v) = params.get(3) {
            cfg.wake_cost_j = cost(v, "wake_j")?;
        }
        if params.len() > 4 {
            return Err(format!("hook 'drs' takes at most 4 params, got {}", params.len()));
        }
        Ok(Box::new(DrsHook::new(cfg)))
    },
),
(
    "preempt",
    "priority preemption: postFail evict lower-priority tenants into the \
     pending queue, then retry (preempt:max_evictions)",
    |params| {
        let [budget] = params else {
            return Err(format!(
                "hook 'preempt' takes exactly one param (max_evictions), got {}",
                params.len()
            ));
        };
        if !(*budget >= 0.0) || !budget.is_finite() || budget.fract() != 0.0 {
            return Err(format!(
                "preempt max_evictions must be a whole number, got {budget}"
            ));
        }
        Ok(Box::new(PreemptHook::new(*budget as u64)))
    },
)];

type FilterBuilder = fn(&[String]) -> Result<Box<dyn FilterPlugin>, String>;
const BUILTIN_FILTER: &[(&str, &str, FilterBuilder)] = &[
    ("resources", "Cond. 1–3: CPU, memory, GPU quantity/shape feasibility", |params| {
        no_filter_params(params, "resources")?;
        Ok(Box::new(ResourcesFilter))
    }),
    ("gpumodel", "C_t^GPU: legacy model pin + declarative model sets", |params| {
        no_filter_params(params, "gpumodel")?;
        Ok(Box::new(GpuModelFilter))
    }),
    ("miglattice", "MIG demands only fit nodes of the profile's lattice", |params| {
        no_filter_params(params, "miglattice")?;
        Ok(Box::new(MigLatticeFilter))
    }),
    ("labels", "node selectors; optional static selector (labels:key=value)", |params| {
        Ok(Box::new(LabelsFilter { selector: parse_selector(params)? }))
    }),
    ("affinity", "class-keyed affinity / anti-affinity / per-node spread caps", |params| {
        no_filter_params(params, "affinity")?;
        Ok(Box::new(AffinityFilter))
    }),
    ("drs", "only Active power-state nodes accept placements (DRS sleep/wake)", |params| {
        no_filter_params(params, "drs")?;
        Ok(Box::new(DrsFilter))
    }),
    ("gang", "gangs need Σ ⌊free whole GPUs / tp⌋ ≥ members (aggregate PreFilter)", |params| {
        no_filter_params(params, "gang")?;
        Ok(Box::new(crate::sched::gang::GangFilter))
    }),
];

/// Parse `key=value` selector params of `filter(labels:…)`.
fn parse_selector(params: &[String]) -> Result<Vec<(String, String)>, String> {
    params
        .iter()
        .map(|p| {
            p.split_once('=')
                .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
                .filter(|(k, v)| !k.is_empty() && !v.is_empty())
                .ok_or_else(|| {
                    format!("bad selector '{p}' in filter(labels:…): expected key=value")
                })
        })
        .collect()
}

fn no_filter_params(params: &[String], key: &str) -> Result<(), String> {
    if params.is_empty() {
        Ok(())
    } else {
        Err(format!("filter '{key}' takes no params, got {}", params.len()))
    }
}

fn builtin_keys<A, B>(table: &[(&'static str, A, B)]) -> String {
    table.iter().map(|(k, _, _)| *k).collect::<Vec<_>>().join(", ")
}

fn build_score_plugin(key: &str) -> Result<Box<dyn ScorePlugin>, String> {
    let key = key.to_ascii_lowercase();
    let key = key.as_str();
    if let Some((_, _, f)) = BUILTIN_SCORE.iter().find(|(k, _, _)| *k == key) {
        return Ok(f());
    }
    match score_ext().read().unwrap().get(key) {
        Some(f) => Ok(f()),
        None => Err(format!(
            "unknown score plugin '{key}' (built-ins: {})",
            builtin_keys(BUILTIN_SCORE)
        )),
    }
}

fn build_binder(key: &str, params: &[f64]) -> Result<Box<dyn BindPlugin>, String> {
    let key = key.to_ascii_lowercase();
    let key = key.as_str();
    if let Some((_, _, f)) = BUILTIN_BIND.iter().find(|(k, _, _)| *k == key) {
        return f(params);
    }
    match bind_ext().read().unwrap().get(key) {
        Some(f) => f(params),
        None => Err(format!(
            "unknown binder '{key}' (built-ins: {})",
            builtin_keys(BUILTIN_BIND)
        )),
    }
}

fn build_modulator(key: &str, params: &[f64]) -> Result<Box<dyn WeightModulator>, String> {
    let key = key.to_ascii_lowercase();
    let key = key.as_str();
    if let Some((_, _, f)) = BUILTIN_MODULATOR.iter().find(|(k, _, _)| *k == key) {
        return f(params);
    }
    match modulator_ext().read().unwrap().get(key) {
        Some(f) => f(params),
        None => Err(format!(
            "unknown modulator '{key}' (built-ins: {})",
            builtin_keys(BUILTIN_MODULATOR)
        )),
    }
}

fn build_hook(key: &str, params: &[f64]) -> Result<Box<dyn PostHook>, String> {
    let key = key.to_ascii_lowercase();
    let key = key.as_str();
    if let Some((_, _, f)) = BUILTIN_HOOK.iter().find(|(k, _, _)| *k == key) {
        return f(params);
    }
    match hook_ext().read().unwrap().get(key) {
        Some(f) => f(params),
        None => Err(format!(
            "unknown hook '{key}' (built-ins: {})",
            builtin_keys(BUILTIN_HOOK)
        )),
    }
}

fn build_filter(key: &str, params: &[String]) -> Result<Box<dyn FilterPlugin>, String> {
    let key = key.to_ascii_lowercase();
    let key = key.as_str();
    if let Some((_, _, f)) = BUILTIN_FILTER.iter().find(|(k, _, _)| *k == key) {
        return f(params);
    }
    match filter_ext().read().unwrap().get(key) {
        Some(f) => f(params),
        None => Err(format!(
            "unknown filter plugin '{key}' (built-ins: {})",
            builtin_keys(BUILTIN_FILTER)
        )),
    }
}

/// Every registered plugin as `(extension point, key, description)` —
/// built-ins (from the registry tables, so the catalog cannot drift)
/// followed by runtime registrations. Backs `repro list-plugins`.
pub fn registry_catalog() -> Vec<(&'static str, String, String)> {
    let mut out: Vec<(&'static str, String, String)> = Vec::new();
    for (k, d, _) in BUILTIN_SCORE {
        out.push(("score", k.to_string(), d.to_string()));
    }
    for (k, d, _) in BUILTIN_BIND {
        out.push(("bind", k.to_string(), d.to_string()));
    }
    for (k, d, _) in BUILTIN_MODULATOR {
        out.push(("mod", k.to_string(), d.to_string()));
    }
    for (k, d, _) in BUILTIN_HOOK {
        out.push(("hook", k.to_string(), d.to_string()));
    }
    for (k, d, _) in BUILTIN_FILTER {
        out.push(("filter", k.to_string(), d.to_string()));
    }
    let runtime: [(&'static str, Vec<String>); 5] = [
        ("score", score_ext().read().unwrap().keys().cloned().collect()),
        ("bind", bind_ext().read().unwrap().keys().cloned().collect()),
        ("mod", modulator_ext().read().unwrap().keys().cloned().collect()),
        ("hook", hook_ext().read().unwrap().keys().cloned().collect()),
        ("filter", filter_ext().read().unwrap().keys().cloned().collect()),
    ];
    for (kind, mut keys) in runtime {
        keys.sort();
        for k in keys {
            out.push((kind, k, "(runtime-registered)".to_string()));
        }
    }
    out
}

/// One freshly built instance of every built-in score plugin, keyed by
/// its registry name. Backs the dynamic purity cross-check
/// (`rust/tests/purity_check.rs`), which exercises each cacheable
/// plugin for bit-identical scores under cache reuse and shard
/// permutation.
pub fn builtin_score_plugins() -> Vec<(&'static str, Box<dyn ScorePlugin>)> {
    BUILTIN_SCORE.iter().map(|(k, _, f)| (*k, f())).collect()
}

fn no_params(params: &[f64], key: &str) -> Result<(), String> {
    if params.is_empty() {
        Ok(())
    } else {
        Err(format!("binder '{key}' takes no params, got {}", params.len()))
    }
}

/// Shared α-domain check (satellite of the profile redesign: the legacy
/// parser and the DSL both reject α ∉ [0, 1] — a 1.7 or −0.3 silently
/// produced negative FGD weights before).
pub fn validate_alpha(alpha: f64, what: &str) -> Result<(), String> {
    if (0.0..=1.0).contains(&alpha) {
        Ok(())
    } else {
        Err(format!("{what}: α must be in [0, 1], got {alpha}"))
    }
}

// ---------------------------------------------------------------------
// DSL parsing.
// ---------------------------------------------------------------------

fn parse_num(s: &str, what: &str) -> Result<f64, String> {
    let v: f64 =
        s.trim().parse().map_err(|_| format!("{what}: '{s}' is not a number"))?;
    if v.is_finite() {
        Ok(v)
    } else {
        Err(format!("{what}: '{s}' must be finite"))
    }
}

/// Parse `key[:num[:num...]]` (bind/mod/hook section bodies).
fn parse_keyed_params(body: &str, what: &str) -> Result<(String, Vec<f64>), String> {
    let mut parts = body.split(':');
    let key = parts.next().unwrap_or("").trim().to_ascii_lowercase();
    if key.is_empty() {
        return Err(format!("{what}: missing key"));
    }
    let params = parts
        .map(|p| parse_num(p, what))
        .collect::<Result<Vec<f64>, String>>()?;
    Ok((key, params))
}

/// Parse a whole-valued section body (`sample(25)`, `shards(4)`).
fn parse_whole(s: &str, what: &str) -> Result<u64, String> {
    let v = parse_num(s, what)?;
    if v.fract() != 0.0 || v < 0.0 {
        return Err(format!("{what}: '{}' must be a whole number >= 0", s.trim()));
    }
    Ok(v as u64)
}

fn parse_dsl(s: &str) -> Result<SchedulerProfile, String> {
    let mut score: Vec<(String, f64)> = Vec::new();
    let mut bind: Option<(String, Vec<f64>)> = None;
    let mut modulator: Option<(String, Vec<f64>)> = None;
    let mut hooks: Vec<(String, Vec<f64>)> = Vec::new();
    let mut filters: Option<Vec<(String, Vec<String>)>> = None;
    let mut sample_pct: Option<u32> = None;
    let mut score_shards: Option<usize> = None;
    for section in s.split('|') {
        let section = section.trim();
        let inner = section
            .strip_suffix(')')
            .and_then(|x| x.split_once('('))
            .ok_or_else(|| {
                format!("bad profile section '{section}': expected section(...)")
            })?;
        let (name, body) = (inner.0.trim().to_ascii_lowercase(), inner.1.trim());
        match name.as_str() {
            "score" => {
                if !score.is_empty() {
                    return Err("duplicate score(...) section".into());
                }
                for entry in body.split(',') {
                    let entry = entry.trim();
                    let (key, weight) = match entry.split_once('=') {
                        Some((k, w)) => {
                            (k.trim().to_ascii_lowercase(), parse_num(w, "score weight")?)
                        }
                        None => (entry.to_ascii_lowercase(), 1.0),
                    };
                    if key.is_empty() {
                        return Err(format!("empty score entry in '{body}'"));
                    }
                    if weight < 0.0 {
                        return Err(format!(
                            "score weight for '{key}' must be >= 0, got {weight}"
                        ));
                    }
                    if score.iter().any(|(k, _)| *k == key) {
                        return Err(format!(
                            "duplicate score plugin '{key}' (its weight would double-count)"
                        ));
                    }
                    score.push((key, weight));
                }
            }
            "bind" => {
                if bind.is_some() {
                    return Err("duplicate bind(...) section".into());
                }
                bind = Some(parse_keyed_params(body, "bind")?);
            }
            "mod" => {
                if modulator.is_some() {
                    return Err("duplicate mod(...) section".into());
                }
                modulator = Some(parse_keyed_params(body, "mod")?);
            }
            "hook" => hooks.push(parse_keyed_params(body, "hook")?),
            "filter" => {
                if filters.is_some() {
                    return Err("duplicate filter(...) section".into());
                }
                let mut fs: Vec<(String, Vec<String>)> = Vec::new();
                for entry in body.split(',') {
                    let entry = entry.trim();
                    let mut parts = entry.split(':');
                    let key = parts.next().unwrap_or("").trim().to_ascii_lowercase();
                    if key.is_empty() {
                        return Err(format!("empty filter entry in '{body}'"));
                    }
                    if fs.iter().any(|(k, _)| *k == key) {
                        return Err(format!("duplicate filter plugin '{key}'"));
                    }
                    let params: Vec<String> =
                        parts.map(|p| p.trim().to_string()).collect();
                    fs.push((key, params));
                }
                filters = Some(fs);
            }
            "sample" => {
                if sample_pct.is_some() {
                    return Err("duplicate sample(...) section".into());
                }
                let pct = parse_whole(body, "sample")?;
                if !(1..=100).contains(&pct) {
                    return Err(format!(
                        "sample(<pct>): percentage must be in [1, 100], got {pct}"
                    ));
                }
                sample_pct = Some(pct as u32);
            }
            "shards" => {
                if score_shards.is_some() {
                    return Err("duplicate shards(...) section".into());
                }
                let n = parse_whole(body, "shards")?;
                if !(1..=256).contains(&n) {
                    return Err(format!(
                        "shards(<n>): shard count must be in [1, 256], got {n}"
                    ));
                }
                score_shards = Some(n as usize);
            }
            other => {
                return Err(format!(
                    "unknown profile section '{other}' \
                     (expected score/bind/mod/hook/filter/sample/shards)"
                ))
            }
        }
    }
    if score.is_empty() {
        return Err("profile needs a score(...) section with at least one plugin".into());
    }
    // The open-simulator default binder; the default filter chain.
    let bind = bind.unwrap_or_else(|| ("bestfit".to_string(), Vec::new()));
    let filters = filters.unwrap_or_else(default_filter_keys);
    let sample_pct = sample_pct.unwrap_or(100);
    let score_shards = score_shards.unwrap_or(1);
    let label = dsl_label(&score, &bind, &modulator, &hooks, &filters, sample_pct, score_shards);
    Ok(SchedulerProfile { score, bind, modulator, hooks, filters, sample_pct, score_shards, label })
}

/// Canonical compact label for DSL profiles (comma-free so CSV headers
/// stay well-formed): `PWR500+FGD300+DOTPROD200|weighted:500|loadalpha:900-0`.
/// Score weights and binder/modulator params are α-like and shown
/// ×1000 (the paper's plot-legend convention); hook params are literal
/// quantities (thresholds, slice counts, budgets) and printed verbatim.
/// A non-default filter chain is appended as
/// `|filter:resources+labels:zone=z1`; the default chain is omitted so
/// pre-filter-era labels are unchanged. Likewise non-default `sample`
/// / `shards` knobs append `|sample:25` / `|shards:4` and the defaults
/// (100 / 1) are omitted.
fn dsl_label(
    score: &[(String, f64)],
    bind: &(String, Vec<f64>),
    modulator: &Option<(String, Vec<f64>)>,
    hooks: &[(String, Vec<f64>)],
    filters: &[(String, Vec<String>)],
    sample_pct: u32,
    score_shards: usize,
) -> String {
    let kilo = |v: f64| format!("{:.0}", v * 1000.0);
    let mut out = score
        .iter()
        .map(|(k, w)| format!("{}{}", k.to_ascii_uppercase(), kilo(*w)))
        .collect::<Vec<_>>()
        .join("+");
    let keyed = |k: &str, params: &[f64], fmt: &dyn Fn(f64) -> String| {
        if params.is_empty() {
            k.to_string()
        } else {
            format!("{k}:{}", params.iter().map(|p| fmt(*p)).collect::<Vec<_>>().join("-"))
        }
    };
    out.push('|');
    out.push_str(&keyed(&bind.0, &bind.1, &kilo));
    if let Some((k, params)) = modulator {
        out.push('|');
        out.push_str(&keyed(k, params, &kilo));
    }
    for (k, params) in hooks {
        out.push('|');
        out.push_str(&keyed(k, params, &|v| format!("{v}")));
    }
    if filters != default_filter_keys().as_slice() {
        out.push_str("|filter:");
        let rendered: Vec<String> = filters
            .iter()
            .map(|(k, params)| {
                if params.is_empty() {
                    k.clone()
                } else {
                    format!("{k}:{}", params.join(":"))
                }
            })
            .collect();
        out.push_str(&rendered.join("+"));
    }
    if sample_pct != 100 {
        out.push_str(&format!("|sample:{sample_pct}"));
    }
    if score_shards != 1 {
        out.push_str(&format!("|shards:{score_shards}"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legacy_strings_lower_with_identical_labels() {
        for s in [
            "fgd", "pwr", "pwrfgd:0.1", "pwrfgddyn:0.9:0.0", "bestfit", "dotprod",
            "gpupacking", "gpuclustering", "firstfit", "random", "mig-bestfit",
            "mig-slicefit", "mig-fgd", "mig-pwr", "mig-pwrfgd:0.1",
        ] {
            let kind = PolicyKind::parse(s).expect(s);
            let profile = SchedulerProfile::parse(s).expect(s);
            assert_eq!(profile.label, kind.label(), "label drifted for '{s}'");
            assert_eq!(profile, SchedulerProfile::from(kind));
            profile.build().expect(s);
        }
    }

    #[test]
    fn dsl_roundtrip_three_objectives() {
        let p = SchedulerProfile::parse(
            "score(pwr=0.5,fgd=0.3,dotprod=0.2)|bind(weighted:0.5)|mod(loadalpha:0.9:0.0)",
        )
        .unwrap();
        assert_eq!(p.score.len(), 3);
        assert_eq!(p.score[2], ("dotprod".to_string(), 0.2));
        assert_eq!(p.bind, ("weighted".to_string(), vec![0.5]));
        assert_eq!(p.modulator, Some(("loadalpha".to_string(), vec![0.9, 0.0])));
        assert_eq!(p.label, "PWR500+FGD300+DOTPROD200|weighted:500|loadalpha:900-0");
        p.build().unwrap();
    }

    #[test]
    fn dsl_defaults_and_hooks() {
        // Bare keys weight 1, default binder bestfit, repeatable hooks.
        let p = SchedulerProfile::parse("score(fgd)|hook(repartition:0.5)").unwrap();
        assert_eq!(p.score, vec![("fgd".to_string(), 1.0)]);
        assert_eq!(p.bind.0, "bestfit");
        assert_eq!(p.hooks, vec![("repartition".to_string(), vec![0.5])]);
        let sched = p.build().unwrap();
        assert_eq!(sched.hook_counter("repartitions"), 0);
        // `-1` threshold sentinel: reactive-only mode with custom
        // migration caps stays expressible.
        SchedulerProfile::parse("score(fgd)|hook(repartition:-1:4:100)")
            .unwrap()
            .build()
            .unwrap();
    }

    #[test]
    fn dsl_drs_hook_and_consolidate_parse() {
        // The canonical DRS composition: consolidate rides along as a
        // third objective, the hook drives the sleep/wake lifecycle.
        let p = SchedulerProfile::parse(
            "score(pwr=0.4,fgd=0.4,consolidate=0.2)|bind(weighted:0.4)|hook(drs:500:100)",
        )
        .unwrap();
        assert_eq!(p.score[2], ("consolidate".to_string(), 0.2));
        assert_eq!(p.hooks, vec![("drs".to_string(), vec![500.0, 100.0])]);
        let sched = p.build().unwrap();
        assert_eq!(sched.hook_counter("drs_sleeps"), 0);
        // `-1` timeout sentinel = ∞ (never sleep), with costs attached.
        SchedulerProfile::parse("score(fgd)|hook(drs:-1:50:25:100)")
            .unwrap()
            .build()
            .unwrap();
        // Bare `hook(drs)` is the all-defaults (legacy-safe) form.
        SchedulerProfile::parse("score(fgd)|hook(drs)").unwrap().build().unwrap();
    }

    #[test]
    fn dsl_fairness_sections_parse_and_build() {
        let p = SchedulerProfile::parse(
            "score(pwr=0.7,fgd=0.3)|mod(starve:1000:0.5)|hook(preempt:4)",
        )
        .unwrap();
        assert_eq!(p.label, "PWR700+FGD300|bestfit|starve:1000000-500|preempt:4");
        let sched = p.build().unwrap();
        assert_eq!(sched.hook_counter("preempt_evictions"), 0);
        assert_eq!(sched.hook_counter("preempt_triggers"), 0);
    }

    #[test]
    fn dsl_rejects_malformed_profiles() {
        for bad in [
            "score()",                                   // empty entry
            "score(nope=1.0)",                           // unknown plugin
            "score(pwr=-0.1)",                           // negative weight
            "score(pwr=0.0,fgd=0.0)",                    // all-zero weights
            "score(pwr)|bind(weighted)",                 // weighted needs α
            "score(pwr)|bind(weighted:1.7)",             // α out of range
            "score(pwr)|bind(nope)",                     // unknown binder
            "score(pwr)|mod(loadalpha:0.5)",             // loadalpha needs 2
            "score(pwr)|mod(loadalpha:0.5:1.2)",         // α_full out of range
            "score(pwr)|hook(nope)",                     // unknown hook
            "score(pwr)|bind(first)|bind(first)",        // duplicate bind
            "score(pwr=0.5)|score(fgd=0.5)",             // duplicate score section
            "score(pwr,pwr)|bind(weighted:1)",           // duplicate plugin key
            "score(fgd=0.7,pwr=0.3)|mod(loadalpha:0.9:0.0)", // loadalpha needs pwr first
            "score(pwr)|mod(latticealpha:0.5)",          // latticealpha needs 3
            "score(pwr)|mod(latticealpha:0.5:1.2:0.1)",  // α_a100 out of range
            "score(fgd)|mod(latticealpha:0.5:0.5:0.5)",  // latticealpha needs pwr first
            "score(pwr)|mod(starve:100)",                // starve needs 2 params
            "score(pwr=0.5,fgd=0.5)|mod(starve:0:0.5)",  // non-positive threshold
            "score(pwr=0.5,fgd=0.5)|mod(starve:100:1.5)", // boost out of range
            "score(fgd=0.7,pwr=0.3)|mod(starve:100:0.5)", // starve needs pwr first
            "score(fgd)|hook(preempt)",                  // preempt needs a budget
            "score(fgd)|hook(preempt:1.5)",              // fractional eviction budget
            "score(fgd)|hook(preempt:-1)",               // negative eviction budget
            "score(fgd)|hook(drs:nan)",                  // drs timeout must be a number
            "score(fgd)|hook(drs:100:1.5)",              // fractional wake latency
            "score(fgd)|hook(drs:100:-2)",               // negative wake latency
            "score(fgd)|hook(drs:100:5:-1)",             // negative sleep cost
            "score(fgd)|hook(drs:100:5:0:inf)",          // non-finite wake cost
            "score(fgd)|hook(drs:1:2:3:4:5)",            // too many params
            "score(fgd)|filter(drs:1)",                  // params on the drs filter
            "score(fgd)|sample(0)",                      // pct below 1
            "score(fgd)|sample(101)",                    // pct above 100
            "score(fgd)|sample(2.5)",                    // fractional pct
            "score(fgd)|sample()",                       // missing pct
            "score(fgd)|sample(50)|sample(50)",          // duplicate sample
            "score(fgd)|shards(0)",                      // zero shards
            "score(fgd)|shards(-4)",                     // negative shards
            "score(fgd)|shards(1.5)",                    // fractional shards
            "score(fgd)|shards(4)|shards(4)",            // duplicate shards
            "gibberish(pwr)",                            // unknown section
            "notaprofile",                               // not legacy, no DSL
        ] {
            assert!(SchedulerProfile::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn dsl_filter_section_parses_and_overrides() {
        // No filter section -> the default chain, label unchanged.
        let p = SchedulerProfile::parse("score(fgd)").unwrap();
        assert_eq!(p.filters, default_filter_keys());
        assert!(!p.label.contains("filter"));
        // Explicit chain with a static selector.
        let p = SchedulerProfile::parse(
            "score(fgd)|filter(resources,gpumodel,labels:zone=z1)",
        )
        .unwrap();
        assert_eq!(p.filters.len(), 3);
        assert_eq!(p.filters[2], ("labels".to_string(), vec!["zone=z1".to_string()]));
        assert_eq!(p.label, "FGD1000|bestfit|filter:resources+gpumodel+labels:zone=z1");
        p.build().unwrap();
        // Explicit default-equivalent chain lowers to the default label.
        let p = SchedulerProfile::parse(
            "score(fgd)|filter(resources,gpumodel,miglattice,labels,affinity,drs,gang)",
        )
        .unwrap();
        assert_eq!(p.filters, default_filter_keys());
        assert!(!p.label.contains("filter"));
        // Dropping the drs/gang gates is an explicit (labeled)
        // non-default chain now that the default includes them.
        let p = SchedulerProfile::parse(
            "score(fgd)|filter(resources,gpumodel,miglattice,labels,affinity)",
        )
        .unwrap();
        assert_ne!(p.filters, default_filter_keys());
        assert!(p.label.contains("filter"));
        p.build().unwrap();
    }

    #[test]
    fn dsl_filter_section_rejects_malformed() {
        for bad in [
            "score(fgd)|filter(nope)",                    // unknown key
            "score(fgd)|filter()",                        // empty entry
            "score(fgd)|filter(resources)|filter(labels)", // duplicate section
            "score(fgd)|filter(resources,resources)",     // duplicate key
            "score(fgd)|filter(labels:zone)",             // bad selector: no '='
            "score(fgd)|filter(labels:=z1)",              // bad selector: empty key
            "score(fgd)|filter(labels:zone=)",            // bad selector: empty value
            "score(fgd)|filter(resources:1)",             // params on a no-param filter
        ] {
            assert!(SchedulerProfile::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn dsl_sample_and_shards_sections_parse() {
        // Defaults: exhaustive sweep, sequential scoring, no label
        // suffix (pre-fast-path labels are unchanged).
        let p = SchedulerProfile::parse("score(fgd)").unwrap();
        assert_eq!(p.sample_pct, 100);
        assert_eq!(p.score_shards, 1);
        assert!(!p.label.contains("sample") && !p.label.contains("shards"));
        // Explicit defaults lower to the same label.
        let p = SchedulerProfile::parse("score(fgd)|sample(100)|shards(1)").unwrap();
        assert_eq!((p.sample_pct, p.score_shards), (100, 1));
        assert!(!p.label.contains("sample") && !p.label.contains("shards"));
        // Non-default knobs parse, build and show up in the label.
        let p = SchedulerProfile::parse(
            "score(pwr=0.5,fgd=0.5)|bind(weighted:0.5)|sample(25)|shards(4)",
        )
        .unwrap();
        assert_eq!((p.sample_pct, p.score_shards), (25, 4));
        assert_eq!(p.label, "PWR500+FGD500|weighted:500|sample:25|shards:4");
        p.build().unwrap();
    }

    #[test]
    fn catalog_covers_every_builtin_key() {
        // Drift-proofing is now owned by the shared static-analysis
        // rules (`repro lint`): catalog-drift cross-checks metric keys
        // in the sources against `METRICS_CATALOG` and
        // `docs/observability.md`, and dsl-docs-drift cross-checks the
        // `BUILTIN_*` tables and `parse_dsl` sections against
        // `docs/scheduler.md`. Running the same rules here keeps
        // `cargo test` self-contained (no CI dependency) and pins that
        // the rules accept the real tree.
        use crate::analysis::{lint, RepoTree};
        let tree = RepoTree::load(std::path::Path::new(env!("CARGO_MANIFEST_DIR")))
            .expect("repo tree readable");
        let findings = lint::registry_drift(&tree);
        assert!(
            findings.is_empty(),
            "registry/catalog drift:\n{}",
            findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
        );
        // The statically parsed builtin keys must all resolve through
        // the runtime registry (⊆, not ==: other tests may have
        // runtime-registered extra keys in this process).
        let sf = tree
            .source("rust/src/sched/profile.rs")
            .expect("profile.rs in tree");
        let parsed = lint::builtin_keys_by_point(&sf);
        assert!(!parsed.is_empty(), "could not parse any BUILTIN_* table");
        let cat = registry_catalog();
        let keys_of = |kind: &str| -> Vec<String> {
            cat.iter()
                .filter(|(k, _, _)| *k == kind)
                .map(|(_, key, _)| key.clone())
                .collect()
        };
        for (point, keys) in &parsed {
            let runtime = keys_of(point);
            for key in keys {
                assert!(runtime.contains(key), "parsed {point}/{key} not in registry_catalog");
            }
        }
        // The default chain's plugin names must all resolve as registry
        // keys (names double as keys; this is what keeps
        // `default_filter_keys` and `default_filter_chain` in lockstep).
        for (key, params) in default_filter_keys() {
            assert!(params.is_empty());
            assert!(
                keys_of("filter").contains(&key),
                "default chain key '{key}' is not a registered filter"
            );
        }
        // Every row carries a non-empty description.
        assert!(cat.iter().all(|(_, _, d)| !d.is_empty()));
    }

    #[test]
    fn custom_registrations_resolve() {
        use crate::cluster::node::{Node, Placement};
        use crate::tasks::Task;
        struct Constant;
        impl ScorePlugin for Constant {
            fn name(&self) -> &'static str {
                "constant"
            }
            fn score(&self, _: &crate::sched::SchedCtx, _: &Node, _: &Task, _: &[Placement]) -> f64 {
                1.0
            }
        }
        register_score_plugin("test-constant", || Box::new(Constant)).unwrap();
        // Built-ins cannot be shadowed.
        assert!(register_score_plugin("pwr", || Box::new(Constant)).is_err());
        let p = SchedulerProfile {
            score: vec![("test-constant".to_string(), 1.0)],
            bind: ("first".to_string(), vec![]),
            modulator: None,
            hooks: vec![],
            filters: vec![],
            sample_pct: 100,
            score_shards: 1,
            label: "test".into(),
        };
        p.build().unwrap();
    }

    #[test]
    fn custom_filter_registration_resolves() {
        use crate::cluster::node::Node;
        use crate::sched::filter::FilterCtx;
        use crate::tasks::Task;
        struct EvenNodesOnly;
        impl FilterPlugin for EvenNodesOnly {
            fn name(&self) -> &'static str {
                "even-nodes"
            }
            fn feasible(&self, _: &FilterCtx, node: &Node, _: &Task) -> bool {
                node.id % 2 == 0
            }
        }
        register_filter_plugin("test-even-nodes", |_params| Ok(Box::new(EvenNodesOnly)))
            .unwrap();
        // Built-ins cannot be shadowed.
        assert!(register_filter_plugin("resources", |_| Ok(Box::new(EvenNodesOnly))).is_err());
        let p = SchedulerProfile::parse(
            "score(firstfit)|bind(first)|filter(resources,gpumodel,test-even-nodes)",
        )
        .unwrap();
        let mut sched = p.build().unwrap();
        // On a 3-node cluster only even node ids are ever selected.
        let dc = crate::cluster::ClusterSpec::tiny(3, 2, 0).build();
        let w = crate::tasks::Workload::default();
        use crate::tasks::GpuDemand;
        for i in 0..4 {
            let t = Task::new(i, 1.0, 0.0, GpuDemand::Frac(0.25));
            let d = sched.schedule(&dc, &w, &t).expect("schedules");
            assert_eq!(d.node % 2, 0, "odd node selected");
        }
    }
}
