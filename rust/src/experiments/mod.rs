//! Experiment harness: regenerates every table and figure of the
//! paper's evaluation (§V–VI) as CSV files under `results/`.
//!
//! | id       | paper    | output                                        |
//! |----------|----------|-----------------------------------------------|
//! | `table1` | Table I  | trace bucket marginals                        |
//! | `table2` | Table II | inventory + power profiles                    |
//! | `fig1`   | Fig. 1   | FGD EOPC stacked CPU/GPU + GPU share          |
//! | `fig2`   | Fig. 2   | α-sweep: savings vs FGD + GRAR                |
//! | `fig3`   | Fig. 3   | savings vs FGD, Default trace                 |
//! | `fig4`   | Fig. 4   | savings, sharing-GPU 100%                     |
//! | `fig5`   | Fig. 5   | savings, multi-GPU 20% / 50%                  |
//! | `fig6`   | Fig. 6   | savings, constrained-GPU 10% / 33%            |
//! | `fig7`   | Fig. 7   | GRAR, Default trace                           |
//! | `fig8`   | Fig. 8   | GRAR, sharing-GPU 40% / 100%                  |
//! | `fig9`   | Fig. 9   | GRAR, multi-GPU 20% / 50%                     |
//! | `fig10`  | Fig. 10  | GRAR, constrained-GPU 10% / 33%               |
//!
//! Runs are cached per (trace, policy) within a harness invocation, so
//! `repro experiment all` shares the 10-repetition simulations between
//! the savings figures (3–6) and the GRAR figures (7–10), exactly as the
//! paper evaluates both metrics on the same runs.
//!
//! Beyond the paper: `ext-dynalpha`, `ext-steady`, `ext-mig`,
//! `ext-mig-het`, `ext-profiles`, `ext-filters`, `ext-drs` (the DRS
//! sleep/wake sweep on diurnal load — `docs/power.md`), `ext-gang`
//! (topology-aware gang scheduling on the `gang-<pct>` trace family —
//! `docs/gang.md`), `ext-fairness` (the pending-queue fairness sweep on
//! `priority-<pct>` churn — `docs/fairness.md`) and `ablation-tiebreak`.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::cluster::ClusterSpec;
use crate::metrics::{average_on_grid, capacity_grid, savings_pct, Column};
use crate::obs::{DecisionTracer, TraceSink};
use crate::sched::{PolicyKind, SchedulerProfile};
use crate::sim::{run_repetitions, RepeatConfig};
use crate::trace::TraceSpec;
use crate::util::csv::CsvWriter;

/// Harness configuration (CLI-controlled).
#[derive(Clone, Debug)]
pub struct ExpConfig {
    /// Seeded repetitions per (trace, policy) — the paper uses 10.
    pub reps: usize,
    /// Base RNG seed.
    pub seed: u64,
    /// Cluster scale ∈ (0, 1]; 1.0 = the full 1,213-node datacenter.
    pub scale: f64,
    /// Inflation target (× GPU capacity).
    pub target: f64,
    /// Output directory for CSVs.
    pub out_dir: String,
    /// Optional decision-trace sink (`--trace-decisions`): every
    /// simulation the harness runs — inflation repetitions and the
    /// direct steady-state loops alike — streams JSONL decision events
    /// into it. Events are self-describing (policy/seed/seq fields), so
    /// one shared sink per experiment suffices. See [`crate::obs`].
    pub trace_sink: Option<TraceSink>,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            reps: 10,
            seed: 42,
            scale: 1.0,
            target: 1.02,
            out_dir: "results".into(),
            trace_sink: None,
        }
    }
}

/// The α values of the Fig. 2 sweep (legend shows α·1000).
pub const FIG2_ALPHAS: [f64; 13] =
    [0.02, 0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 0.93, 1.0];

/// `ext-mig-het` knobs: share of MIG demand targeting the A30 lattice,
/// and the proactive slice-fragmentation threshold
/// ([`crate::sched::policies::RepartitionConfig::frag_threshold`]).
pub const MIG_HET_A30_SHARE: f64 = 0.4;
pub const MIG_HET_FRAG_THRESHOLD: f64 = 0.5;

/// `ext-filters` knob: the constrained-task shares swept over the
/// `constrained-<pct>` trace family.
pub const EXT_FILTERS_PCTS: [f64; 3] = [0.0, 0.25, 0.5];

/// `ext-drs` knobs: the idle-timeout × wake-latency sweep
/// (scheduler-event ticks — see `docs/power.md`) and the diurnal
/// arrival-rate amplitude.
pub const EXT_DRS_TIMEOUTS: [f64; 3] = [50.0, 200.0, 800.0];
pub const EXT_DRS_LATENCIES: [u64; 2] = [0, 100];
pub const EXT_DRS_AMPLITUDE: f64 = 0.6;

/// `ext-gang` knobs: gang shares swept over the `gang-<pct>` trace
/// family, and the zone count stamped on the cluster so the topology
/// tiers (NVLink / fabric / inter-zone) all appear in the topo scores.
pub const EXT_GANG_PCTS: [f64; 3] = [0.0, 0.3, 0.6];
pub const EXT_GANG_ZONES: usize = 4;

/// `ext-fairness` knobs: the starvation-threshold × preemption-budget
/// grid swept over `priority-50` churn. Thresholds are p99 queue waits
/// in simulated seconds (both the `mod(starve)` trigger and the
/// starvation-ledger cutoff); budgets are `hook(preempt:n)` eviction
/// caps per failed placement (0 = queue only, no preemption). The
/// boost is the PWR-weight fraction shifted onto packing while starved.
pub const EXT_FAIRNESS_THRESHOLDS: [f64; 2] = [500.0, 2_000.0];
pub const EXT_FAIRNESS_BUDGETS: [u64; 3] = [0, 2, 8];
pub const EXT_FAIRNESS_PRIORITY_PCT: f64 = 0.5;
pub const EXT_FAIRNESS_BOOST: f64 = 0.5;

/// The three selected combinations (§VI-B) + the four competitors used
/// in Figs. 3–10.
pub fn comparison_policies() -> Vec<PolicyKind> {
    vec![
        PolicyKind::PwrFgd { alpha: 0.05 },
        PolicyKind::PwrFgd { alpha: 0.1 },
        PolicyKind::PwrFgd { alpha: 0.2 },
        PolicyKind::BestFit,
        PolicyKind::DotProd,
        PolicyKind::GpuPacking,
        PolicyKind::GpuClustering,
    ]
}

/// Averaged (EOPC, GRAR) series for one (trace, policy) cell.
#[derive(Clone, Debug)]
pub struct CellSeries {
    pub eopc: Vec<f64>,
    pub cpu_w: Vec<f64>,
    pub gpu_w: Vec<f64>,
    pub grar: Vec<f64>,
}

/// The experiment harness with its run cache.
pub struct Harness {
    pub cfg: ExpConfig,
    cluster: ClusterSpec,
    grid: Vec<f64>,
    cache: HashMap<(String, String), CellSeries>,
}

impl Harness {
    pub fn new(cfg: ExpConfig) -> Harness {
        let cluster = if cfg.scale >= 1.0 {
            ClusterSpec::paper_default()
        } else {
            ClusterSpec::paper_scaled(cfg.scale)
        };
        let grid = capacity_grid(cfg.target, 0.01);
        Harness { cfg, cluster, grid, cache: HashMap::new() }
    }

    /// The common capacity grid.
    pub fn grid(&self) -> &[f64] {
        &self.grid
    }

    /// Attach the harness-level decision-trace sink (if any) to a
    /// freshly built scheduler — the direct `SteadySim` construction
    /// sites mirror what `run_repetitions` does for inflation runs.
    fn attach_trace(&self, sched: &mut crate::sched::Scheduler, seed: u64) {
        if let Some(sink) = &self.cfg.trace_sink {
            let label = sched.label().to_string();
            sched.set_tracer(DecisionTracer::new(sink.clone(), &label, seed));
        }
    }

    /// Run (or fetch) the averaged series for a (trace, policy) cell.
    /// `policy` accepts a legacy [`PolicyKind`] or any
    /// [`SchedulerProfile`]. The cache keys on the *full* profile
    /// contents (labels are not injective: hand-attached hooks keep the
    /// base label, and the ×1000 label rounding collapses close
    /// weights — two distinct profiles must never share a cell).
    pub fn cell(&mut self, trace: &TraceSpec, policy: impl Into<SchedulerProfile>) -> CellSeries {
        let profile: SchedulerProfile = policy.into();
        let key = (trace.name.clone(), format!("{profile:?}"));
        if let Some(c) = self.cache.get(&key) {
            return c.clone();
        }
        eprintln!(
            "[experiment] running {} / {} ({} reps, {} nodes)…",
            trace.name,
            profile.label,
            self.cfg.reps,
            self.cluster.total_nodes()
        );
        let t0 = std::time::Instant::now();
        let rcfg = RepeatConfig {
            reps: self.cfg.reps,
            base_seed: self.cfg.seed,
            target_ratio: self.cfg.target,
            trace: self.cfg.trace_sink.clone(),
            ..Default::default()
        };
        let runs = run_repetitions(&self.cluster, trace, profile, &rcfg);
        let series: Vec<_> = runs.into_iter().map(|r| r.series).collect();
        let cell = CellSeries {
            eopc: average_on_grid(&series, Column::Eopc, &self.grid),
            cpu_w: average_on_grid(&series, Column::CpuW, &self.grid),
            gpu_w: average_on_grid(&series, Column::GpuW, &self.grid),
            grar: average_on_grid(&series, Column::Grar, &self.grid),
        };
        eprintln!(
            "[experiment]   done in {:.1}s (final GRAR {:.3})",
            t0.elapsed().as_secs_f64(),
            cell.grar.last().copied().unwrap_or(0.0)
        );
        self.cache.insert(key, cell.clone());
        cell
    }

    fn out_path(&self, name: &str) -> String {
        format!("{}/{}", self.cfg.out_dir, name)
    }

    /// Dispatch an experiment id; returns the written CSV paths.
    pub fn run(&mut self, id: &str) -> Result<Vec<String>> {
        match id {
            "table1" => self.table1(),
            "table2" => self.table2(),
            "fig1" => self.fig1(),
            "fig2" => self.fig2(),
            "fig3" => self.savings_figure("fig3", &[TraceSpec::default_trace()]),
            "fig4" => self.savings_figure("fig4", &[TraceSpec::sharing_gpu(1.0)]),
            "fig5" => {
                self.savings_figure("fig5", &[TraceSpec::multi_gpu(0.2), TraceSpec::multi_gpu(0.5)])
            }
            "fig6" => self.savings_figure(
                "fig6",
                &[TraceSpec::constrained_gpu(0.1), TraceSpec::constrained_gpu(0.33)],
            ),
            "fig7" => self.grar_figure("fig7", &[TraceSpec::default_trace()]),
            "fig8" => self.grar_figure(
                "fig8",
                &[TraceSpec::sharing_gpu(0.4), TraceSpec::sharing_gpu(1.0)],
            ),
            "fig9" => {
                self.grar_figure("fig9", &[TraceSpec::multi_gpu(0.2), TraceSpec::multi_gpu(0.5)])
            }
            "fig10" => self.grar_figure(
                "fig10",
                &[TraceSpec::constrained_gpu(0.1), TraceSpec::constrained_gpu(0.33)],
            ),
            "ext-dynalpha" => self.ext_dynalpha(),
            "ext-steady" => self.ext_steady(),
            "ext-mig" => self.ext_mig(),
            "ext-mig-het" => self.ext_mig_het(),
            "ext-profiles" => self.ext_profiles(),
            "ext-filters" => self.ext_filters(),
            "ext-drs" => self.ext_drs(),
            "ext-gang" => self.ext_gang(),
            "ext-fairness" => self.ext_fairness(),
            "ablation-tiebreak" => self.ablation_tiebreak(),
            "all" => {
                let ids = [
                    "table1", "table2", "fig1", "fig2", "fig3", "fig4", "fig5", "fig6",
                    "fig7", "fig8", "fig9", "fig10", "ext-dynalpha", "ext-steady",
                    "ext-mig", "ext-mig-het", "ext-profiles", "ext-filters", "ext-drs",
                    "ext-gang", "ext-fairness", "ablation-tiebreak",
                ];
                let mut out = Vec::new();
                for id in ids {
                    out.extend(self.run(id)?);
                }
                Ok(out)
            }
            other => bail!("unknown experiment id '{other}'"),
        }
    }

    /// Extension (paper §VII future work): load-adaptive α vs the best
    /// static combinations — savings vs FGD and GRAR on one CSV.
    fn ext_dynalpha(&mut self) -> Result<Vec<String>> {
        let trace = TraceSpec::default_trace();
        let fgd = self.cell(&trace, PolicyKind::Fgd);
        let policies = [
            PolicyKind::PwrFgd { alpha: 0.1 },
            PolicyKind::PwrFgd { alpha: 0.5 },
            PolicyKind::PwrFgdDynamic { alpha_empty: 0.5, alpha_full: 0.02 },
            PolicyKind::PwrFgdDynamic { alpha_empty: 0.9, alpha_full: 0.05 },
        ];
        let mut headers = vec!["x".to_string()];
        for p in &policies {
            headers.push(format!("savings_{}", p.label()));
            headers.push(format!("grar_{}", p.label()));
        }
        let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let path = self.out_path("ext_dynalpha.csv");
        let mut w = CsvWriter::create(&path, &header_refs)?;
        let cells: Vec<_> = policies.iter().map(|&p| self.cell(&trace, p)).collect();
        for (i, &x) in self.grid.iter().enumerate() {
            let mut row = vec![x];
            for c in &cells {
                row.push(savings_pct(&fgd.eopc[i..=i], &c.eopc[i..=i])[0]);
                row.push(c.grar[i]);
            }
            w.row(&row)?;
        }
        w.flush()?;
        Ok(vec![path])
    }

    /// Extension: composite scheduler profiles (the `SchedulerProfile`
    /// DSL) against the paper's two-objective PWR⊕FGD — can a third
    /// packing objective or load-adaptive weights beat the static
    /// combination? Emits savings-vs-FGD and GRAR series per profile
    /// (legacy labels stay byte-identical, so the PWR100+FGD900 column
    /// is comparable across PRs).
    fn ext_profiles(&mut self) -> Result<Vec<String>> {
        let trace = TraceSpec::default_trace();
        let fgd = self.cell(&trace, PolicyKind::Fgd);
        let profiles: Vec<SchedulerProfile> = vec![
            PolicyKind::PwrFgd { alpha: 0.1 }.profile(),
            // Three objectives: power + fragmentation + dot-product
            // alignment, power-leaning binder.
            SchedulerProfile::parse(
                "score(pwr=0.5,fgd=0.3,dotprod=0.2)|bind(weighted:0.5)",
            )
            .map_err(anyhow::Error::msg)?,
            // Fragmentation-leaning with a best-fit packing assist.
            SchedulerProfile::parse(
                "score(pwr=0.1,fgd=0.7,bestfit=0.2)|bind(weighted:0.1)",
            )
            .map_err(anyhow::Error::msg)?,
            // Load-adaptive three-objective profile: power weight decays
            // from 0.9 (idle) to 0.05 (saturated) while FGD:DotProd keep
            // their 3:1 ratio.
            SchedulerProfile::parse(
                "score(pwr=0.5,fgd=0.375,dotprod=0.125)|bind(weighted:0.5)|mod(loadalpha:0.9:0.05)",
            )
            .map_err(anyhow::Error::msg)?,
        ];
        let mut headers = vec!["x".to_string()];
        for p in &profiles {
            headers.push(format!("savings_{}", p.label));
            headers.push(format!("grar_{}", p.label));
        }
        let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let path = self.out_path("ext_profiles.csv");
        let mut w = CsvWriter::create(&path, &header_refs)?;
        let cells: Vec<_> =
            profiles.iter().map(|p| self.cell(&trace, p.clone())).collect();
        for (i, &x) in self.grid.iter().enumerate() {
            let mut row = vec![x];
            for c in &cells {
                row.push(savings_pct(&fgd.eopc[i..=i], &c.eopc[i..=i])[0]);
                row.push(c.grar[i]);
            }
            w.row(&row)?;
        }
        w.flush()?;
        Ok(vec![path])
    }

    /// Extension: the `filter` extension point under constraint
    /// pressure. Runs PWR⊕FGD (α = 0.1) over the `constrained-<pct>`
    /// trace family (0 / 25 / 50% of GPU tasks carrying tenant
    /// anti-affinity, GPU-model-set or spread constraints — see
    /// [`crate::trace::ConstraintGen`]) through the declarative filter
    /// pipeline, emitting EOPC, fragmentation and GRAR series per
    /// constrained share plus a counter table with the
    /// unschedulable-due-to-constraints attribution. The 0% column is
    /// the legacy-equivalence sanity anchor: it must track the Default
    /// trace's behavior.
    fn ext_filters(&mut self) -> Result<Vec<String>> {
        use crate::sim::{run_repetitions, RepeatConfig};
        let policy = PolicyKind::PwrFgd { alpha: 0.1 };
        let traces: Vec<TraceSpec> =
            EXT_FILTERS_PCTS.iter().map(|&p| TraceSpec::constrained(p)).collect();
        let rcfg = RepeatConfig {
            reps: self.cfg.reps,
            base_seed: self.cfg.seed,
            target_ratio: self.cfg.target,
            record_frag: true,
            trace: self.cfg.trace_sink.clone(),
            ..Default::default()
        };
        let mut headers = vec!["x".to_string()];
        headers.extend(traces.iter().map(|t| t.name.clone()));
        let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut eopc_cols: Vec<Vec<f64>> = Vec::new();
        let mut frag_cols: Vec<Vec<f64>> = Vec::new();
        let mut grar_cols: Vec<Vec<f64>> = Vec::new();
        let mut counter_rows = Vec::new();
        for trace in &traces {
            eprintln!(
                "[experiment] running {} / {} ({} reps, {} nodes)…",
                trace.name,
                policy.label(),
                rcfg.reps,
                self.cluster.total_nodes()
            );
            let runs = run_repetitions(&self.cluster, trace, policy, &rcfg);
            let n = runs.len().max(1) as f64;
            let mean_of = |f: &dyn Fn(&crate::sim::RunResult) -> f64| -> f64 {
                runs.iter().map(f).sum::<f64>() / n
            };
            counter_rows.push((
                trace.name.clone(),
                mean_of(&|r| r.submitted as f64),
                mean_of(&|r| r.failed as f64),
                mean_of(&|r| r.constraint_unschedulable as f64),
            ));
            let series: Vec<_> = runs.into_iter().map(|r| r.series).collect();
            eopc_cols.push(average_on_grid(&series, Column::Eopc, &self.grid));
            frag_cols.push(average_on_grid(&series, Column::Frag, &self.grid));
            grar_cols.push(average_on_grid(&series, Column::Grar, &self.grid));
        }
        let mut out = Vec::new();
        for (name, cols, scale) in [
            ("ext_filters_eopc_kw.csv", &eopc_cols, 1e-3),
            ("ext_filters_frag_gpus.csv", &frag_cols, 1.0),
            ("ext_filters_grar.csv", &grar_cols, 1.0),
        ] {
            let path = self.out_path(name);
            let mut w = CsvWriter::create(&path, &header_refs)?;
            for (i, &x) in self.grid.iter().enumerate() {
                let mut row = vec![x];
                for c in cols.iter() {
                    row.push(c[i] * scale);
                }
                w.row(&row)?;
            }
            w.flush()?;
            out.push(path);
        }
        let path = self.out_path("ext_filters_counters.csv");
        let mut w = CsvWriter::create(
            &path,
            &["trace", "submitted", "failed", "constraint_unschedulable"],
        )?;
        for (name, submitted, failed, constrained) in &counter_rows {
            w.row_str(&[
                name.clone(),
                format!("{submitted:.1}"),
                format!("{failed:.1}"),
                format!("{constrained:.1}"),
            ])?;
        }
        w.flush()?;
        out.push(path);
        Ok(out)
    }

    /// Extension: steady-state churn (arrivals + departures, Poisson/
    /// exponential) instead of monotone inflation — does the PWR⊕FGD
    /// advantage survive, and how much extra does a DRS overlay (idle
    /// nodes slept, Hu et al. [7]) gain on top of each policy?
    fn ext_steady(&mut self) -> Result<Vec<String>> {
        use crate::sim::events::{SteadyConfig, SteadySim};
        let path = self.out_path("ext_steady.csv");
        let mut w = CsvWriter::create(
            &path,
            &[
                "policy", "offered_load", "steady_eopc_kw", "steady_eopc_drs_kw",
                "steady_util", "failure_rate",
            ],
        )?;
        let trace = TraceSpec::default_trace();
        // Offered load knob: mean task duration at fixed arrival rate.
        for &(label_load, duration) in &[(0.4, 2_500.0), (0.7, 4_500.0)] {
            for policy in [
                PolicyKind::Fgd,
                PolicyKind::PwrFgd { alpha: 0.1 },
                PolicyKind::Pwr,
            ] {
                let mut eopc = Vec::new();
                let mut drs = Vec::new();
                let mut util = Vec::new();
                let mut fail = Vec::new();
                for rep in 0..self.cfg.reps.min(5) {
                    let cfg = SteadyConfig {
                        mean_interarrival_s: 1.0,
                        mean_duration_s: duration * self.cfg.scale.min(1.0),
                        horizon_s: 30_000.0 * self.cfg.scale.min(1.0),
                        sample_every_s: 250.0 * self.cfg.scale.min(1.0),
                        seed: self.cfg.seed + rep as u64,
                    };
                    let dc = self.cluster.build();
                    let mut sched = crate::sched::Scheduler::from_policy(policy);
                    self.attach_trace(&mut sched, cfg.seed);
                    let mut sim = SteadySim::new(dc, sched, &trace, &cfg);
                    let r = sim.run(&cfg);
                    eopc.push(r.steady_eopc_w);
                    drs.push(r.steady_eopc_drs_w);
                    util.push(r.steady_util);
                    fail.push(r.failed as f64 / r.arrivals.max(1) as f64);
                }
                let mean = crate::util::stats::mean;
                w.row_str(&[
                    policy.label(),
                    format!("{label_load}"),
                    format!("{:.1}", mean(&eopc) / 1e3),
                    format!("{:.1}", mean(&drs) / 1e3),
                    format!("{:.4}", mean(&util)),
                    format!("{:.4}", mean(&fail)),
                ])?;
            }
        }
        w.flush()?;
        Ok(vec![path])
    }

    /// Extension: the MIG partitioning subsystem end-to-end. Runs the
    /// paper's inflation protocol over a MIG-partitioned A100-class
    /// cluster with a slice-profile demand mix (MIG-aware BestFit /
    /// SliceFit / FGD / PWR / PWR⊕FGD, online repartitioner attached),
    /// emitting EOPC, slice-level fragmentation and GRAR series, plus a
    /// steady-state churn loop with repartitioning counters.
    fn ext_mig(&mut self) -> Result<Vec<String>> {
        use crate::sim::events::{SteadyConfig, SteadySim};
        use crate::sim::{run_repetitions, RepeatConfig};
        let n_nodes = ((32.0 * self.cfg.scale).round() as usize).clamp(8, 64);
        let cluster = ClusterSpec::mig_cluster(n_nodes, 8, n_nodes / 8);
        let trace = TraceSpec::mig_trace(0.3);
        let policies = [
            PolicyKind::MigBestFit,
            PolicyKind::MigSliceFit,
            PolicyKind::MigFgd,
            PolicyKind::MigPwr,
            PolicyKind::MigPwrFgd { alpha: 0.1 },
        ];
        let rcfg = RepeatConfig {
            reps: self.cfg.reps,
            base_seed: self.cfg.seed,
            target_ratio: self.cfg.target,
            record_frag: true,
            mig_repartition: true,
            trace: self.cfg.trace_sink.clone(),
            ..Default::default()
        };
        let mut headers = vec!["x".to_string()];
        headers.extend(policies.iter().map(|p| p.label()));
        let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut eopc_cols = Vec::new();
        let mut frag_cols = Vec::new();
        let mut grar_cols = Vec::new();
        let mut repart_rows = Vec::new();
        for &policy in &policies {
            eprintln!(
                "[experiment] running {} / {} ({} reps, {} MIG nodes)…",
                trace.name,
                policy.label(),
                rcfg.reps,
                n_nodes
            );
            let runs = run_repetitions(&cluster, &trace, policy, &rcfg);
            let reparts: f64 = runs.iter().map(|r| r.repartitions as f64).sum::<f64>()
                / runs.len().max(1) as f64;
            let slices: f64 = runs.iter().map(|r| r.migrated_slices as f64).sum::<f64>()
                / runs.len().max(1) as f64;
            repart_rows.push((policy.label(), reparts, slices));
            let series: Vec<_> = runs.into_iter().map(|r| r.series).collect();
            eopc_cols.push(average_on_grid(&series, Column::Eopc, &self.grid));
            frag_cols.push(average_on_grid(&series, Column::Frag, &self.grid));
            grar_cols.push(average_on_grid(&series, Column::Grar, &self.grid));
        }
        let mut out = Vec::new();
        for (name, cols, scale) in [
            ("ext_mig_eopc_kw.csv", &eopc_cols, 1e-3),
            ("ext_mig_frag_gpus.csv", &frag_cols, 1.0),
            ("ext_mig_grar.csv", &grar_cols, 1.0),
        ] {
            let path = self.out_path(name);
            let mut w = CsvWriter::create(&path, &header_refs)?;
            for (i, &x) in self.grid.iter().enumerate() {
                let mut row = vec![x];
                for c in cols {
                    row.push(c[i] * scale);
                }
                w.row(&row)?;
            }
            w.flush()?;
            out.push(path);
        }
        // Steady-state churn with the online repartitioner.
        let path = self.out_path("ext_mig_steady.csv");
        let mut w = CsvWriter::create(
            &path,
            &[
                "policy", "steady_eopc_kw", "steady_util", "failure_rate",
                "repartitions", "migrated_slices", "inflation_repartitions",
                "inflation_migrated_slices",
            ],
        )?;
        for (pi, &policy) in policies.iter().enumerate() {
            let cfg = SteadyConfig {
                mean_interarrival_s: 1.0,
                mean_duration_s: 400.0,
                horizon_s: 4_000.0,
                sample_every_s: 50.0,
                seed: self.cfg.seed,
            };
            let mut sched = crate::sched::Scheduler::from_policy(policy);
            sched.add_post_hook(Box::new(crate::sched::policies::MigRepartitioner::new(
                crate::sched::policies::RepartitionConfig::default(),
            )));
            self.attach_trace(&mut sched, cfg.seed);
            let mut sim = SteadySim::new(cluster.build(), sched, &trace, &cfg);
            let r = sim.run(&cfg);
            let (label, infl_reparts, infl_slices) = &repart_rows[pi];
            w.row_str(&[
                label.clone(),
                format!("{:.1}", r.steady_eopc_w / 1e3),
                format!("{:.4}", r.steady_util),
                format!("{:.4}", r.failed as f64 / r.arrivals.max(1) as f64),
                format!("{}", r.repartitions),
                format!("{}", r.migrated_slices),
                format!("{infl_reparts:.1}"),
                format!("{infl_slices:.1}"),
            ])?;
        }
        w.flush()?;
        out.push(path);
        Ok(out)
    }

    /// Extension: heterogeneous MIG fleets. Runs the inflation protocol
    /// over a mixed A100 (7-slice lattice) + A30 (4-slice lattice)
    /// cluster with the `mig-het-*` demand mix, the MIG policy family,
    /// and the repartitioner in *proactive* mode (frag-threshold
    /// repacks ahead of demand). Emits overall **and per-lattice-model**
    /// EOPC / fragmentation / GRAR series, plus a churn table with the
    /// reactive/proactive repartition counters.
    fn ext_mig_het(&mut self) -> Result<Vec<String>> {
        use crate::metrics::Column::{
            Eopc, EopcA100, EopcA30, Frag, FragA100, FragA30, Grar, GrarA100, GrarA30,
        };
        use crate::sim::events::{SteadyConfig, SteadySim};
        use crate::sim::{run_repetitions, RepeatConfig};
        let n_a100 = ((20.0 * self.cfg.scale).round() as usize).clamp(4, 40);
        let n_a30 = ((12.0 * self.cfg.scale).round() as usize).clamp(4, 24);
        let cluster = ClusterSpec::mig_het_cluster(n_a100, n_a30, 8, (n_a100 + n_a30) / 8);
        let trace = TraceSpec::mig_het_trace(0.3, MIG_HET_A30_SHARE);
        let policies = [
            PolicyKind::MigBestFit,
            PolicyKind::MigSliceFit,
            PolicyKind::MigFgd,
            PolicyKind::MigPwr,
            PolicyKind::MigPwrFgd { alpha: 0.1 },
        ];
        let rcfg = RepeatConfig {
            reps: self.cfg.reps,
            base_seed: self.cfg.seed,
            target_ratio: self.cfg.target,
            record_frag: true,
            mig_repartition: true,
            mig_frag_threshold: MIG_HET_FRAG_THRESHOLD,
            trace: self.cfg.trace_sink.clone(),
            ..Default::default()
        };
        // Per policy: (total, A100, A30) columns for each metric.
        let mut headers = vec!["x".to_string()];
        for p in &policies {
            for suffix in ["", ":A100-7g", ":A30-4g"] {
                headers.push(format!("{}{}", p.label(), suffix));
            }
        }
        let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut eopc_cols: Vec<Vec<f64>> = Vec::new();
        let mut frag_cols: Vec<Vec<f64>> = Vec::new();
        let mut grar_cols: Vec<Vec<f64>> = Vec::new();
        let mut churn_rows = Vec::new();
        for &policy in &policies {
            eprintln!(
                "[experiment] running {} / {} ({} reps, {} A100 + {} A30 nodes)…",
                trace.name,
                policy.label(),
                rcfg.reps,
                n_a100,
                n_a30
            );
            let runs = run_repetitions(&cluster, &trace, policy, &rcfg);
            let n = runs.len().max(1) as f64;
            let mean_of = |f: &dyn Fn(&crate::sim::RunResult) -> f64| -> f64 {
                runs.iter().map(f).sum::<f64>() / n
            };
            churn_rows.push((
                policy.label(),
                mean_of(&|r| r.repartitions as f64),
                mean_of(&|r| r.proactive_repartitions as f64),
                mean_of(&|r| r.migrated_slices as f64),
            ));
            let series: Vec<_> = runs.into_iter().map(|r| r.series).collect();
            for (cols, group) in [
                (&mut eopc_cols, [Eopc, EopcA100, EopcA30]),
                (&mut frag_cols, [Frag, FragA100, FragA30]),
                (&mut grar_cols, [Grar, GrarA100, GrarA30]),
            ] {
                for col in group {
                    cols.push(average_on_grid(&series, col, &self.grid));
                }
            }
        }
        let mut out = Vec::new();
        for (name, cols, scale) in [
            ("ext_mig_het_eopc_kw.csv", &eopc_cols, 1e-3),
            ("ext_mig_het_frag_gpus.csv", &frag_cols, 1.0),
            ("ext_mig_het_grar.csv", &grar_cols, 1.0),
        ] {
            let path = self.out_path(name);
            let mut w = CsvWriter::create(&path, &header_refs)?;
            for (i, &x) in self.grid.iter().enumerate() {
                let mut row = vec![x];
                for c in cols.iter() {
                    row.push(c[i] * scale);
                }
                w.row(&row)?;
            }
            w.flush()?;
            out.push(path);
        }
        // Churn: inflation counters + a steady-state run per policy with
        // the same proactive threshold.
        let path = self.out_path("ext_mig_het_churn.csv");
        let mut w = CsvWriter::create(
            &path,
            &[
                "policy", "inflation_repartitions", "inflation_proactive",
                "inflation_migrated_slices", "steady_eopc_kw", "steady_util",
                "failure_rate", "steady_repartitions", "steady_proactive",
                "steady_migrated_slices",
            ],
        )?;
        for (pi, &policy) in policies.iter().enumerate() {
            let cfg = SteadyConfig {
                mean_interarrival_s: 1.0,
                mean_duration_s: 400.0,
                horizon_s: 4_000.0,
                sample_every_s: 50.0,
                seed: self.cfg.seed,
            };
            let mut sched = crate::sched::Scheduler::from_policy(policy);
            sched.add_post_hook(Box::new(crate::sched::policies::MigRepartitioner::new(
                crate::sched::policies::RepartitionConfig::with_threshold(
                    MIG_HET_FRAG_THRESHOLD,
                ),
            )));
            self.attach_trace(&mut sched, cfg.seed);
            let mut sim = SteadySim::new(cluster.build(), sched, &trace, &cfg);
            let r = sim.run(&cfg);
            let (label, infl_re, infl_pro, infl_slices) = &churn_rows[pi];
            w.row_str(&[
                label.clone(),
                format!("{infl_re:.1}"),
                format!("{infl_pro:.1}"),
                format!("{infl_slices:.1}"),
                format!("{:.1}", r.steady_eopc_w / 1e3),
                format!("{:.4}", r.steady_util),
                format!("{:.4}", r.failed as f64 / r.arrivals.max(1) as f64),
                format!("{}", r.repartitions),
                format!("{}", r.proactive_repartitions),
                format!("{}", r.migrated_slices),
            ])?;
        }
        w.flush()?;
        out.push(path);
        Ok(out)
    }

    /// Extension: the DRS sleep/wake subsystem (`docs/power.md`) on
    /// diurnal load. Steady-state churn with a sinusoidal arrival rate
    /// (`diurnal-<amp>` trace family); baseline PWR⊕FGD (every node
    /// powered forever) against PWR⊕FGD+consolidate with a
    /// `hook(drs:timeout:latency)` across the idle-timeout ×
    /// wake-latency grid. Emits the sweep summary (EOPC, GRAR, asleep
    /// nodes, sleep/wake churn) plus an EOPC/asleep time series for
    /// one representative cell, showing the power curve following the
    /// diurnal valley instead of flooring at idle watts.
    fn ext_drs(&mut self) -> Result<Vec<String>> {
        use crate::sim::events::{SteadyConfig, SteadySim};
        let scale = self.cfg.scale.min(1.0);
        // Two full diurnal cycles; offered load leaves headroom so the
        // valleys actually empty nodes (≈ 35% mean GPU utilization).
        let horizon = 24_000.0 * scale;
        let trace = TraceSpec::diurnal_with_period(EXT_DRS_AMPLITUDE, horizon / 2.0);
        // Steady-state runs are wall-clock-bound like ext-steady's, and
        // this sweep runs 1 + |timeouts|·|latencies| policies — cap the
        // repetitions the same way ext-steady does (min 5).
        let reps = self.cfg.reps.min(5).max(1);
        let run = |policy: &SchedulerProfile| -> Vec<crate::sim::events::SteadyResult> {
            (0..reps)
                .map(|rep| {
                    let cfg = SteadyConfig {
                        mean_interarrival_s: 1.0,
                        mean_duration_s: 3_000.0 * scale,
                        horizon_s: horizon,
                        sample_every_s: 200.0 * scale,
                        seed: self.cfg.seed + rep as u64,
                    };
                    let mut sched = policy.build().expect("valid ext-drs profile");
                    self.attach_trace(&mut sched, cfg.seed);
                    let mut sim = SteadySim::new(self.cluster.build(), sched, &trace, &cfg);
                    sim.run(&cfg)
                })
                .collect()
        };
        let base_profile: SchedulerProfile = PolicyKind::PwrFgd { alpha: 0.1 }.into();
        let drs_profile = |timeout: f64, latency: u64| -> SchedulerProfile {
            SchedulerProfile::parse(&format!(
                "score(pwr=0.1,fgd=0.7,consolidate=0.2)|bind(weighted:0.1)|hook(drs:{timeout}:{latency})"
            ))
            .expect("valid drs profile")
        };
        let mean = crate::util::stats::mean;
        let summarize = |runs: &[crate::sim::events::SteadyResult]| -> [f64; 6] {
            [
                mean(&runs.iter().map(|r| r.steady_eopc_w).collect::<Vec<_>>()),
                mean(&runs.iter().map(|r| r.final_grar()).collect::<Vec<_>>()),
                mean(&runs
                    .iter()
                    .map(|r| r.failed as f64 / r.arrivals.max(1) as f64)
                    .collect::<Vec<_>>()),
                mean(&runs.iter().map(|r| r.mean_asleep_nodes).collect::<Vec<_>>()),
                mean(&runs.iter().map(|r| r.drs_sleeps as f64).collect::<Vec<_>>()),
                mean(&runs.iter().map(|r| r.drs_wakes as f64).collect::<Vec<_>>()),
            ]
        };
        let path = self.out_path("ext_drs.csv");
        let mut w = CsvWriter::create(
            &path,
            &[
                "policy", "idle_timeout", "wake_latency", "steady_eopc_kw", "grar",
                "failure_rate", "mean_asleep_nodes", "sleeps", "wakes",
            ],
        )?;
        eprintln!(
            "[experiment] running {} / {} ({} reps, {} nodes)…",
            trace.name,
            base_profile.label,
            reps,
            self.cluster.total_nodes()
        );
        let base_runs = run(&base_profile);
        let b = summarize(&base_runs);
        w.row_str(&[
            base_profile.label.clone(),
            "inf".into(),
            "-".into(),
            format!("{:.1}", b[0] / 1e3),
            format!("{:.4}", b[1]),
            format!("{:.4}", b[2]),
            format!("{:.1}", b[3]),
            format!("{:.1}", b[4]),
            format!("{:.1}", b[5]),
        ])?;
        // Keep the representative cell's series for the second CSV.
        let mut series_cell: Option<(String, crate::metrics::RunSeries)> = None;
        for &timeout in &EXT_DRS_TIMEOUTS {
            for &latency in &EXT_DRS_LATENCIES {
                let profile = drs_profile(timeout, latency);
                eprintln!(
                    "[experiment] running {} / {} (timeout {timeout}, latency {latency})…",
                    trace.name, profile.label
                );
                let runs = run(&profile);
                let s = summarize(&runs);
                w.row_str(&[
                    profile.label.clone(),
                    format!("{timeout}"),
                    format!("{latency}"),
                    format!("{:.1}", s[0] / 1e3),
                    format!("{:.4}", s[1]),
                    format!("{:.4}", s[2]),
                    format!("{:.1}", s[3]),
                    format!("{:.1}", s[4]),
                    format!("{:.1}", s[5]),
                ])?;
                if series_cell.is_none()
                    && timeout == EXT_DRS_TIMEOUTS[1]
                    && latency == EXT_DRS_LATENCIES[0]
                {
                    series_cell =
                        Some((profile.label.clone(), runs[0].series.clone()));
                }
            }
        }
        w.flush()?;
        let mut out = vec![path];
        // Time series: base vs the representative DRS cell (first rep;
        // both runs share the sampling cadence, so rows align).
        if let Some((drs_label, drs_series)) = series_cell {
            let path = self.out_path("ext_drs_series.csv");
            let mut w = CsvWriter::create(
                &path,
                &["x", "eopc_base_kw", "eopc_drs_kw", "asleep_drs"],
            )?;
            let base_series = &base_runs[0].series;
            let n = base_series.points.len().min(drs_series.points.len());
            for i in 0..n {
                let bp = &base_series.points[i];
                let dp = &drs_series.points[i];
                w.row(&[bp.x, bp.eopc / 1e3, dp.eopc / 1e3, dp.asleep_nodes])?;
            }
            w.flush()?;
            eprintln!("[experiment]   series cell: {drs_label}");
            out.push(path);
        }
        Ok(out)
    }

    /// Extension: the pending-queue fairness subsystem
    /// (`docs/fairness.md`) under multi-tenant churn. Steady-state
    /// `priority-50` arrivals against a PWR⊕FGD baseline that drops
    /// unschedulable tasks (the seed behavior), then the
    /// starvation-threshold × preemption-budget grid with the pending
    /// queue enabled, `mod(starve)` weight modulation and
    /// `hook(preempt)` priority eviction. One summary CSV: EOPC,
    /// fragmentation and GRAR alongside the starvation metrics (p99
    /// wait, pending depth, starvation events, preemptions) per cell.
    fn ext_fairness(&mut self) -> Result<Vec<String>> {
        use crate::sim::events::{SteadyConfig, SteadyResult, SteadySim};
        let scale = self.cfg.scale.min(1.0);
        let trace = TraceSpec::priority_trace(EXT_FAIRNESS_PRIORITY_PCT);
        // Wall-clock-bound like ext-steady/ext-drs: cap repetitions.
        let reps = self.cfg.reps.min(5).max(1);
        let run = |policy: &SchedulerProfile,
                   fairness: Option<crate::sched::FairnessConfig>|
         -> Vec<SteadyResult> {
            (0..reps)
                .map(|rep| {
                    let cfg = SteadyConfig {
                        mean_interarrival_s: 1.0,
                        mean_duration_s: 2_000.0 * scale,
                        horizon_s: 20_000.0 * scale,
                        sample_every_s: 200.0 * scale,
                        seed: self.cfg.seed + rep as u64,
                    };
                    let mut sched = policy.build().expect("valid ext-fairness profile");
                    self.attach_trace(&mut sched, cfg.seed);
                    let mut sim = SteadySim::new(self.cluster.build(), sched, &trace, &cfg);
                    if let Some(fc) = fairness {
                        sim.enable_fairness(fc);
                    }
                    sim.run(&cfg)
                })
                .collect()
        };
        let mean = crate::util::stats::mean;
        // Fragmentation over the warmed-up second half of the series.
        let frag_mean = |r: &SteadyResult| -> f64 {
            let pts = &r.series.points;
            if pts.is_empty() {
                return 0.0;
            }
            let tail = &pts[pts.len() / 2..];
            tail.iter().map(|p| p.frag).sum::<f64>() / tail.len() as f64
        };
        let summarize = |runs: &[SteadyResult]| -> [f64; 8] {
            [
                mean(&runs.iter().map(|r| r.steady_eopc_w).collect::<Vec<_>>()),
                mean(&runs.iter().map(frag_mean).collect::<Vec<_>>()),
                mean(&runs.iter().map(|r| r.final_grar()).collect::<Vec<_>>()),
                mean(&runs
                    .iter()
                    .map(|r| r.failed as f64 / r.arrivals.max(1) as f64)
                    .collect::<Vec<_>>()),
                mean(&runs.iter().map(|r| r.p99_wait).collect::<Vec<_>>()),
                mean(&runs.iter().map(|r| r.pending_depth as f64).collect::<Vec<_>>()),
                mean(&runs.iter().map(|r| r.starvation_events as f64).collect::<Vec<_>>()),
                mean(&runs.iter().map(|r| r.preemptions as f64).collect::<Vec<_>>()),
            ]
        };
        let path = self.out_path("ext_fairness.csv");
        let mut w = CsvWriter::create(
            &path,
            &[
                "policy", "starve_threshold", "preempt_budget", "steady_eopc_kw",
                "steady_frag_gpus", "grar", "failure_rate", "p99_wait_s",
                "pending_depth", "starvation_events", "preemptions",
            ],
        )?;
        let row = |w: &mut CsvWriter,
                   label: &str,
                   thr: &str,
                   budget: &str,
                   s: &[f64; 8]|
         -> Result<()> {
            w.row_str(&[
                label.to_string(),
                thr.to_string(),
                budget.to_string(),
                format!("{:.1}", s[0] / 1e3),
                format!("{:.2}", s[1]),
                format!("{:.4}", s[2]),
                format!("{:.4}", s[3]),
                format!("{:.1}", s[4]),
                format!("{:.1}", s[5]),
                format!("{:.1}", s[6]),
                format!("{:.1}", s[7]),
            ])?;
            Ok(())
        };
        let base_profile: SchedulerProfile = PolicyKind::PwrFgd { alpha: 0.1 }.into();
        eprintln!(
            "[experiment] running {} / {} (baseline drop, {} reps, {} nodes)…",
            trace.name,
            base_profile.label,
            reps,
            self.cluster.total_nodes()
        );
        let b = summarize(&run(&base_profile, None));
        row(&mut w, &base_profile.label, "-", "-", &b)?;
        for &threshold in &EXT_FAIRNESS_THRESHOLDS {
            for &budget in &EXT_FAIRNESS_BUDGETS {
                let profile = SchedulerProfile::parse(&format!(
                    "score(pwr=0.1,fgd=0.9)|bind(weighted:0.1)\
                     |mod(starve:{threshold}:{boost})|hook(preempt:{budget})",
                    boost = EXT_FAIRNESS_BOOST,
                ))
                .expect("valid ext-fairness profile");
                eprintln!(
                    "[experiment] running {} / {} (threshold {threshold}, budget {budget})…",
                    trace.name, profile.label
                );
                let s = summarize(&run(
                    &profile,
                    Some(crate::sched::FairnessConfig { starve_threshold: threshold }),
                ));
                row(&mut w, &profile.label, &format!("{threshold}"), &format!("{budget}"), &s)?;
            }
        }
        w.flush()?;
        Ok(vec![path])
    }

    /// Extension: topology-aware gang scheduling (`docs/gang.md`). Runs
    /// mixed gang/singleton traces (`gang-<pct>`, 0 / 30 / 60% of the
    /// whole-GPU population replaced by TP×PP×DP gangs) over a zoned
    /// cluster, sweeping the `topo` score weight against plain PWR⊕FGD
    /// and a DRS consolidation profile. Emits EOPC, fragmentation and
    /// GRAR series per (trace, profile) plus a gang counter table —
    /// placement rate, mean PP span (distinct nodes per placed gang)
    /// and the cross-node-TP violation count, which must be zero by
    /// construction (the run aborts otherwise rather than reporting a
    /// broken invariant as data). The gang-0 column is the
    /// legacy-equivalence anchor: `tests/gang_equivalence.rs` pins it
    /// bit-identical to the pre-gang scheduler.
    fn ext_gang(&mut self) -> Result<Vec<String>> {
        use crate::sim::{run_repetitions, RepeatConfig};
        let cluster = self.cluster.clone().with_zones(EXT_GANG_ZONES);
        let traces: Vec<TraceSpec> =
            EXT_GANG_PCTS.iter().map(|&p| TraceSpec::gang_trace(p)).collect();
        let profiles: Vec<SchedulerProfile> = [
            "score(pwr=0.1,fgd=0.9)",
            "score(pwr=0.1,fgd=0.6,topo=0.3)",
            "score(pwr=0.1,fgd=0.3,topo=0.6)",
            "score(pwr=0.3,fgd=0.3,consolidate=0.2,topo=0.2)|hook(drs:200:0)",
        ]
        .iter()
        .map(|&s| SchedulerProfile::parse(s).map_err(anyhow::Error::msg))
        .collect::<Result<_>>()?;
        let rcfg = RepeatConfig {
            reps: self.cfg.reps,
            base_seed: self.cfg.seed,
            target_ratio: self.cfg.target,
            record_frag: true,
            trace: self.cfg.trace_sink.clone(),
            ..Default::default()
        };
        let mut headers = vec!["x".to_string()];
        for trace in &traces {
            for p in &profiles {
                headers.push(format!("{}/{}", trace.name, p.label));
            }
        }
        let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut eopc_cols: Vec<Vec<f64>> = Vec::new();
        let mut frag_cols: Vec<Vec<f64>> = Vec::new();
        let mut grar_cols: Vec<Vec<f64>> = Vec::new();
        let mut counter_rows = Vec::new();
        for trace in &traces {
            for profile in &profiles {
                eprintln!(
                    "[experiment] running {} / {} ({} reps, {} nodes, {} zones)…",
                    trace.name,
                    profile.label,
                    rcfg.reps,
                    cluster.total_nodes(),
                    EXT_GANG_ZONES
                );
                let runs = run_repetitions(&cluster, trace, profile.clone(), &rcfg);
                let n = runs.len().max(1) as f64;
                let mean_of = |f: &dyn Fn(&crate::sim::RunResult) -> f64| -> f64 {
                    runs.iter().map(f).sum::<f64>() / n
                };
                let violations: u64 = runs.iter().map(|r| r.gang_tp_violations).sum();
                if violations > 0 {
                    bail!(
                        "{} / {}: {} cross-node TP violations — the gang binder \
                         must keep every TP group on one NVLink domain",
                        trace.name,
                        profile.label,
                        violations
                    );
                }
                let placed = mean_of(&|r| r.gangs_placed as f64);
                let gang_failed = mean_of(&|r| r.gangs_failed as f64);
                let span_sum = mean_of(&|r| r.gang_pp_span_sum as f64);
                counter_rows.push((
                    trace.name.clone(),
                    profile.label.clone(),
                    placed,
                    gang_failed,
                    if placed + gang_failed > 0.0 {
                        format!("{:.4}", placed / (placed + gang_failed))
                    } else {
                        "-".to_string()
                    },
                    if placed > 0.0 {
                        format!("{:.3}", span_sum / placed)
                    } else {
                        "-".to_string()
                    },
                ));
                let series: Vec<_> = runs.into_iter().map(|r| r.series).collect();
                eopc_cols.push(average_on_grid(&series, Column::Eopc, &self.grid));
                frag_cols.push(average_on_grid(&series, Column::Frag, &self.grid));
                grar_cols.push(average_on_grid(&series, Column::Grar, &self.grid));
            }
        }
        let mut out = Vec::new();
        for (name, cols, scale) in [
            ("ext_gang_eopc_kw.csv", &eopc_cols, 1e-3),
            ("ext_gang_frag_gpus.csv", &frag_cols, 1.0),
            ("ext_gang_grar.csv", &grar_cols, 1.0),
        ] {
            let path = self.out_path(name);
            let mut w = CsvWriter::create(&path, &header_refs)?;
            for (i, &x) in self.grid.iter().enumerate() {
                let mut row = vec![x];
                for c in cols.iter() {
                    row.push(c[i] * scale);
                }
                w.row(&row)?;
            }
            w.flush()?;
            out.push(path);
        }
        let path = self.out_path("ext_gang_counters.csv");
        let mut w = CsvWriter::create(
            &path,
            &[
                "trace", "policy", "gangs_placed", "gangs_failed",
                "gang_placement_rate", "mean_pp_span",
            ],
        )?;
        for (trace, policy, placed, gang_failed, rate, span) in &counter_rows {
            w.row_str(&[
                trace.clone(),
                policy.clone(),
                format!("{placed:.1}"),
                format!("{gang_failed:.1}"),
                rate.clone(),
                span.clone(),
            ])?;
        }
        w.flush()?;
        out.push(path);
        Ok(out)
    }

    /// Ablation: Kubernetes' random tie-break vs deterministic
    /// lowest-id selection. Shows how much of both FGD's EOPC *and*
    /// PWR's advantage rides on `selectHost` semantics.
    fn ablation_tiebreak(&mut self) -> Result<Vec<String>> {
        let trace = TraceSpec::default_trace();
        let path = self.out_path("ablation_tiebreak.csv");
        let mut w = CsvWriter::create(
            &path,
            &["x", "fgd_random_mw", "fgd_det_mw", "combo_random_mw", "combo_det_mw"],
        )?;
        let run = |h: &Harness, p: PolicyKind, det: bool| {
            let rcfg = RepeatConfig {
                reps: h.cfg.reps,
                base_seed: h.cfg.seed,
                target_ratio: h.cfg.target,
                deterministic_ties: det,
                ..Default::default()
            };
            let runs = run_repetitions(&h.cluster, &trace, p, &rcfg);
            let series: Vec<_> = runs.into_iter().map(|r| r.series).collect();
            average_on_grid(&series, Column::Eopc, &h.grid)
        };
        let combo = PolicyKind::PwrFgd { alpha: 0.1 };
        let cols = [
            run(self, PolicyKind::Fgd, false),
            run(self, PolicyKind::Fgd, true),
            run(self, combo, false),
            run(self, combo, true),
        ];
        for (i, &x) in self.grid.clone().iter().enumerate() {
            w.row(&[x, cols[0][i] / 1e6, cols[1][i] / 1e6, cols[2][i] / 1e6, cols[3][i] / 1e6])?;
        }
        w.flush()?;
        Ok(vec![path])
    }

    /// Table I: per-bucket marginals for every trace family.
    fn table1(&mut self) -> Result<Vec<String>> {
        let path = self.out_path("table1.csv");
        let mut w = CsvWriter::create(
            &path,
            &["trace", "bucket", "population_pct", "gpu_share_pct"],
        )?;
        let buckets = ["0", "(0,1)", "1", "2", "4", "8"];
        let specs = [
            TraceSpec::default_trace(),
            TraceSpec::multi_gpu(0.2),
            TraceSpec::multi_gpu(0.5),
            TraceSpec::sharing_gpu(0.4),
            TraceSpec::sharing_gpu(1.0),
            TraceSpec::constrained_gpu(0.33),
        ];
        for spec in &specs {
            let trace = spec.synthesize(self.cfg.seed);
            let pop = trace.population_pct();
            let share = trace.gpu_share_pct();
            for b in 0..buckets.len() {
                w.row_str(&[
                    spec.name.clone(),
                    buckets[b].to_string(),
                    format!("{:.2}", pop[b]),
                    format!("{:.2}", share[b]),
                ])?;
            }
        }
        w.flush()?;
        Ok(vec![path])
    }

    /// Table II: GPU inventory + power profiles as built.
    fn table2(&mut self) -> Result<Vec<String>> {
        let path = self.out_path("table2.csv");
        let mut w =
            CsvWriter::create(&path, &["gpu_model", "amount", "power_idle_w", "tdp_w"])?;
        for (model, count) in ClusterSpec::paper_default().gpus_by_model() {
            w.row_str(&[
                model.to_string(),
                count.to_string(),
                format!("{}", model.p_idle()),
                format!("{}", model.p_max()),
            ])?;
        }
        w.flush()?;
        Ok(vec![path])
    }

    /// Fig. 1: FGD EOPC on the Default trace, CPU/GPU stacked + share.
    fn fig1(&mut self) -> Result<Vec<String>> {
        let cell = self.cell(&TraceSpec::default_trace(), PolicyKind::Fgd);
        let path = self.out_path("fig1.csv");
        let mut w = CsvWriter::create(
            &path,
            &["x", "eopc_mw", "cpu_mw", "gpu_mw", "gpu_share"],
        )?;
        for (i, &x) in self.grid.iter().enumerate() {
            let share = if cell.eopc[i] > 0.0 { cell.gpu_w[i] / cell.eopc[i] } else { 0.0 };
            w.row(&[
                x,
                cell.eopc[i] / 1e6,
                cell.cpu_w[i] / 1e6,
                cell.gpu_w[i] / 1e6,
                share,
            ])?;
        }
        w.flush()?;
        Ok(vec![path])
    }

    /// Fig. 2: α-sweep — savings vs FGD (top) and GRAR (bottom).
    fn fig2(&mut self) -> Result<Vec<String>> {
        let trace = TraceSpec::default_trace();
        let fgd = self.cell(&trace, PolicyKind::Fgd);
        let mut headers = vec!["x".to_string()];
        let mut cells = Vec::new();
        for &alpha in &FIG2_ALPHAS {
            let p = if alpha >= 1.0 {
                PolicyKind::Pwr
            } else {
                PolicyKind::PwrFgd { alpha }
            };
            headers.push(p.label());
            cells.push(self.cell(&trace, p));
        }
        let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();

        let savings_path = self.out_path("fig2_savings.csv");
        let mut w = CsvWriter::create(&savings_path, &header_refs)?;
        for (i, &x) in self.grid.iter().enumerate() {
            let mut row = vec![x];
            for c in &cells {
                row.push(savings_pct(&fgd.eopc[i..=i], &c.eopc[i..=i])[0]);
            }
            w.row(&row)?;
        }
        w.flush()?;

        let grar_path = self.out_path("fig2_grar.csv");
        let mut w = CsvWriter::create(&grar_path, &header_refs)?;
        for (i, &x) in self.grid.iter().enumerate() {
            let mut row = vec![x];
            for c in &cells {
                row.push(c.grar[i]);
            }
            w.row(&row)?;
        }
        w.flush()?;
        Ok(vec![savings_path, grar_path])
    }

    /// Figs. 3–6: power savings vs plain FGD for the comparison set.
    fn savings_figure(&mut self, id: &str, traces: &[TraceSpec]) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for trace in traces {
            let fgd = self.cell(trace, PolicyKind::Fgd);
            let policies = comparison_policies();
            let mut headers = vec!["x".to_string()];
            headers.extend(policies.iter().map(|p| p.label()));
            let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
            let path = self.out_path(&format!("{id}_{}.csv", trace.name));
            let mut w = CsvWriter::create(&path, &header_refs)?;
            let cells: Vec<_> = policies.iter().map(|&p| self.cell(trace, p)).collect();
            for (i, &x) in self.grid.iter().enumerate() {
                let mut row = vec![x];
                for c in &cells {
                    row.push(savings_pct(&fgd.eopc[i..=i], &c.eopc[i..=i])[0]);
                }
                w.row(&row)?;
            }
            w.flush()?;
            out.push(path);
        }
        Ok(out)
    }

    /// Figs. 7–10: GRAR for FGD + the comparison set.
    fn grar_figure(&mut self, id: &str, traces: &[TraceSpec]) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for trace in traces {
            let mut policies = vec![PolicyKind::Fgd];
            policies.extend(comparison_policies());
            let mut headers = vec!["x".to_string()];
            headers.extend(policies.iter().map(|p| p.label()));
            let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
            let path = self.out_path(&format!("{id}_{}.csv", trace.name));
            let mut w = CsvWriter::create(&path, &header_refs)?;
            let cells: Vec<_> = policies.iter().map(|&p| self.cell(trace, p)).collect();
            for (i, &x) in self.grid.iter().enumerate() {
                let mut row = vec![x];
                for c in &cells {
                    row.push(c.grar[i]);
                }
                w.row(&row)?;
            }
            w.flush()?;
            out.push(path);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(dir: &str) -> ExpConfig {
        ExpConfig {
            reps: 2,
            seed: 1,
            scale: 0.03,
            target: 0.6,
            out_dir: dir.to_string(),
            trace_sink: None,
        }
    }

    #[test]
    fn table1_and_table2_write() {
        let dir = std::env::temp_dir().join("repro_exp_tables");
        let mut h = Harness::new(tiny_cfg(dir.to_str().unwrap()));
        let files = h.run("table1").unwrap();
        assert_eq!(files.len(), 1);
        let text = std::fs::read_to_string(&files[0]).unwrap();
        assert!(text.contains("default"));
        let files = h.run("table2").unwrap();
        let text = std::fs::read_to_string(&files[0]).unwrap();
        assert!(text.contains("G2,4392,30,150"));
    }

    #[test]
    fn fig1_writes_and_caches() {
        let dir = std::env::temp_dir().join("repro_exp_fig1");
        let mut h = Harness::new(tiny_cfg(dir.to_str().unwrap()));
        let files = h.run("fig1").unwrap();
        let (header, rows) =
            crate::util::csv::read_csv(&std::fs::read_to_string(&files[0]).unwrap());
        assert_eq!(header[0], "x");
        assert!(rows.len() > 10);
        // Cached: a second run must not re-simulate (same result).
        let files2 = h.run("fig1").unwrap();
        assert_eq!(files, files2);
    }

    #[test]
    fn unknown_id_errors() {
        let mut h = Harness::new(tiny_cfg("/tmp/repro_x"));
        assert!(h.run("fig99").is_err());
    }
}
