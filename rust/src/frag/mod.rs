//! The FGD fragmentation metric (Weng et al., USENIX ATC'23; §II of the
//! paper).
//!
//! For a node `n` and a task class `m`, `F_n(m)` measures how much of
//! `n`'s *unallocated* GPU resource cannot be used by a task of class
//! `m`. Two cases (the paper defers the definition to [19]):
//!
//! 1. `m` **cannot run** on `n` at all (Cond. 1–3 or a model-constraint
//!    failure): every unallocated GPU fraction is a fragment —
//!    `F_n(m) = Σ_g R_{n,g}`.
//! 2. `m` **can run**: a GPU's free fraction is a fragment iff a task of
//!    class `m` could not use that GPU:
//!    * `D_m^GPU ∈ (0,1)`: fragment of GPU g is `R_g` when `0 < R_g < D`;
//!    * `D_m^GPU ∈ Z+`: fragment is `R_g` when `0 < R_g < 1` (whole-GPU
//!      tasks cannot use partial GPUs);
//!    * `D_m^GPU = 0`: CPU-only tasks consume no GPU — no fragment.
//!
//! The node's expected fragmentation is `F_n(M) = Σ_m pop_m · F_n(m)`
//! and the datacenter's is `F_dc = Σ_n F_n(M)` (Eq. 4).
//!
//! **MIG extension** (see [`crate::cluster::mig`]): for a class
//! demanding a MIG profile `p` on a MIG-partitioned node of `p`'s
//! lattice, a free slice is a fragment iff no legal free placement of
//! `p` could consume it ([`crate::cluster::mig::frag_slices`]),
//! measured in GPU units (slices / lattice slices). This reduces to the
//! per-GPU rule above when the profile's windows cover every free
//! slice, and additionally captures lattice fragmentation (e.g. A100
//! slice 6 is unusable by any ≥2g profile). MIG classes on non-MIG
//! nodes or on nodes of the *other* lattice — and fractional/whole
//! classes on MIG nodes — cannot run, so case 1 applies and every free
//! unit fragments.

use crate::cluster::mig::{self, MigLattice, N_PROFILES};
use crate::cluster::node::{ResourceView, EPS};
use crate::cluster::Datacenter;
use crate::tasks::{GpuDemand, TaskClass, Workload};

/// `F_n(m)`: GPU fragmentation of a node view for one task class.
pub fn f_node_class<V: ResourceView + ?Sized>(v: &V, class: &TaskClass) -> f64 {
    let task = class.as_task();
    if !v.can_fit(&task) {
        // Case 1: all unallocated GPU resources are unusable by m.
        return v.gpu_free_total();
    }
    // Case 2: count per-GPU residuals unusable by m.
    match class.gpu {
        GpuDemand::Zero => 0.0,
        GpuDemand::Frac(d) => {
            let mut frag = 0.0;
            for g in 0..v.n_gpus() {
                let r = v.gpu_free_of(g);
                if r > EPS && r < d - EPS {
                    frag += r;
                }
            }
            frag
        }
        GpuDemand::Whole(_) => {
            let mut frag = 0.0;
            for g in 0..v.n_gpus() {
                let r = v.gpu_free_of(g);
                if r > EPS && r < 1.0 - EPS {
                    frag += r;
                }
            }
            frag
        }
        GpuDemand::Mig(p) => {
            // Case-2 implies the node's lattice matches the profile's
            // (`can_fit` gates the other combinations into case 1).
            let slices = p.lattice().slices() as f64;
            let mut frag = 0.0;
            for g in 0..v.n_gpus() {
                if let Some(mask) = v.mig_mask_of(g) {
                    frag += mig::frag_slices(mask, p) as f64 / slices;
                }
            }
            frag
        }
    }
}

/// `F_n(M) = Σ_m pop_m · F_n(m)`: expected fragmentation of a node.
pub fn f_node<V: ResourceView + ?Sized>(v: &V, workload: &Workload) -> f64 {
    workload.classes().iter().map(|m| m.pop * f_node_class(v, m)).sum()
}

/// `F_dc = Σ_n F_n(M)` (Eq. 4), in GPU units.
pub fn f_datacenter(dc: &Datacenter, workload: &Workload) -> f64 {
    dc.nodes.iter().map(|n| f_node(n, workload)).sum()
}

// ---------------------------------------------------------------------------
// Fast path (§Perf): the generic `f_node` above recomputes O(G) node
// reductions *per class*. The scheduler's hot loop instead builds a
// [`FragEval`] once per hypothetical state — O(G log G) — after which
// every class costs O(1)–O(G): feasibility from precomputed stats,
// whole-class fragments from a precomputed total, fractional-class
// fragments from a sorted-residual linear scan (G ≤ 8). Combined with
// [`PreparedWorkload`] (constraint/kind pre-decoded) this takes the FGD
// decision from 1.33 ms to the ~100 µs class at 1,213 nodes.
// ---------------------------------------------------------------------------

/// Hard cap on GPUs per node (the paper's cluster maxes at 8).
pub const MAX_GPUS: usize = 8;

/// A workload class pre-decoded for the hot loop.
#[derive(Clone, Copy, Debug)]
struct PClass {
    cpu: f64,
    mem: f64,
    /// Fractional demand (kind 1) or whole-GPU count (kind 2).
    d: f64,
    /// 0 = CPU-only, 1 = fractional, 2 = whole, 3 = MIG profile.
    kind: u8,
    /// MIG profile index (kind 3); 0 otherwise.
    profile: u8,
    /// GPU-model constraint as an index; -1 = unconstrained.
    constraint: i8,
    pop: f64,
}

/// The target workload `M`, pre-decoded.
#[derive(Clone, Debug)]
pub struct PreparedWorkload {
    classes: Vec<PClass>,
}

impl PreparedWorkload {
    pub fn new(w: &Workload) -> PreparedWorkload {
        let classes = w
            .classes()
            .iter()
            .map(|c| {
                let (kind, d, profile) = match c.gpu {
                    GpuDemand::Zero => (0, 0.0, 0u8),
                    GpuDemand::Frac(d) => (1, d, 0),
                    GpuDemand::Whole(k) => (2, k as f64, 0),
                    GpuDemand::Mig(p) => (3, p.units(), p.index() as u8),
                };
                PClass {
                    cpu: c.cpu,
                    mem: c.mem,
                    d,
                    kind,
                    profile,
                    constraint: c.gpu_model.map(|m| m.index() as i8).unwrap_or(-1),
                    pop: c.pop,
                }
            })
            .collect();
        PreparedWorkload { classes }
    }

    pub fn len(&self) -> usize {
        self.classes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.classes.is_empty()
    }
}

/// Per-state fragmentation evaluator: build once per (node ×
/// hypothetical placement), then evaluate all classes cheaply.
#[derive(Clone, Copy, Debug)]
pub struct FragEval {
    sumfree: f64,
    maxfree: f64,
    nfull: f64,
    /// Partial residuals (0 < r < 1), ascending.
    partials: [f64; MAX_GPUS],
    npart: usize,
    partials_total: f64,
    /// MIG state: set by [`FragEval::from_mig_masks`].
    is_mig: bool,
    /// Per-profile: some GPU has a legal free start (always false for
    /// profiles of a lattice other than the node's).
    mig_placeable: [bool; N_PROFILES],
    /// Per-profile: total fragment units (Σ_g frag_slices / lattice
    /// slices; 0 for foreign-lattice profiles, which are infeasible and
    /// therefore scored with `sumfree`).
    mig_frag_units: [f64; N_PROFILES],
}

impl FragEval {
    /// Build from the per-GPU free fractions of a (possibly
    /// hypothetical) node state.
    pub fn from_residuals(resid: &[f64]) -> FragEval {
        debug_assert!(resid.len() <= MAX_GPUS);
        let mut e = FragEval {
            sumfree: 0.0,
            maxfree: 0.0,
            nfull: 0.0,
            partials: [0.0; MAX_GPUS],
            npart: 0,
            partials_total: 0.0,
            is_mig: false,
            mig_placeable: [false; N_PROFILES],
            mig_frag_units: [0.0; N_PROFILES],
        };
        for &r in resid {
            e.sumfree += r;
            if r > e.maxfree {
                e.maxfree = r;
            }
            if r >= 1.0 - EPS {
                e.nfull += 1.0;
            } else if r > EPS {
                e.partials[e.npart] = r;
                e.npart += 1;
                e.partials_total += r;
            }
        }
        // Insertion sort: npart ≤ 8.
        for i in 1..e.npart {
            let x = e.partials[i];
            let mut j = i;
            while j > 0 && e.partials[j - 1] > x {
                e.partials[j] = e.partials[j - 1];
                j -= 1;
            }
            e.partials[j] = x;
        }
        e
    }

    /// Build from the per-GPU MIG occupancy masks of a (possibly
    /// hypothetical) MIG-node state on the given partition lattice.
    /// Residual aggregates are derived as free-slice fractions;
    /// per-profile placeability and fragment totals are precomputed so
    /// every class costs O(1) in [`FragEval::f_node`]. Profiles of the
    /// other lattice stay non-placeable (case 1: `sumfree`).
    pub fn from_mig_masks(masks: &[u8], lattice: MigLattice) -> FragEval {
        debug_assert!(masks.len() <= MAX_GPUS);
        let slices = lattice.slices();
        let mut resid = [0.0f64; MAX_GPUS];
        for (r, &m) in resid.iter_mut().zip(masks) {
            *r = (slices - m.count_ones() as u8) as f64 / slices as f64;
        }
        let mut e = FragEval::from_residuals(&resid[..masks.len()]);
        e.is_mig = true;
        for &p in lattice.profiles() {
            let pi = p.index();
            let mut frag = 0.0;
            let mut placeable = false;
            for &m in masks {
                if mig::first_fit_start(m, p).is_some() {
                    placeable = true;
                }
                frag += mig::frag_slices(m, p) as f64 / slices as f64;
            }
            e.mig_placeable[pi] = placeable;
            e.mig_frag_units[pi] = frag;
        }
        e
    }

    /// `Σ_g r_g · [EPS < r_g < d−EPS]` — fragments for a fractional
    /// class (ascending scan, early exit).
    #[inline]
    fn frag_frac(&self, d: f64) -> f64 {
        let mut acc = 0.0;
        for &r in &self.partials[..self.npart] {
            if r < d - EPS {
                acc += r;
            } else {
                break;
            }
        }
        acc
    }

    /// `F_n(M)` for a node state with these GPU residuals.
    /// `model_idx` is the node's GPU model index (−1 = CPU-only).
    pub fn f_node(&self, cpu_free: f64, mem_free: f64, model_idx: i8, pw: &PreparedWorkload) -> f64 {
        let mut total = 0.0;
        for c in &pw.classes {
            let fits_basics = c.cpu <= cpu_free + EPS && c.mem <= mem_free + EPS;
            let feas = fits_basics
                && match c.kind {
                    0 => true,
                    _ => {
                        model_idx >= 0
                            && (c.constraint < 0 || c.constraint == model_idx)
                            && match c.kind {
                                1 => !self.is_mig && self.maxfree >= c.d - EPS,
                                2 => !self.is_mig && self.nfull >= c.d - EPS,
                                _ => self.is_mig && self.mig_placeable[c.profile as usize],
                            }
                    }
                };
            let f = if !feas {
                self.sumfree
            } else {
                match c.kind {
                    0 => 0.0,
                    1 => self.frag_frac(c.d),
                    2 => self.partials_total,
                    _ => self.mig_frag_units[c.profile as usize],
                }
            };
            total += c.pop * f;
        }
        total
    }
}

/// Fast `F_n(M)` of a node's *current* state.
pub fn f_node_fast(node: &crate::cluster::node::Node, pw: &PreparedWorkload) -> f64 {
    let g = node.gpu_alloc.len();
    let model_idx = node.gpu_model.map(|m| m.index() as i8).unwrap_or(-1);
    if let Some(migs) = &node.mig {
        let lattice = node.mig_lattice().expect("MIG node has a lattice");
        let mut masks = [0u8; MAX_GPUS];
        for (m, mg) in masks.iter_mut().zip(migs) {
            *m = mg.mask;
        }
        return FragEval::from_mig_masks(&masks[..g], lattice).f_node(
            node.cpu_free(),
            node.mem_free(),
            model_idx,
            pw,
        );
    }
    let mut resid = [0.0f64; MAX_GPUS];
    for (j, r) in resid[..g].iter_mut().enumerate() {
        *r = 1.0 - node.gpu_alloc[j];
    }
    FragEval::from_residuals(&resid[..g]).f_node(node.cpu_free(), node.mem_free(), model_idx, pw)
}

/// Fast `ΔF_n(M)` of a hypothetical `(task, placement)` assignment,
/// given the cached `before = F_n(M)`.
pub fn frag_delta_fast(
    node: &crate::cluster::node::Node,
    task: &crate::tasks::Task,
    placement: &crate::cluster::node::Placement,
    pw: &PreparedWorkload,
    before: f64,
) -> f64 {
    use crate::cluster::node::Placement;
    let g = node.gpu_alloc.len();
    let model_idx = node.gpu_model.map(|m| m.index() as i8).unwrap_or(-1);
    if let Some(migs) = &node.mig {
        let lattice = node.mig_lattice().expect("MIG node has a lattice");
        let mut masks = [0u8; MAX_GPUS];
        for (m, mg) in masks.iter_mut().zip(migs) {
            *m = mg.mask;
        }
        if let (GpuDemand::Mig(p), Placement::MigSlice { gpu, start }) = (task.gpu, placement) {
            masks[*gpu] |= mig::window_mask(p, *start);
        }
        let after = FragEval::from_mig_masks(&masks[..g], lattice).f_node(
            node.cpu_free() - task.cpu,
            node.mem_free() - task.mem,
            model_idx,
            pw,
        );
        return after - before;
    }
    let mut resid = [0.0f64; MAX_GPUS];
    for (j, r) in resid[..g].iter_mut().enumerate() {
        *r = 1.0 - node.gpu_alloc[j];
    }
    match placement {
        Placement::CpuOnly => {}
        Placement::Shared { gpu } => {
            resid[*gpu] = (resid[*gpu] - task.gpu.units()).max(0.0);
        }
        Placement::Whole { gpus } => {
            for &j in gpus {
                resid[j] = 0.0;
            }
        }
        Placement::MigSlice { .. } => {
            debug_assert!(false, "MigSlice placement on a non-MIG node");
        }
    }
    let after = FragEval::from_residuals(&resid[..g]).f_node(
        node.cpu_free() - task.cpu,
        node.mem_free() - task.mem,
        model_idx,
        pw,
    );
    after - before
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::{Node, Placement};
    use crate::cluster::types::{CpuModel, GpuModel};
    use crate::tasks::Task;

    fn node(n_gpus: usize) -> Node {
        Node::new(0, CpuModel::XeonE5_2682V4, Some(GpuModel::G2), 96.0, 393_216.0, n_gpus)
    }

    fn class(cpu: f64, gpu: GpuDemand, pop: f64) -> TaskClass {
        TaskClass { cpu, mem: 0.0, gpu, gpu_model: None, pop }
    }

    #[test]
    fn case1_infeasible_class_fragments_everything() {
        let mut n = node(4);
        // Exhaust CPU so nothing can run.
        n.allocate(&Task::new(1, 96.0, 0.0, GpuDemand::Zero), &Placement::CpuOnly);
        let m = class(1.0, GpuDemand::Frac(0.5), 1.0);
        assert_eq!(f_node_class(&n, &m), 4.0); // all 4 free GPUs stranded
    }

    #[test]
    fn case2_fractional_counts_small_residuals() {
        let mut n = node(4);
        // GPU0 left with 0.3 free, GPU1 with 0.6 free, GPU2/3 fully free.
        n.allocate(&Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.7)), &Placement::Shared { gpu: 0 });
        n.allocate(&Task::new(2, 1.0, 0.0, GpuDemand::Frac(0.4)), &Placement::Shared { gpu: 1 });
        // Class wanting 0.5: GPU0's 0.3 is unusable; GPU1's 0.6 is fine.
        let m = class(1.0, GpuDemand::Frac(0.5), 1.0);
        assert!((f_node_class(&n, &m) - 0.3).abs() < 1e-9);
        // Class wanting 0.2: nothing is unusable.
        let m = class(1.0, GpuDemand::Frac(0.2), 1.0);
        assert_eq!(f_node_class(&n, &m), 0.0);
    }

    #[test]
    fn case2_whole_gpu_counts_all_partials() {
        let mut n = node(4);
        n.allocate(&Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.7)), &Placement::Shared { gpu: 0 });
        n.allocate(&Task::new(2, 1.0, 0.0, GpuDemand::Frac(0.4)), &Placement::Shared { gpu: 1 });
        // A 1-GPU class can't use the 0.3 and 0.6 residuals.
        let m = class(1.0, GpuDemand::Whole(1), 1.0);
        assert!((f_node_class(&n, &m) - 0.9).abs() < 1e-9);
    }

    #[test]
    fn cpu_only_class_never_fragments_when_feasible() {
        let mut n = node(4);
        n.allocate(&Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.7)), &Placement::Shared { gpu: 0 });
        let m = class(1.0, GpuDemand::Zero, 1.0);
        assert_eq!(f_node_class(&n, &m), 0.0);
    }

    #[test]
    fn constrained_class_on_wrong_model_is_case1() {
        let n = node(4); // G2 node
        let m = TaskClass {
            cpu: 1.0,
            mem: 0.0,
            gpu: GpuDemand::Whole(1),
            gpu_model: Some(GpuModel::T4),
            pop: 1.0,
        };
        assert_eq!(f_node_class(&n, &m), 4.0);
    }

    #[test]
    fn expected_frag_weights_by_popularity() {
        let mut n = node(2);
        n.allocate(&Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.8)), &Placement::Shared { gpu: 0 });
        // free: GPU0 0.2, GPU1 1.0
        let w = Workload::new(vec![
                class(1.0, GpuDemand::Frac(0.5), 0.5), // frag 0.2
                class(1.0, GpuDemand::Whole(1), 0.5),  // frag 0.2
        ]);
        assert!((f_node(&n, &w) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn fgd_intuition_packing_reduces_expected_frag() {
        // Placing a 0.5 task on an already-half GPU (perfect fill) should
        // increase fragmentation less than splitting a fresh GPU.
        let mut n = node(2);
        n.allocate(&Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.5)), &Placement::Shared { gpu: 0 });
        let w = Workload::new(vec![
                class(1.0, GpuDemand::Frac(0.5), 0.6),
                class(1.0, GpuDemand::Whole(1), 0.4),
        ]);
        let t = Task::new(2, 1.0, 0.0, GpuDemand::Frac(0.5));
        let before = f_node(&n, &w);
        let pack = {
            let h = n.hypothetical(&t, &Placement::Shared { gpu: 0 });
            f_node(&h, &w) - before
        };
        let split = {
            let h = n.hypothetical(&t, &Placement::Shared { gpu: 1 });
            f_node(&h, &w) - before
        };
        assert!(
            pack < split,
            "packing Δ ({pack}) should beat splitting Δ ({split})"
        );
    }

    /// Property test (hand-rolled, seeded): the fast evaluator must
    /// match the reference `f_node` on random node states, workloads
    /// and hypothetical placements.
    #[test]
    fn fast_path_matches_reference() {
        use crate::cluster::types::GpuModel;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xFA57);
        let fracs = [0.1, 0.25, 0.3, 0.5, 0.6, 0.75, 0.8, 0.9];
        for trial in 0..300 {
            // Random node state.
            let g = rng.range(1, MAX_GPUS + 1);
            let model = *rng.choice(&GpuModel::ALL);
            let mut n = Node::new(0, crate::cluster::types::CpuModel::XeonE5_2682V4,
                Some(model), 96.0, 262_144.0, g);
            n.cpu_alloc = rng.range_f64(0.0, 96.0);
            n.mem_alloc = rng.range_f64(0.0, 200_000.0);
            for j in 0..g {
                n.gpu_alloc[j] = *rng.choice(&[0.0, 0.25, 0.5, 0.75, 1.0]);
            }
            // Random workload.
            let mut classes = Vec::new();
            for _ in 0..rng.range(1, 12) {
                let gpu = match rng.below(3) {
                    0 => GpuDemand::Zero,
                    1 => GpuDemand::Frac(*rng.choice(&fracs)),
                    _ => GpuDemand::Whole(*rng.choice(&[1u32, 2, 4, 8])),
                };
                classes.push(TaskClass {
                    cpu: rng.range_f64(0.0, 64.0),
                    mem: rng.range_f64(0.0, 300_000.0),
                    gpu,
                    gpu_model: if rng.bernoulli(0.2) {
                        Some(*rng.choice(&GpuModel::ALL))
                    } else {
                        None
                    },
                    pop: rng.range_f64(0.01, 1.0),
                });
            }
            let w = Workload::new(classes);
            let pw = PreparedWorkload::new(&w);
            // Current state.
            let slow = f_node(&n, &w);
            let fast = f_node_fast(&n, &pw);
            assert!((slow - fast).abs() < 1e-9, "trial {trial}: {slow} vs {fast}");
            // Hypothetical placements.
            let task = Task::new(
                trial,
                rng.range_f64(0.0, 32.0),
                rng.range_f64(0.0, 50_000.0),
                GpuDemand::Frac(*rng.choice(&fracs)),
            );
            for p in n.candidate_placements(&task) {
                let slow_d = {
                    let h = n.hypothetical(&task, &p);
                    f_node(&h, &w) - slow
                };
                let fast_d = frag_delta_fast(&n, &task, &p, &pw, fast);
                assert!(
                    (slow_d - fast_d).abs() < 1e-9,
                    "trial {trial} {p:?}: {slow_d} vs {fast_d}"
                );
            }
        }
    }

    #[test]
    fn fast_path_whole_and_cpu_placements() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xFA58);
        for trial in 0..100 {
            let mut n = node(4);
            for j in 0..4 {
                n.gpu_alloc[j] = *rng.choice(&[0.0, 0.5, 1.0]);
            }
            n.cpu_alloc = rng.range_f64(0.0, 90.0);
            let w = Workload::new(vec![
                class(8.0, GpuDemand::Frac(0.5), 0.4),
                class(90.0, GpuDemand::Whole(2), 0.4),
                class(4.0, GpuDemand::Zero, 0.2),
            ]);
            let pw = PreparedWorkload::new(&w);
            let before_slow = f_node(&n, &w);
            let before_fast = f_node_fast(&n, &pw);
            assert!((before_slow - before_fast).abs() < 1e-9);
            let k = n.gpus_fully_free().min(2) as u32;
            let tasks = [
                Task::new(trial, 4.0, 0.0, GpuDemand::Zero),
                Task::new(trial, 4.0, 0.0, GpuDemand::Whole(k.max(1))),
            ];
            for t in &tasks {
                for p in n.candidate_placements(t) {
                    let slow_d = {
                        let h = n.hypothetical(t, &p);
                        f_node(&h, &w) - before_slow
                    };
                    let fast_d = frag_delta_fast(&n, t, &p, &pw, before_fast);
                    assert!((slow_d - fast_d).abs() < 1e-9, "trial {trial} {t:?} {p:?}");
                }
            }
        }
    }

    /// MIG property test: the mask-based fast evaluator must match the
    /// reference `f_node` on random partition states, mixed workloads
    /// (MIG + frac + whole classes) and hypothetical slice placements.
    #[test]
    fn mig_fast_path_matches_reference() {
        use crate::cluster::mig::MigProfile;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x316);
        for trial in 0..200 {
            let g = rng.range(1, 5);
            let mut n = Node::new(
                0,
                CpuModel::XeonE5_2682V4,
                Some(GpuModel::G3),
                128.0,
                786_432.0,
                g,
            );
            n.enable_mig();
            n.cpu_alloc = rng.range_f64(0.0, 100.0);
            // Random legal partition per GPU.
            for j in 0..g {
                for _ in 0..rng.below(5) {
                    let p = *rng.choice(&MigProfile::ALL);
                    let migs = n.mig.as_mut().unwrap();
                    if let Some(s) = migs[j].can_place(p) {
                        migs[j].place(p, s);
                        n.gpu_alloc[j] = migs[j].alloc_fraction();
                    }
                }
            }
            // Random mixed workload.
            let mut classes = Vec::new();
            for _ in 0..rng.range(1, 10) {
                let gpu = match rng.below(4) {
                    0 => GpuDemand::Zero,
                    1 => GpuDemand::Frac(*rng.choice(&[0.25, 0.5, 0.75])),
                    2 => GpuDemand::Whole(*rng.choice(&[1u32, 2])),
                    _ => GpuDemand::Mig(*rng.choice(&MigProfile::ALL)),
                };
                classes.push(TaskClass {
                    cpu: rng.range_f64(0.0, 64.0),
                    mem: rng.range_f64(0.0, 400_000.0),
                    gpu,
                    gpu_model: if rng.bernoulli(0.2) {
                        Some(*rng.choice(&[GpuModel::G3, GpuModel::T4]))
                    } else {
                        None
                    },
                    pop: rng.range_f64(0.01, 1.0),
                });
            }
            let w = Workload::new(classes);
            let pw = PreparedWorkload::new(&w);
            let slow = f_node(&n, &w);
            let fast = f_node_fast(&n, &pw);
            assert!((slow - fast).abs() < 1e-9, "trial {trial}: {slow} vs {fast}");
            // Hypothetical slice placements.
            let task = Task::new(
                trial,
                rng.range_f64(0.0, 16.0),
                rng.range_f64(0.0, 50_000.0),
                GpuDemand::Mig(*rng.choice(&MigProfile::ALL)),
            );
            for p in n.candidate_placements(&task) {
                let slow_d = {
                    let h = n.hypothetical(&task, &p);
                    f_node(&h, &w) - slow
                };
                let fast_d = frag_delta_fast(&n, &task, &p, &pw, fast);
                assert!(
                    (slow_d - fast_d).abs() < 1e-9,
                    "trial {trial} {p:?}: {slow_d} vs {fast_d}"
                );
            }
        }
    }

    #[test]
    fn mig_class_on_plain_node_is_case1() {
        use crate::cluster::mig::MigProfile;
        let n = node(4); // plain G2 node, 4 GPUs fully free
        let m = class(1.0, GpuDemand::Mig(MigProfile::P2g), 1.0);
        assert_eq!(f_node_class(&n, &m), 4.0);
    }

    #[test]
    fn frac_class_on_mig_node_is_case1() {
        let mut n = Node::new(0, CpuModel::XeonE5_2682V4, Some(GpuModel::G3), 128.0, 786_432.0, 2);
        n.enable_mig();
        let m = class(1.0, GpuDemand::Frac(0.5), 1.0);
        assert_eq!(f_node_class(&n, &m), 2.0); // both free GPUs stranded
    }

    #[test]
    fn f_datacenter_sums_nodes() {
        let mut dc = crate::cluster::ClusterSpec::tiny(2, 2, 0).build();
        let t = Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.9));
        let p = dc.nodes[0].candidate_placements(&t)[0].clone();
        dc.allocate(&t, 0, &p);
        let w = Workload::new(vec![class(1.0, GpuDemand::Frac(0.5), 1.0)]);
        let total = f_datacenter(&dc, &w);
        let by_hand: f64 = dc.nodes.iter().map(|n| f_node(n, &w)).sum();
        assert_eq!(total, by_hand);
        assert!((total - 0.1).abs() < 1e-9); // only the 0.1 residual fragments
    }
}
