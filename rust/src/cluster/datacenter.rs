//! Aggregate datacenter state: the node set plus cached cluster-level
//! totals maintained incrementally across allocations.

use crate::cluster::node::{Node, Placement};
use crate::tasks::Task;

/// The simulated datacenter.
#[derive(Clone, Debug)]
pub struct Datacenter {
    pub nodes: Vec<Node>,
    /// Cached: total GPUs installed.
    total_gpus: usize,
    /// Cached: total vCPUs installed.
    total_vcpus: f64,
    /// Cached: sum of allocated GPU units across nodes (for GRAR).
    gpu_alloc_units: f64,
    /// Cached: allocated vCPUs across nodes.
    cpu_alloc_units: f64,
    /// Tasks currently resident.
    pub n_tasks: u64,
}

impl Datacenter {
    /// Wrap a node list (normally via [`crate::cluster::ClusterSpec::build`]).
    pub fn new(nodes: Vec<Node>) -> Datacenter {
        let total_gpus = nodes.iter().map(|n| n.gpu_alloc.len()).sum();
        let total_vcpus = nodes.iter().map(|n| n.vcpus).sum();
        Datacenter {
            nodes,
            total_gpus,
            total_vcpus,
            gpu_alloc_units: 0.0,
            cpu_alloc_units: 0.0,
            n_tasks: 0,
        }
    }

    /// Total installed GPUs (the cluster "GPU capacity" the paper's
    /// x-axes are normalized by).
    pub fn total_gpus(&self) -> usize {
        self.total_gpus
    }

    /// GPU capacity in resource units (1.0 per GPU).
    pub fn gpu_capacity(&self) -> f64 {
        self.total_gpus as f64
    }

    /// Total installed vCPUs.
    pub fn total_vcpus(&self) -> f64 {
        self.total_vcpus
    }

    /// Sum of GPU units currently allocated (numerator of GRAR).
    pub fn gpu_allocated_units(&self) -> f64 {
        self.gpu_alloc_units
    }

    /// Sum of vCPUs currently allocated.
    pub fn cpu_allocated_units(&self) -> f64 {
        self.cpu_alloc_units
    }

    /// Fraction of GPU capacity allocated.
    pub fn gpu_utilization(&self) -> f64 {
        if self.total_gpus == 0 {
            0.0
        } else {
            self.gpu_alloc_units / self.total_gpus as f64
        }
    }

    /// Commit `task` to `node_id` at `placement`, maintaining caches.
    pub fn allocate(&mut self, task: &Task, node_id: usize, placement: &Placement) {
        self.nodes[node_id].allocate(task, placement);
        self.gpu_alloc_units += task.gpu.units();
        self.cpu_alloc_units += task.cpu;
        self.n_tasks += 1;
    }

    /// Release `task` from `node_id`.
    pub fn deallocate(&mut self, task: &Task, node_id: usize, placement: &Placement) {
        self.nodes[node_id].deallocate(task, placement);
        self.gpu_alloc_units = (self.gpu_alloc_units - task.gpu.units()).max(0.0);
        self.cpu_alloc_units = (self.cpu_alloc_units - task.cpu).max(0.0);
        self.n_tasks = self.n_tasks.saturating_sub(1);
    }

    /// Number of active (non-empty) nodes.
    pub fn active_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_active()).count()
    }

    /// Number of GPUs with any allocation (drawing `p_max` in Eq. 2).
    pub fn active_gpus(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| n.gpu_alloc.iter().filter(|&&a| a > 0.0).count())
            .sum()
    }

    /// Recompute the allocation caches from scratch (integrity check —
    /// tests call this to verify incremental maintenance).
    pub fn recompute_caches(&self) -> (f64, f64) {
        let gpu: f64 = self.nodes.iter().map(|n| n.gpu_alloc.iter().sum::<f64>()).sum();
        let cpu: f64 = self.nodes.iter().map(|n| n.cpu_alloc).sum();
        (gpu, cpu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::inventory::ClusterSpec;
    use crate::tasks::GpuDemand;

    #[test]
    fn caches_track_allocations() {
        let mut dc = ClusterSpec::tiny(2, 4, 1).build();
        let t1 = Task::new(1, 8.0, 1024.0, GpuDemand::Whole(2));
        let p1 = dc.nodes[0].candidate_placements(&t1).pop().unwrap();
        dc.allocate(&t1, 0, &p1);
        let t2 = Task::new(2, 4.0, 512.0, GpuDemand::Frac(0.5));
        let p2 = dc.nodes[1].candidate_placements(&t2)[0].clone();
        dc.allocate(&t2, 1, &p2);

        assert!((dc.gpu_allocated_units() - 2.5).abs() < 1e-9);
        assert!((dc.cpu_allocated_units() - 12.0).abs() < 1e-9);
        assert_eq!(dc.n_tasks, 2);
        assert_eq!(dc.active_nodes(), 2);
        assert_eq!(dc.active_gpus(), 3);

        // Incremental caches must equal a from-scratch recompute...
        let (gpu, cpu) = dc.recompute_caches();
        assert!((gpu - dc.gpu_allocated_units()).abs() < 1e-9);
        assert!((cpu - dc.cpu_allocated_units()).abs() < 1e-9);

        // ...including after deallocation.
        dc.deallocate(&t1, 0, &p1);
        let (gpu, cpu) = dc.recompute_caches();
        assert!((gpu - dc.gpu_allocated_units()).abs() < 1e-9);
        assert!((cpu - dc.cpu_allocated_units()).abs() < 1e-9);
        assert_eq!(dc.n_tasks, 1);
    }

    #[test]
    fn utilization_ratio() {
        let mut dc = ClusterSpec::tiny(1, 4, 0).build();
        assert_eq!(dc.gpu_utilization(), 0.0);
        let t = Task::new(1, 1.0, 0.0, GpuDemand::Whole(2));
        let p = dc.nodes[0].candidate_placements(&t).pop().unwrap();
        dc.allocate(&t, 0, &p);
        assert!((dc.gpu_utilization() - 0.5).abs() < 1e-9);
    }
}
