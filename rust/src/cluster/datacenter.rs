//! Aggregate datacenter state: the node set plus cached cluster-level
//! totals maintained incrementally across allocations, and the static
//! indexes (nodes per GPU model / MIG lattice / label) the filter
//! plugins' PreFilter pass and the scheduler's sampled candidate
//! shortlist read — both candidate *counts* and candidate *id lists*.
//!
//! Every `Datacenter` carries a process-unique **revision stamp**
//! (same identity-stamp discipline as [`crate::tasks::Workload`]):
//! assigned at construction, re-assigned by
//! [`Datacenter::note_fleet_changed`]. Scheduler-side caches keyed on
//! structural fleet state (cluster caps, score caches) key on the
//! revision, so a fleet swap that happens to preserve the node count
//! can never serve stale values. Code that mutates the `pub nodes`
//! field *structurally* (shape, model, lattice or label changes —
//! not allocations) must call `note_fleet_changed`, which also
//! rebuilds the static indexes.

use std::collections::HashMap;

use crate::cluster::mig::MigLattice;
use crate::cluster::node::{class_count_add, class_count_remove, Node, Placement, ResourceView};
use crate::cluster::types::GpuModel;
use crate::tasks::Task;

/// Interconnect bandwidth tiers of the cluster (GB/s per link class):
/// intra-node NVLink, intra-zone node-to-node fabric (InfiniBand /
/// RoCE), and the slower inter-zone spine. Queried via
/// [`Datacenter::bandwidth_between`] by the gang scheduler's `topo`
/// score plugin (`docs/gang.md`); defaults approximate an NVLink-4 +
/// HDR-InfiniBand pod design (SNIPPETS.md snippet 3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Topology {
    /// GPU-to-GPU bandwidth inside one node's NVLink domain.
    pub nvlink_gbps: f64,
    /// Node-to-node bandwidth inside one zone.
    pub fabric_gbps: f64,
    /// Node-to-node bandwidth across zones.
    pub interzone_gbps: f64,
}

impl Default for Topology {
    fn default() -> Self {
        Topology { nvlink_gbps: 600.0, fabric_gbps: 100.0, interzone_gbps: 25.0 }
    }
}

/// The simulated datacenter.
#[derive(Clone, Debug)]
pub struct Datacenter {
    pub nodes: Vec<Node>,
    /// Cached: total GPUs installed.
    total_gpus: usize,
    /// Cached: total vCPUs installed.
    total_vcpus: f64,
    /// Cached: total memory installed (MiB).
    total_mem: f64,
    /// Cached: sum of allocated GPU units across nodes (for GRAR).
    gpu_alloc_units: f64,
    /// Cached: allocated vCPUs across nodes.
    cpu_alloc_units: f64,
    /// Cached: allocated memory across nodes (MiB).
    mem_alloc_units: f64,
    /// Static index: node count per GPU model (candidate counts for the
    /// `gpumodel` PreFilter).
    nodes_per_model: [usize; GpuModel::ALL.len()],
    /// Static index: node count per MIG lattice (A100 / A30).
    nodes_per_lattice: [usize; 2],
    /// Static index: node count per label key, then value (nested so
    /// lookups borrow `&str`s instead of allocating a tuple key — this
    /// sits on the per-task PreFilter path).
    label_counts: HashMap<String, HashMap<String, usize>>,
    /// Static index: node ids per GPU model, ascending (the sampled
    /// candidate shortlist of model-pinned tasks).
    model_nodes: Vec<Vec<u32>>,
    /// Static index: node ids per MIG lattice, ascending.
    lattice_nodes: [Vec<u32>; 2],
    /// Static index: node ids per `(label key, value)`, ascending.
    label_nodes: HashMap<String, HashMap<String, Vec<u32>>>,
    /// Process-unique identity stamp; see the module docs.
    revision: u64,
    /// Cluster-wide resident task count per constraint class key (the
    /// `affinity` PreFilter's existence check; same discipline as
    /// [`Node::class_counts`] via the shared helpers).
    class_counts: HashMap<String, u32>,
    /// Tasks currently resident.
    pub n_tasks: u64,
    /// Interconnect bandwidth tiers (structural; set by
    /// [`crate::cluster::ClusterSpec::build`], defaults to
    /// [`Topology::default`]).
    pub topology: Topology,
    /// Static index: zone id per node, derived from the `zone` label
    /// (distinct values numbered 1.. in first-seen order; unlabeled
    /// nodes share zone 0). Rebuilt with the other static indexes.
    zone_of: Vec<u32>,
}

/// Next process-unique fleet revision (same discipline as
/// `next_workload_revision`: starts at 1 so 0 is free as a "never
/// stamped" sentinel in caches, relaxed ordering — only uniqueness
/// matters, not cross-thread ordering).
fn next_fleet_revision() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT_REVISION: AtomicU64 = AtomicU64::new(1);
    NEXT_REVISION.fetch_add(1, Ordering::Relaxed)
}

impl Datacenter {
    /// Wrap a node list (normally via [`crate::cluster::ClusterSpec::build`]).
    pub fn new(nodes: Vec<Node>) -> Datacenter {
        let mut dc = Datacenter {
            nodes,
            total_gpus: 0,
            total_vcpus: 0.0,
            total_mem: 0.0,
            gpu_alloc_units: 0.0,
            cpu_alloc_units: 0.0,
            mem_alloc_units: 0.0,
            nodes_per_model: [0; GpuModel::ALL.len()],
            nodes_per_lattice: [0; 2],
            label_counts: HashMap::new(),
            model_nodes: vec![Vec::new(); GpuModel::ALL.len()],
            lattice_nodes: [Vec::new(), Vec::new()],
            label_nodes: HashMap::new(),
            revision: next_fleet_revision(),
            class_counts: HashMap::new(),
            n_tasks: 0,
            topology: Topology::default(),
            zone_of: Vec::new(),
        };
        dc.rebuild_static_indexes();
        dc
    }

    /// The fleet revision stamp: process-unique, re-assigned on every
    /// structural change ([`Self::note_fleet_changed`]). Cache keys
    /// derived from node shapes / models / labels key on this; clones
    /// share their source's stamp (identical content).
    pub fn revision(&self) -> u64 {
        self.revision
    }

    /// Declare a structural fleet change (nodes added/removed/resized,
    /// models or labels edited in place): re-stamps [`Self::revision`]
    /// and rebuilds every static index and installed-capacity total
    /// from the node list. Allocations don't need this — `allocate` /
    /// `deallocate` maintain their caches incrementally.
    pub fn note_fleet_changed(&mut self) {
        self.revision = next_fleet_revision();
        self.rebuild_static_indexes();
    }

    /// Recompute installed totals and the static candidate indexes
    /// (counts *and* id lists) from `self.nodes`.
    fn rebuild_static_indexes(&mut self) {
        self.total_gpus = self.nodes.iter().map(|n| n.gpu_alloc.len()).sum();
        self.total_vcpus = self.nodes.iter().map(|n| n.vcpus).sum();
        self.total_mem = self.nodes.iter().map(|n| n.mem).sum();
        self.nodes_per_model = [0; GpuModel::ALL.len()];
        self.nodes_per_lattice = [0; 2];
        self.label_counts.clear();
        self.model_nodes = vec![Vec::new(); GpuModel::ALL.len()];
        self.lattice_nodes = [Vec::new(), Vec::new()];
        self.label_nodes.clear();
        self.zone_of = vec![0; self.nodes.len()];
        let mut zone_ids: HashMap<&str, u32> = HashMap::new();
        for n in &self.nodes {
            if let Some((_, v)) = n.labels.iter().find(|(k, _)| k == "zone") {
                let next = zone_ids.len() as u32 + 1;
                self.zone_of[n.id] = *zone_ids.entry(v.as_str()).or_insert(next);
            }
        }
        for n in &self.nodes {
            let id = n.id as u32;
            if let Some(m) = n.gpu_model {
                self.nodes_per_model[m.index()] += 1;
                self.model_nodes[m.index()].push(id);
            }
            if let Some(lat) = n.mig_lattice() {
                self.nodes_per_lattice[lat.index()] += 1;
                self.lattice_nodes[lat.index()].push(id);
            }
            for (k, v) in &n.labels {
                *self
                    .label_counts
                    .entry(k.clone())
                    .or_default()
                    .entry(v.clone())
                    .or_insert(0) += 1;
                self.label_nodes
                    .entry(k.clone())
                    .or_default()
                    .entry(v.clone())
                    .or_default()
                    .push(id);
            }
        }
    }

    /// Total installed GPUs (the cluster "GPU capacity" the paper's
    /// x-axes are normalized by).
    pub fn total_gpus(&self) -> usize {
        self.total_gpus
    }

    /// GPU capacity in resource units (1.0 per GPU).
    pub fn gpu_capacity(&self) -> f64 {
        self.total_gpus as f64
    }

    /// Total installed vCPUs.
    pub fn total_vcpus(&self) -> f64 {
        self.total_vcpus
    }

    /// Sum of GPU units currently allocated (numerator of GRAR).
    pub fn gpu_allocated_units(&self) -> f64 {
        self.gpu_alloc_units
    }

    /// Sum of vCPUs currently allocated.
    pub fn cpu_allocated_units(&self) -> f64 {
        self.cpu_alloc_units
    }

    /// Total installed memory (MiB).
    pub fn total_mem(&self) -> f64 {
        self.total_mem
    }

    /// Sum of memory currently allocated (MiB).
    pub fn mem_allocated_units(&self) -> f64 {
        self.mem_alloc_units
    }

    /// Aggregate free vCPUs (an upper bound on any single node's free
    /// CPU — the `resources` PreFilter's Cond. 1 check).
    pub fn cpu_free_total(&self) -> f64 {
        self.total_vcpus - self.cpu_alloc_units
    }

    /// Aggregate free memory in MiB (upper bound per node).
    pub fn mem_free_total(&self) -> f64 {
        self.total_mem - self.mem_alloc_units
    }

    /// Aggregate free GPU units (upper bound per node).
    pub fn gpu_free_units(&self) -> f64 {
        self.total_gpus as f64 - self.gpu_alloc_units
    }

    /// Number of nodes carrying GPUs of `model` (static index).
    pub fn nodes_with_model(&self, model: GpuModel) -> usize {
        self.nodes_per_model[model.index()]
    }

    /// Number of MIG nodes of the given partition lattice (static index).
    pub fn nodes_with_lattice(&self, lattice: MigLattice) -> usize {
        self.nodes_per_lattice[lattice.index()]
    }

    /// Number of nodes carrying the `(key, value)` label (static index;
    /// allocation-free lookup).
    pub fn nodes_with_label(&self, key: &str, value: &str) -> usize {
        self.label_counts
            .get(key)
            .and_then(|values| values.get(value))
            .copied()
            .unwrap_or(0)
    }

    /// Node ids (ascending) carrying GPUs of `model` — the sampled
    /// candidate shortlist for model-pinned tasks.
    pub fn nodes_of_model(&self, model: GpuModel) -> &[u32] {
        &self.model_nodes[model.index()]
    }

    /// Node ids (ascending) of the given MIG partition lattice.
    pub fn nodes_of_lattice(&self, lattice: MigLattice) -> &[u32] {
        &self.lattice_nodes[lattice.index()]
    }

    /// Node ids (ascending) carrying the `(key, value)` label.
    pub fn nodes_of_label(&self, key: &str, value: &str) -> &[u32] {
        self.label_nodes
            .get(key)
            .and_then(|values| values.get(value))
            .map(Vec::as_slice)
            .unwrap_or(&[])
    }

    /// The zone id of a node (static index from the `zone` label; 0 for
    /// unlabeled nodes and out-of-range ids).
    pub fn zone_of(&self, node_id: usize) -> u32 {
        self.zone_of.get(node_id).copied().unwrap_or(0)
    }

    /// Effective GPU-to-GPU bandwidth between two nodes (GB/s): the
    /// NVLink tier within one node, the fabric tier between nodes of
    /// one zone, the inter-zone tier otherwise (see [`Topology`]).
    pub fn bandwidth_between(&self, a: usize, b: usize) -> f64 {
        if a == b {
            self.topology.nvlink_gbps
        } else if self.zone_of(a) == self.zone_of(b) {
            self.topology.fabric_gbps
        } else {
            self.topology.interzone_gbps
        }
    }

    /// Cluster-wide resident task count of a constraint class.
    pub fn class_resident(&self, key: &str) -> u32 {
        self.class_counts.get(key).copied().unwrap_or(0)
    }

    /// Fraction of GPU capacity allocated.
    pub fn gpu_utilization(&self) -> f64 {
        if self.total_gpus == 0 {
            0.0
        } else {
            self.gpu_alloc_units / self.total_gpus as f64
        }
    }

    /// Commit `task` to `node_id` at `placement`, maintaining caches.
    pub fn allocate(&mut self, task: &Task, node_id: usize, placement: &Placement) {
        self.nodes[node_id].allocate(task, placement);
        self.gpu_alloc_units += task.gpu.units();
        self.cpu_alloc_units += task.cpu;
        self.mem_alloc_units += task.mem;
        self.n_tasks += 1;
        if let Some(key) = task.constraints.as_deref().and_then(|c| c.class_key.as_ref()) {
            class_count_add(&mut self.class_counts, key);
        }
    }

    /// Release `task` from `node_id`.
    pub fn deallocate(&mut self, task: &Task, node_id: usize, placement: &Placement) {
        self.nodes[node_id].deallocate(task, placement);
        self.gpu_alloc_units = (self.gpu_alloc_units - task.gpu.units()).max(0.0);
        self.cpu_alloc_units = (self.cpu_alloc_units - task.cpu).max(0.0);
        self.mem_alloc_units = (self.mem_alloc_units - task.mem).max(0.0);
        self.n_tasks = self.n_tasks.saturating_sub(1);
        if let Some(key) = task.constraints.as_deref().and_then(|c| c.class_key.as_ref()) {
            class_count_remove(&mut self.class_counts, key);
        }
    }

    /// Number of active (non-empty) nodes.
    pub fn active_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_active()).count()
    }

    /// Nodes currently in the
    /// [`crate::cluster::node::PowerState::Asleep`] power state (the
    /// EOPC series' nodes-asleep column; zero without a DRS hook).
    pub fn asleep_nodes(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.power_state == crate::cluster::node::PowerState::Asleep)
            .count()
    }

    /// Number of GPUs with any allocation (drawing `p_max` in Eq. 2).
    pub fn active_gpus(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| n.gpu_alloc.iter().filter(|&&a| a > 0.0).count())
            .sum()
    }

    /// Recompute the allocation caches from scratch (integrity check —
    /// tests call this to verify incremental maintenance).
    pub fn recompute_caches(&self) -> (f64, f64) {
        let gpu: f64 = self.nodes.iter().map(|n| n.gpu_alloc.iter().sum::<f64>()).sum();
        let cpu: f64 = self.nodes.iter().map(|n| n.cpu_alloc).sum();
        (gpu, cpu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::inventory::ClusterSpec;
    use crate::tasks::GpuDemand;

    #[test]
    fn caches_track_allocations() {
        let mut dc = ClusterSpec::tiny(2, 4, 1).build();
        let t1 = Task::new(1, 8.0, 1024.0, GpuDemand::Whole(2));
        let p1 = dc.nodes[0].candidate_placements(&t1).pop().unwrap();
        dc.allocate(&t1, 0, &p1);
        let t2 = Task::new(2, 4.0, 512.0, GpuDemand::Frac(0.5));
        let p2 = dc.nodes[1].candidate_placements(&t2)[0].clone();
        dc.allocate(&t2, 1, &p2);

        assert!((dc.gpu_allocated_units() - 2.5).abs() < 1e-9);
        assert!((dc.cpu_allocated_units() - 12.0).abs() < 1e-9);
        assert_eq!(dc.n_tasks, 2);
        assert_eq!(dc.active_nodes(), 2);
        assert_eq!(dc.active_gpus(), 3);

        // Incremental caches must equal a from-scratch recompute...
        let (gpu, cpu) = dc.recompute_caches();
        assert!((gpu - dc.gpu_allocated_units()).abs() < 1e-9);
        assert!((cpu - dc.cpu_allocated_units()).abs() < 1e-9);

        // ...including after deallocation.
        dc.deallocate(&t1, 0, &p1);
        let (gpu, cpu) = dc.recompute_caches();
        assert!((gpu - dc.gpu_allocated_units()).abs() < 1e-9);
        assert!((cpu - dc.cpu_allocated_units()).abs() < 1e-9);
        assert_eq!(dc.n_tasks, 1);
    }

    #[test]
    fn prefilter_indexes_track_state() {
        use crate::tasks::TaskConstraints;
        let mut dc = ClusterSpec::tiny(2, 4, 1).build();
        // Static indexes: tiny() builds G2 GPU nodes + CPU-only nodes.
        assert_eq!(dc.nodes_with_model(GpuModel::G2), 2);
        assert_eq!(dc.nodes_with_model(GpuModel::T4), 0);
        assert_eq!(dc.nodes_with_lattice(crate::cluster::mig::MigLattice::A100), 0);
        assert_eq!(dc.nodes_with_label("zone", "z0"), 0);
        // Aggregate free capacity tracks allocations (incl. memory).
        let free_cpu0 = dc.cpu_free_total();
        let free_mem0 = dc.mem_free_total();
        let c = TaskConstraints {
            class_key: Some("tenant-a".to_string()),
            ..Default::default()
        };
        let t = Task::new(1, 4.0, 1024.0, GpuDemand::Frac(0.5)).with_constraints(c);
        dc.allocate(&t, 0, &Placement::Shared { gpu: 0 });
        assert!((dc.cpu_free_total() - (free_cpu0 - 4.0)).abs() < 1e-9);
        assert!((dc.mem_free_total() - (free_mem0 - 1024.0)).abs() < 1e-9);
        assert_eq!(dc.class_resident("tenant-a"), 1);
        assert_eq!(dc.class_resident("tenant-b"), 0);
        dc.deallocate(&t, 0, &Placement::Shared { gpu: 0 });
        assert_eq!(dc.class_resident("tenant-a"), 0);
        assert!((dc.mem_free_total() - free_mem0).abs() < 1e-9);
    }

    #[test]
    fn revision_restamps_and_indexes_rebuild_on_fleet_change() {
        let mut dc = ClusterSpec::tiny(2, 4, 1).build();
        let dc2 = ClusterSpec::tiny(2, 4, 1).build();
        // Process-unique stamps: two independently built fleets differ.
        assert_ne!(dc.revision(), dc2.revision());
        // Clones share content, so they share the stamp.
        assert_eq!(dc.clone().revision(), dc.revision());

        assert_eq!(dc.nodes_of_model(GpuModel::G2), &[0, 1]);
        assert!(dc.nodes_of_label("zone", "z1").is_empty());

        // Structural in-place mutation + note_fleet_changed: revision
        // moves and every static index reflects the new fleet shape.
        let r0 = dc.revision();
        dc.nodes[1].labels.push(("zone".to_string(), "z1".to_string()));
        dc.nodes[1].gpu_model = Some(GpuModel::T4);
        dc.note_fleet_changed();
        assert_ne!(dc.revision(), r0);
        assert_eq!(dc.nodes_with_label("zone", "z1"), 1);
        assert_eq!(dc.nodes_of_label("zone", "z1"), &[1]);
        assert_eq!(dc.nodes_with_model(GpuModel::G2), 1);
        assert_eq!(dc.nodes_of_model(GpuModel::G2), &[0]);
        assert_eq!(dc.nodes_of_model(GpuModel::T4), &[1]);
    }

    #[test]
    fn bandwidth_tiers_follow_zone_structure() {
        let dc = ClusterSpec::tiny(4, 2, 0).with_zones(2).build();
        let topo = dc.topology;
        // Same node → NVLink; same zone (0 and 2 are both z0) → fabric;
        // different zones (0 and 1) → inter-zone spine.
        assert_eq!(dc.bandwidth_between(0, 0), topo.nvlink_gbps);
        assert_eq!(dc.bandwidth_between(0, 2), topo.fabric_gbps);
        assert_eq!(dc.bandwidth_between(0, 1), topo.interzone_gbps);
        assert_eq!(dc.bandwidth_between(1, 0), topo.interzone_gbps);
        // Unzoned fleets share zone 0 everywhere: fabric between nodes.
        let flat = ClusterSpec::tiny(2, 2, 0).build();
        assert_eq!(flat.zone_of(0), 0);
        assert_eq!(flat.bandwidth_between(0, 1), flat.topology.fabric_gbps);
        // Zone ids rebuild with the other static indexes.
        let mut dc = dc;
        assert_ne!(dc.zone_of(0), dc.zone_of(1));
        dc.nodes[1].labels.retain(|(k, _)| k != "zone");
        dc.nodes[1].labels.push(("zone".to_string(), "z0".to_string()));
        dc.note_fleet_changed();
        assert_eq!(dc.zone_of(0), dc.zone_of(1));
    }

    #[test]
    fn utilization_ratio() {
        let mut dc = ClusterSpec::tiny(1, 4, 0).build();
        assert_eq!(dc.gpu_utilization(), 0.0);
        let t = Task::new(1, 1.0, 0.0, GpuDemand::Whole(2));
        let p = dc.nodes[0].candidate_placements(&t).pop().unwrap();
        dc.allocate(&t, 0, &p);
        assert!((dc.gpu_utilization() - 0.5).abs() < 1e-9);
    }
}
