//! Hardware catalog: GPU and CPU models with the power profiles of the
//! paper's Table II (GPUs) and §V-B (the Intel Xeon E5-2682 v4 CPU).

use std::fmt;

/// GPU models present in the 2023 Alibaba GPU trace (paper Table II),
/// plus the A30 used by the heterogeneous-MIG-fleet extension.
///
/// `G2` and `G3` are the two classified Alibaba models; following the
/// paper we map G2 → A10 and G3 → A100 power profiles. `A30` (idle
/// ~30 W, 165 W TDP, 4-slice MIG lattice) is not part of the paper's
/// inventory (`paper_count` 0); mixed-fleet MIG clusters add it via
/// [`crate::cluster::ClusterSpec::mig_het_cluster`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GpuModel {
    V100M16,
    V100M32,
    P100,
    T4,
    A10,
    G2,
    G3,
    A30,
}

impl GpuModel {
    /// All models, in Table II order (A30 appended last so the dense
    /// indices of the paper models stay stable).
    pub const ALL: [GpuModel; 8] = [
        GpuModel::V100M16,
        GpuModel::V100M32,
        GpuModel::P100,
        GpuModel::T4,
        GpuModel::A10,
        GpuModel::G2,
        GpuModel::G3,
        GpuModel::A30,
    ];

    /// Idle power draw in Watt (`p_idle` in Eq. 2).
    pub fn p_idle(self) -> f64 {
        match self {
            GpuModel::V100M16 | GpuModel::V100M32 => 30.0,
            GpuModel::P100 => 25.0,
            GpuModel::T4 => 10.0,
            GpuModel::A10 | GpuModel::G2 => 30.0,
            GpuModel::G3 => 50.0,
            GpuModel::A30 => 30.0,
        }
    }

    /// Thermal Design Power in Watt (`p_max` in Eq. 2).
    pub fn p_max(self) -> f64 {
        match self {
            GpuModel::V100M16 | GpuModel::V100M32 => 300.0,
            GpuModel::P100 => 250.0,
            GpuModel::T4 => 70.0,
            GpuModel::A10 | GpuModel::G2 => 150.0,
            GpuModel::G3 => 400.0,
            GpuModel::A30 => 165.0,
        }
    }

    /// Number of GPUs of this model in the paper's cluster (Table II).
    pub fn paper_count(self) -> usize {
        match self {
            GpuModel::V100M16 => 195,
            GpuModel::V100M32 => 204,
            GpuModel::P100 => 265,
            GpuModel::T4 => 842,
            GpuModel::A10 => 2,
            GpuModel::G2 => 4392,
            GpuModel::G3 => 312,
            GpuModel::A30 => 0,
        }
    }

    /// Stable small integer id (used by the XLA scorer's dense encoding).
    pub fn index(self) -> usize {
        GpuModel::ALL.iter().position(|&m| m == self).unwrap()
    }

    /// Inverse of [`Self::index`].
    pub fn from_index(i: usize) -> Option<GpuModel> {
        GpuModel::ALL.get(i).copied()
    }

    /// Parse a model name (the CLI accepts these).
    pub fn parse(s: &str) -> Option<GpuModel> {
        match s.to_ascii_uppercase().as_str() {
            "V100M16" => Some(GpuModel::V100M16),
            "V100M32" => Some(GpuModel::V100M32),
            "P100" => Some(GpuModel::P100),
            "T4" => Some(GpuModel::T4),
            "A10" => Some(GpuModel::A10),
            "G2" => Some(GpuModel::G2),
            "G3" => Some(GpuModel::G3),
            "A30" => Some(GpuModel::A30),
            _ => None,
        }
    }
}

impl fmt::Display for GpuModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            GpuModel::V100M16 => "V100M16",
            GpuModel::V100M32 => "V100M32",
            GpuModel::P100 => "P100",
            GpuModel::T4 => "T4",
            GpuModel::A10 => "A10",
            GpuModel::G2 => "G2",
            GpuModel::G3 => "G3",
            GpuModel::A30 => "A30",
        };
        f.write_str(s)
    }
}

/// CPU models. The trace publishes none, so following the paper we use
/// the Intel Xeon E5-2682 v4 everywhere (§V-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CpuModel {
    XeonE5_2682V4,
}

impl CpuModel {
    /// Physical cores per socket (`ncores(·)` in Eq. 1).
    pub fn ncores(self) -> f64 {
        match self {
            CpuModel::XeonE5_2682V4 => 16.0,
        }
    }

    /// Idle power of one socket in Watt (`p_idle` in Eq. 1).
    pub fn p_idle(self) -> f64 {
        match self {
            CpuModel::XeonE5_2682V4 => 15.0,
        }
    }

    /// TDP of one socket in Watt (`p_max` in Eq. 1).
    pub fn p_max(self) -> f64 {
        match self {
            CpuModel::XeonE5_2682V4 => 120.0,
        }
    }

    /// vCPUs served by one socket (2 vCPU per physical core, §II).
    pub fn vcpus_per_socket(self) -> f64 {
        2.0 * self.ncores()
    }
}

impl fmt::Display for CpuModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CpuModel::XeonE5_2682V4 => f.write_str("Xeon-E5-2682v4"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_power_profiles() {
        assert_eq!(GpuModel::V100M16.p_idle(), 30.0);
        assert_eq!(GpuModel::V100M16.p_max(), 300.0);
        assert_eq!(GpuModel::P100.p_idle(), 25.0);
        assert_eq!(GpuModel::P100.p_max(), 250.0);
        assert_eq!(GpuModel::T4.p_idle(), 10.0);
        assert_eq!(GpuModel::T4.p_max(), 70.0);
        assert_eq!(GpuModel::G2.p_max(), 150.0);
        assert_eq!(GpuModel::G3.p_idle(), 50.0);
        assert_eq!(GpuModel::G3.p_max(), 400.0);
    }

    #[test]
    fn table2_counts_total_6212() {
        let total: usize = GpuModel::ALL.iter().map(|m| m.paper_count()).sum();
        assert_eq!(total, 6212);
    }

    #[test]
    fn index_roundtrip() {
        for m in GpuModel::ALL {
            assert_eq!(GpuModel::from_index(m.index()), Some(m));
        }
        assert_eq!(GpuModel::from_index(8), None);
    }

    #[test]
    fn parse_names() {
        assert_eq!(GpuModel::parse("t4"), Some(GpuModel::T4));
        assert_eq!(GpuModel::parse("g3"), Some(GpuModel::G3));
        assert_eq!(GpuModel::parse("a30"), Some(GpuModel::A30));
        assert_eq!(GpuModel::parse("H100"), None);
    }

    #[test]
    fn a30_profile_outside_paper_inventory() {
        assert_eq!(GpuModel::A30.p_idle(), 30.0);
        assert_eq!(GpuModel::A30.p_max(), 165.0);
        assert_eq!(GpuModel::A30.paper_count(), 0);
    }

    #[test]
    fn cpu_profile() {
        let c = CpuModel::XeonE5_2682V4;
        assert_eq!(c.ncores(), 16.0);
        assert_eq!(c.p_idle(), 15.0);
        assert_eq!(c.p_max(), 120.0);
        assert_eq!(c.vcpus_per_socket(), 32.0);
    }
}
