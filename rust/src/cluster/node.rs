//! Node state: allocated/unallocated resource vectors (`R_n`, `Ra_n`),
//! feasibility (Cond. 1–3 + constraints), placements and allocation.

use crate::cluster::mig::{
    first_fit_start, window_mask, MigGpu, MigLattice, MigProfile, RepackPlan,
};
use crate::cluster::types::{CpuModel, GpuModel};
use crate::tasks::{GpuDemand, Task, NUM_BUCKETS};

/// Numerical slack for GPU-fraction comparisons (fractions arrive as
/// sums of trace values like 0.25/0.5; we never want 0.7500000000000002
/// to make a feasible placement infeasible).
pub const EPS: f64 = 1e-9;

/// Shared class-count maintenance for the affinity indexes: the
/// node-level store ([`Node::class_counts`]) and the cluster-wide one
/// (`Datacenter`) follow the same discipline — saturating decrement,
/// drained keys removed so emptiness checks and iteration stay clean.
pub(crate) fn class_count_add(map: &mut std::collections::HashMap<String, u32>, key: &str) {
    *map.entry(key.to_string()).or_insert(0) += 1;
}

/// See [`class_count_add`].
pub(crate) fn class_count_remove(map: &mut std::collections::HashMap<String, u32>, key: &str) {
    let drained = match map.get_mut(key) {
        Some(n) => {
            *n = n.saturating_sub(1);
            *n == 0
        }
        None => false,
    };
    if drained {
        map.remove(key);
    }
}

/// Node power state — the DRS (Dynamic Resource Scaling) state
/// machine (`rust/src/sched/drs.rs`, `docs/power.md`). Without a DRS
/// hook every node stays `Active` forever, which keeps all pre-DRS
/// behavior bit-identical (pinned by `rust/tests/drs_equivalence.rs`).
///
/// ```text
///            idle ≥ idle_timeout           next tick, still idle
///   Active ───────────────────▶ Draining ───────────────────▶ Asleep
///     ▲  ▲                         │                            │
///     │  │ ready_at reached        │ demand pressure            │ demand pressure
///     │  └────────── Waking ◀──────┼────────────────────────────┘
///     └─────────────────(cancel: never slept)
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PowerState {
    /// Powered and schedulable — the only state without DRS.
    #[default]
    Active,
    /// Marked for sleep: still fully powered and drawing idle watts,
    /// but excluded from placement by the `drs` filter plugin. The DRS
    /// hook completes the transition to `Asleep` on its next tick, or
    /// cancels back to `Active` for free under demand pressure.
    Draining,
    /// Powered down: draws [`crate::power::NODE_STANDBY_W`] instead of
    /// its Eq. 1/2 idle wattage; excluded from placement until woken.
    Asleep,
    /// Booting after a wake request; becomes `Active` once the
    /// scheduler-event clock reaches `ready_at`. Excluded from
    /// placement (it cannot host work yet) but counted as future
    /// capacity by the aggregate PreFilter checks, which read
    /// state-independent [`crate::cluster::Datacenter`] totals.
    Waking { ready_at: u64 },
}

/// Where a task lands inside a node.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Placement {
    /// No GPU touched (CPU-only task).
    CpuOnly,
    /// Shares GPU `gpu` (fractional demand).
    Shared { gpu: usize },
    /// Takes these whole GPUs exclusively.
    Whole { gpus: Vec<usize> },
    /// Occupies the MIG instance `(profile from the task, start)` on
    /// GPU `gpu` of a MIG-enabled node.
    MigSlice { gpu: usize, start: u8 },
}

/// Read-only view of a node's free resources. Implemented both by
/// [`Node`] and by [`Hypothetical`], so the power/fragmentation models
/// evaluate hypothetical assignments without cloning node state.
pub trait ResourceView {
    fn cpu_model(&self) -> CpuModel;
    fn gpu_model(&self) -> Option<GpuModel>;
    /// Total vCPUs installed.
    fn cpu_capacity(&self) -> f64;
    /// Allocated vCPUs (`Ra_n^CPU`).
    fn cpu_alloc(&self) -> f64;
    /// Total memory installed (MiB).
    fn mem_capacity(&self) -> f64;
    /// Allocated memory (MiB).
    fn mem_alloc(&self) -> f64;
    /// Number of GPUs installed.
    fn n_gpus(&self) -> usize;
    /// Allocated fraction of GPU `g` (`Ra_{n,g}^GPU ∈ [0,1]`).
    fn gpu_alloc_of(&self, g: usize) -> f64;
    /// MIG occupancy bitmask of GPU `g`, or `None` when the node is not
    /// MIG-enabled. MIG nodes report `gpu_alloc_of = used_slices /
    /// lattice slices`, so every slice-free aggregate below stays
    /// consistent.
    fn mig_mask_of(&self, _g: usize) -> Option<u8> {
        None
    }
    /// The partition lattice of the node's GPUs, or `None` when the
    /// node is not MIG-enabled. Nodes are lattice-homogeneous (one GPU
    /// model per node).
    fn mig_lattice(&self) -> Option<MigLattice> {
        None
    }
    /// True when the node's GPUs are MIG-partitioned. MIG nodes host
    /// only [`GpuDemand::Mig`] (and CPU-only) tasks; fractional and
    /// whole-GPU demands do not mix with a partitioned GPU.
    fn is_mig(&self) -> bool {
        false
    }

    /// Free vCPUs (`R_n^CPU`).
    fn cpu_free(&self) -> f64 {
        self.cpu_capacity() - self.cpu_alloc()
    }
    /// Free memory (`R_n^MEM`).
    fn mem_free(&self) -> f64 {
        self.mem_capacity() - self.mem_alloc()
    }
    /// Unallocated fraction of GPU `g` (`R_{n,g}^GPU`).
    fn gpu_free_of(&self, g: usize) -> f64 {
        1.0 - self.gpu_alloc_of(g)
    }
    /// Sum of unallocated GPU fractions on the node.
    fn gpu_free_total(&self) -> f64 {
        (0..self.n_gpus()).map(|g| self.gpu_free_of(g)).sum()
    }
    /// Count of fully-free GPUs.
    fn gpus_fully_free(&self) -> usize {
        (0..self.n_gpus()).filter(|&g| self.gpu_free_of(g) >= 1.0 - EPS).count()
    }
    /// Largest per-GPU free fraction strictly below 1.
    fn largest_partial_free(&self) -> f64 {
        (0..self.n_gpus())
            .map(|g| self.gpu_free_of(g))
            .filter(|&f| f < 1.0 - EPS)
            .fold(0.0, f64::max)
    }
    /// Largest per-GPU free fraction (including fully-free GPUs).
    fn largest_free(&self) -> f64 {
        (0..self.n_gpus()).map(|g| self.gpu_free_of(g)).fold(0.0, f64::max)
    }

    /// The scalar `u_n` of §II: `Σ_g ⌊R_g⌋ + max_g (R_g − ⌊R_g⌋)`.
    fn u_n(&self) -> f64 {
        let whole: f64 = self.gpus_fully_free() as f64;
        whole + self.largest_partial_free()
    }

    /// Feasibility of `task` on this node: Cond. 1 (CPU), Cond. 2 (MEM),
    /// Cond. 3 (GPU), plus the `C_t^GPU` model constraint.
    ///
    /// Note on Cond. 3 for fractional demands: the paper states
    /// `D ≤ u_n − ⌊u_n⌋`, which taken literally would reject a fractional
    /// task on a node whose GPUs are all fully free. Following the FGD
    /// reference implementation (and the paper's own deference to [19])
    /// we use the intended semantics: some single GPU must have at least
    /// `D` free.
    fn can_fit(&self, task: &Task) -> bool {
        if task.cpu > self.cpu_free() + EPS {
            return false; // Cond. 1
        }
        if task.mem > self.mem_free() + EPS {
            return false; // Cond. 2
        }
        match task.gpu {
            GpuDemand::Zero => true,
            _ => {
                let Some(model) = self.gpu_model() else { return false };
                if let Some(required) = task.gpu_model {
                    if required != model {
                        return false;
                    }
                }
                match task.gpu {
                    GpuDemand::Zero => unreachable!(),
                    GpuDemand::Frac(d) => !self.is_mig() && self.largest_free() >= d - EPS,
                    GpuDemand::Whole(k) => {
                        !self.is_mig() && self.gpus_fully_free() >= k as usize
                    }
                    GpuDemand::Mig(p) => {
                        self.mig_lattice() == Some(p.lattice())
                            && (0..self.n_gpus()).any(|g| {
                                self.mig_mask_of(g)
                                    .is_some_and(|m| first_fit_start(m, p).is_some())
                            })
                    }
                }
            }
        }
    }
}

/// A datacenter node.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: usize,
    pub cpu_model: CpuModel,
    pub gpu_model: Option<GpuModel>,
    /// Total installed vCPUs.
    pub vcpus: f64,
    /// Total installed memory (MiB).
    pub mem: f64,
    /// Allocated vCPUs.
    pub cpu_alloc: f64,
    /// Allocated memory (MiB).
    pub mem_alloc: f64,
    /// Per-GPU allocated fraction. On MIG nodes this mirrors
    /// `mig[g].alloc_fraction()` (used slices / lattice slices) so
    /// every fraction-based aggregate (power Eq. 2 activity, GRAR
    /// caches, `u_n`) keeps working at slice granularity.
    pub gpu_alloc: Vec<f64>,
    /// MIG partition state per GPU; `None` for non-MIG nodes.
    pub mig: Option<Vec<MigGpu>>,
    /// Number of resident tasks per Table-I bucket (used by the
    /// GpuClustering policy and by node-activity checks).
    pub bucket_mix: [u32; NUM_BUCKETS],
    /// Total resident tasks.
    pub n_tasks: u32,
    /// Scheduling labels (zone / tenant / rack …), matched by the
    /// `labels` filter plugin against task node-selectors. Assigned at
    /// build time ([`crate::cluster::ClusterSpec`]); never mutated by
    /// allocation, so cluster-level label indexes stay valid.
    pub labels: Vec<(String, String)>,
    /// Resident task count per constraint class key (see
    /// [`crate::tasks::TaskConstraints::class_key`]) — the state the
    /// `affinity` filter plugin reads. Maintained by
    /// [`Node::allocate`] / [`Node::deallocate`].
    pub class_counts: std::collections::HashMap<String, u32>,
    /// DRS power state (always [`PowerState::Active`] unless a `drs`
    /// hook drives the sleep/wake lifecycle). Read by the `drs` filter
    /// plugin and the state-aware datacenter power sums.
    pub power_state: PowerState,
}

impl Node {
    /// Construct an empty node.
    pub fn new(
        id: usize,
        cpu_model: CpuModel,
        gpu_model: Option<GpuModel>,
        vcpus: f64,
        mem: f64,
        n_gpus: usize,
    ) -> Node {
        assert!(gpu_model.is_some() || n_gpus == 0, "GPUs require a model");
        Node {
            id,
            cpu_model,
            gpu_model,
            vcpus,
            mem,
            cpu_alloc: 0.0,
            mem_alloc: 0.0,
            gpu_alloc: vec![0.0; n_gpus],
            mig: None,
            bucket_mix: [0; NUM_BUCKETS],
            n_tasks: 0,
            labels: Vec::new(),
            class_counts: std::collections::HashMap::new(),
            power_state: PowerState::Active,
        }
    }

    /// True when the node carries the `(key, value)` label.
    pub fn has_label(&self, key: &str, value: &str) -> bool {
        self.labels.iter().any(|(k, v)| k == key && v == value)
    }

    /// Resident tasks of the given constraint class.
    pub fn class_count(&self, key: &str) -> u32 {
        self.class_counts.get(key).copied().unwrap_or(0)
    }

    /// Turn the (empty) node's GPUs into MIG-partitioned devices using
    /// the lattice of the node's GPU model
    /// ([`MigLattice::for_gpu`]: A30 → 4-slice, otherwise A100-style).
    pub fn enable_mig(&mut self) {
        assert_eq!(self.n_tasks, 0, "enable MIG only on an empty node");
        let model = self.gpu_model.expect("MIG requires GPUs");
        let lattice = MigLattice::for_gpu(model);
        self.mig = Some(vec![MigGpu::with_lattice(lattice); self.gpu_alloc.len()]);
    }

    /// Plan a repack of GPU `gpu` that opens a legal start for
    /// `profile` (see [`MigGpu::repack_plan`]); `None` on non-MIG
    /// nodes or when the profile cannot fit.
    pub fn mig_repack_plan(&self, gpu: usize, profile: MigProfile) -> Option<RepackPlan> {
        self.mig.as_ref()?.get(gpu)?.repack_plan(profile)
    }

    /// Apply a plan from [`Self::mig_repack_plan`]. Slice counts are
    /// unchanged, so `gpu_alloc` and the datacenter caches stay valid.
    pub fn mig_apply_repack(&mut self, gpu: usize, plan: &[(usize, u8)]) {
        if let Some(migs) = self.mig.as_mut() {
            migs[gpu].apply_repack(plan);
        }
    }

    /// True if any resource is allocated (an "active" node for the
    /// GpuPacking policy's tiers).
    pub fn is_active(&self) -> bool {
        self.n_tasks > 0
    }

    /// Enumerate the placements `task` could take on this node.
    /// * CPU-only → `[CpuOnly]`
    /// * fractional → one `Shared{g}` per GPU with enough free fraction
    /// * whole-k → a single canonical placement over the first k fully
    ///   free GPUs (all whole-GPU subsets are equivalent: same model,
    ///   same power, same fragmentation)
    ///
    /// Empty when the task does not fit.
    pub fn candidate_placements(&self, task: &Task) -> Vec<Placement> {
        if !self.can_fit(task) {
            return Vec::new();
        }
        match task.gpu {
            GpuDemand::Zero => vec![Placement::CpuOnly],
            GpuDemand::Frac(d) => (0..self.gpu_alloc.len())
                .filter(|&g| self.gpu_free_of(g) >= d - EPS)
                .map(|g| Placement::Shared { gpu: g })
                .collect(),
            GpuDemand::Whole(k) => {
                let free: Vec<usize> = (0..self.gpu_alloc.len())
                    .filter(|&g| self.gpu_free_of(g) >= 1.0 - EPS)
                    .take(k as usize)
                    .collect();
                debug_assert_eq!(free.len(), k as usize);
                vec![Placement::Whole { gpus: free }]
            }
            GpuDemand::Mig(p) => {
                let Some(migs) = &self.mig else { return Vec::new() };
                let mut out = Vec::new();
                for (g, mg) in migs.iter().enumerate() {
                    for s in mg.free_starts(p) {
                        out.push(Placement::MigSlice { gpu: g, start: s });
                    }
                }
                out
            }
        }
    }

    /// Validate that `placement` is currently legal for `task`.
    pub fn placement_fits(&self, task: &Task, placement: &Placement) -> bool {
        if task.cpu > self.cpu_free() + EPS || task.mem > self.mem_free() + EPS {
            return false;
        }
        match (task.gpu, placement) {
            (GpuDemand::Zero, Placement::CpuOnly) => true,
            (GpuDemand::Frac(d), Placement::Shared { gpu }) => {
                *gpu < self.gpu_alloc.len() && self.gpu_free_of(*gpu) >= d - EPS
            }
            (GpuDemand::Whole(k), Placement::Whole { gpus }) => {
                gpus.len() == k as usize
                    && gpus.iter().all(|&g| {
                        g < self.gpu_alloc.len() && self.gpu_free_of(g) >= 1.0 - EPS
                    })
            }
            (GpuDemand::Mig(p), Placement::MigSlice { gpu, start }) => {
                self.mig.as_ref().is_some_and(|migs| {
                    *gpu < migs.len()
                        && migs[*gpu].lattice == p.lattice()
                        && p.legal_starts().contains(start)
                        && migs[*gpu].mask & window_mask(p, *start) == 0
                })
            }
            _ => false,
        }
    }

    /// Commit an allocation. Panics (debug) on an illegal placement —
    /// the scheduler must only bind placements from
    /// [`Self::candidate_placements`].
    pub fn allocate(&mut self, task: &Task, placement: &Placement) {
        debug_assert!(self.placement_fits(task, placement), "illegal placement");
        self.cpu_alloc += task.cpu;
        self.mem_alloc += task.mem;
        match placement {
            Placement::CpuOnly => {}
            Placement::Shared { gpu } => {
                self.gpu_alloc[*gpu] = (self.gpu_alloc[*gpu] + task.gpu.units()).min(1.0);
            }
            Placement::Whole { gpus } => {
                for &g in gpus {
                    self.gpu_alloc[g] = 1.0;
                }
            }
            Placement::MigSlice { gpu, start } => {
                let GpuDemand::Mig(p) = task.gpu else { unreachable!("MigSlice needs Mig demand") };
                let migs = self.mig.as_mut().expect("MigSlice on non-MIG node");
                let ok = migs[*gpu].place(p, *start);
                debug_assert!(ok, "illegal MIG placement");
                self.gpu_alloc[*gpu] = migs[*gpu].alloc_fraction();
            }
        }
        self.bucket_mix[task.gpu.bucket()] += 1;
        self.n_tasks += 1;
        if let Some(key) = task.constraints.as_deref().and_then(|c| c.class_key.as_ref()) {
            class_count_add(&mut self.class_counts, key);
        }
    }

    /// Release an allocation made with the same (task, placement) pair.
    pub fn deallocate(&mut self, task: &Task, placement: &Placement) {
        self.cpu_alloc = (self.cpu_alloc - task.cpu).max(0.0);
        self.mem_alloc = (self.mem_alloc - task.mem).max(0.0);
        match placement {
            Placement::CpuOnly => {}
            Placement::Shared { gpu } => {
                self.gpu_alloc[*gpu] = (self.gpu_alloc[*gpu] - task.gpu.units()).max(0.0);
            }
            Placement::Whole { gpus } => {
                for &g in gpus {
                    self.gpu_alloc[g] = 0.0;
                }
            }
            Placement::MigSlice { gpu, start } => {
                if let (GpuDemand::Mig(p), Some(migs)) = (task.gpu, self.mig.as_mut()) {
                    // Exact (gpu, start) first; a repack may have moved
                    // the instance, so fall back to any instance of the
                    // profile (same GPU, then node-wide) — instances of
                    // equal profile are fungible.
                    let released = migs[*gpu].release(p, Some(*start))
                        || migs[*gpu].release(p, None)
                        || (0..migs.len()).any(|j| migs[j].release(p, None));
                    debug_assert!(released, "no MIG instance of {p} to release");
                    for j in 0..migs.len() {
                        self.gpu_alloc[j] = migs[j].alloc_fraction();
                    }
                }
            }
        }
        self.bucket_mix[task.gpu.bucket()] =
            self.bucket_mix[task.gpu.bucket()].saturating_sub(1);
        self.n_tasks = self.n_tasks.saturating_sub(1);
        if let Some(key) = task.constraints.as_deref().and_then(|c| c.class_key.as_ref()) {
            class_count_remove(&mut self.class_counts, key);
        }
    }

    /// A zero-copy hypothetical view of this node after assigning
    /// `(task, placement)` — used by every score plugin's what-if pass.
    pub fn hypothetical<'a>(&'a self, task: &'a Task, placement: &'a Placement) -> Hypothetical<'a> {
        debug_assert!(self.placement_fits(task, placement));
        Hypothetical { node: self, task, placement }
    }
}

impl ResourceView for Node {
    fn cpu_model(&self) -> CpuModel {
        self.cpu_model
    }
    fn gpu_model(&self) -> Option<GpuModel> {
        self.gpu_model
    }
    fn cpu_capacity(&self) -> f64 {
        self.vcpus
    }
    fn cpu_alloc(&self) -> f64 {
        self.cpu_alloc
    }
    fn mem_capacity(&self) -> f64 {
        self.mem
    }
    fn mem_alloc(&self) -> f64 {
        self.mem_alloc
    }
    fn n_gpus(&self) -> usize {
        self.gpu_alloc.len()
    }
    fn gpu_alloc_of(&self, g: usize) -> f64 {
        self.gpu_alloc[g]
    }
    fn mig_mask_of(&self, g: usize) -> Option<u8> {
        self.mig.as_ref().map(|m| m[g].mask)
    }
    fn mig_lattice(&self) -> Option<MigLattice> {
        // The per-GPU lattice tag is authoritative (nodes are
        // lattice-homogeneous: `enable_mig` partitions every GPU with
        // the model's lattice).
        self.mig.as_ref()?.first().map(|g| g.lattice)
    }
    fn is_mig(&self) -> bool {
        self.mig.is_some()
    }
}

/// Zero-copy overlay representing a node *after* a hypothetical
/// assignment (the `HYPASSIGNTONODE` of Algorithm 1).
pub struct Hypothetical<'a> {
    node: &'a Node,
    task: &'a Task,
    placement: &'a Placement,
}

impl ResourceView for Hypothetical<'_> {
    fn cpu_model(&self) -> CpuModel {
        self.node.cpu_model
    }
    fn gpu_model(&self) -> Option<GpuModel> {
        self.node.gpu_model
    }
    fn cpu_capacity(&self) -> f64 {
        self.node.vcpus
    }
    fn cpu_alloc(&self) -> f64 {
        self.node.cpu_alloc + self.task.cpu
    }
    fn mem_capacity(&self) -> f64 {
        self.node.mem
    }
    fn mem_alloc(&self) -> f64 {
        self.node.mem_alloc + self.task.mem
    }
    fn n_gpus(&self) -> usize {
        self.node.gpu_alloc.len()
    }
    fn gpu_alloc_of(&self, g: usize) -> f64 {
        let base = self.node.gpu_alloc[g];
        match self.placement {
            Placement::CpuOnly => base,
            Placement::Shared { gpu } if *gpu == g => {
                (base + self.task.gpu.units()).min(1.0)
            }
            Placement::Shared { .. } => base,
            Placement::Whole { gpus } => {
                if gpus.contains(&g) {
                    1.0
                } else {
                    base
                }
            }
            Placement::MigSlice { gpu, .. } => {
                if *gpu == g {
                    (base + self.task.gpu.units()).min(1.0)
                } else {
                    base
                }
            }
        }
    }
    fn mig_mask_of(&self, g: usize) -> Option<u8> {
        let base = self.node.mig.as_ref().map(|m| m[g].mask)?;
        Some(match (self.task.gpu, self.placement) {
            (GpuDemand::Mig(p), Placement::MigSlice { gpu, start }) if *gpu == g => {
                base | window_mask(p, *start)
            }
            _ => base,
        })
    }
    fn mig_lattice(&self) -> Option<MigLattice> {
        self.node.mig_lattice()
    }
    fn is_mig(&self) -> bool {
        self.node.mig.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::types::{CpuModel, GpuModel};

    fn node8() -> Node {
        Node::new(0, CpuModel::XeonE5_2682V4, Some(GpuModel::G2), 96.0, 393_216.0, 8)
    }

    #[test]
    fn fresh_node_is_free() {
        let n = node8();
        assert_eq!(n.cpu_free(), 96.0);
        assert_eq!(n.gpu_free_total(), 8.0);
        assert_eq!(n.gpus_fully_free(), 8);
        assert_eq!(n.u_n(), 8.0);
        assert!(!n.is_active());
        // Nodes are born powered on; only a DRS hook changes this.
        assert_eq!(n.power_state, PowerState::Active);
        assert_eq!(PowerState::default(), PowerState::Active);
    }

    #[test]
    fn cond1_cpu() {
        let n = node8();
        assert!(n.can_fit(&Task::new(0, 96.0, 0.0, GpuDemand::Zero)));
        assert!(!n.can_fit(&Task::new(0, 96.5, 0.0, GpuDemand::Zero)));
    }

    #[test]
    fn cond2_mem() {
        let n = node8();
        assert!(!n.can_fit(&Task::new(0, 1.0, 400_000.0, GpuDemand::Zero)));
    }

    #[test]
    fn cond3_whole_gpus() {
        let mut n = node8();
        assert!(n.can_fit(&Task::new(0, 1.0, 0.0, GpuDemand::Whole(8))));
        // Occupy a slice of one GPU -> only 7 fully free.
        let t = Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.25));
        n.allocate(&t, &Placement::Shared { gpu: 0 });
        assert!(!n.can_fit(&Task::new(0, 1.0, 0.0, GpuDemand::Whole(8))));
        assert!(n.can_fit(&Task::new(0, 1.0, 0.0, GpuDemand::Whole(7))));
        assert!((n.u_n() - 7.75).abs() < EPS);
    }

    #[test]
    fn cond3_fractional_on_free_gpu() {
        let n = node8();
        // Intended semantics: a fractional task fits a fully free GPU.
        assert!(n.can_fit(&Task::new(0, 1.0, 0.0, GpuDemand::Frac(0.9))));
    }

    #[test]
    fn fractional_needs_single_gpu_with_room() {
        let mut n = Node::new(0, CpuModel::XeonE5_2682V4, Some(GpuModel::T4), 64.0, 131_072.0, 2);
        n.allocate(&Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.6)), &Placement::Shared { gpu: 0 });
        n.allocate(&Task::new(2, 1.0, 0.0, GpuDemand::Frac(0.6)), &Placement::Shared { gpu: 1 });
        // 0.4 + 0.4 free in aggregate, but no single GPU has 0.5.
        assert!(!n.can_fit(&Task::new(3, 1.0, 0.0, GpuDemand::Frac(0.5))));
        assert!(n.can_fit(&Task::new(3, 1.0, 0.0, GpuDemand::Frac(0.4))));
    }

    #[test]
    fn constraint_filters_model() {
        let n = node8(); // G2 node
        let ok = Task::new(0, 1.0, 0.0, GpuDemand::Whole(1)).constrained(GpuModel::G2);
        let bad = Task::new(0, 1.0, 0.0, GpuDemand::Whole(1)).constrained(GpuModel::T4);
        assert!(n.can_fit(&ok));
        assert!(!n.can_fit(&bad));
    }

    #[test]
    fn cpu_only_node_rejects_gpu_tasks() {
        let n = Node::new(0, CpuModel::XeonE5_2682V4, None, 94.0, 262_144.0, 0);
        assert!(!n.can_fit(&Task::new(0, 1.0, 0.0, GpuDemand::Frac(0.1))));
        assert!(n.can_fit(&Task::new(0, 1.0, 0.0, GpuDemand::Zero)));
    }

    #[test]
    fn candidate_placements_fractional() {
        let mut n = node8();
        n.allocate(&Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.7)), &Placement::Shared { gpu: 3 });
        let t = Task::new(2, 1.0, 0.0, GpuDemand::Frac(0.5));
        let ps = n.candidate_placements(&t);
        // GPU 3 has only 0.3 free -> 7 candidates.
        assert_eq!(ps.len(), 7);
        assert!(!ps.contains(&Placement::Shared { gpu: 3 }));
    }

    #[test]
    fn candidate_placements_whole_is_canonical() {
        let n = node8();
        let ps = n.candidate_placements(&Task::new(0, 1.0, 0.0, GpuDemand::Whole(2)));
        assert_eq!(ps, vec![Placement::Whole { gpus: vec![0, 1] }]);
    }

    #[test]
    fn allocate_deallocate_roundtrip() {
        let mut n = node8();
        let t = Task::new(1, 8.0, 1024.0, GpuDemand::Whole(2));
        let p = n.candidate_placements(&t).pop().unwrap();
        n.allocate(&t, &p);
        assert_eq!(n.cpu_alloc, 8.0);
        assert_eq!(n.gpus_fully_free(), 6);
        assert_eq!(n.n_tasks, 1);
        n.deallocate(&t, &p);
        assert_eq!(n.cpu_alloc, 0.0);
        assert_eq!(n.gpus_fully_free(), 8);
        assert_eq!(n.n_tasks, 0);
    }

    #[test]
    fn hypothetical_matches_committed() {
        let mut n = node8();
        let t = Task::new(1, 4.0, 512.0, GpuDemand::Frac(0.5));
        let p = Placement::Shared { gpu: 2 };
        // Hypothetical view first...
        {
            let h = n.hypothetical(&t, &p);
            assert_eq!(h.cpu_alloc(), 4.0);
            assert!((h.gpu_alloc_of(2) - 0.5).abs() < EPS);
            assert_eq!(h.gpu_alloc_of(1), 0.0);
        }
        // ...must equal the committed state.
        n.allocate(&t, &p);
        assert_eq!(n.cpu_alloc(), 4.0);
        assert!((n.gpu_alloc_of(2) - 0.5).abs() < EPS);
    }

    #[test]
    fn float_accumulation_tolerated() {
        let mut n = node8();
        // 10 × 0.1 fills a GPU exactly despite float error.
        for i in 0..10 {
            let t = Task::new(i, 0.5, 0.0, GpuDemand::Frac(0.1));
            assert!(n.placement_fits(&t, &Placement::Shared { gpu: 0 }), "iter {i}");
            n.allocate(&t, &Placement::Shared { gpu: 0 });
        }
        assert!(n.gpu_alloc[0] <= 1.0);
        assert!(!n.placement_fits(
            &Task::new(99, 0.5, 0.0, GpuDemand::Frac(0.1)),
            &Placement::Shared { gpu: 0 }
        ));
    }

    fn mig_node2() -> Node {
        let mut n =
            Node::new(0, CpuModel::XeonE5_2682V4, Some(GpuModel::G3), 128.0, 786_432.0, 2);
        n.enable_mig();
        n
    }

    #[test]
    fn mig_demand_separation() {
        use crate::cluster::mig::MigProfile;
        let mig = mig_node2();
        let plain = node8();
        // MIG demand only fits MIG nodes; frac/whole only fit plain ones.
        let t_mig = Task::new(0, 1.0, 0.0, GpuDemand::Mig(MigProfile::P2g));
        assert!(mig.can_fit(&t_mig));
        assert!(!plain.can_fit(&t_mig));
        assert!(!mig.can_fit(&Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.5))));
        assert!(!mig.can_fit(&Task::new(2, 1.0, 0.0, GpuDemand::Whole(1))));
        // CPU-only fits both.
        assert!(mig.can_fit(&Task::new(3, 1.0, 0.0, GpuDemand::Zero)));
    }

    #[test]
    fn mig_alloc_release_roundtrip_keeps_mirror() {
        use crate::cluster::mig::MigProfile;
        let mut n = mig_node2();
        let t = Task::new(1, 4.0, 1024.0, GpuDemand::Mig(MigProfile::P3g));
        let ps = n.candidate_placements(&t);
        // 2 GPUs × starts {4, 0} each.
        assert_eq!(ps.len(), 4);
        assert_eq!(ps[0], Placement::MigSlice { gpu: 0, start: 4 });
        n.allocate(&t, &ps[0]);
        assert!((n.gpu_alloc[0] - 3.0 / 7.0).abs() < EPS);
        assert!((n.gpu_free_total() - (4.0 / 7.0 + 1.0)).abs() < EPS);
        assert_eq!(n.n_tasks, 1);
        n.deallocate(&t, &ps[0]);
        assert_eq!(n.gpu_alloc[0], 0.0);
        assert_eq!(n.mig.as_ref().unwrap()[0].mask, 0);
        assert_eq!(n.n_tasks, 0);
    }

    #[test]
    fn mig_release_survives_stale_start_after_repack() {
        use crate::cluster::mig::MigProfile;
        let mut n = mig_node2();
        let t3 = Task::new(1, 1.0, 0.0, GpuDemand::Mig(MigProfile::P3g));
        let t2 = Task::new(2, 1.0, 0.0, GpuDemand::Mig(MigProfile::P2g));
        // Force the awkward layout {3g@0, 2g@4} directly.
        n.allocate(&t3, &Placement::MigSlice { gpu: 0, start: 0 });
        n.allocate(&t2, &Placement::MigSlice { gpu: 0, start: 4 });
        let (plan, moved) = n.mig_repack_plan(0, MigProfile::P2g).unwrap();
        assert!(moved > 0);
        n.mig_apply_repack(0, &plan);
        // The recorded placements now have stale starts; release must
        // still free the instances (fungible within a profile).
        n.deallocate(&t3, &Placement::MigSlice { gpu: 0, start: 0 });
        n.deallocate(&t2, &Placement::MigSlice { gpu: 0, start: 4 });
        assert_eq!(n.mig.as_ref().unwrap()[0].mask, 0);
        assert_eq!(n.gpu_alloc[0], 0.0);
    }

    #[test]
    fn labels_and_class_counts_track_residency() {
        use crate::tasks::TaskConstraints;
        let mut n = node8();
        n.labels.push(("zone".to_string(), "z1".to_string()));
        assert!(n.has_label("zone", "z1"));
        assert!(!n.has_label("zone", "z2"));
        assert!(!n.has_label("tenant", "z1"));
        let c = TaskConstraints {
            class_key: Some("tenant-a".to_string()),
            ..Default::default()
        };
        let t = Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.5)).with_constraints(c);
        n.allocate(&t, &Placement::Shared { gpu: 0 });
        assert_eq!(n.class_count("tenant-a"), 1);
        n.allocate(&t, &Placement::Shared { gpu: 1 });
        assert_eq!(n.class_count("tenant-a"), 2);
        n.deallocate(&t, &Placement::Shared { gpu: 1 });
        assert_eq!(n.class_count("tenant-a"), 1);
        n.deallocate(&t, &Placement::Shared { gpu: 0 });
        assert_eq!(n.class_count("tenant-a"), 0);
        assert!(n.class_counts.is_empty(), "drained keys are removed");
    }

    #[test]
    fn mig_hypothetical_matches_committed() {
        use crate::cluster::mig::MigProfile;
        let mut n = mig_node2();
        let t = Task::new(1, 4.0, 512.0, GpuDemand::Mig(MigProfile::P4g));
        let p = Placement::MigSlice { gpu: 1, start: 0 };
        {
            let h = n.hypothetical(&t, &p);
            assert!((h.gpu_alloc_of(1) - 4.0 / 7.0).abs() < EPS);
            assert_eq!(h.mig_mask_of(1), Some(0b000_1111));
            assert_eq!(h.mig_mask_of(0), Some(0));
            assert!(h.is_mig());
        }
        n.allocate(&t, &p);
        assert!((n.gpu_alloc_of(1) - 4.0 / 7.0).abs() < EPS);
        assert_eq!(n.mig_mask_of(1), Some(0b000_1111));
    }
}
