//! Cluster inventory generation.
//!
//! Reproduces the paper's simulated datacenter (§V-B): 1,213 nodes — 310
//! CPU-only — 107,018 vCPUs and the 6,212 GPUs of Table II. The trace
//! does not publish per-node GPU counts, so [`ClusterSpec::paper_default`]
//! packs each model into standard node sizes (documented per pool below);
//! the construction is asserted to hit the published totals exactly.

use crate::cluster::datacenter::Topology;
use crate::cluster::node::Node;
use crate::cluster::types::{CpuModel, GpuModel};
use crate::cluster::Datacenter;

/// One homogeneous pool of nodes.
#[derive(Clone, Debug, PartialEq)]
pub struct NodePool {
    /// Number of identical nodes in the pool.
    pub count: usize,
    /// vCPUs per node.
    pub vcpus: f64,
    /// Memory per node (MiB).
    pub mem: f64,
    /// GPU model, if any.
    pub gpu_model: Option<GpuModel>,
    /// GPUs per node.
    pub gpus_per_node: usize,
    /// MIG-partition the GPUs (slice-granular allocation; see
    /// [`crate::cluster::mig`]).
    pub mig: bool,
    /// Scheduling labels stamped on every node of the pool (matched by
    /// the `labels` filter plugin against task node-selectors).
    pub labels: Vec<(String, String)>,
}

/// Declarative cluster description; `build()` materializes nodes.
#[derive(Clone, Debug, Default)]
pub struct ClusterSpec {
    pub pools: Vec<NodePool>,
    /// When > 0, `build()` additionally stamps a round-robin
    /// `zone = z<id % zones>` label on every node (cheap multi-zone
    /// topology for node-selector experiments; see
    /// [`ClusterSpec::with_zones`]).
    pub zones: usize,
    /// Interconnect bandwidth tiers carried onto the built
    /// [`Datacenter`] (`None` = [`Topology::default`]; see
    /// [`ClusterSpec::with_topology`]).
    pub topology: Option<Topology>,
}

impl ClusterSpec {
    /// The paper's cluster (§V-B, Table II). Pool layout:
    ///
    /// | model   | nodes             | GPUs/node | vCPUs | mem MiB |
    /// |---------|-------------------|-----------|-------|---------|
    /// | V100M16 | 24 + 1 remainder  | 8 (+3)    | 64    | 262144  |
    /// | V100M32 | 25 + 1 remainder  | 8 (+4)    | 64    | 262144  |
    /// | P100    | 15 / 36 / 1       | 8 / 4 / 1 | 64    | 262144  |
    /// | T4      | 210 + 1 remainder | 4 (+2)    | 64    | 131072  |
    /// | A10     | 1                 | 2         | 96    | 393216  |
    /// | G2      | 549               | 8         | 96    | 393216  |
    /// | G3      | 39                | 8         | 128   | 786432  |
    /// | CPU-only| 309 + 1 remainder | 0         | 94/84 | 262144  |
    ///
    /// G2/G3 node vCPU+memory sizes are published by the paper; the rest
    /// are standard Alibaba instance shapes. Totals assert to 1,213
    /// nodes, 903 GPU nodes, 6,212 GPUs, 107,018 vCPUs.
    pub fn paper_default() -> ClusterSpec {
        use GpuModel::*;
        let p = |count, vcpus: f64, mem: f64, model: Option<GpuModel>, gpn| NodePool {
            count,
            vcpus,
            mem,
            gpu_model: model,
            gpus_per_node: gpn,
            mig: false,
            labels: Vec::new(),
        };
        ClusterSpec {
            zones: 0,
            topology: None,
            pools: vec![
                p(24, 64.0, 262_144.0, Some(V100M16), 8),
                p(1, 64.0, 262_144.0, Some(V100M16), 3),
                p(25, 64.0, 262_144.0, Some(V100M32), 8),
                p(1, 64.0, 262_144.0, Some(V100M32), 4),
                p(15, 64.0, 262_144.0, Some(P100), 8),
                p(36, 64.0, 262_144.0, Some(P100), 4),
                p(1, 64.0, 262_144.0, Some(P100), 1),
                p(210, 64.0, 131_072.0, Some(T4), 4),
                p(1, 64.0, 131_072.0, Some(T4), 2),
                p(1, 96.0, 393_216.0, Some(A10), 2),
                p(549, 96.0, 393_216.0, Some(G2), 8),
                p(39, 128.0, 786_432.0, Some(G3), 8),
                p(309, 94.0, 262_144.0, None, 0),
                p(1, 84.0, 262_144.0, None, 0),
            ],
        }
    }

    /// A scaled-down cluster for fast tests/benches: same model mix and
    /// proportions, `scale` ∈ (0,1] of the node counts (min 1 per pool).
    pub fn paper_scaled(scale: f64) -> ClusterSpec {
        assert!(scale > 0.0 && scale <= 1.0);
        let mut spec = Self::paper_default();
        for pool in &mut spec.pools {
            pool.count = ((pool.count as f64 * scale).round() as usize).max(1);
        }
        spec
    }

    /// A tiny homogeneous cluster for unit tests.
    pub fn tiny(n_gpu_nodes: usize, gpus_per_node: usize, n_cpu_nodes: usize) -> ClusterSpec {
        ClusterSpec {
            zones: 0,
            topology: None,
            pools: vec![
                NodePool {
                    count: n_gpu_nodes,
                    vcpus: 96.0,
                    mem: 393_216.0,
                    gpu_model: Some(GpuModel::G2),
                    gpus_per_node,
                    mig: false,
                    labels: Vec::new(),
                },
                NodePool {
                    count: n_cpu_nodes,
                    vcpus: 94.0,
                    mem: 262_144.0,
                    gpu_model: None,
                    gpus_per_node: 0,
                    mig: false,
                    labels: Vec::new(),
                },
            ],
        }
    }

    /// Stamp round-robin `zone = z<i>` labels on every built node (the
    /// node-selector topology knob; see [`crate::sched::filter`]).
    pub fn with_zones(mut self, zones: usize) -> ClusterSpec {
        self.zones = zones;
        self
    }

    /// Override the interconnect bandwidth tiers carried onto the built
    /// [`Datacenter`] (see [`Topology`]).
    pub fn with_topology(mut self, topology: Topology) -> ClusterSpec {
        self.topology = Some(topology);
        self
    }

    /// A MIG-partitioned cluster: `n_mig_nodes` A100-class nodes (the
    /// G3 power profile of Table II, 128 vCPUs / 768 GiB, up to 8 GPUs
    /// each, every GPU MIG-enabled) plus optional CPU-only nodes.
    pub fn mig_cluster(
        n_mig_nodes: usize,
        gpus_per_node: usize,
        n_cpu_nodes: usize,
    ) -> ClusterSpec {
        assert!(gpus_per_node <= crate::frag::MAX_GPUS);
        ClusterSpec {
            zones: 0,
            topology: None,
            pools: vec![
                NodePool {
                    count: n_mig_nodes,
                    vcpus: 128.0,
                    mem: 786_432.0,
                    gpu_model: Some(GpuModel::G3),
                    gpus_per_node,
                    mig: true,
                    labels: Vec::new(),
                },
                NodePool {
                    count: n_cpu_nodes,
                    vcpus: 94.0,
                    mem: 262_144.0,
                    gpu_model: None,
                    gpus_per_node: 0,
                    mig: false,
                    labels: Vec::new(),
                },
            ],
        }
    }

    /// A heterogeneous MIG fleet: `n_a100_nodes` A100-class nodes (G3
    /// power profile, 7-slice lattice, 128 vCPUs / 768 GiB) plus
    /// `n_a30_nodes` A30-class nodes (4-slice lattice, 96 vCPUs /
    /// 384 GiB), every GPU MIG-enabled with its model's lattice
    /// ([`crate::cluster::mig::MigLattice::for_gpu`]), plus optional
    /// CPU-only nodes.
    pub fn mig_het_cluster(
        n_a100_nodes: usize,
        n_a30_nodes: usize,
        gpus_per_node: usize,
        n_cpu_nodes: usize,
    ) -> ClusterSpec {
        assert!(gpus_per_node <= crate::frag::MAX_GPUS);
        ClusterSpec {
            zones: 0,
            topology: None,
            pools: vec![
                NodePool {
                    count: n_a100_nodes,
                    vcpus: 128.0,
                    mem: 786_432.0,
                    gpu_model: Some(GpuModel::G3),
                    gpus_per_node,
                    mig: true,
                    labels: Vec::new(),
                },
                NodePool {
                    count: n_a30_nodes,
                    vcpus: 96.0,
                    mem: 393_216.0,
                    gpu_model: Some(GpuModel::A30),
                    gpus_per_node,
                    mig: true,
                    labels: Vec::new(),
                },
                NodePool {
                    count: n_cpu_nodes,
                    vcpus: 94.0,
                    mem: 262_144.0,
                    gpu_model: None,
                    gpus_per_node: 0,
                    mig: false,
                    labels: Vec::new(),
                },
            ],
        }
    }

    /// Total nodes described.
    pub fn total_nodes(&self) -> usize {
        self.pools.iter().map(|p| p.count).sum()
    }

    /// Total GPUs described.
    pub fn total_gpus(&self) -> usize {
        self.pools.iter().map(|p| p.count * p.gpus_per_node).sum()
    }

    /// Total vCPUs described.
    pub fn total_vcpus(&self) -> f64 {
        self.pools.iter().map(|p| p.count as f64 * p.vcpus).sum()
    }

    /// Per-model GPU counts (Table II check).
    pub fn gpus_by_model(&self) -> Vec<(GpuModel, usize)> {
        GpuModel::ALL
            .iter()
            .map(|&m| {
                let count = self
                    .pools
                    .iter()
                    .filter(|p| p.gpu_model == Some(m))
                    .map(|p| p.count * p.gpus_per_node)
                    .sum();
                (m, count)
            })
            .collect()
    }

    /// Materialize the datacenter (node ids are assigned pool-by-pool).
    pub fn build(&self) -> Datacenter {
        let mut nodes = Vec::with_capacity(self.total_nodes());
        for pool in &self.pools {
            for _ in 0..pool.count {
                let id = nodes.len();
                let mut node = Node::new(
                    id,
                    CpuModel::XeonE5_2682V4,
                    pool.gpu_model,
                    pool.vcpus,
                    pool.mem,
                    pool.gpus_per_node,
                );
                if pool.mig {
                    node.enable_mig();
                }
                node.labels = pool.labels.clone();
                if self.zones > 0 {
                    node.labels.push(("zone".to_string(), format!("z{}", id % self.zones)));
                }
                nodes.push(node);
            }
        }
        let mut dc = Datacenter::new(nodes);
        if let Some(topology) = self.topology {
            dc.topology = topology;
        }
        dc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_totals_match_section_vb() {
        let spec = ClusterSpec::paper_default();
        assert_eq!(spec.total_nodes(), 1213);
        assert_eq!(spec.total_gpus(), 6212);
        assert_eq!(spec.total_vcpus(), 107_018.0);
        let cpu_only: usize =
            spec.pools.iter().filter(|p| p.gpu_model.is_none()).map(|p| p.count).sum();
        assert_eq!(cpu_only, 310);
    }

    #[test]
    fn paper_gpu_counts_match_table2() {
        let spec = ClusterSpec::paper_default();
        let by_model = spec.gpus_by_model();
        let expect = [
            (GpuModel::V100M16, 195),
            (GpuModel::V100M32, 204),
            (GpuModel::P100, 265),
            (GpuModel::T4, 842),
            (GpuModel::A10, 2),
            (GpuModel::G2, 4392),
            (GpuModel::G3, 312),
        ];
        assert_eq!(by_model, expect);
    }

    #[test]
    fn build_materializes_all_nodes() {
        let dc = ClusterSpec::paper_default().build();
        assert_eq!(dc.nodes.len(), 1213);
        assert_eq!(dc.total_gpus(), 6212);
        assert!((dc.total_vcpus() - 107_018.0).abs() < 1e-9);
        // ids are dense
        for (i, n) in dc.nodes.iter().enumerate() {
            assert_eq!(n.id, i);
        }
    }

    #[test]
    fn g2_g3_node_shapes_match_paper() {
        let dc = ClusterSpec::paper_default().build();
        let g2 = dc.nodes.iter().find(|n| n.gpu_model == Some(GpuModel::G2)).unwrap();
        assert_eq!(g2.vcpus, 96.0);
        assert_eq!(g2.mem, 393_216.0);
        assert_eq!(g2.gpu_alloc.len(), 8);
        let g3 = dc.nodes.iter().find(|n| n.gpu_model == Some(GpuModel::G3)).unwrap();
        assert_eq!(g3.vcpus, 128.0);
        assert_eq!(g3.mem, 786_432.0);
    }

    #[test]
    fn scaled_cluster_preserves_mix() {
        let spec = ClusterSpec::paper_scaled(0.1);
        assert!(spec.total_nodes() >= 100 && spec.total_nodes() <= 160);
        // every model still present
        for (_, count) in spec.gpus_by_model() {
            assert!(count > 0);
        }
    }

    #[test]
    fn tiny_builds() {
        let dc = ClusterSpec::tiny(2, 4, 1).build();
        assert_eq!(dc.nodes.len(), 3);
        assert_eq!(dc.total_gpus(), 8);
    }

    #[test]
    fn zone_labels_round_robin() {
        let dc = ClusterSpec::tiny(4, 2, 0).with_zones(2).build();
        assert!(dc.nodes[0].has_label("zone", "z0"));
        assert!(dc.nodes[1].has_label("zone", "z1"));
        assert!(dc.nodes[2].has_label("zone", "z0"));
        assert_eq!(dc.nodes_with_label("zone", "z0"), 2);
        assert_eq!(dc.nodes_with_label("zone", "z1"), 2);
        assert_eq!(dc.nodes_with_label("zone", "z9"), 0);
        // Pool labels propagate too.
        let mut spec = ClusterSpec::tiny(1, 2, 0);
        spec.pools[0].labels.push(("tenant".to_string(), "acme".to_string()));
        let dc = spec.build();
        assert!(dc.nodes[0].has_label("tenant", "acme"));
    }

    #[test]
    fn with_topology_overrides_build_defaults() {
        let dc = ClusterSpec::tiny(2, 2, 0).build();
        assert_eq!(dc.topology, Topology::default());
        let custom = Topology { nvlink_gbps: 900.0, fabric_gbps: 200.0, interzone_gbps: 50.0 };
        let dc = ClusterSpec::tiny(2, 2, 0).with_topology(custom).build();
        assert_eq!(dc.topology, custom);
    }

    #[test]
    fn mig_het_cluster_builds_both_lattices() {
        use crate::cluster::mig::MigLattice;
        let spec = ClusterSpec::mig_het_cluster(3, 2, 4, 1);
        assert_eq!(spec.total_nodes(), 6);
        assert_eq!(spec.total_gpus(), 20);
        let dc = spec.build();
        let lattices: Vec<_> = dc
            .nodes
            .iter()
            .filter_map(|n| n.mig.as_ref().map(|m| (n.gpu_model.unwrap(), m[0].lattice)))
            .collect();
        assert_eq!(lattices.iter().filter(|(_, l)| *l == MigLattice::A100).count(), 3);
        assert_eq!(lattices.iter().filter(|(_, l)| *l == MigLattice::A30).count(), 2);
        for (model, lat) in lattices {
            assert_eq!(lat, MigLattice::for_gpu(model));
        }
    }

    #[test]
    fn mig_cluster_builds_partitioned_nodes() {
        let spec = ClusterSpec::mig_cluster(4, 8, 2);
        assert_eq!(spec.total_nodes(), 6);
        assert_eq!(spec.total_gpus(), 32);
        let dc = spec.build();
        let mig_nodes = dc.nodes.iter().filter(|n| n.mig.is_some()).count();
        assert_eq!(mig_nodes, 4);
        for n in &dc.nodes {
            if let Some(migs) = &n.mig {
                assert_eq!(n.gpu_model, Some(GpuModel::G3));
                assert_eq!(migs.len(), 8);
                assert!(migs.iter().all(|m| m.mask == 0));
            }
        }
    }
}
