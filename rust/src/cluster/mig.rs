//! MIG (Multi-Instance GPU) partitioning: per-model slice lattices
//! ([`MigLattice`]), per-GPU partition state, and slice-level
//! fragmentation accounting.
//!
//! NVIDIA ships different partition lattices per GPU model. The crate
//! models the two canonical ones:
//!
//! **A100 — 7 compute slices** (`MigLattice::A100`):
//!
//! | profile | slices | legal starts (preferred order) |
//! |---------|--------|--------------------------------|
//! | 1g      | 1      | 0, 1, 2, 3, 4, 5, 6            |
//! | 2g      | 2      | 0, 2, 4                        |
//! | 3g      | 3      | 4, 0                           |
//! | 4g      | 4      | 0                              |
//! | 7g      | 7      | 0                              |
//!
//! **A30 — 4 compute slices** (`MigLattice::A30`):
//!
//! | profile | slices | legal starts (preferred order) |
//! |---------|--------|--------------------------------|
//! | a30-1g  | 1      | 0, 1, 2, 3                     |
//! | a30-2g  | 2      | 0, 2                           |
//! | a30-4g  | 4      | 0                              |
//!
//! A MIG *instance* occupies a contiguous run of slices and may only
//! begin at the profile's architecturally legal start offsets (the
//! partition placement tree of the MIG spec). The A100 3g profile
//! prefers start 4 so that a lone 3g instance keeps the 0–3 window
//! available for a later 4g — the same heuristic nvidia-smi applies.
//! Any set of non-overlapping legally-placed instances is a valid
//! partition; co-residency constraints (e.g. "4g+4g is illegal",
//! "3g+3g is the largest pair") all fall out of the start lattice.
//! A profile is bound to its lattice: an `a30-2g` demand can only run
//! on an A30-partitioned GPU, a `3g` only on an A100-partitioned one.
//!
//! Slice-level fragmentation generalizes the FGD rule (see
//! [`crate::frag`]): a free slice is *fragmented for profile `p`* iff no
//! legal free placement of `p` could consume it ([`frag_slices`]). On an
//! A100 with slice 1 occupied, a 4g can never run (start 0 blocked), so
//! all six free slices are 4g-fragments; a 2g can still land at starts
//! 2 and 4, leaving only slices 0 and 6 as 2g-fragments.
//!
//! The greedy repack planner ([`MigGpu::repack_plan`]) re-places the
//! resident instances first-fit-decreasing to open a legal start for an
//! incoming profile — the primitive behind the online repartitioner in
//! [`crate::sched::policies::mig`]. [`MigGpu::frag_ratio`] condenses a
//! GPU's lattice fragmentation into one scalar (free slices unusable by
//! the widest still-fitting profile ÷ free slices) — the trigger signal
//! of the proactive, threshold-driven repartitioning mode. Slice counts
//! are preserved by repacks, so cluster-level allocation caches and
//! GRAR are unaffected.

use std::fmt;

use crate::cluster::types::GpuModel;

/// Number of distinct MIG profiles across all lattices (dense
/// per-profile table size; see [`MigProfile::index`]).
pub const N_PROFILES: usize = 8;

/// A partition-lattice model: the slice count and profile set of one
/// MIG-capable GPU generation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MigLattice {
    /// A100-class: 7 compute slices, profiles 1g/2g/3g/4g/7g.
    #[default]
    A100,
    /// A30-class: 4 compute slices, profiles a30-1g/a30-2g/a30-4g.
    A30,
}

impl MigLattice {
    /// All shipped lattices.
    pub const ALL: [MigLattice; 2] = [MigLattice::A100, MigLattice::A30];

    /// Compute slices exposed by a GPU of this lattice.
    pub fn slices(self) -> u8 {
        match self {
            MigLattice::A100 => 7,
            MigLattice::A30 => 4,
        }
    }

    /// Bitmask of all slices.
    pub fn full_mask(self) -> u8 {
        (1u8 << self.slices()) - 1
    }

    /// The lattice's profile set, ascending by slice count.
    pub fn profiles(self) -> &'static [MigProfile] {
        match self {
            MigLattice::A100 => &[
                MigProfile::P1g,
                MigProfile::P2g,
                MigProfile::P3g,
                MigProfile::P4g,
                MigProfile::P7g,
            ],
            MigLattice::A30 => {
                &[MigProfile::A30P1g, MigProfile::A30P2g, MigProfile::A30P4g]
            }
        }
    }

    /// Widest profile whose slice count fits into `free` slices.
    pub fn widest_fitting(self, free: u8) -> Option<MigProfile> {
        self.profiles().iter().rev().copied().find(|p| p.slices() <= free)
    }

    /// The lattice a GPU model's MIG mode exposes (A30 → the 4-slice
    /// lattice; every other MIG-capable model is A100-style).
    pub fn for_gpu(model: GpuModel) -> MigLattice {
        match model {
            GpuModel::A30 => MigLattice::A30,
            _ => MigLattice::A100,
        }
    }

    /// Stable small integer id (dense per-lattice tables).
    pub fn index(self) -> usize {
        match self {
            MigLattice::A100 => 0,
            MigLattice::A30 => 1,
        }
    }
}

impl fmt::Display for MigLattice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            MigLattice::A100 => "A100-7g",
            MigLattice::A30 => "A30-4g",
        })
    }
}

/// MIG profiles (compute-slice widths), across both lattices. A profile
/// pins its lattice: `units()` and legality are defined per model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MigProfile {
    /// A100: 1 slice (1g.5gb-class).
    P1g,
    /// A100: 2 slices (2g.10gb-class).
    P2g,
    /// A100: 3 slices (3g.20gb-class).
    P3g,
    /// A100: 4 slices (4g.20gb-class).
    P4g,
    /// A100: 7 slices — the whole GPU as one instance (7g.40gb-class).
    P7g,
    /// A30: 1 slice (1g.6gb-class).
    A30P1g,
    /// A30: 2 slices (2g.12gb-class).
    A30P2g,
    /// A30: 4 slices — the whole A30 as one instance (4g.24gb-class).
    A30P4g,
}

impl MigProfile {
    /// All profiles of all lattices (A100 first, then A30), each group
    /// ascending by slice count.
    pub const ALL: [MigProfile; N_PROFILES] = [
        MigProfile::P1g,
        MigProfile::P2g,
        MigProfile::P3g,
        MigProfile::P4g,
        MigProfile::P7g,
        MigProfile::A30P1g,
        MigProfile::A30P2g,
        MigProfile::A30P4g,
    ];

    /// The lattice this profile belongs to.
    pub fn lattice(self) -> MigLattice {
        match self {
            MigProfile::P1g
            | MigProfile::P2g
            | MigProfile::P3g
            | MigProfile::P4g
            | MigProfile::P7g => MigLattice::A100,
            MigProfile::A30P1g | MigProfile::A30P2g | MigProfile::A30P4g => MigLattice::A30,
        }
    }

    /// Compute slices the profile occupies.
    pub fn slices(self) -> u8 {
        match self {
            MigProfile::P1g | MigProfile::A30P1g => 1,
            MigProfile::P2g | MigProfile::A30P2g => 2,
            MigProfile::P3g => 3,
            MigProfile::P4g | MigProfile::A30P4g => 4,
            MigProfile::P7g => 7,
        }
    }

    /// Legal start offsets, in preferred (packing-friendly) order.
    pub fn legal_starts(self) -> &'static [u8] {
        match self {
            MigProfile::P1g => &[0, 1, 2, 3, 4, 5, 6],
            MigProfile::P2g => &[0, 2, 4],
            MigProfile::P3g => &[4, 0],
            MigProfile::P4g => &[0],
            MigProfile::P7g => &[0],
            MigProfile::A30P1g => &[0, 1, 2, 3],
            MigProfile::A30P2g => &[0, 2],
            MigProfile::A30P4g => &[0],
        }
    }

    /// GPU resource units (fraction of one GPU of the profile's model):
    /// `slices / lattice slices`.
    pub fn units(self) -> f64 {
        self.slices() as f64 / self.lattice().slices() as f64
    }

    /// True for the whole-GPU profile of a lattice (7g on A100, a30-4g
    /// on A30).
    pub fn is_full_gpu(self) -> bool {
        self.slices() == self.lattice().slices()
    }

    /// Stable small integer id (dense per-profile tables of width
    /// [`N_PROFILES`]).
    pub fn index(self) -> usize {
        MigProfile::ALL.iter().position(|&p| p == self).unwrap()
    }

    /// Inverse of [`Self::index`].
    pub fn from_index(i: usize) -> Option<MigProfile> {
        MigProfile::ALL.get(i).copied()
    }

    /// Parse a profile name (`1g`…`7g` for A100; `a30-1g`, `a30-2g`,
    /// `a30-4g` for A30).
    pub fn parse(s: &str) -> Option<MigProfile> {
        match s.to_ascii_lowercase().as_str() {
            "1g" => Some(MigProfile::P1g),
            "2g" => Some(MigProfile::P2g),
            "3g" => Some(MigProfile::P3g),
            "4g" => Some(MigProfile::P4g),
            "7g" => Some(MigProfile::P7g),
            "a30-1g" => Some(MigProfile::A30P1g),
            "a30-2g" => Some(MigProfile::A30P2g),
            "a30-4g" => Some(MigProfile::A30P4g),
            _ => None,
        }
    }
}

impl fmt::Display for MigProfile {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            MigProfile::P1g => "1g",
            MigProfile::P2g => "2g",
            MigProfile::P3g => "3g",
            MigProfile::P4g => "4g",
            MigProfile::P7g => "7g",
            MigProfile::A30P1g => "a30-1g",
            MigProfile::A30P2g => "a30-2g",
            MigProfile::A30P4g => "a30-4g",
        };
        f.write_str(s)
    }
}

/// Slice-occupancy window of `(profile, start)` as a bitmask.
pub fn window_mask(profile: MigProfile, start: u8) -> u8 {
    (((1u16 << profile.slices()) - 1) as u8) << start
}

/// First free legal start for `profile` on an occupancy `mask`, in the
/// profile's preferred order; `None` when no placement is legal. The
/// mask must belong to a GPU of the profile's lattice.
pub fn first_fit_start(mask: u8, profile: MigProfile) -> Option<u8> {
    profile
        .legal_starts()
        .iter()
        .copied()
        .find(|&s| mask & window_mask(profile, s) == 0)
}

/// Free slices on `mask` that **no** legal free placement of `profile`
/// could consume — the slice-level FGD fragment count (in slices). The
/// mask must belong to a GPU of the profile's lattice.
pub fn frag_slices(mask: u8, profile: MigProfile) -> u8 {
    let free = !mask & profile.lattice().full_mask();
    if free == 0 {
        return 0;
    }
    let mut cover = 0u8;
    for &s in profile.legal_starts() {
        let w = window_mask(profile, s);
        if mask & w == 0 {
            cover |= w;
        }
    }
    (free & !cover).count_ones() as u8
}

/// One placed MIG instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MigInstance {
    pub profile: MigProfile,
    pub start: u8,
}

/// Per-GPU partition state: the lattice model, the occupancy bitmask,
/// and the resident instance list (instances of equal profile are
/// fungible).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MigGpu {
    /// The partition lattice this GPU exposes.
    pub lattice: MigLattice,
    /// Occupied-slice bitmask (bit `i` ⇔ slice `i` in use).
    pub mask: u8,
    /// Resident instances; `mask` is always their window union.
    pub instances: Vec<MigInstance>,
}

/// A planned re-placement: `(instance index, new start)` for every
/// resident instance (unchanged entries included), plus the total
/// number of slices that would move.
pub type RepackPlan = (Vec<(usize, u8)>, u32);

impl MigGpu {
    /// Fresh, unpartitioned A100-lattice GPU.
    pub fn new() -> MigGpu {
        MigGpu::with_lattice(MigLattice::A100)
    }

    /// Fresh, unpartitioned GPU of the given lattice.
    pub fn with_lattice(lattice: MigLattice) -> MigGpu {
        MigGpu { lattice, mask: 0, instances: Vec::new() }
    }

    /// Total slices of this GPU's lattice.
    pub fn total_slices(&self) -> u8 {
        self.lattice.slices()
    }

    /// Occupied slices.
    pub fn used_slices(&self) -> u8 {
        self.mask.count_ones() as u8
    }

    /// Free slices.
    pub fn free_slices(&self) -> u8 {
        self.total_slices() - self.used_slices()
    }

    /// Allocated fraction of the GPU (`used / lattice slices`) — the
    /// value mirrored into [`crate::cluster::node::Node::gpu_alloc`].
    pub fn alloc_fraction(&self) -> f64 {
        self.used_slices() as f64 / self.total_slices() as f64
    }

    /// First free legal start for `profile` (preferred order); `None`
    /// when the profile belongs to another lattice.
    pub fn can_place(&self, profile: MigProfile) -> Option<u8> {
        if profile.lattice() != self.lattice {
            return None;
        }
        first_fit_start(self.mask, profile)
    }

    /// All free legal starts for `profile`, preferred order (empty for
    /// foreign-lattice profiles).
    pub fn free_starts(&self, profile: MigProfile) -> Vec<u8> {
        if profile.lattice() != self.lattice {
            return Vec::new();
        }
        profile
            .legal_starts()
            .iter()
            .copied()
            .filter(|&s| self.mask & window_mask(profile, s) == 0)
            .collect()
    }

    /// Place an instance; returns `false` (state untouched) when the
    /// profile belongs to another lattice, the start is illegal or the
    /// window overlaps.
    pub fn place(&mut self, profile: MigProfile, start: u8) -> bool {
        if profile.lattice() != self.lattice || !profile.legal_starts().contains(&start) {
            return false;
        }
        let w = window_mask(profile, start);
        if self.mask & w != 0 {
            return false;
        }
        self.mask |= w;
        self.instances.push(MigInstance { profile, start });
        true
    }

    /// Release an instance of `profile`. With `start = Some(s)` an
    /// exact `(profile, s)` instance is required; with `None` any
    /// instance of the profile is released (instances of equal profile
    /// are fungible — this is what keeps releases correct after a
    /// repack moved instances to new starts). Returns `false` when no
    /// matching instance exists.
    pub fn release(&mut self, profile: MigProfile, start: Option<u8>) -> bool {
        let idx = self
            .instances
            .iter()
            .position(|i| i.profile == profile && (start.is_none() || start == Some(i.start)));
        match idx {
            Some(i) => {
                let inst = self.instances.swap_remove(i);
                self.mask &= !window_mask(inst.profile, inst.start);
                true
            }
            None => false,
        }
    }

    /// Slice-fragmentation ratio of this GPU: the share of its free
    /// slices that no legal free placement of the *widest profile that
    /// could still fit* (by raw free capacity) can consume. 0 on empty
    /// and full GPUs; 1 when the free capacity exists but the widest
    /// candidate profile is fully locked out of it. This is the trigger
    /// signal of the proactive repartitioner
    /// ([`crate::sched::policies::mig::RepartitionConfig::frag_threshold`]).
    pub fn frag_ratio(&self) -> f64 {
        let free = self.free_slices();
        if free == 0 {
            return 0.0;
        }
        match self.lattice.widest_fitting(free) {
            Some(p) => frag_slices(self.mask, p) as f64 / free as f64,
            None => 0.0,
        }
    }

    /// Plan a repack that opens a legal start for `profile` without
    /// changing which instances are resident: re-place `profile` plus
    /// all residents first-fit-decreasing on an empty lattice (the A100
    /// 3g prefers start 4, so `{3g,2g,2g}`-style sets pack). Returns
    /// `None` when the profile belongs to another lattice or cannot fit
    /// even after repacking (or the greedy order fails);
    /// `Some((plan, 0))` when it already fits.
    pub fn repack_plan(&self, profile: MigProfile) -> Option<RepackPlan> {
        if profile.lattice() != self.lattice || self.free_slices() < profile.slices() {
            return None;
        }
        if self.can_place(profile).is_some() {
            return Some((
                self.instances.iter().enumerate().map(|(i, inst)| (i, inst.start)).collect(),
                0,
            ));
        }
        // Items: the incoming profile (marker usize::MAX) + residents,
        // sorted by descending slice count (stable — incoming first
        // among equals).
        let mut items: Vec<(usize, MigProfile)> = vec![(usize::MAX, profile)];
        items.extend(self.instances.iter().enumerate().map(|(i, inst)| (i, inst.profile)));
        items.sort_by(|a, b| b.1.slices().cmp(&a.1.slices()));
        let mut mask = 0u8;
        let mut plan: Vec<(usize, u8)> = Vec::with_capacity(self.instances.len());
        for (idx, p) in items {
            let s = first_fit_start(mask, p)?;
            mask |= window_mask(p, s);
            if idx != usize::MAX {
                plan.push((idx, s));
            }
        }
        let moved: u32 = plan
            .iter()
            .filter(|&&(i, s)| self.instances[i].start != s)
            .map(|&(i, _)| self.instances[i].profile.slices() as u32)
            .sum();
        Some((plan, moved))
    }

    /// Apply a plan from [`Self::repack_plan`] (same instance set).
    pub fn apply_repack(&mut self, plan: &[(usize, u8)]) {
        for &(i, s) in plan {
            self.instances[i].start = s;
        }
        self.mask = self
            .instances
            .iter()
            .fold(0u8, |m, inst| m | window_mask(inst.profile, inst.start));
        debug_assert_eq!(self.instances.len(), plan.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_table() {
        let widths: Vec<u8> = MigProfile::ALL.iter().map(|p| p.slices()).collect();
        assert_eq!(widths, vec![1, 2, 3, 4, 7, 1, 2, 4]);
        for p in MigProfile::ALL {
            assert_eq!(MigProfile::from_index(p.index()), Some(p));
            assert_eq!(MigProfile::parse(&p.to_string()), Some(p));
            // Every legal start keeps the window inside the lattice.
            for &s in p.legal_starts() {
                assert!(s + p.slices() <= p.lattice().slices(), "{p} @ {s} overflows");
            }
        }
        assert_eq!(MigProfile::parse("5g"), None);
        assert_eq!(MigProfile::parse("a30-3g"), None);
        assert!((MigProfile::P7g.units() - 1.0).abs() < 1e-12);
        assert!((MigProfile::A30P4g.units() - 1.0).abs() < 1e-12);
        assert!((MigProfile::A30P2g.units() - 0.5).abs() < 1e-12);
        assert!(MigProfile::P7g.is_full_gpu());
        assert!(MigProfile::A30P4g.is_full_gpu());
        assert!(!MigProfile::P4g.is_full_gpu());
    }

    #[test]
    fn lattice_tables() {
        assert_eq!(MigLattice::A100.slices(), 7);
        assert_eq!(MigLattice::A30.slices(), 4);
        assert_eq!(MigLattice::A100.full_mask(), 0b111_1111);
        assert_eq!(MigLattice::A30.full_mask(), 0b1111);
        for lat in MigLattice::ALL {
            for p in lat.profiles() {
                assert_eq!(p.lattice(), lat);
            }
        }
        assert_eq!(MigLattice::A100.widest_fitting(7), Some(MigProfile::P7g));
        assert_eq!(MigLattice::A100.widest_fitting(6), Some(MigProfile::P4g));
        assert_eq!(MigLattice::A100.widest_fitting(0), None);
        assert_eq!(MigLattice::A30.widest_fitting(3), Some(MigProfile::A30P2g));
        assert_eq!(MigLattice::for_gpu(GpuModel::A30), MigLattice::A30);
        assert_eq!(MigLattice::for_gpu(GpuModel::G3), MigLattice::A100);
    }

    #[test]
    fn window_masks() {
        assert_eq!(window_mask(MigProfile::P1g, 6), 0b100_0000);
        assert_eq!(window_mask(MigProfile::P2g, 2), 0b000_1100);
        assert_eq!(window_mask(MigProfile::P4g, 0), 0b000_1111);
        assert_eq!(window_mask(MigProfile::P7g, 0), MigLattice::A100.full_mask());
        assert_eq!(window_mask(MigProfile::A30P2g, 2), 0b1100);
        assert_eq!(window_mask(MigProfile::A30P4g, 0), MigLattice::A30.full_mask());
    }

    #[test]
    fn lattice_legality() {
        let mut g = MigGpu::new();
        // 4g+4g illegal (both need start 0).
        assert!(g.place(MigProfile::P4g, 0));
        assert_eq!(g.can_place(MigProfile::P4g), None);
        // 4g+3g legal (3g at 4).
        assert_eq!(g.can_place(MigProfile::P3g), Some(4));
        assert!(g.place(MigProfile::P3g, 4));
        assert_eq!(g.free_slices(), 0);
        // Illegal starts rejected without state change.
        let before = g.clone();
        assert!(!g.place(MigProfile::P2g, 1)); // 1 is not a 2g start
        assert!(!g.place(MigProfile::P1g, 0)); // occupied
        assert_eq!(g, before);
    }

    #[test]
    fn a30_lattice_legality() {
        let mut g = MigGpu::with_lattice(MigLattice::A30);
        // a30-2g + a30-2g fill the GPU; a third is illegal.
        assert_eq!(g.can_place(MigProfile::A30P2g), Some(0));
        assert!(g.place(MigProfile::A30P2g, 0));
        assert_eq!(g.can_place(MigProfile::A30P2g), Some(2));
        assert!(g.place(MigProfile::A30P2g, 2));
        assert_eq!(g.free_slices(), 0);
        assert_eq!(g.can_place(MigProfile::A30P1g), None);
        assert!((g.alloc_fraction() - 1.0).abs() < 1e-12);
        // Foreign-lattice profiles are rejected outright.
        let mut g = MigGpu::with_lattice(MigLattice::A30);
        assert_eq!(g.can_place(MigProfile::P2g), None);
        assert!(!g.place(MigProfile::P2g, 0));
        assert!(g.free_starts(MigProfile::P1g).is_empty());
        assert!(g.repack_plan(MigProfile::P1g).is_none());
        let mut a100 = MigGpu::new();
        assert!(!a100.place(MigProfile::A30P1g, 0));
    }

    #[test]
    fn every_greedy_fill_stays_within_lattice() {
        // Exhaustively place profiles in every short sequence over each
        // lattice's profile set; the mask can never exceed the lattice
        // and used+free is invariant.
        for lat in MigLattice::ALL {
            let profiles = lat.profiles();
            let k = profiles.len();
            for a in 0..k {
                for b in 0..k {
                    for c in 0..k {
                        for d in 0..k {
                            let mut g = MigGpu::with_lattice(lat);
                            let mut placed = Vec::new();
                            for idx in [a, b, c, d] {
                                let p = profiles[idx];
                                if let Some(s) = g.can_place(p) {
                                    assert!(g.place(p, s));
                                    placed.push((p, s));
                                }
                            }
                            let total: u8 = placed.iter().map(|(p, _)| p.slices()).sum();
                            assert!(total <= lat.slices());
                            assert_eq!(g.used_slices(), total);
                            assert_eq!(g.used_slices() + g.free_slices(), lat.slices());
                            // Round-trip: release everything -> empty GPU.
                            for (p, s) in placed {
                                assert!(g.release(p, Some(s)));
                            }
                            assert_eq!(g, MigGpu::with_lattice(lat));
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn lone_3g_prefers_high_start() {
        let mut g = MigGpu::new();
        assert_eq!(g.can_place(MigProfile::P3g), Some(4));
        g.place(MigProfile::P3g, 4);
        // ...which keeps the 4g window open.
        assert_eq!(g.can_place(MigProfile::P4g), Some(0));
    }

    #[test]
    fn frag_slices_examples() {
        // Slice 1 occupied: 4g can never run -> all 6 free slices are
        // 4g-fragments; 2g can still use starts 2 and 4 -> slices 0 and
        // 6 are 2g-fragments; 1g covers everything free.
        let mask = 0b000_0010u8;
        assert_eq!(frag_slices(mask, MigProfile::P4g), 6);
        assert_eq!(frag_slices(mask, MigProfile::P2g), 2);
        assert_eq!(frag_slices(mask, MigProfile::P1g), 0);
        // Empty GPU: 4g placements cover only slices 0-3 -> 4,5,6 are
        // structural 4g-fragments; 7g covers all.
        assert_eq!(frag_slices(0, MigProfile::P4g), 3);
        assert_eq!(frag_slices(0, MigProfile::P7g), 0);
        // Full GPU: nothing free, nothing fragmented.
        assert_eq!(frag_slices(MigLattice::A100.full_mask(), MigProfile::P1g), 0);
        // A30: slice 1 occupied -> a30-2g can only use start 2, so
        // slice 0 is a fragment; a30-4g is locked out entirely.
        assert_eq!(frag_slices(0b0010, MigProfile::A30P2g), 1);
        assert_eq!(frag_slices(0b0010, MigProfile::A30P4g), 3);
        assert_eq!(frag_slices(0b0000, MigProfile::A30P4g), 0);
    }

    #[test]
    fn frag_ratio_tracks_lattice_damage() {
        // Empty GPU: no fragmentation.
        assert_eq!(MigGpu::new().frag_ratio(), 0.0);
        // 1g at slice 0: the widest fitting profile (4g over 6 free
        // slices) is fully locked out -> ratio 1.
        let mut g = MigGpu::new();
        g.place(MigProfile::P1g, 0);
        assert!((g.frag_ratio() - 1.0).abs() < 1e-12);
        // Repacking toward the widest fitting profile moves the 1g high
        // and repairs it (the proactive repartitioner's move).
        let widest = g.lattice.widest_fitting(g.free_slices()).unwrap();
        assert_eq!(widest, MigProfile::P4g);
        let (plan, moved) = g.repack_plan(widest).unwrap();
        assert!(moved > 0);
        g.apply_repack(&plan);
        assert!(g.frag_ratio() < 1.0 - 1e-12);
        assert_eq!(g.can_place(MigProfile::P4g), Some(0));
        // Full GPU: no free slices, no fragmentation.
        let mut g = MigGpu::new();
        g.place(MigProfile::P7g, 0);
        assert_eq!(g.frag_ratio(), 0.0);
        // A30 checkerboard {1g@1}: a30-2g only fits at start 2 ->
        // slice 0 fragments; ratio = 1/3.
        let mut g = MigGpu::with_lattice(MigLattice::A30);
        g.place(MigProfile::A30P1g, 1);
        assert!((g.frag_ratio() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn release_by_profile_is_fungible() {
        let mut g = MigGpu::new();
        g.place(MigProfile::P1g, 0);
        g.place(MigProfile::P1g, 3);
        // Exact-start release of a stale start falls back at the caller
        // level; by-profile release frees one of the two.
        assert!(g.release(MigProfile::P1g, None));
        assert_eq!(g.used_slices(), 1);
        assert!(!g.release(MigProfile::P2g, None));
    }

    #[test]
    fn repack_opens_room_for_ffd_hard_case() {
        // {3g@0, 2g@4} blocks a second 2g (starts 0,2 overlap 3g@0; 4
        // taken) even though 2 slices are free.
        let mut g = MigGpu::new();
        assert!(g.place(MigProfile::P3g, 0));
        assert!(g.place(MigProfile::P2g, 4));
        assert_eq!(g.can_place(MigProfile::P2g), None);
        assert_eq!(g.free_slices(), 2);
        let (plan, moved) = g.repack_plan(MigProfile::P2g).expect("repack must fit 3g+2g+2g");
        assert!(moved > 0);
        g.apply_repack(&plan);
        assert_eq!(g.used_slices(), 5); // same residents, new starts
        let s = g.can_place(MigProfile::P2g).expect("2g start open after repack");
        assert!(g.place(MigProfile::P2g, s));
        assert_eq!(g.free_slices(), 0);
    }

    #[test]
    fn a30_repack_opens_room() {
        // {1g@1} blocks an a30-2g at start 0; repack packs the 1g away.
        let mut g = MigGpu::with_lattice(MigLattice::A30);
        assert!(g.place(MigProfile::A30P1g, 1));
        assert!(g.place(MigProfile::A30P1g, 3));
        assert_eq!(g.can_place(MigProfile::A30P2g), None);
        let (plan, moved) = g.repack_plan(MigProfile::A30P2g).expect("2 slices free");
        assert!(moved > 0);
        g.apply_repack(&plan);
        let s = g.can_place(MigProfile::A30P2g).expect("open after repack");
        assert!(g.place(MigProfile::A30P2g, s));
        assert_eq!(g.free_slices(), 0);
    }

    #[test]
    fn repack_noop_when_already_placeable() {
        let mut g = MigGpu::new();
        g.place(MigProfile::P1g, 0);
        let (plan, moved) = g.repack_plan(MigProfile::P2g).unwrap();
        assert_eq!(moved, 0);
        g.apply_repack(&plan);
        assert_eq!(g.can_place(MigProfile::P2g), Some(2));
    }

    #[test]
    fn repack_refuses_when_capacity_short() {
        let mut g = MigGpu::new();
        g.place(MigProfile::P4g, 0);
        assert!(g.repack_plan(MigProfile::P4g).is_none());
        assert!(g.repack_plan(MigProfile::P7g).is_none());
    }
}
