//! The simulated GPU datacenter: hardware types, node state, the
//! per-model MIG partition lattices ([`mig`]: A100-7g, A30-4g), the
//! cluster-inventory generator reproducing the paper's Table II, and
//! the aggregate [`datacenter::Datacenter`] state.

pub mod datacenter;
pub mod inventory;
pub mod mig;
pub mod node;
pub mod types;

pub use datacenter::{Datacenter, Topology};
pub use inventory::ClusterSpec;
pub use mig::{MigGpu, MigInstance, MigLattice, MigProfile};
pub use node::{Node, Placement, PowerState, ResourceView};
pub use types::{CpuModel, GpuModel};
