//! The simulated GPU datacenter: hardware types, node state, the
//! A100-style MIG partition lattice ([`mig`]), the cluster-inventory
//! generator reproducing the paper's Table II, and the aggregate
//! [`datacenter::Datacenter`] state.

pub mod datacenter;
pub mod inventory;
pub mod mig;
pub mod node;
pub mod types;

pub use datacenter::Datacenter;
pub use inventory::ClusterSpec;
pub use mig::{MigGpu, MigInstance, MigProfile};
pub use node::{Node, Placement, ResourceView};
pub use types::{CpuModel, GpuModel};
