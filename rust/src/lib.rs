//! # repro — Power- and Fragmentation-aware Online Scheduling for GPU Datacenters
//!
//! A full reproduction of the PWR + FGD scheduling system (Lettich et al.,
//! CS.DC 2024): a simulated heterogeneous GPU datacenter, a
//! Kubernetes-scheduling-framework analog with filter/score/normalize
//! plugins, the paper's power model (Eq. 1–3), the FGD fragmentation
//! metric of Weng et al. (USENIX ATC'23), seven scheduling policies, a
//! Monte-Carlo workload-inflation simulator over Alibaba-trace-calibrated
//! workloads, and an experiment harness regenerating every table and
//! figure of the paper.
//!
//! The numeric hot-spot — batched node scoring (power delta + expected
//! fragmentation delta over all nodes × GPUs × task classes) — is also
//! implemented as a JAX/Pallas program AOT-lowered to HLO and executed
//! from Rust through the PJRT C API (see [`runtime`] and
//! `python/compile/`; requires the `xla` cargo feature). The native
//! scorer in [`sched`] and the XLA scorer must agree; integration tests
//! assert this.
//!
//! Beyond the paper, the crate models **MIG partitioning**
//! (`docs/mig.md`): per-model slice lattices (A100-7g and A30-4g) on
//! [`cluster::mig`], slice-granular demands
//! ([`tasks::GpuDemand::Mig`]) and placements, slice-level
//! fragmentation ([`frag`]) and per-slice power attribution
//! ([`power`]), MIG-aware policies with an online repartitioner —
//! reactive on placement failure, proactive past a configurable
//! frag-ratio threshold — ([`sched::policies::mig`]), heterogeneous
//! A100+A30 fleets, and the `ext-mig` / `ext-mig-het` experiments.
//!
//! Scheduling is organized as **profiles over named extension points**
//! (`docs/scheduler.md`): a [`sched::SchedulerProfile`] names entries
//! in string-keyed registries for `score` (N weighted plugins), `bind`,
//! `weightModulator` (load-adaptive α generalized; per-lattice α),
//! `postPlace`/`postFail` hooks (the MIG repartitioner) and `filter`
//! — declarative feasibility ([`sched::filter`]): the paper's Filter
//! phase decomposed into plugins plus [`tasks::TaskConstraints`]
//! (GPU-model sets, node selectors, tenant affinity/anti-affinity,
//! spread caps) with a k8s-style PreFilter early-exit — with a textual
//! DSL behind `--policy` —
//! `score(pwr=0.5,fgd=0.3,dotprod=0.2)|bind(weighted:0.5)|mod(loadalpha:0.9:0.0)|filter(resources,gpumodel,labels:zone=z0)` —
//! and every legacy policy name kept as sugar with a byte-identical
//! label (`ext-profiles` sweeps composite profiles against PWR⊕FGD;
//! `ext-filters` sweeps PWR⊕FGD under 0/25/50% constrained traces).
//!
//! ## Layer map
//! * L3 (this crate): coordinator, simulator, the profile-driven
//!   scheduling framework ([`sched::framework`], [`sched::profile`],
//!   [`sched::filter`], `docs/scheduler.md`) with its policy zoo
//!   (incl. the MIG family + repartitioner hook), experiments.
//! * L2 (`python/compile/model.py`): the scoring graph, lowered once to
//!   `artifacts/*.hlo.txt`.
//! * L1 (`python/compile/kernels/score.py`): the Pallas scoring kernel.
//!
//! ## Quickstart
//! ```no_run
//! use repro::cluster::inventory::ClusterSpec;
//! use repro::sched::{Scheduler, PolicyKind};
//! use repro::trace::TraceSpec;
//! use repro::sim::Simulation;
//!
//! let dc = ClusterSpec::paper_default().build();
//! let trace = TraceSpec::default_trace().synthesize(42);
//! let sched = Scheduler::from_policy(PolicyKind::PwrFgd { alpha: 0.1 });
//! let mut sim = Simulation::new(dc, sched, &trace, 42);
//! let out = sim.run_inflation(1.02);
//! println!("final EOPC = {:.1} kW", out.final_eopc() / 1e3);
//! ```

pub mod cluster;
pub mod coordinator;
pub mod experiments;
pub mod frag;
pub mod metrics;
pub mod power;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod tasks;
pub mod trace;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
