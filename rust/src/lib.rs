//! # repro — Power- and Fragmentation-aware Online Scheduling for GPU Datacenters
//!
//! A full reproduction of the PWR + FGD scheduling system (Lettich et al.,
//! CS.DC 2024): a simulated heterogeneous GPU datacenter, a
//! Kubernetes-scheduling-framework analog with filter/score/normalize
//! plugins, the paper's power model (Eq. 1–3), the FGD fragmentation
//! metric of Weng et al. (USENIX ATC'23), seven scheduling policies, a
//! Monte-Carlo workload-inflation simulator over Alibaba-trace-calibrated
//! workloads, and an experiment harness regenerating every table and
//! figure of the paper.
//!
//! The numeric hot-spot — batched node scoring (power delta + expected
//! fragmentation delta over all nodes × GPUs × task classes) — is also
//! implemented as a JAX/Pallas program AOT-lowered to HLO and executed
//! from Rust through the PJRT C API (see [`runtime`] and
//! `python/compile/`; requires the `xla` cargo feature). The native
//! scorer in [`sched`] and the XLA scorer must agree; integration tests
//! assert this.
//!
//! Beyond the paper, the crate models **MIG partitioning**
//! (`docs/mig.md`), organizes scheduling as **profiles over named
//! extension points** with a `--policy` DSL (`docs/scheduler.md`),
//! and adds the **DRS node sleep/wake subsystem** with a documented,
//! state-aware power layer (`docs/power.md`): [`cluster::PowerState`]
//! on every node, the [`sched::drs`] hook/filter/score plugins,
//! `diurnal-<amp>` traces and the `ext-drs` experiment. The
//! **observability layer** (`docs/observability.md`) adds a
//! scheduler-owned metrics registry with a drift-proof catalog,
//! opt-in JSONL decision tracing (`--trace-decisions`, `repro
//! explain`), and phase-latency histograms served by the coordinator
//! in Prometheus text format — see [`obs`].
//!
//! ## Layer map
//!
//! See **`docs/architecture.md`** for the one-page layer map (trace →
//! cluster → sched framework → sim loops → experiments/CLI) and the
//! full extension-point registry table (`repro list-plugins` prints it
//! live). The XLA side: L2 (`python/compile/model.py`) lowers the
//! scoring graph to `artifacts/*.hlo.txt`; L1
//! (`python/compile/kernels/score.py`) is the Pallas scoring kernel.
//!
//! ## Quickstart
//! ```no_run
//! use repro::cluster::inventory::ClusterSpec;
//! use repro::sched::{Scheduler, PolicyKind};
//! use repro::trace::TraceSpec;
//! use repro::sim::Simulation;
//!
//! let dc = ClusterSpec::paper_default().build();
//! let trace = TraceSpec::default_trace().synthesize(42);
//! let sched = Scheduler::from_policy(PolicyKind::PwrFgd { alpha: 0.1 });
//! let mut sim = Simulation::new(dc, sched, &trace, 42);
//! let out = sim.run_inflation(1.02);
//! println!("final EOPC = {:.1} kW", out.final_eopc() / 1e3);
//! ```

pub mod analysis;
pub mod cluster;
pub mod coordinator;
pub mod experiments;
pub mod frag;
pub mod metrics;
pub mod obs;
pub mod power;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod tasks;
pub mod trace;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
