//! The paper's power model (§II, Eq. 1–3).
//!
//! * Eq. 1 — CPU power of a node: sockets with *any* allocation draw
//!   `p_max`, fully idle sockets draw `p_idle`:
//!   `p_CPU(n) = p_max·⌈Ra/(2·ncores)⌉ + p_idle·⌊R/(2·ncores)⌋`.
//! * Eq. 2 — GPU power: a GPU with any allocation draws `p_max`
//!   (GPU-sharing tasks may opportunistically use the whole device),
//!   otherwise `p_idle`.
//! * Eq. 3 — datacenter power: `P = Σ_n p(n)` — the EOPC metric.
//!
//! **MIG extension (Eq. 2-MIG)**: unlike an opportunistically-shared
//! GPU, a MIG instance is hard-partitioned and cannot burst beyond its
//! slices, so a partitioned GPU draws power *per slice* rather than
//! jumping to `p_max` on first touch (Lipe et al.'s per-slice energy
//! accounting, arXiv 2606.25082). With `a` of the lattice's `S` slices
//! active on a powered GPU (A100: `S = 7`; A30: `S = 4`):
//!
//! `p = p_idle + (p_max − p_idle) · (a + κ·(S − a)) / S`,
//!
//! where `κ =` [`MIG_IDLE_SLICE_FACTOR`] attributes the residual draw
//! of idle-but-powered slices (uncore/HBM overhead). A fully-idle
//! unpartitioned-or-empty GPU draws `p_idle`; a fully-occupied one
//! draws `p_max`. Packing slices onto already-powered GPUs is therefore
//! strictly cheaper than waking a fresh GPU — the signal the MIG-aware
//! PWR policies descend, on both lattices.
//!
//! **DRS extension (node power states)**: with a `drs` hook attached
//! (`rust/src/sched/drs.rs`, `docs/power.md`) a node may be `Asleep`,
//! in which case it draws [`NODE_STANDBY_W`] instead of its Eq. 1/2
//! idle wattage. `Draining` and `Waking` nodes are fully powered (they
//! are idle hardware waiting out a deadline / booting), so they report
//! plain `p_node`. Without a DRS hook every node is `Active` and all
//! sums below are bit-identical to the pre-DRS model
//! (`rust/tests/drs_equivalence.rs`).

use crate::cluster::mig::MigLattice;
use crate::cluster::node::{Node, PowerState, ResourceView};
use crate::cluster::types::GpuModel;
use crate::cluster::Datacenter;

/// κ in Eq. 2-MIG: share of a slice's dynamic power an idle slice on a
/// powered GPU still draws.
pub const MIG_IDLE_SLICE_FACTOR: f64 = 0.2;

/// Standby wattage of an [`PowerState::Asleep`] node: the BMC + NIC
/// stay powered for wake-on-LAN (single-digit watts in the DRS
/// literature, Hu et al.). Far below any node's idle draw, so sleeping
/// an idle node is always a strict saving.
pub const NODE_STANDBY_W: f64 = 5.0;

/// Eq. 2-MIG: power of one MIG-partitioned GPU of `lattice` with
/// occupancy `mask`.
pub fn p_gpu_mig(model: GpuModel, mask: u8, lattice: MigLattice) -> f64 {
    let active = mask.count_ones() as f64;
    if active == 0.0 {
        return model.p_idle();
    }
    let total = lattice.slices() as f64;
    let idle = total - active;
    model.p_idle()
        + (model.p_max() - model.p_idle()) * (active + MIG_IDLE_SLICE_FACTOR * idle) / total
}

/// CPU power of a node view (Eq. 1), in Watt.
pub fn p_cpu<V: ResourceView + ?Sized>(v: &V) -> f64 {
    let model = v.cpu_model();
    let per_socket = model.vcpus_per_socket(); // 2 · ncores
    let used_sockets = (v.cpu_alloc() / per_socket).ceil();
    let idle_sockets = (v.cpu_free() / per_socket).floor();
    model.p_max() * used_sockets + model.p_idle() * idle_sockets
}

/// GPU power of a node view (Eq. 2; Eq. 2-MIG per partitioned GPU), in
/// Watt.
pub fn p_gpu<V: ResourceView + ?Sized>(v: &V) -> f64 {
    let Some(model) = v.gpu_model() else { return 0.0 };
    let (p_max, p_idle) = (model.p_max(), model.p_idle());
    let lattice = v.mig_lattice();
    let mut total = 0.0;
    for g in 0..v.n_gpus() {
        total += match (v.mig_mask_of(g), lattice) {
            (Some(mask), Some(lat)) => p_gpu_mig(model, mask, lat),
            _ => {
                if v.gpu_alloc_of(g) > 0.0 {
                    p_max
                } else {
                    p_idle
                }
            }
        };
    }
    total
}

/// Node power `p(n) = p_CPU(n) + p_GPU(n)`.
pub fn p_node<V: ResourceView + ?Sized>(v: &V) -> f64 {
    p_cpu(v) + p_gpu(v)
}

/// Observed node power under the DRS power-state machine: an `Asleep`
/// node draws [`NODE_STANDBY_W`] instead of Eq. 1/2; every other state
/// is fully powered and reports [`p_node`]. A node contributes exactly
/// one of the two — standby energy is never double-counted on top of
/// idle watts (property-pinned by `rust/tests/drs_equivalence.rs`).
pub fn p_node_observed(n: &Node) -> f64 {
    match n.power_state {
        PowerState::Asleep => NODE_STANDBY_W,
        _ => p_node(n),
    }
}

/// Datacenter power split into (CPU watts, GPU watts). Eq. 3 is the
/// sum. Asleep nodes contribute their standby watts on the CPU side
/// (the residual draw is motherboard/BMC, not GPU).
pub fn p_datacenter_split(dc: &Datacenter) -> (f64, f64) {
    let mut cpu = 0.0;
    let mut gpu = 0.0;
    for n in &dc.nodes {
        if n.power_state == PowerState::Asleep {
            cpu += NODE_STANDBY_W;
        } else {
            cpu += p_cpu(n);
            gpu += p_gpu(n);
        }
    }
    (cpu, gpu)
}

/// [`p_datacenter_split`] plus per-lattice node-power sums (indexed by
/// [`MigLattice::index`]; zero on non-MIG fleets) in one node walk —
/// the shared sampler primitive of the inflation and churn loops, so
/// heterogeneous-fleet breakdowns cannot drift between them.
pub fn p_datacenter_by_lattice(dc: &Datacenter) -> (f64, f64, [f64; 2]) {
    let mut cpu = 0.0;
    let mut gpu = 0.0;
    let mut by_lattice = [0.0f64; 2];
    for n in &dc.nodes {
        let (pc, pg) = if n.power_state == PowerState::Asleep {
            (NODE_STANDBY_W, 0.0)
        } else {
            (p_cpu(n), p_gpu(n))
        };
        cpu += pc;
        gpu += pg;
        if let Some(lat) = n.mig_lattice() {
            by_lattice[lat.index()] += pc + pg;
        }
    }
    (cpu, gpu, by_lattice)
}

/// Datacenter power (Eq. 3) — the EOPC metric, in Watt.
pub fn p_datacenter(dc: &Datacenter) -> f64 {
    let (c, g) = p_datacenter_split(dc);
    c + g
}

/// EOPC under a hypothetical *overlay* estimate of DRS (Dynamic
/// Resource Sleep, Hu et al. [7]): fully-idle nodes are assumed
/// powered down (0 W) instead of drawing idle power, regardless of
/// their actual [`PowerState`]. This is the what-if upper bound the
/// `ext-steady` experiment reports; the *realized* DRS subsystem
/// (`rust/src/sched/drs.rs` + the state-aware sums above) instead
/// sleeps nodes through an explicit lifecycle with timeouts, wake
/// latency and standby watts — see `docs/power.md`.
pub fn p_datacenter_drs(dc: &Datacenter) -> f64 {
    dc.nodes.iter().filter(|n| n.is_active()).map(|n| p_node(n)).sum()
}

/// Lower bound of the cluster's power (everything idle). Useful as the
/// baseline the Fig. 1 curve starts from.
pub fn p_datacenter_idle(dc: &Datacenter) -> f64 {
    dc.nodes
        .iter()
        .map(|n| {
            let cpu = n.cpu_model.p_idle() * (n.vcpus / n.cpu_model.vcpus_per_socket()).floor();
            let gpu = n
                .gpu_model
                .map(|m| m.p_idle() * n.gpu_alloc.len() as f64)
                .unwrap_or(0.0);
            cpu + gpu
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::{Node, Placement};
    use crate::cluster::types::{CpuModel, GpuModel};
    use crate::cluster::ClusterSpec;
    use crate::tasks::{GpuDemand, Task};

    fn g2_node() -> Node {
        Node::new(0, CpuModel::XeonE5_2682V4, Some(GpuModel::G2), 96.0, 393_216.0, 8)
    }

    #[test]
    fn idle_node_power() {
        let n = g2_node();
        // 96 vCPU = 3 sockets idle -> 3·15 W; 8 idle G2 -> 8·30 W.
        assert_eq!(p_cpu(&n), 45.0);
        assert_eq!(p_gpu(&n), 240.0);
        assert_eq!(p_node(&n), 285.0);
    }

    #[test]
    fn eq1_ceil_floor_behaviour() {
        let mut n = g2_node();
        // 1 vCPU used: ceil(1/32)=1 socket maxed, floor(95/32)=2 idle.
        n.allocate(&Task::new(1, 1.0, 0.0, GpuDemand::Zero), &Placement::CpuOnly);
        assert_eq!(p_cpu(&n), 120.0 + 2.0 * 15.0);
        // 32 vCPU used: 1 maxed, 2 idle (boundary: exactly one socket).
        n.allocate(&Task::new(2, 31.0, 0.0, GpuDemand::Zero), &Placement::CpuOnly);
        assert_eq!(p_cpu(&n), 120.0 + 2.0 * 15.0);
        // 33 vCPU used: 2 maxed, floor(63/32)=1 idle.
        n.allocate(&Task::new(3, 1.0, 0.0, GpuDemand::Zero), &Placement::CpuOnly);
        assert_eq!(p_cpu(&n), 240.0 + 15.0);
        // Fully allocated: 3 maxed, 0 idle.
        n.allocate(&Task::new(4, 63.0, 0.0, GpuDemand::Zero), &Placement::CpuOnly);
        assert_eq!(p_cpu(&n), 360.0);
    }

    #[test]
    fn eq2_partial_gpu_draws_max() {
        let mut n = g2_node();
        let t = Task::new(1, 1.0, 0.0, GpuDemand::Frac(0.1));
        n.allocate(&t, &Placement::Shared { gpu: 0 });
        // One GPU at p_max (opportunistic full use), 7 idle.
        assert_eq!(p_gpu(&n), 150.0 + 7.0 * 30.0);
    }

    #[test]
    fn eq2_whole_gpus() {
        let mut n = g2_node();
        let t = Task::new(1, 1.0, 0.0, GpuDemand::Whole(8));
        let p = n.candidate_placements(&t).pop().unwrap();
        n.allocate(&t, &p);
        assert_eq!(p_gpu(&n), 8.0 * 150.0);
    }

    #[test]
    fn hypothetical_delta_matches_commit() {
        let mut n = g2_node();
        let t = Task::new(1, 8.0, 1024.0, GpuDemand::Frac(0.5));
        let p = Placement::Shared { gpu: 4 };
        let before = p_node(&n);
        let delta = {
            let h = n.hypothetical(&t, &p);
            p_node(&h) - before
        };
        n.allocate(&t, &p);
        assert!((p_node(&n) - before - delta).abs() < 1e-9);
        // Δ = one GPU idle->max (120) + one socket idle->max (105).
        assert_eq!(delta, 120.0 + 105.0);
    }

    #[test]
    fn cpu_only_node_has_no_gpu_power() {
        let n = Node::new(0, CpuModel::XeonE5_2682V4, None, 94.0, 262_144.0, 0);
        assert_eq!(p_gpu(&n), 0.0);
        // 94 vCPU -> floor(94/32)=2 idle sockets... (2.9375 sockets: the
        // fractional socket is neither ceil'd as used nor floor'd idle).
        assert_eq!(p_cpu(&n), 30.0);
    }

    #[test]
    fn mig_power_is_slice_attributable() {
        use crate::cluster::mig::{window_mask, MigLattice, MigProfile};
        let a100 = MigLattice::A100;
        // Empty partitioned GPU: idle power only.
        assert_eq!(p_gpu_mig(GpuModel::G3, 0, a100), 50.0);
        // Fully occupied (7g): exactly p_max.
        assert!((p_gpu_mig(GpuModel::G3, 0x7F, a100) - 400.0).abs() < 1e-9);
        // 2 active slices: idle + range·(2 + 0.2·5)/7.
        let mask = window_mask(MigProfile::P2g, 0);
        let expect = 50.0 + 350.0 * (2.0 + 0.2 * 5.0) / 7.0;
        assert!((p_gpu_mig(GpuModel::G3, mask, a100) - expect).abs() < 1e-9);
        // Monotone in active slices, bounded by [p_idle, p_max].
        let mut prev = 50.0;
        for a in 1..=7u8 {
            let m = ((1u16 << a) - 1) as u8;
            let p = p_gpu_mig(GpuModel::G3, m, a100);
            assert!(p > prev && p <= 400.0 + 1e-9, "a={a}: {p}");
            prev = p;
        }
        // A30 lattice: 4 slices, 30 W idle, 165 W TDP.
        let a30 = MigLattice::A30;
        assert_eq!(p_gpu_mig(GpuModel::A30, 0, a30), 30.0);
        assert!((p_gpu_mig(GpuModel::A30, 0b1111, a30) - 165.0).abs() < 1e-9);
        // 1 active slice of 4: idle + range·(1 + 0.2·3)/4.
        let expect = 30.0 + 135.0 * (1.0 + 0.2 * 3.0) / 4.0;
        assert!((p_gpu_mig(GpuModel::A30, 0b0001, a30) - expect).abs() < 1e-9);
    }

    #[test]
    fn a30_mig_node_power_via_view() {
        use crate::cluster::mig::MigProfile;
        use crate::tasks::GpuDemand;
        let mut n = Node::new(0, CpuModel::XeonE5_2682V4, Some(GpuModel::A30), 96.0, 393_216.0, 2);
        n.enable_mig();
        // Idle A30 MIG node: both GPUs at p_idle.
        assert_eq!(p_gpu(&n), 60.0);
        let t = Task::new(1, 2.0, 512.0, GpuDemand::Mig(MigProfile::A30P2g));
        let p = Placement::MigSlice { gpu: 0, start: 0 };
        let before = p_node(&n);
        let delta = {
            let h = n.hypothetical(&t, &p);
            p_node(&h) - before
        };
        n.allocate(&t, &p);
        assert!((p_node(&n) - before - delta).abs() < 1e-9);
        // GPU Δ: 135·(2 + 0.2·2)/4 = 81 W; CPU Δ: one socket idle→max.
        assert!((delta - (135.0 * (2.0 + 0.4) / 4.0 + 105.0)).abs() < 1e-9);
    }

    #[test]
    fn mig_node_power_via_view_and_hypothetical() {
        use crate::cluster::mig::MigProfile;
        use crate::tasks::GpuDemand;
        let mut n = Node::new(0, CpuModel::XeonE5_2682V4, Some(GpuModel::G3), 128.0, 786_432.0, 2);
        n.enable_mig();
        // Idle MIG node: both GPUs at p_idle.
        assert_eq!(p_gpu(&n), 100.0);
        let t = Task::new(1, 4.0, 1024.0, GpuDemand::Mig(MigProfile::P3g));
        let p = Placement::MigSlice { gpu: 0, start: 4 };
        let before = p_node(&n);
        let delta = {
            let h = n.hypothetical(&t, &p);
            p_node(&h) - before
        };
        n.allocate(&t, &p);
        assert!((p_node(&n) - before - delta).abs() < 1e-9);
        // GPU Δ: 350·(3 + 0.2·4)/7 = 190 W; CPU Δ: one socket idle→max.
        assert!((delta - (350.0 * (3.0 + 0.8) / 7.0 + 105.0)).abs() < 1e-9);
        // Packing a second instance onto the powered GPU is cheaper
        // than waking the idle one.
        let t2 = Task::new(2, 1.0, 0.0, GpuDemand::Mig(MigProfile::P2g));
        let d_packed = {
            let h = n.hypothetical(&t2, &Placement::MigSlice { gpu: 0, start: 0 });
            p_node(&h) - p_node(&n)
        };
        let d_fresh = {
            let h = n.hypothetical(&t2, &Placement::MigSlice { gpu: 1, start: 0 });
            p_node(&h) - p_node(&n)
        };
        assert!(d_packed < d_fresh, "packed {d_packed} vs fresh {d_fresh}");
    }

    #[test]
    fn asleep_nodes_draw_standby_not_idle() {
        use crate::cluster::node::PowerState;
        let mut dc = ClusterSpec::tiny(2, 4, 0).build();
        let all_on = p_datacenter(&dc);
        let one_node = p_node(&dc.nodes[0]);
        assert_eq!(p_node_observed(&dc.nodes[0]), one_node);
        // Sleep node 0: it contributes NODE_STANDBY_W instead of its
        // idle watts — exactly once, on the CPU side of the split.
        dc.nodes[0].power_state = PowerState::Asleep;
        assert_eq!(p_node_observed(&dc.nodes[0]), NODE_STANDBY_W);
        let (cpu_w, gpu_w) = p_datacenter_split(&dc);
        assert!(
            (cpu_w + gpu_w - (all_on - one_node + NODE_STANDBY_W)).abs() < 1e-9,
            "split {cpu_w}+{gpu_w} vs expected"
        );
        let (c2, g2, _) = p_datacenter_by_lattice(&dc);
        assert_eq!(c2.to_bits(), cpu_w.to_bits());
        assert_eq!(g2.to_bits(), gpu_w.to_bits());
        // Draining / Waking nodes are fully powered.
        dc.nodes[0].power_state = PowerState::Draining;
        assert_eq!(p_node_observed(&dc.nodes[0]), one_node);
        dc.nodes[0].power_state = PowerState::Waking { ready_at: 7 };
        assert_eq!(p_node_observed(&dc.nodes[0]), one_node);
        dc.nodes[0].power_state = PowerState::Active;
        assert_eq!(p_datacenter(&dc).to_bits(), all_on.to_bits());
        // Standby sits strictly below every node's idle draw.
        assert!(NODE_STANDBY_W < p_datacenter_idle(&dc) / dc.nodes.len() as f64);
    }

    #[test]
    fn idle_cluster_eopc_magnitude() {
        // Fig. 1: FGD EOPC starts just above 200 kW on the empty cluster.
        let dc = ClusterSpec::paper_default().build();
        let (cpu_w, gpu_w) = p_datacenter_split(&dc);
        let total = cpu_w + gpu_w;
        assert!(total > 150_000.0 && total < 260_000.0, "idle EOPC = {total} W");
        assert_eq!(total, p_datacenter(&dc));
        assert_eq!(p_datacenter_idle(&dc), total);
    }

    #[test]
    fn full_cluster_eopc_magnitude() {
        // Fig. 1: EOPC peaks around 1.4 MW near saturation. Saturate
        // every node and check the ballpark.
        let mut dc = ClusterSpec::paper_default().build();
        for i in 0..dc.nodes.len() {
            let n = &dc.nodes[i];
            let gpus = n.gpu_alloc.len() as u32;
            let cpu = n.vcpus;
            let mem = 0.0;
            let t = if gpus > 0 {
                Task::new(i as u64, cpu, mem, GpuDemand::Whole(gpus))
            } else {
                Task::new(i as u64, cpu, mem, GpuDemand::Zero)
            };
            let p = dc.nodes[i].candidate_placements(&t).pop().unwrap();
            dc.allocate(&t, i, &p);
        }
        let total = p_datacenter(&dc);
        assert!(total > 1_100_000.0 && total < 1_700_000.0, "full EOPC = {total} W");
        // GPU share of power should sit in the paper's 72–76% band.
        let (_, gpu_w) = p_datacenter_split(&dc);
        let share = gpu_w / total;
        assert!(share > 0.65 && share < 0.85, "gpu share = {share}");
    }
}
