//! Task model: resource demand vectors `D_t`, constraint sets `C_t`
//! (§II), and the *target workload* `M` of task classes used by the FGD
//! fragmentation metric.

use crate::cluster::mig::MigProfile;
use crate::cluster::types::GpuModel;

/// GPU demand of a task: `D_t^GPU ∈ {0} ∪ (0,1) ∪ Z+` (§II), extended
/// with MIG slice profiles. A task may share one GPU, take whole GPUs,
/// *or* request one MIG instance — never a mix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GpuDemand {
    /// CPU-only task.
    Zero,
    /// Shares a single GPU, demanding this fraction in `(0, 1)`.
    Frac(f64),
    /// Exclusively uses this many whole GPUs.
    Whole(u32),
    /// One MIG instance of this profile on a MIG-partitioned GPU of the
    /// profile's lattice (slice-granular demand; `units = slices /
    /// lattice slices`).
    Mig(MigProfile),
}

impl GpuDemand {
    /// Construct from a raw request, validating the paper's domain.
    /// Non-finite, negative, fractional-above-one and >64 requests are
    /// all rejected (MIG demands are constructed from a profile, not
    /// from raw units — see [`GpuDemand::Mig`]).
    pub fn from_units(units: f64) -> Option<GpuDemand> {
        if units == 0.0 {
            Some(GpuDemand::Zero)
        } else if units > 0.0 && units < 1.0 {
            Some(GpuDemand::Frac(units))
        } else if units >= 1.0 && units.fract() == 0.0 && units <= 64.0 {
            Some(GpuDemand::Whole(units as u32))
        } else {
            None
        }
    }

    /// Total GPU resource units requested (fraction, whole count, or
    /// MIG slices / lattice slices).
    pub fn units(self) -> f64 {
        match self {
            GpuDemand::Zero => 0.0,
            GpuDemand::Frac(f) => f,
            GpuDemand::Whole(k) => k as f64,
            GpuDemand::Mig(p) => p.units(),
        }
    }

    /// True for any GPU-requesting task.
    pub fn is_gpu(self) -> bool {
        !matches!(self, GpuDemand::Zero)
    }

    /// Table-I bucket index: 0→`0`, 1→`(0,1)`, 2→`1`, 3→`2`, 4→`4`, 5→`8`
    /// (other whole counts fall into the nearest-larger bucket; the paper
    /// traces only contain {2,4,8}).
    pub fn bucket(self) -> usize {
        match self {
            GpuDemand::Zero => 0,
            GpuDemand::Frac(_) => 1,
            // Sub-GPU MIG instances behave like sharing tasks in the
            // Table-I marginals; the full-GPU profiles (7g, a30-4g)
            // like 1-GPU.
            GpuDemand::Mig(p) if !p.is_full_gpu() => 1,
            GpuDemand::Mig(_) => 2,
            GpuDemand::Whole(1) => 2,
            GpuDemand::Whole(2) => 3,
            GpuDemand::Whole(k) if k <= 4 => 4,
            GpuDemand::Whole(_) => 5,
        }
    }
}

/// Number of Table-I buckets.
pub const NUM_BUCKETS: usize = 6;

/// The parallelism split of a gang task (an LLM training/inference
/// job): `tp` GPUs per tensor-parallel group, `pp` pipeline stages,
/// `dp` data-parallel replicas. One *member* of the gang is one TP
/// group — `tp` whole GPUs that must share a node's NVLink domain —
/// so a gang places `pp × dp` members for `tp × pp × dp` GPUs total.
/// Carried on [`Task::gang`]; the demand vector of the carrying task
/// holds the *gang totals* (GPU = `Whole(total_gpus)`), so aggregate
/// accounting (GRAR, PreFilter capacity checks) needs no special case.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GangSpec {
    /// GPUs per tensor-parallel group (all on one node).
    pub tp: u32,
    /// Pipeline-parallel stages per replica.
    pub pp: u32,
    /// Data-parallel replicas.
    pub dp: u32,
}

impl GangSpec {
    /// Validated constructor: every degree ≥ 1 and the total GPU count
    /// within the demand domain (≤ 64, matching
    /// [`GpuDemand::from_units`]).
    pub fn new(tp: u32, pp: u32, dp: u32) -> Option<GangSpec> {
        let spec = GangSpec { tp, pp, dp };
        if tp >= 1 && pp >= 1 && dp >= 1 && spec.total_gpus() <= 64 {
            Some(spec)
        } else {
            None
        }
    }

    /// Members to place: one per (replica, stage) pair.
    pub fn n_members(self) -> u32 {
        self.pp * self.dp
    }

    /// Total GPUs across the gang.
    pub fn total_gpus(self) -> u32 {
        self.tp * self.pp * self.dp
    }

    /// Member `i`'s data-parallel replica index (members are laid out
    /// replica-major: `i = replica·pp + stage`).
    pub fn replica_of(self, member: u32) -> u32 {
        member / self.pp
    }

    /// Member `i`'s pipeline-stage index.
    pub fn stage_of(self, member: u32) -> u32 {
        member % self.pp
    }
}

/// Declarative feasibility constraints (`C_t` beyond the demand vector),
/// evaluated by the scheduler's `filter` extension point
/// ([`crate::sched::filter`]). Every field is optional; the default is
/// fully unconstrained. Multi-tenant GPU clouds need exactly this
/// vocabulary (Zambianco et al.): tenant isolation is anti-affinity on a
/// tenant class key, instance-type restrictions are GPU-model sets, and
/// blast-radius limits are per-node spread caps.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct TaskConstraints {
    /// Allowed GPU models — a *set*, generalizing the single
    /// [`Task::gpu_model`]. Empty = any model.
    pub gpu_models: Vec<GpuModel>,
    /// Required node labels: every `(key, value)` pair must be present
    /// on the node (k8s nodeSelector semantics).
    pub node_selector: Vec<(String, String)>,
    /// The task's own class key (tenant / team / job group). Registered
    /// on the hosting node while the task is resident; affinity rules of
    /// *other* tasks reference it.
    pub class_key: Option<String>,
    /// Anti-affinity: reject nodes currently hosting any task of these
    /// classes (tenant isolation: list every other tenant's key).
    pub anti_affinity: Vec<String>,
    /// Affinity: require a node currently hosting a task of at least one
    /// of these classes (k8s requiredDuringScheduling semantics).
    pub affinity: Vec<String>,
    /// Spread limit: at most this many resident tasks of
    /// [`Self::class_key`] per node.
    pub max_per_node: Option<u32>,
}

impl TaskConstraints {
    /// True when no constraint is set (the default).
    pub fn is_unconstrained(&self) -> bool {
        self.gpu_models.is_empty()
            && self.node_selector.is_empty()
            && self.class_key.is_none()
            && self.anti_affinity.is_empty()
            && self.affinity.is_empty()
            && self.max_per_node.is_none()
    }

    /// Deterministic content signature (FNV-1a over the debug form) —
    /// used by [`Workload::from_tasks`] so tasks differing only in
    /// constraints do not collapse into one class.
    pub fn signature(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in format!("{self:?}").bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        h
    }
}

/// A task submitted to the datacenter: demand vector `D_t` plus the
/// constraint set `C_t` — the legacy single GPU-model pin and the
/// declarative [`TaskConstraints`]. (The trace has no CPU-model
/// constraints — the cluster is CPU-homogeneous — so `C_t^CPU` is
/// omitted.)
#[derive(Clone, Debug, PartialEq)]
pub struct Task {
    /// Unique id assigned by the trace/sampler.
    pub id: u64,
    /// vCPUs requested (`D_t^CPU`, fractional allowed).
    pub cpu: f64,
    /// Memory requested in MiB (`D_t^MEM`).
    pub mem: f64,
    /// GPU demand (`D_t^GPU`).
    pub gpu: GpuDemand,
    /// If set, the task only runs on nodes with this GPU model
    /// (`C_t^GPU`; constrained-GPU traces). Kept alongside
    /// [`Task::constraints`] for the legacy traces and the XLA scorer's
    /// dense encoding.
    pub gpu_model: Option<GpuModel>,
    /// Declarative constraints (`None` = unconstrained; boxed so the
    /// common unconstrained task stays one pointer wide).
    pub constraints: Option<Box<TaskConstraints>>,
    /// Gang shape (`None` = ordinary single-node task). When set, the
    /// demand fields above hold the *gang totals* and placement goes
    /// through the all-or-nothing gang path
    /// ([`crate::sched::Scheduler::place_gang`]).
    pub gang: Option<GangSpec>,
    /// Tenant priority (higher = more important; 0 = best-effort, the
    /// default). Read by the fairness subsystem: the pending queue
    /// orders retries priority-first and the `preempt` postFail hook
    /// may evict strictly-lower-priority residents
    /// ([`crate::sched::fairness`]).
    pub priority: u8,
}

impl Task {
    /// Convenience constructor for tests and examples.
    pub fn new(id: u64, cpu: f64, mem: f64, gpu: GpuDemand) -> Task {
        Task { id, cpu, mem, gpu, gpu_model: None, constraints: None, gang: None, priority: 0 }
    }

    /// With a tenant priority (builder style).
    pub fn with_priority(mut self, priority: u8) -> Task {
        self.priority = priority;
        self
    }

    /// With a gang shape (builder style). The demand fields are
    /// reinterpreted as gang totals; callers normally build gang tasks
    /// via [`crate::sched::gang::gang_task`], which derives the totals
    /// from the spec.
    pub fn with_gang(mut self, spec: GangSpec) -> Task {
        self.gang = Some(spec);
        self
    }

    /// With a GPU-model constraint.
    pub fn constrained(mut self, model: GpuModel) -> Task {
        self.gpu_model = Some(model);
        self
    }

    /// With a declarative constraint set (builder style).
    pub fn with_constraints(mut self, c: TaskConstraints) -> Task {
        self.constraints = if c.is_unconstrained() { None } else { Some(Box::new(c)) };
        self
    }

    /// The declarative constraints, if any.
    pub fn constraint_set(&self) -> Option<&TaskConstraints> {
        self.constraints.as_deref()
    }
}

/// One class `m` of the target workload `M`: a representative demand and
/// its popularity `p_m` (empirical frequency in the trace).
#[derive(Clone, Debug, PartialEq)]
pub struct TaskClass {
    pub cpu: f64,
    pub mem: f64,
    pub gpu: GpuDemand,
    pub gpu_model: Option<GpuModel>,
    /// Popularity `p_m ∈ (0, 1]`; classes of a workload sum to 1.
    pub pop: f64,
}

impl TaskClass {
    /// View the class as a task (for feasibility checks). Declarative
    /// constraints are placement-state-dependent (affinity counts live
    /// on nodes), so the FGD metric evaluates classes constraint-free
    /// beyond the model pin.
    pub fn as_task(&self) -> Task {
        Task {
            id: u64::MAX,
            cpu: self.cpu,
            mem: self.mem,
            gpu: self.gpu,
            gpu_model: self.gpu_model,
            constraints: None,
            gang: None,
            priority: 0,
        }
    }
}

/// The target workload `M`: the class catalog the FGD metric averages
/// over, extracted from historical trace data.
///
/// Every construction stamps a process-unique `revision`; scheduler-side
/// caches (see `sched::framework`) key on it instead of on pointer
/// identity, which is immune to allocator address reuse (ABA). Clones
/// share their source's revision — identical content, still-valid cache.
#[derive(Clone, Debug)]
pub struct Workload {
    /// Private so every mutation path re-stamps `revision` — read via
    /// [`Workload::classes`], mutate via [`Workload::classes_mut`].
    classes: Vec<TaskClass>,
    revision: u64,
}

impl Default for Workload {
    fn default() -> Self {
        Workload::new(Vec::new())
    }
}

fn next_workload_revision() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT_REVISION: AtomicU64 = AtomicU64::new(1);
    NEXT_REVISION.fetch_add(1, Ordering::Relaxed)
}

impl Workload {
    /// Build a workload from an explicit class catalog, stamping a fresh
    /// revision.
    pub fn new(classes: Vec<TaskClass>) -> Workload {
        Workload { classes, revision: next_workload_revision() }
    }

    /// The class catalog `M`.
    pub fn classes(&self) -> &[TaskClass] {
        &self.classes
    }

    /// Mutable access to the catalog; re-stamps the revision so
    /// scheduler-side caches rebuild on the next decision.
    pub fn classes_mut(&mut self) -> &mut Vec<TaskClass> {
        self.revision = next_workload_revision();
        &mut self.classes
    }

    /// The identity stamp caches key on (unique per construction or
    /// [`Workload::classes_mut`] borrow; shared by clones).
    pub fn revision(&self) -> u64 {
        self.revision
    }

    /// Extract classes from a task list: tasks are grouped by their
    /// (rounded CPU, GPU-demand, constraint) signature and popularity is
    /// the group's frequency. This mirrors how FGD derives `M` from
    /// historical traces.
    pub fn from_tasks(tasks: &[Task]) -> Workload {
        use std::collections::BTreeMap;
        // Signature: (cpu in 0.25-vCPU steps, gpu demand in 1/64 units,
        // kind tag, constraint index, declarative-constraint hash). MIG
        // demands tag their profile so same-unit profiles of different
        // lattices (e.g. 7g vs a30-4g, both 1.0 units) stay distinct
        // classes — their feasibility differs per node. Constraint-free
        // tasks hash to 0, so legacy grouping is unchanged.
        let mut groups: BTreeMap<(u64, u64, u8, u8, u64, u32), (Task, usize)> = BTreeMap::new();
        for t in tasks {
            let sig = (
                (t.cpu * 4.0).round() as u64,
                (t.gpu.units() * 64.0).round() as u64,
                match t.gpu {
                    GpuDemand::Whole(_) => 1u8,
                    GpuDemand::Mig(p) => 2 + p.index() as u8,
                    _ => 0,
                },
                t.gpu_model.map(|m| m.index() as u8 + 1).unwrap_or(0),
                t.constraints.as_deref().map(TaskConstraints::signature).unwrap_or(0),
                // Gang shapes with equal totals but different splits
                // stay distinct classes (gang-free tasks tag 0).
                t.gang.map(|g| (g.tp << 16) | (g.pp << 8) | g.dp).unwrap_or(0),
            );
            groups.entry(sig).and_modify(|e| e.1 += 1).or_insert((t.clone(), 1));
        }
        let total = tasks.len().max(1) as f64;
        let classes = groups
            .into_values()
            .map(|(t, count)| TaskClass {
                cpu: t.cpu,
                mem: t.mem,
                gpu: t.gpu,
                gpu_model: t.gpu_model,
                pop: count as f64 / total,
            })
            .collect();
        Workload::new(classes)
    }

    /// Keep only the `k` most popular classes, renormalizing popularity.
    /// The XLA scorer uses a fixed class capacity; FGD's metric is
    /// dominated by the popular classes, so truncation is benign.
    pub fn top_k(&self, k: usize) -> Workload {
        let mut classes = self.classes.clone();
        classes.sort_by(|a, b| b.pop.partial_cmp(&a.pop).unwrap());
        classes.truncate(k);
        let total: f64 = classes.iter().map(|c| c.pop).sum();
        if total > 0.0 {
            for c in &mut classes {
                c.pop /= total;
            }
        }
        Workload::new(classes)
    }

    /// Sum of popularities (≈1 for a full extraction).
    pub fn total_pop(&self) -> f64 {
        self.classes.iter().map(|c| c.pop).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_demand_domain() {
        assert_eq!(GpuDemand::from_units(0.0), Some(GpuDemand::Zero));
        assert_eq!(GpuDemand::from_units(0.5), Some(GpuDemand::Frac(0.5)));
        assert_eq!(GpuDemand::from_units(2.0), Some(GpuDemand::Whole(2)));
        assert_eq!(GpuDemand::from_units(1.5), None);
        assert_eq!(GpuDemand::from_units(-1.0), None);
    }

    #[test]
    fn gpu_demand_edge_cases() {
        // Non-finite inputs are rejected, never panicking or truncating.
        assert_eq!(GpuDemand::from_units(f64::NAN), None);
        assert_eq!(GpuDemand::from_units(f64::INFINITY), None);
        assert_eq!(GpuDemand::from_units(f64::NEG_INFINITY), None);
        // Negative values, including -0.0's negative neighbours.
        assert_eq!(GpuDemand::from_units(-0.25), None);
        assert_eq!(GpuDemand::from_units(-f64::MIN_POSITIVE), None);
        // -0.0 == 0.0 in IEEE 754: accepted as CPU-only.
        assert_eq!(GpuDemand::from_units(-0.0), Some(GpuDemand::Zero));
        // Whole-GPU cap: 64 is the last accepted integer.
        assert_eq!(GpuDemand::from_units(64.0), Some(GpuDemand::Whole(64)));
        assert_eq!(GpuDemand::from_units(65.0), None);
        assert_eq!(GpuDemand::from_units(1e9), None);
        // 1.0 − ε stays fractional; exactly 1.0 is whole.
        let just_under = 1.0 - f64::EPSILON;
        assert_eq!(GpuDemand::from_units(just_under), Some(GpuDemand::Frac(just_under)));
        assert_eq!(GpuDemand::from_units(1.0), Some(GpuDemand::Whole(1)));
        // Tiny positive values are a (degenerate but valid) fraction.
        assert_eq!(
            GpuDemand::from_units(f64::MIN_POSITIVE),
            Some(GpuDemand::Frac(f64::MIN_POSITIVE))
        );
    }

    #[test]
    fn mig_demand_units_and_buckets() {
        use crate::cluster::mig::MigProfile;
        assert!((GpuDemand::Mig(MigProfile::P2g).units() - 2.0 / 7.0).abs() < 1e-12);
        assert_eq!(GpuDemand::Mig(MigProfile::P7g).units(), 1.0);
        assert!(GpuDemand::Mig(MigProfile::P1g).is_gpu());
        assert_eq!(GpuDemand::Mig(MigProfile::P1g).bucket(), 1);
        assert_eq!(GpuDemand::Mig(MigProfile::P4g).bucket(), 1);
        assert_eq!(GpuDemand::Mig(MigProfile::P7g).bucket(), 2);
        // A30 lattice: units are slices/4; the full-GPU a30-4g profile
        // lands in the 1-GPU bucket like 7g.
        assert!((GpuDemand::Mig(MigProfile::A30P2g).units() - 0.5).abs() < 1e-12);
        assert_eq!(GpuDemand::Mig(MigProfile::A30P1g).bucket(), 1);
        assert_eq!(GpuDemand::Mig(MigProfile::A30P4g).bucket(), 2);
    }

    #[test]
    fn workload_distinguishes_lattices() {
        // 7g (A100) and a30-4g (A30) both request 1.0 units but are
        // feasible on disjoint node sets — they must stay two classes.
        let tasks = vec![
            Task::new(0, 4.0, 1024.0, GpuDemand::Mig(MigProfile::P7g)),
            Task::new(1, 4.0, 1024.0, GpuDemand::Mig(MigProfile::A30P4g)),
        ];
        let w = Workload::from_tasks(&tasks);
        assert_eq!(w.classes.len(), 2);
    }

    #[test]
    fn workload_distinguishes_mig_from_frac() {
        use crate::cluster::mig::MigProfile;
        // A 1g instance (1/7 GPU) and a Frac of the same units must not
        // collapse into one class.
        let u = MigProfile::P1g.units();
        let tasks = vec![
            Task::new(0, 4.0, 1024.0, GpuDemand::Mig(MigProfile::P1g)),
            Task::new(1, 4.0, 1024.0, GpuDemand::Frac(u)),
        ];
        let w = Workload::from_tasks(&tasks);
        assert_eq!(w.classes.len(), 2);
    }

    #[test]
    fn units_roundtrip() {
        for u in [0.0, 0.25, 0.9, 1.0, 4.0, 8.0] {
            assert_eq!(GpuDemand::from_units(u).unwrap().units(), u);
        }
    }

    #[test]
    fn buckets_match_table1_layout() {
        assert_eq!(GpuDemand::Zero.bucket(), 0);
        assert_eq!(GpuDemand::Frac(0.3).bucket(), 1);
        assert_eq!(GpuDemand::Whole(1).bucket(), 2);
        assert_eq!(GpuDemand::Whole(2).bucket(), 3);
        assert_eq!(GpuDemand::Whole(4).bucket(), 4);
        assert_eq!(GpuDemand::Whole(8).bucket(), 5);
        assert_eq!(GpuDemand::Whole(3).bucket(), 4);
    }

    #[test]
    fn workload_extraction_groups_and_normalizes() {
        let tasks = vec![
            Task::new(0, 4.0, 1024.0, GpuDemand::Frac(0.5)),
            Task::new(1, 4.0, 1024.0, GpuDemand::Frac(0.5)),
            Task::new(2, 8.0, 2048.0, GpuDemand::Whole(1)),
        ];
        let w = Workload::from_tasks(&tasks);
        assert_eq!(w.classes.len(), 2);
        assert!((w.total_pop() - 1.0).abs() < 1e-12);
        let frac_class = w.classes.iter().find(|c| c.gpu == GpuDemand::Frac(0.5)).unwrap();
        assert!((frac_class.pop - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn workload_distinguishes_constraints() {
        let tasks = vec![
            Task::new(0, 4.0, 1024.0, GpuDemand::Whole(1)),
            Task::new(1, 4.0, 1024.0, GpuDemand::Whole(1)).constrained(GpuModel::T4),
        ];
        let w = Workload::from_tasks(&tasks);
        assert_eq!(w.classes.len(), 2);
    }

    #[test]
    fn workload_distinguishes_declarative_constraints() {
        let tenant = |k: &str| TaskConstraints {
            class_key: Some(k.to_string()),
            anti_affinity: vec!["other".to_string()],
            ..Default::default()
        };
        let tasks = vec![
            Task::new(0, 4.0, 1024.0, GpuDemand::Whole(1)),
            Task::new(1, 4.0, 1024.0, GpuDemand::Whole(1)).with_constraints(tenant("a")),
            Task::new(2, 4.0, 1024.0, GpuDemand::Whole(1)).with_constraints(tenant("b")),
            Task::new(3, 4.0, 1024.0, GpuDemand::Whole(1)).with_constraints(tenant("a")),
        ];
        let w = Workload::from_tasks(&tasks);
        // unconstrained + tenant-a + tenant-b = 3 classes.
        assert_eq!(w.classes.len(), 3);
    }

    #[test]
    fn empty_constraint_set_normalizes_to_none() {
        let t = Task::new(0, 1.0, 0.0, GpuDemand::Zero)
            .with_constraints(TaskConstraints::default());
        assert!(t.constraints.is_none());
        assert!(TaskConstraints::default().is_unconstrained());
        let c = TaskConstraints { max_per_node: Some(2), ..Default::default() };
        assert!(!c.is_unconstrained());
        // Signature is deterministic and content-keyed.
        assert_eq!(c.signature(), c.clone().signature());
        assert_ne!(c.signature(), TaskConstraints::default().signature());
    }

    #[test]
    fn gang_spec_domain_and_layout() {
        let g = GangSpec::new(2, 2, 2).unwrap();
        assert_eq!(g.n_members(), 4);
        assert_eq!(g.total_gpus(), 8);
        // Replica-major member layout: (replica, stage) pairs.
        assert_eq!((g.replica_of(0), g.stage_of(0)), (0, 0));
        assert_eq!((g.replica_of(1), g.stage_of(1)), (0, 1));
        assert_eq!((g.replica_of(2), g.stage_of(2)), (1, 0));
        assert_eq!((g.replica_of(3), g.stage_of(3)), (1, 1));
        // Domain: zero degrees and >64-GPU totals are rejected.
        assert!(GangSpec::new(0, 1, 1).is_none());
        assert!(GangSpec::new(8, 4, 4).is_none());
        assert!(GangSpec::new(8, 4, 2).is_some());
    }

    #[test]
    fn workload_distinguishes_gang_splits() {
        let shape = |tp, pp, dp| {
            Task::new(0, 8.0, 1024.0, GpuDemand::Whole(8))
                .with_gang(GangSpec::new(tp, pp, dp).unwrap())
        };
        let tasks = vec![
            shape(2, 2, 2),
            shape(4, 2, 1),
            Task::new(2, 8.0, 1024.0, GpuDemand::Whole(8)),
        ];
        let w = Workload::from_tasks(&tasks);
        assert_eq!(w.classes.len(), 3);
    }

    #[test]
    fn top_k_renormalizes() {
        let tasks = vec![
            Task::new(0, 1.0, 0.0, GpuDemand::Zero),
            Task::new(1, 2.0, 0.0, GpuDemand::Zero),
            Task::new(2, 2.0, 0.0, GpuDemand::Zero),
            Task::new(3, 3.0, 0.0, GpuDemand::Zero),
        ];
        let w = Workload::from_tasks(&tasks).top_k(2);
        assert_eq!(w.classes.len(), 2);
        assert!((w.total_pop() - 1.0).abs() < 1e-12);
    }
}
