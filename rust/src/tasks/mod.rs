//! Task model: resource demand vectors `D_t`, constraint sets `C_t`
//! (§II), and the *target workload* `M` of task classes used by the FGD
//! fragmentation metric.

use crate::cluster::types::GpuModel;

/// GPU demand of a task: `D_t^GPU ∈ {0} ∪ (0,1) ∪ Z+` (§II). A task may
/// share one GPU *or* take whole GPUs, never both.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GpuDemand {
    /// CPU-only task.
    Zero,
    /// Shares a single GPU, demanding this fraction in `(0, 1)`.
    Frac(f64),
    /// Exclusively uses this many whole GPUs.
    Whole(u32),
}

impl GpuDemand {
    /// Construct from a raw request, validating the paper's domain.
    pub fn from_units(units: f64) -> Option<GpuDemand> {
        if units == 0.0 {
            Some(GpuDemand::Zero)
        } else if units > 0.0 && units < 1.0 {
            Some(GpuDemand::Frac(units))
        } else if units >= 1.0 && units.fract() == 0.0 && units <= 64.0 {
            Some(GpuDemand::Whole(units as u32))
        } else {
            None
        }
    }

    /// Total GPU resource units requested (fraction or whole count).
    pub fn units(self) -> f64 {
        match self {
            GpuDemand::Zero => 0.0,
            GpuDemand::Frac(f) => f,
            GpuDemand::Whole(k) => k as f64,
        }
    }

    /// True for any GPU-requesting task.
    pub fn is_gpu(self) -> bool {
        !matches!(self, GpuDemand::Zero)
    }

    /// Table-I bucket index: 0→`0`, 1→`(0,1)`, 2→`1`, 3→`2`, 4→`4`, 5→`8`
    /// (other whole counts fall into the nearest-larger bucket; the paper
    /// traces only contain {2,4,8}).
    pub fn bucket(self) -> usize {
        match self {
            GpuDemand::Zero => 0,
            GpuDemand::Frac(_) => 1,
            GpuDemand::Whole(1) => 2,
            GpuDemand::Whole(2) => 3,
            GpuDemand::Whole(k) if k <= 4 => 4,
            GpuDemand::Whole(_) => 5,
        }
    }
}

/// Number of Table-I buckets.
pub const NUM_BUCKETS: usize = 6;

/// A task submitted to the datacenter: demand vector `D_t` plus the
/// optional GPU-model constraint from `C_t`. (The trace has no CPU-model
/// constraints — the cluster is CPU-homogeneous — so `C_t^CPU` is
/// omitted.)
#[derive(Clone, Debug, PartialEq)]
pub struct Task {
    /// Unique id assigned by the trace/sampler.
    pub id: u64,
    /// vCPUs requested (`D_t^CPU`, fractional allowed).
    pub cpu: f64,
    /// Memory requested in MiB (`D_t^MEM`).
    pub mem: f64,
    /// GPU demand (`D_t^GPU`).
    pub gpu: GpuDemand,
    /// If set, the task only runs on nodes with this GPU model
    /// (`C_t^GPU`; constrained-GPU traces).
    pub gpu_model: Option<GpuModel>,
}

impl Task {
    /// Convenience constructor for tests and examples.
    pub fn new(id: u64, cpu: f64, mem: f64, gpu: GpuDemand) -> Task {
        Task { id, cpu, mem, gpu, gpu_model: None }
    }

    /// With a GPU-model constraint.
    pub fn constrained(mut self, model: GpuModel) -> Task {
        self.gpu_model = Some(model);
        self
    }
}

/// One class `m` of the target workload `M`: a representative demand and
/// its popularity `p_m` (empirical frequency in the trace).
#[derive(Clone, Debug, PartialEq)]
pub struct TaskClass {
    pub cpu: f64,
    pub mem: f64,
    pub gpu: GpuDemand,
    pub gpu_model: Option<GpuModel>,
    /// Popularity `p_m ∈ (0, 1]`; classes of a workload sum to 1.
    pub pop: f64,
}

impl TaskClass {
    /// View the class as a task (for feasibility checks).
    pub fn as_task(&self) -> Task {
        Task {
            id: u64::MAX,
            cpu: self.cpu,
            mem: self.mem,
            gpu: self.gpu,
            gpu_model: self.gpu_model,
        }
    }
}

/// The target workload `M`: the class catalog the FGD metric averages
/// over, extracted from historical trace data.
#[derive(Clone, Debug, Default)]
pub struct Workload {
    pub classes: Vec<TaskClass>,
}

impl Workload {
    /// Extract classes from a task list: tasks are grouped by their
    /// (rounded CPU, GPU-demand, constraint) signature and popularity is
    /// the group's frequency. This mirrors how FGD derives `M` from
    /// historical traces.
    pub fn from_tasks(tasks: &[Task]) -> Workload {
        use std::collections::BTreeMap;
        // Signature: (cpu in 0.25-vCPU steps, gpu demand in 1/64 units,
        // whole-vs-frac tag, constraint index).
        let mut groups: BTreeMap<(u64, u64, u8, u8), (Task, usize)> = BTreeMap::new();
        for t in tasks {
            let sig = (
                (t.cpu * 4.0).round() as u64,
                (t.gpu.units() * 64.0).round() as u64,
                matches!(t.gpu, GpuDemand::Whole(_)) as u8,
                t.gpu_model.map(|m| m.index() as u8 + 1).unwrap_or(0),
            );
            groups.entry(sig).and_modify(|e| e.1 += 1).or_insert((t.clone(), 1));
        }
        let total = tasks.len().max(1) as f64;
        let classes = groups
            .into_values()
            .map(|(t, count)| TaskClass {
                cpu: t.cpu,
                mem: t.mem,
                gpu: t.gpu,
                gpu_model: t.gpu_model,
                pop: count as f64 / total,
            })
            .collect();
        Workload { classes }
    }

    /// Keep only the `k` most popular classes, renormalizing popularity.
    /// The XLA scorer uses a fixed class capacity; FGD's metric is
    /// dominated by the popular classes, so truncation is benign.
    pub fn top_k(&self, k: usize) -> Workload {
        let mut classes = self.classes.clone();
        classes.sort_by(|a, b| b.pop.partial_cmp(&a.pop).unwrap());
        classes.truncate(k);
        let total: f64 = classes.iter().map(|c| c.pop).sum();
        if total > 0.0 {
            for c in &mut classes {
                c.pop /= total;
            }
        }
        Workload { classes }
    }

    /// Sum of popularities (≈1 for a full extraction).
    pub fn total_pop(&self) -> f64 {
        self.classes.iter().map(|c| c.pop).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_demand_domain() {
        assert_eq!(GpuDemand::from_units(0.0), Some(GpuDemand::Zero));
        assert_eq!(GpuDemand::from_units(0.5), Some(GpuDemand::Frac(0.5)));
        assert_eq!(GpuDemand::from_units(2.0), Some(GpuDemand::Whole(2)));
        assert_eq!(GpuDemand::from_units(1.5), None);
        assert_eq!(GpuDemand::from_units(-1.0), None);
    }

    #[test]
    fn units_roundtrip() {
        for u in [0.0, 0.25, 0.9, 1.0, 4.0, 8.0] {
            assert_eq!(GpuDemand::from_units(u).unwrap().units(), u);
        }
    }

    #[test]
    fn buckets_match_table1_layout() {
        assert_eq!(GpuDemand::Zero.bucket(), 0);
        assert_eq!(GpuDemand::Frac(0.3).bucket(), 1);
        assert_eq!(GpuDemand::Whole(1).bucket(), 2);
        assert_eq!(GpuDemand::Whole(2).bucket(), 3);
        assert_eq!(GpuDemand::Whole(4).bucket(), 4);
        assert_eq!(GpuDemand::Whole(8).bucket(), 5);
        assert_eq!(GpuDemand::Whole(3).bucket(), 4);
    }

    #[test]
    fn workload_extraction_groups_and_normalizes() {
        let tasks = vec![
            Task::new(0, 4.0, 1024.0, GpuDemand::Frac(0.5)),
            Task::new(1, 4.0, 1024.0, GpuDemand::Frac(0.5)),
            Task::new(2, 8.0, 2048.0, GpuDemand::Whole(1)),
        ];
        let w = Workload::from_tasks(&tasks);
        assert_eq!(w.classes.len(), 2);
        assert!((w.total_pop() - 1.0).abs() < 1e-12);
        let frac_class = w.classes.iter().find(|c| c.gpu == GpuDemand::Frac(0.5)).unwrap();
        assert!((frac_class.pop - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn workload_distinguishes_constraints() {
        let tasks = vec![
            Task::new(0, 4.0, 1024.0, GpuDemand::Whole(1)),
            Task::new(1, 4.0, 1024.0, GpuDemand::Whole(1)).constrained(GpuModel::T4),
        ];
        let w = Workload::from_tasks(&tasks);
        assert_eq!(w.classes.len(), 2);
    }

    #[test]
    fn top_k_renormalizes() {
        let tasks = vec![
            Task::new(0, 1.0, 0.0, GpuDemand::Zero),
            Task::new(1, 2.0, 0.0, GpuDemand::Zero),
            Task::new(2, 2.0, 0.0, GpuDemand::Zero),
            Task::new(3, 3.0, 0.0, GpuDemand::Zero),
        ];
        let w = Workload::from_tasks(&tasks).top_k(2);
        assert_eq!(w.classes.len(), 2);
        assert!((w.total_pop() - 1.0).abs() < 1e-12);
    }
}
