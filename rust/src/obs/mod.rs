//! Observability layer: the metrics registry, decision tracing, and
//! phase-latency profiling (`docs/observability.md`).
//!
//! Three pillars, all **off by default and zero-cost when disabled** so
//! the bit-identity pins of earlier PRs survive untouched:
//!
//! * **[`MetricsRegistry`]** — named counters / gauges / histograms
//!   owned by [`crate::sched::Scheduler`]. The single home for every
//!   counter the simulator used to hand-thread through result structs
//!   (DRS lifecycle, MIG repartitions, constraint failures, scorer
//!   fallbacks), with a drift-proof [`METRICS_CATALOG`] mirroring the
//!   plugin registries of [`crate::sched::profile`]: `repro
//!   list-plugins` prints it, a unit test pins every key to a non-empty
//!   description, and the Prometheus exposition
//!   ([`MetricsRegistry::to_prometheus`]) covers every key.
//! * **Decision tracing** ([`trace`]) — an opt-in JSONL event stream
//!   recording, per `place`/`release`, the PreFilter verdict, per-filter
//!   veto counts, the normalized per-plugin scores of the winner and
//!   top-k runners-up (post-modulator weights included), the bind
//!   choice, the tie-break seed, and hook actions (DRS wakes,
//!   repartitions). `--trace-decisions <path>` on `simulate`/`ext-*`
//!   turns it on; `repro explain` replays one arrival and
//!   pretty-prints the scoring table.
//! * **Phase-latency profiling** — [`crate::util::benchkit::PhaseTimer`]
//!   wraps the filter / score / bind / hook phases and accumulates into
//!   registry histograms (p50/p95/p99 ns), surfaced in the
//!   `obs_summary.json` artifact and served live by the coordinator's
//!   `metrics` request in Prometheus text exposition format.

pub mod trace;

pub use trace::{DecisionTracer, ScoreRow, TraceCapture, TraceSink};

use std::collections::BTreeMap;

use crate::util::json::Json;

/// The kind of a registry metric (drives the Prometheus `# TYPE` line).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

/// The drift-proof metrics catalog: every metric the framework itself
/// maintains, with its one-line description. `repro list-plugins`
/// prints this table and the profile-registry drift test asserts it
/// stays complete (keys resolve, descriptions non-empty). Hooks may
/// still report *dynamic* counters outside the catalog (custom
/// [`crate::sched::PostHook::counters`] names pass through snapshots
/// unharmed); the catalog covers the built-in fleet.
pub const METRICS_CATALOG: &[(&str, MetricKind, &str)] = &[
    (
        "sched_places",
        MetricKind::Counter,
        "tasks committed through the place protocol",
    ),
    (
        "sched_releases",
        MetricKind::Counter,
        "departures processed through the release protocol",
    ),
    (
        "sched_failures",
        MetricKind::Counter,
        "tasks definitively unschedulable (after the postFail retry)",
    ),
    (
        "sched_retries",
        MetricKind::Counter,
        "decision retries granted by a postFail hook",
    ),
    (
        "sched_prefilter_rejections",
        MetricKind::Counter,
        "schedule calls vetoed cluster-wide by a PreFilter",
    ),
    (
        "constraint_unschedulable",
        MetricKind::Counter,
        "failures attributed to declarative task constraints",
    ),
    (
        "trace_events",
        MetricKind::Counter,
        "decision-trace events emitted to the JSONL sink",
    ),
    (
        "mig_scorer_fallbacks",
        MetricKind::Counter,
        "MIG demands routed past the XLA scorer (process-wide)",
    ),
    (
        "repartitions",
        MetricKind::Counter,
        "reactive MIG repacks triggered by a scheduling failure",
    ),
    (
        "proactive_repartitions",
        MetricKind::Counter,
        "threshold-triggered proactive MIG repacks",
    ),
    (
        "migrated_slices",
        MetricKind::Counter,
        "MIG instances moved by the repartitioner",
    ),
    ("drs_sleeps", MetricKind::Counter, "nodes put to sleep by DRS"),
    ("drs_wakes", MetricKind::Counter, "node wakes initiated by DRS"),
    (
        "drs_drains",
        MetricKind::Counter,
        "nodes entering the Draining power state",
    ),
    (
        "drs_wake_cancels",
        MetricKind::Counter,
        "DRS wakes cancelled before completion",
    ),
    (
        "drs_transition_j",
        MetricKind::Counter,
        "Joules spent in DRS sleep/wake transitions (rounded)",
    ),
    (
        "score_cache_hits",
        MetricKind::Counter,
        "per-node raw scores reused from the revision-keyed score cache",
    ),
    (
        "score_cache_misses",
        MetricKind::Counter,
        "per-node raw scores recomputed (cache cold, stale or bypassed)",
    ),
    (
        "sched_sampled_sweeps",
        MetricKind::Counter,
        "feasibility sweeps truncated by sample(<pct>) node sampling",
    ),
    (
        "score_shard_batches",
        MetricKind::Counter,
        "scoring batches dispatched to shard threads (shards(<n>) > 1)",
    ),
    (
        "phase_filter_ns",
        MetricKind::Histogram,
        "PreFilter + filter-chain latency per decision (ns)",
    ),
    (
        "phase_score_ns",
        MetricKind::Histogram,
        "score + normalize + combine latency per decision (ns)",
    ),
    (
        "phase_bind_ns",
        MetricKind::Histogram,
        "arg-max + bind latency per decision (ns)",
    ),
    (
        "phase_hooks_ns",
        MetricKind::Histogram,
        "onTick + postFail + postPlace hook latency per protocol entry (ns)",
    ),
    (
        "place_ns",
        MetricKind::Histogram,
        "end-to-end place protocol latency (ns)",
    ),
    (
        "gangs_placed",
        MetricKind::Counter,
        "gangs committed atomically through the place_gang protocol",
    ),
    (
        "gangs_failed",
        MetricKind::Counter,
        "gangs rolled back with no member committed",
    ),
    (
        "gang_tp_violations",
        MetricKind::Counter,
        "gang members placed outside one whole-GPU NVLink domain (must stay 0)",
    ),
    (
        "gang_pp_span_sum",
        MetricKind::Counter,
        "distinct nodes summed over placed gangs (mean PP span = sum / gangs_placed)",
    ),
    (
        "pending_depth",
        MetricKind::Gauge,
        "tasks currently waiting in the fairness pending queue",
    ),
    (
        "p99_wait",
        MetricKind::Gauge,
        "p99 queue wait over completed waits plus current pending ages",
    ),
    (
        "oldest_pending_age",
        MetricKind::Gauge,
        "age of the oldest task still waiting in the pending queue",
    ),
    (
        "starvation_events",
        MetricKind::Counter,
        "pending tasks whose wait crossed the starvation threshold",
    ),
    (
        "pending_enqueues",
        MetricKind::Counter,
        "tasks entering the pending queue (failed arrivals + preemption requeues)",
    ),
    (
        "pending_drains",
        MetricKind::Counter,
        "pending tasks later placed on a capacity event retry",
    ),
    (
        "preempt_evictions",
        MetricKind::Counter,
        "lower-priority residents evicted by the preempt postFail hook",
    ),
    (
        "preempt_triggers",
        MetricKind::Counter,
        "failed placements that triggered at least one preemption",
    ),
];

/// The catalog, for callers that iterate it (`repro list-plugins`).
pub fn catalog() -> &'static [(&'static str, MetricKind, &'static str)] {
    METRICS_CATALOG
}

/// One-line description of a catalog key; `None` for dynamic keys.
pub fn describe(key: &str) -> Option<&'static str> {
    METRICS_CATALOG
        .iter()
        .find(|(k, _, _)| *k == key)
        .map(|(_, _, d)| *d)
}

/// Number of log2 nanosecond buckets (`u64` bit widths + the zero
/// bucket): bucket `i > 0` holds observations in `[2^(i-1), 2^i - 1]`.
const N_BUCKETS: usize = 65;

/// A fixed-footprint latency histogram: log2 nanosecond buckets plus
/// exact count / sum / min / max. Quantiles report the upper edge of
/// the covering bucket (clamped into `[min, max]`), so p50/p95/p99 are
/// accurate to within a factor of two — plenty for phase attribution,
/// and observation stays allocation-free on the hot path.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: [u64; N_BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: [0; N_BUCKETS], count: 0, sum: 0.0, min: 0.0, max: 0.0 }
    }
}

fn bucket_index(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

impl Histogram {
    /// Record one observation (nanoseconds; negatives clamp to zero).
    pub fn observe(&mut self, ns: f64) {
        let v = if ns.is_finite() && ns > 0.0 { ns } else { 0.0 };
        self.buckets[bucket_index(v as u64)] += 1;
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Quantile `q ∈ [0, 1]`: upper edge of the bucket covering the
    /// q-th observation, clamped into `[min, max]`. Zero when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                let upper = if i == 0 {
                    0.0
                } else if i >= 64 {
                    self.max
                } else {
                    ((1u64 << i) - 1) as f64
                };
                return upper.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// JSON summary (count, sum, mean, min/max, p50/p95/p99).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum_ns", Json::Num(self.sum)),
            ("mean_ns", Json::Num(self.mean())),
            ("min_ns", Json::Num(self.min)),
            ("max_ns", Json::Num(self.max)),
            ("p50_ns", Json::Num(self.quantile(0.50))),
            ("p95_ns", Json::Num(self.quantile(0.95))),
            ("p99_ns", Json::Num(self.quantile(0.99))),
        ])
    }
}

/// Named counters, gauges, and latency histograms. Owned by
/// [`crate::sched::Scheduler`] (one registry per scheduler, so
/// repetition threads never contend); snapshots merge in hook counters
/// and the process-wide scorer fallback count
/// (see [`crate::sched::Scheduler::metrics`]).
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    /// Empty registry (dynamic keys only).
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Registry with every [`METRICS_CATALOG`] key pre-registered at
    /// zero, so expositions cover the whole catalog even before the
    /// first event (the coordinator acceptance contract).
    pub fn with_catalog() -> MetricsRegistry {
        let mut m = MetricsRegistry::default();
        for (key, kind, _) in METRICS_CATALOG {
            match kind {
                MetricKind::Counter => {
                    m.counters.insert((*key).to_string(), 0);
                }
                MetricKind::Gauge => {
                    m.gauges.insert((*key).to_string(), 0.0);
                }
                MetricKind::Histogram => {
                    m.histograms.insert((*key).to_string(), Histogram::default());
                }
            }
        }
        m
    }

    /// Increment a counter (registered on first touch).
    pub fn inc(&mut self, key: &str, by: u64) {
        *self.counters.entry(key.to_string()).or_insert(0) += by;
    }

    /// Overwrite a counter (snapshot merges).
    pub fn set_counter(&mut self, key: &str, value: u64) {
        self.counters.insert(key.to_string(), value);
    }

    /// Current counter value (0 when unregistered).
    pub fn counter(&self, key: &str) -> u64 {
        self.counters.get(key).copied().unwrap_or(0)
    }

    /// Set a gauge (registered on first touch).
    pub fn set_gauge(&mut self, key: &str, value: f64) {
        self.gauges.insert(key.to_string(), value);
    }

    /// Current gauge value (0.0 when unregistered).
    pub fn gauge(&self, key: &str) -> f64 {
        self.gauges.get(key).copied().unwrap_or(0.0)
    }

    /// Record one histogram observation (registered on first touch).
    pub fn observe_ns(&mut self, key: &str, ns: f64) {
        self.histograms.entry(key.to_string()).or_default().observe(ns);
    }

    /// Histogram accessor.
    pub fn histogram(&self, key: &str) -> Option<&Histogram> {
        self.histograms.get(key)
    }

    /// Iterate counters (sorted by key).
    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Iterate gauges (sorted by key).
    pub fn gauges(&self) -> impl Iterator<Item = (&str, f64)> {
        self.gauges.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Iterate histograms (sorted by key).
    pub fn histograms(&self) -> impl Iterator<Item = (&str, &Histogram)> {
        self.histograms.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// JSON snapshot (`obs_summary.json`): `{counters: {...},
    /// gauges: {...}, histograms: {name: {count, p50_ns, ...}}}`.
    pub fn to_json(&self) -> Json {
        let counters =
            self.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect();
        let gauges = self.gauges.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect();
        let histograms =
            self.histograms.iter().map(|(k, h)| (k.clone(), h.to_json())).collect();
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(histograms)),
        ])
    }

    /// Prometheus text exposition (format 0.0.4). Counters and gauges
    /// render directly; histograms render as `summary` metrics with
    /// p50/p95/p99 quantiles plus `_sum` and `_count`. `prefix` is
    /// prepended to every metric name (`repro_` by convention); names
    /// are sanitized to the Prometheus charset.
    pub fn to_prometheus(&self, prefix: &str) -> String {
        let mut out = String::new();
        let help = |key: &str| describe(key).unwrap_or("runtime-registered metric");
        for (key, value) in &self.counters {
            let name = format!("{prefix}{}", sanitize(key));
            out.push_str(&format!("# HELP {name} {}\n", help(key)));
            out.push_str(&format!("# TYPE {name} counter\n"));
            out.push_str(&format!("{name} {value}\n"));
        }
        for (key, value) in &self.gauges {
            let name = format!("{prefix}{}", sanitize(key));
            out.push_str(&format!("# HELP {name} {}\n", help(key)));
            out.push_str(&format!("# TYPE {name} gauge\n"));
            out.push_str(&format!("{name} {value}\n"));
        }
        for (key, h) in &self.histograms {
            let name = format!("{prefix}{}", sanitize(key));
            out.push_str(&format!("# HELP {name} {}\n", help(key)));
            out.push_str(&format!("# TYPE {name} summary\n"));
            for (label, q) in [("0.5", 0.50), ("0.95", 0.95), ("0.99", 0.99)] {
                out.push_str(&format!(
                    "{name}{{quantile=\"{label}\"}} {}\n",
                    h.quantile(q)
                ));
            }
            out.push_str(&format!("{name}_sum {}\n", h.sum()));
            out.push_str(&format!("{name}_count {}\n", h.count()));
        }
        out
    }
}

/// Restrict a key to the Prometheus metric-name charset
/// (`[a-zA-Z0-9_:]`; anything else becomes `_`).
fn sanitize(key: &str) -> String {
    key.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect()
}

/// Per-scheduler observability state: the registry plus the opt-in
/// tracing/profiling switches. Lives on [`crate::sched::Scheduler`];
/// everything defaults to *off* so the disabled path is byte-identical
/// to the pre-observability scheduler (pinned by
/// `rust/tests/obs_equivalence.rs`).
#[derive(Debug)]
pub struct ObsState {
    /// The scheduler-owned metrics registry (catalog pre-registered).
    pub registry: MetricsRegistry,
    /// Phase-latency profiling switch ([`crate::util::benchkit::PhaseTimer`]).
    pub profiling: bool,
    /// Attached decision tracer (None = tracing off).
    pub tracer: Option<DecisionTracer>,
    /// One-shot capture request (`repro explain` replays).
    pub capture_requested: bool,
    /// Capture of the most recent `schedule()` call (tracer or
    /// explain mode only).
    pub capture: Option<TraceCapture>,
    /// How many runners-up each trace event records.
    pub top_k: usize,
    /// The seed last passed to `reseed_ties` (recorded in events).
    pub tie_seed: u64,
}

impl Default for ObsState {
    fn default() -> Self {
        ObsState {
            registry: MetricsRegistry::with_catalog(),
            profiling: false,
            tracer: None,
            capture_requested: false,
            capture: None,
            top_k: 3,
            tie_seed: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_keys_unique_and_described() {
        let mut seen = std::collections::BTreeSet::new();
        for (key, _, desc) in METRICS_CATALOG {
            assert!(seen.insert(*key), "duplicate catalog key {key}");
            assert!(!desc.is_empty(), "catalog key {key} lacks a description");
            assert_eq!(sanitize(key), *key, "catalog key {key} is not Prometheus-safe");
        }
        assert_eq!(describe("drs_sleeps"), Some("nodes put to sleep by DRS"));
        assert_eq!(describe("nope"), None);
    }

    #[test]
    fn histogram_quantiles_bracket_observations() {
        let mut h = Histogram::default();
        for ns in [100.0, 200.0, 400.0, 800.0, 100_000.0] {
            h.observe(ns);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min(), 100.0);
        assert_eq!(h.max(), 100_000.0);
        let p50 = h.quantile(0.50);
        // Third observation (400 ns) lives in the [256, 511] bucket.
        assert!((100.0..=511.0).contains(&p50), "p50 = {p50}");
        assert_eq!(h.quantile(0.99), 100_000.0);
        assert_eq!(h.quantile(0.0), h.quantile(0.0)); // no panic on edges
        assert_eq!(Histogram::default().quantile(0.5), 0.0);
    }

    #[test]
    fn histogram_handles_degenerate_observations() {
        let mut h = Histogram::default();
        h.observe(-5.0);
        h.observe(f64::NAN);
        h.observe(0.0);
        assert_eq!(h.count(), 3);
        assert_eq!(h.sum(), 0.0);
        assert_eq!(h.quantile(0.99), 0.0);
    }

    #[test]
    fn registry_roundtrip() {
        let mut m = MetricsRegistry::with_catalog();
        assert_eq!(m.counter("drs_sleeps"), 0);
        m.inc("drs_sleeps", 3);
        m.inc("custom_counter", 1);
        m.set_gauge("eopc_w", 123.5);
        m.observe_ns("place_ns", 1000.0);
        assert_eq!(m.counter("drs_sleeps"), 3);
        assert_eq!(m.counter("custom_counter"), 1);
        assert_eq!(m.gauge("eopc_w"), 123.5);
        assert_eq!(m.histogram("place_ns").unwrap().count(), 1);
        let j = m.to_json();
        assert_eq!(j.get("counters").and_then(|c| c.get("drs_sleeps")).and_then(Json::as_u64), Some(3));
        assert_eq!(
            j.get("histograms")
                .and_then(|h| h.get("place_ns"))
                .and_then(|p| p.get("count"))
                .and_then(Json::as_u64),
            Some(1)
        );
    }

    #[test]
    fn prometheus_exposition_covers_every_catalog_key() {
        let mut m = MetricsRegistry::with_catalog();
        m.set_gauge("grar", 0.75);
        m.observe_ns("place_ns", 512.0);
        let text = m.to_prometheus("repro_");
        for (key, kind, _) in METRICS_CATALOG {
            assert!(
                text.contains(&format!("# HELP repro_{key} ")),
                "missing HELP for {key}"
            );
            let ty = match kind {
                MetricKind::Counter => "counter",
                MetricKind::Gauge => "gauge",
                MetricKind::Histogram => "summary",
            };
            assert!(
                text.contains(&format!("# TYPE repro_{key} {ty}")),
                "missing TYPE for {key}"
            );
        }
        assert!(text.contains("repro_grar 0.75"));
        assert!(text.contains("repro_place_ns{quantile=\"0.5\"}"));
        assert!(text.contains("repro_place_ns_count 1"));
        // Every line is either a comment or `name[{labels}] value`.
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.split_whitespace().count() == 2,
                "malformed exposition line: {line}"
            );
        }
    }

    #[test]
    fn sanitize_maps_to_prometheus_charset() {
        assert_eq!(sanitize("drs_sleeps"), "drs_sleeps");
        assert_eq!(sanitize("weird-key.v2"), "weird_key_v2");
    }
}
