//! Decision tracing: JSONL event stream + capture plumbing.
//!
//! The scheduler captures one [`TraceCapture`] per `schedule()` call
//! whenever a [`DecisionTracer`] is attached (or a one-shot capture is
//! requested by `repro explain`); [`crate::sched::Scheduler::place`] /
//! `release` turn captures into self-describing JSONL events — one
//! object per line, each carrying the policy label, seed, and sequence
//! number, so concurrent repetition threads can share a single sink and
//! the stream still demultiplexes. The event schema is documented in
//! `docs/observability.md` and round-trip-tested in
//! `rust/tests/obs_equivalence.rs`.

use std::fmt;
use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::sched::framework::Decision;
use crate::tasks::Task;
use crate::util::json::Json;

/// A shared line-oriented trace sink. Cheap to clone (all clones append
/// to the same underlying writer); `Send + Sync`, so one sink serves
/// every repetition thread of `run_repetitions`. Writes are
/// best-effort: a full disk must never fail a scheduling decision.
pub struct TraceSink {
    inner: Arc<Mutex<Box<dyn Write + Send>>>,
    kind: &'static str,
    /// Backing buffer for [`TraceSink::memory`] sinks (tests, explain).
    buffer: Option<Arc<Mutex<Vec<u8>>>>,
}

impl Clone for TraceSink {
    fn clone(&self) -> Self {
        TraceSink {
            inner: Arc::clone(&self.inner),
            kind: self.kind,
            buffer: self.buffer.as_ref().map(Arc::clone),
        }
    }
}

impl fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TraceSink({})", self.kind)
    }
}

impl TraceSink {
    /// Buffered file sink (`--trace-decisions <path>`).
    pub fn file<P: AsRef<Path>>(path: P) -> io::Result<TraceSink> {
        let f = File::create(path)?;
        Ok(TraceSink {
            inner: Arc::new(Mutex::new(Box::new(BufWriter::new(f)))),
            kind: "file",
            buffer: None,
        })
    }

    /// In-memory sink; read back with [`TraceSink::contents`].
    pub fn memory() -> TraceSink {
        struct MemWriter(Arc<Mutex<Vec<u8>>>);
        impl Write for MemWriter {
            fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let buf = Arc::new(Mutex::new(Vec::new()));
        TraceSink {
            inner: Arc::new(Mutex::new(Box::new(MemWriter(Arc::clone(&buf))))),
            kind: "memory",
            buffer: Some(buf),
        }
    }

    /// Discarding sink — pays the full capture + serialization cost
    /// without IO (the `bench-scale` tracing-overhead measurement).
    pub fn null() -> TraceSink {
        TraceSink { inner: Arc::new(Mutex::new(Box::new(io::sink()))), kind: "null", buffer: None }
    }

    /// Append one line (best-effort; IO errors are swallowed).
    pub fn write_line(&self, line: &str) {
        if let Ok(mut w) = self.inner.lock() {
            let _ = writeln!(w, "{line}");
        }
    }

    /// Flush buffered output (best-effort).
    pub fn flush(&self) {
        if let Ok(mut w) = self.inner.lock() {
            let _ = w.flush();
        }
    }

    /// Contents of a [`TraceSink::memory`] sink (empty otherwise).
    pub fn contents(&self) -> String {
        match &self.buffer {
            Some(b) => String::from_utf8_lossy(&b.lock().unwrap()).into_owned(),
            None => String::new(),
        }
    }
}

/// The per-scheduler tracer: stamps each event with the policy label,
/// repetition seed, and a monotone sequence number, then appends it to
/// the sink as one JSONL line.
#[derive(Clone, Debug)]
pub struct DecisionTracer {
    sink: TraceSink,
    policy: String,
    seed: u64,
    seq: u64,
}

impl DecisionTracer {
    pub fn new(sink: TraceSink, policy: &str, seed: u64) -> DecisionTracer {
        DecisionTracer { sink, policy: policy.to_string(), seed, seq: 0 }
    }

    /// Stamp `event` with `policy`/`seed`/`seq` and append it.
    pub fn emit(&mut self, mut event: Json) {
        if let Json::Obj(m) = &mut event {
            m.insert("policy".to_string(), Json::Str(self.policy.clone()));
            m.insert("seed".to_string(), Json::Num(self.seed as f64));
            m.insert("seq".to_string(), Json::Num(self.seq as f64));
        }
        self.seq += 1;
        self.sink.write_line(&event.dump());
    }

    /// Events emitted so far.
    pub fn emitted(&self) -> u64 {
        self.seq
    }

    pub fn sink(&self) -> &TraceSink {
        &self.sink
    }
}

/// One row of the scoring table: a node's combined score plus the
/// normalized per-plugin scores that produced it.
#[derive(Clone, Debug)]
pub struct ScoreRow {
    pub node: usize,
    pub combined: f64,
    pub per_plugin: Vec<f64>,
    pub winner: bool,
}

/// What `schedule()` records when tracing/capture is active. Filled
/// incrementally along the decision pipeline; turned into a JSONL event
/// by [`place_event`].
#[derive(Clone, Debug, Default)]
pub struct TraceCapture {
    /// Name of the PreFilter that vetoed the task cluster-wide.
    pub prefilter_veto: Option<&'static str>,
    /// Filter-chain names, parallel to [`TraceCapture::filter_vetoes`].
    pub filter_names: Vec<&'static str>,
    /// Per-filter count of nodes vetoed (first-rejector attribution:
    /// filters run in chain order and the first `false` wins the veto).
    pub filter_vetoes: Vec<u64>,
    /// Nodes surviving the filter chain with ≥ 1 candidate placement.
    pub feasible: usize,
    /// Score-plugin names, parallel to per-plugin score columns.
    pub plugins: Vec<&'static str>,
    /// Effective (post-modulator) plugin weights for this decision.
    pub weights: Vec<f64>,
    /// Normalized score rows, one `Vec` per plugin (scratch; drained
    /// into [`TraceCapture::scores`] after the arg-max).
    pub norm_rows: Vec<Vec<f64>>,
    /// Winner first, then up to `top_k` runners-up by combined score.
    pub scores: Vec<ScoreRow>,
    /// Number of max-scoring nodes the tie-break sampled over.
    pub ties: u32,
    /// Bound node (None = unschedulable).
    pub bind_node: Option<usize>,
    /// Candidate placements the binder chose among.
    pub candidates: usize,
    /// Debug rendering of the chosen placement.
    pub placement: Option<String>,
    /// Whether the rejection was attributed to declarative constraints.
    pub constrained: bool,
}

fn num(x: u64) -> Json {
    Json::Num(x as f64)
}

fn task_json(task: &Task) -> Json {
    Json::obj(vec![
        ("id", num(task.id)),
        ("cpu", Json::Num(task.cpu)),
        ("mem", Json::Num(task.mem)),
        ("gpu", Json::Str(format!("{:?}", task.gpu))),
        ("constrained", Json::Bool(task.constraints.is_some())),
    ])
}

fn hooks_json(deltas: &[(String, u64)]) -> Json {
    Json::Obj(deltas.iter().map(|(k, v)| (k.clone(), num(*v))).collect())
}

/// Build the `place` event from a capture and the decision outcome.
/// `hook_deltas` are the per-hook counter increments observed across
/// this protocol entry (DRS wakes, repartitions, …); only non-zero
/// deltas should be passed.
pub fn place_event(
    task: &Task,
    cap: &TraceCapture,
    decision: Option<&Decision>,
    retried: bool,
    now: u64,
    tie_seed: u64,
    hook_deltas: &[(String, u64)],
) -> Json {
    let prefilter = match cap.prefilter_veto {
        Some(name) => Json::obj(vec![
            ("verdict", Json::Str("veto".to_string())),
            ("vetoed_by", Json::Str(name.to_string())),
        ]),
        None => Json::obj(vec![("verdict", Json::Str("pass".to_string()))]),
    };
    let filters = Json::Arr(
        cap.filter_names
            .iter()
            .zip(&cap.filter_vetoes)
            .map(|(name, vetoes)| {
                Json::obj(vec![
                    ("name", Json::Str(name.to_string())),
                    ("vetoes", num(*vetoes)),
                ])
            })
            .collect(),
    );
    let weights = Json::Arr(
        cap.plugins
            .iter()
            .zip(&cap.weights)
            .map(|(plugin, w)| {
                Json::obj(vec![
                    ("plugin", Json::Str(plugin.to_string())),
                    ("weight", Json::Num(*w)),
                ])
            })
            .collect(),
    );
    let scores = Json::Arr(
        cap.scores
            .iter()
            .map(|row| {
                let per_plugin = cap
                    .plugins
                    .iter()
                    .zip(&row.per_plugin)
                    .map(|(plugin, s)| (plugin.to_string(), Json::Num(*s)))
                    .collect();
                Json::obj(vec![
                    ("node", num(row.node as u64)),
                    ("combined", Json::Num(row.combined)),
                    ("per_plugin", Json::Obj(per_plugin)),
                    ("winner", Json::Bool(row.winner)),
                ])
            })
            .collect(),
    );
    let bind = match decision {
        Some(d) => Json::obj(vec![
            ("node", num(d.node as u64)),
            ("placement", Json::Str(format!("{:?}", d.placement))),
            ("candidates", num(cap.candidates as u64)),
        ]),
        None => Json::Null,
    };
    Json::obj(vec![
        ("event", Json::Str("place".to_string())),
        ("now", num(now)),
        ("tie_seed", num(tie_seed)),
        ("task", task_json(task)),
        ("prefilter", prefilter),
        ("filters", filters),
        ("feasible", num(cap.feasible as u64)),
        ("weights", weights),
        ("scores", scores),
        ("ties", num(cap.ties as u64)),
        ("bind", bind),
        (
            "outcome",
            Json::Str(if decision.is_some() { "placed" } else { "failed" }.to_string()),
        ),
        ("retried", Json::Bool(retried)),
        ("constrained", Json::Bool(cap.constrained)),
        ("hooks", hooks_json(hook_deltas)),
    ])
}

/// Build the `release` event (departures carry no scoring table, but
/// hook actions — DRS idling a node to sleep, proactive repartitions —
/// still show up in the deltas).
/// One committed gang as a single JSONL event: the parent task plus a
/// per-member bind record (member index, node, placement) for every TP
/// group of the [`crate::sched::gang::GangDecision`]. Emitted only
/// after the all-or-nothing protocol commits — failed/rolled-back
/// gangs leave no event (`rust/tests/gang_equivalence.rs` pins
/// `gangs_placed == gang events`).
pub fn gang_event(
    task: &Task,
    members: &[crate::sched::framework::Decision],
    now: u64,
    hook_deltas: &[(String, u64)],
) -> Json {
    let member_rows = members
        .iter()
        .enumerate()
        .map(|(i, d)| {
            Json::obj(vec![
                ("member", num(i as u64)),
                ("node", num(d.node as u64)),
                ("placement", Json::Str(format!("{:?}", d.placement))),
            ])
        })
        .collect();
    Json::obj(vec![
        ("event", Json::Str("gang".to_string())),
        ("now", num(now)),
        ("task", task_json(task)),
        ("n_members", num(members.len() as u64)),
        ("members", Json::Arr(member_rows)),
        ("hooks", hooks_json(hook_deltas)),
    ])
}

pub fn release_event(
    task: &Task,
    node: usize,
    placement: &crate::cluster::node::Placement,
    now: u64,
    hook_deltas: &[(String, u64)],
) -> Json {
    Json::obj(vec![
        ("event", Json::Str("release".to_string())),
        ("now", num(now)),
        ("task", task_json(task)),
        ("node", num(node as u64)),
        ("placement", Json::Str(format!("{placement:?}"))),
        ("hooks", hooks_json(hook_deltas)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::Placement;
    use crate::tasks::GpuDemand;
    use crate::util::json;

    #[test]
    fn memory_sink_roundtrips_lines() {
        let sink = TraceSink::memory();
        let clone = sink.clone();
        sink.write_line("{\"a\":1}");
        clone.write_line("{\"b\":2}");
        sink.flush();
        let lines: Vec<&str> = sink.contents().lines().collect();
        assert_eq!(lines, vec!["{\"a\":1}", "{\"b\":2}"]);
        assert_eq!(format!("{sink:?}"), "TraceSink(memory)");
    }

    #[test]
    fn null_sink_discards() {
        let sink = TraceSink::null();
        sink.write_line("dropped");
        assert_eq!(sink.contents(), "");
    }

    #[test]
    fn tracer_stamps_policy_seed_seq() {
        let sink = TraceSink::memory();
        let mut t = DecisionTracer::new(sink.clone(), "FGD", 42);
        t.emit(Json::obj(vec![("event", Json::Str("place".to_string()))]));
        t.emit(Json::obj(vec![("event", Json::Str("release".to_string()))]));
        assert_eq!(t.emitted(), 2);
        let text = sink.contents();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = json::parse(lines[0]).expect("valid JSON");
        assert_eq!(first.get("policy").and_then(Json::as_str), Some("FGD"));
        assert_eq!(first.get("seed").and_then(Json::as_u64), Some(42));
        assert_eq!(first.get("seq").and_then(Json::as_u64), Some(0));
        let second = json::parse(lines[1]).expect("valid JSON");
        assert_eq!(second.get("seq").and_then(Json::as_u64), Some(1));
    }

    #[test]
    fn place_event_schema() {
        let task = Task::new(7, 2.0, 512.0, GpuDemand::Whole(1));
        let cap = TraceCapture {
            filter_names: vec!["resources"],
            filter_vetoes: vec![1],
            feasible: 2,
            plugins: vec!["Pwr", "Fgd"],
            weights: vec![0.1, 0.9],
            scores: vec![ScoreRow {
                node: 3,
                combined: 95.0,
                per_plugin: vec![50.0, 100.0],
                winner: true,
            }],
            ties: 1,
            bind_node: Some(3),
            candidates: 2,
            placement: Some("Whole { gpus: [0] }".to_string()),
            ..Default::default()
        };
        let d = Decision { node: 3, placement: Placement::Whole { gpus: vec![0] } };
        let ev = place_event(
            &task,
            &cap,
            Some(&d),
            false,
            11,
            42,
            &[("drs_wakes".to_string(), 1)],
        );
        let parsed = json::parse(&ev.dump()).expect("self-parses");
        assert_eq!(parsed.get("event").and_then(Json::as_str), Some("place"));
        assert_eq!(parsed.get("outcome").and_then(Json::as_str), Some("placed"));
        assert_eq!(
            parsed.get("task").and_then(|t| t.get("id")).and_then(Json::as_u64),
            Some(7)
        );
        assert_eq!(
            parsed.get("bind").and_then(|b| b.get("node")).and_then(Json::as_u64),
            Some(3)
        );
        assert_eq!(
            parsed
                .get("hooks")
                .and_then(|h| h.get("drs_wakes"))
                .and_then(Json::as_u64),
            Some(1)
        );
        let scores = parsed.get("scores").and_then(Json::as_arr).unwrap();
        assert_eq!(scores.len(), 1);
        assert_eq!(
            scores[0]
                .get("per_plugin")
                .and_then(|p| p.get("Fgd"))
                .and_then(Json::as_f64),
            Some(100.0)
        );
    }

    #[test]
    fn failed_place_event_has_null_bind() {
        let task = Task::new(1, 1.0, 0.0, GpuDemand::Whole(64));
        let cap = TraceCapture {
            prefilter_veto: Some("resources"),
            constrained: false,
            ..Default::default()
        };
        let ev = place_event(&task, &cap, None, true, 5, 0, &[]);
        assert_eq!(ev.get("outcome").and_then(Json::as_str), Some("failed"));
        assert!(matches!(ev.get("bind"), Some(Json::Null)));
        assert_eq!(
            ev.get("prefilter").and_then(|p| p.get("vetoed_by")).and_then(Json::as_str),
            Some("resources")
        );
        assert_eq!(ev.get("retried"), Some(&Json::Bool(true)));
    }

    #[test]
    fn release_event_schema() {
        let task = Task::new(9, 1.0, 128.0, GpuDemand::Frac(0.5));
        let ev = release_event(&task, 4, &Placement::Shared { gpu: 1 }, 20, &[]);
        let parsed = json::parse(&ev.dump()).expect("self-parses");
        assert_eq!(parsed.get("event").and_then(Json::as_str), Some("release"));
        assert_eq!(parsed.get("node").and_then(Json::as_u64), Some(4));
        assert!(parsed.get("placement").and_then(Json::as_str).unwrap().contains("Shared"));
    }
}
