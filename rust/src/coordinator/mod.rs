//! The online scheduling coordinator: a long-running service that
//! accepts task submissions and schedules them against the live cluster
//! state — the deployable form of the paper's Kubernetes plugin.
//!
//! Scheduling is atomic (§II: "a new scheduling decision starts only
//! after the previous one has completed"): all state sits behind one
//! mutex and each request holds it for exactly one decision. The wire
//! protocol is JSON-lines over TCP (the offline vendor set has no
//! tokio; the server is a thread-per-connection std::net design, which
//! comfortably sustains the paper-scale decision rates — see
//! `benches/policies.rs`).
//!
//! ## Protocol
//! ```text
//! → {"op":"submit","id":1,"cpu":4,"mem":1024,"gpu":0.5}
//! ← {"ok":true,"node":17,"gpu":3}
//! → {"op":"release","id":1}
//! ← {"ok":true}
//! → {"op":"stats"}
//! ← {"ok":true,"eopc_w":...,"grar":...,"tasks":...,"active_gpus":...}
//! → {"op":"metrics"}
//! ← {"ok":true,"format":"prometheus-text-0.0.4","body":"# HELP repro_sched_places ..."}
//! → {"op":"shutdown"}
//! ```
//!
//! The `metrics` op serves the scheduler's full observability registry
//! ([`crate::obs`]) — every catalogued counter and phase-latency
//! histogram plus live coordinator gauges — in Prometheus text
//! exposition format, ready to paste behind a scrape endpoint (see
//! `docs/observability.md`).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::cluster::node::Placement;
use crate::cluster::Datacenter;
use crate::power;
use crate::sched::{FairnessConfig, FairnessState, Scheduler, SchedulerProfile};
use crate::tasks::{GpuDemand, Task, Workload};
use crate::util::json::{parse, Json};

/// Shared coordinator state (one scheduling decision at a time).
pub struct CoordinatorState {
    pub dc: Datacenter,
    pub sched: Scheduler,
    pub workload: Workload,
    /// Live allocations: task id → (task, node, placement).
    allocations: HashMap<u64, (Task, usize, Placement)>,
    /// Pending-queue fairness state: unschedulable submissions park
    /// here (priority-ordered, FIFO within priority) and are retried
    /// after every `release` frees capacity. The queue is *not* bound
    /// into the scheduler via `bind_fairness`, so `mod(starve)` /
    /// `hook(preempt)` sections in a served profile stay inert — the
    /// coordinator has no eviction path back to its clients yet.
    fairness: FairnessState,
    /// Counters. `failed` counts submit-time refusals; a refused task
    /// that later drains from the pending queue also counts in
    /// `scheduled` (clients observe placement via a fresh `stats` /
    /// `metrics` poll, the original reply stays `ok:false`).
    pub submitted: u64,
    pub scheduled: u64,
    pub failed: u64,
    pub arrived_gpu_units: f64,
}

impl CoordinatorState {
    /// `policy` accepts a legacy [`crate::sched::PolicyKind`] or any
    /// [`SchedulerProfile`] — `repro serve --policy "score(...)|..."`
    /// deploys composite profiles (hooks included) unchanged.
    ///
    /// # Panics
    /// On a hand-built profile that fails
    /// [`SchedulerProfile::build`] (unknown keys, bad weights).
    /// Profiles from [`SchedulerProfile::parse`] and legacy
    /// `PolicyKind`s are pre-validated and never panic here.
    pub fn new(
        dc: Datacenter,
        policy: impl Into<SchedulerProfile>,
        workload: Workload,
    ) -> CoordinatorState {
        CoordinatorState {
            dc,
            sched: policy.into().build().expect("invalid scheduler profile"),
            workload,
            allocations: HashMap::new(),
            fairness: FairnessState::new(FairnessConfig::default()),
            submitted: 0,
            scheduled: 0,
            failed: 0,
            arrived_gpu_units: 0.0,
        }
    }

    /// Submit a task: the scheduler's full `place` protocol (postFail
    /// repack-and-retry, commit, postPlace hooks), then register the
    /// allocation. Returns the decision.
    pub fn submit(&mut self, task: Task) -> Option<(usize, Placement)> {
        self.submitted += 1;
        self.arrived_gpu_units += task.gpu.units();
        match self.sched.place(&mut self.dc, &self.workload, &task) {
            Some(d) => {
                self.allocations.insert(task.id, (task, d.node, d.placement.clone()));
                self.scheduled += 1;
                Some((d.node, d.placement))
            }
            None => {
                self.failed += 1;
                let now = self.submitted as f64;
                self.fairness.with_core(|c| {
                    c.set_now(now);
                    c.enqueue(task, false);
                });
                None
            }
        }
    }

    /// Release a previously scheduled task (departure; runs the
    /// scheduler's postPlace hooks), then retry the pending queue
    /// against the freed capacity.
    pub fn release(&mut self, task_id: u64) -> bool {
        match self.allocations.remove(&task_id) {
            Some((task, node, placement)) => {
                self.sched.release(&mut self.dc, &task, node, &placement);
                self.drain_pending();
                true
            }
            None => false,
        }
    }

    /// Place queued tasks highest-priority-first (FIFO within a
    /// priority) until the head fails again; placed tasks join the
    /// live allocation table and count as `scheduled`. The queue clock
    /// is the submission count, matching the scheduler's event-count
    /// notion of time.
    fn drain_pending(&mut self) {
        let now = self.submitted as f64;
        loop {
            let Some(task) = self.fairness.with_core(|c| {
                c.set_now(now);
                c.head()
            }) else {
                break;
            };
            let Some(d) = self.sched.place(&mut self.dc, &self.workload, &task) else {
                break;
            };
            let Some(entry) = self.fairness.with_core(|c| c.pop_placed()) else {
                break;
            };
            if !entry.requeued {
                self.scheduled += 1;
            }
            self.allocations.insert(entry.task.id, (entry.task, d.node, d.placement));
        }
    }

    /// Current metrics snapshot as JSON.
    pub fn stats(&self) -> Json {
        let (cpu_w, gpu_w) = power::p_datacenter_split(&self.dc);
        let grar = if self.arrived_gpu_units > 0.0 {
            self.dc.gpu_allocated_units() / self.arrived_gpu_units
        } else {
            1.0
        };
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("eopc_w", Json::Num(cpu_w + gpu_w)),
            ("cpu_w", Json::Num(cpu_w)),
            ("gpu_w", Json::Num(gpu_w)),
            ("grar", Json::Num(grar)),
            ("tasks", Json::Num(self.dc.n_tasks as f64)),
            ("submitted", Json::Num(self.submitted as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("pending", Json::Num(self.fairness.with_core(|c| c.pending_depth()) as f64)),
            ("active_gpus", Json::Num(self.dc.active_gpus() as f64)),
            ("active_nodes", Json::Num(self.dc.active_nodes() as f64)),
        ])
    }

    /// The full observability registry — the scheduler's merged metrics
    /// snapshot ([`Scheduler::metrics`]) plus live coordinator gauges —
    /// rendered in Prometheus text exposition format under the
    /// `repro_` prefix. Served by the `metrics` wire op.
    pub fn prometheus_metrics(&self) -> String {
        let mut reg = self.sched.metrics();
        let (cpu_w, gpu_w) = power::p_datacenter_split(&self.dc);
        let grar = if self.arrived_gpu_units > 0.0 {
            self.dc.gpu_allocated_units() / self.arrived_gpu_units
        } else {
            1.0
        };
        reg.set_gauge("coordinator_eopc_w", cpu_w + gpu_w);
        reg.set_gauge("coordinator_cpu_w", cpu_w);
        reg.set_gauge("coordinator_gpu_w", gpu_w);
        reg.set_gauge("coordinator_grar", grar);
        reg.set_gauge("coordinator_tasks", self.dc.n_tasks as f64);
        reg.set_gauge("coordinator_submitted", self.submitted as f64);
        reg.set_gauge("coordinator_failed", self.failed as f64);
        reg.set_gauge("coordinator_active_gpus", self.dc.active_gpus() as f64);
        reg.set_gauge("coordinator_active_nodes", self.dc.active_nodes() as f64);
        // Pending-queue starvation gauges/counters (pending_depth,
        // p99_wait, oldest_pending_age, starvation_events, …) ride on
        // the same body.
        if let Ok(core) = self.fairness.shared().lock() {
            core.publish(&mut reg);
        }
        reg.to_prometheus("repro_")
    }
}

/// Parse a `submit` request body into a [`Task`]. A `"mig":"2g"`-style
/// field requests one MIG instance instead of fraction/whole units.
fn task_from_json(v: &Json) -> Result<Task, String> {
    let id = v.get("id").and_then(|x| x.as_u64()).ok_or("missing id")?;
    let cpu = v.get("cpu").and_then(|x| x.as_f64()).ok_or("missing cpu")?;
    let mem = v.get("mem").and_then(|x| x.as_f64()).unwrap_or(0.0);
    let gpu = match v.get("mig").and_then(|x| x.as_str()) {
        Some(profile) => GpuDemand::Mig(
            crate::cluster::mig::MigProfile::parse(profile).ok_or("unknown mig profile")?,
        ),
        None => {
            let gpu_units = v.get("gpu").and_then(|x| x.as_f64()).unwrap_or(0.0);
            GpuDemand::from_units(gpu_units).ok_or("invalid gpu demand")?
        }
    };
    let gpu_model = match v.get("gpu_model").and_then(|x| x.as_str()) {
        Some(s) => {
            Some(crate::cluster::types::GpuModel::parse(s).ok_or("unknown gpu_model")?)
        }
        None => None,
    };
    // Optional declarative constraints (see crate::tasks::TaskConstraints):
    // "tenant" registers the task under that class key *verbatim*,
    // "anti_affinity" rejects nodes hosting the named class (also
    // verbatim, so {"tenant":"a"} and {"anti_affinity":"a"} refer to
    // the same class), and "gpu_models" restricts placement to a model
    // set.
    let mut constraints = crate::tasks::TaskConstraints::default();
    if let Some(tenant) = v.get("tenant").and_then(|x| x.as_str()) {
        constraints.class_key = Some(tenant.to_string());
    }
    if let Some(Json::Arr(models)) = v.get("gpu_models") {
        for m in models {
            let name = m.as_str().ok_or("gpu_models entries must be strings")?;
            constraints
                .gpu_models
                .push(crate::cluster::types::GpuModel::parse(name).ok_or("unknown gpu_models entry")?);
        }
    }
    if let Some(anti) = v.get("anti_affinity").and_then(|x| x.as_str()) {
        constraints.anti_affinity.push(anti.to_string());
    }
    // Optional tenant priority (0 = best-effort default, 255 = highest);
    // consumed by the pending-queue ordering and `hook(preempt)`.
    let priority = match v.get("priority") {
        Some(p) => {
            let n = p.as_f64().ok_or("priority must be a number")?;
            if !(0.0..=255.0).contains(&n) || n.fract() != 0.0 {
                return Err(format!("priority must be an integer in 0..=255, got {n}"));
            }
            n as u8
        }
        None => 0,
    };
    Ok(Task {
        id,
        cpu,
        mem,
        gpu,
        gpu_model,
        constraints: if constraints.is_unconstrained() {
            None
        } else {
            Some(Box::new(constraints))
        },
        gang: None,
        priority,
    })
}

/// Handle one request line; returns (response, shutdown?).
pub fn handle_request(state: &Mutex<CoordinatorState>, line: &str) -> (Json, bool) {
    let err = |msg: &str| {
        Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))])
    };
    let v = match parse(line) {
        Ok(v) => v,
        Err(e) => return (err(&format!("bad json: {e}")), false),
    };
    let op = v.get("op").and_then(|x| x.as_str()).unwrap_or("");
    match op {
        "submit" => match task_from_json(&v) {
            Ok(task) => {
                let mut st = state.lock().unwrap();
                match st.submit(task) {
                    Some((node, placement)) => {
                        let gpu = match &placement {
                            Placement::Shared { gpu } => Json::Num(*gpu as f64),
                            Placement::Whole { gpus } => {
                                Json::Arr(gpus.iter().map(|&g| Json::Num(g as f64)).collect())
                            }
                            Placement::CpuOnly => Json::Null,
                            Placement::MigSlice { gpu, start } => Json::Arr(vec![
                                Json::Num(*gpu as f64),
                                Json::Num(*start as f64),
                            ]),
                        };
                        (
                            Json::obj(vec![
                                ("ok", Json::Bool(true)),
                                ("node", Json::Num(node as f64)),
                                ("gpu", gpu),
                            ]),
                            false,
                        )
                    }
                    None => (err("unschedulable"), false),
                }
            }
            Err(e) => (err(&e), false),
        },
        "release" => {
            let Some(id) = v.get("id").and_then(|x| x.as_u64()) else {
                return (err("missing id"), false);
            };
            let ok = state.lock().unwrap().release(id);
            if ok {
                (Json::obj(vec![("ok", Json::Bool(true))]), false)
            } else {
                (err("unknown task"), false)
            }
        }
        "stats" => (state.lock().unwrap().stats(), false),
        "metrics" => {
            let body = state.lock().unwrap().prometheus_metrics();
            (
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("format", Json::Str("prometheus-text-0.0.4".into())),
                    ("body", Json::Str(body)),
                ]),
                false,
            )
        }
        "shutdown" => (Json::obj(vec![("ok", Json::Bool(true))]), true),
        _ => (err("unknown op"), false),
    }
}

/// The TCP server. Bind, then call [`Server::run`] (blocking) or use
/// [`Server::port`] to connect a client first.
pub struct Server {
    listener: TcpListener,
    state: Arc<Mutex<CoordinatorState>>,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    /// Bind to `addr` (use port 0 for an ephemeral port).
    pub fn bind(addr: &str, state: CoordinatorState) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        Ok(Server {
            listener,
            state: Arc::new(Mutex::new(state)),
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound port.
    pub fn port(&self) -> u16 {
        self.listener.local_addr().unwrap().port()
    }

    /// Shared state handle (for in-process inspection).
    pub fn state(&self) -> Arc<Mutex<CoordinatorState>> {
        self.state.clone()
    }

    /// Accept loop: one thread per connection; returns after a
    /// `shutdown` request completes.
    pub fn run(&self) -> std::io::Result<()> {
        self.listener.set_nonblocking(false)?;
        let mut workers = Vec::new();
        for stream in self.listener.incoming() {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = stream?;
            let state = self.state.clone();
            let shutdown = self.shutdown.clone();
            workers.push(std::thread::spawn(move || {
                let _ = serve_connection(stream, &state, &shutdown);
            }));
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
        }
        for w in workers {
            let _ = w.join();
        }
        Ok(())
    }
}

fn serve_connection(
    stream: TcpStream,
    state: &Mutex<CoordinatorState>,
    shutdown: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_nodelay(true)?; // request/response protocol: defeat Nagle
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (resp, quit) = handle_request(state, &line);
        writer.write_all(resp.dump().as_bytes())?;
        writer.write_all(b"\n")?;
        if quit {
            shutdown.store(true, Ordering::SeqCst);
            // Nudge the accept loop with a dummy connection.
            let _ = TcpStream::connect(writer.local_addr()?);
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::sched::PolicyKind;

    fn state() -> Mutex<CoordinatorState> {
        Mutex::new(CoordinatorState::new(
            ClusterSpec::tiny(2, 4, 1).build(),
            PolicyKind::PwrFgd { alpha: 0.1 },
            Workload::default(),
        ))
    }

    #[test]
    fn submit_release_roundtrip() {
        let st = state();
        let (resp, _) =
            handle_request(&st, r#"{"op":"submit","id":1,"cpu":4,"mem":1024,"gpu":0.5}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert!(resp.get("node").is_some());
        {
            let s = st.lock().unwrap();
            assert_eq!(s.dc.n_tasks, 1);
        }
        let (resp, _) = handle_request(&st, r#"{"op":"release","id":1}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(st.lock().unwrap().dc.n_tasks, 0);
    }

    #[test]
    fn dsl_profile_serves_submissions() {
        let st = Mutex::new(CoordinatorState::new(
            ClusterSpec::tiny(2, 4, 1).build(),
            SchedulerProfile::parse("score(pwr=0.4,fgd=0.4,dotprod=0.2)|bind(weighted:0.4)")
                .unwrap(),
            Workload::default(),
        ));
        let (resp, _) =
            handle_request(&st, r#"{"op":"submit","id":1,"cpu":4,"mem":1024,"gpu":0.5}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn unschedulable_reported() {
        let st = state();
        let (resp, _) =
            handle_request(&st, r#"{"op":"submit","id":1,"cpu":4,"mem":0,"gpu":64}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(st.lock().unwrap().failed, 1);
    }

    #[test]
    fn mig_submit_release_roundtrip() {
        let st = Mutex::new(CoordinatorState::new(
            ClusterSpec::mig_cluster(2, 2, 0).build(),
            PolicyKind::MigPwrFgd { alpha: 0.1 },
            Workload::default(),
        ));
        let (resp, _) =
            handle_request(&st, r#"{"op":"submit","id":1,"cpu":4,"mem":1024,"mig":"3g"}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        // Placement reported as [gpu, start].
        let arr = resp.get("gpu").and_then(|g| g.as_arr()).expect("slice placement");
        assert_eq!(arr.len(), 2);
        let (resp, _) = handle_request(&st, r#"{"op":"release","id":1}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(st.lock().unwrap().dc.n_tasks, 0);
        // Unknown profile rejected.
        let (resp, _) =
            handle_request(&st, r#"{"op":"submit","id":2,"cpu":1,"mig":"5g"}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn stats_reports_power() {
        let st = state();
        let (resp, _) = handle_request(&st, r#"{"op":"stats"}"#);
        assert!(resp.get("eopc_w").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(resp.get("grar").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn metrics_request_serves_prometheus_text_for_every_catalog_key() {
        let st = state();
        let (_, _) =
            handle_request(&st, r#"{"op":"submit","id":1,"cpu":4,"mem":1024,"gpu":0.5}"#);
        let (resp, quit) = handle_request(&st, r#"{"op":"metrics"}"#);
        assert!(!quit);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(
            resp.get("format").and_then(|f| f.as_str()),
            Some("prometheus-text-0.0.4")
        );
        let body = resp.get("body").and_then(|b| b.as_str()).expect("body");
        // Every catalogued metric key must be present under the prefix.
        for (key, _, _) in crate::obs::catalog() {
            assert!(
                body.contains(&format!("repro_{key}")),
                "metrics body missing catalog key {key}"
            );
        }
        // Coordinator gauges ride along, and the submit above counted.
        assert!(body.contains("repro_coordinator_eopc_w"));
        assert!(body.contains("repro_sched_places 1"));
        // Well-formed exposition: every non-comment line is `name value`.
        for line in body.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let mut parts = line.split_whitespace();
            let name = parts.next().expect("metric name");
            let value = parts.next().expect("metric value");
            assert!(parts.next().is_none(), "trailing tokens in {line:?}");
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric()
                    || c == '_'
                    || c == ':'
                    || c == '{'
                    || c == '}'
                    || c == '"'
                    || c == '='
                    || c == '.'),
                "bad metric name {name:?}"
            );
            assert!(value.parse::<f64>().is_ok(), "bad value in {line:?}");
        }
    }

    #[test]
    fn pending_queue_drains_on_release_and_exports_gauges() {
        // One 4-GPU node: fill it, then the second submission parks in
        // the pending queue instead of vanishing.
        let st = Mutex::new(CoordinatorState::new(
            ClusterSpec::tiny(1, 4, 0).build(),
            PolicyKind::PwrFgd { alpha: 0.1 },
            Workload::default(),
        ));
        let (resp, _) =
            handle_request(&st, r#"{"op":"submit","id":1,"cpu":2,"mem":512,"gpu":4}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let (resp, _) = handle_request(
            &st,
            r#"{"op":"submit","id":2,"cpu":2,"mem":512,"gpu":4,"priority":3}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Parked, not lost: queue gauges are live in the metrics body
        // and the stats snapshot.
        let (resp, _) = handle_request(&st, r#"{"op":"metrics"}"#);
        let body = resp.get("body").and_then(|b| b.as_str()).expect("body");
        assert!(body.contains("repro_pending_depth 1"), "missing live pending gauge");
        assert!(body.contains("repro_pending_enqueues 1"));
        let (resp, _) = handle_request(&st, r#"{"op":"stats"}"#);
        assert_eq!(resp.get("pending").and_then(|p| p.as_f64()), Some(1.0));
        // The departure frees the node; the queued task places and can
        // then be released like any other allocation.
        let (resp, _) = handle_request(&st, r#"{"op":"release","id":1}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        {
            let s = st.lock().unwrap();
            assert_eq!(s.dc.n_tasks, 1);
            assert_eq!(s.scheduled, 2);
            assert_eq!(s.fairness.with_core(|c| c.pending_depth()), 0);
        }
        let (resp, _) = handle_request(&st, r#"{"op":"release","id":2}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(st.lock().unwrap().dc.n_tasks, 0);
        // Out-of-range / fractional priorities are rejected at parse.
        for bad in [
            r#"{"op":"submit","id":3,"cpu":1,"priority":300}"#,
            r#"{"op":"submit","id":3,"cpu":1,"priority":1.5}"#,
            r#"{"op":"submit","id":3,"cpu":1,"priority":-1}"#,
        ] {
            let (resp, _) = handle_request(&st, bad);
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "accepted {bad}");
        }
    }

    #[test]
    fn malformed_requests_rejected() {
        let st = state();
        let (resp, _) = handle_request(&st, "not json");
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        let (resp, _) = handle_request(&st, r#"{"op":"nope"}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        let (resp, _) = handle_request(&st, r#"{"op":"submit","id":1,"cpu":1,"gpu":1.5}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn tcp_end_to_end() {
        let server = Server::bind(
            "127.0.0.1:0",
            CoordinatorState::new(
                ClusterSpec::tiny(2, 4, 1).build(),
                PolicyKind::Pwr,
                Workload::default(),
            ),
        )
        .unwrap();
        let port = server.port();
        let handle = std::thread::spawn(move || server.run().unwrap());
        let mut conn = TcpStream::connect(("127.0.0.1", port)).unwrap();
        conn.write_all(b"{\"op\":\"submit\",\"id\":7,\"cpu\":2,\"mem\":512,\"gpu\":1}\n")
            .unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = parse(line.trim()).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
        conn.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        handle.join().unwrap();
    }
}
