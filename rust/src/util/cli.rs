//! Tiny CLI argument parser (no `clap` in the offline vendor set).
//!
//! Supports the shapes the `repro` binary needs:
//! `repro <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, `--key value` options, bare `--flag`s
/// and positional arguments, in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Keys that take a value; anything else starting with `--` is a flag.
pub fn parse_args<I: IntoIterator<Item = String>>(argv: I, value_keys: &[&str]) -> Args {
    let mut args = Args::default();
    let mut it = argv.into_iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            // --key=value form
            if let Some((k, v)) = key.split_once('=') {
                args.options.insert(k.to_string(), v.to_string());
            } else if value_keys.contains(&key) {
                match it.next() {
                    Some(v) => {
                        args.options.insert(key.to_string(), v);
                    }
                    None => {
                        args.flags.push(key.to_string());
                    }
                }
            } else {
                args.flags.push(key.to_string());
            }
        } else if args.subcommand.is_none() && args.positional.is_empty() {
            args.subcommand = Some(a);
        } else {
            args.positional.push(a);
        }
    }
    args
}

impl Args {
    /// String option with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// `f64` option with default; panics with a clear message on junk.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        match self.options.get(key) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")),
        }
    }

    /// `u64` option with default.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        match self.options.get(key) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")),
        }
    }

    /// `usize` option with default.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get_u64(key, default as u64) as usize
    }

    /// Presence of a bare flag.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        parse_args(s.split_whitespace().map(String::from), &["seed", "out", "alpha", "policy"])
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("experiment --seed 42 --out results/fig1.csv fig1");
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.get_u64("seed", 0), 42);
        assert_eq!(a.get("out", ""), "results/fig1.csv");
        assert_eq!(a.positional, vec!["fig1"]);
    }

    #[test]
    fn eq_form_and_flags() {
        let a = parse("simulate --alpha=0.1 --verbose");
        assert_eq!(a.get_f64("alpha", 0.0), 0.1);
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("simulate");
        assert_eq!(a.get_f64("alpha", 0.25), 0.25);
        assert_eq!(a.get("policy", "fgd"), "fgd");
    }

    #[test]
    #[should_panic(expected = "expects a number")]
    fn bad_number_panics() {
        let a = parse("simulate --alpha junk");
        a.get_f64("alpha", 0.0);
    }
}
