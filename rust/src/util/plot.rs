//! Minimal SVG line-chart renderer (no plotting library offline).
//!
//! Turns the experiment CSVs (first column = x, remaining columns =
//! series) into self-contained SVG files so the regenerated figures are
//! directly viewable: `repro plot results/fig3_default.csv`.

use std::fmt::Write as _;

/// Chart configuration.
#[derive(Clone, Debug)]
pub struct PlotConfig {
    pub width: f64,
    pub height: f64,
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    /// Clamp y to this range if set (e.g. GRAR plots zoom on [0.9, 1]).
    pub y_range: Option<(f64, f64)>,
    /// Restrict x to this range if set.
    pub x_range: Option<(f64, f64)>,
}

impl Default for PlotConfig {
    fn default() -> Self {
        PlotConfig {
            width: 860.0,
            height: 460.0,
            title: String::new(),
            x_label: "requested GPU capacity".into(),
            y_label: String::new(),
            y_range: None,
            x_range: None,
        }
    }
}

const COLORS: [&str; 10] = [
    "#1f77b4", "#ff7f0e", "#2ca02c", "#d62728", "#9467bd", "#8c564b", "#e377c2",
    "#7f7f7f", "#bcbd22", "#17becf",
];

const MARGIN_L: f64 = 64.0;
const MARGIN_R: f64 = 180.0;
const MARGIN_T: f64 = 36.0;
const MARGIN_B: f64 = 46.0;

/// Render one chart: `series` is a list of (name, points) with shared x.
pub fn render_lines(cfg: &PlotConfig, series: &[(String, Vec<(f64, f64)>)]) -> String {
    let plot_w = cfg.width - MARGIN_L - MARGIN_R;
    let plot_h = cfg.height - MARGIN_T - MARGIN_B;

    // Data extents.
    let mut pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|(_, p)| p.iter().copied())
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if let Some((lo, hi)) = cfg.x_range {
        pts.retain(|(x, _)| *x >= lo && *x <= hi);
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if let Some((lo, hi)) = cfg.x_range {
        x0 = lo;
        x1 = hi;
    }
    if let Some((lo, hi)) = cfg.y_range {
        y0 = lo;
        y1 = hi;
    }
    if !x0.is_finite() || x1 - x0 < 1e-12 {
        x0 = 0.0;
        x1 = 1.0;
    }
    if !y0.is_finite() || y1 - y0 < 1e-12 {
        y0 -= 0.5;
        y1 += 0.5;
    }
    // A little y headroom.
    let pad = (y1 - y0) * 0.05;
    let (y0, y1) = match cfg.y_range {
        Some(r) => r,
        None => (y0 - pad, y1 + pad),
    };

    let sx = move |x: f64| MARGIN_L + (x - x0) / (x1 - x0) * plot_w;
    let sy = move |y: f64| MARGIN_T + (1.0 - (y - y0) / (y1 - y0)) * plot_h;

    let mut svg = String::new();
    let _ = write!(
        svg,
        r##"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" viewBox="0 0 {w} {h}" font-family="sans-serif" font-size="12">
<rect width="{w}" height="{h}" fill="white"/>
<text x="{tx}" y="20" text-anchor="middle" font-size="15" font-weight="bold">{title}</text>
"##,
        w = cfg.width,
        h = cfg.height,
        tx = MARGIN_L + plot_w / 2.0,
        title = escape(&cfg.title),
    );

    // Gridlines + ticks (5 divisions each way).
    for i in 0..=5 {
        let fx = x0 + (x1 - x0) * i as f64 / 5.0;
        let fy = y0 + (y1 - y0) * i as f64 / 5.0;
        let px = sx(fx);
        let py = sy(fy);
        let _ = write!(
            svg,
            r##"<line x1="{px:.1}" y1="{t:.1}" x2="{px:.1}" y2="{b:.1}" stroke="#ddd"/>
<text x="{px:.1}" y="{lb:.1}" text-anchor="middle" fill="#444">{fx}</text>
<line x1="{l:.1}" y1="{py:.1}" x2="{r:.1}" y2="{py:.1}" stroke="#ddd"/>
<text x="{ll:.1}" y="{pyt:.1}" text-anchor="end" fill="#444">{fy}</text>
"##,
            t = MARGIN_T,
            b = MARGIN_T + plot_h,
            lb = MARGIN_T + plot_h + 18.0,
            l = MARGIN_L,
            r = MARGIN_L + plot_w,
            ll = MARGIN_L - 8.0,
            pyt = py + 4.0,
            fx = trim_num(fx),
            fy = trim_num(fy),
        );
    }
    // Axes labels.
    let _ = write!(
        svg,
        r##"<text x="{cx:.1}" y="{by:.1}" text-anchor="middle" fill="#222">{xl}</text>
<text x="16" y="{cy:.1}" text-anchor="middle" transform="rotate(-90 16 {cy:.1})" fill="#222">{yl}</text>
"##,
        cx = MARGIN_L + plot_w / 2.0,
        by = cfg.height - 8.0,
        cy = MARGIN_T + plot_h / 2.0,
        xl = escape(&cfg.x_label),
        yl = escape(&cfg.y_label),
    );

    // Series.
    for (si, (name, points)) in series.iter().enumerate() {
        let color = COLORS[si % COLORS.len()];
        let mut path = String::new();
        for &(x, y) in points {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            if let Some((lo, hi)) = cfg.x_range {
                if x < lo || x > hi {
                    continue;
                }
            }
            let cmd = if path.is_empty() { 'M' } else { 'L' };
            let yc = y.clamp(y0, y1);
            let _ = write!(path, "{cmd}{:.1},{:.1} ", sx(x), sy(yc));
        }
        let _ = write!(
            svg,
            r##"<path d="{path}" fill="none" stroke="{color}" stroke-width="1.8"/>
"##
        );
        // Legend entry.
        let ly = MARGIN_T + 10.0 + si as f64 * 18.0;
        let lx = MARGIN_L + plot_w + 12.0;
        let _ = write!(
            svg,
            r##"<line x1="{lx:.1}" y1="{ly:.1}" x2="{x2:.1}" y2="{ly:.1}" stroke="{color}" stroke-width="2.5"/>
<text x="{tx:.1}" y="{ty:.1}" fill="#222">{name}</text>
"##,
            x2 = lx + 22.0,
            tx = lx + 28.0,
            ty = ly + 4.0,
            name = escape(name),
        );
    }
    // Frame.
    let _ = write!(
        svg,
        r##"<rect x="{l:.1}" y="{t:.1}" width="{pw:.1}" height="{ph:.1}" fill="none" stroke="#333"/>
</svg>
"##,
        l = MARGIN_L,
        t = MARGIN_T,
        pw = plot_w,
        ph = plot_h,
    );
    svg
}

/// Plot an experiment CSV (col 0 = x) to SVG.
pub fn plot_csv(csv_text: &str, cfg: &PlotConfig) -> String {
    let (header, rows) = crate::util::csv::read_csv(csv_text);
    let mut series: Vec<(String, Vec<(f64, f64)>)> = header
        .iter()
        .skip(1)
        .map(|h| (h.clone(), Vec::new()))
        .collect();
    for row in &rows {
        let Some(x) = row.first().and_then(|v| v.parse::<f64>().ok()) else { continue };
        for (i, s) in series.iter_mut().enumerate() {
            if let Some(y) = row.get(i + 1).and_then(|v| v.parse::<f64>().ok()) {
                s.1.push((x, y));
            }
        }
    }
    render_lines(cfg, &series)
}

fn escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

fn trim_num(v: f64) -> String {
    if v.abs() >= 100.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_valid_svg() {
        let cfg = PlotConfig { title: "test".into(), ..Default::default() };
        let svg = render_lines(
            &cfg,
            &[
                ("a".into(), vec![(0.0, 1.0), (1.0, 2.0)]),
                ("b".into(), vec![(0.0, 2.0), (1.0, 0.5)]),
            ],
        );
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>\n"));
        assert_eq!(svg.matches("<path").count(), 2);
        assert!(svg.contains(">a</text>"));
    }

    #[test]
    fn plot_csv_parses_all_columns() {
        let csv = "x,p1,p2\n0,1,4\n0.5,2,5\n1,3,6\n";
        let svg = plot_csv(csv, &PlotConfig::default());
        assert_eq!(svg.matches("<path").count(), 2);
        assert!(svg.contains(">p1</text>") && svg.contains(">p2</text>"));
    }

    #[test]
    fn y_range_clamps() {
        let cfg = PlotConfig { y_range: Some((0.9, 1.0)), ..Default::default() };
        let svg = render_lines(&cfg, &[("a".into(), vec![(0.0, 0.5), (1.0, 0.95)])]);
        assert!(svg.contains("<path"));
    }

    #[test]
    fn degenerate_inputs_do_not_panic() {
        let cfg = PlotConfig::default();
        let _ = render_lines(&cfg, &[("empty".into(), vec![])]);
        let _ = render_lines(&cfg, &[("flat".into(), vec![(0.0, 1.0), (1.0, 1.0)])]);
        let _ = plot_csv("x\n1\n", &cfg);
    }
}
