//! Criterion-style micro/meso benchmark harness (the offline vendor set
//! has no `criterion`). Provides warmup, timed iterations, simple
//! statistics (mean/median/p95), throughput reporting, and CSV output so
//! `cargo bench` produces comparable, recordable numbers for
//! EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

use crate::util::stats;

/// One benchmark's configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Minimum wall time spent in warmup.
    pub warmup: Duration,
    /// Minimum wall time spent measuring.
    pub measure: Duration,
    /// Cap on measured samples.
    pub max_samples: usize,
    /// Floor on measured samples (even if over time budget).
    pub min_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            max_samples: 200,
            min_samples: 10,
        }
    }
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        stats::mean(&self.samples_ns)
    }
    pub fn median_ns(&self) -> f64 {
        stats::median(&self.samples_ns)
    }
    pub fn p50_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 50.0)
    }
    pub fn p95_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 95.0)
    }
    pub fn p99_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 99.0)
    }
    pub fn stddev_ns(&self) -> f64 {
        stats::stddev(&self.samples_ns)
    }
    /// items/s if `items_per_iter` was set.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / (self.mean_ns() * 1e-9))
    }

    fn report_line(&self) -> String {
        let mut s = format!(
            "{:<44} {:>12} median {:>12} mean {:>12} p95 (n={})",
            self.name,
            fmt_ns(self.median_ns()),
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p95_ns()),
            self.samples_ns.len()
        );
        if let Some(tp) = self.throughput() {
            s.push_str(&format!("  [{} items/s]", fmt_count(tp)));
        }
        s
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn fmt_count(x: f64) -> String {
    if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.1}k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Benchmark group: collects results, prints a report, writes CSV.
pub struct Bencher {
    config: BenchConfig,
    results: Vec<BenchResult>,
    /// Substring filter from argv (cargo bench passes it through).
    filter: Option<String>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    /// Build with the default config and the argv filter, honouring
    /// `REPRO_BENCH_FAST=1` (CI smoke mode: much shorter windows).
    pub fn new() -> Bencher {
        Self::with_config(BenchConfig::default())
    }

    /// Build with an explicit config (macro-benchmarks with multi-second
    /// iterations pass smaller sample floors).
    pub fn with_config(mut config: BenchConfig) -> Bencher {
        if std::env::var("REPRO_BENCH_FAST").as_deref() == Ok("1") {
            config.warmup = Duration::from_millis(20);
            config.measure = Duration::from_millis(150);
            config.max_samples = 20;
            config.min_samples = 3;
        }
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-') && a != "--bench");
        Bencher { config, results: Vec::new(), filter }
    }

    /// Build **without** the argv substring filter. `cargo bench`
    /// passes a name filter as the first bare argument, but when the
    /// harness is embedded in a `repro` subcommand (`repro
    /// bench-scale`) that argument is the subcommand itself and would
    /// silently skip every benchmark. Still honours
    /// `REPRO_BENCH_FAST=1` via [`Self::with_config`]'s window
    /// shrinking.
    pub fn unfiltered(config: BenchConfig) -> Bencher {
        let mut b = Self::with_config(config);
        b.filter = None;
        b
    }

    fn skipped(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => !name.contains(f.as_str()),
            None => false,
        }
    }

    /// Time `f` repeatedly. `f` should perform one logical iteration and
    /// return a value (consumed with `black_box` semantics).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        self.bench_with_items(name, None, &mut f)
    }

    /// Like [`Self::bench`], reporting `items` per iteration throughput.
    pub fn bench_items<T>(&mut self, name: &str, items: f64, mut f: impl FnMut() -> T) {
        self.bench_with_items(name, Some(items), &mut f)
    }

    fn bench_with_items<T>(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) {
        if self.skipped(name) {
            return;
        }
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.config.warmup {
            black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.config.measure && samples.len() < self.config.max_samples)
            || samples.len() < self.config.min_samples
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            samples_ns: samples,
            items_per_iter: items,
        };
        println!("{}", result.report_line());
        self.results.push(result);
    }

    /// Write all results as CSV (appends directory creation).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut w = crate::util::csv::CsvWriter::create(
            path,
            &["name", "median_ns", "mean_ns", "p95_ns", "stddev_ns", "samples", "items_per_s"],
        )?;
        for r in &self.results {
            w.row_str(&[
                r.name.clone(),
                format!("{:.1}", r.median_ns()),
                format!("{:.1}", r.mean_ns()),
                format!("{:.1}", r.p95_ns()),
                format!("{:.1}", r.stddev_ns()),
                format!("{}", r.samples_ns.len()),
                r.throughput().map(|t| format!("{t:.1}")).unwrap_or_default(),
            ])?;
        }
        w.flush()
    }

    /// Access collected results (tests).
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// A conditionally-armed phase timer for the scheduler's phase-latency
/// profiling ([`crate::obs`]): `start(false)` is a no-op that never
/// reads the clock, so the disabled path costs one branch on a `Copy`
/// option — zero-cost enough to live permanently inside
/// `Scheduler::schedule`'s hot loop.
#[derive(Clone, Copy, Debug)]
pub struct PhaseTimer(Option<Instant>);

impl PhaseTimer {
    /// Arm the timer iff `enabled`.
    #[inline]
    pub fn start(enabled: bool) -> PhaseTimer {
        PhaseTimer(if enabled { Some(Instant::now()) } else { None })
    }

    /// Elapsed nanoseconds since `start`; `None` when unarmed.
    #[inline]
    pub fn stop_ns(self) -> Option<f64> {
        self.0.map(|t0| t0.elapsed().as_nanos() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> Bencher {
        Bencher {
            config: BenchConfig {
                warmup: Duration::from_millis(1),
                measure: Duration::from_millis(5),
                max_samples: 10,
                min_samples: 3,
            },
            results: Vec::new(),
            filter: None,
        }
    }

    #[test]
    fn collects_samples() {
        let mut b = fast();
        b.bench("noop", || 1 + 1);
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].samples_ns.len() >= 3);
        assert!(b.results()[0].mean_ns() >= 0.0);
    }

    #[test]
    fn throughput_reported() {
        let mut b = fast();
        b.bench_items("items", 100.0, || std::thread::sleep(Duration::from_micros(50)));
        let tp = b.results()[0].throughput().unwrap();
        assert!(tp > 0.0);
    }

    #[test]
    fn filter_skips() {
        let mut b = fast();
        b.filter = Some("beta".into());
        b.bench("alpha-xyz", || 0);
        b.bench("beta-abc", || 0);
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].name, "beta-abc");
    }

    #[test]
    fn phase_timer_disabled_is_inert() {
        let t = PhaseTimer::start(false);
        assert!(t.stop_ns().is_none());
        let t = PhaseTimer::start(true);
        let ns = t.stop_ns().expect("armed timer reports");
        assert!(ns >= 0.0);
    }

    #[test]
    fn unfiltered_ignores_argv() {
        // Under `cargo test` argv carries bare filter tokens; the
        // unfiltered constructor must run everything regardless.
        let mut b = Bencher::unfiltered(BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(2),
            max_samples: 5,
            min_samples: 1,
        });
        b.bench("anything-goes", || 0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(12.0), "12.0 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_count(1.2e6), "1.20M");
    }
}
