//! Seedable pseudo-random number generation.
//!
//! Implements SplitMix64 (for seeding) and xoshiro256** 1.0 (Blackman &
//! Vigna, public domain) from scratch — the offline build has no `rand`
//! crate. All simulation randomness flows through [`Rng`], so every
//! experiment is reproducible from a single `u64` seed, and the ten
//! repetitions the paper averages over are seeds `base..base+10`.

/// SplitMix64 step — used to expand a single `u64` seed into the
/// 256-bit xoshiro state and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator. Fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (used to give each simulation
    /// repetition its own stream without coupling).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. Uses Lemire-style rejection to avoid
    /// modulo bias.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)` (half-open).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + self.below(hi - lo)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index according to unnormalized `weights`.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() with zero total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1 // numerical slack
    }

    /// Uniformly pick one element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Precomputed cumulative table for repeated weighted sampling
/// (the trace sampler draws millions of tasks; O(log k) per draw).
#[derive(Clone, Debug)]
pub struct WeightedIndex {
    cumulative: Vec<f64>,
}

impl WeightedIndex {
    /// Build from unnormalized weights. Panics on a non-positive total.
    pub fn new(weights: &[f64]) -> Self {
        let mut cumulative = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            assert!(w >= 0.0, "negative weight");
            acc += w;
            cumulative.push(acc);
        }
        assert!(acc > 0.0, "zero total weight");
        WeightedIndex { cumulative }
    }

    /// Draw an index.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let total = *self.cumulative.last().unwrap();
        let x = rng.f64() * total;
        // binary search for first cumulative > x
        match self
            .cumulative
            .binary_search_by(|c| c.partial_cmp(&x).unwrap())
        {
            Ok(i) => (i + 1).min(self.cumulative.len() - 1),
            Err(i) => i,
        }
    }

    /// Number of categories.
    pub fn len(&self) -> usize {
        self.cumulative.len()
    }

    /// True when empty (never for a constructed table).
    pub fn is_empty(&self) -> bool {
        self.cumulative.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_is_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn weighted_matches_weights() {
        let mut r = Rng::new(13);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        for _ in 0..100_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert!((counts[0] as f64 / 100_000.0 - 0.1).abs() < 0.01);
        assert!((counts[1] as f64 / 100_000.0 - 0.3).abs() < 0.01);
        assert!((counts[2] as f64 / 100_000.0 - 0.6).abs() < 0.01);
    }

    #[test]
    fn weighted_index_matches_direct() {
        let mut r = Rng::new(17);
        let w = [0.5, 0.25, 0.25];
        let idx = WeightedIndex::new(&w);
        let mut counts = [0usize; 3];
        for _ in 0..100_000 {
            counts[idx.sample(&mut r)] += 1;
        }
        assert!((counts[0] as f64 / 100_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
