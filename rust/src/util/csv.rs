//! CSV writing (and a small reader) for experiment results.
//!
//! Every figure/table harness emits a CSV under `results/`; the reader is
//! used by tests that round-trip harness output.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    out: Box<dyn Write>,
    cols: usize,
}

impl CsvWriter {
    /// Create a file-backed writer; parent dirs are created as needed.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let f = BufWriter::new(File::create(path)?);
        Self::from_writer(Box::new(f), header)
    }

    /// Create a writer over any sink (used by tests).
    pub fn from_writer(mut out: Box<dyn Write>, header: &[&str]) -> std::io::Result<CsvWriter> {
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, cols: header.len() })
    }

    /// Write a numeric row (checked against the header arity).
    pub fn row(&mut self, values: &[f64]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "row arity mismatch");
        let line: Vec<String> = values.iter().map(|v| format_num(*v)).collect();
        writeln!(self.out, "{}", line.join(","))
    }

    /// Write a row of preformatted string fields.
    pub fn row_str(&mut self, values: &[String]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "row arity mismatch");
        let quoted: Vec<String> = values.iter().map(|v| quote(v)).collect();
        writeln!(self.out, "{}", quoted.join(","))
    }

    /// Flush the sink.
    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

fn format_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6}")
    }
}

fn quote(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Parse a simple CSV document into (header, rows of strings).
/// Handles quoted fields with embedded commas/quotes; no embedded
/// newlines inside quoted fields (our writers never emit them).
pub fn read_csv(text: &str) -> (Vec<String>, Vec<Vec<String>>) {
    let mut lines = text.lines().filter(|l| !l.is_empty());
    let header = lines.next().map(split_line).unwrap_or_default();
    let rows = lines.map(split_line).collect();
    (header, rows)
}

fn split_line(line: &str) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                fields.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    fields.push(cur);
    fields
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let buf: Vec<u8> = Vec::new();
        let cell = std::sync::Arc::new(std::sync::Mutex::new(buf));
        struct Sink(std::sync::Arc<std::sync::Mutex<Vec<u8>>>);
        impl Write for Sink {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut w =
            CsvWriter::from_writer(Box::new(Sink(cell.clone())), &["x", "y"]).unwrap();
        w.row(&[1.0, 2.5]).unwrap();
        w.row(&[3.0, 4.0]).unwrap();
        w.flush().unwrap();
        let text = String::from_utf8(cell.lock().unwrap().clone()).unwrap();
        assert_eq!(text, "x,y\n1,2.500000\n3,4\n");
    }

    #[test]
    fn roundtrip_read() {
        let (h, rows) = read_csv("a,b\n1,2\n3,4\n");
        assert_eq!(h, vec!["a", "b"]);
        assert_eq!(rows, vec![vec!["1", "2"], vec!["3", "4"]]);
    }

    #[test]
    fn quoted_fields() {
        let (_, rows) = read_csv("a,b\n\"x,y\",\"he said \"\"hi\"\"\"\n");
        assert_eq!(rows[0][0], "x,y");
        assert_eq!(rows[0][1], "he said \"hi\"");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut w = CsvWriter::from_writer(Box::new(std::io::sink()), &["a", "b"]).unwrap();
        let _ = w.row(&[1.0]);
    }
}
