//! Self-contained utility substrates.
//!
//! The build environment is fully offline with a minimal vendored crate
//! set (`xla`, `anyhow`), so every supporting library the system needs is
//! implemented here from scratch: a seedable PRNG ([`rng`]), a JSON
//! encoder/decoder ([`json`]), a CSV writer ([`csv`]), descriptive
//! statistics ([`stats`]), a tiny CLI argument parser ([`cli`]), and a
//! criterion-style micro-benchmark harness ([`benchkit`]).

pub mod benchkit;
pub mod cli;
pub mod csv;
pub mod json;
pub mod plot;
pub mod rng;
pub mod stats;
