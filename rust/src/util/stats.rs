//! Descriptive statistics over `f64` samples.
//!
//! Used by the metrics recorders (averaging the paper's 10 repetitions),
//! the benchmark harness, and the experiment harness.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0.0 for <2 samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Linear-interpolated percentile, `p` in `[0, 100]`. Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Minimum; NaN-free input assumed. 0.0 for an empty slice.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min).min(f64::INFINITY)
}

/// Maximum; 0.0 for an empty slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Linear interpolation of `ys` (sampled at ascending `xs`) at `x`.
/// Clamps outside the domain. Core of the savings-vs-FGD computation,
/// which compares two EOPC series on a common capacity grid.
pub fn interp(xs: &[f64], ys: &[f64], x: f64) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    if x <= xs[0] {
        return ys[0];
    }
    if x >= xs[xs.len() - 1] {
        return ys[ys.len() - 1];
    }
    // binary search for the bracketing interval
    let mut lo = 0;
    let mut hi = xs.len() - 1;
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if xs[mid] <= x {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let w = (x - xs[lo]) / (xs[hi] - xs[lo]);
    ys[lo] * (1.0 - w) + ys[hi] * w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn stddev_basic() {
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.138089935).abs() < 1e-6);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&v, 50.0), 2.5);
    }

    #[test]
    fn median_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn interp_basic() {
        let xs = [0.0, 1.0, 2.0];
        let ys = [0.0, 10.0, 40.0];
        assert_eq!(interp(&xs, &ys, 0.5), 5.0);
        assert_eq!(interp(&xs, &ys, 1.5), 25.0);
        assert_eq!(interp(&xs, &ys, -1.0), 0.0); // clamp
        assert_eq!(interp(&xs, &ys, 9.0), 40.0); // clamp
    }

    #[test]
    fn min_max_basic() {
        assert_eq!(max(&[1.0, 5.0, 2.0]), 5.0);
        assert_eq!(min(&[1.0, 5.0, 2.0]), 1.0);
    }
}
