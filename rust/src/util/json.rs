//! Minimal JSON encoder/decoder (RFC 8259 subset).
//!
//! The offline build has no `serde`/`serde_json`, so the coordinator's
//! JSON-lines wire protocol and the experiment config files use this
//! self-contained implementation. Supports the full JSON value model;
//! numbers are `f64` (adequate for the protocol — ids fit in 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Field lookup on an object; `None` otherwise.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer accessor (rejects non-integral numbers).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize to a compact string.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { at: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: join if a low surrogate follows.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                            .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("invalid codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Reassemble multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf8"))?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.dump()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":null}],"c":"x\ny","d":true}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.dump()).unwrap(), v);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parses_whitespace() {
        let v = parse(" { \"a\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nulll").is_err());
        assert!(parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
        // surrogate pair for 😀
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo → 🚀\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 🚀");
        assert_eq!(parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(parse("-2.5E-1").unwrap().as_f64().unwrap(), -0.25);
        assert_eq!(parse("9007199254740991").unwrap().as_u64().unwrap(), 9007199254740991);
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
    }

    #[test]
    fn deterministic_object_order() {
        let v = Json::obj(vec![("z", Json::Num(1.0)), ("a", Json::Num(2.0))]);
        assert_eq!(v.dump(), r#"{"a":2,"z":1}"#);
    }
}
