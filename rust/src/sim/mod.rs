//! The event-driven cluster simulator (the open-simulator analog).
//!
//! [`Simulation`] drives the paper's Monte-Carlo workload inflation
//! (§V-A): tasks are sampled from a trace with replacement and submitted
//! one at a time — each scheduling decision is atomic (§II) — until the
//! cumulative arrived GPU requests reach a target multiple of the
//! cluster's GPU capacity. Metrics are sampled on a fixed capacity grid.
//!
//! Each submission runs the scheduler's full
//! [`place`](crate::sched::Scheduler::place) protocol, so profile hooks
//! (e.g. the MIG repartitioner) execute structurally — the loop cannot
//! silently skip them.
//!
//! [`run_repetitions`] runs the paper's 10 seeded repetitions (in
//! parallel threads — each repetition owns its own datacenter, scheduler
//! and sampler) and returns the per-run series for grid averaging.

pub mod events;

use crate::cluster::Datacenter;
use crate::frag;
use crate::metrics::{RunSeries, SeriesPoint};
use crate::obs::{DecisionTracer, TraceSink};
use crate::power;
use crate::sched::policies::{MigRepartitioner, RepartitionConfig};
use crate::sched::{Scheduler, SchedulerProfile};
use crate::tasks::Workload;
use crate::trace::{Trace, TraceSpec};

/// Safety cap on submitted tasks per run (the Default trace saturates
/// the paper cluster after ~8.5k GPU tasks; CPU-heavy traces could
/// otherwise inflate forever).
pub const MAX_TASKS: usize = 400_000;

/// Default metric-sampling resolution on the capacity axis.
pub const SAMPLE_STEP: f64 = 0.005;

/// Outcome of one inflation run.
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub series: RunSeries,
    /// Tasks submitted / scheduled / failed.
    pub submitted: u64,
    pub scheduled: u64,
    pub failed: u64,
    /// Final GPU units arrived and allocated.
    pub arrived_gpu_units: f64,
    pub allocated_gpu_units: f64,
    /// MIG repartitioning activity (zero without a repartition hook):
    /// reactive (failure-triggered) and proactive (threshold-triggered)
    /// repacks plus total migrated slices.
    pub repartitions: u64,
    pub proactive_repartitions: u64,
    pub migrated_slices: u64,
    /// Failures attributed to declarative constraints: some node had
    /// the resources but a `filter` constraint (model set / selector /
    /// affinity / spread) forbade every admissible placement (see
    /// [`crate::sched::Scheduler::constraint_unschedulable`]).
    pub constraint_unschedulable: u64,
    /// DRS sleep/wake activity (zero without a `drs` hook; see
    /// [`crate::sched::drs`]).
    pub drs_sleeps: u64,
    pub drs_wakes: u64,
    /// Gang scheduling activity (zero on gang-free traces; see
    /// [`crate::sched::gang`]): gangs atomically committed / failed,
    /// members whose placement is not one whole-GPU TP group on a
    /// single node (must stay 0 — `ext-gang` asserts it), and the sum
    /// of distinct-node spans over placed gangs (mean span =
    /// `gang_pp_span_sum / gangs_placed`).
    pub gangs_placed: u64,
    pub gangs_failed: u64,
    pub gang_tp_violations: u64,
    pub gang_pp_span_sum: u64,
    /// Fairness pending-queue state at end of run (all zero unless
    /// [`Simulation::enable_fairness`] was called; see
    /// [`crate::sched::fairness`]). The inflation loop's clock is the
    /// arrival count, so waits are measured in arrivals.
    pub pending_depth: u64,
    pub p99_wait: f64,
    pub oldest_pending_age: f64,
    pub starvation_events: u64,
    pub pending_enqueues: u64,
    pub pending_drains: u64,
    /// Residents evicted by the `preempt` postFail hook (and requeued).
    pub preemptions: u64,
}

impl RunResult {
    /// EOPC at the end of inflation (W).
    pub fn final_eopc(&self) -> f64 {
        self.series.last().map(|p| p.eopc).unwrap_or(0.0)
    }

    /// GRAR at the end of inflation.
    pub fn final_grar(&self) -> f64 {
        if self.arrived_gpu_units > 0.0 {
            self.allocated_gpu_units / self.arrived_gpu_units
        } else {
            1.0
        }
    }
}

/// One online-scheduling simulation.
pub struct Simulation {
    pub dc: Datacenter,
    pub sched: Scheduler,
    pub workload: Workload,
    sampler: crate::trace::InflationSampler,
    arrived_gpu_units: f64,
    /// Arrived GPU units per MIG lattice — the denominator of the
    /// per-lattice GRAR columns (indexed by `MigLattice::index()`).
    arrived_mig_units: [f64; 2],
    failed: u64,
    scheduled: u64,
    submitted: u64,
    /// Record full `F_dc` series (O(N·M) per sample; off for benches).
    pub record_frag: bool,
    /// Fairness pending queue (`None` = historical drop behavior,
    /// bit-identical to pre-fairness runs).
    fairness: Option<crate::sched::FairnessState>,
}

impl Simulation {
    /// Build a simulation: the workload `M` is extracted from a
    /// materialization of the trace (as FGD derives `M` from historical
    /// data), and arrivals are sampled with replacement from the spec.
    pub fn new(dc: Datacenter, sched: Scheduler, trace: &Trace, seed: u64) -> Simulation {
        let workload = trace.workload();
        // Re-derive the generating spec from the trace name; arrivals
        // stream from the spec's catalog (statistically identical to
        // resampling the materialized trace with replacement).
        let spec = TraceSpec::by_name(&trace.name).unwrap_or_else(TraceSpec::default_trace);
        Simulation::with_spec(dc, sched, &spec, workload, seed)
    }

    /// Build directly from a [`TraceSpec`] and a prepared workload.
    pub fn with_spec(
        dc: Datacenter,
        mut sched: Scheduler,
        spec: &TraceSpec,
        workload: Workload,
        seed: u64,
    ) -> Simulation {
        sched.reseed_ties(seed); // independent tie-break stream per rep
        Simulation {
            dc,
            sched,
            workload,
            sampler: spec.sampler(seed),
            arrived_gpu_units: 0.0,
            arrived_mig_units: [0.0; 2],
            failed: 0,
            scheduled: 0,
            submitted: 0,
            record_frag: true,
            fairness: None,
        }
    }

    /// Switch the run from drop-on-failure to the fairness pending
    /// queue ([`crate::sched::fairness`]): failed non-gang arrivals
    /// enqueue and are retried at every subsequent arrival (the
    /// inflation loop's capacity tick), and the scheduler's plugins get
    /// the shared core (arming `mod(starve:…)` / `hook(preempt:…)` if
    /// the profile carries them). Gang arrivals keep the legacy
    /// all-or-nothing drop (queueing partial gangs is future work).
    pub fn enable_fairness(&mut self, cfg: crate::sched::FairnessConfig) {
        let fs = crate::sched::FairnessState::new(cfg);
        self.sched.bind_fairness(fs.shared());
        self.fairness = Some(fs);
    }

    /// Shared fairness core, when enabled (tests/diagnostics).
    pub fn fairness_shared(&self) -> Option<&crate::sched::FairnessShared> {
        self.fairness.as_ref().map(|f| f.shared())
    }

    /// Retry queued tasks in priority/FIFO order until one fails (no
    /// bypass) or the queue empties. The inflation clock is the arrival
    /// count. Never holds the core lock across a `place` call — the
    /// preempt hook re-locks the core from inside the postFail phase.
    fn drain_pending(&mut self) {
        let Some(fs) = &self.fairness else { return };
        fs.set_now(self.submitted as f64);
        loop {
            let Some(task) = fs.with_core(|c| c.head()) else { break };
            let Some(d) = self.sched.place(&mut self.dc, &self.workload, &task) else {
                break;
            };
            let requeued =
                fs.with_core(|c| c.pop_placed()).map(|e| e.requeued).unwrap_or(false);
            if !requeued {
                self.scheduled += 1;
            }
            fs.with_core(|c| c.note_resident(&task, d.node, &d.placement));
            // The placement may itself have preempted lower-priority
            // residents; move them from the outbox into the queue.
            fs.with_core(|c| {
                c.requeue_evicted();
            });
        }
    }

    /// Submit one sampled task; returns whether it was scheduled. The
    /// whole per-task protocol — schedule, postFail repack-and-retry,
    /// commit, postPlace defrag — lives in [`Scheduler::place`];
    /// gang-carrying arrivals take the all-or-nothing
    /// [`Scheduler::place_gang`] protocol instead (one submission, one
    /// atomic multi-node decision).
    pub fn step(&mut self) -> bool {
        // With fairness on, every arrival doubles as the capacity tick
        // that retries the pending queue.
        self.drain_pending();
        let task = self.sampler.next_task();
        self.submitted += 1;
        self.arrived_gpu_units += task.gpu.units();
        if let crate::tasks::GpuDemand::Mig(p) = task.gpu {
            self.arrived_mig_units[p.lattice().index()] += p.units();
        }
        if task.gang.is_some() {
            // Gang arrivals keep the legacy all-or-nothing drop even
            // under fairness (queueing partial gangs is future work).
            let placed = self.sched.place_gang(&mut self.dc, &self.workload, &task).is_some();
            if placed {
                self.scheduled += 1;
            } else {
                self.failed += 1;
            }
            return placed;
        }
        let decision = self.sched.place(&mut self.dc, &self.workload, &task);
        match (&self.fairness, &decision) {
            (None, Some(_)) => self.scheduled += 1,
            (None, None) => self.failed += 1,
            (Some(fs), Some(d)) => {
                self.scheduled += 1;
                fs.with_core(|c| {
                    c.set_now(self.submitted as f64);
                    c.note_resident(&task, d.node, &d.placement);
                    // A postFail preemption may have cleared the way
                    // for this very placement: requeue its victims.
                    c.requeue_evicted();
                });
            }
            (Some(fs), None) => {
                // Enqueue instead of dropping; a failed retry may
                // still have evicted victims (freed capacity drains
                // on the next tick).
                fs.with_core(|c| {
                    c.set_now(self.submitted as f64);
                    c.requeue_evicted();
                    c.enqueue(task.clone(), false);
                });
            }
        }
        decision.is_some()
    }

    /// Replay the inflation run up to the `nth` sampled arrival
    /// (1-based) — committing the first `n − 1` decisions exactly as
    /// [`Simulation::run_inflation`] would — then **explain** arrival
    /// `n` without committing it: returns the decision-trace event with
    /// the full scoring table (the `repro explain` subcommand
    /// pretty-prints it; see `docs/observability.md`).
    pub fn explain_arrival(&mut self, nth: u64, top_k: usize) -> crate::util::json::Json {
        while self.submitted + 1 < nth && (self.submitted as usize) < MAX_TASKS {
            self.step();
        }
        let task = self.sampler.next_task();
        self.submitted += 1;
        self.sched.explain(&self.dc, &self.workload, &task, top_k)
    }

    /// Current capacity ratio (arrived GPU units ÷ installed GPUs).
    pub fn capacity_ratio(&self) -> f64 {
        self.arrived_gpu_units / self.dc.gpu_capacity()
    }

    /// Snapshot the metrics into a [`SeriesPoint`]. On MIG fleets the
    /// per-lattice breakdown columns (EOPC/frag/GRAR restricted to the
    /// A100-lattice and A30-lattice nodes / demands) are filled in too.
    pub fn sample(&self) -> SeriesPoint {
        use crate::cluster::mig::MigLattice;
        use crate::cluster::node::ResourceView;
        let grar = if self.arrived_gpu_units > 0.0 {
            self.dc.gpu_allocated_units() / self.arrived_gpu_units
        } else {
            1.0
        };
        let (cpu_w, gpu_w, eopc_lat) = power::p_datacenter_by_lattice(&self.dc);
        let mut point = SeriesPoint {
            x: self.capacity_ratio(),
            eopc: cpu_w + gpu_w,
            cpu_w,
            gpu_w,
            grar,
            failures: self.failed as f64,
            active_gpus: self.dc.active_gpus() as f64,
            active_nodes: self.dc.active_nodes() as f64,
            asleep_nodes: self.dc.asleep_nodes() as f64,
            ..Default::default()
        };
        // One further pass fills the total fragmentation (Eq. 4 — the
        // per-node `f_node` is the expensive reference path, so never
        // compute it twice) and the per-lattice frag/allocation
        // breakdowns of a heterogeneous MIG fleet.
        let mut frag_lat = [0.0f64; 2];
        let mut alloc_lat = [0.0f64; 2];
        let mut has_mig = false;
        for n in &self.dc.nodes {
            let f = if self.record_frag { frag::f_node(n, &self.workload) } else { 0.0 };
            point.frag += f;
            if let Some(lat) = n.mig_lattice() {
                has_mig = true;
                let i = lat.index();
                frag_lat[i] += f;
                alloc_lat[i] += n.gpu_alloc.iter().sum::<f64>();
            }
        }
        if has_mig {
            let grar_of = |lat: MigLattice| {
                let arrived = self.arrived_mig_units[lat.index()];
                if arrived > 0.0 {
                    alloc_lat[lat.index()] / arrived
                } else {
                    1.0
                }
            };
            point.eopc_a100 = eopc_lat[MigLattice::A100.index()];
            point.eopc_a30 = eopc_lat[MigLattice::A30.index()];
            point.frag_a100 = frag_lat[MigLattice::A100.index()];
            point.frag_a30 = frag_lat[MigLattice::A30.index()];
            point.grar_a100 = grar_of(MigLattice::A100);
            point.grar_a30 = grar_of(MigLattice::A30);
        }
        point
    }

    /// Run inflation until arrived GPU requests reach
    /// `target_ratio × capacity`, sampling metrics every
    /// [`SAMPLE_STEP`] of capacity.
    pub fn run_inflation(&mut self, target_ratio: f64) -> RunResult {
        let mut series = RunSeries::default();
        series.points.push(self.sample());
        let mut next_sample = SAMPLE_STEP;
        while self.capacity_ratio() < target_ratio && (self.submitted as usize) < MAX_TASKS {
            self.step();
            if self.capacity_ratio() >= next_sample {
                series.points.push(self.sample());
                next_sample += SAMPLE_STEP;
            }
        }
        series.points.push(self.sample());
        let mut fair = (0u64, 0.0f64, 0.0f64, 0u64, 0u64, 0u64, 0u64);
        if let Some(fs) = &self.fairness {
            fs.set_now(self.submitted as f64);
            fair = fs.with_core(|c| {
                (
                    c.pending_depth(),
                    c.p99_wait(),
                    c.oldest_pending_age(),
                    c.starvation_events(),
                    c.enqueues() + c.requeues(),
                    c.drains(),
                    c.preemptions(),
                )
            });
            let reg = self.sched.registry_mut();
            if let Ok(core) = fs.shared().lock() {
                core.publish(reg);
            }
        }
        let m = self.sched.metrics();
        RunResult {
            series,
            submitted: self.submitted,
            scheduled: self.scheduled,
            failed: self.failed,
            arrived_gpu_units: self.arrived_gpu_units,
            allocated_gpu_units: self.dc.gpu_allocated_units(),
            repartitions: self.sched.hook_counter("repartitions"),
            proactive_repartitions: self.sched.hook_counter("proactive_repartitions"),
            migrated_slices: self.sched.hook_counter("migrated_slices"),
            constraint_unschedulable: self.sched.constraint_unschedulable(),
            drs_sleeps: self.sched.hook_counter("drs_sleeps"),
            drs_wakes: self.sched.hook_counter("drs_wakes"),
            gangs_placed: m.counter("gangs_placed"),
            gangs_failed: m.counter("gangs_failed"),
            gang_tp_violations: m.counter("gang_tp_violations"),
            gang_pp_span_sum: m.counter("gang_pp_span_sum"),
            pending_depth: fair.0,
            p99_wait: fair.1,
            oldest_pending_age: fair.2,
            starvation_events: fair.3,
            pending_enqueues: fair.4,
            pending_drains: fair.5,
            preemptions: fair.6,
        }
    }
}

/// Configuration for a repeated experiment run.
#[derive(Clone, Debug)]
pub struct RepeatConfig {
    /// Number of seeded repetitions (the paper uses 10).
    pub reps: usize,
    /// Base seed; repetition `i` uses `base_seed + i`.
    pub base_seed: u64,
    /// Inflation target (× GPU capacity).
    pub target_ratio: f64,
    /// Record the (expensive) full fragmentation series.
    pub record_frag: bool,
    /// Ablation: lowest-id tie-break instead of k8s's random choice.
    pub deterministic_ties: bool,
    /// Attach a MIG repartition hook (default cost caps) to each run's
    /// scheduler.
    pub mig_repartition: bool,
    /// Proactive slice-fragmentation threshold of the attached
    /// repartition hook; `f64::INFINITY` (default) keeps it failure-only.
    pub mig_frag_threshold: f64,
    /// Decision-trace sink (`--trace-decisions`): when set, every
    /// repetition's scheduler gets a [`DecisionTracer`] appending to
    /// this shared sink. Each JSONL event carries the policy label,
    /// seed, and sequence number, so the interleaved multi-thread
    /// stream demultiplexes. `None` (default) = tracing off, results
    /// bit-identical to pre-observability runs.
    pub trace: Option<TraceSink>,
}

impl Default for RepeatConfig {
    fn default() -> Self {
        RepeatConfig {
            reps: 10,
            base_seed: 42,
            target_ratio: 1.02,
            record_frag: false,
            deterministic_ties: false,
            mig_repartition: false,
            mig_frag_threshold: f64::INFINITY,
            trace: None,
        }
    }
}

/// Run `cfg.reps` independent repetitions of (cluster spec × trace spec
/// × policy) across threads; returns each repetition's series. `policy`
/// accepts a legacy [`crate::sched::PolicyKind`] or any
/// [`SchedulerProfile`] (each repetition thread builds its own
/// scheduler from the shared profile).
pub fn run_repetitions(
    cluster: &crate::cluster::ClusterSpec,
    trace_spec: &TraceSpec,
    policy: impl Into<SchedulerProfile>,
    cfg: &RepeatConfig,
) -> Vec<RunResult> {
    let profile: SchedulerProfile = policy.into();
    // Validate once, eagerly, so a bad profile fails loudly here instead
    // of panicking inside a repetition thread.
    profile.build().expect("invalid scheduler profile");
    let threads: Vec<_> = (0..cfg.reps)
        .map(|i| {
            let cluster = cluster.clone();
            let trace_spec = trace_spec.clone();
            let cfg = cfg.clone();
            let profile = profile.clone();
            std::thread::spawn(move || {
                let seed = cfg.base_seed + i as u64;
                let dc = cluster.build();
                let mut sched = profile.build().expect("profile validated above");
                sched.set_deterministic_ties(cfg.deterministic_ties);
                if cfg.mig_repartition {
                    sched.add_post_hook(Box::new(MigRepartitioner::new(
                        RepartitionConfig::with_threshold(cfg.mig_frag_threshold),
                    )));
                }
                if let Some(sink) = &cfg.trace {
                    let label = sched.label().to_string();
                    sched.set_tracer(DecisionTracer::new(sink.clone(), &label, seed));
                }
                // Workload M extracted from a materialized trace with
                // this repetition's seed (fresh historical sample).
                let workload = trace_spec.synthesize(seed ^ 0x57AB1E).workload();
                let mut sim = Simulation::with_spec(dc, sched, &trace_spec, workload, seed);
                sim.record_frag = cfg.record_frag;
                let out = sim.run_inflation(cfg.target_ratio);
                sim.sched.trace_flush();
                out
            })
        })
        .collect();
    threads.into_iter().map(|t| t.join().expect("repetition panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::sched::PolicyKind;

    fn small_run(policy: PolicyKind) -> RunResult {
        let dc = ClusterSpec::tiny(8, 4, 2).build();
        let spec = TraceSpec::default_trace();
        let workload = spec.synthesize(1).workload();
        let sched = Scheduler::from_policy(policy);
        let mut sim = Simulation::with_spec(dc, sched, &spec, workload, 7);
        sim.record_frag = false;
        sim.run_inflation(1.0)
    }

    #[test]
    fn inflation_reaches_target() {
        let r = small_run(PolicyKind::FirstFit);
        assert!(r.arrived_gpu_units >= 32.0);
        assert!(r.submitted > 0);
        assert_eq!(r.submitted, r.scheduled + r.failed);
    }

    #[test]
    fn gang_traces_place_gangs_with_zero_tp_violations() {
        let dc = ClusterSpec::tiny(8, 4, 0).build();
        let spec = TraceSpec::gang_trace(0.5);
        let workload = spec.synthesize(1).workload();
        let sched = Scheduler::from_policy(PolicyKind::PwrFgd { alpha: 0.5 });
        let mut sim = Simulation::with_spec(dc, sched, &spec, workload, 7);
        sim.record_frag = false;
        let r = sim.run_inflation(1.0);
        assert!(r.gangs_placed > 0, "gang-50 should place at least one gang");
        assert_eq!(r.gang_tp_violations, 0, "TP groups must never cross a node");
        assert!(
            r.gang_pp_span_sum >= r.gangs_placed,
            "each placed gang spans at least one node"
        );
        assert_eq!(r.submitted, r.scheduled + r.failed);
    }

    #[test]
    fn grar_is_bounded_and_monotone_sane() {
        let r = small_run(PolicyKind::Fgd);
        for p in &r.series.points {
            assert!((0.0..=1.0 + 1e-9).contains(&p.grar), "GRAR {}", p.grar);
        }
        assert!(r.final_grar() <= 1.0 + 1e-9);
    }

    #[test]
    fn eopc_grows_with_load() {
        let r = small_run(PolicyKind::Fgd);
        let first = r.series.points.first().unwrap().eopc;
        let last = r.series.points.last().unwrap().eopc;
        assert!(last > first, "EOPC should grow: {first} -> {last}");
    }

    #[test]
    fn x_axis_is_monotone() {
        let r = small_run(PolicyKind::BestFit);
        for w in r.series.points.windows(2) {
            assert!(w[1].x >= w[0].x);
        }
    }

    #[test]
    fn same_seed_reproduces() {
        let a = small_run(PolicyKind::Fgd);
        let b = small_run(PolicyKind::Fgd);
        assert_eq!(a.submitted, b.submitted);
        assert_eq!(a.scheduled, b.scheduled);
        assert!((a.final_eopc() - b.final_eopc()).abs() < 1e-9);
    }

    #[test]
    fn explain_arrival_replays_without_committing_the_nth() {
        let dc = ClusterSpec::tiny(4, 4, 1).build();
        let spec = TraceSpec::default_trace();
        let workload = spec.synthesize(1).workload();
        let sched = Scheduler::from_policy(PolicyKind::PwrFgd { alpha: 0.1 });
        let mut sim = Simulation::with_spec(dc, sched, &spec, workload, 7);
        let ev = sim.explain_arrival(5, 3);
        assert_eq!(ev.get("event").and_then(|e| e.as_str()), Some("place"));
        assert!(ev.get("outcome").is_some());
        assert_eq!(sim.submitted, 5);
        // The 5th arrival was explained, not committed: only the first
        // four decisions count as protocol entries.
        assert_eq!(sim.sched.metrics().counter("sched_places") + sim.failed, 4);
    }

    #[test]
    fn traced_repetitions_share_one_jsonl_sink() {
        use crate::obs::TraceSink;
        use crate::util::json;
        let cluster = ClusterSpec::tiny(4, 4, 1);
        let spec = TraceSpec::default_trace();
        let sink = TraceSink::memory();
        let cfg = RepeatConfig {
            reps: 2,
            base_seed: 1,
            target_ratio: 0.3,
            trace: Some(sink.clone()),
            ..Default::default()
        };
        let runs = run_repetitions(&cluster, &spec, PolicyKind::FirstFit, &cfg);
        assert_eq!(runs.len(), 2);
        let text = sink.contents();
        let mut seeds = std::collections::BTreeSet::new();
        let mut events = 0u64;
        for line in text.lines() {
            let ev = json::parse(line).expect("traced line parses");
            seeds.insert(ev.get("seed").and_then(json::Json::as_u64).unwrap());
            events += 1;
        }
        let submitted: u64 = runs.iter().map(|r| r.submitted).sum();
        assert_eq!(events, submitted, "one place event per submission");
        assert_eq!(seeds, [1u64, 2].into_iter().collect());
    }

    #[test]
    fn repetitions_run_in_parallel() {
        let cluster = ClusterSpec::tiny(4, 4, 1);
        let spec = TraceSpec::default_trace();
        let cfg = RepeatConfig { reps: 3, base_seed: 1, target_ratio: 0.5, ..Default::default() };
        let runs = run_repetitions(&cluster, &spec, PolicyKind::FirstFit, &cfg);
        assert_eq!(runs.len(), 3);
        for r in &runs {
            assert!(r.submitted > 0);
        }
    }

    #[test]
    fn repetitions_accept_dsl_profiles() {
        let cluster = ClusterSpec::tiny(4, 4, 1);
        let spec = TraceSpec::default_trace();
        let cfg = RepeatConfig { reps: 2, base_seed: 1, target_ratio: 0.4, ..Default::default() };
        let profile = SchedulerProfile::parse(
            "score(pwr=0.4,fgd=0.4,bestfit=0.2)|bind(weighted:0.4)|mod(loadalpha:0.9:0.1)",
        )
        .unwrap();
        let runs = run_repetitions(&cluster, &spec, profile, &cfg);
        assert_eq!(runs.len(), 2);
        for r in &runs {
            assert!(r.scheduled > 0, "composite profile scheduled nothing");
        }
    }
}
