//! Event-driven time simulation with task departures.
//!
//! The paper's inflation protocol (§V-A) never releases tasks — it
//! measures capacity. A real datacenter, however, runs in steady state
//! with arrivals *and* completions; the open-simulator the paper builds
//! on is event-driven for exactly this reason. This module adds the
//! missing substrate: a discrete-event loop with a Poisson arrival
//! process (sinusoidally modulated for the `diurnal-<amp>` trace
//! family), per-class task durations, and departure events — used by
//! the `ext-steady` experiment to check that PWR⊕FGD's savings persist
//! under churn (not just monotone fill), and by `ext-drs` to measure
//! what the DRS sleep/wake subsystem (`docs/power.md`) harvests from
//! the load valleys.
//!
//! Observability ([`crate::obs`]) flows through unchanged: a tracer
//! attached to the scheduler emits one JSONL event per place/release
//! of this loop, and the counters below are thin shims over the
//! scheduler's metrics registry — [`SteadySim::sched`] exposes the
//! full snapshot after a run.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::cluster::node::Placement;
use crate::cluster::Datacenter;
use crate::metrics::{RunSeries, SeriesPoint};
use crate::power;
use crate::sched::Scheduler;
use crate::tasks::{Task, Workload};
use crate::trace::{DiurnalMod, InflationSampler, TraceSpec};
use crate::util::rng::Rng;

/// Discrete event kinds.
#[derive(Clone, Debug, PartialEq)]
enum Event {
    /// A new task arrives.
    Arrival,
    /// A running task completes and releases its resources. `epoch` is
    /// the task's placement epoch at scheduling time: with the fairness
    /// subsystem on, a preempted-and-replaced task gets a fresh epoch,
    /// so the departure scheduled for its *old* placement no longer
    /// matches and is skipped as stale. Without fairness the epoch is
    /// always 0 and the comparison never fires.
    Departure { task_id: u64, epoch: u64 },
}

/// Heap entry ordered by time (min-heap via reversed comparison).
#[derive(Clone, Debug)]
struct Scheduled {
    at: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap; tie-break on sequence for determinism.
        // `total_cmp` gives a total order even for non-finite times (a
        // NaN would previously panic the heap's internal sift), though
        // `push` already refuses to enqueue non-finite times.
        other
            .at
            .total_cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Steady-state simulation configuration.
#[derive(Clone, Debug)]
pub struct SteadyConfig {
    /// Mean task inter-arrival time (seconds); arrivals are Poisson.
    pub mean_interarrival_s: f64,
    /// Mean task duration (seconds); durations are exponential, so the
    /// offered load is `mean_duration / mean_interarrival` tasks.
    pub mean_duration_s: f64,
    /// Simulated horizon (seconds).
    pub horizon_s: f64,
    /// Metric sampling period (seconds).
    pub sample_every_s: f64,
    /// RNG seed (arrivals, durations).
    pub seed: u64,
}

impl Default for SteadyConfig {
    fn default() -> Self {
        SteadyConfig {
            mean_interarrival_s: 1.0,
            mean_duration_s: 2_000.0,
            horizon_s: 20_000.0,
            sample_every_s: 100.0,
            seed: 42,
        }
    }
}

/// Outcome of a steady-state run.
#[derive(Clone, Debug, Default)]
pub struct SteadyResult {
    /// Time series sampled every `sample_every_s` (x = time fraction of
    /// the horizon; other columns as usual).
    pub series: RunSeries,
    pub arrivals: u64,
    pub scheduled: u64,
    pub failed: u64,
    pub departures: u64,
    /// MIG repartitioning activity under churn (zero without a
    /// repartition hook): reactive (failure-triggered) and proactive
    /// (frag-threshold-triggered) repacks plus total migrated slices.
    pub repartitions: u64,
    pub proactive_repartitions: u64,
    pub migrated_slices: u64,
    /// Failures attributed to declarative constraints (see
    /// [`crate::sched::Scheduler::constraint_unschedulable`]).
    pub constraint_unschedulable: u64,
    /// DRS sleep/wake activity under churn (zero without a `drs`
    /// hook; see [`crate::sched::drs`]).
    pub drs_sleeps: u64,
    pub drs_wakes: u64,
    /// Gang scheduling activity under churn (zero on gang-free
    /// traces; see [`crate::sched::gang`]). A gang is one arrival and
    /// one scheduled/failed outcome, but commits (and on departure
    /// releases) one member placement per TP group.
    pub gangs_placed: u64,
    pub gangs_failed: u64,
    pub gang_tp_violations: u64,
    pub gang_pp_span_sum: u64,
    /// Fairness pending-queue state at end of run (all zero unless
    /// [`SteadySim::enable_fairness`] was called; see
    /// [`crate::sched::fairness`]). Waits are in simulated seconds.
    pub pending_depth: u64,
    pub p99_wait: f64,
    pub oldest_pending_age: f64,
    pub starvation_events: u64,
    pub pending_enqueues: u64,
    pub pending_drains: u64,
    /// Residents evicted by the `preempt` postFail hook (and requeued).
    pub preemptions: u64,
    /// Cumulative GPU units requested by arrivals / allocated to
    /// scheduled tasks — the churn loop's GRAR numerator/denominator.
    pub arrived_gpu_units: f64,
    pub allocated_gpu_units: f64,
    /// Time-averaged EOPC over the second half (warmed-up steady state).
    pub steady_eopc_w: f64,
    /// Time-averaged EOPC with the DRS overlay (idle nodes slept).
    pub steady_eopc_drs_w: f64,
    /// Mean GPU utilization over the second half.
    pub steady_util: f64,
    /// Mean `Asleep` node count over the second half (realized DRS,
    /// not the overlay estimate above).
    pub mean_asleep_nodes: f64,
}

impl SteadyResult {
    /// GRAR over the whole run: GPU units allocated to scheduled tasks
    /// ÷ GPU units requested by arrivals.
    pub fn final_grar(&self) -> f64 {
        if self.arrived_gpu_units > 0.0 {
            self.allocated_gpu_units / self.arrived_gpu_units
        } else {
            1.0
        }
    }
}

/// How a resident task holds its resources — singletons commit one
/// placement on one node, gangs commit one member placement per TP
/// group and must be released through the same all-or-nothing path
/// ([`Scheduler::release_gang`]) so every member's GPUs come back.
#[derive(Clone, Debug)]
enum Resident {
    Single { node: usize, placement: Placement },
    Gang(crate::sched::gang::GangDecision),
}

/// Run an arrivals+departures simulation for one policy.
pub struct SteadySim {
    dc: Datacenter,
    sched: Scheduler,
    workload: Workload,
    sampler: InflationSampler,
    rng: Rng,
    queue: BinaryHeap<Scheduled>,
    running: std::collections::HashMap<u64, (Task, Resident)>,
    now: f64,
    seq: u64,
    /// Arrival-rate modulation of the `diurnal-<amp>` trace family;
    /// `None` leaves the arrival process exactly as before (the gap
    /// computation must stay bit-identical for legacy traces).
    diurnal: Option<DiurnalMod>,
    /// Fairness pending queue (`None` = historical drop behavior,
    /// bit-identical to pre-fairness runs).
    fairness: Option<crate::sched::FairnessState>,
}

impl SteadySim {
    pub fn new(dc: Datacenter, sched: Scheduler, spec: &TraceSpec, cfg: &SteadyConfig) -> SteadySim {
        let workload = spec.synthesize(cfg.seed ^ 0x57AB1E).workload();
        SteadySim {
            dc,
            sched,
            workload,
            sampler: spec.sampler(cfg.seed),
            rng: Rng::new(cfg.seed ^ 0xE7E47),
            queue: BinaryHeap::new(),
            running: std::collections::HashMap::new(),
            now: 0.0,
            seq: 0,
            diurnal: spec.diurnal,
            fairness: None,
        }
    }

    /// Switch the run from drop-on-failure to the fairness pending
    /// queue ([`crate::sched::fairness`]): failed non-gang arrivals
    /// enqueue and are retried after every departure (the churn loop's
    /// capacity event), preemption victims are requeued (never lost,
    /// their stale departures skipped via placement epochs), and the
    /// scheduler's plugins get the shared core (arming
    /// `mod(starve:…)` / `hook(preempt:…)` if the profile carries
    /// them). Gang arrivals keep the legacy all-or-nothing drop.
    pub fn enable_fairness(&mut self, cfg: crate::sched::FairnessConfig) {
        let fs = crate::sched::FairnessState::new(cfg);
        self.sched.bind_fairness(fs.shared());
        self.fairness = Some(fs);
    }

    /// Shared fairness core, when enabled (tests/diagnostics).
    pub fn fairness_shared(&self) -> Option<&crate::sched::FairnessShared> {
        self.fairness.as_ref().map(|f| f.shared())
    }

    /// The cluster state (for post-run invariant checks in tests).
    pub fn dc(&self) -> &Datacenter {
        &self.dc
    }

    /// The scheduler (post-run observability access:
    /// `sched().metrics()` for the registry snapshot,
    /// `sched().trace_flush()` to drain an attached tracer).
    pub fn sched(&self) -> &Scheduler {
        &self.sched
    }

    fn push(&mut self, at: f64, event: Event) {
        // Reject non-finite event times at insertion: a NaN/∞ duration
        // (degenerate config, numerical accident) maps to "past the
        // horizon", so the run loop drops it instead of the heap
        // panicking mid-simulation. Negative times (impossible from the
        // exponential sampler, kept for safety) clamp to `now`.
        let at = if at.is_finite() { at.max(self.now) } else { f64::MAX };
        self.seq += 1;
        self.queue.push(Scheduled { at, seq: self.seq, event });
    }

    /// Exponential variate with the given mean.
    fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.rng.f64()).ln()
    }

    /// Next Poisson arrival gap. Under a diurnal trace the
    /// instantaneous rate is modulated sinusoidally
    /// (`rate(t) = base · (1 + a·sin(2πt/period))`, clamped ≥ 5% of
    /// base — approximating the inhomogeneous process by scaling the
    /// exponential gap with the rate at emission time). The `None`
    /// branch is byte-for-byte the legacy computation, so
    /// non-diurnal traces reproduce bit-identically.
    fn next_arrival_gap(&mut self, cfg: &SteadyConfig) -> f64 {
        match self.diurnal {
            None => self.exp(cfg.mean_interarrival_s),
            Some(m) => {
                let phase = 2.0 * std::f64::consts::PI * self.now / m.period_s;
                let rate = (1.0 + m.amplitude * phase.sin()).max(0.05);
                self.exp(cfg.mean_interarrival_s / rate)
            }
        }
    }

    /// Run to the horizon, sampling metrics periodically.
    pub fn run(&mut self, cfg: &SteadyConfig) -> SteadyResult {
        let mut out = SteadyResult::default();
        let first = self.next_arrival_gap(cfg);
        self.push(first, Event::Arrival);
        let mut next_sample = 0.0;
        // (eopc, util, eopc_drs_overlay, asleep_nodes)
        let mut steady_samples: Vec<(f64, f64, f64, f64)> = Vec::new();

        while let Some(Scheduled { at, event, .. }) = self.queue.pop() {
            if at > cfg.horizon_s {
                break;
            }
            self.now = at;
            // Periodic metric samples up to `now`.
            while next_sample <= self.now {
                let p = self.sample(next_sample / cfg.horizon_s);
                if next_sample >= cfg.horizon_s * 0.5 {
                    steady_samples.push((
                        p.eopc,
                        self.dc.gpu_utilization(),
                        power::p_datacenter_drs(&self.dc),
                        p.asleep_nodes,
                    ));
                }
                out.series.points.push(p);
                next_sample += cfg.sample_every_s;
            }
            match event {
                Event::Arrival => {
                    out.arrivals += 1;
                    let task = self.sampler.next_task();
                    let id = task.id;
                    out.arrived_gpu_units += task.gpu.units();
                    // The full per-task protocol (onTick wake/sleep,
                    // schedule, postFail repack-and-retry, commit,
                    // postPlace defrag) lives in the framework —
                    // nothing to remember here. Gang arrivals take the
                    // all-or-nothing multi-node protocol instead; the
                    // non-gang branch is byte-for-byte the legacy call
                    // so gang-free traces reproduce bit-identically.
                    let resident = if task.gang.is_some() {
                        self.sched
                            .place_gang(&mut self.dc, &self.workload, &task)
                            .map(Resident::Gang)
                    } else {
                        self.sched
                            .place(&mut self.dc, &self.workload, &task)
                            .map(|d| Resident::Single { node: d.node, placement: d.placement })
                    };
                    match resident {
                        Some(r) => {
                            out.allocated_gpu_units += task.gpu.units();
                            let mut epoch = 0;
                            let mut victims: Vec<u64> = Vec::new();
                            if let Some(fs) = &mut self.fairness {
                                if let Resident::Single { node, placement } = &r {
                                    fs.with_core(|c| {
                                        c.set_now(at);
                                        c.note_resident(&task, *node, placement);
                                    });
                                }
                                epoch = fs.bump_epoch(id);
                                // A postFail preemption may have cleared
                                // the way for this very placement.
                                victims = fs.with_core(|c| c.requeue_evicted());
                            }
                            for vid in victims {
                                self.running.remove(&vid);
                            }
                            self.running.insert(id, (task, r));
                            out.scheduled += 1;
                            let dur = self.exp(cfg.mean_duration_s);
                            self.push(self.now + dur, Event::Departure { task_id: id, epoch });
                        }
                        None => {
                            if self.fairness.is_some() && task.gang.is_none() {
                                // Enqueue instead of dropping; a failed
                                // retry may still have evicted victims.
                                let tnow = self.now;
                                let mut victims: Vec<u64> = Vec::new();
                                if let Some(fs) = &self.fairness {
                                    victims = fs.with_core(|c| {
                                        c.set_now(tnow);
                                        let v = c.requeue_evicted();
                                        c.enqueue(task, false);
                                        v
                                    });
                                }
                                for vid in victims {
                                    self.running.remove(&vid);
                                }
                            } else {
                                out.failed += 1;
                            }
                        }
                    }
                    let gap = self.next_arrival_gap(cfg);
                    self.push(self.now + gap, Event::Arrival);
                }
                Event::Departure { task_id, epoch } => {
                    // Stale-departure guard: only fires with fairness on
                    // (epochs are 0 on both sides otherwise).
                    let current =
                        self.fairness.as_ref().map(|f| f.epoch(task_id)).unwrap_or(0);
                    if epoch == current {
                        if let Some((task, resident)) = self.running.remove(&task_id) {
                            // Departures are where lattice holes open up —
                            // release() runs the postPlace hooks (proactive
                            // defrag's main use under churn).
                            match resident {
                                Resident::Single { node, placement } => {
                                    self.sched.release(&mut self.dc, &task, node, &placement);
                                }
                                Resident::Gang(d) => {
                                    self.sched.release_gang(&mut self.dc, &task, &d);
                                }
                            }
                            out.departures += 1;
                            if let Some(fs) = &self.fairness {
                                fs.with_core(|c| {
                                    c.forget_resident(task_id);
                                });
                            }
                            // The freed capacity is the queue's retry
                            // signal (no-op without fairness).
                            self.drain_pending(cfg, &mut out);
                        }
                    }
                }
            }
            #[cfg(debug_assertions)]
            if let Some(fs) = &self.fairness {
                // Conservation at every step: each arrival is exactly
                // one of running / departed / pending / failed(gang).
                let depth = fs.with_core(|c| c.pending_depth());
                debug_assert_eq!(
                    out.arrivals,
                    self.running.len() as u64 + out.departures + depth + out.failed,
                    "fairness conservation violated at t={}",
                    self.now
                );
            }
        }
        if !steady_samples.is_empty() {
            let n = steady_samples.len() as f64;
            out.steady_eopc_w = steady_samples.iter().map(|s| s.0).sum::<f64>() / n;
            out.steady_util = steady_samples.iter().map(|s| s.1).sum::<f64>() / n;
            out.steady_eopc_drs_w = steady_samples.iter().map(|s| s.2).sum::<f64>() / n;
            out.mean_asleep_nodes = steady_samples.iter().map(|s| s.3).sum::<f64>() / n;
        }
        if let Some(fs) = &self.fairness {
            fs.set_now(self.now);
            let fair = fs.with_core(|c| {
                (
                    c.pending_depth(),
                    c.p99_wait(),
                    c.oldest_pending_age(),
                    c.starvation_events(),
                    c.enqueues() + c.requeues(),
                    c.drains(),
                    c.preemptions(),
                )
            });
            out.pending_depth = fair.0;
            out.p99_wait = fair.1;
            out.oldest_pending_age = fair.2;
            out.starvation_events = fair.3;
            out.pending_enqueues = fair.4;
            out.pending_drains = fair.5;
            out.preemptions = fair.6;
        }
        if let Some(shared) = self.fairness.as_ref().map(|f| f.shared().clone()) {
            if let Ok(core) = shared.lock() {
                core.publish(self.sched.registry_mut());
            }
        }
        out.repartitions = self.sched.hook_counter("repartitions");
        out.proactive_repartitions = self.sched.hook_counter("proactive_repartitions");
        out.migrated_slices = self.sched.hook_counter("migrated_slices");
        out.constraint_unschedulable = self.sched.constraint_unschedulable();
        out.drs_sleeps = self.sched.hook_counter("drs_sleeps");
        out.drs_wakes = self.sched.hook_counter("drs_wakes");
        let m = self.sched.metrics();
        out.gangs_placed = m.counter("gangs_placed");
        out.gangs_failed = m.counter("gangs_failed");
        out.gang_tp_violations = m.counter("gang_tp_violations");
        out.gang_pp_span_sum = m.counter("gang_pp_span_sum");
        out
    }

    /// Retry queued tasks in priority/FIFO order until one fails (no
    /// bypass) or the queue empties. Takes the fairness state out of
    /// `self` for the duration so the place/push/exp calls below can
    /// borrow `self` mutably; the shared core itself stays reachable by
    /// the scheduler's bound plugins (it is behind an `Arc`). Never
    /// holds the core lock across a `place` call — the preempt hook
    /// re-locks the core from inside the postFail phase.
    fn drain_pending(&mut self, cfg: &SteadyConfig, out: &mut SteadyResult) {
        let Some(mut fs) = self.fairness.take() else { return };
        fs.set_now(self.now);
        loop {
            let Some(task) = fs.with_core(|c| c.head()) else { break };
            let decision = self.sched.place(&mut self.dc, &self.workload, &task);
            // Preemption evictions from this attempt (whether or not
            // the retry then succeeded): requeue the victims and drop
            // them from the running ledger — their queued departures
            // are now stale by epoch.
            for vid in fs.with_core(|c| c.requeue_evicted()) {
                self.running.remove(&vid);
            }
            let Some(d) = decision else { break };
            let requeued =
                fs.with_core(|c| c.pop_placed()).map(|e| e.requeued).unwrap_or(false);
            if !requeued {
                // First placement of this arrival: count it now (a
                // requeued victim was already counted when it first
                // placed).
                out.scheduled += 1;
                out.allocated_gpu_units += task.gpu.units();
            }
            fs.with_core(|c| c.note_resident(&task, d.node, &d.placement));
            let epoch = fs.bump_epoch(task.id);
            let id = task.id;
            self.running
                .insert(id, (task, Resident::Single { node: d.node, placement: d.placement }));
            let dur = self.exp(cfg.mean_duration_s);
            self.push(self.now + dur, Event::Departure { task_id: id, epoch });
        }
        self.fairness = Some(fs);
    }

    fn sample(&self, x: f64) -> SeriesPoint {
        use crate::cluster::mig::MigLattice;
        // Power split + per-lattice power breakdown on MIG fleets
        // (frag/GRAR splits are inflation-loop metrics; churn reports
        // power + counters).
        let (cpu_w, gpu_w, eopc_lat) = power::p_datacenter_by_lattice(&self.dc);
        SeriesPoint {
            x,
            eopc: cpu_w + gpu_w,
            cpu_w,
            gpu_w,
            grar: 1.0, // per-interval GRAR tracked via failure counts
            active_gpus: self.dc.active_gpus() as f64,
            active_nodes: self.dc.active_nodes() as f64,
            asleep_nodes: self.dc.asleep_nodes() as f64,
            eopc_a100: eopc_lat[MigLattice::A100.index()],
            eopc_a30: eopc_lat[MigLattice::A30.index()],
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::sched::PolicyKind;

    fn run(policy: PolicyKind, seed: u64) -> SteadyResult {
        let cfg = SteadyConfig {
            mean_interarrival_s: 1.0,
            mean_duration_s: 300.0,
            horizon_s: 3_000.0,
            sample_every_s: 50.0,
            seed,
        };
        let dc = ClusterSpec::tiny(16, 4, 4).build();
        let sched = Scheduler::from_policy(policy);
        let mut sim = SteadySim::new(dc, sched, &TraceSpec::default_trace(), &cfg);
        sim.run(&cfg)
    }

    #[test]
    fn churn_reaches_steady_state() {
        let r = run(PolicyKind::Fgd, 1);
        assert!(r.arrivals > 2_000, "arrivals {}", r.arrivals);
        assert!(r.departures > 1_000, "departures {}", r.departures);
        // Little's law ballpark: L = λ·W = (1/1s)·300s = ~300 tasks
        // offered; the 64-GPU cluster saturates below that, so failures
        // must occur and utilization must be high.
        assert!(r.steady_util > 0.5, "util {}", r.steady_util);
        assert!(r.steady_eopc_w > 0.0);
    }

    #[test]
    fn resources_conserve_under_churn() {
        let cfg = SteadyConfig {
            mean_interarrival_s: 2.0,
            mean_duration_s: 100.0,
            horizon_s: 2_000.0,
            sample_every_s: 100.0,
            seed: 3,
        };
        let dc = ClusterSpec::tiny(8, 4, 2).build();
        let sched = Scheduler::from_policy(PolicyKind::PwrFgd { alpha: 0.1 });
        let mut sim = SteadySim::new(dc, sched, &TraceSpec::default_trace(), &cfg);
        let r = sim.run(&cfg);
        // Every scheduled task either departed or is still resident.
        assert_eq!(r.scheduled, r.departures + sim.dc.n_tasks);
        let (gpu, cpu) = sim.dc.recompute_caches();
        assert!((gpu - sim.dc.gpu_allocated_units()).abs() < 1e-6);
        assert!((cpu - sim.dc.cpu_allocated_units()).abs() < 1e-6);
    }

    #[test]
    fn gang_churn_conserves_resources_member_wise() {
        let cfg = SteadyConfig {
            mean_interarrival_s: 2.0,
            mean_duration_s: 100.0,
            horizon_s: 2_000.0,
            sample_every_s: 100.0,
            seed: 3,
        };
        let dc = ClusterSpec::tiny(8, 4, 0).build();
        let sched = Scheduler::from_policy(PolicyKind::PwrFgd { alpha: 0.1 });
        let mut sim = SteadySim::new(dc, sched, &TraceSpec::gang_trace(0.5), &cfg);
        let r = sim.run(&cfg);
        // A gang is one scheduled arrival but binds one task per member,
        // so the ledger is member-wise: resident members == dc.n_tasks.
        let resident_members: u64 = sim
            .running
            .values()
            .map(|(_, res)| match res {
                Resident::Single { .. } => 1,
                Resident::Gang(d) => d.members.len() as u64,
            })
            .sum();
        assert_eq!(resident_members, sim.dc.n_tasks);
        assert_eq!(r.scheduled, r.departures + sim.running.len() as u64);
        let (gpu, cpu) = sim.dc.recompute_caches();
        assert!((gpu - sim.dc.gpu_allocated_units()).abs() < 1e-6);
        assert!((cpu - sim.dc.cpu_allocated_units()).abs() < 1e-6);
        assert!(r.gangs_placed > 0, "gang-50 churn should place gangs");
        assert_eq!(r.gang_tp_violations, 0);
    }

    #[test]
    fn deterministic_for_seed() {
        let a = run(PolicyKind::Pwr, 9);
        let b = run(PolicyKind::Pwr, 9);
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.scheduled, b.scheduled);
        assert!((a.steady_eopc_w - b.steady_eopc_w).abs() < 1e-9);
    }

    #[test]
    fn heap_orders_non_finite_times_without_panicking() {
        // Direct heap check: NaN/∞ entries must not panic `cmp` and
        // must sort after every finite time.
        let mut heap = BinaryHeap::new();
        for (seq, at) in
            [(1u64, 5.0f64), (2, f64::NAN), (3, 1.0), (4, f64::INFINITY), (5, 3.0)]
        {
            heap.push(Scheduled { at, seq, event: Event::Arrival });
        }
        let mut finite = Vec::new();
        let mut rest = 0;
        while let Some(s) = heap.pop() {
            if s.at.is_finite() {
                assert_eq!(rest, 0, "finite time {} popped after non-finite", s.at);
                finite.push(s.at);
            } else {
                rest += 1;
            }
        }
        assert_eq!(finite, vec![1.0, 3.0, 5.0]);
        assert_eq!(rest, 2);
    }

    #[test]
    fn nan_duration_cannot_panic_the_loop() {
        // A degenerate config producing NaN durations (0/0-style) must
        // yield a clean run, not a heap panic: every departure lands
        // past the horizon and is dropped.
        let cfg = SteadyConfig {
            mean_interarrival_s: 1.0,
            mean_duration_s: f64::NAN,
            horizon_s: 50.0,
            sample_every_s: 10.0,
            seed: 1,
        };
        let dc = ClusterSpec::tiny(2, 2, 0).build();
        let sched = Scheduler::from_policy(PolicyKind::FirstFit);
        let mut sim = SteadySim::new(dc, sched, &TraceSpec::default_trace(), &cfg);
        let r = sim.run(&cfg);
        assert!(r.arrivals > 10);
        assert_eq!(r.departures, 0, "NaN-duration tasks never depart");
    }

    #[test]
    fn diurnal_modulation_shapes_arrivals() {
        let cfg = SteadyConfig {
            mean_interarrival_s: 1.0,
            mean_duration_s: 100.0,
            horizon_s: 4_000.0,
            sample_every_s: 100.0,
            seed: 5,
        };
        let run = |spec: &TraceSpec| {
            let dc = ClusterSpec::tiny(8, 4, 2).build();
            let sched = Scheduler::from_policy(PolicyKind::Fgd);
            let mut sim = SteadySim::new(dc, sched, spec, &cfg);
            sim.run(&cfg)
        };
        let base = run(&TraceSpec::default_trace());
        // Zero amplitude: the rate factor is exactly 1.0, so the gap
        // stream — and the whole run — is bit-identical to Default.
        let flat = run(&TraceSpec::diurnal_with_period(0.0, 1_000.0));
        assert_eq!(base.arrivals, flat.arrivals);
        assert_eq!(base.scheduled, flat.scheduled);
        assert_eq!(base.steady_eopc_w.to_bits(), flat.steady_eopc_w.to_bits());
        // Full-swing modulation changes arrival timing (same demand
        // catalog, different gaps) while the mean rate stays ~base:
        // the count moves, but not by an order of magnitude.
        let wavy = run(&TraceSpec::diurnal_with_period(1.0, 1_000.0));
        assert_ne!(base.arrivals, wavy.arrivals);
        let ratio = wavy.arrivals as f64 / base.arrivals.max(1) as f64;
        assert!((0.5..2.0).contains(&ratio), "arrival ratio {ratio}");
        // The churn GRAR ledger is populated and bounded.
        assert!(wavy.arrived_gpu_units > 0.0);
        assert!(wavy.final_grar() <= 1.0 + 1e-9);
    }

    #[test]
    fn pwr_saves_power_in_steady_state_too() {
        // The paper's claim under churn: at equal offered load, the
        // power-aware combination should not draw more steady-state
        // power than plain FGD (it consolidates).
        let fgd = run(PolicyKind::Fgd, 7);
        let combo = run(PolicyKind::PwrFgd { alpha: 0.1 }, 7);
        assert!(
            combo.steady_eopc_w <= fgd.steady_eopc_w * 1.02,
            "combo {} vs fgd {}",
            combo.steady_eopc_w,
            fgd.steady_eopc_w
        );
    }
}
