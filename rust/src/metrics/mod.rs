//! Evaluation metrics (§V-C) and series aggregation.
//!
//! * **EOPC** — Estimated Overall Power Consumption (Eq. 3), in Watt,
//!   split into CPU and GPU components (Fig. 1).
//! * **GRAR** — GPU Resource Allocation Ratio: GPU units allocated to
//!   scheduled tasks ÷ GPU units requested by *arrived* tasks.
//!
//! All figures plot metrics against the *requested GPU capacity ratio*
//! (cumulative arrived GPU requests ÷ cluster GPU capacity). Runs are
//! recorded as [`SeriesPoint`]s and resampled onto a common grid so the
//! paper's 10-repetition averages and the savings-vs-FGD curves can be
//! computed point-wise.
//!
//! This module is the *evaluation* metrics layer (what the paper plots).
//! Operational metrics — scheduler counters, decision traces and phase
//! latencies — live in [`crate::obs`] (see `docs/observability.md`).

use crate::util::stats;

/// One sample of the simulation state.
#[derive(Clone, Copy, Debug, Default)]
pub struct SeriesPoint {
    /// Arrived GPU requests ÷ cluster GPU capacity (the x-axis).
    pub x: f64,
    /// EOPC in Watt (Eq. 3).
    pub eopc: f64,
    /// CPU component of EOPC (Watt).
    pub cpu_w: f64,
    /// GPU component of EOPC (Watt).
    pub gpu_w: f64,
    /// GPU Resource Allocation Ratio ∈ [0, 1].
    pub grar: f64,
    /// Expected datacenter fragmentation `F_dc(M)` in GPU units (Eq. 4).
    pub frag: f64,
    /// Cumulative scheduling failures.
    pub failures: f64,
    /// GPUs drawing `p_max` (any allocation).
    pub active_gpus: f64,
    /// Nodes with any allocation.
    pub active_nodes: f64,
    /// Nodes in the `Asleep` DRS power state, drawing standby watts
    /// (zero without a `drs` hook — see `docs/power.md`).
    pub asleep_nodes: f64,
    /// Per-lattice-model breakdowns (heterogeneous MIG fleets): node
    /// power, fragmentation and GRAR restricted to the nodes / demands
    /// of one partition lattice. Zero on non-MIG runs.
    pub eopc_a100: f64,
    pub eopc_a30: f64,
    pub frag_a100: f64,
    pub frag_a30: f64,
    pub grar_a100: f64,
    pub grar_a30: f64,
}

/// Column selector for series extraction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Column {
    Eopc,
    CpuW,
    GpuW,
    Grar,
    Frag,
    Failures,
    ActiveGpus,
    ActiveNodes,
    AsleepNodes,
    EopcA100,
    EopcA30,
    FragA100,
    FragA30,
    GrarA100,
    GrarA30,
}

impl Column {
    pub fn of(self, p: &SeriesPoint) -> f64 {
        match self {
            Column::Eopc => p.eopc,
            Column::CpuW => p.cpu_w,
            Column::GpuW => p.gpu_w,
            Column::Grar => p.grar,
            Column::Frag => p.frag,
            Column::Failures => p.failures,
            Column::ActiveGpus => p.active_gpus,
            Column::ActiveNodes => p.active_nodes,
            Column::AsleepNodes => p.asleep_nodes,
            Column::EopcA100 => p.eopc_a100,
            Column::EopcA30 => p.eopc_a30,
            Column::FragA100 => p.frag_a100,
            Column::FragA30 => p.frag_a30,
            Column::GrarA100 => p.grar_a100,
            Column::GrarA30 => p.grar_a30,
        }
    }
}

/// A recorded run: monotone-x sequence of samples.
#[derive(Clone, Debug, Default)]
pub struct RunSeries {
    pub points: Vec<SeriesPoint>,
}

impl RunSeries {
    /// Extract one column as (xs, ys).
    pub fn column(&self, col: Column) -> (Vec<f64>, Vec<f64>) {
        (
            self.points.iter().map(|p| p.x).collect(),
            self.points.iter().map(|p| col.of(p)).collect(),
        )
    }

    /// Value of a column at capacity ratio `x` (linear interpolation).
    pub fn at(&self, col: Column, x: f64) -> f64 {
        let (xs, ys) = self.column(col);
        stats::interp(&xs, &ys, x)
    }

    /// Last sample (end of inflation).
    pub fn last(&self) -> Option<&SeriesPoint> {
        self.points.last()
    }
}

/// The common x-grid every figure uses.
pub fn capacity_grid(max_x: f64, step: f64) -> Vec<f64> {
    let n = (max_x / step).round() as usize;
    (0..=n).map(|i| i as f64 * step).collect()
}

/// Average multiple repetitions of a run column onto `grid`.
pub fn average_on_grid(runs: &[RunSeries], col: Column, grid: &[f64]) -> Vec<f64> {
    grid.iter()
        .map(|&x| {
            let vals: Vec<f64> = runs.iter().map(|r| r.at(col, x)).collect();
            stats::mean(&vals)
        })
        .collect()
}

/// Power savings (%) of `policy` vs `baseline` on `grid`:
/// `100·(EOPC_base − EOPC_policy)/EOPC_base` — the y-axis of Figs. 2–6.
pub fn savings_pct(baseline: &[f64], policy: &[f64]) -> Vec<f64> {
    baseline
        .iter()
        .zip(policy)
        .map(|(&b, &p)| if b > 0.0 { 100.0 * (b - p) / b } else { 0.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series(points: &[(f64, f64)]) -> RunSeries {
        RunSeries {
            points: points
                .iter()
                .map(|&(x, eopc)| SeriesPoint { x, eopc, ..Default::default() })
                .collect(),
        }
    }

    #[test]
    fn grid_covers_range() {
        let g = capacity_grid(1.0, 0.25);
        assert_eq!(g, vec![0.0, 0.25, 0.5, 0.75, 1.0]);
    }

    #[test]
    fn interpolated_lookup() {
        let r = series(&[(0.0, 100.0), (1.0, 200.0)]);
        assert_eq!(r.at(Column::Eopc, 0.5), 150.0);
        assert_eq!(r.at(Column::Eopc, 2.0), 200.0); // clamped
    }

    #[test]
    fn averaging_across_reps() {
        let a = series(&[(0.0, 100.0), (1.0, 200.0)]);
        let b = series(&[(0.0, 300.0), (1.0, 400.0)]);
        let grid = vec![0.0, 0.5, 1.0];
        let avg = average_on_grid(&[a, b], Column::Eopc, &grid);
        assert_eq!(avg, vec![200.0, 250.0, 300.0]);
    }

    #[test]
    fn savings_formula() {
        let s = savings_pct(&[100.0, 200.0], &[90.0, 220.0]);
        assert_eq!(s, vec![10.0, -10.0]);
    }
}
