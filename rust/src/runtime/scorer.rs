//! The XLA batch scorer: the PWR⊕FGD node-scoring pass executed as an
//! AOT-compiled HLO program (L2 JAX graph + L1 Pallas kernel) through
//! PJRT.
//!
//! ## Dense encoding contract (must match `python/compile/model.py`)
//!
//! All tensors are `f32`. With `N` node slots, `G` GPU slots per node
//! and `M` workload-class slots (padded; shapes are baked at AOT time
//! and published in `artifacts/scorer_meta.json`):
//!
//! * `gpu_free   [N, G]` — free fraction per GPU; `-1` marks a padding
//!   GPU slot (also used for CPU-only nodes).
//! * `node_aux   [N, 6]` — `[cpu_free, mem_free, cpu_alloc, model_idx,
//!   gpu_p_idle, gpu_p_max]`; `model_idx = -1` for CPU-only nodes.
//!   Padding node slots have `cpu_free = -1`.
//! * `classes    [M, 7]` — `[cpu, mem, gpu_units, is_frac, is_whole,
//!   pop, constraint_idx]`; padding classes have `pop = 0`.
//! * `task       [8]` — `[cpu, mem, gpu_units, is_frac, is_whole,
//!   whole_k, constraint_idx, mig_profile]`; `mig_profile` is
//!   `1 + MigProfile::index()` for slice demands on a MIG-aware
//!   artifact (`"mig": true` in the meta) and `0` otherwise — legacy
//!   artifacts never see a non-zero slot 7.
//! * `alpha      [1]` — the PWR weight α.
//!
//! Outputs: `(score [N], best_gpu [N], feasible [N])` where `score` is
//! the k8s-normalized weighted combination (`-1e9` for infeasible
//! slots), `best_gpu` the placement arg-min for fractional tasks (`-1`
//! otherwise) and `feasible` a 0/1 mask.
//!
//! The CPU power model constants (Xeon E5-2682 v4: 32 vCPU/socket,
//! 15 W idle, 120 W max) are baked into the artifact.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use crate::cluster::node::{Placement, ResourceView, EPS};
use crate::cluster::Datacenter;
use crate::runtime::{Artifact, Runtime};
use crate::sched::framework::Decision;
use crate::tasks::{GpuDemand, Task, Workload};
use crate::util::json;

/// Shapes of a compiled scorer artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScorerConfig {
    /// Node slots.
    pub n: usize,
    /// GPU slots per node.
    pub g: usize,
    /// Workload-class slots.
    pub m: usize,
    /// The artifact encodes MIG slice demands (task slot 7). Absent
    /// from legacy metas → `false`, which preserves the native-fallback
    /// behavior (and its `mig_scorer_fallbacks` accounting) exactly.
    pub mig: bool,
}

impl ScorerConfig {
    /// Parse `scorer_meta.json` produced by `aot.py`.
    pub fn from_meta(text: &str) -> Result<ScorerConfig> {
        let v = json::parse(text).context("parsing scorer_meta.json")?;
        let get = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(|x| x.as_u64())
                .map(|x| x as usize)
                .with_context(|| format!("meta key {k}"))
        };
        let mig = v.get("mig").and_then(|x| x.as_bool()).unwrap_or(false);
        Ok(ScorerConfig { n: get("n")?, g: get("g")?, m: get("m")?, mig })
    }
}

/// Sentinel score for infeasible nodes (mirrors the Python side).
pub const NEG_INF_SCORE: f32 = -1.0e9;

/// MIG demands routed past the XLA scorer because the loaded artifact's
/// dense encoding predates the MIG subsystem (`"mig"` absent from its
/// meta): such slice demands fall back to the native scheduler.
/// MIG-aware artifacts score slice demands in-graph and never touch
/// this counter. Previously the fallback was a silent `None`;
/// mixed-fleet runs now read the counter to report how many placements
/// bypassed the compiled path.
static MIG_SCORER_FALLBACKS: AtomicU64 = AtomicU64::new(0);

/// Cumulative count of MIG demands the scorer declined (process-wide).
///
/// [`crate::sched::Scheduler::metrics`] folds this counter into every
/// snapshot under the catalogued `mig_scorer_fallbacks` key, so registry
/// consumers (`obs_summary.json`, the coordinator's Prometheus
/// exposition) see it without touching this module directly. Note the
/// registry copy is process-wide like the atomic itself, not per-run;
/// use [`reset_mig_scorer_fallbacks`] for per-run deltas.
pub fn mig_scorer_fallbacks() -> u64 {
    MIG_SCORER_FALLBACKS.load(Ordering::Relaxed)
}

/// Reset the fallback counter (tests / per-run reporting).
pub fn reset_mig_scorer_fallbacks() {
    MIG_SCORER_FALLBACKS.store(0, Ordering::Relaxed);
}

/// The XLA-backed scorer with reusable host buffers.
pub struct XlaScorer {
    #[cfg_attr(not(feature = "xla"), allow(dead_code))]
    artifact: Artifact,
    pub config: ScorerConfig,
    // Reused encode buffers (hot path: no per-decision allocation).
    gpu_free: Vec<f32>,
    node_aux: Vec<f32>,
    classes: Vec<f32>,
    task_buf: Vec<f32>,
}

/// Decoded scorer outputs.
#[derive(Clone, Debug)]
pub struct ScoreOutput {
    pub score: Vec<f32>,
    pub best_gpu: Vec<f32>,
    pub feasible: Vec<f32>,
}

impl XlaScorer {
    /// Load `scorer.hlo.txt` + `scorer_meta.json` from `dir`.
    pub fn load(rt: &Runtime, dir: &std::path::Path) -> Result<XlaScorer> {
        let meta_path = dir.join("scorer_meta.json");
        let meta = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let config = ScorerConfig::from_meta(&meta)?;
        let artifact = rt.load_hlo_text(dir.join("scorer.hlo.txt"))?;
        Ok(XlaScorer {
            artifact,
            config,
            gpu_free: vec![0.0; config.n * config.g],
            node_aux: vec![0.0; config.n * 6],
            classes: vec![0.0; config.m * 7],
            task_buf: vec![0.0; 8],
        })
    }

    /// Encode the datacenter into the dense node tensors.
    pub fn encode_cluster(&mut self, dc: &Datacenter) -> Result<()> {
        let (n, g) = (self.config.n, self.config.g);
        if dc.nodes.len() > n {
            bail!("cluster has {} nodes but artifact supports {n}", dc.nodes.len());
        }
        self.gpu_free.iter_mut().for_each(|x| *x = -1.0);
        self.node_aux.iter_mut().for_each(|x| *x = 0.0);
        for slot in dc.nodes.len()..n {
            self.node_aux[slot * 6] = -1.0; // padding: cpu_free = -1
        }
        for (i, node) in dc.nodes.iter().enumerate() {
            if node.gpu_alloc.len() > g {
                bail!("node {} has {} GPUs but artifact supports {g}", i, node.gpu_alloc.len());
            }
            for (j, _) in node.gpu_alloc.iter().enumerate() {
                self.gpu_free[i * g + j] = node.gpu_free_of(j) as f32;
            }
            let aux = &mut self.node_aux[i * 6..i * 6 + 6];
            aux[0] = node.cpu_free() as f32;
            aux[1] = node.mem_free() as f32;
            aux[2] = node.cpu_alloc as f32;
            aux[3] = node.gpu_model.map(|m| m.index() as f32).unwrap_or(-1.0);
            aux[4] = node.gpu_model.map(|m| m.p_idle() as f32).unwrap_or(0.0);
            aux[5] = node.gpu_model.map(|m| m.p_max() as f32).unwrap_or(0.0);
        }
        Ok(())
    }

    /// Encode the target workload `M` (truncated to the top `m` classes).
    pub fn encode_workload(&mut self, workload: &Workload) {
        let m = self.config.m;
        let top = workload.top_k(m);
        self.classes.iter_mut().for_each(|x| *x = 0.0);
        for (i, c) in top.classes().iter().enumerate() {
            let row = &mut self.classes[i * 7..i * 7 + 7];
            row[0] = c.cpu as f32;
            row[1] = c.mem as f32;
            row[2] = c.gpu.units() as f32;
            row[3] = matches!(c.gpu, GpuDemand::Frac(_)) as u8 as f32;
            row[4] = matches!(c.gpu, GpuDemand::Whole(_)) as u8 as f32;
            row[5] = c.pop as f32;
            row[6] = c.gpu_model.map(|mm| mm.index() as f32).unwrap_or(-1.0);
        }
    }

    fn encode_task(&mut self, task: &Task) {
        let mig = self.config.mig;
        let t = &mut self.task_buf;
        t.iter_mut().for_each(|x| *x = 0.0);
        t[0] = task.cpu as f32;
        t[1] = task.mem as f32;
        t[2] = task.gpu.units() as f32;
        t[3] = matches!(task.gpu, GpuDemand::Frac(_)) as u8 as f32;
        t[4] = matches!(task.gpu, GpuDemand::Whole(_)) as u8 as f32;
        t[5] = if let GpuDemand::Whole(k) = task.gpu { k as f32 } else { 0.0 };
        t[6] = task.gpu_model.map(|m| m.index() as f32).unwrap_or(-1.0);
        // Slot 7 stays 0 on legacy artifacts so their baked HLO never
        // sees an input it predates.
        if mig {
            if let GpuDemand::Mig(p) = task.gpu {
                t[7] = 1.0 + p.index() as f32;
            }
        }
    }

    /// Run the compiled scoring pass for one task.
    #[cfg(feature = "xla")]
    pub fn score(&mut self, task: &Task, alpha: f64) -> Result<ScoreOutput> {
        self.encode_task(task);
        let (n, g, m) = (self.config.n as i64, self.config.g as i64, self.config.m as i64);
        let inputs = [
            xla::Literal::vec1(&self.gpu_free).reshape(&[n, g])?,
            xla::Literal::vec1(&self.node_aux).reshape(&[n, 6])?,
            xla::Literal::vec1(&self.classes).reshape(&[m, 7])?,
            xla::Literal::vec1(&self.task_buf).reshape(&[8])?,
            xla::Literal::vec1(&[alpha as f32]).reshape(&[1])?,
        ];
        let out = self.artifact.execute(&inputs)?;
        if out.len() != 3 {
            bail!("scorer returned {} outputs, expected 3", out.len());
        }
        Ok(ScoreOutput {
            score: out[0].to_vec::<f32>()?,
            best_gpu: out[1].to_vec::<f32>()?,
            feasible: out[2].to_vec::<f32>()?,
        })
    }

    /// Run the compiled scoring pass for one task (stub: the artifact
    /// cannot execute without the `xla` feature; `XlaScorer::load`
    /// already fails earlier in such builds, this keeps the API total).
    #[cfg(not(feature = "xla"))]
    pub fn score(&mut self, task: &Task, _alpha: f64) -> Result<ScoreOutput> {
        self.encode_task(task);
        bail!("XLA scorer unavailable: built without the `xla` cargo feature")
    }

    /// Full decision: encode state, execute, arg-max (ties → lowest node
    /// id) and reconstruct the placement.
    pub fn schedule(
        &mut self,
        dc: &Datacenter,
        workload: &Workload,
        task: &Task,
        alpha: f64,
    ) -> Result<Option<Decision>> {
        self.encode_cluster(dc)?;
        self.encode_workload(workload);
        let out = self.score(task, alpha)?;
        Ok(decode_decision(dc, task, &out, self.config.mig))
    }
}

/// Pick the arg-max feasible node and rebuild the concrete placement.
/// On legacy artifacts (`mig_encoded = false`) MIG demands are counted
/// into [`mig_scorer_fallbacks`] and return `None` — the caller must
/// fall back to the native scheduler. MIG-aware artifacts score slice
/// demands in-graph; the concrete slice window is reconstructed here
/// via first-fit on the chosen node's real occupancy masks (preferring
/// the graph's `best_gpu` hint), mirroring how fractional placements
/// are rebuilt.
pub fn decode_decision(
    dc: &Datacenter,
    task: &Task,
    out: &ScoreOutput,
    mig_encoded: bool,
) -> Option<Decision> {
    if matches!(task.gpu, GpuDemand::Mig(_)) && !mig_encoded {
        MIG_SCORER_FALLBACKS.fetch_add(1, Ordering::Relaxed);
        return None;
    }
    let mut best: Option<usize> = None;
    for i in 0..dc.nodes.len() {
        if out.feasible[i] < 0.5 {
            continue;
        }
        match best {
            None => best = Some(i),
            Some(b) if out.score[i] > out.score[b] + 1e-6 => best = Some(i),
            _ => {}
        }
    }
    let node_id = best?;
    let node = &dc.nodes[node_id];
    let placement = match task.gpu {
        GpuDemand::Zero => Placement::CpuOnly,
        GpuDemand::Frac(d) => {
            let g = out.best_gpu[node_id];
            let g = if g >= 0.0 { g as usize } else { 0 };
            // Guard against f32 rounding: fall back to first feasible GPU.
            if node.gpu_free_of(g) >= d - EPS {
                Placement::Shared { gpu: g }
            } else {
                let g = (0..node.gpu_alloc.len())
                    .find(|&j| node.gpu_free_of(j) >= d - EPS)?;
                Placement::Shared { gpu: g }
            }
        }
        GpuDemand::Whole(k) => {
            let gpus: Vec<usize> = (0..node.gpu_alloc.len())
                .filter(|&j| node.gpu_free_of(j) >= 1.0 - EPS)
                .take(k as usize)
                .collect();
            if gpus.len() != k as usize {
                return None;
            }
            Placement::Whole { gpus }
        }
        // Legacy artifacts were counted and rejected at the top of the
        // function; here the artifact scored the slice demand, so
        // rebuild a legal window from the node's occupancy masks.
        GpuDemand::Mig(p) => {
            let migs = node.mig.as_ref()?;
            let hint = out.best_gpu[node_id];
            let hinted = if hint >= 0.0 {
                let g = hint as usize;
                migs.get(g).and_then(|mg| mg.can_place(p)).map(|s| (g, s))
            } else {
                None
            };
            let (gpu, start) = hinted.or_else(|| {
                migs.iter()
                    .enumerate()
                    .find_map(|(g, mg)| mg.can_place(p).map(|s| (g, s)))
            })?;
            Placement::MigSlice { gpu, start }
        }
    };
    Some(Decision { node: node_id, placement })
}

/// Result of a native-vs-XLA parity run.
#[derive(Clone, Debug, Default)]
pub struct ParityReport {
    pub decisions: usize,
    /// Same node chosen by both paths.
    pub exact_matches: usize,
    /// Different node, but XLA's combined score for the native node is
    /// within tolerance of its own choice (an f32-rounding near-tie).
    pub near_ties: usize,
    /// Genuine disagreements.
    pub mismatches: usize,
    /// Both infeasible.
    pub both_infeasible: usize,
    /// MIG demands routed past the scorer to the native scheduler
    /// during this run (see [`mig_scorer_fallbacks`]).
    pub mig_fallbacks: u64,
}

impl ParityReport {
    /// Pass criterion: zero genuine disagreements.
    pub fn passed(&self) -> bool {
        self.mismatches == 0 && self.decisions > 0
    }
}

impl std::fmt::Display for ParityReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "parity: {} decisions | {} exact | {} near-ties | {} mismatches | {} infeasible | {} MIG fallbacks -> {}",
            self.decisions,
            self.exact_matches,
            self.near_ties,
            self.mismatches,
            self.both_infeasible,
            self.mig_fallbacks,
            if self.passed() { "PASS" } else { "FAIL" }
        )
    }
}

/// Drive a seeded inflation on a small cluster, scheduling every task
/// with both the native `PwrFgd(α)` scheduler and the XLA scorer on the
/// identical state, committing the native decision. Near-ties (k8s
/// scores within 0.05 of each other, i.e. f32 rounding) are tolerated;
/// anything else is a mismatch.
pub fn parity_check(
    artifacts: &std::path::Path,
    n_tasks: usize,
    alpha: f64,
    seed: u64,
) -> Result<ParityReport> {
    use crate::sched::PolicyKind;
    use crate::trace::TraceSpec;

    // Same α domain the policy parsers enforce: an out-of-range α would
    // silently flip the FGD weight negative on the native side only,
    // making every comparison a spurious mismatch.
    crate::sched::profile::validate_alpha(alpha, "--alpha").map_err(anyhow::Error::msg)?;
    let rt = Runtime::cpu()?;
    let mut scorer = XlaScorer::load(&rt, artifacts)?;
    // A cluster that fits the artifact's node capacity (paper_scaled
    // rounds per pool with a floor of 1 node, so leave ~20% headroom).
    let spec = crate::cluster::ClusterSpec::paper_scaled(
        (scorer.config.n as f64 / 1500.0).min(1.0),
    );
    let mut dc = spec.build();
    if dc.nodes.len() > scorer.config.n {
        anyhow::bail!("scaled cluster still exceeds artifact capacity");
    }
    let trace = TraceSpec::default_trace();
    // Truncate the workload to the artifact's class capacity so both
    // paths score against the identical target workload M.
    let workload = trace.synthesize(seed ^ 0x57AB1E).workload().top_k(scorer.config.m);
    let mut sampler = trace.sampler(seed);
    // Build through the profile lowering (the same path `--policy`
    // takes), so parity also covers the registry assembly.
    let mut native =
        PolicyKind::PwrFgd { alpha }.profile().build().map_err(anyhow::Error::msg)?;

    let mut report = ParityReport::default();
    let fallbacks_before = mig_scorer_fallbacks();
    for _ in 0..n_tasks {
        let task = sampler.next_task();
        let nd = native.schedule(&dc, &workload, &task);
        scorer.encode_cluster(&dc)?;
        scorer.encode_workload(&workload);
        let out = scorer.score(&task, alpha)?;
        let xd = decode_decision(&dc, &task, &out, scorer.config.mig);
        report.decisions += 1;
        match (&nd, &xd) {
            (None, None) => report.both_infeasible += 1,
            (Some(n), Some(x)) if n.node == x.node => report.exact_matches += 1,
            (Some(n), Some(x)) => {
                // Tolerate f32 near-ties: the XLA score of the native
                // node must be close to the XLA score of its own pick.
                let diff = (out.score[x.node] - out.score[n.node]).abs();
                if diff <= 0.05 {
                    report.near_ties += 1;
                } else {
                    report.mismatches += 1;
                    eprintln!(
                        "mismatch task {} ({:?}): native -> node {} (xla score {}), xla -> node {} (score {})",
                        task.id, task.gpu, n.node, out.score[n.node], x.node, out.score[x.node]
                    );
                }
            }
            _ => {
                report.mismatches += 1;
                eprintln!(
                    "feasibility mismatch task {} ({:?}): native {:?}, xla {:?}",
                    task.id,
                    task.gpu,
                    nd.as_ref().map(|d| d.node),
                    xd.as_ref().map(|d| d.node)
                );
            }
        }
        if let Some(d) = nd {
            dc.allocate(&task, d.node, &d.placement);
            native.notify_node_changed(d.node);
        }
    }
    report.mig_fallbacks = mig_scorer_fallbacks().saturating_sub(fallbacks_before);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses() {
        let c = ScorerConfig::from_meta(r#"{"n": 64, "g": 8, "m": 32}"#).unwrap();
        assert_eq!(c, ScorerConfig { n: 64, g: 8, m: 32, mig: false });
        let c = ScorerConfig::from_meta(r#"{"n": 64, "g": 8, "m": 32, "mig": true}"#).unwrap();
        assert!(c.mig, "MIG-aware artifacts advertise the encoding in the meta");
        assert!(ScorerConfig::from_meta("{}").is_err());
    }

    #[test]
    fn decode_prefers_highest_score_lowest_id() {
        let dc = crate::cluster::ClusterSpec::tiny(3, 2, 0).build();
        let t = Task::new(0, 1.0, 0.0, GpuDemand::Frac(0.5));
        let out = ScoreOutput {
            score: vec![50.0, 90.0, 90.0],
            best_gpu: vec![0.0, 1.0, 0.0],
            feasible: vec![1.0, 1.0, 1.0],
        };
        let d = decode_decision(&dc, &t, &out, false).unwrap();
        assert_eq!(d.node, 1); // ties → lowest id among the 90s
        assert_eq!(d.placement, Placement::Shared { gpu: 1 });
    }

    #[test]
    fn mig_demand_fallback_is_counted() {
        use crate::cluster::mig::MigProfile;
        let dc = crate::cluster::ClusterSpec::mig_cluster(2, 2, 0).build();
        let out = ScoreOutput {
            score: vec![90.0, 90.0],
            best_gpu: vec![-1.0, -1.0],
            feasible: vec![1.0, 1.0],
        };
        // Delta-based so the assertion is robust to the process-wide
        // counter being touched by concurrently-running tests.
        let before = mig_scorer_fallbacks();
        // Both lattices' demands bypass the legacy scorer and are
        // counted.
        for p in [MigProfile::P3g, MigProfile::A30P2g] {
            let t = Task::new(0, 1.0, 0.0, GpuDemand::Mig(p));
            assert!(decode_decision(&dc, &t, &out, false).is_none());
        }
        assert!(mig_scorer_fallbacks() - before >= 2);
        // Non-MIG demands decode normally (and this test adds no more
        // fallbacks itself).
        let t = Task::new(1, 1.0, 0.0, GpuDemand::Zero);
        assert!(decode_decision(&dc, &t, &out, false).is_some());
    }

    #[test]
    fn mig_aware_encoding_decodes_slices_without_fallbacks() {
        use crate::cluster::mig::MigProfile;
        let dc = crate::cluster::ClusterSpec::mig_cluster(2, 2, 0).build();
        let out = ScoreOutput {
            score: vec![90.0, 50.0],
            best_gpu: vec![1.0, -1.0],
            feasible: vec![1.0, 1.0],
        };
        let before = mig_scorer_fallbacks();
        // The graph's best_gpu hint is honored when the slice fits there.
        let t = Task::new(0, 1.0, 0.0, GpuDemand::Mig(MigProfile::P3g));
        let d = decode_decision(&dc, &t, &out, true).unwrap();
        assert_eq!(d.node, 0);
        match d.placement {
            Placement::MigSlice { gpu, start } => {
                assert_eq!(gpu, 1);
                assert!(dc.nodes[0].mig.as_ref().unwrap()[gpu].can_place(MigProfile::P3g)
                    == Some(start));
            }
            other => panic!("expected a MIG slice, got {other:?}"),
        }
        // A foreign-lattice demand on this fleet has no legal window on
        // the chosen node: decode declines, still without a fallback.
        let t = Task::new(1, 1.0, 0.0, GpuDemand::Mig(MigProfile::A30P2g));
        assert!(decode_decision(&dc, &t, &out, true).is_none());
        // The pin: a MIG-aware artifact never counts native fallbacks.
        assert_eq!(mig_scorer_fallbacks() - before, 0);
    }

    #[test]
    fn decode_none_when_all_infeasible() {
        let dc = crate::cluster::ClusterSpec::tiny(2, 2, 0).build();
        let t = Task::new(0, 1.0, 0.0, GpuDemand::Whole(1));
        let out = ScoreOutput {
            score: vec![NEG_INF_SCORE, NEG_INF_SCORE],
            best_gpu: vec![-1.0, -1.0],
            feasible: vec![0.0, 0.0],
        };
        assert!(decode_decision(&dc, &t, &out).is_none());
    }
}
