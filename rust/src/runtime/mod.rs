//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! the Rust hot path (no Python at runtime).
//!
//! `python/compile/aot.py` lowers the L2 JAX scoring graph (which calls
//! the L1 Pallas kernel) to **HLO text** — the interchange format that
//! survives the jax≥0.5 ↔ xla_extension 0.5.1 proto-id mismatch — and
//! this module compiles it once with the PJRT CPU client and executes it
//! per scheduling decision.
//!
//! The whole execution path sits behind the **`xla` cargo feature**
//! (off by default): it needs the external `xla` crate plus the PJRT
//! native toolchain, neither of which exists in a pure-Rust build
//! environment. Without the feature, [`Runtime`] and [`Artifact`] keep
//! their API but every entry point returns a descriptive error, so the
//! scorer-parity tests and benches skip cleanly (`rust/tests/
//! scorer_parity.rs` is additionally compile-gated on the feature).

pub mod scorer;

use std::path::Path;

use anyhow::Result;
#[cfg(feature = "xla")]
use anyhow::Context;

/// Wrapper over the PJRT client (CPU).
#[cfg(feature = "xla")]
pub struct Runtime {
    client: xla::PjRtClient,
}

/// A compiled HLO artifact ready for execution.
#[cfg(feature = "xla")]
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "xla")]
impl Runtime {
    /// Create a PJRT CPU client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it (once; execution is then
    /// Python-free).
    pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<Artifact> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Artifact { exe })
    }
}

#[cfg(feature = "xla")]
impl Artifact {
    /// Execute with literal inputs; returns the elements of the result
    /// tuple (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut result = self.exe.execute::<xla::Literal>(inputs)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        match result.decompose_tuple() {
            Ok(elems) => Ok(elems),
            Err(_) => Ok(vec![result]),
        }
    }
}

/// Stub runtime for builds without the `xla` feature: same API, every
/// entry point fails with a build-configuration error.
#[cfg(not(feature = "xla"))]
pub struct Runtime {
    _private: (),
}

/// Stub artifact for builds without the `xla` feature.
#[cfg(not(feature = "xla"))]
pub struct Artifact {
    _private: (),
}

#[cfg(not(feature = "xla"))]
const NO_XLA: &str =
    "built without the `xla` cargo feature; rebuild with `--features xla` (requires the \
     external `xla` crate and the PJRT toolchain) to run the AOT scorer";

#[cfg(not(feature = "xla"))]
impl Runtime {
    /// Unavailable: always errors in non-`xla` builds.
    pub fn cpu() -> Result<Runtime> {
        anyhow::bail!("{NO_XLA}")
    }

    /// Platform name placeholder.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Unavailable: always errors in non-`xla` builds.
    pub fn load_hlo_text<P: AsRef<Path>>(&self, _path: P) -> Result<Artifact> {
        anyhow::bail!("{NO_XLA}")
    }
}

/// Default artifact directory (`artifacts/` at the repo root, or
/// `$REPRO_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("REPRO_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

#[cfg(all(test, feature = "xla"))]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_starts() {
        let rt = Runtime::cpu().expect("PJRT CPU client");
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn missing_artifact_errors_cleanly() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.load_hlo_text("/nonexistent/x.hlo.txt").is_err());
    }
}

#[cfg(all(test, not(feature = "xla")))]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_errors_descriptively() {
        let err = Runtime::cpu().unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
