//! Repo-invariant static analysis — the engine behind `repro lint`.
//!
//! The reproduction's credibility rests on invariants the compiler
//! cannot see: every counter lives in [`crate::obs::METRICS_CATALOG`]
//! *and* the docs tables, every `rust/tests/` file is registered in
//! `Cargo.toml` *and* runs in CI, the place→filter→score→bind hot path
//! stays free of panicking shortcuts, and a [`ScorePlugin`]
//! (`crate::sched::ScorePlugin`) that touches interior mutability must
//! make an explicit `cacheable()` call so the revision-keyed score
//! cache's bit-identity guarantee is a decision, not an accident.
//! Before this subsystem those invariants were enforced by hand-written
//! drift tests that themselves drifted; now they are named, fixable,
//! allowlistable rules checked mechanically on every commit
//! (`docs/analysis.md` catalogues them).
//!
//! Design constraints, in the same spirit as the vendored `anyhow`
//! shim: zero dependencies, hand-rolled line/token scanning — no
//! syn/proc-macro parsing. The scanner is deliberately conservative: a
//! [`SourceFile`] carries the raw lines plus two sanitized views
//! (comments blanked; comments *and* string/char contents blanked) and
//! a `#[cfg(test)]` mask, which is enough for every rule to avoid the
//! classic greps-lie failure modes (tokens inside strings, comments,
//! or test modules).
//!
//! Rules live one-per-family under [`lint`]; suppression is inline:
//!
//! ```text
//! // lint:allow(<rule>[,<rule>…]) <reason — required>
//! ```
//!
//! on the offending line or the line directly above. An allowlist
//! comment without a reason is itself a finding.

pub mod lint;

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

/// One rule violation. `line` is 1-based; `0` means the finding is
/// file- or repo-level (e.g. a missing catalog entry has no single
/// offending line).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
    /// A concrete remediation, shown under `--fix-hints`.
    pub hint: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line > 0 {
            write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
        } else {
            write!(f, "{}: [{}] {}", self.file, self.rule, self.message)
        }
    }
}

/// The analyzed snapshot of the repository: repo-relative path →
/// contents. Loaded from disk for the real tree ([`RepoTree::load`])
/// or assembled in-memory for rule fixtures ([`RepoTree::from_files`]),
/// so every rule is a pure function of the tree.
pub struct RepoTree {
    pub files: BTreeMap<String, String>,
}

impl RepoTree {
    /// Read the analyzed subset of the repo: `Cargo.toml`, the CI
    /// workflow, `docs/*.md`, `rust/src/**/*.rs` and `rust/tests/*.rs`.
    /// Missing singletons are tolerated here (each rule reports its own
    /// missing inputs with a proper finding).
    pub fn load(root: &Path) -> io::Result<RepoTree> {
        let mut files = BTreeMap::new();
        for rel in ["Cargo.toml", ".github/workflows/ci.yml"] {
            let abs = root.join(rel);
            if abs.is_file() {
                files.insert(rel.to_string(), fs::read_to_string(&abs)?);
            }
        }
        read_dir_files(&root.join("docs"), "docs", ".md", false, &mut files)?;
        read_dir_files(&root.join("rust/src"), "rust/src", ".rs", true, &mut files)?;
        read_dir_files(&root.join("rust/tests"), "rust/tests", ".rs", false, &mut files)?;
        Ok(RepoTree { files })
    }

    /// Assemble a fixture tree for analyzer tests.
    pub fn from_files(files: &[(&str, &str)]) -> RepoTree {
        RepoTree {
            files: files.iter().map(|(p, c)| (p.to_string(), c.to_string())).collect(),
        }
    }

    pub fn get(&self, path: &str) -> Option<&str> {
        self.files.get(path).map(String::as_str)
    }

    /// Sanitized view of one Rust source file, if present.
    pub fn source(&self, path: &str) -> Option<SourceFile> {
        self.get(path).map(|c| SourceFile::new(path, c))
    }

    /// Sanitized views of every `.rs` file under `prefix`
    /// (e.g. `"rust/src/"`), in path order.
    pub fn sources(&self, prefix: &str) -> Vec<SourceFile> {
        self.files
            .iter()
            .filter(|(p, _)| p.starts_with(prefix) && p.ends_with(".rs"))
            .map(|(p, c)| SourceFile::new(p, c))
            .collect()
    }
}

/// Recursively (if `recurse`) collect files under `dir` with the given
/// extension into `files`, keyed by `rel_prefix/<subpath>`.
fn read_dir_files(
    dir: &Path,
    rel_prefix: &str,
    ext: &str,
    recurse: bool,
    files: &mut BTreeMap<String, String>,
) -> io::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    let mut entries: Vec<_> = fs::read_dir(dir)?.filter_map(|e| e.ok()).collect();
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().to_string();
        let rel = format!("{rel_prefix}/{name}");
        if path.is_dir() {
            if recurse {
                read_dir_files(&path, &rel, ext, recurse, files)?;
            }
        } else if name.ends_with(ext) {
            files.insert(rel, fs::read_to_string(&path)?);
        }
    }
    Ok(())
}

/// A Rust source file plus the sanitized views the rules scan.
///
/// * `raw_lines` — the file verbatim (allowlist comments live here).
/// * `code` — comments blanked, string *contents* kept: the view for
///   rules that read string literals (catalog keys, registry keys).
/// * `bare` — comments **and** string/char contents blanked: the view
///   for token scans (`panic!`, `Mutex<`) and brace-depth tracking,
///   immune to `"}"`-in-a-format-string style corruption.
/// * `test_mask[i]` — line `i` (0-based) is inside a `#[cfg(test)]`
///   block.
///
/// All three views preserve line structure exactly, so a line index is
/// valid across them.
pub struct SourceFile {
    pub path: String,
    pub raw_lines: Vec<String>,
    pub code: Vec<String>,
    pub bare: Vec<String>,
    pub test_mask: Vec<bool>,
}

impl SourceFile {
    pub fn new(path: &str, content: &str) -> SourceFile {
        let (code_text, bare_text) = sanitize(content);
        let raw_lines: Vec<String> = content.split('\n').map(str::to_string).collect();
        let code: Vec<String> = code_text.split('\n').map(str::to_string).collect();
        let bare: Vec<String> = bare_text.split('\n').map(str::to_string).collect();
        let test_mask = test_mask(&bare);
        SourceFile { path: path.to_string(), raw_lines, code, bare, test_mask }
    }
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Character-level sanitizer. Returns `(code, bare)`; see
/// [`SourceFile`] for what each view blanks. Handles line and nested
/// block comments, plain/byte/raw strings (`"…"`, `b"…"`, `r"…"`,
/// `r#"…"#`), char literals incl. escapes (`'x'`, `'\n'`, `'\u{…}'`,
/// `'"'`, `'{'`) and distinguishes them from lifetimes (`'a`,
/// `'static`). Newlines always pass through so line numbers survive.
fn sanitize(raw: &str) -> (String, String) {
    let b: Vec<char> = raw.chars().collect();
    let n = b.len();
    let mut code = String::with_capacity(raw.len());
    let mut bare = String::with_capacity(raw.len());
    let mut i = 0;
    // Push one source char as blank (newlines survive) to one view.
    let blank = |out: &mut String, c: char| out.push(if c == '\n' { '\n' } else { ' ' });
    while i < n {
        let c = b[i];
        // Line comment: blank to end of line in both views.
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                code.push(' ');
                bare.push(' ');
                i += 1;
            }
            continue;
        }
        // Block comment (Rust nests them): blank, keep newlines.
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 0usize;
            while i < n {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    code.push_str("  ");
                    bare.push_str("  ");
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    code.push_str("  ");
                    bare.push_str("  ");
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    blank(&mut code, b[i]);
                    blank(&mut bare, b[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw (and byte-raw) strings: r"…", r#"…"#, br#"…"#.
        if (c == 'r' || c == 'b') && (i == 0 || !is_ident_char(b[i - 1])) {
            let mut j = i;
            if b[j] == 'b' && j + 1 < n && b[j + 1] == 'r' {
                j += 1;
            }
            if b[j] == 'r' {
                let mut k = j + 1;
                let mut hashes = 0;
                while k < n && b[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && b[k] == '"' {
                    // Opening delimiter: keep in `code`, blank in `bare`.
                    for &ch in &b[i..=k] {
                        code.push(ch);
                        bare.push(' ');
                    }
                    i = k + 1;
                    // Scan for `"` followed by `hashes` hashes.
                    loop {
                        if i >= n {
                            break;
                        }
                        if b[i] == '"' && b[i + 1..].iter().take(hashes).filter(|&&h| h == '#').count() == hashes {
                            for _ in 0..=hashes {
                                if i < n {
                                    code.push(b[i]);
                                    bare.push(' ');
                                    i += 1;
                                }
                            }
                            break;
                        }
                        code.push(b[i]);
                        blank(&mut bare, b[i]);
                        i += 1;
                    }
                    continue;
                }
            }
            // Not a raw string ('r'/'b' as an ordinary char): fall through.
        }
        // Plain (and byte) string literal.
        if c == '"' || (c == 'b' && i + 1 < n && b[i + 1] == '"' && (i == 0 || !is_ident_char(b[i - 1]))) {
            if c == 'b' {
                code.push('b');
                bare.push(' ');
                i += 1;
            }
            code.push('"');
            bare.push(' ');
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    code.push(b[i]);
                    blank(&mut bare, b[i]);
                    i += 1;
                    code.push(b[i]);
                    blank(&mut bare, b[i]);
                    i += 1;
                    continue;
                }
                if b[i] == '"' {
                    code.push('"');
                    bare.push(' ');
                    i += 1;
                    break;
                }
                // Keep newlines in both views (multi-line strings).
                if b[i] == '\n' {
                    code.push('\n');
                    bare.push('\n');
                } else {
                    code.push(b[i]);
                    bare.push(' ');
                }
                i += 1;
            }
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // Escaped char literal: '\n', '\\', '\'', '\u{…}'.
                let mut k = i + 3; // opening quote, backslash, escaped char
                while k < n && b[k] != '\'' {
                    k += 1;
                }
                code.push('\'');
                bare.push(' ');
                for _ in i + 1..k {
                    code.push(' ');
                    bare.push(' ');
                }
                if k < n {
                    code.push('\'');
                    bare.push(' ');
                    k += 1;
                }
                i = k;
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'' {
                // Plain char literal — content may be '"' or '{', so
                // blank it in both views.
                code.push('\'');
                code.push(' ');
                code.push('\'');
                bare.push_str("   ");
                i += 3;
                continue;
            }
            // Lifetime tick (or stray quote): harmless, keep.
            code.push('\'');
            bare.push('\'');
            i += 1;
            continue;
        }
        code.push(c);
        bare.push(c);
        i += 1;
    }
    (code, bare)
}

/// Per-line `#[cfg(test)]` mask, computed over the `bare` view (brace
/// depth cannot be corrupted by braces in strings/comments there). The
/// attribute line, the item header and the whole brace block — closing
/// brace included — are masked.
fn test_mask(bare_text: &str) -> Vec<bool> {
    let lines: Vec<&str> = bare_text.split('\n').collect();
    let mut mask = vec![false; lines.len()];
    let mut depth: i64 = 0;
    let mut pending = false; // saw #[cfg(test)], waiting for its block
    let mut test_depth: i64 = -1;
    for (li, line) in lines.iter().enumerate() {
        if pending || test_depth >= 0 {
            mask[li] = true;
        }
        if test_depth < 0 && line.contains("#[cfg(test)]") {
            pending = true;
            mask[li] = true;
        }
        for c in line.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if pending {
                        test_depth = depth;
                        pending = false;
                    }
                }
                '}' => {
                    if depth == test_depth {
                        test_depth = -1;
                    }
                    depth -= 1;
                }
                _ => {}
            }
        }
    }
    mask
}

/// Parse an inline allowlist comment out of a raw source line:
/// `// lint:allow(rule-a,rule-b) reason text`. Returns the named rules
/// and the (possibly empty) reason.
pub fn allow_directive(raw_line: &str) -> Option<(Vec<String>, String)> {
    let marker = "// lint:allow(";
    let idx = raw_line.find(marker)?;
    let rest = &raw_line[idx + marker.len()..];
    let close = rest.find(')')?;
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let reason = rest[close + 1..].trim().to_string();
    Some((rules, reason))
}

/// Allowlist verdict for an occurrence of `rule` at 0-based `line_idx`.
pub enum Allow {
    /// No matching directive: report the violation.
    No,
    /// Suppressed by a directive with a reason.
    Yes,
    /// A directive names the rule but gives no reason — itself a
    /// finding (payload: 0-based line of the bad directive).
    MissingReason(usize),
}

/// Check the occurrence line and the line directly above for a
/// suppressing `// lint:allow(<rule>) <reason>` directive.
pub fn allowed(sf: &SourceFile, line_idx: usize, rule: &str) -> Allow {
    let candidates = [Some(line_idx), line_idx.checked_sub(1)];
    for li in candidates.into_iter().flatten() {
        if let Some(raw) = sf.raw_lines.get(li) {
            if let Some((rules, reason)) = allow_directive(raw) {
                if rules.iter().any(|r| r == rule) {
                    if reason.is_empty() {
                        return Allow::MissingReason(li);
                    }
                    return Allow::Yes;
                }
            }
        }
    }
    Allow::No
}

/// Extract `"…"` string literal contents (with their 0-based line
/// index) from a joined multi-line `code`-view snippet. Escapes are
/// skipped over, not decoded — catalog/registry keys never contain
/// them.
pub fn string_literals(code_text: &str) -> Vec<(usize, String)> {
    let b: Vec<char> = code_text.chars().collect();
    let n = b.len();
    let mut out = Vec::new();
    let mut line = 0usize;
    let mut i = 0usize;
    while i < n {
        match b[i] {
            '\n' => {
                line += 1;
                i += 1;
            }
            '"' => {
                let start_line = line;
                let mut lit = String::new();
                i += 1;
                while i < n {
                    if b[i] == '\\' && i + 1 < n {
                        lit.push(b[i]);
                        lit.push(b[i + 1]);
                        i += 2;
                        continue;
                    }
                    if b[i] == '"' {
                        i += 1;
                        break;
                    }
                    if b[i] == '\n' {
                        line += 1;
                    }
                    lit.push(b[i]);
                    i += 1;
                }
                out.push((start_line, lit));
            }
            _ => i += 1,
        }
    }
    out
}

/// Find the 0-based line range `[start, end]` of the brace block that
/// opens at or after `start_li` (tracked on the `bare` view). Returns
/// `None` when no `{` opens by `end of file` (e.g. a unit struct).
pub fn brace_block(sf: &SourceFile, start_li: usize) -> Option<(usize, usize)> {
    let mut depth: i64 = 0;
    let mut opened = false;
    for li in start_li..sf.bare.len() {
        for c in sf.bare[li].chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => {
                    depth -= 1;
                    if opened && depth == 0 {
                        return Some((start_li, li));
                    }
                }
                ';' if !opened && depth == 0 => {
                    // Item ended before any block opened (unit struct,
                    // tuple struct): the item is its header lines.
                    return Some((start_li, li));
                }
                _ => {}
            }
        }
    }
    None
}

/// 0-based line range of a bracketed const table
/// (`const NAME: &[…] = &[ … ];`) whose header is at `start_li`:
/// `[`/`]` depth is tracked from just past the `=` on the header line,
/// so the brackets in the type annotation don't close the block early.
pub fn table_block(sf: &SourceFile, start_li: usize) -> Option<(usize, usize)> {
    let mut depth: i64 = 0;
    let mut opened = false;
    for li in start_li..sf.bare.len() {
        let line = &sf.bare[li];
        let from = if li == start_li {
            line.find('=').map(|p| p + 1).unwrap_or(0)
        } else {
            0
        };
        for (bi, c) in line.char_indices() {
            if bi < from {
                continue;
            }
            match c {
                '[' => {
                    depth += 1;
                    opened = true;
                }
                ']' => {
                    depth -= 1;
                    if opened && depth == 0 {
                        return Some((start_li, li));
                    }
                }
                _ => {}
            }
        }
    }
    None
}

/// Occurrences of `token` in `line` at proper word boundaries: the
/// character before and after the match must not be identifier chars
/// (checked only where the token itself starts/ends with one).
pub fn token_occurrences(line: &str, token: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let bytes = line.as_bytes();
    let tb = token.as_bytes();
    let first_ident = token.chars().next().map(is_ident_char).unwrap_or(false);
    let last_ident = token.chars().last().map(is_ident_char).unwrap_or(false);
    let mut from = 0;
    while let Some(pos) = line[from..].find(token) {
        let at = from + pos;
        let before_ok = !first_ident
            || at == 0
            || !is_ident_char(bytes[at - 1] as char);
        let after = at + tb.len();
        let after_ok = !last_ident
            || after >= bytes.len()
            || !is_ident_char(bytes[after] as char);
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_blanks_comments_and_strings() {
        let src = "let a = \"panic!\"; // panic! here\nlet b = 1; /* unsafe */";
        let (code, bare) = sanitize(src);
        assert!(code.contains("\"panic!\""), "code keeps string contents: {code}");
        assert!(!code.contains("here"), "code blanks comments: {code}");
        assert!(!bare.contains("panic!"), "bare blanks both: {bare}");
        assert!(!bare.contains("unsafe"), "bare blanks block comments: {bare}");
        assert_eq!(code.split('\n').count(), 2);
        assert_eq!(bare.split('\n').count(), 2);
    }

    #[test]
    fn sanitize_handles_char_literals_and_lifetimes() {
        let src = "if c == '\"' { x('{', \"y\") } fn f<'a>(s: &'a str) {}";
        let (code, bare) = sanitize(src);
        // The quote char literal must not open a string.
        assert!(code.contains("\"y\""), "string after char literal intact: {code}");
        assert!(!bare.contains('{') || bare.matches('{').count() == bare.matches('}').count());
        assert!(code.contains("<'a>"), "lifetimes survive: {code}");
    }

    #[test]
    fn sanitize_handles_raw_and_escaped() {
        let src = "let r = r#\"no \" end\"#; let e = \"a\\\"b\"; let c = '\\n';";
        let (code, bare) = sanitize(src);
        assert!(code.contains("no \" end"), "{code}");
        assert!(!bare.contains("no"), "{bare}");
        assert!(code.ends_with("' ';") || code.contains("'"), "{code}");
    }

    #[test]
    fn test_mask_covers_block() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}";
        let sf = SourceFile::new("x.rs", src);
        assert_eq!(sf.test_mask, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn allow_directive_parses_rules_and_reason() {
        let (rules, reason) =
            allow_directive("    x(); // lint:allow(hot-path-hygiene, other) join is safe").unwrap();
        assert_eq!(rules, vec!["hot-path-hygiene", "other"]);
        assert_eq!(reason, "join is safe");
        let (_, empty) = allow_directive("// lint:allow(r)").unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn token_occurrences_respect_boundaries() {
        assert_eq!(token_occurrences("let unsafe_x = unsafe { 1 };", "unsafe"), vec![15]);
        assert!(token_occurrences("x.unwrap_or(1)", ".unwrap()").is_empty());
        assert_eq!(token_occurrences("x.unwrap().y.unwrap()", ".unwrap()"), vec![1, 12]);
    }
}
