//! `cacheable-purity` — the revision-keyed score cache (PR 7) reuses a
//! plugin's per-node scores bit-for-bit across decisions, keyed only on
//! (workload revision × fleet revision × node generation × task
//! signature). That is sound **iff** `score` is a pure function of the
//! key. A plugin that smuggles state through interior mutability
//! (`Mutex`, `RefCell`, `Cell`, `Atomic*`) may still be pure (a memo of
//! a pure function, like `fgd`) or genuinely impure (`random`) — but
//! either way the author must *decide* by overriding
//! [`crate::sched::ScorePlugin::cacheable`]; silently inheriting the
//! `true` default is how bit-identity guarantees rot. The dynamic side
//! of the same contract is `rust/tests/purity_check.rs`, which runs
//! every registered cacheable plugin cache-on vs cache-off vs
//! shard-permuted and asserts exact f64-bit equality.
//!
//! Scope: for each non-test `impl ScorePlugin for X`, the rule scans
//! the impl block itself, `struct X`'s definition and any inherent
//! `impl X` blocks in the same file for interior-mutability types; if
//! found and the `ScorePlugin` impl has no `fn cacheable`, it fires
//! (struct-scoped on purpose — an unrelated `RefCell` elsewhere in the
//! file is not evidence).

use crate::analysis::{allowed, brace_block, token_occurrences, Allow, Finding, RepoTree, SourceFile};

pub const RULE: &str = "cacheable-purity";

/// Interior-mutability markers: exact generic uses, with word
/// boundaries so `RefCell<` does not also count as `Cell<`. `Atomic*`
/// types are handled separately as a boundary-prefixed match.
const INTERIOR: &[&str] = &["Mutex<", "RwLock<", "RefCell<", "Cell<", "UnsafeCell<"];

/// Does this (bare-view) line mention an interior-mutability type?
fn touches_interior(line: &str) -> bool {
    if INTERIOR.iter().any(|t| !token_occurrences(line, t).is_empty()) {
        return true;
    }
    // `Atomic` as an ident *prefix* (AtomicU64, AtomicBool, …).
    let bytes = line.as_bytes();
    let mut from = 0;
    while let Some(p) = line[from..].find("Atomic") {
        let at = from + p;
        let bounded = at == 0 || !(bytes[at - 1].is_ascii_alphanumeric() || bytes[at - 1] == b'_');
        if bounded {
            return true;
        }
        from = at + 1;
    }
    false
}

pub fn check(tree: &RepoTree) -> Vec<Finding> {
    let mut out = Vec::new();
    for sf in tree.sources("rust/src/") {
        for (li, line) in sf.bare.iter().enumerate() {
            if sf.test_mask[li] {
                continue;
            }
            let Some(name) = score_impl_target(line) else {
                continue;
            };
            let Some(impl_range) = brace_block(&sf, li) else {
                continue;
            };
            let mut regions = vec![impl_range];
            regions.extend(type_regions(&sf, &name));
            let has_override = (impl_range.0..=impl_range.1)
                .any(|lj| sf.bare[lj].contains("fn cacheable"));
            let touched = regions.iter().any(|&(s, e)| {
                (s..=e.min(sf.bare.len() - 1)).any(|lj| touches_interior(&sf.bare[lj]))
            });
            if touched && !has_override {
                match allowed(&sf, li, RULE) {
                    Allow::Yes => {}
                    Allow::MissingReason(bl) => out.push(Finding {
                        rule: RULE,
                        file: sf.path.clone(),
                        line: bl + 1,
                        message: "lint:allow directive without a reason".to_string(),
                        hint: "append a short justification after the closing paren".to_string(),
                    }),
                    Allow::No => out.push(Finding {
                        rule: RULE,
                        file: sf.path.clone(),
                        line: li + 1,
                        message: format!(
                            "ScorePlugin `{name}` touches interior mutability but does not \
                             override cacheable()"
                        ),
                        hint: "add an explicit `fn cacheable(&self) -> bool` (true only if \
                               score is a pure function of the cache key; document why), or \
                               drop the interior mutability"
                            .to_string(),
                    }),
                }
            }
        }
    }
    out
}

/// `impl ScorePlugin for Name {` → `Name` (generics and the trait's
/// crate path tolerated).
fn score_impl_target(bare_line: &str) -> Option<String> {
    let pos = bare_line.find("impl")?;
    let rest = &bare_line[pos..];
    if !rest.contains("ScorePlugin") || !rest.contains(" for ") {
        return None;
    }
    let after_for = &rest[rest.find(" for ")? + " for ".len()..];
    let name: String = after_for
        .trim_start()
        .chars()
        .take_while(|&c| c.is_ascii_alphanumeric() || c == '_')
        .collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// Line ranges of `struct Name …` and inherent `impl Name {` blocks in
/// the same file (non-test).
fn type_regions(sf: &SourceFile, name: &str) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let struct_tok = format!("struct {name}");
    let impl_tok = format!("impl {name}");
    for (li, line) in sf.bare.iter().enumerate() {
        if sf.test_mask[li] {
            continue;
        }
        let is_struct = !token_occurrences(line, &struct_tok).is_empty();
        // Inherent impl only: `impl Name {` / `impl Name<…>`, not
        // `impl Trait for Name`.
        let is_inherent_impl =
            !token_occurrences(line, &impl_tok).is_empty() && !line.contains(" for ");
        if is_struct || is_inherent_impl {
            if let Some(range) = brace_block(sf, li) {
                out.push(range);
            }
        }
    }
    out
}
