//! `dsl-docs-drift` — `docs/scheduler.md` is the user-facing contract
//! for the `--policy` DSL: its grammar block must list exactly the
//! sections `parse_dsl` dispatches on, and its extension-point table
//! must list exactly the built-in registry keys. Both are checked in
//! both directions against `rust/src/sched/profile.rs` (the
//! `BUILTIN_*` tables and the `parse_dsl` match), so adding a knob
//! without documenting it — or documenting one that doesn't exist —
//! fails the lint.

use crate::analysis::{brace_block, table_block, Finding, RepoTree, SourceFile};
use std::collections::{BTreeMap, BTreeSet};

pub const RULE: &str = "dsl-docs-drift";

const PROFILE: &str = "rust/src/sched/profile.rs";
const DOC: &str = "docs/scheduler.md";

/// Extension point → its builtin registry const in `profile.rs`.
const TABLES: &[(&str, &str)] = &[
    ("score", "BUILTIN_SCORE"),
    ("bind", "BUILTIN_BIND"),
    ("mod", "BUILTIN_MODULATOR"),
    ("hook", "BUILTIN_HOOK"),
    ("filter", "BUILTIN_FILTER"),
];

pub fn check(tree: &RepoTree) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(sf) = tree.source(PROFILE) else {
        return vec![missing(PROFILE)];
    };
    let Some(doc) = tree.get(DOC) else {
        return vec![missing(DOC)];
    };

    // Registry keys ↔ the extension-point table.
    let builtin = builtin_keys_by_point(&sf);
    let documented = doc_registry_keys(doc);
    for (point, const_name) in TABLES {
        let Some(b) = builtin.get(point) else {
            out.push(Finding {
                rule: RULE,
                file: PROFILE.to_string(),
                line: 0,
                message: format!("could not parse the {const_name} registry table"),
                hint: "keep the `const BUILTIN_*: &[…] = &[…];` shape scannable".to_string(),
            });
            continue;
        };
        let empty = BTreeSet::new();
        let d = documented.get(point).unwrap_or(&empty);
        for key in b.difference(d) {
            out.push(Finding {
                rule: RULE,
                file: DOC.to_string(),
                line: 0,
                message: format!("registry key {point}/{key} missing from the extension table"),
                hint: format!("add `{key}` to the {point} row's built-in keys cell in {DOC}"),
            });
        }
        for key in d.difference(b) {
            out.push(Finding {
                rule: RULE,
                file: DOC.to_string(),
                line: 0,
                message: format!("documented key {point}/{key} is not a built-in registry key"),
                hint: "drop the stale key or add the plugin to the registry".to_string(),
            });
        }
    }

    // DSL sections ↔ the grammar block.
    let sections = dsl_sections(&sf);
    let grammar = grammar_tokens(doc);
    if sections.is_empty() {
        out.push(Finding {
            rule: RULE,
            file: PROFILE.to_string(),
            line: 0,
            message: "could not parse the parse_dsl section dispatch".to_string(),
            hint: "keep the `match name.as_str() { \"section\" => … }` shape scannable"
                .to_string(),
        });
    }
    if grammar.is_empty() {
        out.push(Finding {
            rule: RULE,
            file: DOC.to_string(),
            line: 0,
            message: "could not find the DSL grammar block".to_string(),
            hint: "keep a ```text fence under the `## DSL grammar` heading".to_string(),
        });
    }
    for s in sections.difference(&grammar) {
        out.push(Finding {
            rule: RULE,
            file: DOC.to_string(),
            line: 0,
            message: format!("DSL section '{s}(' missing from the grammar block"),
            hint: format!("add a `'{s}(' …` production to the grammar in {DOC}"),
        });
    }
    for g in grammar.difference(&sections) {
        out.push(Finding {
            rule: RULE,
            file: DOC.to_string(),
            line: 0,
            message: format!("grammar documents a '{g}(' section parse_dsl does not accept"),
            hint: "drop the stale production or implement the section".to_string(),
        });
    }
    out
}

/// The built-in registry keys per extension point, parsed from the
/// `BUILTIN_*` const tables (a key is any pure-lowercase alnum string
/// literal in the table — descriptions and error strings all carry
/// spaces, underscores or punctuation). Shared with the `profile.rs`
/// drift test, which cross-checks this parse against the runtime
/// `registry_catalog()`.
pub fn builtin_keys_by_point(sf: &SourceFile) -> BTreeMap<&'static str, BTreeSet<String>> {
    let mut out = BTreeMap::new();
    for (point, const_name) in TABLES {
        let header = format!("const {const_name}");
        let Some(start) = sf.code.iter().position(|l| l.contains(&header)) else {
            continue;
        };
        let Some((s, e)) = table_block(sf, start) else {
            continue;
        };
        let block = sf.code[s..=e].join("\n");
        let keys: BTreeSet<String> = crate::analysis::string_literals(&block)
            .into_iter()
            .map(|(_, lit)| lit)
            .filter(|lit| is_registry_key(lit))
            .collect();
        if !keys.is_empty() {
            out.insert(*point, keys);
        }
    }
    out
}

fn is_registry_key(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_lowercase())
        && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit())
}

/// Section names `parse_dsl` dispatches on: string literals directly
/// followed by `=>` inside the function body.
pub fn dsl_sections(sf: &SourceFile) -> BTreeSet<String> {
    let Some(start) = sf.code.iter().position(|l| l.contains("fn parse_dsl")) else {
        return BTreeSet::new();
    };
    let Some((s, e)) = brace_block(sf, start) else {
        return BTreeSet::new();
    };
    let block: Vec<char> = sf.code[s..=e].join("\n").chars().collect();
    let mut out = BTreeSet::new();
    let mut i = 0;
    while i < block.len() {
        if block[i] == '"' {
            let mut j = i + 1;
            let mut lit = String::new();
            while j < block.len() && block[j] != '"' {
                if block[j] == '\\' && j + 1 < block.len() {
                    j += 2;
                    lit.push('\\');
                    continue;
                }
                lit.push(block[j]);
                j += 1;
            }
            let mut k = j + 1;
            while k < block.len() && block[k].is_whitespace() {
                k += 1;
            }
            if block.get(k) == Some(&'=') && block.get(k + 1) == Some(&'>') && is_registry_key(&lit)
            {
                out.insert(lit);
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    out
}

/// Extension-table rows in `docs/scheduler.md`: first cell names the
/// point (the `weightModulator` / `postPlace…` rows map to `mod` /
/// `hook`), third cell lists backticked keys whose parameter suffixes
/// (`:α`, `[:key=value…]`) are stripped at the first `:` or `[`.
fn doc_registry_keys(doc: &str) -> BTreeMap<&'static str, BTreeSet<String>> {
    let mut out: BTreeMap<&'static str, BTreeSet<String>> = BTreeMap::new();
    for line in doc.lines() {
        let t = line.trim();
        if !t.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = t.trim_matches('|').split('|').collect();
        if cells.len() < 3 {
            continue;
        }
        let first = cells[0].replace('`', "");
        let first = first.trim();
        let point = if first == "filter" {
            "filter"
        } else if first == "score" {
            "score"
        } else if first == "bind" {
            "bind"
        } else if first.contains("weightModulator") {
            "mod"
        } else if first.contains("postPlace") || first.contains("postFail") {
            "hook"
        } else {
            continue;
        };
        let keys = out.entry(point).or_default();
        let mut rest = cells[2];
        while let Some(open) = rest.find('`') {
            let tail = &rest[open + 1..];
            let Some(close) = tail.find('`') else { break };
            let full = &tail[..close];
            let stem = full
                .split(|c| c == ':' || c == '[')
                .next()
                .unwrap_or("")
                .trim();
            if is_registry_key(stem) {
                keys.insert(stem.to_string());
            }
            rest = &tail[close + 1..];
        }
    }
    out
}

/// `'section('` tokens inside the ```text fence under `## DSL grammar`.
fn grammar_tokens(doc: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let mut in_heading = false;
    let mut in_fence = false;
    for line in doc.lines() {
        if line.trim_start().starts_with("## ") {
            in_heading = line.contains("DSL grammar");
            continue;
        }
        if in_heading && line.trim_start().starts_with("```") {
            if in_fence {
                break; // closing fence: done
            }
            in_fence = true;
            continue;
        }
        if !in_fence {
            continue;
        }
        let chars: Vec<char> = line.chars().collect();
        let mut i = 0;
        while i < chars.len() {
            if chars[i] == '\'' {
                let mut j = i + 1;
                let mut tok = String::new();
                while j < chars.len() && chars[j].is_ascii_lowercase() {
                    tok.push(chars[j]);
                    j += 1;
                }
                if !tok.is_empty()
                    && chars.get(j) == Some(&'(')
                    && chars.get(j + 1) == Some(&'\'')
                {
                    out.insert(tok);
                    i = j + 2;
                    continue;
                }
            }
            i += 1;
        }
    }
    out
}

fn missing(file: &str) -> Finding {
    Finding {
        rule: RULE,
        file: file.to_string(),
        line: 0,
        message: "required input file is missing from the tree".to_string(),
        hint: "restore the file (or fix RepoTree::load coverage)".to_string(),
    }
}
