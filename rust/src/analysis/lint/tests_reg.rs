//! `test-registration` — a test file that exists but is not wired into
//! `Cargo.toml` never runs under `cargo test`, and a `[[test]]` target
//! with no CI step can silently rot on CI-only regressions (PR 6
//! shipped exactly this retro-fix for `drs_equivalence`). Three-way
//! check:
//!
//! 1. every `rust/tests/*.rs` file has a `[[test]]` target whose
//!    `path` points at it;
//! 2. every `[[test]]` target's `path` exists in the tree;
//! 3. every `[[test]]` target's `name` appears as `--test <name>` in a
//!    (non-comment) line of `.github/workflows/ci.yml`.

use crate::analysis::{Finding, RepoTree};
use std::collections::BTreeSet;

pub const RULE: &str = "test-registration";

const MANIFEST: &str = "Cargo.toml";
const CI: &str = ".github/workflows/ci.yml";

pub fn check(tree: &RepoTree) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(manifest) = tree.get(MANIFEST) else {
        return vec![missing(MANIFEST)];
    };
    let targets = test_targets(manifest);

    // (1) every test file is registered.
    for path in tree.files.keys() {
        if !(path.starts_with("rust/tests/") && path.ends_with(".rs")) {
            continue;
        }
        if !targets.iter().any(|t| t.path.as_deref() == Some(path.as_str())) {
            out.push(Finding {
                rule: RULE,
                file: path.clone(),
                line: 0,
                message: format!("no [[test]] target in {MANIFEST} points at this file"),
                hint: format!(
                    "add `[[test]]\\nname = \"<stem>\"\\npath = \"{path}\"` to {MANIFEST}"
                ),
            });
        }
    }

    // (2) every target's path exists; (3) every target runs in CI.
    let ci_tests = tree.get(CI).map(ci_test_names);
    for t in &targets {
        match &t.path {
            None => out.push(Finding {
                rule: RULE,
                file: MANIFEST.to_string(),
                line: t.line + 1,
                message: format!("[[test]] target \"{}\" has no path", t.name_or("?")),
                hint: "add a `path = \"rust/tests/….rs\"` entry".to_string(),
            }),
            Some(p) => {
                if tree.get(p).is_none() {
                    out.push(Finding {
                        rule: RULE,
                        file: MANIFEST.to_string(),
                        line: t.line + 1,
                        message: format!("[[test]] path \"{p}\" does not exist"),
                        hint: "fix the path or delete the stale target".to_string(),
                    });
                }
            }
        }
        match (&t.name, &ci_tests) {
            (None, _) => out.push(Finding {
                rule: RULE,
                file: MANIFEST.to_string(),
                line: t.line + 1,
                message: "[[test]] target has no name".to_string(),
                hint: "add a `name = \"…\"` entry".to_string(),
            }),
            (Some(name), Some(ci)) => {
                if !ci.contains(name.as_str()) {
                    out.push(Finding {
                        rule: RULE,
                        file: CI.to_string(),
                        line: 0,
                        message: format!(
                            "test target \"{name}\" has no `--test {name}` step in CI"
                        ),
                        hint: format!(
                            "add (or extend) a `cargo test -q --test {name}` step in {CI}"
                        ),
                    });
                }
            }
            (Some(_), None) => {}
        }
    }
    if tree.get(CI).is_none() {
        out.push(missing(CI));
    }
    out
}

struct TestTarget {
    name: Option<String>,
    path: Option<String>,
    /// 0-based line of the `[[test]]` header.
    line: usize,
}

impl TestTarget {
    fn name_or<'a>(&'a self, dflt: &'a str) -> &'a str {
        self.name.as_deref().unwrap_or(dflt)
    }
}

/// Minimal TOML walk: `[[test]]` opens a target, any other `[`-header
/// closes it, `name =` / `path =` quoted values fill it in.
fn test_targets(manifest: &str) -> Vec<TestTarget> {
    let mut out: Vec<TestTarget> = Vec::new();
    let mut open = false;
    for (li, raw) in manifest.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line == "[[test]]" {
            out.push(TestTarget { name: None, path: None, line: li });
            open = true;
            continue;
        }
        if line.starts_with('[') {
            open = false;
            continue;
        }
        if !open {
            continue;
        }
        if let Some(t) = out.last_mut() {
            if let Some(v) = toml_string_value(line, "name") {
                t.name = Some(v);
            }
            if let Some(v) = toml_string_value(line, "path") {
                t.path = Some(v);
            }
        }
    }
    out
}

fn toml_string_value(line: &str, key: &str) -> Option<String> {
    let rest = line.strip_prefix(key)?.trim_start().strip_prefix('=')?.trim();
    let rest = rest.strip_prefix('"')?;
    Some(rest[..rest.find('"')?].to_string())
}

/// Every `--test <name>` mention in the workflow, YAML comments
/// stripped so a commented-out step doesn't satisfy the rule.
fn ci_test_names(ci: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for raw in ci.lines() {
        let line = match raw.find('#') {
            Some(i) if i == 0 || raw[..i].ends_with(' ') => &raw[..i],
            _ => raw,
        };
        let mut rest = line;
        while let Some(pos) = rest.find("--test ") {
            let tail = &rest[pos + "--test ".len()..];
            let name: String = tail
                .chars()
                .take_while(|&c| c.is_ascii_alphanumeric() || c == '_')
                .collect();
            if !name.is_empty() {
                out.insert(name);
            }
            rest = tail;
        }
    }
    out
}

fn missing(file: &str) -> Finding {
    Finding {
        rule: RULE,
        file: file.to_string(),
        line: 0,
        message: "required input file is missing from the tree".to_string(),
        hint: "restore the file (or fix RepoTree::load coverage)".to_string(),
    }
}
