//! `hot-path-hygiene` — the place→filter→score→bind protocol modules
//! are the code every single decision runs through; a stray `unwrap()`
//! there turns an internal invariant slip into a scheduler crash that
//! takes the whole simulated (or served) fleet down. Production
//! schedulers treat this path as no-panic territory; so do we. The
//! rule bans `unwrap()` / `expect(` / `panic!` / `unsafe` in the five
//! protocol files outside `#[cfg(test)]` blocks, unless an inline
//! `// lint:allow(hot-path-hygiene) <reason>` documents why the panic
//! is genuinely unreachable or the right failure mode (e.g. a poisoned
//! scoped-thread join, or debug-only validation).
//!
//! `debug_assert!`/`assert!` are deliberately *not* banned: assertions
//! state invariants; the banned tokens hide fallibility.

use crate::analysis::{allowed, token_occurrences, Allow, Finding, RepoTree};

pub const RULE: &str = "hot-path-hygiene";

/// The protocol modules (`docs/scheduler.md` pipeline order).
pub const HOT_PATH_FILES: &[&str] = &[
    "rust/src/sched/framework.rs",
    "rust/src/sched/filter.rs",
    "rust/src/sched/bind.rs",
    "rust/src/sched/drs.rs",
    "rust/src/sched/gang.rs",
];

/// Banned tokens. `.unwrap()` with the parens so `unwrap_or…`
/// combinators stay legal; `expect(` with the paren so
/// `.expect_err` or idents containing "expect" don't match.
const TOKENS: &[&str] = &[".unwrap()", ".expect(", "panic!", "unsafe"];

pub fn check(tree: &RepoTree) -> Vec<Finding> {
    let mut out = Vec::new();
    for path in HOT_PATH_FILES {
        let Some(sf) = tree.source(path) else {
            // A renamed/removed protocol file is a rule-config drift,
            // not silently fine.
            out.push(Finding {
                rule: RULE,
                file: path.to_string(),
                line: 0,
                message: "hot-path file listed in the rule is missing".to_string(),
                hint: "update HOT_PATH_FILES in rust/src/analysis/lint/hotpath.rs".to_string(),
            });
            continue;
        };
        for (li, line) in sf.bare.iter().enumerate() {
            if sf.test_mask[li] {
                continue;
            }
            for token in TOKENS {
                for _ in token_occurrences(line, token) {
                    match allowed(&sf, li, RULE) {
                        Allow::Yes => {}
                        Allow::MissingReason(bl) => out.push(Finding {
                            rule: RULE,
                            file: sf.path.clone(),
                            line: bl + 1,
                            message: "lint:allow directive without a reason".to_string(),
                            hint: "append a short justification after the closing paren"
                                .to_string(),
                        }),
                        Allow::No => out.push(Finding {
                            rule: RULE,
                            file: sf.path.clone(),
                            line: li + 1,
                            message: format!("`{token}` on the scheduling hot path"),
                            hint: "restructure to an infallible form (get_or_insert_with, \
                                   match, let-else), or allowlist with a documented reason"
                                .to_string(),
                        }),
                    }
                }
            }
        }
    }
    out
}
