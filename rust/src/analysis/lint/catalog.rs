//! `catalog-drift` — the observability catalog is the single source of
//! truth for framework metrics (PR 6's contract). Three directions are
//! checked:
//!
//! 1. every key written through `.inc("…")` / `.observe_ns("…")` or
//!    read through `.counter("…")` in non-test `rust/src/**` exists in
//!    [`crate::obs::METRICS_CATALOG`];
//! 2. every catalog key is actually referenced somewhere in non-test
//!    `rust/src/**` outside the catalog definition itself (no
//!    zombie entries);
//! 3. every catalog key appears in the `docs/observability.md` metrics
//!    table, and every key that table documents is in the catalog.
//!
//! Dynamic keys (hook-reported counters, coordinator gauges) pass
//! through the registry by design and are written via variables, not
//! string literals at the call sites this rule scans — so the catalog
//! stays a complete map of the *built-in* fleet without banning
//! extensions.

use crate::analysis::{allowed, string_literals, Allow, Finding, RepoTree, SourceFile};
use std::collections::{BTreeMap, BTreeSet};

pub const RULE: &str = "catalog-drift";

const OBS_PATH: &str = "rust/src/obs/mod.rs";
const DOC_PATH: &str = "docs/observability.md";

/// Registry write/read call patterns whose first argument is a
/// catalogued key. Built by concatenation so the analyzer's own source
/// never contains a scannable call-site pattern.
fn call_patterns() -> Vec<String> {
    [".inc", ".observe_ns", ".counter"]
        .iter()
        .map(|m| format!("{m}(\""))
        .collect()
}

pub fn check(tree: &RepoTree) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(obs) = tree.source(OBS_PATH) else {
        out.push(missing(OBS_PATH, "the metrics-catalog module is missing"));
        return out;
    };
    let Some((catalog, block_range)) = catalog_keys(&obs) else {
        out.push(missing(OBS_PATH, "could not locate the METRICS_CATALOG table"));
        return out;
    };
    let catalog_set: BTreeSet<&str> = catalog.iter().map(String::as_str).collect();
    let patterns = call_patterns();

    // (1) call-site keys ⊆ catalog, and collect quoted references for (2).
    let mut quoted: BTreeSet<String> = BTreeSet::new();
    for sf in tree.sources("rust/src/") {
        let in_catalog_block =
            |li: usize| sf.path == OBS_PATH && li >= block_range.0 && li <= block_range.1;
        for (li, line) in sf.code.iter().enumerate() {
            if sf.test_mask[li] {
                continue;
            }
            if !in_catalog_block(li) {
                for (_, lit) in string_literals(line) {
                    if !lit.is_empty() && lit.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
                    {
                        quoted.insert(lit);
                    }
                }
            }
            for pat in &patterns {
                let mut from = 0;
                while let Some(pos) = line[from..].find(pat.as_str()) {
                    let at = from + pos + pat.len();
                    let key: String = line[at..]
                        .chars()
                        .take_while(|&c| c.is_ascii_alphanumeric() || c == '_')
                        .collect();
                    from = at;
                    // Only ident-shaped literal keys are checkable; a
                    // dynamic first argument never matches `("`.
                    if key.is_empty() || !line[at + key.len()..].starts_with('"') {
                        continue;
                    }
                    if !catalog_set.contains(key.as_str()) {
                        match allowed(&sf, li, RULE) {
                            Allow::Yes => {}
                            Allow::MissingReason(bl) => out.push(no_reason(&sf.path, bl)),
                            Allow::No => out.push(Finding {
                                rule: RULE,
                                file: sf.path.clone(),
                                line: li + 1,
                                message: format!(
                                    "metric key \"{key}\" is not in METRICS_CATALOG"
                                ),
                                hint: format!(
                                    "add (\"{key}\", MetricKind::…, \"…\") to METRICS_CATALOG in \
                                     {OBS_PATH} and a row to {DOC_PATH}, or fix the key"
                                ),
                            }),
                        }
                    }
                }
            }
        }
    }

    // (2) catalog ⊆ referenced-somewhere (zombie entries).
    for key in &catalog {
        if !quoted.contains(key) {
            out.push(Finding {
                rule: RULE,
                file: OBS_PATH.to_string(),
                line: 0,
                message: format!(
                    "catalog key \"{key}\" is never referenced in non-test rust/src code"
                ),
                hint: format!("wire \"{key}\" up at its call site or drop the catalog entry"),
            });
        }
    }

    // (3) catalog ↔ docs/observability.md metrics table.
    match tree.get(DOC_PATH) {
        None => out.push(missing(DOC_PATH, "the observability doc is missing")),
        Some(doc) => {
            let doc_keys = doc_table_keys(doc);
            for key in &catalog {
                if !doc_keys.contains_key(key.as_str()) {
                    out.push(Finding {
                        rule: RULE,
                        file: DOC_PATH.to_string(),
                        line: 0,
                        message: format!("catalog key \"{key}\" missing from the metrics table"),
                        hint: format!("add a `| kind | \\`{key}\\` | meaning |` row"),
                    });
                }
            }
            for (key, line) in &doc_keys {
                if !catalog_set.contains(key.as_str()) {
                    out.push(Finding {
                        rule: RULE,
                        file: DOC_PATH.to_string(),
                        line: line + 1,
                        message: format!(
                            "documented metric \"{key}\" is not in METRICS_CATALOG"
                        ),
                        hint: "drop the stale row or add the catalog entry".to_string(),
                    });
                }
            }
        }
    }
    out
}

/// Parse the `METRICS_CATALOG` const: a key is a string literal whose
/// following tokens are `, MetricKind::…` (entries may span lines).
/// Returns the keys plus the 0-based line range of the whole table so
/// reference scans can exclude the definition itself.
fn catalog_keys(obs: &SourceFile) -> Option<(Vec<String>, (usize, usize))> {
    let start = obs.code.iter().position(|l| l.contains("METRICS_CATALOG"))?;
    let (s, e) = crate::analysis::table_block(obs, start)?;
    let block: Vec<char> = obs.code[s..=e].join("\n").chars().collect();
    let mut keys = Vec::new();
    let mut i = 0;
    while i < block.len() {
        if block[i] == '"' {
            let mut j = i + 1;
            let mut lit = String::new();
            while j < block.len() && block[j] != '"' {
                if block[j] == '\\' && j + 1 < block.len() {
                    j += 2; // keys never contain escapes; skip them
                    continue;
                }
                lit.push(block[j]);
                j += 1;
            }
            if next_is_metric_kind(&block, j + 1) {
                keys.push(lit);
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    if keys.is_empty() {
        None
    } else {
        Some((keys, (s, e)))
    }
}

/// After a candidate key literal, an entry reads `, MetricKind::…` —
/// possibly across a line break.
fn next_is_metric_kind(block: &[char], mut k: usize) -> bool {
    while k < block.len() && block[k].is_whitespace() {
        k += 1;
    }
    if k >= block.len() || block[k] != ',' {
        return false;
    }
    k += 1;
    while k < block.len() && block[k].is_whitespace() {
        k += 1;
    }
    let pat: Vec<char> = "MetricKind::".chars().collect();
    block.get(k..k + pat.len()) == Some(&pat[..])
}

/// Backticked ident keys from metrics-table rows (first cell is a
/// metric kind), mapped to their 0-based line. A single cell may list
/// several keys (`` `drs_sleeps` / `drs_wakes` ``).
fn doc_table_keys(doc: &str) -> BTreeMap<String, usize> {
    let mut out = BTreeMap::new();
    for (li, line) in doc.lines().enumerate() {
        let t = line.trim();
        if !t.starts_with('|') {
            continue;
        }
        let first_cell = t.trim_start_matches('|').split('|').next().unwrap_or("").trim();
        if !matches!(first_cell, "counter" | "gauge" | "histogram") {
            continue;
        }
        let mut rest = t;
        while let Some(open) = rest.find('`') {
            let tail = &rest[open + 1..];
            let Some(close) = tail.find('`') else { break };
            let key = &tail[..close];
            if !key.is_empty() && key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                out.entry(key.to_string()).or_insert(li);
            }
            rest = &tail[close + 1..];
        }
    }
    out
}

fn missing(file: &str, what: &str) -> Finding {
    Finding {
        rule: RULE,
        file: file.to_string(),
        line: 0,
        message: what.to_string(),
        hint: "restore the file (or fix RepoTree::load coverage)".to_string(),
    }
}

fn no_reason(file: &str, line_idx: usize) -> Finding {
    Finding {
        rule: RULE,
        file: file.to_string(),
        line: line_idx + 1,
        message: "lint:allow directive without a reason".to_string(),
        hint: "append a short justification after the closing paren".to_string(),
    }
}
