//! The lint rule families — one module per family, each exposing
//! `RULE` (the allowlistable name) and `check(&RepoTree) -> Vec<Finding>`.
//!
//! | rule | invariant |
//! |------|-----------|
//! | `catalog-drift` | metric keys ↔ `METRICS_CATALOG` ↔ `docs/observability.md` |
//! | `test-registration` | `rust/tests/*` ↔ `Cargo.toml [[test]]` ↔ CI steps |
//! | `hot-path-hygiene` | no `unwrap`/`expect`/`panic!`/`unsafe` on the place path |
//! | `cacheable-purity` | interior-mutability `ScorePlugin`s declare `cacheable()` |
//! | `dsl-docs-drift` | DSL sections + registry keys ↔ `docs/scheduler.md` |
//!
//! `docs/analysis.md` is the narrative catalog (rationale, allowlist
//! syntax, fix guidance).

pub mod catalog;
pub mod dsl_docs;
pub mod hotpath;
pub mod purity;
pub mod tests_reg;

use super::{Finding, RepoTree};

pub use dsl_docs::builtin_keys_by_point;

/// Every rule family: `(name, one-line description, check fn)`.
pub const RULES: &[(&str, &str, fn(&RepoTree) -> Vec<Finding>)] = &[
    (
        catalog::RULE,
        "metric keys referenced in src ↔ METRICS_CATALOG ↔ docs/observability.md",
        catalog::check,
    ),
    (
        tests_reg::RULE,
        "every rust/tests file has a Cargo.toml [[test]] target and a CI step",
        tests_reg::check,
    ),
    (
        hotpath::RULE,
        "no unwrap/expect/panic!/unsafe in the place→filter→score→bind modules",
        hotpath::check,
    ),
    (
        purity::RULE,
        "ScorePlugins touching interior mutability must override cacheable()",
        purity::check,
    ),
    (
        dsl_docs::RULE,
        "profile-DSL sections and registry keys ↔ docs/scheduler.md grammar/tables",
        dsl_docs::check,
    ),
];

/// Run every rule family over the tree; findings in rule order.
pub fn run_all(tree: &RepoTree) -> Vec<Finding> {
    RULES.iter().flat_map(|(_, _, check)| check(tree)).collect()
}

/// The registry/docs/catalog drift subset — the shared implementation
/// behind `repro list-plugins --check` and the `profile.rs` drift test.
pub fn registry_drift(tree: &RepoTree) -> Vec<Finding> {
    let mut out = catalog::check(tree);
    out.extend(dsl_docs::check(tree));
    out
}
