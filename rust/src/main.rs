//! `repro` — CLI for the PWR+FGD GPU-datacenter scheduling system.
//!
//! ```text
//! repro simulate   --policy pwrfgd:0.1 --trace default --seed 42 [--scale 0.25] [--target 1.02] [--trace-decisions t.jsonl] [--obs-summary obs_summary.json]
//! repro experiment <table1|table2|fig1..fig10|ext-mig|ext-mig-het|ext-profiles|ext-filters|ext-drs|ext-gang|ext-fairness|all> [--reps 10] [--scale 1.0] [--out results] [--trace-decisions t.jsonl]
//! repro ext-mig    [--reps 10] [--scale 1.0] [--out results]   (MIG subsystem end-to-end)
//! repro ext-mig-het [--reps 10] [--scale 1.0] [--out results]  (mixed A100+A30 MIG fleet)
//! repro ext-profiles [--reps 10] [--scale 1.0] [--out results] (composite profile DSL sweep)
//! repro ext-filters [--reps 10] [--scale 1.0] [--out results]  (constraint-aware filter sweep)
//! repro ext-drs    [--reps 10] [--scale 1.0] [--out results]   (DRS sleep/wake on diurnal load)
//! repro ext-gang   [--reps 10] [--scale 1.0] [--out results]   (topology-aware gang scheduling)
//! repro ext-fairness [--reps 10] [--scale 1.0] [--out results] (pending-queue fairness sweep)
//! repro list-plugins [--check]                                 (every registry key + description; --check exits non-zero on registry/docs/catalog drift)
//! repro lint       [--json] [--fix-hints] [--root DIR]         (repo-invariant static analysis — docs/analysis.md)
//! repro explain    [--policy pwrfgd:0.1] [--trace default] [--seed 42] [--at 1] [--top 5]
//! repro bench-scale [--quick] [--out BENCH_scale.json]         (scale sweep + phase latencies)
//! repro trace      <default|multi-gpu-20|sharing-gpu-100|constrained-50|mig-30|diurnal-60|...> [--seed 42]
//! repro inventory
//! repro serve      [--addr 127.0.0.1:7077] [--policy pwrfgd:0.1]
//! repro scorer-check [--artifacts artifacts] [--tasks 200]   (XLA vs native parity)
//! ```
//!
//! `--policy` accepts every legacy policy name (`fgd`, `pwrfgd:0.1`,
//! `mig-pwrfgd:0.1`, …) *and* the scheduler-profile DSL
//! (docs/scheduler.md):
//!
//! ```text
//! --policy "score(pwr=0.5,fgd=0.3,dotprod=0.2)|bind(weighted:0.5)|mod(loadalpha:0.9:0.0)|filter(resources,gpumodel,labels:zone=z0)"
//! ```
//!
//! Observability (`docs/observability.md`): `--trace-decisions <path>`
//! streams one JSONL event per scheduling decision, `--obs-summary
//! <path>` writes the metrics-registry snapshot (phase-latency
//! histograms included), `repro explain` replays one arrival and
//! pretty-prints its scoring table, and `repro bench-scale` regenerates
//! `BENCH_scale.json`.

use anyhow::{bail, Context, Result};
use repro::cluster::ClusterSpec;
use repro::coordinator::{CoordinatorState, Server};
use repro::experiments::{ExpConfig, Harness};
use repro::sched::SchedulerProfile;
use repro::sim::Simulation;
use repro::trace::TraceSpec;
use repro::util::cli::parse_args;

const VALUE_KEYS: &[&str] = &[
    "policy", "trace", "seed", "scale", "target", "reps", "out", "addr", "alpha",
    "artifacts", "tasks", "trace-decisions", "obs-summary", "at", "top", "root",
];

fn main() -> Result<()> {
    let args = parse_args(std::env::args().skip(1), VALUE_KEYS);
    match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("experiment") => cmd_experiment(&args, None),
        // Shortcuts: `repro ext-mig` / `repro ext-mig-het` run the MIG
        // subsystem / heterogeneous-fleet experiments.
        Some("ext-mig") => cmd_experiment(&args, Some("ext-mig")),
        Some("ext-mig-het") => cmd_experiment(&args, Some("ext-mig-het")),
        Some("ext-profiles") => cmd_experiment(&args, Some("ext-profiles")),
        Some("ext-filters") => cmd_experiment(&args, Some("ext-filters")),
        Some("ext-drs") => cmd_experiment(&args, Some("ext-drs")),
        Some("ext-gang") => cmd_experiment(&args, Some("ext-gang")),
        Some("ext-fairness") => cmd_experiment(&args, Some("ext-fairness")),
        Some("list-plugins") => cmd_list_plugins(&args),
        Some("lint") => cmd_lint(&args),
        Some("explain") => cmd_explain(&args),
        Some("bench-scale") => cmd_bench_scale(&args),
        Some("trace") => cmd_trace(&args),
        Some("inventory") => cmd_inventory(),
        Some("serve") => cmd_serve(&args),
        Some("scorer-check") => cmd_scorer_check(&args),
        Some("plot") => cmd_plot(&args),
        _ => {
            eprintln!(
                "usage: repro <simulate|experiment|ext-mig|ext-mig-het|ext-profiles|ext-filters|ext-drs|ext-gang|ext-fairness|list-plugins|lint|explain|bench-scale|trace|inventory|serve|scorer-check|plot> [options]\n\
                 see rust/src/main.rs header for details"
            );
            Ok(())
        }
    }
}

/// Print every registered extension-point key (score / bind / mod /
/// hook / filter) with its one-line description — the discoverability
/// companion of the `--policy` DSL (docs/scheduler.md). `--check`
/// additionally runs the registry/docs/catalog drift rules of the
/// static analyzer (docs/analysis.md) and exits non-zero on drift.
fn cmd_list_plugins(args: &repro::util::cli::Args) -> Result<()> {
    println!("{:<8} {:<16} description", "point", "key");
    for (kind, key, desc) in repro::sched::profile::registry_catalog() {
        println!("{kind:<8} {key:<16} {desc}");
    }
    // The metrics catalog rides along: every registry key the
    // observability layer maintains (docs/observability.md).
    println!();
    println!("{:<10} {:<26} description", "metric", "key");
    for (key, kind, desc) in repro::obs::catalog() {
        let kind = match kind {
            repro::obs::MetricKind::Counter => "counter",
            repro::obs::MetricKind::Gauge => "gauge",
            repro::obs::MetricKind::Histogram => "histogram",
        };
        println!("{kind:<10} {key:<26} {desc}");
    }
    if args.has_flag("check") {
        let root = lint_root(args)?;
        let tree = repro::analysis::RepoTree::load(&root)
            .with_context(|| format!("reading repo tree at {}", root.display()))?;
        let findings = repro::analysis::lint::registry_drift(&tree);
        println!();
        if findings.is_empty() {
            println!("list-plugins --check: registries, docs and catalog agree");
        } else {
            for f in &findings {
                eprintln!("{f}");
            }
            eprintln!("list-plugins --check: {} drift finding(s)", findings.len());
            std::process::exit(1);
        }
    }
    Ok(())
}

/// Resolve the repo root for analysis commands: `--root DIR`, or the
/// nearest ancestor of the current directory holding a `Cargo.toml`.
fn lint_root(args: &repro::util::cli::Args) -> Result<std::path::PathBuf> {
    if let Some(dir) = args.opt("root") {
        return Ok(std::path::PathBuf::from(dir));
    }
    let mut dir = std::env::current_dir().context("resolving current directory")?;
    loop {
        if dir.join("Cargo.toml").is_file() {
            return Ok(dir);
        }
        if !dir.pop() {
            bail!("no Cargo.toml found above the current directory; pass --root <repo>");
        }
    }
}

/// `repro lint` — run every repo-invariant rule (docs/analysis.md) and
/// exit non-zero on findings. `--json` emits machine-readable output,
/// `--fix-hints` appends each finding's remediation hint.
fn cmd_lint(args: &repro::util::cli::Args) -> Result<()> {
    use repro::analysis::{lint, RepoTree};
    let root = lint_root(args)?;
    let tree = RepoTree::load(&root)
        .with_context(|| format!("reading repo tree at {}", root.display()))?;
    let findings = lint::run_all(&tree);
    if args.has_flag("json") {
        // One JSON object per line (same JSONL convention as the
        // decision trace) so CI annotations can stream it.
        use repro::util::json::Json;
        for f in &findings {
            let obj = Json::obj(vec![
                ("rule", Json::Str(f.rule.to_string())),
                ("file", Json::Str(f.file.clone())),
                ("line", Json::Num(f.line as f64)),
                ("message", Json::Str(f.message.clone())),
                ("hint", Json::Str(f.hint.clone())),
            ]);
            println!("{}", obj.dump());
        }
    } else {
        for f in &findings {
            println!("{f}");
            if args.has_flag("fix-hints") {
                println!("    hint: {}", f.hint);
            }
        }
    }
    let files = tree.files.len();
    let rules = lint::RULES.len();
    if findings.is_empty() {
        if !args.has_flag("json") {
            println!("repro lint: clean ({rules} rules over {files} files)");
        }
        Ok(())
    } else {
        eprintln!("repro lint: {} finding(s) ({rules} rules over {files} files)", findings.len());
        std::process::exit(1);
    }
}

/// Render experiment CSVs to SVG. With no positional args, plots every
/// CSV under `--out` (default `results/`).
fn cmd_plot(args: &repro::util::cli::Args) -> Result<()> {
    use repro::util::plot::{plot_csv, PlotConfig};
    let dir = args.get("out", "results");
    let files: Vec<String> = if args.positional.is_empty() {
        let mut v: Vec<String> = std::fs::read_dir(&dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path().display().to_string())
            .filter(|p| p.ends_with(".csv") && !p.contains("bench_") && !p.contains("table"))
            .collect();
        v.sort();
        v
    } else {
        args.positional.clone()
    };
    for f in files {
        let text = std::fs::read_to_string(&f)?;
        let stem = f.trim_end_matches(".csv");
        let name = std::path::Path::new(stem)
            .file_name()
            .unwrap()
            .to_string_lossy()
            .to_string();
        let mut cfg = PlotConfig { title: name.clone(), ..Default::default() };
        // Figure-appropriate axes.
        if name.starts_with("fig2_grar") || name.starts_with("fig7") || name.starts_with("fig8")
            || name.starts_with("fig9") || name.starts_with("fig10")
        {
            cfg.y_label = "GRAR".into();
            cfg.y_range = Some((0.82, 1.005));
            cfg.x_range = Some((0.7, 1.02));
        } else if name.starts_with("fig1") {
            cfg.y_label = "EOPC (MW) / GPU share".into();
        } else if name.contains("savings") || name.starts_with("fig3") || name.starts_with("fig4")
            || name.starts_with("fig5") || name.starts_with("fig6")
        {
            cfg.y_label = "power savings vs FGD (%)".into();
        }
        let svg = plot_csv(&text, &cfg);
        let out = format!("{stem}.svg");
        std::fs::write(&out, svg)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cluster_for(scale: f64) -> ClusterSpec {
    if scale >= 1.0 {
        ClusterSpec::paper_default()
    } else {
        ClusterSpec::paper_scaled(scale)
    }
}

/// Parse `--policy`: legacy policy names and the profile DSL both work
/// (see [`SchedulerProfile::parse`]).
fn policy_from(args: &repro::util::cli::Args) -> Result<SchedulerProfile> {
    let name = args.get("policy", "pwrfgd:0.1");
    SchedulerProfile::parse(&name).map_err(anyhow::Error::msg)
}

fn cmd_simulate(args: &repro::util::cli::Args) -> Result<()> {
    let policy = policy_from(args)?;
    let trace_name = args.get("trace", "default");
    let spec = TraceSpec::by_name(&trace_name)
        .with_context(|| format!("unknown trace '{trace_name}'"))?;
    let seed = args.get_u64("seed", 42);
    let scale = args.get_f64("scale", 1.0);
    let target = args.get_f64("target", 1.02);

    let dc = cluster_for(scale).build();
    eprintln!(
        "cluster: {} nodes / {} GPUs / {} vCPUs; policy {}; trace {}",
        dc.nodes.len(),
        dc.total_gpus(),
        dc.total_vcpus(),
        policy.label,
        spec.name
    );
    let workload = spec.synthesize(seed ^ 0x57AB1E).workload();
    let mut sched = policy.build().map_err(anyhow::Error::msg)?;
    if let Some(path) = args.opt("trace-decisions") {
        let sink = repro::obs::TraceSink::file(path)
            .with_context(|| format!("cannot open trace sink '{path}'"))?;
        sched.set_tracer(repro::obs::DecisionTracer::new(sink, &policy.label, seed));
        eprintln!("tracing decisions to {path}");
    }
    let obs_summary = args.opt("obs-summary").map(str::to_string);
    if obs_summary.is_some() {
        sched.enable_profiling(true);
    }
    let mut sim = Simulation::with_spec(dc, sched, &spec, workload, seed);
    sim.record_frag = false;
    let t0 = std::time::Instant::now();
    let out = sim.run_inflation(target);
    let dt = t0.elapsed().as_secs_f64();
    sim.sched.trace_flush();
    if let Some(path) = obs_summary {
        std::fs::write(&path, format!("{}\n", sim.sched.metrics().to_json().dump()))
            .with_context(|| format!("cannot write obs summary '{path}'"))?;
        eprintln!("wrote {path}");
    }
    println!(
        "submitted {} scheduled {} failed {} in {:.1}s ({:.0} decisions/s)",
        out.submitted,
        out.scheduled,
        out.failed,
        dt,
        out.submitted as f64 / dt
    );
    println!(
        "final EOPC {:.1} kW | GRAR {:.4} | arrived {:.0} GPU units",
        out.final_eopc() / 1e3,
        out.final_grar(),
        out.arrived_gpu_units
    );
    if out.gangs_placed + out.gangs_failed > 0 {
        println!(
            "gangs placed {} failed {} | cross-node TP violations {} | mean PP span {:.2}",
            out.gangs_placed,
            out.gangs_failed,
            out.gang_tp_violations,
            out.gang_pp_span_sum as f64 / out.gangs_placed.max(1) as f64
        );
    }
    Ok(())
}

fn cmd_experiment(args: &repro::util::cli::Args, forced_id: Option<&str>) -> Result<()> {
    let id = match forced_id {
        Some(id) => id.to_string(),
        None => args
            .positional
            .first()
            .cloned()
            .unwrap_or_else(|| "all".to_string()),
    };
    let trace_sink = match args.opt("trace-decisions") {
        Some(path) => {
            let sink = repro::obs::TraceSink::file(path)
                .with_context(|| format!("cannot open trace sink '{path}'"))?;
            eprintln!("tracing decisions to {path}");
            Some(sink)
        }
        None => None,
    };
    let cfg = ExpConfig {
        reps: args.get_usize("reps", 10),
        seed: args.get_u64("seed", 42),
        scale: args.get_f64("scale", 1.0),
        target: args.get_f64("target", 1.02),
        out_dir: args.get("out", "results"),
        trace_sink: trace_sink.clone(),
    };
    let mut harness = Harness::new(cfg);
    let files = harness.run(&id)?;
    if let Some(sink) = &trace_sink {
        sink.flush();
    }
    for f in files {
        println!("wrote {f}");
    }
    Ok(())
}

fn cmd_trace(args: &repro::util::cli::Args) -> Result<()> {
    let name = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "default".to_string());
    let spec = TraceSpec::by_name(&name).with_context(|| format!("unknown trace '{name}'"))?;
    let trace = spec.synthesize(args.get_u64("seed", 42));
    println!("trace {} ({} tasks)", trace.name, trace.tasks.len());
    println!("bucket       population%   gpu-share%");
    let pop = trace.population_pct();
    let share = trace.gpu_share_pct();
    for (i, b) in ["0", "(0,1)", "1", "2", "4", "8"].iter().enumerate() {
        println!("{b:<12} {:>10.2} {:>12.2}", pop[i], share[i]);
    }
    let w = trace.workload();
    println!("workload classes: {}", w.classes().len());
    Ok(())
}

fn cmd_inventory() -> Result<()> {
    let spec = ClusterSpec::paper_default();
    println!(
        "nodes {} | GPUs {} | vCPUs {}",
        spec.total_nodes(),
        spec.total_gpus(),
        spec.total_vcpus()
    );
    println!("model     amount  idle W  TDP W");
    for (m, count) in spec.gpus_by_model() {
        println!("{:<9} {:>6} {:>7} {:>6}", m.to_string(), count, m.p_idle(), m.p_max());
    }
    Ok(())
}

fn cmd_serve(args: &repro::util::cli::Args) -> Result<()> {
    let policy = policy_from(args)?;
    let addr = args.get("addr", "127.0.0.1:7077");
    let scale = args.get_f64("scale", 1.0);
    let spec = TraceSpec::default_trace();
    let workload = spec.synthesize(7).workload();
    let label = policy.label.clone();
    let state = CoordinatorState::new(cluster_for(scale).build(), policy, workload);
    let server = Server::bind(&addr, state)?;
    eprintln!("coordinator listening on {addr} (policy {label})");
    server.run()?;
    Ok(())
}

/// Replay one arrival of a simulated run and pretty-print the decision
/// trace: PreFilter verdict, per-filter vetoes, the scoring table
/// (winner + runners-up with per-plugin normalized scores and
/// post-modulator weights), tie-break and bind. The replay commits the
/// first `--at − 1` decisions exactly as `simulate` would, then
/// explains the `--at`-th without committing it.
fn cmd_explain(args: &repro::util::cli::Args) -> Result<()> {
    use repro::util::json::Json;
    let policy = policy_from(args)?;
    let trace_name = args.get("trace", "default");
    let spec = TraceSpec::by_name(&trace_name)
        .with_context(|| format!("unknown trace '{trace_name}'"))?;
    let seed = args.get_u64("seed", 42);
    let scale = args.get_f64("scale", 1.0);
    let nth = args.get_u64("at", 1).max(1);
    let top_k = args.get_usize("top", 5);
    let dc = cluster_for(scale).build();
    let workload = spec.synthesize(seed ^ 0x57AB1E).workload();
    let sched = policy.build().map_err(anyhow::Error::msg)?;
    let mut sim = Simulation::with_spec(dc, sched, &spec, workload, seed);
    let ev = sim.explain_arrival(nth, top_k);
    println!(
        "explain: arrival #{nth} on trace {} (policy {}, seed {seed})",
        spec.name, policy.label
    );
    if let Some(t) = ev.get("task") {
        println!(
            "task id {} | cpu {} | mem {} | gpu {}",
            t.get("id").and_then(Json::as_u64).unwrap_or(0),
            t.get("cpu").and_then(Json::as_f64).unwrap_or(0.0),
            t.get("mem").and_then(Json::as_f64).unwrap_or(0.0),
            t.get("gpu").and_then(Json::as_str).unwrap_or("-"),
        );
    }
    if let Some(p) = ev.get("prefilter") {
        match p.get("vetoed_by").and_then(Json::as_str) {
            Some(by) => println!("prefilter: veto (by {by})"),
            None => println!(
                "prefilter: {}",
                p.get("verdict").and_then(Json::as_str).unwrap_or("-")
            ),
        }
    }
    if let Some(Json::Arr(filters)) = ev.get("filters") {
        for f in filters {
            let vetoes = f.get("vetoes").and_then(Json::as_u64).unwrap_or(0);
            if vetoes > 0 {
                println!(
                    "filter {:<14} vetoed {vetoes} node(s)",
                    f.get("name").and_then(Json::as_str).unwrap_or("?")
                );
            }
        }
    }
    println!(
        "feasible nodes: {}",
        ev.get("feasible").and_then(Json::as_u64).unwrap_or(0)
    );
    if let Some(Json::Arr(ws)) = ev.get("weights") {
        let rendered: Vec<String> = ws
            .iter()
            .map(|w| {
                format!(
                    "{}(w={:.3})",
                    w.get("plugin").and_then(Json::as_str).unwrap_or("?"),
                    w.get("weight").and_then(Json::as_f64).unwrap_or(0.0)
                )
            })
            .collect();
        if !rendered.is_empty() {
            println!("score plugins: {}", rendered.join(" "));
        }
    }
    if let Some(Json::Arr(scores)) = ev.get("scores") {
        if !scores.is_empty() {
            println!("{:<6} {:>10}  per-plugin (normalized)", "node", "combined");
        }
        for row in scores {
            let per: Vec<String> = match row.get("per_plugin") {
                Some(Json::Obj(m)) => m
                    .iter()
                    .map(|(k, v)| format!("{k}={:.4}", v.as_f64().unwrap_or(0.0)))
                    .collect(),
                _ => Vec::new(),
            };
            let winner = row.get("winner").and_then(Json::as_bool).unwrap_or(false);
            println!(
                "{:<6} {:>10.4}  {}{}",
                row.get("node").and_then(Json::as_u64).unwrap_or(0),
                row.get("combined").and_then(Json::as_f64).unwrap_or(0.0),
                per.join(" "),
                if winner { "  <- winner" } else { "" }
            );
        }
    }
    let ties = ev.get("ties").and_then(Json::as_u64).unwrap_or(0);
    if ties > 1 {
        println!(
            "tie-break: {ties} nodes at max score (tie seed {})",
            ev.get("tie_seed").and_then(Json::as_u64).unwrap_or(0)
        );
    }
    if let Some(b @ Json::Obj(_)) = ev.get("bind") {
        println!(
            "bind: node {} via {} ({} candidate placement(s))",
            b.get("node").and_then(Json::as_u64).unwrap_or(0),
            b.get("placement").and_then(Json::as_str).unwrap_or("?"),
            b.get("candidates").and_then(Json::as_u64).unwrap_or(0)
        );
    }
    println!(
        "outcome: {}",
        ev.get("outcome").and_then(Json::as_str).unwrap_or("?")
    );
    Ok(())
}

/// The `bench-scale` scenario sweep: inflation and steady-state churn
/// at two cluster sizes, with the phase-latency breakdown from a
/// profiled run, the decision-tracing overhead (plain vs null-sink
/// tracer) on the small inflation scenario, and the fast-path speedup
/// cell (naive loop vs score cache + sharded scoring, bit-identical
/// decisions) on the large inflation. Writes `BENCH_scale.json`
/// (committed at the repo root; regenerate with `repro bench-scale`).
/// `--quick` (or `REPRO_BENCH_FAST=1`) shrinks cluster sizes and
/// sample counts for the CI smoke while keeping the schema identical.
fn cmd_bench_scale(args: &repro::util::cli::Args) -> Result<()> {
    use repro::obs::{DecisionTracer, MetricsRegistry, TraceSink};
    use repro::sched::{PolicyKind, Scheduler};
    use repro::sim::events::{SteadyConfig, SteadySim};
    use repro::util::benchkit::{BenchConfig, BenchResult, Bencher};
    use repro::util::json::Json;
    use std::time::Duration;

    let quick = args.has_flag("quick")
        || std::env::var("REPRO_BENCH_FAST").as_deref() == Ok("1");
    let out_path = args.get("out", "BENCH_scale.json");
    let policy = PolicyKind::PwrFgd { alpha: 0.1 };
    // ~1k nodes is paper scale; ~10k is the order-of-magnitude stress
    // point. --quick shrinks both (the JSON records the actual counts).
    let (small, large) = if quick { (64, 256) } else { (1_000, 10_000) };
    let target = if quick { 0.4 } else { 1.0 };
    let horizon = if quick { 400.0 } else { 6_000.0 };
    let bc = BenchConfig {
        warmup: Duration::from_millis(if quick { 0 } else { 200 }),
        measure: Duration::from_secs(if quick { 1 } else { 20 }),
        max_samples: if quick { 1 } else { 5 },
        min_samples: 1,
    };
    let spec = TraceSpec::default_trace();

    // One full inflation run; returns (decisions, metrics snapshot).
    let run_inflation = |nodes: usize, profiled: bool, traced: bool, seed: u64| {
        let dc = ClusterSpec::tiny(nodes, 8, nodes / 8).build();
        let mut sched = Scheduler::from_policy(policy);
        sched.enable_profiling(profiled);
        if traced {
            let label = sched.label().to_string();
            sched.set_tracer(DecisionTracer::new(TraceSink::null(), &label, seed));
        }
        let workload = spec.synthesize(seed ^ 0x57AB1E).workload();
        let mut sim = Simulation::with_spec(dc, sched, &spec, workload, seed);
        sim.record_frag = false;
        let out = sim.run_inflation(target);
        (out.submitted, sim.sched.metrics())
    };
    // One steady-state churn run; returns (protocol entries, metrics).
    let run_churn = |nodes: usize, profiled: bool, seed: u64| {
        let cfg = SteadyConfig {
            mean_interarrival_s: 1.0,
            mean_duration_s: horizon / 10.0,
            horizon_s: horizon,
            sample_every_s: horizon / 40.0,
            seed,
        };
        let dc = ClusterSpec::tiny(nodes, 8, nodes / 8).build();
        let mut sched = Scheduler::from_policy(policy);
        sched.enable_profiling(profiled);
        let mut sim = SteadySim::new(dc, sched, &spec, &cfg);
        let r = sim.run(&cfg);
        (r.arrivals + r.departures, sim.sched().metrics())
    };

    let phase_json = |metrics: &MetricsRegistry| -> Json {
        let phases = [
            "phase_filter_ns", "phase_score_ns", "phase_bind_ns", "phase_hooks_ns",
            "place_ns",
        ]
        .iter()
        .filter_map(|key| metrics.histogram(key).map(|h| (key.to_string(), h.to_json())))
        .collect();
        Json::Obj(phases)
    };
    let scenario_json = |name: &str,
                         mode: &str,
                         nodes: usize,
                         decisions: u64,
                         r: &BenchResult,
                         metrics: &MetricsRegistry| {
        let per_s = if r.mean_ns() > 0.0 {
            decisions as f64 / (r.mean_ns() * 1e-9)
        } else {
            0.0
        };
        Json::obj(vec![
            ("name", Json::Str(name.into())),
            ("mode", Json::Str(mode.into())),
            ("nodes", Json::Num(nodes as f64)),
            ("decisions", Json::Num(decisions as f64)),
            ("run_mean_ns", Json::Num(r.mean_ns())),
            ("run_p50_ns", Json::Num(r.p50_ns())),
            ("run_p99_ns", Json::Num(r.p99_ns())),
            ("samples", Json::Num(r.samples_ns.len() as f64)),
            ("decisions_per_s", Json::Num(per_s)),
            ("phase_latency", phase_json(metrics)),
        ])
    };

    let mut scenarios = Vec::new();
    let mut b = Bencher::unfiltered(bc.clone());
    for (name, nodes) in [("inflate_small", small), ("inflate_large", large)] {
        let mut decisions = 0u64;
        b.bench(name, || {
            decisions = run_inflation(nodes, false, false, 42).0;
        });
        // A separate profiled run feeds the phase-latency breakdown
        // (profiling stays off in the timed samples above).
        let (_, metrics) = run_inflation(nodes, true, false, 42);
        let r = b.results().last().expect("bench ran");
        scenarios.push(scenario_json(name, "inflation", nodes, decisions, r, &metrics));
    }
    for (name, nodes) in [("churn_small", small), ("churn_large", large)] {
        let mut decisions = 0u64;
        b.bench(name, || {
            decisions = run_churn(nodes, false, 42).0;
        });
        let (_, metrics) = run_churn(nodes, true, 42);
        let r = b.results().last().expect("bench ran");
        scenarios.push(scenario_json(name, "churn", nodes, decisions, r, &metrics));
    }

    // Tracing overhead on the small inflation scenario: plain vs a
    // null-sink tracer (full capture + serialization cost, no IO).
    // Acceptance gate: < 5% mean-latency overhead.
    let mut bo = Bencher::unfiltered(bc.clone());
    bo.bench("inflate_small_plain", || run_inflation(small, false, false, 7).0);
    bo.bench("inflate_small_traced", || run_inflation(small, false, true, 7).0);
    let plain = bo.results()[0].mean_ns();
    let traced = bo.results()[1].mean_ns();
    let overhead_pct = if plain > 0.0 { (traced - plain) / plain * 100.0 } else { 0.0 };

    // Fast-path speedup at the large inflation cell: naive loop (score
    // cache off, sequential scoring) vs the scale-out fast path
    // (revision-keyed cache + sharded scoring). Sampling stays at 100%
    // so both runs make bit-identical decisions and only throughput
    // differs; the acceptance gate is >= 1.5x decisions/s at the
    // full-size (10k-node) cell.
    let shards = std::thread::available_parallelism()
        .map(|n| n.get().min(8))
        .unwrap_or(1);
    let mut cache_hits = 0u64;
    let mut cache_misses = 0u64;
    let mut shard_batches = 0u64;
    let mut run_fastpath = |fast: bool| -> (u64, f64) {
        let run = || {
            let dc = ClusterSpec::tiny(large, 8, large / 8).build();
            let mut sched = Scheduler::from_policy(policy);
            if fast {
                sched.set_score_shards(shards);
            } else {
                sched.set_score_cache(false);
            }
            let workload = spec.synthesize(42 ^ 0x57AB1E).workload();
            let mut sim = Simulation::with_spec(dc, sched, &spec, workload, 42);
            sim.record_frag = false;
            let out = sim.run_inflation(target);
            (out.submitted, sim.sched.metrics())
        };
        let mut bf = Bencher::unfiltered(bc.clone());
        let mut decisions = 0u64;
        let name = if fast { "inflate_large_fast" } else { "inflate_large_naive" };
        bf.bench(name, || {
            let (d, metrics) = run();
            decisions = d;
            if fast {
                cache_hits = metrics.counter("score_cache_hits");
                cache_misses = metrics.counter("score_cache_misses");
                shard_batches = metrics.counter("score_shard_batches");
            }
        });
        let mean_ns = bf.results()[0].mean_ns();
        let per_s = if mean_ns > 0.0 { decisions as f64 / (mean_ns * 1e-9) } else { 0.0 };
        (decisions, per_s)
    };
    let (naive_decisions, naive_per_s) = run_fastpath(false);
    let (fast_decisions, fast_per_s) = run_fastpath(true);
    let speedup = if naive_per_s > 0.0 { fast_per_s / naive_per_s } else { 0.0 };

    let doc = Json::obj(vec![
        ("bench", Json::Str("scale".into())),
        ("quick", Json::Bool(quick)),
        ("policy", Json::Str(policy.label())),
        ("scenarios", Json::Arr(scenarios)),
        (
            "trace_overhead",
            Json::obj(vec![
                ("scenario", Json::Str(format!("inflate_small ({small} nodes)"))),
                ("plain_mean_ns", Json::Num(plain)),
                ("traced_mean_ns", Json::Num(traced)),
                ("overhead_pct", Json::Num(overhead_pct)),
            ]),
        ),
        (
            "fast_path",
            Json::obj(vec![
                ("scenario", Json::Str(format!("inflate_large ({large} nodes)"))),
                ("shards", Json::Num(shards as f64)),
                ("naive_decisions", Json::Num(naive_decisions as f64)),
                ("fast_decisions", Json::Num(fast_decisions as f64)),
                ("decisions_match", Json::Bool(naive_decisions == fast_decisions)),
                ("naive_decisions_per_s", Json::Num(naive_per_s)),
                ("fast_decisions_per_s", Json::Num(fast_per_s)),
                ("speedup", Json::Num(speedup)),
                ("score_cache_hits", Json::Num(cache_hits as f64)),
                ("score_cache_misses", Json::Num(cache_misses as f64)),
                ("score_shard_batches", Json::Num(shard_batches as f64)),
            ]),
        ),
    ]);
    std::fs::write(&out_path, format!("{}\n", doc.dump()))
        .with_context(|| format!("cannot write '{out_path}'"))?;
    println!(
        "wrote {out_path} (tracing overhead {overhead_pct:.2}% on the {small}-node inflation, \
         fast-path speedup {speedup:.2}x on the {large}-node inflation)"
    );
    Ok(())
}

fn cmd_scorer_check(args: &repro::util::cli::Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.get("artifacts", "artifacts"));
    let n_tasks = args.get_usize("tasks", 200);
    let alpha = args.get_f64("alpha", 0.1);
    let report = repro::runtime::scorer::parity_check(&dir, n_tasks, alpha, 42)?;
    println!("{report}");
    if !report.passed() {
        bail!("parity check failed");
    }
    Ok(())
}
