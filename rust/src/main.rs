//! `repro` — CLI for the PWR+FGD GPU-datacenter scheduling system.
//!
//! ```text
//! repro simulate   --policy pwrfgd:0.1 --trace default --seed 42 [--scale 0.25] [--target 1.02]
//! repro experiment <table1|table2|fig1..fig10|ext-mig|ext-mig-het|ext-profiles|ext-filters|ext-drs|all> [--reps 10] [--scale 1.0] [--out results]
//! repro ext-mig    [--reps 10] [--scale 1.0] [--out results]   (MIG subsystem end-to-end)
//! repro ext-mig-het [--reps 10] [--scale 1.0] [--out results]  (mixed A100+A30 MIG fleet)
//! repro ext-profiles [--reps 10] [--scale 1.0] [--out results] (composite profile DSL sweep)
//! repro ext-filters [--reps 10] [--scale 1.0] [--out results]  (constraint-aware filter sweep)
//! repro ext-drs    [--reps 10] [--scale 1.0] [--out results]   (DRS sleep/wake on diurnal load)
//! repro list-plugins                                           (every registry key + description)
//! repro trace      <default|multi-gpu-20|sharing-gpu-100|constrained-50|mig-30|diurnal-60|...> [--seed 42]
//! repro inventory
//! repro serve      [--addr 127.0.0.1:7077] [--policy pwrfgd:0.1]
//! repro scorer-check [--artifacts artifacts] [--tasks 200]   (XLA vs native parity)
//! ```
//!
//! `--policy` accepts every legacy policy name (`fgd`, `pwrfgd:0.1`,
//! `mig-pwrfgd:0.1`, …) *and* the scheduler-profile DSL
//! (docs/scheduler.md):
//!
//! ```text
//! --policy "score(pwr=0.5,fgd=0.3,dotprod=0.2)|bind(weighted:0.5)|mod(loadalpha:0.9:0.0)|filter(resources,gpumodel,labels:zone=z0)"
//! ```

use anyhow::{bail, Context, Result};
use repro::cluster::ClusterSpec;
use repro::coordinator::{CoordinatorState, Server};
use repro::experiments::{ExpConfig, Harness};
use repro::sched::SchedulerProfile;
use repro::sim::Simulation;
use repro::trace::TraceSpec;
use repro::util::cli::parse_args;

const VALUE_KEYS: &[&str] = &[
    "policy", "trace", "seed", "scale", "target", "reps", "out", "addr", "alpha",
    "artifacts", "tasks",
];

fn main() -> Result<()> {
    let args = parse_args(std::env::args().skip(1), VALUE_KEYS);
    match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("experiment") => cmd_experiment(&args, None),
        // Shortcuts: `repro ext-mig` / `repro ext-mig-het` run the MIG
        // subsystem / heterogeneous-fleet experiments.
        Some("ext-mig") => cmd_experiment(&args, Some("ext-mig")),
        Some("ext-mig-het") => cmd_experiment(&args, Some("ext-mig-het")),
        Some("ext-profiles") => cmd_experiment(&args, Some("ext-profiles")),
        Some("ext-filters") => cmd_experiment(&args, Some("ext-filters")),
        Some("ext-drs") => cmd_experiment(&args, Some("ext-drs")),
        Some("list-plugins") => cmd_list_plugins(),
        Some("trace") => cmd_trace(&args),
        Some("inventory") => cmd_inventory(),
        Some("serve") => cmd_serve(&args),
        Some("scorer-check") => cmd_scorer_check(&args),
        Some("plot") => cmd_plot(&args),
        _ => {
            eprintln!(
                "usage: repro <simulate|experiment|ext-mig|ext-mig-het|ext-profiles|ext-filters|ext-drs|list-plugins|trace|inventory|serve|scorer-check|plot> [options]\n\
                 see rust/src/main.rs header for details"
            );
            Ok(())
        }
    }
}

/// Print every registered extension-point key (score / bind / mod /
/// hook / filter) with its one-line description — the discoverability
/// companion of the `--policy` DSL (docs/scheduler.md).
fn cmd_list_plugins() -> Result<()> {
    println!("{:<8} {:<16} description", "point", "key");
    for (kind, key, desc) in repro::sched::profile::registry_catalog() {
        println!("{kind:<8} {key:<16} {desc}");
    }
    Ok(())
}

/// Render experiment CSVs to SVG. With no positional args, plots every
/// CSV under `--out` (default `results/`).
fn cmd_plot(args: &repro::util::cli::Args) -> Result<()> {
    use repro::util::plot::{plot_csv, PlotConfig};
    let dir = args.get("out", "results");
    let files: Vec<String> = if args.positional.is_empty() {
        let mut v: Vec<String> = std::fs::read_dir(&dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path().display().to_string())
            .filter(|p| p.ends_with(".csv") && !p.contains("bench_") && !p.contains("table"))
            .collect();
        v.sort();
        v
    } else {
        args.positional.clone()
    };
    for f in files {
        let text = std::fs::read_to_string(&f)?;
        let stem = f.trim_end_matches(".csv");
        let name = std::path::Path::new(stem)
            .file_name()
            .unwrap()
            .to_string_lossy()
            .to_string();
        let mut cfg = PlotConfig { title: name.clone(), ..Default::default() };
        // Figure-appropriate axes.
        if name.starts_with("fig2_grar") || name.starts_with("fig7") || name.starts_with("fig8")
            || name.starts_with("fig9") || name.starts_with("fig10")
        {
            cfg.y_label = "GRAR".into();
            cfg.y_range = Some((0.82, 1.005));
            cfg.x_range = Some((0.7, 1.02));
        } else if name.starts_with("fig1") {
            cfg.y_label = "EOPC (MW) / GPU share".into();
        } else if name.contains("savings") || name.starts_with("fig3") || name.starts_with("fig4")
            || name.starts_with("fig5") || name.starts_with("fig6")
        {
            cfg.y_label = "power savings vs FGD (%)".into();
        }
        let svg = plot_csv(&text, &cfg);
        let out = format!("{stem}.svg");
        std::fs::write(&out, svg)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cluster_for(scale: f64) -> ClusterSpec {
    if scale >= 1.0 {
        ClusterSpec::paper_default()
    } else {
        ClusterSpec::paper_scaled(scale)
    }
}

/// Parse `--policy`: legacy policy names and the profile DSL both work
/// (see [`SchedulerProfile::parse`]).
fn policy_from(args: &repro::util::cli::Args) -> Result<SchedulerProfile> {
    let name = args.get("policy", "pwrfgd:0.1");
    SchedulerProfile::parse(&name).map_err(anyhow::Error::msg)
}

fn cmd_simulate(args: &repro::util::cli::Args) -> Result<()> {
    let policy = policy_from(args)?;
    let trace_name = args.get("trace", "default");
    let spec = TraceSpec::by_name(&trace_name)
        .with_context(|| format!("unknown trace '{trace_name}'"))?;
    let seed = args.get_u64("seed", 42);
    let scale = args.get_f64("scale", 1.0);
    let target = args.get_f64("target", 1.02);

    let dc = cluster_for(scale).build();
    eprintln!(
        "cluster: {} nodes / {} GPUs / {} vCPUs; policy {}; trace {}",
        dc.nodes.len(),
        dc.total_gpus(),
        dc.total_vcpus(),
        policy.label,
        spec.name
    );
    let workload = spec.synthesize(seed ^ 0x57AB1E).workload();
    let sched = policy.build().map_err(anyhow::Error::msg)?;
    let mut sim = Simulation::with_spec(dc, sched, &spec, workload, seed);
    sim.record_frag = false;
    let t0 = std::time::Instant::now();
    let out = sim.run_inflation(target);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "submitted {} scheduled {} failed {} in {:.1}s ({:.0} decisions/s)",
        out.submitted,
        out.scheduled,
        out.failed,
        dt,
        out.submitted as f64 / dt
    );
    println!(
        "final EOPC {:.1} kW | GRAR {:.4} | arrived {:.0} GPU units",
        out.final_eopc() / 1e3,
        out.final_grar(),
        out.arrived_gpu_units
    );
    Ok(())
}

fn cmd_experiment(args: &repro::util::cli::Args, forced_id: Option<&str>) -> Result<()> {
    let id = match forced_id {
        Some(id) => id.to_string(),
        None => args
            .positional
            .first()
            .cloned()
            .unwrap_or_else(|| "all".to_string()),
    };
    let cfg = ExpConfig {
        reps: args.get_usize("reps", 10),
        seed: args.get_u64("seed", 42),
        scale: args.get_f64("scale", 1.0),
        target: args.get_f64("target", 1.02),
        out_dir: args.get("out", "results"),
    };
    let mut harness = Harness::new(cfg);
    let files = harness.run(&id)?;
    for f in files {
        println!("wrote {f}");
    }
    Ok(())
}

fn cmd_trace(args: &repro::util::cli::Args) -> Result<()> {
    let name = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "default".to_string());
    let spec = TraceSpec::by_name(&name).with_context(|| format!("unknown trace '{name}'"))?;
    let trace = spec.synthesize(args.get_u64("seed", 42));
    println!("trace {} ({} tasks)", trace.name, trace.tasks.len());
    println!("bucket       population%   gpu-share%");
    let pop = trace.population_pct();
    let share = trace.gpu_share_pct();
    for (i, b) in ["0", "(0,1)", "1", "2", "4", "8"].iter().enumerate() {
        println!("{b:<12} {:>10.2} {:>12.2}", pop[i], share[i]);
    }
    let w = trace.workload();
    println!("workload classes: {}", w.classes().len());
    Ok(())
}

fn cmd_inventory() -> Result<()> {
    let spec = ClusterSpec::paper_default();
    println!(
        "nodes {} | GPUs {} | vCPUs {}",
        spec.total_nodes(),
        spec.total_gpus(),
        spec.total_vcpus()
    );
    println!("model     amount  idle W  TDP W");
    for (m, count) in spec.gpus_by_model() {
        println!("{:<9} {:>6} {:>7} {:>6}", m.to_string(), count, m.p_idle(), m.p_max());
    }
    Ok(())
}

fn cmd_serve(args: &repro::util::cli::Args) -> Result<()> {
    let policy = policy_from(args)?;
    let addr = args.get("addr", "127.0.0.1:7077");
    let scale = args.get_f64("scale", 1.0);
    let spec = TraceSpec::default_trace();
    let workload = spec.synthesize(7).workload();
    let label = policy.label.clone();
    let state = CoordinatorState::new(cluster_for(scale).build(), policy, workload);
    let server = Server::bind(&addr, state)?;
    eprintln!("coordinator listening on {addr} (policy {label})");
    server.run()?;
    Ok(())
}

fn cmd_scorer_check(args: &repro::util::cli::Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.get("artifacts", "artifacts"));
    let n_tasks = args.get_usize("tasks", 200);
    let alpha = args.get_f64("alpha", 0.1);
    let report = repro::runtime::scorer::parity_check(&dir, n_tasks, alpha, 42)?;
    println!("{report}");
    if !report.passed() {
        bail!("parity check failed");
    }
    Ok(())
}
